"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes (all multiples of the block sizes, as enforced by
the AOT shape buckets) and data distributions; fixed-seed numpy cases cover
the exact artifact shapes used by the rust coordinator.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import facility_gain_sums, pairwise_sqdist, rbf_kernel
from compile.kernels.ref import (
    facility_gain_sums_ref,
    info_gain_ref,
    pairwise_sqdist_ref,
    rbf_kernel_ref,
)

RNG = np.random.default_rng(1234)


def randn(*shape, scale=1.0):
    return jnp.asarray(RNG.normal(scale=scale, size=shape), dtype=jnp.float32)


# ---------------------------------------------------------------- pairwise


class TestPairwiseSqdist:
    def test_exact_artifact_shape_d8(self):
        x, y = randn(64, 8), randn(1024, 8)
        np.testing.assert_allclose(
            pairwise_sqdist(x, y), pairwise_sqdist_ref(x, y), atol=1e-4
        )

    def test_exact_artifact_shape_d32(self):
        x, y = randn(64, 32), randn(1024, 32)
        np.testing.assert_allclose(
            pairwise_sqdist(x, y), pairwise_sqdist_ref(x, y), atol=1e-4
        )

    def test_identical_points_zero(self):
        x = randn(64, 16)
        d2 = pairwise_sqdist(x, jnp.tile(x, (4, 1))[:256])
        # diagonal of the first block must be ~0 and never negative
        diag = jnp.diagonal(d2[:, :64])
        assert float(jnp.max(jnp.abs(diag))) < 1e-4
        assert float(jnp.min(d2)) >= 0.0

    def test_symmetry(self):
        x = randn(256, 8)
        d2 = pairwise_sqdist(x, x)
        np.testing.assert_allclose(d2, d2.T, atol=1e-4)

    def test_large_magnitude_stability(self):
        x, y = randn(64, 8, scale=100.0), randn(256, 8, scale=100.0)
        ref = pairwise_sqdist_ref(x, y)
        got = pairwise_sqdist(x, y)
        np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-2)

    @settings(max_examples=20, deadline=None)
    @given(
        mi=st.integers(1, 3),
        nj=st.integers(1, 4),
        d=st.sampled_from([4, 8, 16, 32]),
        seed=st.integers(0, 2**31 - 1),
        scale=st.sampled_from([0.1, 1.0, 10.0]),
    )
    def test_hypothesis_shapes(self, mi, nj, d, seed, scale):
        r = np.random.default_rng(seed)
        m, n = 64 * mi, 256 * nj
        x = jnp.asarray(r.normal(scale=scale, size=(m, d)), dtype=jnp.float32)
        y = jnp.asarray(r.normal(scale=scale, size=(n, d)), dtype=jnp.float32)
        ref = pairwise_sqdist_ref(x, y)
        tol = 1e-4 * max(1.0, scale * scale)
        np.testing.assert_allclose(pairwise_sqdist(x, y), ref, atol=tol, rtol=1e-4)


# --------------------------------------------------------------------- rbf


class TestRbfKernel:
    def test_exact_artifact_shape(self):
        x, y = randn(64, 32), randn(256, 32)
        np.testing.assert_allclose(
            rbf_kernel(x, y, h=0.75), rbf_kernel_ref(x, y, h=0.75), atol=1e-5
        )

    def test_self_kernel_diagonal_one(self):
        x = randn(256, 8)
        k = rbf_kernel(x, x)
        np.testing.assert_allclose(jnp.diagonal(k), jnp.ones(256), atol=1e-5)

    def test_range_zero_one(self):
        x, y = randn(64, 8, scale=3.0), randn(256, 8, scale=3.0)
        k = rbf_kernel(x, y)
        assert float(jnp.min(k)) >= 0.0
        assert float(jnp.max(k)) <= 1.0 + 1e-6

    def test_bandwidth_monotonicity(self):
        """Wider bandwidth => larger kernel values (off-diagonal)."""
        x, y = randn(64, 8), randn(256, 8)
        k_small = rbf_kernel(x, y, h=0.5)
        k_large = rbf_kernel(x, y, h=2.0)
        assert float(jnp.min(k_large - k_small)) >= -1e-6

    @settings(max_examples=15, deadline=None)
    @given(
        d=st.sampled_from([4, 8, 22, 32]),
        h=st.sampled_from([0.5, 0.75, 1.5]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_matches_ref(self, d, h, seed):
        r = np.random.default_rng(seed)
        x = jnp.asarray(r.normal(size=(64, d)), dtype=jnp.float32)
        y = jnp.asarray(r.normal(size=(256, d)), dtype=jnp.float32)
        np.testing.assert_allclose(
            rbf_kernel(x, y, h=h), rbf_kernel_ref(x, y, h=h), atol=1e-5
        )


# ----------------------------------------------------------- facility gain


class TestFacilityGain:
    def test_exact_artifact_shape(self):
        c, x = randn(64, 32), randn(1024, 32)
        cm = jnp.asarray(RNG.uniform(0.5, 4.0, size=1024), dtype=jnp.float32)
        np.testing.assert_allclose(
            facility_gain_sums(c, x, cm),
            facility_gain_sums_ref(c, x, cm),
            rtol=1e-4,
            atol=1e-2,
        )

    def test_zero_curmin_zero_gain(self):
        """curmin == 0 (everything perfectly covered) => no gain anywhere."""
        c, x = randn(64, 8), randn(1024, 8)
        gains = facility_gain_sums(c, x, jnp.zeros(1024))
        np.testing.assert_allclose(gains, jnp.zeros((64, 1)), atol=1e-6)

    def test_gains_nonnegative(self):
        c, x = randn(64, 8), randn(1024, 8)
        cm = jnp.asarray(RNG.uniform(0, 2, size=1024), dtype=jnp.float32)
        assert float(jnp.min(facility_gain_sums(c, x, cm))) >= 0.0

    def test_gain_monotone_in_curmin(self):
        """Raising curmin (worse current cover) can only increase gains."""
        c, x = randn(64, 8), randn(1024, 8)
        cm = jnp.asarray(RNG.uniform(0.5, 2, size=1024), dtype=jnp.float32)
        g1 = facility_gain_sums(c, x, cm)
        g2 = facility_gain_sums(c, x, cm + 1.0)
        assert float(jnp.min(g2 - g1)) >= -1e-4

    def test_self_candidate_dominates(self):
        """A candidate equal to a data point fully recovers its curmin."""
        x = randn(1024, 8)
        c = jnp.tile(x[:1], (64, 1))  # candidate == data point 0
        cm = jnp.full((1024,), 1e-3, dtype=jnp.float32)
        gains = facility_gain_sums(c, x, cm)
        # every candidate covers point 0 perfectly: gain >= curmin[0]
        assert float(jnp.min(gains)) >= 1e-3 - 1e-6

    def test_padding_rows_contribute_zero(self):
        """The rust coordinator pads shards with curmin=0 rows — verify."""
        c = randn(64, 8)
        x_real, x_pad = randn(512, 8), jnp.zeros((512, 8))
        cm_real = jnp.asarray(RNG.uniform(0.5, 2, size=512), dtype=jnp.float32)
        g_full = facility_gain_sums(
            c,
            jnp.concatenate([x_real, x_pad]),
            jnp.concatenate([cm_real, jnp.zeros(512)]),
        )
        # compare against a 512-point call (bv=256 divides both)
        g_real = facility_gain_sums(c, x_real, cm_real)
        np.testing.assert_allclose(g_full, g_real, rtol=1e-4, atol=1e-3)

    @settings(max_examples=20, deadline=None)
    @given(
        nblocks=st.integers(1, 4),
        d=st.sampled_from([4, 8, 32]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_matches_ref(self, nblocks, d, seed):
        r = np.random.default_rng(seed)
        n = 256 * nblocks
        c = jnp.asarray(r.normal(size=(64, d)), dtype=jnp.float32)
        x = jnp.asarray(r.normal(size=(n, d)), dtype=jnp.float32)
        cm = jnp.asarray(r.uniform(0, 3, size=n), dtype=jnp.float32)
        np.testing.assert_allclose(
            facility_gain_sums(c, x, cm),
            facility_gain_sums_ref(c, x, cm),
            rtol=1e-4,
            atol=1e-2,
        )


# ------------------------------------------------------------ info gain ref


class TestInfoGainRef:
    """Sanity for the oracle the rust incremental Cholesky is checked against."""

    def test_empty_like_identity(self):
        assert float(info_gain_ref(jnp.zeros((4, 4)))) == pytest.approx(0.0)

    def test_monotone_in_sigma(self):
        x = randn(64, 8)
        k = rbf_kernel_ref(x[:16], x[:16])
        assert float(info_gain_ref(k, sigma=0.5)) > float(info_gain_ref(k, sigma=2.0))

    def test_submodular_diminishing_returns(self):
        """f(S+e)-f(S) >= f(T+e)-f(T) for S subset T on a random PSD kernel."""
        x = randn(32, 8)
        k = np.asarray(rbf_kernel_ref(x, x))
        s_idx = [0, 1, 2]
        t_idx = [0, 1, 2, 3, 4, 5]
        e = 7

        def f(idx):
            sub = jnp.asarray(k[np.ix_(idx, idx)])
            return float(info_gain_ref(sub))

        gain_s = f(s_idx + [e]) - f(s_idx)
        gain_t = f(t_idx + [e]) - f(t_idx)
        assert gain_s >= gain_t - 1e-5
