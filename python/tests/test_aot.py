"""AOT pipeline: every artifact lowers to parseable HLO text with the
declared parameter shapes, and the manifest is consistent."""

import json
import os
import re

import pytest

from compile import aot


@pytest.fixture(scope="module")
def entries():
    return aot.build_entries()


class TestBuildEntries:
    def test_unique_names(self, entries):
        names = [e[0] for e in entries]
        assert len(names) == len(set(names))

    def test_every_dim_bucket_has_all_three_graphs(self, entries):
        names = [e[0] for e in entries]
        for d in aot.DIMS:
            assert any(n.startswith("facility_gain") and n.endswith(f"_d{d}") for n in names)
            assert any(n.startswith("sqdist") and n.endswith(f"_d{d}") for n in names)
            assert any(n.startswith("rbf") and n.endswith(f"_d{d}") for n in names)

    def test_io_shapes_well_formed(self, entries):
        for name, _fn, in_specs, out_shapes, _doc in entries:
            assert len(in_specs) >= 1, name
            assert len(out_shapes) >= 1, name
            for s in in_specs:
                assert all(dim > 0 for dim in s.shape), name


class TestLowering:
    def test_facility_gain_lowers_to_hlo_text(self, entries):
        name, fn, in_specs, _out, _doc = next(
            e for e in entries if e[0].startswith("facility_gain") and "_d8" in e[0]
        )
        text = aot.to_hlo_text(fn.lower(*in_specs))
        assert "HloModule" in text
        assert "ENTRY" in text
        # parameters must carry the bucketed shapes
        assert "f32[64,8]" in text  # candidate block
        assert "f32[1024,8]" in text  # shard block

    def test_coverage_lowers_with_dot(self, entries):
        name, fn, in_specs, _out, _doc = next(
            e for e in entries if e[0].startswith("coverage")
        )
        text = aot.to_hlo_text(fn.lower(*in_specs))
        assert "HloModule" in text
        assert "dot(" in text  # the membership @ uncovered contraction

    def test_output_is_tuple(self, entries):
        """Lowered with return_tuple=True — rust unwraps with to_tuple1()."""
        name, fn, in_specs, _out, _doc = next(
            e for e in entries if e[0].startswith("sqdist") and "_d8" in e[0]
        )
        text = aot.to_hlo_text(fn.lower(*in_specs))
        root = [l for l in text.splitlines() if "ROOT" in l and "ENTRY" not in l]
        assert any("tuple" in l or "(f32[" in l for l in root), root


class TestArtifactsOnDisk:
    """Validate what `make artifacts` actually produced (skips if not built)."""

    ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

    @pytest.fixture()
    def manifest(self):
        path = os.path.join(self.ART, "manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built — run `make artifacts`")
        with open(path) as f:
            return json.load(f)

    def test_manifest_files_exist_and_parse(self, manifest):
        assert manifest["format"] == "hlo-text"
        assert len(manifest["entries"]) >= 7
        for e in manifest["entries"]:
            p = os.path.join(self.ART, e["file"])
            assert os.path.exists(p), e["file"]
            head = open(p).read(200)
            assert head.startswith("HloModule"), e["file"]

    def test_manifest_shapes_match_hlo_parameters(self, manifest):
        for e in manifest["entries"]:
            lines = open(os.path.join(self.ART, e["file"])).read().splitlines()
            start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
            end = next(i for i in range(start, len(lines)) if lines[i] == "}")
            got_shapes = []
            for l in lines[start:end]:
                if "parameter(" not in l:
                    continue
                m = re.search(r"f32\[([0-9,]*)\]", l)
                if m:
                    d = m.group(1)
                    got_shapes.append([int(x) for x in d.split(",")] if d else [])
            for shape in e["inputs"]:
                assert shape in got_shapes, (e["name"], shape, got_shapes)
