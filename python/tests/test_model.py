"""Layer-2 model graphs: shapes, semantics, and coverage-count correctness."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import facility_gain_sums_ref, pairwise_sqdist_ref

RNG = np.random.default_rng(7)


def randn(*shape):
    return jnp.asarray(RNG.normal(size=shape), dtype=jnp.float32)


class TestFacilityGains:
    def test_returns_tuple_of_flat_gains(self):
        c, x = randn(64, 8), randn(1024, 8)
        cm = jnp.ones(1024)
        (gains,) = model.facility_gains(c, x, cm)
        assert gains.shape == (64,)
        np.testing.assert_allclose(
            gains,
            facility_gain_sums_ref(c, x, cm)[:, 0],
            rtol=1e-4,
            atol=1e-2,
        )

    def test_normalization_contract(self):
        """Model returns sums; mean = sums / n is what the paper's f uses."""
        c, x = randn(64, 8), randn(1024, 8)
        cm = jnp.full((1024,), 2.0)
        (gains,) = model.facility_gains(c, x, cm)
        per_point_mean = gains / 1024.0
        assert float(jnp.max(per_point_mean)) <= 2.0 + 1e-5


class TestSqdistRows:
    def test_shape_and_values(self):
        c, x = randn(64, 32), randn(1024, 32)
        (d2,) = model.sqdist_rows(c, x)
        assert d2.shape == (64, 1024)
        np.testing.assert_allclose(d2, pairwise_sqdist_ref(c, x), atol=1e-3)


class TestRbfBlock:
    def test_default_bandwidth_is_paper_value(self):
        x, y = randn(64, 8), randn(256, 8)
        (k,) = model.rbf_block(x, y)
        expect = jnp.exp(-pairwise_sqdist_ref(x, y) / (0.75 * 0.75))
        np.testing.assert_allclose(k, expect, atol=1e-5)


class TestCoverageCounts:
    def test_counts_newly_covered(self):
        membership = jnp.zeros((64, 2048)).at[0, :100].set(1.0)
        covered = jnp.zeros(2048).at[:50].set(1.0)
        (counts,) = model.coverage_counts(membership, covered)
        assert float(counts[0]) == 50.0  # covers 100, 50 already covered
        assert float(counts[1]) == 0.0

    def test_fully_covered_universe(self):
        membership = jnp.ones((64, 2048))
        (counts,) = model.coverage_counts(membership, jnp.ones(2048))
        np.testing.assert_allclose(counts, jnp.zeros(64))

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), density=st.floats(0.01, 0.5))
    def test_hypothesis_matches_set_semantics(self, seed, density):
        r = np.random.default_rng(seed)
        mem = (r.random((64, 2048)) < density).astype(np.float32)
        cov = (r.random(2048) < density).astype(np.float32)
        (counts,) = model.coverage_counts(jnp.asarray(mem), jnp.asarray(cov))
        expect = (mem.astype(bool) & ~cov.astype(bool)).sum(axis=1)
        np.testing.assert_allclose(counts, expect.astype(np.float32), atol=1e-3)
