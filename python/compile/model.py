"""Layer-2 JAX compute graphs for the GreeDi objective hot spots.

Each public function here is a jit-able graph that calls the Layer-1 Pallas
kernels and is AOT-lowered by :mod:`aot` into an HLO-text artifact. The rust
coordinator (Layer 3) streams fixed-shape blocks through these graphs on the
request path; python never runs after ``make artifacts``.

Shape discipline: all shapes are static buckets (see ``aot.SHAPE_BUCKETS``);
the rust side pads candidate blocks / shard blocks up to the bucket and masks
out padding (padded curmin entries are 0 so they contribute nothing; padded
data rows are filtered by the coordinator before aggregation).
"""

import jax.numpy as jnp

from .kernels import facility_gain_sums, pairwise_sqdist, rbf_kernel


def facility_gains(cands, data, curmin):
    """Batched facility-location marginal gains (UNNORMALIZED sums).

    cands  : (B, D) candidate exemplars
    data   : (N, D) shard block
    curmin : (N,)   cached min squared distance to the current solution
    returns: (B,)   sum_v max(curmin[v] - ||c - v||^2, 0)

    The coordinator divides by the true ground-set size n. Returned as a
    1-tuple because jax lowering uses return_tuple=True (see aot.py).
    """
    sums = facility_gain_sums(cands, data, curmin)  # (B, 1)
    return (sums[:, 0],)


def sqdist_rows(cands, data):
    """Pairwise squared distances (B, D) x (N, D) -> (B, N).

    Used by the coordinator to refresh ``curmin`` after each selection
    (one row per newly selected exemplar) and to compute f(S) exactly.
    """
    return (pairwise_sqdist(cands, data),)


def rbf_block(x, y, h: float = 0.75):
    """RBF kernel block for GP info-gain (paper's h = 0.75 default)."""
    return (rbf_kernel(x, y, h=h),)


def coverage_counts(membership, covered):
    """Batched coverage marginal gains over a dense incidence block.

    membership : (B, U) 0/1 — candidate-to-universe incidence rows
    covered    : (U,)   0/1 — already-covered indicator
    returns    : (B,)   number of newly covered universe items per candidate

    Plain-XLA graph (no Pallas): this one is bandwidth-bound with no matmul
    structure; XLA's native fusion already produces the optimal loop.
    """
    uncovered = 1.0 - covered
    return (jnp.dot(membership, uncovered),)
