"""AOT bridge: lower the Layer-2 graphs to HLO-text artifacts for rust.

Interchange format is HLO **text**, not serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published ``xla`` 0.1.6 crate links) rejects with
``proto.id() <= INT_MAX``. The text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage (from python/):  python -m compile.aot --outdir ../artifacts

Produces one ``<name>.hlo.txt`` per shape-bucketed graph plus a
``manifest.json`` the rust artifact registry reads at startup.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

F32 = "f32"


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


# Shape buckets. The rust coordinator pads up to the nearest bucket:
#   d=6  (Yahoo-like)        -> d=8
#   d=22 (Parkinsons-like)   -> d=32
#   d=32 (tiny-image-like)   -> d=32
# B is the candidate batch, N the shard-block length, U the coverage universe
# block. Keeping the bucket list short bounds `make artifacts` time; adding a
# bucket is a one-line change here and is picked up by the registry via the
# manifest.
FACILITY_B, FACILITY_N = 64, 1024
RBF_M, RBF_N = 64, 256
COVERAGE_B, COVERAGE_U = 64, 2048
DIMS = (8, 32)


def build_entries():
    """(name, jitted fn, example specs, doc) for every artifact."""
    entries = []
    for d in DIMS:
        entries.append(
            (
                f"facility_gain_b{FACILITY_B}_n{FACILITY_N}_d{d}",
                jax.jit(model.facility_gains),
                [spec(FACILITY_B, d), spec(FACILITY_N, d), spec(FACILITY_N)],
                [(FACILITY_B,)],
                "batched facility-location marginal gain sums",
            )
        )
        entries.append(
            (
                f"sqdist_b{FACILITY_B}_n{FACILITY_N}_d{d}",
                jax.jit(model.sqdist_rows),
                [spec(FACILITY_B, d), spec(FACILITY_N, d)],
                [(FACILITY_B, FACILITY_N)],
                "pairwise squared distances (curmin refresh / exact eval)",
            )
        )
        entries.append(
            (
                f"rbf_m{RBF_M}_n{RBF_N}_d{d}",
                jax.jit(lambda x, y: model.rbf_block(x, y, h=0.75)),
                [spec(RBF_M, d), spec(RBF_N, d)],
                [(RBF_M, RBF_N)],
                "RBF kernel block, h=0.75 (paper section 6.2)",
            )
        )
    entries.append(
        (
            f"coverage_b{COVERAGE_B}_u{COVERAGE_U}",
            jax.jit(model.coverage_counts),
            [spec(COVERAGE_B, COVERAGE_U), spec(COVERAGE_U)],
            [(COVERAGE_B,)],
            "batched coverage marginal gains over a dense incidence block",
        )
    )
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on names")
    args = ap.parse_args()
    os.makedirs(args.outdir, exist_ok=True)

    manifest = {"format": "hlo-text", "entries": []}
    for name, fn, in_specs, out_shapes, doc in build_entries():
        if args.only and args.only not in name:
            continue
        lowered = fn.lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.outdir, fname), "w") as f:
            f.write(text)
        manifest["entries"].append(
            {
                "name": name,
                "file": fname,
                "doc": doc,
                "inputs": [list(s.shape) for s in in_specs],
                "outputs": [list(s) for s in out_shapes],
                "dtype": F32,
            }
        )
        print(f"wrote {fname} ({len(text)} chars)")

    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(manifest['entries'])} artifacts)")


if __name__ == "__main__":
    main()
