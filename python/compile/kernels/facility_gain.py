"""Fused facility-location marginal-gain Pallas kernel — the greedy hot loop.

For exemplar-based clustering (paper eq. 5-6) the marginal gain of adding a
candidate ``c`` to the current solution ``S`` is

    gain(c | S) = 1/n * sum_v max(curmin[v] - l(c, v), 0)

where ``curmin[v] = min_{e in S u {e0}} l(e, v)`` is the cached
min-dissimilarity vector and ``l = ||.||^2``. A greedy round evaluates this
for every remaining candidate — O(n) work per candidate — so the whole
selection is dominated by this kernel.

This kernel fuses the distance expansion, the clamp and the row reduction
into a single pass over the data block, accumulating partial sums across the
``v``-grid dimension in the output tile (revisited output block => sequential
accumulation, the standard Pallas reduction idiom). The kernel returns SUMS,
not means: the rust coordinator streams shard blocks through the fixed-shape
artifact and divides by the true ``n`` at the end (padding rows contribute 0
because their curmin is padded with 0).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gain_block_kernel(c_ref, x_ref, m_ref, o_ref):
    """Accumulate sum_v max(curmin[v] - d2(c, v), 0) over one data block."""
    j = pl.program_id(1)

    c = c_ref[...]  # (bc, D) candidate tile (pinned across the v-grid)
    x = x_ref[...]  # (bv, D) data tile (streamed)
    cm = m_ref[...]  # (1, bv) curmin tile

    c2 = jnp.sum(c * c, axis=1, keepdims=True)  # (bc, 1)
    x2 = jnp.sum(x * x, axis=1, keepdims=True).T  # (1, bv)
    cross = jnp.dot(c, x.T, preferred_element_type=jnp.float32)  # MXU
    d2 = jnp.maximum(c2 + x2 - 2.0 * cross, 0.0)  # (bc, bv)
    reduction = jnp.maximum(cm - d2, 0.0)  # benefit against current cover
    partial = jnp.sum(reduction, axis=1, keepdims=True)  # (bc, 1)

    # First visit initializes the accumulator, later visits add to it.
    @pl.when(j == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(j > 0)
    def _acc():
        o_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("bc", "bv"))
def facility_gain_sums(cands, data, curmin, *, bc: int = 64, bv: int = 256):
    """Per-candidate UNNORMALIZED gains: sum_v max(curmin[v] - d2(c,v), 0).

    cands:  (B, D) candidate block
    data:   (N, D) shard block
    curmin: (N,)   cached min squared distance per data point
    returns (B, 1) float32 sums (divide by the true n on the caller side).
    """
    b, d = cands.shape
    n, d2 = data.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    assert curmin.shape == (n,), curmin.shape
    assert b % bc == 0 and n % bv == 0, (b, n, bc, bv)
    cm2 = curmin.reshape(1, n)
    return pl.pallas_call(
        _gain_block_kernel,
        grid=(b // bc, n // bv),
        in_specs=[
            pl.BlockSpec((bc, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bv, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bv), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bc, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        interpret=True,
    )(cands, data, cm2)
