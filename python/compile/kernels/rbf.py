"""Tiled RBF (squared-exponential) kernel-matrix Pallas kernel.

The active-set-selection objective (paper sections 3.4.1 / 6.2) is the GP
information gain ``f(S) = 1/2 log det(I + sigma^-2 K_SS)`` with
``K(e_i, e_j) = exp(-||e_i - e_j||^2 / h^2)`` (h = 0.75 in the paper's
experiments). The hot spot is materializing kernel rows/blocks; the log-det
itself is an O(k^2) incremental Cholesky update on the rust side.

Same tiling as :mod:`pairwise` with an ``exp`` epilogue fused into the tile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rbf_block_kernel(x_ref, y_ref, o_ref, *, inv_h2: float):
    x = x_ref[...]
    y = y_ref[...]
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    y2 = jnp.sum(y * y, axis=1, keepdims=True).T
    cross = jnp.dot(x, y.T, preferred_element_type=jnp.float32)
    d2 = jnp.maximum(x2 + y2 - 2.0 * cross, 0.0)
    o_ref[...] = jnp.exp(-d2 * inv_h2)


@functools.partial(jax.jit, static_argnames=("h", "bm", "bn"))
def rbf_kernel(x, y, *, h: float = 0.75, bm: int = 64, bn: int = 256):
    """RBF kernel block K[i, j] = exp(-||x_i - y_j||^2 / h^2)."""
    m, d = x.shape
    n, d2 = y.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    import functools as ft

    kernel = ft.partial(_rbf_block_kernel, inv_h2=1.0 / (h * h))
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)
