"""Tiled pairwise squared-distance Pallas kernel.

The exemplar-based-clustering objective (paper section 3.4.2, experiments
section 6.1) is driven entirely by squared Euclidean distances
``l(x, x') = ||x - x'||^2``. This kernel computes the ``[M, N]`` distance
matrix between a candidate block ``X`` and a data block ``Y`` using the
``||x||^2 + ||y||^2 - 2<x, y>`` expansion so the inner product maps onto the
MXU systolic array on TPU (and a dgemm on CPU), instead of an O(M*N*D)
gather-subtract-square loop.

Tiling: grid over (M/bm, N/bn); each step holds an ``(bm, D)`` X-tile, an
``(bn, D)`` Y-tile and the ``(bm, bn)`` output tile in VMEM. For the default
bm=64, bn=256, D<=64 the working set is < 200 KiB f32 — far under the ~16 MiB
VMEM budget, leaving room for double buffering.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sqdist_block_kernel(x_ref, y_ref, o_ref):
    """One (bm, bn) tile: o = |x|^2 + |y|^2 - 2 x y^T, clamped at 0."""
    x = x_ref[...]  # (bm, D)
    y = y_ref[...]  # (bn, D)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # (bm, 1)
    y2 = jnp.sum(y * y, axis=1, keepdims=True).T  # (1, bn)
    cross = jnp.dot(x, y.T, preferred_element_type=jnp.float32)  # MXU
    # Numerical guard: the expansion can go epsilon-negative for x ~= y.
    o_ref[...] = jnp.maximum(x2 + y2 - 2.0 * cross, 0.0)


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def pairwise_sqdist(x, y, *, bm: int = 64, bn: int = 256):
    """Squared distances between rows of ``x`` [M, D] and ``y`` [N, D].

    M must be divisible by ``bm`` and N by ``bn`` (the AOT shapes are padded
    on the rust side to the bucket shape, so this is enforced statically).
    """
    m, d = x.shape
    n, d2 = y.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    grid = (m // bm, n // bn)
    return pl.pallas_call(
        _sqdist_block_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)
