"""Layer-1 Pallas kernels for the GreeDi compute hot spots.

Each kernel ships with a pure-jnp oracle in :mod:`ref` and is verified by
``python/tests``. Kernels are lowered with ``interpret=True`` so the emitted
HLO runs on any PJRT backend (including the rust CPU client); on a real TPU
the same BlockSpecs express the HBM->VMEM schedule (see DESIGN.md
section "Hardware adaptation").
"""

from .pairwise import pairwise_sqdist
from .rbf import rbf_kernel
from .facility_gain import facility_gain_sums

__all__ = ["pairwise_sqdist", "rbf_kernel", "facility_gain_sums"]
