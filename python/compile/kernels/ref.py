"""Pure-jnp oracles for the Pallas kernels (correctness ground truth).

Deliberately written in the most direct way possible (no expansion tricks, no
tiling) so any agreement with the kernels is meaningful.
"""

import jax.numpy as jnp


def pairwise_sqdist_ref(x, y):
    """[M, D] x [N, D] -> [M, N] squared Euclidean distances."""
    diff = x[:, None, :] - y[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def rbf_kernel_ref(x, y, h: float = 0.75):
    """RBF kernel matrix with bandwidth h."""
    return jnp.exp(-pairwise_sqdist_ref(x, y) / (h * h))


def facility_gain_sums_ref(cands, data, curmin):
    """Unnormalized facility-location marginal gains, see facility_gain.py."""
    d2 = pairwise_sqdist_ref(cands, data)  # (B, N)
    return jnp.sum(jnp.maximum(curmin[None, :] - d2, 0.0), axis=1, keepdims=True)


def info_gain_ref(kernel_ss, sigma: float = 1.0):
    """GP information gain f(S) = 1/2 log det(I + sigma^-2 K_SS)."""
    k = kernel_ss.shape[0]
    m = jnp.eye(k) + kernel_ss / (sigma * sigma)
    sign, logdet = jnp.linalg.slogdet(m)
    return 0.5 * logdet
