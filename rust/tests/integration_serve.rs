//! End-to-end guarantees of the always-on selection service:
//!
//! 1. a served query is **bit-identical** (solution + value) to a direct
//!    `protocol::by_name(..).run(..)` with the same `RunSpec` and seed —
//!    for batch and streaming protocols, cold and warm caches alike;
//! 2. ≥ 8 concurrent clients all get that same bit-identical answer while
//!    admission control keeps peak in-flight ≤ the concurrency cap and
//!    every query's `threads_used` at the oracle_threads split of the
//!    budget (never oversubscribing the pool);
//! 3. overload is shed as a typed `overloaded` error (driven
//!    deterministically by holding an admission permit from the test);
//! 4. bad requests come back as typed errors, never dropped connections;
//! 5. the `stats` reply carries p50/p99 latency and qps;
//! 6. dataset drift through `advance` bumps the version and keeps serving
//!    answers bit-identical to a direct run on the equivalent prefix.

use std::sync::Arc;

use greedi::coordinator::protocol::{self, Protocol, RunSpec};
use greedi::coordinator::FacilityProblem;
use greedi::data::synth::{gaussian_blobs, SynthConfig};
use greedi::data::Dataset;
use greedi::serve::{Admission, Client, ErrorKind, ServeMetrics, ServeSpec, Server, WarmState};
use greedi::stream::{DriftSource, StreamOrder, StreamSource};

fn dataset(n: usize, seed: u64) -> Arc<Dataset> {
    Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 8), seed))
}

fn spec_for(addr: &str, threads: usize, max_concurrency: usize, queue_depth: usize) -> ServeSpec {
    let mut s = ServeSpec::default();
    s.addr = addr.to_string();
    s.threads = threads;
    s.max_concurrency = max_concurrency;
    s.queue_depth = queue_depth;
    s.dataset = "demo".to_string();
    s
}

fn start_static(n: usize, threads: usize, conc: usize, queue: usize) -> (Server, Arc<Dataset>) {
    let data = dataset(n, 42);
    let state = Arc::new(WarmState::new());
    state.register("demo", Arc::clone(&data));
    let server = Server::start(&spec_for("127.0.0.1:0", threads, conc, queue), state).unwrap();
    (server, data)
}

#[test]
fn served_query_bit_identical_to_direct_run() {
    let (server, data) = start_static(400, 4, 2, 8);
    let mut client = Client::connect(server.addr()).unwrap();
    let problem = FacilityProblem::new(&data);

    for proto in ["greedi", "stream_greedi", "greedy_max", "centralized"] {
        let spec = RunSpec::new(5, 8).seed(7);
        let direct = protocol::by_name(proto).unwrap().run(&problem, &spec);
        let served = client.query(proto, None, &spec).unwrap_or_else(|e| {
            panic!("served {proto}: {e}");
        });
        assert_eq!(served.solution, direct.solution, "{proto}: solution drifted");
        assert_eq!(
            served.value.to_bits(),
            direct.value.to_bits(),
            "{proto}: value not bit-identical ({} vs {})",
            served.value,
            direct.value
        );
        assert_eq!(served.oracle_calls, direct.oracle_calls, "{proto}");
        assert_eq!(served.rounds, direct.rounds, "{proto}");
        assert_eq!(served.protocol, direct.name, "{proto}");
    }
}

#[test]
fn warm_singleton_cache_keeps_answers_bit_identical() {
    let (server, data) = start_static(400, 4, 2, 8);
    let mut client = Client::connect(server.addr()).unwrap();
    let spec = RunSpec::new(4, 6).seed(11);
    let direct =
        protocol::by_name("stream_greedi").unwrap().run(&FacilityProblem::new(&data), &spec);

    // cold, then warm (second query answers singleton pricing from cache)
    let cold = client.query("stream_greedi", None, &spec).unwrap();
    let warm = client.query("stream_greedi", None, &spec).unwrap();
    for (label, reply) in [("cold", &cold), ("warm", &warm)] {
        assert_eq!(reply.solution, direct.solution, "{label} solution");
        assert_eq!(reply.value.to_bits(), direct.value.to_bits(), "{label} value");
    }

    // the stats surface proves the cache was actually exercised
    let stats = client.stats().unwrap();
    let cache = stats.get("cache").unwrap();
    let hits = cache.get("singleton_hits").and_then(|v| v.as_u64()).unwrap();
    let misses = cache.get("singleton_misses").and_then(|v| v.as_u64()).unwrap();
    assert!(misses >= 1, "first query must fill the cache (misses={misses})");
    assert!(hits >= 1, "second query must hit the cache (hits={hits})");
}

#[test]
fn eight_concurrent_clients_admitted_without_oversubscription() {
    const CLIENTS: usize = 8;
    const THREADS: usize = 8;
    const CONC: usize = 2;
    let (server, data) = start_static(300, THREADS, CONC, CLIENTS);
    let spec = RunSpec::new(4, 6).seed(3);
    let direct = protocol::by_name("greedi").unwrap().run(&FacilityProblem::new(&data), &spec);
    let addr = server.addr();

    let workers: Vec<_> = (0..CLIENTS)
        .map(|_| {
            let spec = spec.clone();
            std::thread::spawn(move || {
                let mut c = Client::connect(addr).unwrap();
                c.query("greedi", None, &spec)
            })
        })
        .collect();
    let replies: Vec<_> =
        workers.into_iter().map(|w| w.join().unwrap().expect("query under load")).collect();

    let per_query = THREADS / CONC;
    for (i, r) in replies.iter().enumerate() {
        assert_eq!(r.solution, direct.solution, "client {i}: solution drifted under load");
        assert_eq!(r.value.to_bits(), direct.value.to_bits(), "client {i}");
        assert_eq!(
            r.threads_used, per_query,
            "client {i}: admission must narrow each query to budget/slots threads"
        );
    }

    let mut c = Client::connect(addr).unwrap();
    let stats = c.stats().unwrap();
    let adm = stats.get("admission").unwrap();
    let get = |k: &str| adm.get(k).and_then(|v| v.as_u64()).unwrap();
    assert_eq!(get("admitted"), CLIENTS as u64);
    assert_eq!(get("shed"), 0, "queue depth {CLIENTS} must absorb all waiters");
    assert!(
        get("peak_in_flight") <= CONC as u64,
        "oversubscribed: peak {} > cap {CONC}",
        get("peak_in_flight")
    );
    assert_eq!(get("in_flight"), 0);
    let completed =
        stats.get("latency").and_then(|l| l.get("completed")).and_then(|v| v.as_u64()).unwrap();
    assert_eq!(completed, CLIENTS as u64);
}

#[test]
fn overload_is_shed_as_typed_error() {
    // with_parts + a permit held by the test makes the shed deterministic:
    // max_concurrency 1 is occupied, queue_depth 0 means no waiting.
    let data = dataset(200, 42);
    let state = Arc::new(WarmState::new());
    state.register("demo", Arc::clone(&data));
    let spec = spec_for("127.0.0.1:0", 4, 1, 0);
    let admission = Admission::new(spec.threads, spec.max_concurrency, spec.queue_depth);
    let metrics = Arc::new(ServeMetrics::new(spec.ring));
    let server =
        Server::with_parts(&spec, state, admission.clone(), Arc::clone(&metrics)).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();
    let qspec = RunSpec::new(3, 5).seed(1);

    let held = admission.admit().unwrap();
    let err = client.query("greedi", None, &qspec).unwrap_err();
    assert_eq!(err.kind, ErrorKind::Overloaded, "{err}");
    drop(held);

    let reply = client.query("greedi", None, &qspec).expect("slot freed");
    let direct =
        protocol::by_name("greedi").unwrap().run(&FacilityProblem::new(&data), &qspec);
    assert_eq!(reply.value.to_bits(), direct.value.to_bits());
    assert_eq!(metrics.snapshot().errors, 1, "the shed must be counted");
    assert_eq!(admission.stats().shed, 1);
}

#[test]
fn bad_requests_get_typed_errors_not_dropped_connections() {
    let (server, _data) = start_static(150, 2, 1, 4);
    let mut client = Client::connect(server.addr()).unwrap();
    let spec = RunSpec::new(3, 5).seed(1);

    let err = client.query("definitely_not_a_protocol", None, &spec).unwrap_err();
    assert_eq!(err.kind, ErrorKind::UnknownProtocol);
    assert!(err.msg.contains("greedi"), "error should list known protocols: {}", err.msg);

    let err = client.query("greedi", Some("no_such_dataset"), &spec).unwrap_err();
    assert_eq!(err.kind, ErrorKind::UnknownDataset);

    let err = client.advance(None, 10).unwrap_err();
    assert_eq!(err.kind, ErrorKind::BadRequest, "advance on a static dataset: {err}");

    // raw garbage on the same wire protocol — connection must survive
    {
        use std::io::{BufRead, BufReader, Write};
        let mut raw = std::net::TcpStream::connect(server.addr()).unwrap();
        raw.write_all(b"this is not json\n{\"v\":99,\"op\":\"ping\",\"id\":1}\n").unwrap();
        let mut reader = BufReader::new(raw.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        assert!(line.contains("bad_request"), "garbage line: {line}");
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(
            line.contains("bad_request") && line.contains("version"),
            "version mismatch must be typed: {line}"
        );
    }

    // after all that, the connection and server still answer real queries
    let reply = client.query("greedi", None, &spec).unwrap();
    assert!(!reply.solution.is_empty());
}

#[test]
fn stats_reply_reports_percentiles_and_qps() {
    let (server, _data) = start_static(200, 2, 2, 8);
    let mut client = Client::connect(server.addr()).unwrap();
    let spec = RunSpec::new(3, 4).seed(5);
    for _ in 0..4 {
        client.query("greedy_max", None, &spec).unwrap();
    }
    let stats = client.stats().unwrap();
    let lat = stats.get("latency").unwrap();
    assert_eq!(lat.get("completed").and_then(|v| v.as_u64()), Some(4));
    let qps = lat.get("qps").and_then(|v| v.as_f64()).unwrap();
    assert!(qps > 0.0 && qps.is_finite(), "qps={qps}");
    let window = lat.get("latency").unwrap();
    let p50 = window.get("p50_us").and_then(|v| v.as_f64()).unwrap();
    let p99 = window.get("p99_us").and_then(|v| v.as_f64()).unwrap();
    assert!(p50 > 0.0 && p50.is_finite());
    assert!(p99 >= p50, "p99={p99} < p50={p50}");
    assert!(stats.get("uptime_s").and_then(|v| v.as_f64()).unwrap() >= 0.0);
    // ping lists the whole protocol registry for discoverability
    let pong = client.ping().unwrap();
    let protos = pong.get("protocols").and_then(|v| v.as_arr()).unwrap();
    assert_eq!(protos.len(), protocol::NAMES.len());
}

#[test]
fn drift_advance_versions_dataset_and_stays_bit_identical() {
    let n = 240;
    let initial = 120;
    let step = 60;
    let backing = dataset(n, 9);

    // the server's streaming view: drift order, half visible at boot
    let state = Arc::new(WarmState::new());
    let src = DriftSource::new(&backing, backing.ids(), StreamOrder::Drift);
    state.register_streaming("demo", Arc::clone(&backing), Box::new(src), initial).unwrap();
    let server = Server::start(&spec_for("127.0.0.1:0", 4, 2, 8), state).unwrap();
    let mut client = Client::connect(server.addr()).unwrap();

    // the reference: the same deterministic order, materialized directly
    let mut order_src = DriftSource::new(&backing, backing.ids(), StreamOrder::Drift);
    let order = order_src.next_batch(n);
    assert_eq!(order.len(), n);
    let spec = RunSpec::new(4, 6).seed(2);
    let direct_at = |live: usize| {
        let view = Arc::new(backing.subset(&order[..live]));
        protocol::by_name("greedi").unwrap().run(&FacilityProblem::new(&view), &spec)
    };

    let before = client.query("greedi", None, &spec).unwrap();
    let d0 = direct_at(initial);
    assert_eq!(before.solution, d0.solution);
    assert_eq!(before.value.to_bits(), d0.value.to_bits());
    assert_eq!(before.dataset_version, 0);

    let adv = client.advance(None, step).unwrap();
    assert_eq!(adv.get("added").and_then(|v| v.as_usize()), Some(step));
    assert_eq!(adv.get("live").and_then(|v| v.as_usize()), Some(initial + step));
    assert_eq!(adv.get("version").and_then(|v| v.as_u64()), Some(1));

    let after = client.query("greedi", None, &spec).unwrap();
    let d1 = direct_at(initial + step);
    assert_eq!(after.solution, d1.solution, "post-drift solution must match direct prefix run");
    assert_eq!(after.value.to_bits(), d1.value.to_bits());
    assert_eq!(after.dataset_version, 1);

    let listing = client.datasets().unwrap();
    let rows = listing.get("datasets").and_then(|v| v.as_arr()).unwrap();
    assert_eq!(rows.len(), 1);
    assert_eq!(rows[0].get("version").and_then(|v| v.as_u64()), Some(1));
    assert_eq!(rows[0].get("streaming").and_then(|v| v.as_bool()), Some(true));
    assert_eq!(rows[0].get("n").and_then(|v| v.as_usize()), Some(initial + step));
}

#[test]
fn warm_op_prefills_and_shutdown_stops_the_daemon() {
    let (mut server, _data) = start_static(150, 2, 1, 4);
    let mut client = Client::connect(server.addr()).unwrap();

    let w = client.warm(None).unwrap();
    assert_eq!(w.get("was_warm").and_then(|v| v.as_bool()), Some(false));
    assert_eq!(w.get("n").and_then(|v| v.as_usize()), Some(150));
    let w2 = client.warm(None).unwrap();
    assert_eq!(w2.get("was_warm").and_then(|v| v.as_bool()), Some(true));

    let bye = client.shutdown().unwrap();
    assert_eq!(bye.get("op").and_then(|v| v.as_str()), Some("shutdown"));
    // the accept loop must actually exit — join() would hang forever if not
    server.join();
    let err = client.query("greedi", None, &RunSpec::new(3, 5)).unwrap_err();
    assert!(
        matches!(err.kind, ErrorKind::Internal | ErrorKind::ShuttingDown),
        "post-shutdown query must fail, got: {err}"
    );
}
