//! Integration: Algorithm 3 (GreeDi under general hereditary constraints)
//! across matroid / knapsack / p-system / intersection systems, with
//! feasibility verified on the final solutions (Theorem 12 setting) — both
//! through `Greedi::run_constrained` and through the `RunSpec` constraint
//! slots of the unified protocol API.

use std::sync::Arc;

use greedi::constraints::cardinality::Cardinality;
use greedi::constraints::intersection::Intersection;
use greedi::constraints::knapsack::Knapsack;
use greedi::constraints::matroid::PartitionMatroid;
use greedi::constraints::psystem::MatroidIntersection;
use greedi::constraints::Constraint;
use greedi::coordinator::greedi::Greedi;
use greedi::coordinator::protocol::{self, Protocol, RunSpec};
use greedi::coordinator::FacilityProblem;
use greedi::data::synth::{gaussian_blobs, SynthConfig};

fn problem(n: usize, seed: u64) -> (Arc<greedi::data::Dataset>, FacilityProblem) {
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 8), seed));
    let p = FacilityProblem::new(&ds);
    (ds, p)
}

#[test]
fn greedi_under_partition_matroid() {
    let (ds, p) = problem(200, 1);
    // categories: 4 groups round-robin, 2 slots each => ρ = 8
    let cats: Vec<usize> = (0..ds.n).map(|i| i % 4).collect();
    let con = PartitionMatroid::new(cats, vec![2, 2, 2, 2]);
    let spec = RunSpec::new(4, con.rho()).seed(3);
    let r = Greedi.run_constrained(&p, &con, &con, &spec);
    assert!(con.is_feasible(&r.solution), "infeasible {:?}", r.solution);
    assert!(r.solution.len() <= 8);
    assert!(r.value > 0.0);
}

#[test]
fn greedi_under_knapsack() {
    let (ds, p) = problem(150, 2);
    let costs: Vec<f64> = (0..ds.n).map(|i| 1.0 + (i % 3) as f64).collect();
    let con = Knapsack::new(costs, 10.0);
    let spec = RunSpec::new(3, con.rho()).seed(4);
    let r = Greedi.run_constrained(&p, &con, &con, &spec);
    assert!(con.is_feasible(&r.solution));
    assert!(r.value > 0.0);
}

#[test]
fn greedi_under_matroid_intersection() {
    let (ds, p) = problem(120, 3);
    let m1 = PartitionMatroid::new((0..ds.n).map(|i| i % 3).collect(), vec![2, 2, 2]);
    let m2 = PartitionMatroid::new((0..ds.n).map(|i| (i / 3) % 2).collect(), vec![3, 3]);
    let con = MatroidIntersection::new(vec![m1, m2]);
    let spec = RunSpec::new(3, con.rho()).seed(5);
    let r = Greedi.run_constrained(&p, &con, &con, &spec);
    assert!(con.is_feasible(&r.solution));
}

#[test]
fn greedi_under_psystem_plus_knapsack() {
    // The paper's §5.2 composite: p-system ∩ d-knapsack.
    let (ds, p) = problem(120, 4);
    let matroid = PartitionMatroid::new((0..ds.n).map(|i| i % 5).collect(), vec![2; 5]);
    let knap = Knapsack::new((0..ds.n).map(|i| 1.0 + (i % 2) as f64).collect(), 8.0);
    let con = Intersection::new(vec![Box::new(matroid), Box::new(knap)]);
    let spec = RunSpec::new(3, con.rho()).seed(6);
    let r = Greedi.run_constrained(&p, &con, &con, &spec);
    assert!(con.is_feasible(&r.solution));
    assert!(r.value > 0.0);
}

#[test]
fn tighter_round2_constraint_respected() {
    // Algorithm 2's κ > k: round 1 over-selects, round 2 enforces k.
    let (_, p) = problem(200, 5);
    let r1 = Cardinality::new(16);
    let r2 = Cardinality::new(8);
    let r = Greedi.run_constrained(&p, &r1, &r2, &RunSpec::new(4, 8).seed(7));
    assert!(r.solution.len() <= 8);
}

#[test]
fn constrained_matches_plain_when_cardinality() {
    // Protocol::run is sugar for run_constrained(Cardinality(κ), Cardinality(k)).
    let (_, p) = problem(150, 6);
    let spec = RunSpec::new(4, 6).seed(8);
    let a = Greedi.run(&p, &spec);
    let b = Greedi.run_constrained(&p, &Cardinality::new(6), &Cardinality::new(6), &spec);
    assert_eq!(a.solution, b.solution);
}

#[test]
fn spec_constraint_slots_drive_algorithm3_through_registry() {
    // Arc'd constraints in the spec make Algorithm 3 reachable from
    // protocol::by_name — no direct Greedi construction anywhere.
    let (ds, p) = problem(160, 7);
    let cats: Vec<usize> = (0..ds.n).map(|i| i % 4).collect();
    let con: Arc<dyn Constraint + Send + Sync> =
        Arc::new(PartitionMatroid::new(cats, vec![2, 2, 2, 2]));
    let rho = con.rho();
    let spec = RunSpec::new(4, rho)
        .constraints(Arc::clone(&con), Arc::clone(&con))
        .seed(9);
    let r = protocol::by_name("greedi").unwrap().run(&p, &spec);
    assert!(con.is_feasible(&r.solution), "infeasible {:?}", r.solution);
    assert!(r.solution.len() <= rho);
    // identical to the explicit run_constrained path
    let direct = Greedi.run_constrained(&p, con.as_ref(), con.as_ref(), &spec);
    assert_eq!(r.solution, direct.solution);
}

#[test]
fn rho_drives_default_budgets() {
    let con = Knapsack::new(vec![2.0; 10], 6.0);
    assert_eq!(con.rho(), 3);
    let m = PartitionMatroid::new(vec![0, 0, 1], vec![1, 1]);
    assert_eq!(m.rho(), 2);
}
