//! Integration tests for the simulated MapReduce runtime driving real
//! protocol work: timing accounting, shuffle volumes, and the Fig-8
//! speedup mechanics (round-2 dominance at large m).

use std::sync::Arc;

use greedi::coordinator::greedi::{centralized, Greedi};
use greedi::coordinator::protocol::{self, Protocol, RunSpec};
use greedi::coordinator::InfoGainProblem;
use greedi::data::synth::yahoo_like;
use greedi::mapreduce::{JobReport, MapReduce};

#[test]
fn stage_timing_accounting() {
    let mr = MapReduce::new(1);
    let (outs, rep) = mr.run_stage(vec![10_000usize, 100_000, 1_000], |_, n| {
        (0..n as u64).map(std::hint::black_box).sum::<u64>()
    });
    assert_eq!(outs.len(), 3);
    // the 100k task must be the max
    let max_idx = rep
        .task_times
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0;
    assert_eq!(max_idx, 1);
    assert!((rep.total_cpu_time - rep.task_times.iter().sum::<f64>()).abs() < 1e-12);
}

#[test]
fn greedi_two_stages_recorded() {
    let ds = Arc::new(yahoo_like(500, 1));
    let p = InfoGainProblem::paper_params(&ds);
    let r = Greedi.run(&p, &RunSpec::new(4, 8).seed(1));
    assert_eq!(r.job.stages.len(), 2, "map + reduce");
    assert_eq!(r.job.stages[0].task_times.len(), 4, "one task per machine");
    assert_eq!(r.job.stages[1].task_times.len(), 1, "single merge task");
    assert!(r.job.shuffled_elements <= 4 * 8);
    assert!(r.sim_time() > 0.0);
}

#[test]
fn speedup_grows_then_saturates() {
    // Fig 8 mechanics: sim-parallel time falls as m grows (map shards
    // shrink) until the merge round's m·κ-candidate greedy dominates.
    let ds = Arc::new(yahoo_like(4_000, 2));
    let p = InfoGainProblem::paper_params(&ds);
    let k = 24;
    let central = centralized(&p, k, "lazy", 1).sim_time();

    let mut speedups = Vec::new();
    for m in [2, 8, 32] {
        let r = Greedi.run(&p, &RunSpec::new(m, k).seed(1));
        speedups.push(central / r.sim_time());
    }
    // speedup at m=8 must beat m=2
    assert!(
        speedups[1] > speedups[0],
        "speedups not increasing: {speedups:?}"
    );
    // and the round-2 share of time must grow with m
    let share = |m: usize| {
        let r = Greedi.run(&p, &RunSpec::new(m, k).seed(1));
        r.job.stages[1].max_task_time / r.sim_time()
    };
    let s2 = share(2);
    let s64 = share(64);
    assert!(
        s64 > s2,
        "merge share must grow with m: m=2 {s2:.3} vs m=64 {s64:.3}"
    );
}

#[test]
fn job_report_shuffle_accumulates_across_protocols() {
    let mut job = JobReport::default();
    job.record_shuffle(10);
    job.record_shuffle(5);
    assert_eq!(job.shuffled_elements, 15);
}

#[test]
fn parallel_engine_matches_sequential_results() {
    let ds = Arc::new(yahoo_like(600, 3));
    let p = InfoGainProblem::paper_params(&ds);
    let seq = Greedi.run(&p, &RunSpec::new(4, 8).threads(1).seed(9));
    let par = Greedi.run(&p, &RunSpec::new(4, 8).threads(4).seed(9));
    assert_eq!(seq.solution, par.solution, "thread count must not change results");
    assert_eq!(seq.value, par.value);
}

#[test]
fn threads_honored_uniformly_across_registry() {
    // Every protocol's map stage runs through the same MapReduce engine, so
    // task counts and shuffle volumes must be identical at any thread count.
    let ds = Arc::new(yahoo_like(400, 4));
    let p = InfoGainProblem::paper_params(&ds);
    for name in protocol::NAMES {
        let proto = protocol::by_name(name).unwrap();
        let seq = proto.run(&p, &RunSpec::new(4, 6).threads(1).seed(2));
        let par = proto.run(&p, &RunSpec::new(4, 6).threads(3).seed(2));
        assert_eq!(seq.solution, par.solution, "{name}");
        assert_eq!(
            seq.job.shuffled_elements, par.job.shuffled_elements,
            "{name}: shuffle volume changed with threads"
        );
        assert_eq!(seq.rounds, par.rounds, "{name}");
        assert_eq!(
            seq.job.stages.len(),
            par.job.stages.len(),
            "{name}: stage count changed with threads"
        );
    }
}
