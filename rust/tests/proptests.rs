//! Property-based tests (hand-rolled harness — proptest is not in the
//! offline dependency closure): randomized invariants over the coordinator
//! (partitioning/routing, protocol feasibility, state management), the
//! objective states, and the algorithm family. Each property runs across a
//! deterministic seed sweep; failures print the offending seed.

use std::sync::Arc;

use greedi::algorithms::{self, Maximizer};
use greedi::constraints::cardinality::Cardinality;
use greedi::constraints::knapsack::Knapsack;
use greedi::constraints::matroid::PartitionMatroid;
use greedi::constraints::Constraint;
use greedi::coordinator::greedi::Greedi;
use greedi::coordinator::protocol::{Protocol, RunSpec};
use greedi::coordinator::{CutProblem, FacilityProblem, Problem};
use greedi::data::graph::social_network;
use greedi::data::synth::{gaussian_blobs, SynthConfig};
use greedi::data::transactions::zipf_transactions;
use greedi::objective::coverage::Coverage;
use greedi::objective::cut::GraphCut;
use greedi::objective::facility::FacilityLocation;
use greedi::objective::SubmodularFn;
use greedi::mapreduce::partition::{balanced_partition, check_is_partition, random_partition};
use greedi::util::rng::Rng;

const SEEDS: std::ops::Range<u64> = 0..12;

/// Random (objective, ground-size) generator spanning the three main
/// objective families. The objectives own their data (Arc), so the boxes
/// are 'static.
fn random_objective(seed: u64) -> (Box<dyn SubmodularFn>, usize) {
    let mut rng = Rng::new(seed);
    match rng.below(3) {
        0 => {
            let n = 30 + rng.below(60);
            let ds = Arc::new(gaussian_blobs(
                &SynthConfig::tiny_images(n, 4 + rng.below(6)),
                seed,
            ));
            (Box::new(FacilityLocation::from_dataset(&ds)), n)
        }
        1 => {
            let n = 30 + rng.below(60);
            let td = Arc::new(zipf_transactions(
                n,
                40 + rng.below(60),
                5 + rng.below(10),
                1.1,
                seed,
            ));
            (Box::new(Coverage::new(&td)), n)
        }
        _ => {
            let n = 30 + rng.below(60);
            let g = Arc::new(social_network(n, n * 5, seed));
            (Box::new(GraphCut::new(&g)), n)
        }
    }
}

// ---------------------------------------------------------------- properties

#[test]
fn prop_partitions_are_exact() {
    for seed in SEEDS {
        let mut rng = Rng::new(seed);
        let n = 50 + rng.below(500);
        let m = 1 + rng.below(16);
        let ground: Vec<usize> = (0..n).collect();
        let p1 = random_partition(&ground, m, &mut rng);
        assert!(check_is_partition(&ground, &p1), "random partition seed {seed}");
        let p2 = balanced_partition(&ground, m, &mut rng);
        assert!(check_is_partition(&ground, &p2), "balanced partition seed {seed}");
        let sizes: Vec<usize> = p2.iter().map(|s| s.len()).collect();
        let (mn, mx) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(mx - mn <= 1, "balanced sizes seed {seed}: {sizes:?}");
    }
}

#[test]
fn prop_greedi_solution_feasible_and_within_bounds() {
    for seed in SEEDS {
        let mut rng = Rng::new(seed ^ 0xABCD);
        let ds = Arc::new(gaussian_blobs(
            &SynthConfig::tiny_images(80 + rng.below(200), 6),
            seed,
        ));
        let p = FacilityProblem::new(&ds);
        let m = 2 + rng.below(6);
        let k = 2 + rng.below(10);
        let alpha = [0.5, 1.0, 2.0][rng.below(3)];
        let r = Greedi.run(&p, &RunSpec::new(m, k).alpha(alpha).seed(seed));
        // feasibility: |S| <= k, S ⊆ V, no duplicates
        assert!(r.solution.len() <= k, "seed {seed}");
        let set: std::collections::HashSet<_> = r.solution.iter().collect();
        assert_eq!(set.len(), r.solution.len(), "duplicates seed {seed}");
        assert!(r.solution.iter().all(|&e| e < ds.n), "seed {seed}");
        // value consistency: reported value is the true global objective
        let true_val = p.global().eval(&r.solution);
        assert!((true_val - r.value).abs() < 1e-9, "seed {seed}");
        // communication bound: ≤ m·κ ids
        let kappa = ((alpha * k as f64).round() as usize).max(1);
        assert!(r.job.shuffled_elements <= m * kappa, "seed {seed}");
    }
}

#[test]
fn prop_gain_matches_eval_difference() {
    for seed in SEEDS {
        let (f, n) = random_objective(seed);
        let mut rng = Rng::new(seed ^ 0x1234);
        let mut st = f.state();
        // random prefix (distinct elements)
        let prefix_len = rng.below(5);
        let prefix: Vec<usize> = rng.sample_indices(n, prefix_len.min(n));
        for &e in &prefix {
            st.push(e);
        }
        let e = rng.below(n);
        if prefix.contains(&e) {
            continue;
        }
        let g = st.gain(e);
        let mut with = prefix.clone();
        with.push(e);
        let brute = f.eval(&with) - f.eval(&prefix);
        assert!(
            (g - brute).abs() < 1e-6 * (1.0 + brute.abs()),
            "seed {seed}: gain {g} vs brute {brute}"
        );
    }
}

#[test]
fn prop_greedy_value_never_below_random_set_average() {
    for seed in SEEDS {
        let (f, n) = random_objective(seed ^ 0x77);
        if !f.is_monotone() {
            continue; // greedy comparison only meaningful for monotone
        }
        let ground: Vec<usize> = (0..n).collect();
        let k = 3 + (seed as usize % 5);
        let mut rng = Rng::new(seed);
        let greedy = algorithms::greedy::Greedy
            .maximize(f.as_ref(), &ground, &Cardinality::new(k), &mut rng)
            .value;
        let mut rand_avg = 0.0;
        for _ in 0..5 {
            let idx = rng.sample_indices(n, k.min(n));
            rand_avg += f.eval(&idx);
        }
        rand_avg /= 5.0;
        assert!(
            greedy >= rand_avg - 1e-9,
            "seed {seed}: greedy {greedy} < random avg {rand_avg}"
        );
    }
}

#[test]
fn prop_lazy_equals_plain_greedy() {
    for seed in SEEDS {
        let (f, n) = random_objective(seed ^ 0x5A5A);
        if !f.is_monotone() {
            continue;
        }
        let ground: Vec<usize> = (0..n).collect();
        let k = 2 + (seed as usize % 6);
        let mut rng = Rng::new(seed);
        let a = algorithms::greedy::Greedy
            .maximize(f.as_ref(), &ground, &Cardinality::new(k), &mut rng)
            .value;
        let b = algorithms::lazy::LazyGreedy
            .maximize(f.as_ref(), &ground, &Cardinality::new(k), &mut rng)
            .value;
        assert!(
            (a - b).abs() < 1e-6 * (1.0 + a.abs()),
            "seed {seed}: plain {a} vs lazy {b}"
        );
    }
}

#[test]
fn prop_constraints_hereditary() {
    // every prefix of a feasible greedy solution is feasible
    for seed in SEEDS {
        let mut rng = Rng::new(seed);
        let n = 20 + rng.below(30);
        let cats: Vec<usize> = (0..n).map(|_| rng.below(4)).collect();
        let caps = vec![1 + rng.below(3); 4];
        let matroid = PartitionMatroid::new(cats, caps);
        let costs: Vec<f64> = (0..n).map(|_| 0.5 + rng.f64() * 2.0).collect();
        let knap = Knapsack::new(costs, 4.0);
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 4), seed));
        let f = FacilityLocation::from_dataset(&ds);
        for con in [&matroid as &dyn Constraint, &knap as &dyn Constraint] {
            let r = algorithms::greedy::Greedy.maximize(
                &f,
                &(0..n).collect::<Vec<_>>(),
                con,
                &mut rng,
            );
            for cut in 0..=r.solution.len() {
                assert!(
                    con.is_feasible(&r.solution[..cut]),
                    "seed {seed}: prefix {cut} infeasible"
                );
            }
        }
    }
}

#[test]
fn prop_cut_protocol_state_consistent() {
    // Non-monotone distributed runs: reported value always equals a fresh
    // global evaluation of the returned solution (no state leakage between
    // rounds/machines).
    for seed in SEEDS {
        let g = Arc::new(social_network(100, 600, seed));
        let p = CutProblem::new(&g);
        let r = Greedi.run(
            &p,
            &RunSpec::new(4, 8).algorithm("random_greedy").local().seed(seed),
        );
        let fresh = p.global().eval(&r.solution);
        assert!((fresh - r.value).abs() < 1e-9, "seed {seed}");
    }
}

#[test]
fn prop_batch_gains_agree_with_scalar_gains() {
    for seed in SEEDS {
        let (f, n) = random_objective(seed ^ 0xBEEF);
        let mut rng = Rng::new(seed);
        let mut st = f.state();
        let prefix_len = rng.below(4).min(n);
        for &e in &rng.sample_indices(n, prefix_len) {
            st.push(e);
        }
        let cand_len = (5 + rng.below(10)).min(n);
        let cands = rng.sample_indices(n, cand_len);
        let batch = st.batch_gains(&cands);
        for (i, &e) in cands.iter().enumerate() {
            let g = st.gain(e);
            assert!(
                (batch[i] - g).abs() < 1e-9 * (1.0 + g.abs()),
                "seed {seed}: batch[{i}] {} vs {g}",
                batch[i]
            );
        }
    }
}

#[test]
fn prop_rng_stream_splitting_reproducible() {
    for seed in SEEDS {
        let base = Rng::new(seed);
        for i in 0..4 {
            let mut a = base.fork(i);
            let mut b = base.fork(i);
            for _ in 0..16 {
                assert_eq!(a.next_u64(), b.next_u64(), "seed {seed} fork {i}");
            }
        }
    }
}
