//! Cross-layer guarantees of the bounded-memory streaming subsystem:
//!
//! 1. the batched sieve's output is **identical** across batch sizes
//!    {1, 64, 4096} and thread counts on a fixed-order stream;
//! 2. peak live candidates never exceed the O(k·log(k)/ε) ladder bound,
//!    even under adversarial (value-ascending) arrival orders;
//! 3. `stream_greedi` is deterministic under `FaultPlan` retries — map
//!    tasks are pure functions of (shard, seed), so rescheduling loses
//!    nothing;
//! 4. the protocol runs end-to-end on a chunked disk source and reports
//!    its per-machine memory peaks in `RunMetrics`;
//! 5. on the Fig. 4 facility-location setup the one-pass protocol reaches
//!    ≥ 85% of two-round GreeDi's objective at equal (m, k).

use std::sync::Arc;

use greedi::coordinator::protocol::{self, Protocol, RunSpec};
use greedi::coordinator::{FacilityProblem, Problem};
use greedi::data::loader::save_csv;
use greedi::data::synth::{gaussian_blobs, SynthConfig};
use greedi::mapreduce::fault::FaultPlan;
use greedi::objective::facility::FacilityLocation;
use greedi::stream::{
    candidate_bound, sieve_stream, ChunkedCsvSource, DriftSource, StreamGreedi, StreamOrder,
    StreamSource, VecSource,
};

const BATCH_SWEEP: [usize; 3] = [1, 64, 4096];
const THREAD_SWEEP: [usize; 3] = [1, 2, 8];

#[test]
fn sieve_identical_across_batch_sizes_and_threads_on_fixed_order() {
    // n = 600 gives the facility window multiple shards (|W|/256 ≥ 2), so
    // the parallel gain engine genuinely fans out inside the sieve pricing.
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(600, 8), 41));
    let f = FacilityLocation::from_dataset(&ds);
    let order: Vec<usize> = VecSource::shuffled(ds.ids(), 7).next_batch(600);
    assert_eq!(order.len(), 600);

    let mut reference_src = VecSource::new(order.clone());
    let reference = sieve_stream(&f, &mut reference_src, 10, 0.2, 1, 1);
    assert!(!reference.solution.is_empty(), "sieve must select something");

    for batch in BATCH_SWEEP {
        for threads in THREAD_SWEEP {
            let mut src = VecSource::new(order.clone());
            let r = sieve_stream(&f, &mut src, 10, 0.2, batch, threads);
            assert_eq!(
                reference.solution, r.solution,
                "batch={batch} threads={threads} changed the selection"
            );
            assert_eq!(reference.value, r.value, "batch={batch} threads={threads}");
            assert_eq!(
                reference.union, r.union,
                "batch={batch} threads={threads} changed the summary"
            );
            assert_eq!(r.elements, 600);
        }
    }
}

#[test]
fn peak_live_candidates_respect_ladder_bound() {
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(500, 8), 43));
    let f = FacilityLocation::from_dataset(&ds);
    // Value-ascending order is the ladder's worst case: every improvement
    // of the best singleton reshapes the rung range.
    for order in [StreamOrder::ValueAscending, StreamOrder::Drift, StreamOrder::ValueDescending] {
        for (k, eps) in [(5usize, 0.1f64), (15, 0.2), (25, 0.5)] {
            let mut src = DriftSource::new(&ds, ds.ids(), order);
            let r = sieve_stream(&f, &mut src, k, eps, 64, 1);
            let bound = candidate_bound(k, eps);
            assert_eq!(r.bound, bound);
            assert!(
                r.peak_live <= bound,
                "{order:?} k={k} ε={eps}: peak {} > bound {bound}",
                r.peak_live
            );
            assert!(r.union.len() <= bound, "{order:?}: summary exceeds the bound");
        }
    }
}

#[test]
fn stream_greedi_deterministic_under_fault_plan_retries() {
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(400, 8), 47));
    let p = FacilityProblem::new(&ds);
    let spec = RunSpec::new(5, 8).epsilon(0.2).batch(32).seed(11);

    let clean = StreamGreedi.run(&p, &spec);
    let cs = clean.stream.clone().expect("stats");
    assert_eq!(cs.retries, 0);

    // Several deterministic fault plans: every one must reproduce the clean
    // run exactly, and collectively they must actually inject retries.
    let mut total_retries = 0usize;
    for plan_seed in 1..=5u64 {
        let faulty = StreamGreedi
            .run_with_faults(&p, &spec, &FaultPlan::new(0.5, 30, plan_seed))
            .expect("30 attempts at p=0.5 cannot plausibly exhaust");
        assert_eq!(clean.solution, faulty.solution, "plan {plan_seed}: retries changed the solution");
        assert_eq!(clean.value, faulty.value, "plan {plan_seed}");
        assert_eq!(
            clean.oracle_calls, faulty.oracle_calls,
            "plan {plan_seed}: oracle accounting must not see retries"
        );
        let fs = faulty.stream.expect("stats");
        assert_eq!(cs.peak_live_per_machine, fs.peak_live_per_machine, "plan {plan_seed}");
        assert_eq!(cs.elements_per_machine, fs.elements_per_machine, "plan {plan_seed}");
        total_retries += fs.retries;
    }
    assert!(total_retries > 0, "p=0.5 across 5 plans and 6 tasks must retry somewhere");
}

#[test]
fn stream_greedi_end_to_end_on_chunked_disk_source() {
    // The full bounded-memory story: the corpus streams off disk in chunks
    // feeding the sieve, and the protocol run over the same data reports
    // per-machine peaks within the bound.
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(300, 8), 53));
    let path = std::env::temp_dir().join("greedi_stream_e2e.csv");
    save_csv(&ds, &path).unwrap();

    // (a) single-machine pass directly off the chunked source
    let f = FacilityLocation::from_dataset(&ds);
    let mut src = ChunkedCsvSource::open(&path).unwrap();
    let r = sieve_stream(&f, &mut src, 10, 0.2, 64, 1);
    assert!(src.error().is_none());
    assert_eq!(src.rows_read(), 300, "one pass must consume the whole file");
    assert_eq!(r.elements, 300);
    assert!(!r.solution.is_empty());
    assert!(r.peak_live <= r.bound);
    // identical to the same pass over an in-memory source in file order
    let mut mem = VecSource::new(ds.ids());
    let rm = sieve_stream(&f, &mut mem, 10, 0.2, 64, 1);
    assert_eq!(r.solution, rm.solution, "ingest path must not change the math");
    assert_eq!(r.value, rm.value);

    // (b) the registered protocol end-to-end with memory accounting
    let p = FacilityProblem::new(&ds);
    let run = protocol::by_name("stream_greedi")
        .unwrap()
        .run(&p, &RunSpec::new(4, 10).epsilon(0.2).batch(64).seed(3));
    assert!(run.solution.len() <= 10);
    assert!((run.value - p.global().eval(&run.solution)).abs() < 1e-9);
    let stats = run.stream.expect("protocol must report stream stats");
    assert_eq!(stats.peak_live_per_machine.len(), 4);
    assert!(stats.within_bound(), "peak {} vs bound {}", stats.peak_live(), stats.live_bound);
    assert_eq!(stats.elements_per_machine.iter().sum::<usize>(), 300);

    std::fs::remove_file(&path).ok();
}

#[test]
fn stream_greedi_within_85_percent_of_greedi_on_fig4_setup() {
    // Scaled Fig. 4 exemplar-clustering setup (tiny-images surrogate),
    // equal (m, k) for both protocols — the acceptance criterion.
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(500, 16), 42));
    let p = FacilityProblem::new(&ds);
    let (m, k) = (5, 15);
    let spec = RunSpec::new(m, k).epsilon(0.1).batch(64).seed(42);
    let greedi = protocol::by_name("greedi").unwrap().run(&p, &spec);
    let stream = protocol::by_name("stream_greedi").unwrap().run(&p, &spec);
    assert!(
        stream.value >= 0.85 * greedi.value,
        "stream_greedi {} < 85% of greedi {}",
        stream.value,
        greedi.value
    );
    // and the memory story must hold while quality does
    assert!(stream.stream.expect("stats").within_bound());
}

#[test]
fn protocol_threads_do_not_change_stream_greedi_results() {
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(400, 8), 59));
    let p = FacilityProblem::new(&ds);
    let base = RunSpec::new(4, 6).epsilon(0.2).batch(32).seed(17);
    let serial = StreamGreedi.run(&p, &base);
    for threads in [2usize, 4, 8] {
        let par = StreamGreedi.run(&p, &base.clone().threads(threads));
        assert_eq!(serial.solution, par.solution, "threads={threads}");
        assert_eq!(serial.value, par.value, "threads={threads}");
        assert_eq!(serial.oracle_calls, par.oracle_calls, "threads={threads}");
    }
}
