//! Integration: the `util::trace` observability layer against whole
//! protocol runs.
//!
//! The load-bearing contract is **non-perturbation**: a traced run must be
//! bit-identical to an untraced run — same solution, same f64 value bits —
//! because spans only read values the algorithms already computed. These
//! tests pin that across several registry protocols and thread counts,
//! then check the exported artifacts themselves: the Chrome trace file
//! parses with `util::json::parse`, covers every MapReduce stage of a
//! greedi run, and forms a well-shaped span forest (per-thread intervals
//! disjoint or properly nested).
//!
//! Tracing is process-global, so every test here serializes on one lock
//! and clears the event buffers before running.

use std::path::PathBuf;
use std::sync::{Mutex, OnceLock};

use greedi::coordinator::protocol::{by_name, RunSpec};
use greedi::coordinator::FacilityProblem;
use greedi::data::synth::{gaussian_blobs, SynthConfig};
use greedi::util::json::{self, Json};
use greedi::util::trace;

fn test_lock() -> &'static Mutex<()> {
    static L: OnceLock<Mutex<()>> = OnceLock::new();
    L.get_or_init(|| Mutex::new(()))
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(format!("greedi_trace_it_{name}_{}", std::process::id()))
}

fn problem(n: usize, seed: u64) -> FacilityProblem {
    let ds = std::sync::Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 8), seed));
    FacilityProblem::new(&ds)
}

/// Protocols the bit-identity sweep covers — two-round, multi-round,
/// randomized baselines and the centralized reference (> 4, as the PR's
/// acceptance bar requires).
const PROTOCOLS: [&str; 5] =
    ["greedi", "multiround", "random_greedy", "greedy_merge", "centralized"];

#[test]
fn traced_runs_are_bit_identical_to_untraced() {
    let _l = test_lock().lock().unwrap();
    trace::disable();
    trace::clear_events();
    let p = problem(300, 11);

    // Pass 1: untraced reference results.
    let mut reference = Vec::new();
    for proto in PROTOCOLS {
        for threads in [1usize, 2, 8] {
            let spec = RunSpec::new(4, 8).seed(7).threads(threads);
            let r = by_name(proto).unwrap().run(&p, &spec);
            reference.push((proto, threads, r.solution, r.value.to_bits()));
        }
    }

    // Pass 2: identical sweep with tracing live.
    let path = tmp("bitident");
    trace::enable(&path);
    for (proto, threads, ref_solution, ref_bits) in &reference {
        let spec = RunSpec::new(4, 8).seed(7).threads(*threads);
        let r = by_name(proto).unwrap().run(&p, &spec);
        assert_eq!(
            &r.solution, ref_solution,
            "{proto} (threads={threads}): traced solution diverged"
        );
        assert_eq!(
            r.value.to_bits(),
            *ref_bits,
            "{proto} (threads={threads}): traced value not bit-identical"
        );
    }
    trace::disable();
    let written = trace::flush().expect("flush returns the configured path");
    assert_eq!(written, path);
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(trace::ndjson_path(&path));
}

/// Flush the buffered events and parse the Chrome-trace document back.
fn flush_and_parse() -> (Json, PathBuf) {
    let path = trace::flush().expect("flush with path configured");
    let doc = json::parse(&std::fs::read_to_string(&path).unwrap())
        .expect("chrome trace must parse with util::json");
    (doc, path)
}

#[test]
fn chrome_trace_covers_every_greedi_stage() {
    let _l = test_lock().lock().unwrap();
    trace::disable();
    trace::clear_events();
    let p = problem(300, 12);
    let path = tmp("stages");
    trace::enable(&path);
    let spec = RunSpec::new(5, 10).seed(3).threads(2);
    let r = by_name("greedi").unwrap().run(&p, &spec);
    assert!(r.value > 0.0);
    trace::disable();

    let (doc, path) = flush_and_parse();
    let evs = doc.get("traceEvents").and_then(|v| v.as_arr()).expect("traceEvents array");
    let count = |name: &str| {
        evs.iter()
            .filter(|e| e.get("name").and_then(|v| v.as_str()) == Some(name))
            .count()
    };
    // one protocol span, both MapReduce rounds (round 1 map + merge), and
    // one mr.task per machine in round 1 plus the merge task
    assert_eq!(count("protocol.greedi"), 1, "protocol span");
    assert_eq!(count("greedi.round1"), 1, "round-1 span");
    assert_eq!(count("greedi.merge"), 1, "merge span");
    assert!(count("mr.stage") >= 2, "a greedi run is at least 2 MapReduce stages");
    assert!(count("mr.task") >= spec.m + 1, "m round-1 tasks + 1 merge task");
    assert!(count("engine.price") > 0, "pricing spans from the gain engine");

    // the metrics block rides in the same document and snapshots cleanly
    let metrics = doc.get("metrics").expect("metrics key");
    assert!(metrics.get("counters").is_some());

    // NDJSON sidecar: one parseable object per line, spans carry dur_us
    let nd = std::fs::read_to_string(trace::ndjson_path(&path)).unwrap();
    let mut saw_span = false;
    for line in nd.lines() {
        let row = json::parse(line).expect("each NDJSON line parses");
        if row.get("kind").and_then(|v| v.as_str()) == Some("span") {
            assert!(row.get("dur_us").and_then(|v| v.as_f64()).unwrap() >= 0.0);
            saw_span = true;
        }
    }
    assert!(saw_span, "sidecar carries span rows");

    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(trace::ndjson_path(&path));
}

#[test]
fn span_forest_is_well_formed_per_thread() {
    let _l = test_lock().lock().unwrap();
    trace::disable();
    trace::clear_events();
    let p = problem(300, 13);
    let path = tmp("forest");
    trace::enable(&path);
    for proto in ["greedi", "multiround"] {
        let spec = RunSpec::new(4, 8).seed(5).threads(8);
        by_name(proto).unwrap().run(&p, &spec);
    }
    trace::disable();

    let (doc, path) = flush_and_parse();
    let evs = doc.get("traceEvents").and_then(|v| v.as_arr()).unwrap();

    // group complete ("X") spans by tid as (start, end) intervals
    let mut by_tid: std::collections::BTreeMap<u64, Vec<(f64, f64)>> = Default::default();
    let mut spans = 0usize;
    for e in evs {
        if e.get("ph").and_then(|v| v.as_str()) != Some("X") {
            continue;
        }
        let tid = e.get("tid").and_then(|v| v.as_u64()).expect("tid");
        let ts = e.get("ts").and_then(|v| v.as_f64()).expect("ts");
        let dur = e.get("dur").and_then(|v| v.as_f64()).expect("dur");
        assert!(dur >= 0.0, "negative span duration");
        assert!(
            e.get("args").and_then(|a| a.get("depth")).and_then(|v| v.as_f64()).is_some(),
            "every span carries its nesting depth"
        );
        by_tid.entry(tid).or_default().push((ts, ts + dur));
        spans += 1;
    }
    assert!(spans > 0, "the runs must have produced spans");

    // Within one thread, RAII spans form a forest: any two intervals are
    // disjoint or one contains the other. Sweep with an enclosing-span
    // stack (sort by start, longest-first on ties); ε absorbs the ns→µs
    // float conversion.
    const EPS: f64 = 1e-3;
    for (tid, mut iv) in by_tid {
        iv.sort_by(|a, b| {
            a.0.partial_cmp(&b.0).unwrap().then(b.1.partial_cmp(&a.1).unwrap())
        });
        let mut stack: Vec<(f64, f64)> = Vec::new();
        for (s, e) in iv {
            while let Some(&(_, top_end)) = stack.last() {
                if top_end <= s + EPS {
                    stack.pop();
                } else {
                    break;
                }
            }
            if let Some(&(_, top_end)) = stack.last() {
                assert!(
                    e <= top_end + EPS,
                    "tid {tid}: span [{s}, {e}] straddles its enclosing span ending {top_end}"
                );
            }
            stack.push((s, e));
        }
    }

    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(trace::ndjson_path(&path));
}
