//! Shared property harness for partitioning: every [`PartitionStrategy`] ×
//! machine count × multiplicity is pushed through the same four invariants:
//!
//! 1. every element lands on **exactly c distinct machines**;
//! 2. no element appears twice on one machine;
//! 3. the split is deterministic given the seed;
//! 4. `c = 1` is bit-identical to the un-replicated `split` (so turning the
//!    multiplicity knob off reproduces every pre-existing run exactly).

//! PR 8 extends the harness with placement: `split_placed` under
//! `distinct_domains` must put every element's replicas in distinct failure
//! domains, and domain crashes must be rack-atomic and deterministic from
//! `(seed, plan)`.

use std::collections::{HashMap, HashSet};

use greedi::mapreduce::fault::{DomainMap, FaultPlan};
use greedi::mapreduce::partition::{
    check_distinct_domain_placement, check_replicated_partition, PartitionStrategy,
    PlacementPolicy,
};
use greedi::util::rng::Rng;

/// The one checker every (strategy, m, c) cell goes through.
fn assert_replication_properties(
    strat: PartitionStrategy,
    ground: &[usize],
    m: usize,
    c: usize,
    seed: u64,
) {
    let label = format!("{} n={} m={m} c={c}", strat.label(), ground.len());
    let shards = strat.split_replicated(ground, m, c, &mut Rng::new(seed));
    assert_eq!(shards.len(), m, "{label}: wrong machine count");

    // 1 + 2: exactly c copies, all on distinct machines.
    assert!(
        check_replicated_partition(ground, &shards, c),
        "{label}: not an exact c-replicated partition"
    );
    let mut owners: HashMap<usize, HashSet<usize>> = HashMap::new();
    for (i, shard) in shards.iter().enumerate() {
        for &e in shard {
            owners.entry(e).or_default().insert(i);
        }
    }
    for &e in ground {
        assert_eq!(
            owners.get(&e).map(HashSet::len),
            Some(c),
            "{label}: element {e} not on exactly {c} distinct machines"
        );
    }

    // 3: same seed => same shards; the replica volume is exactly n*c.
    let again = strat.split_replicated(ground, m, c, &mut Rng::new(seed));
    assert_eq!(shards, again, "{label}: split is not deterministic per seed");
    let volume: usize = shards.iter().map(Vec::len).sum();
    assert_eq!(volume, ground.len() * c, "{label}: replica volume drifted");

    // 4: multiplicity 1 collapses to the plain split, bit for bit.
    if c == 1 {
        let plain = strat.split(ground, m, &mut Rng::new(seed));
        assert_eq!(shards, plain, "{label}: c=1 must equal split()");
    }
}

#[test]
fn every_strategy_m_c_cell_holds_the_invariants() {
    // non-contiguous, descending ids to rule out positional luck
    let ground: Vec<usize> = (0..257).map(|i| i * 3 + 1).rev().collect();
    for strat in PartitionStrategy::ALL {
        for m in [1usize, 2, 5, 9, 16] {
            for c in 1..=m.min(4) {
                assert_replication_properties(strat, &ground, m, c, 71);
            }
        }
    }
}

#[test]
fn full_replication_puts_everything_everywhere() {
    let ground: Vec<usize> = (0..40).collect();
    for strat in PartitionStrategy::ALL {
        let m = 5;
        let shards = strat.split_replicated(&ground, m, m, &mut Rng::new(3));
        for (i, shard) in shards.iter().enumerate() {
            let s: HashSet<usize> = shard.iter().copied().collect();
            assert_eq!(
                s.len(),
                ground.len(),
                "{} c=m: machine {i} must hold the whole ground set",
                strat.label()
            );
        }
        assert_replication_properties(strat, &ground, m, m, 3);
    }
}

#[test]
fn randomized_strategies_respond_to_the_seed() {
    let ground: Vec<usize> = (0..300).collect();
    for strat in [PartitionStrategy::Random, PartitionStrategy::Balanced] {
        let a = strat.split_replicated(&ground, 8, 2, &mut Rng::new(21));
        let b = strat.split_replicated(&ground, 8, 2, &mut Rng::new(22));
        assert_ne!(a, b, "{}: replicated split ignores the seed", strat.label());
    }
    // contiguous has no randomness: any seed gives the same layout
    let a = PartitionStrategy::Contiguous.split_replicated(&ground, 8, 2, &mut Rng::new(21));
    let b = PartitionStrategy::Contiguous.split_replicated(&ground, 8, 2, &mut Rng::new(22));
    assert_eq!(a, b, "contiguous replication must be seed-independent");
}

#[test]
fn distinct_domain_placement_holds_for_every_strategy() {
    let ground: Vec<usize> = (0..257).map(|i| i * 3 + 1).rev().collect();
    for strat in PartitionStrategy::ALL {
        for (m, d) in [(4usize, 2usize), (9, 3), (16, 4)] {
            let domains = DomainMap::Modulo(d);
            for c in 2..=d.min(3) {
                let shards = strat.split_placed(
                    &ground,
                    m,
                    c,
                    PlacementPolicy::DistinctDomains,
                    &domains,
                    &mut Rng::new(83),
                );
                assert!(
                    check_distinct_domain_placement(&ground, &shards, c, &domains),
                    "{} m={m} d={d} c={c}: replicas share a failure domain",
                    strat.label()
                );
                // deterministic per seed, like every other split
                let again = strat.split_placed(
                    &ground,
                    m,
                    c,
                    PlacementPolicy::DistinctDomains,
                    &domains,
                    &mut Rng::new(83),
                );
                assert_eq!(shards, again, "{} m={m} d={d} c={c}", strat.label());
            }
            // anywhere placement must be byte-identical to the pre-placement
            // split_replicated on the same RNG stream
            let anywhere = strat.split_placed(
                &ground,
                m,
                2,
                PlacementPolicy::Anywhere,
                &domains,
                &mut Rng::new(83),
            );
            let plain = strat.split_replicated(&ground, m, 2, &mut Rng::new(83));
            assert_eq!(anywhere, plain, "{} m={m}: anywhere drifted from legacy", strat.label());
        }
    }
}

#[test]
fn impossible_distinct_placement_falls_back_to_anywhere() {
    // c > #domains: domain-distinct placement cannot exist, so the split
    // must silently take the legacy path rather than panic or dead-loop.
    let ground: Vec<usize> = (0..100).collect();
    for strat in PartitionStrategy::ALL {
        let domains = DomainMap::Modulo(2);
        let placed = strat.split_placed(
            &ground,
            6,
            3,
            PlacementPolicy::DistinctDomains,
            &domains,
            &mut Rng::new(7),
        );
        let plain = strat.split_replicated(&ground, 6, 3, &mut Rng::new(7));
        assert_eq!(placed, plain, "{}: c > d must fall back", strat.label());
    }
}

#[test]
fn domain_crashes_are_rack_atomic_and_deterministic() {
    let m = 12;
    let plan = FaultPlan::new(0.0, 1, 91).domain_groups(4).domain_crashes(0.5);
    let crashed: Vec<bool> = (0..m).map(|t| plan.crashed(t)).collect();
    // rack-atomic: two machines in the same domain share a fate
    for t in 0..m {
        let dom = plan.domains.domain_of(t);
        assert_eq!(
            crashed[t],
            plan.domain_crashed(dom),
            "machine {t} disagrees with its domain {dom}"
        );
        for u in 0..m {
            if plan.domains.domain_of(u) == dom {
                assert_eq!(crashed[t], crashed[u], "machines {t},{u} share domain {dom}");
            }
        }
    }
    // deterministic from (seed, plan): an identical rebuild draws the same coins
    let rebuilt = FaultPlan::new(0.0, 1, 91).domain_groups(4).domain_crashes(0.5);
    let again: Vec<bool> = (0..m).map(|t| rebuilt.crashed(t)).collect();
    assert_eq!(crashed, again, "same (seed, plan) must crash the same racks");
    // ...and the seed actually matters: across many seeds, at least one
    // draws a different crash pattern (p = 0.5 over 4 racks).
    let differs = (0..16u64).any(|s| {
        let alt = FaultPlan::new(0.0, 1, 91 ^ (s + 1)).domain_groups(4).domain_crashes(0.5);
        (0..m).map(|t| alt.crashed(t)).collect::<Vec<bool>>() != crashed
    });
    assert!(differs, "domain crash coins ignore the seed");
}

#[test]
fn small_grounds_and_edge_shapes_still_partition() {
    for strat in PartitionStrategy::ALL {
        // empty ground: m empty shards, any c <= m
        let shards = strat.split_replicated(&[], 4, 2, &mut Rng::new(1));
        assert_eq!(shards.len(), 4);
        assert!(shards.iter().all(Vec::is_empty), "{}", strat.label());
        // fewer elements than machines
        assert_replication_properties(strat, &[7, 9], 6, 2, 5);
        // single element, replicated everywhere
        assert_replication_properties(strat, &[42], 3, 3, 5);
    }
}
