//! Cross-module integration: GreeDi + baselines + GreedyScaling over every
//! objective family, checking the paper's qualitative claims end-to-end —
//! all driven through the unified `Protocol` + `RunSpec` API.

use std::sync::Arc;

use greedi::coordinator::baselines::Baseline;
use greedi::coordinator::greedi::{centralized, Greedi, PartitionStrategy};
use greedi::coordinator::greedy_scaling::GreedyScaling;
use greedi::coordinator::protocol::{self, Protocol, RunSpec};
use greedi::coordinator::{
    CoverageProblem, CutProblem, FacilityProblem, InfoGainProblem, Problem,
};
use greedi::data::graph::social_network;
use greedi::data::synth::{gaussian_blobs, parkinsons_like, yahoo_like, SynthConfig};
use greedi::data::transactions::accidents_like;
use greedi::util::stats::mean;

#[test]
fn facility_full_protocol_suite_ordering() {
    // The paper's headline ordering: greedi ≥ greedy/max ≥ random/random,
    // and greedi close to centralized.
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(600, 8), 1));
    let p = FacilityProblem::new(&ds);
    let (m, k) = (6, 12);
    let central = centralized(&p, k, "lazy", 5).value;

    let mut greedi_vals = Vec::new();
    let mut gmax_vals = Vec::new();
    let mut rr_vals = Vec::new();
    for seed in 0..4 {
        let spec = RunSpec::new(m, k).seed(seed);
        greedi_vals.push(Greedi.run(&p, &spec).value);
        gmax_vals.push(Baseline::GreedyMax.run(&p, &spec).value);
        rr_vals.push(Baseline::RandomRandom.run(&p, &spec).value);
    }
    let (g, gm, rr) = (mean(&greedi_vals), mean(&gmax_vals), mean(&rr_vals));
    assert!(g / central > 0.93, "greedi ratio {}", g / central);
    assert!(g >= gm - 1e-9, "greedi {g} < greedy/max {gm}");
    assert!(gm > rr, "greedy/max {gm} <= random/random {rr}");
}

#[test]
fn infogain_all_machine_counts() {
    let ds = Arc::new(parkinsons_like(300, 10, 2));
    let p = InfoGainProblem::paper_params(&ds);
    let k = 10;
    let central = centralized(&p, k, "lazy", 3).value;
    for m in [2, 4, 8] {
        let r = Greedi.run(&p, &RunSpec::new(m, k).seed(3));
        assert!(
            r.value / central > 0.9,
            "m={m}: ratio {}",
            r.value / central
        );
    }
}

#[test]
fn yahoo_like_infogain_m32() {
    // Fig 7 geometry at reduced n: m = 32 shards over a 6-d corpus.
    let ds = Arc::new(yahoo_like(1_000, 4));
    let p = InfoGainProblem::paper_params(&ds);
    let central = centralized(&p, 16, "lazy", 1).value;
    let r = Greedi.run(&p, &RunSpec::new(32, 16).seed(1));
    assert!(r.value / central > 0.85, "ratio {}", r.value / central);
}

#[test]
fn cut_nonmonotone_distributed() {
    let g = Arc::new(social_network(400, 3_000, 5));
    let p = CutProblem::new(&g);
    let central: Vec<f64> = (0..3)
        .map(|s| centralized(&p, 20, "random_greedy", s).value)
        .collect();
    let grd: Vec<f64> = (0..3)
        .map(|s| {
            Greedi
                .run(
                    &p,
                    &RunSpec::new(5, 20).algorithm("random_greedy").local().seed(s),
                )
                .value
        })
        .collect();
    // paper: ≈0.90 ratio for max cut; allow slack for the small instance
    assert!(
        mean(&grd) / mean(&central) > 0.7,
        "cut ratio {}",
        mean(&grd) / mean(&central)
    );
}

#[test]
fn coverage_greedi_beats_or_matches_greedy_scaling_with_fewer_rounds() {
    let td = Arc::new(accidents_like(3_000, 6));
    let p = CoverageProblem::new(&td);
    let k = 20;
    let central = centralized(&p, k, "lazy", 2).value;
    let spec = RunSpec::new(8, k).seed(2);
    let grd = Greedi.run(&p, &spec);
    let gs = GreedyScaling.run(&p, &spec.clone().delta(0.5));
    assert_eq!(grd.rounds, 2);
    assert!(gs.rounds >= grd.rounds, "gs rounds {}", gs.rounds);
    assert!(grd.value / central > 0.9);
    // on Accidents-like data the paper shows GreeDi ≥ GreedyScaling
    assert!(
        grd.value >= 0.95 * gs.value,
        "greedi {} vs greedy-scaling {}",
        grd.value,
        gs.value
    );
}

#[test]
fn local_mode_close_to_global_mode() {
    // Theorem 10: decomposable local evaluation loses little.
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(800, 8), 7));
    let p = FacilityProblem::new(&ds);
    let k = 10;
    let global: Vec<f64> = (0..3)
        .map(|s| Greedi.run(&p, &RunSpec::new(5, k).seed(s)).value)
        .collect();
    let local: Vec<f64> = (0..3)
        .map(|s| Greedi.run(&p, &RunSpec::new(5, k).local().seed(s)).value)
        .collect();
    assert!(
        mean(&local) > 0.9 * mean(&global),
        "local {} vs global {}",
        mean(&local),
        mean(&global)
    );
}

#[test]
fn partition_strategies_all_work() {
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(300, 8), 8));
    let p = FacilityProblem::new(&ds);
    for strat in [
        PartitionStrategy::Random,
        PartitionStrategy::Balanced,
        PartitionStrategy::Contiguous,
    ] {
        let r = Greedi.run(&p, &RunSpec::new(4, 8).partition(strat).seed(1));
        assert!(r.solution.len() <= 8);
        assert!(r.value > 0.0);
    }
}

#[test]
fn deterministic_end_to_end() {
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(200, 8), 9));
    let p = FacilityProblem::new(&ds);
    let a = Greedi.run(&p, &RunSpec::new(4, 6).seed(33));
    let b = Greedi.run(&p, &RunSpec::new(4, 6).seed(33));
    assert_eq!(a.solution, b.solution);
    assert_eq!(a.oracle_calls, b.oracle_calls);
}

#[test]
fn stochastic_greedy_inside_greedi() {
    // swapping the per-machine black box (Alg 3's X) still yields a
    // competitive distributed solution.
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(500, 8), 10));
    let p = FacilityProblem::new(&ds);
    let central = centralized(&p, 10, "lazy", 4).value;
    let r = Greedi.run(&p, &RunSpec::new(5, 10).algorithm("stochastic").seed(4));
    assert!(r.value / central > 0.85, "ratio {}", r.value / central);
}

#[test]
fn merge_objective_window_used_in_local_mode() {
    // Local-mode round 2 must evaluate on a ⌈n/m⌉ window — observable via
    // the Problem::merge hook returning a restricted objective whose eval
    // differs from global on most sets. Smoke-check it still produces a
    // feasible, competitive solution at several m.
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(400, 8), 11));
    let p = FacilityProblem::new(&ds);
    for m in [2, 8] {
        let r = Greedi.run(&p, &RunSpec::new(m, 8).local().seed(6));
        assert!(r.solution.len() <= 8);
        let global_val = p.global().eval(&r.solution);
        assert!((global_val - r.value).abs() < 1e-9);
    }
}

#[test]
fn registry_suite_shares_one_spec_across_objectives() {
    // The tentpole's promise: sweep the whole registry over heterogeneous
    // problems with a single spec and no per-protocol plumbing.
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(250, 8), 12));
    let facility = FacilityProblem::new(&ds);
    let td = Arc::new(accidents_like(500, 13));
    let coverage = CoverageProblem::new(&td);
    let problems: [&dyn Problem; 2] = [&facility, &coverage];
    let spec = RunSpec::new(4, 6).seed(14);
    for problem in problems {
        let central = protocol::by_name("centralized").unwrap().run(problem, &spec);
        for name in protocol::NAMES {
            let run = protocol::by_name(name).unwrap().run(problem, &spec);
            assert!(run.solution.len() <= 6, "{name}: budget");
            assert!(run.value.is_finite() && run.value >= 0.0, "{name}: value");
            // every heuristic is greedy-family; none should meaningfully
            // beat the centralized reference (tiny slack for tie-breaks)
            assert!(
                run.value <= central.value * 1.02 + 1e-9,
                "{name}: beat centralized ({} vs {})",
                run.value,
                central.value
            );
        }
    }
}
