//! Integration tests for the staged r-ary accumulation-tree merge
//! (`mapreduce::reduce::TreeReduce`) as wired into the three distributed
//! protocols via `RunSpec::fanout`.
//!
//! The headline pins:
//!
//! * any fanout is **thread-invariant**: solution and `value.to_bits()`
//!   are identical at 1/2/8 threads for greedi, multiround and
//!   stream_greedi;
//! * `fanout >= m` (and the 0 default, for the flat-by-default protocols)
//!   reproduces the classic single-root merge **bit for bit** — the tree
//!   is a strict generalization, not a fork;
//! * an interior merge-node crash under `survivor_merge` / `resume` is
//!   recovered to the bit-identical fault-free output;
//! * staging is what it claims to be for memory: the root's candidate
//!   pool at r = 2 never exceeds the flat merge's.

use std::sync::Arc;

use greedi::coordinator::protocol::{
    self, FaultPlan, Protocol, RecoveryPolicy, RunSpec,
};
use greedi::coordinator::FacilityProblem;
use greedi::data::synth::{gaussian_blobs, SynthConfig};

fn problem(n: usize, seed: u64) -> FacilityProblem {
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 8), seed));
    FacilityProblem::new(&ds)
}

const PROTOCOLS: [&str; 3] = ["greedi", "multiround", "stream_greedi"];

#[test]
fn tree_outputs_are_thread_invariant_across_fanouts() {
    let p = problem(300, 71);
    let m = 6usize;
    for name in PROTOCOLS {
        let proto = protocol::by_name(name).unwrap();
        for fanout in [2usize, 4, m] {
            let base = RunSpec::new(m, 8).seed(41).fanout(fanout);
            let serial = proto.run(&p, &base.clone().threads(1));
            let tree = serial.tree.as_ref().expect("tree stats attach");
            assert_eq!(tree.nodes_per_level.len(), tree.depth, "{name} r={fanout}");
            assert_eq!(*tree.nodes_per_level.last().unwrap(), 1, "{name}: one root");
            for threads in [2usize, 8] {
                let par = proto.run(&p, &base.clone().threads(threads));
                assert_eq!(
                    par.solution, serial.solution,
                    "{name} r={fanout} threads={threads}: solution drifted"
                );
                assert_eq!(
                    par.value.to_bits(),
                    serial.value.to_bits(),
                    "{name} r={fanout} threads={threads}: value drifted"
                );
                assert_eq!(
                    par.tree.as_ref().unwrap().peak_per_level,
                    tree.peak_per_level,
                    "{name} r={fanout} threads={threads}: per-level peaks drifted"
                );
            }
        }
    }
}

#[test]
fn saturating_fanout_reproduces_the_flat_merge_bit_for_bit() {
    let p = problem(300, 72);
    let m = 5usize;
    // greedi and stream_greedi default to the flat single-root merge; any
    // r >= m must collapse back onto it exactly
    for name in ["greedi", "stream_greedi"] {
        let proto = protocol::by_name(name).unwrap();
        let flat = proto.run(&p, &RunSpec::new(m, 8).seed(43));
        let flat_tree = flat.tree.as_ref().expect("tree stats");
        assert_eq!(flat_tree.depth, 1, "{name}: default merge is one level");
        assert_eq!(flat.rounds, 2, "{name}: map + merge");
        for r in [m, 64] {
            let sat = proto.run(&p, &RunSpec::new(m, 8).seed(43).fanout(r));
            assert_eq!(sat.solution, flat.solution, "{name} r={r}");
            assert_eq!(sat.value.to_bits(), flat.value.to_bits(), "{name} r={r}");
            assert_eq!(sat.rounds, flat.rounds, "{name} r={r}");
            assert_eq!(
                sat.tree.as_ref().unwrap().peak_per_level,
                flat_tree.peak_per_level,
                "{name} r={r}"
            );
        }
    }
    // multiround's historic default is the binary tree: fanout 0 == fanout 2
    let proto = protocol::by_name("multiround").unwrap();
    let default = proto.run(&p, &RunSpec::new(m, 8).seed(43));
    let binary = proto.run(&p, &RunSpec::new(m, 8).seed(43).fanout(2));
    assert_eq!(default.solution, binary.solution);
    assert_eq!(default.value.to_bits(), binary.value.to_bits());
    assert_eq!(default.rounds, binary.rounds);
}

#[test]
fn interior_node_crash_recovers_bit_identically() {
    let p = problem(300, 73);
    let m = 4usize;
    for name in PROTOCOLS {
        let proto = protocol::by_name(name).unwrap();
        // multiplicity 2 keeps the map-stage crash of machine 0 invisible
        // (PR 7's pin); what's new here is that the SAME plan also crashes
        // node 0 of every interior tree level, recovered in place
        let clean_spec =
            RunSpec::new(m, 8).multiplicity(2).seed(47).fanout(2).faults(FaultPlan::none());
        let clean = proto.run(&p, &clean_spec);
        assert!(
            clean.tree.as_ref().unwrap().depth > 1,
            "{name}: fanout 2 over {m} leaves must stage"
        );
        for policy in [RecoveryPolicy::SurvivorMerge, RecoveryPolicy::Resume] {
            let spec = clean_spec
                .clone()
                .recovery(policy)
                .checkpoint_every(2)
                .faults(FaultPlan::none().crash_tasks(vec![0]));
            let r = proto.run(&p, &spec);
            assert_eq!(
                r.solution,
                clean.solution,
                "{name}/{}: interior crash changed the solution",
                policy.label()
            );
            assert_eq!(r.value.to_bits(), clean.value.to_bits(), "{name}/{}", policy.label());
            let tree = r.tree.as_ref().expect("tree stats");
            assert!(
                tree.recovered_nodes >= 1,
                "{name}/{}: the crashed interior node must be re-merged",
                policy.label()
            );
            assert_eq!(tree.peak_per_level, clean.tree.as_ref().unwrap().peak_per_level);
        }
    }
}

#[test]
fn root_peak_is_monotone_versus_flat() {
    let p = problem(400, 74);
    for name in PROTOCOLS {
        let proto = protocol::by_name(name).unwrap();
        let m = 8usize;
        let flat = proto.run(&p, &RunSpec::new(m, 8).seed(53).fanout(m));
        let deep = proto.run(&p, &RunSpec::new(m, 8).seed(53).fanout(2));
        let (flat_t, deep_t) = (flat.tree.as_ref().unwrap(), deep.tree.as_ref().unwrap());
        assert_eq!(flat_t.depth, 1, "{name}");
        assert!(deep_t.depth > 1, "{name}");
        // interior winners are drawn from subsets of what the flat merge
        // pools directly, so staging can only shrink the root's pool
        assert!(
            deep_t.root_peak() <= flat_t.root_peak(),
            "{name}: root peak grew under staging: {} vs flat {}",
            deep_t.root_peak(),
            flat_t.root_peak()
        );
        assert_eq!(deep.rounds, 1 + deep_t.depth, "{name}: rounds track depth");
    }
}

#[test]
fn m100_tree_caps_root_peak_well_below_flat() {
    // the acceptance-scale point: at m = 100 the flat merge pools O(m·κ)
    // candidates at the root while an r = 4 tree caps it at O(r·κ)
    let p = problem(600, 75);
    let proto = protocol::by_name("greedi").unwrap();
    let k = 4usize;
    let flat = proto.run(&p, &RunSpec::new(100, k).seed(59).algorithm("greedy"));
    let tree = proto.run(&p, &RunSpec::new(100, k).seed(59).algorithm("greedy").fanout(4));
    let (ft, tt) = (flat.tree.as_ref().unwrap(), tree.tree.as_ref().unwrap());
    assert!(
        tt.root_peak() < ft.root_peak(),
        "r=4 root peak {} must undercut flat {}",
        tt.root_peak(),
        ft.root_peak()
    );
    assert!(
        tt.root_peak() <= 4 * k,
        "r=4 root pool is at most r·κ = {}: got {}",
        4 * k,
        tt.root_peak()
    );
    assert!(ft.root_peak() > 4 * k, "flat pools many machines' candidates");
    // staging trades memory for quality only mildly: within 10% here
    assert!(
        tree.value >= 0.9 * flat.value,
        "tree quality collapsed: {} vs {}",
        tree.value,
        flat.value
    );
}
