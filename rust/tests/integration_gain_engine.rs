//! Cross-layer guarantees of the sharded gain engine
//! (`objective::engine::ShardedGainEngine`) — ONE shared harness instead of
//! the three copy-pasted per-objective unit tests it replaced:
//!
//! 1. every objective in the crate prices **bit-identically** across
//!    thread counts {1, 2, 8} and across every pricing surface
//!    (`gain` == `batch_gains` == `par_batch_gains`), because shard
//!    boundaries depend only on problem shape and per-shard partials
//!    reduce in a fixed order;
//! 2. `singleton_gains` (the sieve's ladder entry, including the
//!    closed-form overrides on modular/coverage and the `ForwardFn`
//!    forwarding shim) is bit-identical to fresh-state pricing;
//! 3. `eval`-replay consistency: a state's accumulated `value()` equals
//!    `f.eval(selected)` exactly (eval IS a push replay);
//! 4. batch-repriced `LazyGreedy` selects **exactly** the plain-`Greedy`
//!    set, serial or parallel, standalone or inside a protocol round-trip;
//! 5. threading a full protocol (`RunSpec::threads`) is invisible in its
//!    results — only in its wallclock — and fixed seeds reproduce.
//!
//! CI re-runs this suite under `GREEDI_NO_SIMD=1`, under
//! `GREEDI_EXECUTOR_SERIAL=1`, and under both combined, so the matrix in
//! the module docs of `objective::engine` is exercised end to end.

use std::sync::Arc;

use greedi::algorithms::{greedy::Greedy, lazy::LazyGreedy, Maximizer};
use greedi::constraints::cardinality::Cardinality;
use greedi::coordinator::protocol::{self, RunSpec};
use greedi::coordinator::{
    CoverageProblem, CutProblem, FacilityProblem, OpaqueProblem, Problem,
};
use greedi::data::graph::social_network;
use greedi::data::synth::{gaussian_blobs, parkinsons_like, SynthConfig};
use greedi::data::transactions::zipf_transactions;
use greedi::objective::coverage::Coverage;
use greedi::objective::cut::GraphCut;
use greedi::objective::dpp::DppLogDet;
use greedi::objective::entropy_worstcase::EntropyWorstCase;
use greedi::objective::facility::FacilityLocation;
use greedi::objective::infogain::InfoGain;
use greedi::objective::modular::Modular;
use greedi::objective::SubmodularFn;
use greedi::util::rng::Rng;

const THREAD_SWEEP: [usize; 3] = [1, 2, 8];

/// The shared invariance harness: every objective instance must satisfy
/// the engine contract on a seeded state (after `pushes`) AND on a fresh
/// state (the singleton path).
fn assert_engine_invariants(
    label: &str,
    f: &dyn SubmodularFn,
    pushes: &[usize],
    cands: &[usize],
) {
    // --- singleton path: bit-identical to a fresh state, at any threads.
    // The reference is priced through `gain()`, which always runs the real
    // sharded kernel path — batch_gains on an empty state would take the
    // same closed-form fast path the singleton override uses, making the
    // comparison tautological for modular/coverage.
    let mut fresh = f.state();
    let fresh_ref: Vec<f64> = cands.iter().map(|&e| fresh.gain(e)).collect();
    for threads in THREAD_SWEEP {
        assert_eq!(
            fresh_ref,
            f.singleton_gains(cands, threads),
            "{label}: singleton_gains diverged from fresh-state kernel pricing at {threads} threads"
        );
    }
    // ...and the engine's empty-state fast path must agree with the same
    // kernel reference too.
    assert_eq!(
        fresh_ref,
        f.state().batch_gains(cands),
        "{label}: empty-state batch pricing diverged from the kernel path"
    );

    // --- seeded state: gain == batch_gains == par_batch_gains, bitwise.
    let mut st = f.state();
    for &e in pushes {
        st.push(e);
    }
    let reference = st.batch_gains(cands);
    for (i, &e) in cands.iter().enumerate() {
        assert_eq!(
            reference[i],
            st.gain(e),
            "{label}: gain({e}) diverged from batch_gains"
        );
    }
    for threads in THREAD_SWEEP {
        assert_eq!(
            reference,
            st.par_batch_gains(cands, threads),
            "{label}: par_batch_gains changed bits at {threads} threads"
        );
    }

    // --- eval-replay consistency: eval IS a push replay, so the state's
    // accumulated value must reproduce it exactly (bitwise).
    assert_eq!(
        st.value(),
        f.eval(st.selected()),
        "{label}: value() diverged from eval replay of selected()"
    );

    // --- engine-owned oracle accounting: pure function of the call
    // sequence (hence thread-invariant by construction).
    let mut counted = f.state();
    counted.batch_gains(cands);
    counted.par_batch_gains(cands, 8);
    counted.gain(cands[0]);
    let c = counted.oracle_counter();
    assert_eq!(c.batches, 2, "{label}: batch count");
    assert_eq!(c.gains, 2 * cands.len() as u64 + 1, "{label}: gain count");
}

#[test]
fn every_objective_satisfies_the_engine_contract() {
    // facility, global window — n = 1500 guarantees several window shards,
    // so the parallel path genuinely fans out.
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(1500, 8), 3));
    let fac = FacilityLocation::from_dataset(&ds);
    let fac_cands: Vec<usize> = (0..128).map(|i| (i * 11) % 1500).collect();
    assert_engine_invariants("facility", &fac, &[42, 901], &fac_cands);

    // facility, restricted window (the paper's §4.5 local mode).
    let fac_local = FacilityLocation::with_window(&ds, (0..1500).step_by(2).collect());
    assert_engine_invariants("facility/windowed", &fac_local, &[8, 700], &fac_cands);

    // coverage, unweighted + weighted (closed-form singleton override).
    let td = Arc::new(zipf_transactions(500, 400, 9, 1.1, 4));
    let cov = Coverage::new(&td);
    let all500: Vec<usize> = (0..500).collect();
    assert_engine_invariants("coverage", &cov, &[17, 250], &all500);
    let cov_w = Coverage::weighted(&td, (0..400).map(|i| 0.25 + (i % 7) as f64).collect());
    assert_engine_invariants("coverage/weighted", &cov_w, &[17, 250], &all500);

    // cut, full graph + induced-subgraph restriction (non-monotone path).
    let g = Arc::new(social_network(300, 2_000, 5));
    let cut = GraphCut::new(&g);
    let all300: Vec<usize> = (0..300).collect();
    assert_engine_invariants("cut", &cut, &[3, 120], &all300);
    let cut_local = GraphCut::restricted(&g, &(0..150).collect::<Vec<_>>());
    assert_engine_invariants("cut/restricted", &cut_local, &[3, 120], &all300);

    // dpp — per-shard Schur complements (first-ever parallel path).
    let ds_small = Arc::new(gaussian_blobs(&SynthConfig::unstructured(120, 6), 13));
    let dpp = DppLogDet::new(&ds_small, 1.0, 0.5);
    let all120: Vec<usize> = (0..120).collect();
    assert_engine_invariants("dpp", &dpp, &[2, 61, 99], &all120);

    // infogain — per-shard Cholesky probe columns (first-ever parallel path).
    let pk = Arc::new(parkinsons_like(150, 10, 3));
    let ig = InfoGain::paper_params(&pk);
    let all150: Vec<usize> = (0..150).collect();
    assert_engine_invariants("infogain", &ig, &[1, 75, 149], &all150);

    // entropy worst-case — the Theorem-3 tightness instance.
    let ent = EntropyWorstCase::new(12, 10);
    let ent_cands: Vec<usize> = (0..ent.ground_size()).collect();
    assert_engine_invariants("entropy_worstcase", &ent, &[10, 21, 35], &ent_cands);

    // modular — closed-form singleton override.
    let weights: Vec<f64> = (0..300).map(|i| (i % 13) as f64 + 0.5).collect();
    let modular = Modular::new(weights);
    assert_engine_invariants("modular", &modular, &[7, 100], &all300);
}

#[test]
fn forwarding_shim_preserves_closed_form_singletons() {
    // OpaqueProblem's ForwardFn must forward singleton_gains — the trait
    // default would rebuild a fresh state and miss the inner override.
    let modular = Modular::new((0..64).map(|i| i as f64 * 0.5).collect());
    let p = OpaqueProblem::new(&modular);
    let fwd = p.global();
    let es: Vec<usize> = (0..64).rev().collect();
    for threads in THREAD_SWEEP {
        assert_eq!(
            modular.singleton_gains(&es, threads),
            fwd.singleton_gains(&es, threads),
            "ForwardFn singleton_gains diverged at {threads} threads"
        );
    }
    assert_engine_invariants("modular/forwarded", fwd.as_ref(), &[5, 31], &es);
}

#[test]
fn batch_repriced_lazy_equals_plain_greedy_across_objectives_and_threads() {
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(400, 8), 6));
    let facility = FacilityLocation::from_dataset(&ds);
    let td = Arc::new(zipf_transactions(200, 250, 8, 1.1, 7));
    let coverage = Coverage::new(&td);
    let g = Arc::new(social_network(180, 1_200, 8));
    let cut = GraphCut::new(&g);
    let pk = Arc::new(parkinsons_like(120, 10, 5));
    let infogain = InfoGain::paper_params(&pk);
    let dpp = DppLogDet::new(&pk, 1.0, 0.5);

    let cases: [(&str, &dyn SubmodularFn, usize); 5] = [
        ("facility", &facility, 400),
        ("coverage", &coverage, 200),
        ("cut", &cut, 180),
        ("infogain", &infogain, 120),
        ("dpp", &dpp, 120),
    ];
    for (label, f, n) in cases {
        let ground: Vec<usize> = (0..n).collect();
        let con = Cardinality::new(12);
        let mut rng = Rng::new(0);
        let plain = Greedy.maximize(f, &ground, &con, &mut rng);
        for threads in THREAD_SWEEP {
            let lazy = LazyGreedy.maximize_threaded(f, &ground, &con, &mut rng, threads);
            assert_eq!(
                plain.solution, lazy.solution,
                "{label}: lazy({threads}t) diverged from plain greedy"
            );
            assert_eq!(plain.value, lazy.value, "{label}: value diverged");
        }
    }
}

#[test]
fn protocol_round_trip_greedy_vs_lazy_bit_identical() {
    // The acceptance check: swapping the black box between plain and
    // batch-repriced lazy greedy must not move a single element of any
    // protocol's output (they agree up to ties, and ties break identically).
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(350, 8), 9));
    let facility = FacilityProblem::new(&ds);
    let td = Arc::new(zipf_transactions(300, 260, 8, 1.1, 10));
    let coverage = CoverageProblem::new(&td);
    let problems: [&dyn Problem; 2] = [&facility, &coverage];
    for problem in problems {
        for name in ["greedi", "multiround", "centralized", "greedy_max"] {
            let spec = RunSpec::new(4, 8).seed(11);
            let with_greedy = protocol::by_name(name)
                .unwrap()
                .run(problem, &spec.clone().algorithm("greedy"));
            let with_lazy = protocol::by_name(name)
                .unwrap()
                .run(problem, &spec.algorithm("lazy"));
            assert_eq!(
                with_greedy.solution, with_lazy.solution,
                "{name}: lazy black box changed the solution"
            );
            assert_eq!(with_greedy.value, with_lazy.value, "{name}");
        }
    }
}

#[test]
fn protocol_results_reproduce_for_fixed_seeds() {
    // Post-refactor acceptance: the engine under every objective must not
    // perturb seed-fixed protocol round-trip values between repeated runs.
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(320, 8), 15));
    let p = FacilityProblem::new(&ds);
    for name in ["greedi", "multiround", "stream_greedi", "greedy_merge"] {
        let spec = RunSpec::new(4, 8).seed(21).threads(4);
        let a = protocol::by_name(name).unwrap().run(&p, &spec);
        let b = protocol::by_name(name).unwrap().run(&p, &spec);
        assert_eq!(a.solution, b.solution, "{name}: seed-fixed rerun moved the solution");
        assert_eq!(a.value, b.value, "{name}: seed-fixed rerun moved the value");
        assert_eq!(a.oracle_calls, b.oracle_calls, "{name}: oracle calls moved");
    }
}

#[test]
fn protocol_threads_only_change_wallclock_cut_problem() {
    // Non-monotone path: random_greedy black box on the cut objective, with
    // local evaluation — the stack the paper's §6.3 runs — at 1 vs 8
    // threads.
    let g = Arc::new(social_network(250, 1_800, 12));
    let p = CutProblem::new(&g);
    let base = RunSpec::new(5, 10).algorithm("random_greedy").local().seed(13);
    let serial = protocol::by_name("greedi").unwrap().run(&p, &base);
    let par = protocol::by_name("greedi")
        .unwrap()
        .run(&p, &base.clone().threads(8));
    assert_eq!(serial.solution, par.solution);
    assert_eq!(serial.value, par.value);
    assert_eq!(serial.oracle_calls, par.oracle_calls);
}
