//! Cross-layer guarantees of the window-sharded parallel gain engine
//! (perf pass §A, iteration 5):
//!
//! 1. `State::par_batch_gains` is **bit-identical** across thread counts on
//!    every objective that implements it (shard boundaries depend only on
//!    problem shape, and per-shard partials reduce in a fixed order);
//! 2. batch-repriced `LazyGreedy` selects **exactly** the plain-`Greedy`
//!    set, serial or parallel, standalone or inside a protocol round-trip;
//! 3. threading a full protocol (`RunSpec::threads`) is invisible in its
//!    results — only in its wallclock.

use std::sync::Arc;

use greedi::algorithms::{greedy::Greedy, lazy::LazyGreedy, Maximizer};
use greedi::constraints::cardinality::Cardinality;
use greedi::coordinator::protocol::{self, RunSpec};
use greedi::coordinator::{CoverageProblem, CutProblem, FacilityProblem, Problem};
use greedi::data::graph::social_network;
use greedi::data::synth::{gaussian_blobs, SynthConfig};
use greedi::data::transactions::zipf_transactions;
use greedi::objective::coverage::Coverage;
use greedi::objective::cut::GraphCut;
use greedi::objective::facility::FacilityLocation;
use greedi::objective::SubmodularFn;
use greedi::util::rng::Rng;

const THREAD_SWEEP: [usize; 4] = [1, 2, 4, 8];

#[test]
fn facility_gain_engine_thread_invariant() {
    // n = 1500 guarantees several window shards, so parallelism is real.
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(1500, 8), 3));
    let f = FacilityLocation::from_dataset(&ds);
    let mut st = f.state();
    st.push(42);
    st.push(901);
    let cands: Vec<usize> = (0..128).map(|i| (i * 11) % 1500).collect();
    let reference = st.batch_gains(&cands);
    for threads in THREAD_SWEEP {
        assert_eq!(
            reference,
            st.par_batch_gains(&cands, threads),
            "facility gains changed at {threads} threads"
        );
    }
}

#[test]
fn coverage_gain_engine_thread_invariant() {
    let td = Arc::new(zipf_transactions(500, 400, 9, 1.1, 4));
    let f = Coverage::new(&td);
    let mut st = f.state();
    st.push(17);
    let cands: Vec<usize> = (0..500).collect();
    let reference = st.batch_gains(&cands);
    for threads in THREAD_SWEEP {
        assert_eq!(
            reference,
            st.par_batch_gains(&cands, threads),
            "coverage gains changed at {threads} threads"
        );
    }
}

#[test]
fn cut_gain_engine_thread_invariant() {
    let g = Arc::new(social_network(300, 2_000, 5));
    let f = GraphCut::new(&g);
    let mut st = f.state();
    st.push(3);
    st.push(120);
    let cands: Vec<usize> = (0..300).collect();
    let reference = st.batch_gains(&cands);
    for threads in THREAD_SWEEP {
        assert_eq!(
            reference,
            st.par_batch_gains(&cands, threads),
            "cut gains changed at {threads} threads"
        );
    }
}

#[test]
fn batch_repriced_lazy_equals_plain_greedy_across_objectives_and_threads() {
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(400, 8), 6));
    let facility = FacilityLocation::from_dataset(&ds);
    let td = Arc::new(zipf_transactions(200, 250, 8, 1.1, 7));
    let coverage = Coverage::new(&td);
    let g = Arc::new(social_network(180, 1_200, 8));
    let cut = GraphCut::new(&g);

    let cases: [(&str, &dyn SubmodularFn, usize); 3] = [
        ("facility", &facility, 400),
        ("coverage", &coverage, 200),
        ("cut", &cut, 180),
    ];
    for (label, f, n) in cases {
        let ground: Vec<usize> = (0..n).collect();
        let con = Cardinality::new(12);
        let mut rng = Rng::new(0);
        let plain = Greedy.maximize(f, &ground, &con, &mut rng);
        for threads in THREAD_SWEEP {
            let lazy = LazyGreedy.maximize_threaded(f, &ground, &con, &mut rng, threads);
            assert_eq!(
                plain.solution, lazy.solution,
                "{label}: lazy({threads}t) diverged from plain greedy"
            );
            assert_eq!(plain.value, lazy.value, "{label}: value diverged");
        }
    }
}

#[test]
fn protocol_round_trip_greedy_vs_lazy_bit_identical() {
    // The acceptance check: swapping the black box between plain and
    // batch-repriced lazy greedy must not move a single element of any
    // protocol's output (they agree up to ties, and ties break identically).
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(350, 8), 9));
    let facility = FacilityProblem::new(&ds);
    let td = Arc::new(zipf_transactions(300, 260, 8, 1.1, 10));
    let coverage = CoverageProblem::new(&td);
    let problems: [&dyn Problem; 2] = [&facility, &coverage];
    for problem in problems {
        for name in ["greedi", "multiround", "centralized", "greedy_max"] {
            let spec = RunSpec::new(4, 8).seed(11);
            let with_greedy = protocol::by_name(name)
                .unwrap()
                .run(problem, &spec.clone().algorithm("greedy"));
            let with_lazy = protocol::by_name(name)
                .unwrap()
                .run(problem, &spec.algorithm("lazy"));
            assert_eq!(
                with_greedy.solution, with_lazy.solution,
                "{name}: lazy black box changed the solution"
            );
            assert_eq!(with_greedy.value, with_lazy.value, "{name}");
        }
    }
}

#[test]
fn protocol_threads_only_change_wallclock_cut_problem() {
    // Non-monotone path: random_greedy black box on the cut objective, with
    // local evaluation — the stack the paper's §6.3 runs — at 1 vs 8
    // threads.
    let g = Arc::new(social_network(250, 1_800, 12));
    let p = CutProblem::new(&g);
    let base = RunSpec::new(5, 10).algorithm("random_greedy").local().seed(13);
    let serial = protocol::by_name("greedi").unwrap().run(&p, &base);
    let par = protocol::by_name("greedi")
        .unwrap()
        .run(&p, &base.clone().threads(8));
    assert_eq!(serial.solution, par.solution);
    assert_eq!(serial.value, par.value);
    assert_eq!(serial.oracle_calls, par.oracle_calls);
}
