//! Integration: the persistent executor across whole protocol runs.
//!
//! These tests live in their own binary on purpose: nothing here creates a
//! local `Executor`, so `Executor::total_spawned_workers()` is exactly the
//! global pool's worker count once any test has touched it — which is what
//! lets the reuse tests assert "no workers leaked across runs" without
//! flaking against unrelated pools.

use std::sync::Arc;

use greedi::coordinator::protocol::{by_name, RunSpec, NAMES};
use greedi::coordinator::FacilityProblem;
use greedi::data::synth::{gaussian_blobs, SynthConfig};
use greedi::util::executor::{parallel_map, Executor};

fn problem(n: usize, seed: u64) -> (Arc<greedi::data::Dataset>, FacilityProblem) {
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 8), seed));
    let p = FacilityProblem::new(&ds);
    (ds, p)
}

#[test]
fn back_to_back_protocol_runs_reuse_one_pool() {
    let (_ds, p) = problem(160, 5);
    let spec = RunSpec::new(4, 6).threads(8).seed(3);
    // First run lazily initializes the global pool…
    let first = by_name("greedi").unwrap().run(&p, &spec);
    let workers = Executor::global().workers();
    let spawned = Executor::total_spawned_workers();
    assert!(workers >= 1);
    assert!(spawned >= workers, "global pool workers must be counted");
    // …and every subsequent run must reuse it: the process-wide spawn
    // counter stays flat (a per-run pool would re-spawn each time) and the
    // results are identical to the first run (reuse is invisible).
    for _ in 0..4 {
        let again = by_name("greedi").unwrap().run(&p, &spec);
        assert_eq!(again.solution, first.solution, "pool reuse changed the solution");
        assert_eq!(again.value, first.value);
        assert_eq!(again.oracle_calls, first.oracle_calls);
    }
    assert_eq!(
        Executor::total_spawned_workers(),
        spawned,
        "protocol runs must not spawn new workers"
    );
    assert_eq!(Executor::global().workers(), workers);
}

#[test]
fn protocol_sweep_bit_identical_under_thread_sweep() {
    // The full registry under threads ∈ {1, 2, 8}: the pool (and its
    // scheduling nondeterminism) must be invisible in every reported
    // metric. Within one process the facility kernel's dispatch path is
    // fixed, so this holds on the SIMD path exactly as on the scalar path
    // (CI additionally runs this binary under GREEDI_NO_SIMD=1).
    let (_ds, p) = problem(150, 7);
    for name in NAMES {
        let base = by_name(name).unwrap().run(&p, &RunSpec::new(4, 5).seed(11));
        for threads in [2usize, 8] {
            let par = by_name(name)
                .unwrap()
                .run(&p, &RunSpec::new(4, 5).seed(11).threads(threads));
            assert_eq!(base.solution, par.solution, "{name}@{threads}t: solution drifted");
            assert_eq!(base.value, par.value, "{name}@{threads}t: value drifted");
            assert_eq!(
                base.oracle_calls, par.oracle_calls,
                "{name}@{threads}t: oracle accounting drifted"
            );
        }
    }
}

#[test]
fn repeated_seeded_runs_are_identical() {
    // Seed-identical RunMetrics without pool re-creation between runs —
    // the in-process proxy for "matches a fresh-process run" (nothing in
    // the pool carries state from one run into the next).
    let (_ds, p) = problem(120, 9);
    for name in ["greedi", "multiround", "stream_greedi", "centralized"] {
        let spec = RunSpec::new(3, 5).threads(4).seed(21);
        let a = by_name(name).unwrap().run(&p, &spec);
        let b = by_name(name).unwrap().run(&p, &spec);
        assert_eq!(a.solution, b.solution, "{name}: run-to-run drift");
        assert_eq!(a.value, b.value, "{name}");
        assert_eq!(a.oracle_calls, b.oracle_calls, "{name}");
    }
}

#[test]
fn pool_survives_a_panicking_stage_and_keeps_serving_protocols() {
    let (_ds, p) = problem(100, 13);
    let spec = RunSpec::new(3, 4).threads(4).seed(2);
    let before = by_name("greedi").unwrap().run(&p, &spec);
    let spawned = Executor::total_spawned_workers();
    // A user task panicking through the pool…
    let err = std::panic::catch_unwind(|| {
        parallel_map((0..64).collect(), 8, |i, _x: i32| -> i32 {
            if i % 3 == 0 {
                panic!("injected fault {i}");
            }
            0
        })
    });
    assert!(err.is_err(), "panic must propagate to the caller");
    // …must not cost workers or poison later protocol runs.
    let after = by_name("greedi").unwrap().run(&p, &spec);
    assert_eq!(after.solution, before.solution);
    assert_eq!(after.value, before.value);
    assert_eq!(
        Executor::total_spawned_workers(),
        spawned,
        "panic recovery must reuse the same workers"
    );
}

#[test]
fn deep_nesting_under_load_completes() {
    // Protocol shape stress: outer map stage × nested oracle fan-out, many
    // times the pool's worker count, all multiplexed on one bounded pool.
    // Helping waiters make this deadlock-free by construction; this test
    // pins that property under real contention.
    let out = parallel_map((0..24).collect(), 8, |_, x: i64| {
        parallel_map((0..24).collect(), 8, |_, y: i64| x * 100 + y)
            .into_iter()
            .sum::<i64>()
    });
    let expect: Vec<i64> = (0..24)
        .map(|x| (0..24).map(|y| x * 100 + y).sum())
        .collect();
    assert_eq!(out, expect);
}
