//! Registry-completeness: the protocol registry, the CLI `protocols`
//! sweep, bench coverage and the serving layer must agree on the protocol
//! list, so the next protocol added to `protocol::NAMES` cannot silently
//! miss a surface (the way `stream_greedi` nearly missed the bench sweep).
//!
//! Surfaces that *iterate the registry* are checked structurally (their
//! source must loop over `protocol::NAMES`, not spell out a stale list);
//! runtime agreement is checked by driving `by_name` itself.

use greedi::coordinator::protocol;

#[test]
fn names_are_unique_and_roundtrip_through_by_name() {
    let mut seen = std::collections::BTreeSet::new();
    for name in protocol::NAMES {
        assert!(seen.insert(name), "duplicate registry entry {name:?}");
        let proto = protocol::by_name(name)
            .unwrap_or_else(|| panic!("NAMES entry {name:?} missing from by_name"));
        assert_eq!(proto.name(), name, "registry id must round-trip");
    }
    assert!(seen.contains("centralized"), "the reference baseline must stay registered");
    assert!(protocol::by_name("no_such_protocol").is_none());
}

#[test]
fn cli_protocols_sweep_iterates_the_registry() {
    let src = include_str!("../src/main.rs");
    assert!(
        src.contains("for name in protocol::NAMES"),
        "the `protocols` subcommand must sweep protocol::NAMES, not a hand-kept list"
    );
}

#[test]
fn bench_sweep_iterates_the_registry() {
    let src = include_str!("../benches/bench_protocols.rs");
    assert!(
        src.contains("for name in protocol::NAMES"),
        "bench_protocols must sweep protocol::NAMES so new protocols are benched for free"
    );
}

#[test]
fn serve_dispatch_is_registry_driven() {
    // the daemon resolves protocols through by_name and advertises the
    // registry on `ping` — no protocol list of its own to go stale
    let src = include_str!("../src/serve/server.rs");
    assert!(src.contains("protocol::by_name(&q.protocol)"), "serve must dispatch via by_name");
    assert!(src.contains("protocol::NAMES"), "ping must advertise the registry");
}

#[test]
fn config_accepts_every_registered_protocol() {
    use greedi::config::ExperimentConfig;
    for name in protocol::NAMES {
        let toml = format!("protocol = \"{name}\"");
        ExperimentConfig::from_toml(&toml)
            .unwrap_or_else(|e| panic!("config must accept registered protocol {name:?}: {e}"));
    }
    assert!(ExperimentConfig::from_toml("protocol = \"bogus\"").is_err());
}
