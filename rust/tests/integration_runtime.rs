//! Integration tests over the PJRT runtime: load real artifacts, execute,
//! and check numerics against the pure-rust reference paths.
//!
//! These tests require `make artifacts` to have run; they are skipped (not
//! failed) when the artifacts directory is absent so `cargo test` stays
//! usable in a fresh checkout.

use std::sync::Arc;

use greedi::data::synth::{gaussian_blobs, SynthConfig};
use greedi::objective::facility::{FacilityLocation, GainBackend};
use greedi::objective::SubmodularFn;
use greedi::runtime::{default_artifact_dir, Engine, XlaFacilityBackend};

fn engine() -> Option<Arc<Engine>> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    match Engine::load(&dir) {
        Ok(e) => Some(Arc::new(e)),
        Err(e) => {
            // stub engine (built without `--features xla`) or a broken
            // artifact set — skip rather than fail, as with missing artifacts
            eprintln!("skipping: engine unavailable ({e})");
            None
        }
    }
}

#[test]
fn manifest_loads_all_entries() {
    let Some(engine) = engine() else { return };
    assert!(engine.manifest.entries.len() >= 7);
    for e in &engine.manifest.entries {
        assert!(!e.inputs.is_empty(), "{}", e.name);
    }
}

#[test]
fn sqdist_artifact_matches_rust() {
    let Some(engine) = engine() else { return };
    let ds = gaussian_blobs(&SynthConfig::tiny_images(1024, 8), 5);
    // candidates = first 64 points, data = all 1024, d = 8 exactly
    let mut cbuf = vec![0.0f32; 64 * 8];
    for i in 0..64 {
        cbuf[i * 8..(i + 1) * 8].copy_from_slice(ds.row(i));
    }
    let out = engine
        .execute_f32("sqdist_b64_n1024_d8", &[&cbuf, &ds.xs])
        .unwrap();
    assert_eq!(out.len(), 64 * 1024);
    for i in 0..8 {
        for j in 0..32 {
            let want = ds.sqdist(i, j) as f32;
            let got = out[i * 1024 + j];
            assert!(
                (want - got).abs() < 1e-3 * (1.0 + want.abs()),
                "d2[{i},{j}]: {got} vs {want}"
            );
        }
    }
    // diagonal zero
    for i in 0..64 {
        assert!(out[i * 1024 + i].abs() < 1e-4);
    }
}

#[test]
fn rbf_artifact_range_and_diagonal() {
    let Some(engine) = engine() else { return };
    let ds = gaussian_blobs(&SynthConfig::tiny_images(256, 8), 6);
    let mut xbuf = vec![0.0f32; 64 * 8];
    for i in 0..64 {
        xbuf[i * 8..(i + 1) * 8].copy_from_slice(ds.row(i));
    }
    let mut ybuf = vec![0.0f32; 256 * 8];
    for j in 0..256 {
        ybuf[j * 8..(j + 1) * 8].copy_from_slice(ds.row(j));
    }
    let out = engine.execute_f32("rbf_m64_n256_d8", &[&xbuf, &ybuf]).unwrap();
    assert_eq!(out.len(), 64 * 256);
    for (idx, &v) in out.iter().enumerate() {
        assert!((0.0..=1.0 + 1e-5).contains(&v), "K[{idx}] = {v}");
    }
    // K(x, x) = 1 on the diagonal block
    for i in 0..64 {
        assert!((out[i * 256 + i] - 1.0).abs() < 1e-4);
    }
}

#[test]
fn facility_backend_matches_scalar_gains() {
    let Some(engine) = engine() else { return };
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(700, 6), 7)); // d=6 → pads to 8
    let window: Vec<usize> = ds.ids();
    let backend = XlaFacilityBackend::new(&engine, &ds, &window).unwrap();

    let scalar = FacilityLocation::from_dataset(&ds);
    let mut st = scalar.state();
    st.push(3);
    st.push(77);
    // reconstruct curmin exactly as the objective does
    let phantom: Vec<f64> = window
        .iter()
        .map(|&v| ds.row(v).iter().map(|&x| (x as f64) * (x as f64)).sum())
        .collect();
    let curmin: Vec<f32> = window
        .iter()
        .zip(&phantom)
        .map(|(&v, &ph)| {
            [3usize, 77]
                .iter()
                .map(|&e| ds.sqdist(e, v))
                .fold(ph, f64::min) as f32
        })
        .collect();

    let cands: Vec<usize> = vec![0, 10, 99, 200, 345, 650];
    let xla_sums = backend.batch_gain_sums(&cands, &curmin);
    for (i, &c) in cands.iter().enumerate() {
        let scalar_gain = st.gain(c); // mean
        let xla_gain = xla_sums[i] / window.len() as f64;
        assert!(
            (scalar_gain - xla_gain).abs() < 1e-4 * (1.0 + scalar_gain.abs()),
            "cand {c}: scalar {scalar_gain} vs xla {xla_gain}"
        );
    }
}

#[test]
fn facility_backend_greedy_end_to_end() {
    // Full greedy with the XLA oracle matches the scalar-oracle greedy.
    let Some(engine) = engine() else { return };
    use greedi::algorithms::{greedy::Greedy, Maximizer};
    use greedi::constraints::cardinality::Cardinality;
    use greedi::util::rng::Rng;

    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(600, 8), 8));
    let window = ds.ids();
    let backend: Arc<dyn GainBackend> =
        Arc::new(XlaFacilityBackend::new(&engine, &ds, &window).unwrap());

    let scalar_obj = FacilityLocation::from_dataset(&ds);
    let xla_obj = FacilityLocation::from_dataset(&ds).with_backend(backend);

    let ground = ds.ids();
    let c = Cardinality::new(8);
    let mut rng = Rng::new(1);
    let a = Greedy.maximize(&scalar_obj, &ground, &c, &mut rng);
    let b = Greedy.maximize(&xla_obj, &ground, &c, &mut rng);
    assert!(
        (a.value - b.value).abs() < 1e-4 * (1.0 + a.value.abs()),
        "scalar {} vs xla {}",
        a.value,
        b.value
    );
}

#[test]
fn execute_rejects_bad_shapes() {
    let Some(engine) = engine() else { return };
    let too_small = vec![0.0f32; 8];
    assert!(engine
        .execute_f32("sqdist_b64_n1024_d8", &[&too_small, &too_small])
        .is_err());
    assert!(engine.execute_f32("no_such_artifact", &[]).is_err());
}

#[test]
fn coverage_artifact_counts() {
    let Some(engine) = engine() else { return };
    // membership: candidate 0 covers universe items [0, 100); covered: [0, 50)
    let mut membership = vec![0.0f32; 64 * 2048];
    for u in 0..100 {
        membership[u] = 1.0;
    }
    let mut covered = vec![0.0f32; 2048];
    for c in covered.iter_mut().take(50) {
        *c = 1.0;
    }
    let out = engine
        .execute_f32("coverage_b64_u2048", &[&membership, &covered])
        .unwrap();
    assert_eq!(out.len(), 64);
    assert!((out[0] - 50.0).abs() < 1e-3);
    assert!(out[1].abs() < 1e-3);
}
