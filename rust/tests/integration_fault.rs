//! Integration tests for replicated-shard fault tolerance: multiplicity
//! partitioning + machine crashes + recovery policies, end to end through
//! the real protocols.
//!
//! The headline pin: with multiplicity c = 2, any single machine crash
//! recovered by `survivor_merge` yields the bit-identical solution (and
//! `value.to_bits()`) of the fault-free run — replication makes machine
//! loss invisible, which is the whole point of the subsystem.

//! PR 8 adds the failure-domain pins: under `distinct_domains` placement
//! with c ≥ 2, crashing any **whole domain** is as invisible as a single
//! machine crash was in PR 7, and `resume` recovery salvages checkpointed
//! partial progress without moving a single output bit.

use std::sync::Arc;

use greedi::coordinator::protocol::{
    self, FaultPlan, PlacementPolicy, Protocol, RecoveryPolicy, RunSpec,
};
use greedi::coordinator::FacilityProblem;
use greedi::data::synth::{gaussian_blobs, SynthConfig};

fn problem(n: usize, seed: u64) -> FacilityProblem {
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 8), seed));
    FacilityProblem::new(&ds)
}

#[test]
fn survivor_merge_recovers_any_single_crash_bit_identically() {
    let p = problem(300, 61);
    let (m, k) = (4usize, 8usize);
    let proto = protocol::by_name("greedi").unwrap();
    let clean_spec = RunSpec::new(m, k).multiplicity(2).seed(11).faults(FaultPlan::none());
    let clean = proto.run(&p, &clean_spec);
    for j in 0..m {
        let spec = clean_spec
            .clone()
            .recovery(RecoveryPolicy::SurvivorMerge)
            .faults(FaultPlan::none().crash_tasks(vec![j]));
        let r = proto.run(&p, &spec);
        assert_eq!(r.solution, clean.solution, "crash of machine {j} changed the solution");
        assert_eq!(
            r.value.to_bits(),
            clean.value.to_bits(),
            "crash of machine {j} changed the value"
        );
        let fs = r.fault.as_ref().expect("fault stats under an active plan");
        assert_eq!(fs.crashed_machines, vec![j]);
        assert_eq!(fs.dropped_elements, 0, "c=2 keeps every element alive somewhere");
        assert_eq!(fs.coverage(), 1.0);
        assert_eq!(fs.multiplicity, 2);
        assert_eq!(fs.policy, "survivor_merge");
        assert_eq!(
            r.job.stages.len(),
            clean.job.stages.len() + 1,
            "recovery adds exactly one stage"
        );
    }
}

#[test]
fn drop_shard_degrades_gracefully_and_reports_lost_coverage() {
    let p = problem(300, 62);
    let proto = protocol::by_name("greedi").unwrap();
    let base = RunSpec::new(4, 8).seed(13);
    let clean = proto.run(&p, &base);
    let r = proto.run(
        &p,
        &base
            .clone()
            .recovery(RecoveryPolicy::DropShard)
            .faults(FaultPlan::none().crash_tasks(vec![1])),
    );
    let fs = r.fault.as_ref().expect("fault stats");
    assert_eq!(fs.crashed_machines, vec![1]);
    assert!(fs.dropped_elements > 0, "c=1: a crashed shard is lost outright");
    assert!(fs.coverage() < 1.0, "coverage {}", fs.coverage());
    assert!(
        r.value <= clean.value + 1e-9,
        "survivors-only run cannot beat the fault-free one: {} vs {}",
        r.value,
        clean.value
    );
    assert!(r.solution.len() <= 8);
}

#[test]
fn retry_policy_is_thread_invariant_and_deterministic() {
    let p = problem(250, 63);
    let proto = protocol::by_name("greedi").unwrap();
    let plan = FaultPlan::new(0.4, 30, 17);
    let base = RunSpec::new(4, 8).seed(5).faults(plan.clone());
    let clean = proto.run(&p, &RunSpec::new(4, 8).seed(5).faults(FaultPlan::none()));
    let serial = proto.run(&p, &base.clone().threads(1));
    assert_eq!(serial.solution, clean.solution, "retries must not change the output");
    assert_eq!(serial.value.to_bits(), clean.value.to_bits());
    let retries = serial.fault.as_ref().expect("fault stats").retries;
    // Retries per task = the plan's leading streak of failed attempts; the
    // job runs 4 map tasks plus one merge task (task index 0 of its stage),
    // so the total is exactly computable from the coin.
    let streak = |t: usize| (0..30).take_while(|&a| plan.fails(t, a)).count();
    let expected: usize = (0..4).map(&streak).sum::<usize>() + streak(0);
    assert_eq!(retries, expected, "retry accounting must match the fault coin");
    for threads in [2usize, 8] {
        let par = proto.run(&p, &base.clone().threads(threads));
        assert_eq!(par.solution, serial.solution, "threads={threads}");
        assert_eq!(par.value.to_bits(), serial.value.to_bits(), "threads={threads}");
        assert_eq!(
            par.fault.as_ref().unwrap().retries,
            retries,
            "threads={threads}: retry accounting drifted"
        );
    }
    // same (seed, plan) twice => identical everything
    let again = proto.run(&p, &base.clone().threads(1));
    assert_eq!(again.solution, serial.solution);
    assert_eq!(again.fault.as_ref().unwrap().retries, retries);
}

#[test]
fn survivor_merge_holds_for_multiround_and_stream_protocols() {
    let p = problem(300, 64);
    for name in ["multiround", "stream_greedi"] {
        let proto = protocol::by_name(name).unwrap();
        let clean_spec = RunSpec::new(4, 8).multiplicity(2).seed(21).faults(FaultPlan::none());
        let clean = proto.run(&p, &clean_spec);
        let r = proto.run(
            &p,
            &clean_spec
                .clone()
                .recovery(RecoveryPolicy::SurvivorMerge)
                .faults(FaultPlan::none().crash_tasks(vec![0])),
        );
        assert_eq!(r.solution, clean.solution, "{name}: crash changed the solution");
        assert_eq!(r.value.to_bits(), clean.value.to_bits(), "{name}");
        let fs = r.fault.as_ref().expect("fault stats");
        assert_eq!(fs.crashed_machines, vec![0], "{name}");
        assert_eq!(fs.dropped_elements, 0, "{name}");
    }
}

#[test]
fn crashes_are_deterministic_from_seed_and_plan() {
    let p = problem(250, 65);
    let proto = protocol::by_name("greedi").unwrap();
    let spec = RunSpec::new(6, 8)
        .multiplicity(2)
        .seed(31)
        .recovery(RecoveryPolicy::DropShard)
        .faults(FaultPlan::new(0.0, 1, 99).crashes(0.5));
    let a = proto.run(&p, &spec);
    let b = proto.run(&p, &spec.clone());
    let (fa, fb) = (a.fault.as_ref().unwrap(), b.fault.as_ref().unwrap());
    assert_eq!(fa.crashed_machines, fb.crashed_machines);
    assert_eq!(fa.dropped_elements, fb.dropped_elements);
    assert_eq!(a.solution, b.solution);
    assert_eq!(a.value.to_bits(), b.value.to_bits());
}

#[test]
fn distinct_domains_placement_survives_whole_domain_crashes() {
    let p = problem(300, 67);
    let (m, d) = (4usize, 2usize);
    for name in ["greedi", "multiround", "stream_greedi"] {
        let proto = protocol::by_name(name).unwrap();
        // The fault-free reference carries the same domain map (inactive
        // plan), so the placement-aware partition is identical — and no
        // FaultStats attach to it.
        let clean_spec = RunSpec::new(m, 8)
            .multiplicity(2)
            .placement(PlacementPolicy::DistinctDomains)
            .algorithm("greedy")
            .seed(23)
            .faults(FaultPlan::none().domain_groups(d));
        let clean = proto.run(&p, &clean_spec);
        assert!(clean.fault.is_none(), "{name}: inactive plan must not attach stats");
        for dom in 0..d {
            for policy in [RecoveryPolicy::SurvivorMerge, RecoveryPolicy::Resume] {
                let plan = FaultPlan::none().domain_groups(d).crash_domains(vec![dom]);
                let spec = clean_spec
                    .clone()
                    .recovery(policy)
                    .checkpoint_every(2)
                    .faults(plan.clone());
                let r = proto.run(&p, &spec);
                assert_eq!(
                    r.solution, clean.solution,
                    "{name}/{}: crash of domain {dom} changed the solution",
                    policy.label()
                );
                assert_eq!(r.value.to_bits(), clean.value.to_bits(), "{name} domain {dom}");
                let fs = r.fault.as_ref().expect("fault stats under an active plan");
                let rack: Vec<usize> =
                    (0..m).filter(|&j| plan.domains.domain_of(j) == dom).collect();
                assert_eq!(fs.crashed_machines, rack, "{name}: domain crash takes the whole rack");
                assert_eq!(fs.dropped_elements, 0, "{name}: a replica survives in the other rack");
                assert_eq!(fs.coverage(), 1.0, "{name}");
                assert_eq!(fs.policy, policy.label(), "{name}");
            }
        }
    }
}

#[test]
fn resume_salvages_checkpointed_progress_without_changing_bits() {
    let p = problem(300, 68);
    let proto = protocol::by_name("greedi").unwrap();
    let clean_spec = RunSpec::new(4, 10)
        .multiplicity(2)
        .placement(PlacementPolicy::DistinctDomains)
        .algorithm("greedy")
        .seed(29)
        .faults(FaultPlan::none().domain_groups(2));
    let clean = proto.run(&p, &clean_spec);
    let crash = FaultPlan::none()
        .domain_groups(2)
        .crash_tasks(vec![2])
        .crash_progress(0.8);
    let resumed = proto.run(
        &p,
        &clean_spec
            .clone()
            .recovery(RecoveryPolicy::Resume)
            .checkpoint_every(2)
            .faults(crash.clone()),
    );
    assert_eq!(resumed.solution, clean.solution, "resume must not change the solution");
    assert_eq!(resumed.value.to_bits(), clean.value.to_bits());
    let fs = resumed.fault.as_ref().expect("fault stats");
    assert_eq!(fs.policy, "resume");
    assert!(fs.salvaged_units > 0, "the checkpointed prefix must be salvaged");
    assert!(
        fs.replayed_units < fs.salvaged_units + fs.replayed_units,
        "resume must replay strictly less than a from-scratch rebuild"
    );
    assert_eq!(fs.coverage(), 1.0);
    // checkpoint_every = 0: resume degrades to a full recompute — still
    // bit-identical, nothing salvaged.
    let cold = proto.run(&p, &clean_spec.clone().recovery(RecoveryPolicy::Resume).faults(crash));
    assert_eq!(cold.solution, clean.solution);
    assert_eq!(cold.value.to_bits(), clean.value.to_bits());
    let cold_fs = cold.fault.as_ref().unwrap();
    assert_eq!(cold_fs.salvaged_units, 0, "no checkpoints => nothing to salvage");
}

#[test]
fn anywhere_placement_ignores_the_domain_map_bit_for_bit() {
    // Acceptance pin: the defaults (anywhere placement, checkpoints off)
    // reproduce the pre-domain runs exactly, even when the plan carries a
    // rack map — split_placed must delegate on the same RNG stream.
    let p = problem(300, 69);
    for name in ["greedi", "multiround", "stream_greedi"] {
        let proto = protocol::by_name(name).unwrap();
        let legacy = RunSpec::new(4, 8).multiplicity(2).seed(33).faults(FaultPlan::none());
        let base = proto.run(&p, &legacy);
        let domained =
            proto.run(&p, &legacy.clone().faults(FaultPlan::none().domain_groups(3)));
        assert_eq!(domained.solution, base.solution, "{name}: rack map moved a replica");
        assert_eq!(domained.value.to_bits(), base.value.to_bits(), "{name}");
    }
}

#[test]
fn losing_every_replica_degrades_to_drop_shard_semantics() {
    // c = 2 but three of four machines die: some elements lose both
    // replicas, so even rebuild policies cannot restore full coverage —
    // they degrade to drop_shard semantics on whatever survived.
    let p = problem(300, 70);
    let proto = protocol::by_name("greedi").unwrap();
    let clean_spec = RunSpec::new(4, 8).multiplicity(2).seed(37).faults(FaultPlan::none());
    let clean = proto.run(&p, &clean_spec);
    for policy in [RecoveryPolicy::SurvivorMerge, RecoveryPolicy::Resume] {
        let spec = clean_spec
            .clone()
            .recovery(policy)
            .checkpoint_every(2)
            .faults(FaultPlan::none().crash_tasks(vec![0, 1, 2]));
        let r = proto.run(&p, &spec);
        let fs = r.fault.as_ref().expect("fault stats");
        assert!(
            fs.dropped_elements > 0,
            "{}: losing every replica of an element must drop it",
            policy.label()
        );
        assert!(fs.coverage() < 1.0, "{}: coverage {}", policy.label(), fs.coverage());
        assert!(
            r.value <= clean.value + 1e-9,
            "{}: a partial-coverage run cannot beat the fault-free one",
            policy.label()
        );
        // incomplete rebuilds are never salvaged: resume falls back to a
        // full recompute of the partial shard
        assert_eq!(fs.salvaged_units, 0, "{}", policy.label());
        assert!(r.solution.len() <= 8);
    }
}

#[test]
fn stragglers_slow_the_stage_without_changing_results() {
    let p = problem(300, 66);
    let proto = protocol::by_name("greedi").unwrap();
    let clean = proto.run(&p, &RunSpec::new(4, 8).seed(9).faults(FaultPlan::none()));
    let r = proto.run(
        &p,
        &RunSpec::new(4, 8)
            .seed(9)
            .faults(FaultPlan::new(0.0, 1, 7).stragglers(1.0, 1_000.0)),
    );
    assert_eq!(r.solution, clean.solution, "stragglers must not touch outputs");
    assert_eq!(r.value.to_bits(), clean.value.to_bits());
    let fs = r.fault.as_ref().expect("fault stats");
    assert_eq!(fs.straggled_machines, vec![0, 1, 2, 3], "p=1.0 straggles every machine");
    assert!(fs.crashed_machines.is_empty());
    assert!(
        r.job.stages[0].max_task_time > clean.job.stages[0].max_task_time * 10.0,
        "×1000 straggle factor must dominate timing noise: {} vs {}",
        r.job.stages[0].max_task_time,
        clean.job.stages[0].max_task_time
    );
}
