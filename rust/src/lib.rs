//! # GreeDi — Distributed Submodular Maximization
//!
//! A production-grade reproduction of *"Distributed Submodular Maximization"*
//! (Mirzasoleiman, Karbasi, Sarkar, Krause — JMLR/arXiv 2014). The paper's
//! two-round MapReduce protocol **GreeDi** is implemented as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the distributed coordinator: a simulated
//!   MapReduce runtime, the GreeDi protocol (Algorithms 2 & 3), naive
//!   baselines, the GreedyScaling comparator, objective/constraint/algorithm
//!   libraries, and the experiment harnesses that regenerate every figure in
//!   the paper's evaluation section.
//! * **Layer 2 (python/compile/model.py)** — JAX compute graphs for the
//!   objective-function hot spots (pairwise distances, RBF kernel matrices,
//!   batched facility-location marginal gains), AOT-lowered to HLO text.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels implementing the
//!   hot loops, lowered inside the L2 graphs (interpret mode for CPU PJRT).
//!
//! Python never runs at coordination time: `make artifacts` produces
//! `artifacts/*.hlo.txt`, which [`runtime`] loads through the PJRT C API
//! (build with `--features xla`).
//!
//! ## Quickstart
//!
//! Every distributed coordinator — GreeDi, the tree-reduction variant, the
//! four naive baselines, GreedyScaling, the bounded-memory streaming
//! sieve→merge protocol (`"stream_greedi"`, see [`stream`]), and the
//! centralized reference — sits
//! behind one trait ([`coordinator::protocol::Protocol`]), one spec
//! ([`coordinator::protocol::RunSpec`]), and one registry
//! (`coordinator::protocol::by_name`), mirroring `algorithms::by_name`:
//!
//! ```no_run
//! use std::sync::Arc;
//! use greedi::coordinator::protocol::{self, Protocol, RunSpec};
//! use greedi::coordinator::FacilityProblem;
//! use greedi::data::synth::{gaussian_blobs, SynthConfig};
//!
//! // 10k points in 16-d, 50 exemplars, 10 machines, 4 worker threads.
//! let data = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(10_000, 16), 42));
//! let problem = FacilityProblem::new(&data);
//! let spec = RunSpec::new(10, 50).threads(4).seed(7);
//!
//! // One spec drives any protocol in the registry, apples-to-apples.
//! let central = protocol::by_name("centralized").unwrap().run(&problem, &spec);
//! for name in ["greedi", "multiround", "greedy_max"] {
//!     let run = protocol::by_name(name).unwrap().run(&problem, &spec);
//!     println!("{name}: f(S) = {}, ratio = {:.4}", run.value, run.ratio_vs(central.value));
//! }
//! ```
//!
//! For an always-on deployment — one resident process, warm caches,
//! concurrent queries over TCP with admission control and a latency
//! metrics surface — see [`serve`] and the `greedi serve` / `greedi query`
//! subcommands.
pub mod algorithms;
pub mod config;
pub mod constraints;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod linalg;
pub mod mapreduce;
pub mod objective;
pub mod runtime;
pub mod serve;
pub mod stream;
pub mod util;

pub mod prelude {
    //! Convenience re-exports covering the common public API surface.
    pub use crate::algorithms::{
        greedy::Greedy, lazy::LazyGreedy, random_greedy::RandomGreedy,
        stochastic::StochasticGreedy, Maximizer,
    };
    pub use crate::config::ExperimentConfig;
    pub use crate::constraints::{
        cardinality::Cardinality, knapsack::Knapsack, matroid::PartitionMatroid, Constraint,
    };
    pub use crate::coordinator::{
        baselines::Baseline,
        greedi::{centralized, Greedi},
        greedy_scaling::GreedyScaling,
        metrics::RunMetrics,
        multiround::MultiRoundGreedi,
        protocol::{Protocol, RunSpec},
        CoverageProblem, CutProblem, FacilityProblem, InfoGainProblem, Problem,
    };
    pub use crate::data::{synth, synth::SynthConfig, Dataset};
    pub use crate::mapreduce::partition::PartitionStrategy;
    pub use crate::objective::{
        coverage::Coverage, cut::GraphCut, facility::FacilityLocation, infogain::InfoGain,
        SubmodularFn,
    };
    pub use crate::serve::{Client, ServeSpec, Server, WarmState};
    pub use crate::stream::{
        candidate_bound, sieve_stream, BatchedSieve, ChunkedCsvSource, SieveResult,
        StreamGreedi, StreamSource, VecSource,
    };
    pub use crate::util::rng::Rng;
}
