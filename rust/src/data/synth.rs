//! Synthetic point-cloud generators standing in for the paper's corpora.
//!
//! * [`gaussian_blobs`] — cluster-structured data (Tiny-Images surrogate):
//!   exemplar clustering only observes pairwise distances, so a Gaussian
//!   mixture with well-populated clusters exercises the identical code path
//!   and satisfies the dense-neighborhood condition of Theorem 8.
//! * [`parkinsons_like`] — 22-d correlated Gaussian rows, zero-mean and
//!   row-normalized like the paper's preprocessing (§6.2).
//! * [`yahoo_like`] — 6-d non-negative user-feature vectors (§6.2, Fig 7).

use super::Dataset;
use crate::util::rng::Rng;

/// Configuration for the Gaussian-mixture generator.
#[derive(Debug, Clone)]
pub struct SynthConfig {
    pub n: usize,
    pub d: usize,
    pub clusters: usize,
    /// Std of cluster centers around the origin.
    pub center_spread: f64,
    /// Std of points around their cluster center.
    pub cluster_std: f64,
    /// Apply mean-subtraction + row normalization (paper §6.1 pipeline).
    pub preprocess: bool,
}

impl SynthConfig {
    /// Tiny-Images-like preset: clustered, centered, unit-norm rows.
    pub fn tiny_images(n: usize, d: usize) -> Self {
        SynthConfig {
            n,
            d,
            clusters: 10,
            center_spread: 3.0,
            cluster_std: 1.0,
            preprocess: true,
        }
    }

    /// Uniform cloud with no cluster structure (worst-case-ish inputs).
    pub fn unstructured(n: usize, d: usize) -> Self {
        SynthConfig {
            n,
            d,
            clusters: 1,
            center_spread: 0.0,
            cluster_std: 1.0,
            preprocess: false,
        }
    }
}

/// Gaussian mixture with `clusters` components.
pub fn gaussian_blobs(cfg: &SynthConfig, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let mut centers = vec![0.0f64; cfg.clusters * cfg.d];
    for c in centers.iter_mut() {
        *c = rng.normal_ms(0.0, cfg.center_spread);
    }
    let mut ds = Dataset::zeros(cfg.n, cfg.d);
    for i in 0..cfg.n {
        let c = rng.below(cfg.clusters);
        for t in 0..cfg.d {
            let mu = centers[c * cfg.d + t];
            ds.xs[i * cfg.d + t] = rng.normal_ms(mu, cfg.cluster_std) as f32;
        }
    }
    if cfg.preprocess {
        ds.center();
        ds.normalize_rows();
    }
    ds
}

/// Parkinsons-Telemonitoring-like data: `n` rows of `d` correlated
/// Gaussian features (a few latent factors), zero-mean, unit-norm — the
/// paper's exact preprocessing for the GP active-set experiment.
pub fn parkinsons_like(n: usize, d: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed);
    let factors = 4.min(d);
    // random loading matrix L (d x factors)
    let mut loading = vec![0.0f64; d * factors];
    for l in loading.iter_mut() {
        *l = rng.normal();
    }
    let mut ds = Dataset::zeros(n, d);
    for i in 0..n {
        let z: Vec<f64> = (0..factors).map(|_| rng.normal()).collect();
        for t in 0..d {
            let mut v = 0.25 * rng.normal(); // idiosyncratic noise
            for (f, zf) in z.iter().enumerate() {
                v += loading[t * factors + f] * zf;
            }
            ds.xs[i * d + t] = v as f32;
        }
    }
    ds.center();
    ds.normalize_rows();
    ds
}

/// Yahoo!-Front-Page-like user features: 6-d, non-negative, normalized
/// (the released dataset's features are simplex-like).
pub fn yahoo_like(n: usize, seed: u64) -> Dataset {
    let d = 6;
    let mut rng = Rng::new(seed);
    let mut ds = Dataset::zeros(n, d);
    for i in 0..n {
        let mut row = [0.0f64; 6];
        let mut sum = 0.0;
        for r in row.iter_mut() {
            // mixture of sparse near-zero mass and a few active features
            *r = if rng.bool(0.4) { rng.f64() } else { 0.02 * rng.f64() };
            sum += *r;
        }
        for (t, r) in row.iter().enumerate() {
            ds.xs[i * d + t] = (r / sum.max(1e-9)) as f32;
        }
    }
    ds
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blobs_shape_and_determinism() {
        let cfg = SynthConfig::tiny_images(500, 16);
        let a = gaussian_blobs(&cfg, 7);
        let b = gaussian_blobs(&cfg, 7);
        assert_eq!(a.n, 500);
        assert_eq!(a.d, 16);
        assert_eq!(a.xs, b.xs);
        let c = gaussian_blobs(&cfg, 8);
        assert_ne!(a.xs, c.xs);
    }

    #[test]
    fn blobs_preprocessed_unit_norm() {
        let ds = gaussian_blobs(&SynthConfig::tiny_images(200, 8), 1);
        for i in 0..ds.n {
            let norm: f64 = ds.row(i).iter().map(|&x| (x as f64).powi(2)).sum();
            assert!((norm - 1.0).abs() < 1e-4 || norm < 1e-8, "row {i}: {norm}");
        }
    }

    #[test]
    fn blobs_have_cluster_structure() {
        // With 10 tight clusters, the mean nearest-neighbor distance must be
        // far below the mean pairwise distance.
        let cfg = SynthConfig {
            n: 300,
            d: 8,
            clusters: 5,
            center_spread: 10.0,
            cluster_std: 0.5,
            preprocess: false,
        };
        let ds = gaussian_blobs(&cfg, 3);
        let mut nn = 0.0;
        let mut all = 0.0;
        let mut cnt = 0.0;
        for i in 0..100 {
            let mut best = f64::INFINITY;
            for j in 0..ds.n {
                if i == j {
                    continue;
                }
                let d2 = ds.sqdist(i, j);
                best = best.min(d2);
                all += d2;
                cnt += 1.0;
            }
            nn += best;
        }
        assert!(nn / 100.0 < 0.2 * (all / cnt));
    }

    #[test]
    fn parkinsons_like_preprocessed() {
        let ds = parkinsons_like(100, 22, 5);
        assert_eq!(ds.d, 22);
        // rows unit-norm
        let norm: f64 = ds.row(0).iter().map(|&x| (x as f64).powi(2)).sum();
        assert!((norm - 1.0).abs() < 1e-4);
    }

    #[test]
    fn yahoo_like_nonneg_normalized() {
        let ds = yahoo_like(100, 2);
        assert_eq!(ds.d, 6);
        for i in 0..ds.n {
            let sum: f32 = ds.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-4);
            assert!(ds.row(i).iter().all(|&x| x >= 0.0));
        }
    }
}
