//! Datasets: dense point sets, graphs and transaction (set-system) data,
//! plus the synthetic generators that stand in for the paper's corpora
//! (Tiny Images, Parkinsons Telemonitoring, Yahoo! Front Page, the UCI
//! social network, Accidents and Kosarak — see DESIGN.md §3 for the
//! substitution rationale).

pub mod graph;
pub mod loader;
pub mod synth;
pub mod transactions;

/// Dense row-major point set: `n` points in `d` dimensions, f32 (matching
/// the artifact dtype so shard blocks upload without conversion).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub n: usize,
    pub d: usize,
    pub xs: Vec<f32>, // row-major n*d
}

impl Dataset {
    pub fn zeros(n: usize, d: usize) -> Self {
        Dataset { n, d, xs: vec![0.0; n * d] }
    }

    pub fn from_rows(rows: Vec<Vec<f32>>) -> Self {
        let n = rows.len();
        let d = rows.first().map(|r| r.len()).unwrap_or(0);
        let mut xs = Vec::with_capacity(n * d);
        for r in &rows {
            assert_eq!(r.len(), d, "ragged rows");
            xs.extend_from_slice(r);
        }
        Dataset { n, d, xs }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.xs[i * self.d..(i + 1) * self.d]
    }

    /// All element ids `0..n` (the ground set `V`).
    pub fn ids(&self) -> Vec<usize> {
        (0..self.n).collect()
    }

    /// Squared Euclidean distance between points `i` and `j`.
    #[inline]
    pub fn sqdist(&self, i: usize, j: usize) -> f64 {
        let (a, b) = (self.row(i), self.row(j));
        let mut s = 0.0f64;
        for t in 0..self.d {
            let diff = (a[t] - b[t]) as f64;
            s += diff * diff;
        }
        s
    }

    /// Squared distance from point `i` to an arbitrary vector.
    #[inline]
    pub fn sqdist_to(&self, i: usize, v: &[f32]) -> f64 {
        let a = self.row(i);
        let mut s = 0.0f64;
        for t in 0..self.d {
            let diff = (a[t] - v[t]) as f64;
            s += diff * diff;
        }
        s
    }

    /// Subtract the dataset mean from every row (paper §6.1 preprocessing).
    pub fn center(&mut self) {
        let mut mean = vec![0.0f64; self.d];
        for i in 0..self.n {
            for (t, m) in mean.iter_mut().enumerate() {
                *m += self.row(i)[t] as f64;
            }
        }
        for m in &mut mean {
            *m /= self.n.max(1) as f64;
        }
        for i in 0..self.n {
            for t in 0..self.d {
                self.xs[i * self.d + t] -= mean[t] as f32;
            }
        }
    }

    /// L2-normalize every row (paper §6.1/§6.2 preprocessing). Zero rows
    /// are left untouched.
    pub fn normalize_rows(&mut self) {
        for i in 0..self.n {
            let norm: f64 = self.row(i).iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
            if norm > 1e-12 {
                for t in 0..self.d {
                    self.xs[i * self.d + t] /= norm as f32;
                }
            }
        }
    }

    /// Maximum squared distance between any point and the origin — used to
    /// validate the phantom-exemplar condition (paper §3.4.2).
    pub fn max_sqnorm(&self) -> f64 {
        (0..self.n)
            .map(|i| self.row(i).iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>())
            .fold(0.0, f64::max)
    }

    /// Restrict to a subset of rows (used to materialize shards).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        let mut xs = Vec::with_capacity(idx.len() * self.d);
        for &i in idx {
            xs.extend_from_slice(self.row(i));
        }
        Dataset { n: idx.len(), d: self.d, xs }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Dataset {
        Dataset::from_rows(vec![
            vec![0.0, 0.0],
            vec![3.0, 4.0],
            vec![1.0, 1.0],
        ])
    }

    #[test]
    fn row_access_and_sqdist() {
        let ds = small();
        assert_eq!(ds.row(1), &[3.0, 4.0]);
        assert!((ds.sqdist(0, 1) - 25.0).abs() < 1e-9);
        assert!((ds.sqdist(1, 1)).abs() < 1e-12);
    }

    #[test]
    fn sqdist_symmetry() {
        let ds = small();
        assert_eq!(ds.sqdist(0, 2), ds.sqdist(2, 0));
    }

    #[test]
    fn center_zeroes_mean() {
        let mut ds = small();
        ds.center();
        for t in 0..ds.d {
            let mean: f32 = (0..ds.n).map(|i| ds.row(i)[t]).sum::<f32>() / ds.n as f32;
            assert!(mean.abs() < 1e-6);
        }
    }

    #[test]
    fn normalize_rows_unit_norm() {
        let mut ds = small();
        ds.normalize_rows();
        // row 0 is zero and stays zero
        assert_eq!(ds.row(0), &[0.0, 0.0]);
        let norm: f32 = ds.row(1).iter().map(|x| x * x).sum::<f32>();
        assert!((norm - 1.0).abs() < 1e-6);
    }

    #[test]
    fn subset_preserves_rows() {
        let ds = small();
        let sub = ds.subset(&[2, 0]);
        assert_eq!(sub.n, 2);
        assert_eq!(sub.row(0), ds.row(2));
        assert_eq!(sub.row(1), ds.row(0));
    }

    #[test]
    #[should_panic]
    fn ragged_rows_rejected() {
        Dataset::from_rows(vec![vec![1.0], vec![1.0, 2.0]]);
    }
}
