//! Dataset persistence: CSV read/write for point sets so synthetic corpora
//! can be cached across runs (and real data dropped in without code
//! changes — the paper's workflows all start from on-disk feature files).
//!
//! Format: plain headerless CSV, one row per point, f32 values. Loading
//! validates rectangularity and finiteness.

use std::io::{BufRead, BufWriter, Write};
use std::path::Path;

use crate::util::error::{bail, Context, Result};

use super::Dataset;

/// Write a dataset as headerless CSV.
pub fn save_csv(ds: &Dataset, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path).with_context(|| format!("create {path:?}"))?;
    let mut w = BufWriter::new(file);
    for i in 0..ds.n {
        let row = ds.row(i);
        for (t, v) in row.iter().enumerate() {
            if t > 0 {
                w.write_all(b",")?;
            }
            write!(w, "{v}")?;
        }
        w.write_all(b"\n")?;
    }
    w.flush()?;
    Ok(())
}

/// Load a headerless CSV of f32 rows.
pub fn load_csv(path: &Path) -> Result<Dataset> {
    let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
    let reader = std::io::BufReader::new(file);
    let mut xs: Vec<f32> = Vec::new();
    let mut d = 0usize;
    let mut n = 0usize;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let mut count = 0usize;
        for tok in trimmed.split(',') {
            let v: f32 = tok
                .trim()
                .parse()
                .with_context(|| format!("{path:?}:{}: bad value {tok:?}", lineno + 1))?;
            if !v.is_finite() {
                bail!("{path:?}:{}: non-finite value", lineno + 1);
            }
            xs.push(v);
            count += 1;
        }
        if n == 0 {
            d = count;
        } else if count != d {
            bail!(
                "{path:?}:{}: ragged row ({count} cols, expected {d})",
                lineno + 1
            );
        }
        n += 1;
    }
    if n == 0 {
        bail!("{path:?}: empty dataset");
    }
    Ok(Dataset { n, d, xs })
}

/// Incremental bounded-memory reader for the same headerless-CSV format as
/// [`load_csv`]: rows are pulled `max_rows` at a time, so arbitrarily large
/// files stream through a fixed-size buffer. This is the ingestion path of
/// the `stream::` subsystem ([`crate::stream::source::ChunkedCsvSource`]).
///
/// Validation matches [`load_csv`] (finite values, rectangular rows, blank
/// lines skipped), applied chunk by chunk.
pub struct ChunkedCsvReader {
    lines: std::io::Lines<std::io::BufReader<std::fs::File>>,
    path: std::path::PathBuf,
    /// Columns per row; fixed by the first non-empty row.
    d: Option<usize>,
    rows_read: usize,
    lineno: usize,
}

impl ChunkedCsvReader {
    pub fn open(path: &Path) -> Result<Self> {
        let file = std::fs::File::open(path).with_context(|| format!("open {path:?}"))?;
        Ok(ChunkedCsvReader {
            lines: std::io::BufReader::new(file).lines(),
            path: path.to_path_buf(),
            d: None,
            rows_read: 0,
            lineno: 0,
        })
    }

    /// Row width, once the first row has been read.
    pub fn d(&self) -> Option<usize> {
        self.d
    }

    /// Rows successfully parsed so far.
    pub fn rows_read(&self) -> usize {
        self.rows_read
    }

    /// Parse up to `max_rows` further rows. The returned chunk has
    /// `chunk.n == 0` exactly at end of file.
    pub fn next_chunk(&mut self, max_rows: usize) -> Result<Dataset> {
        let path = &self.path;
        let mut xs: Vec<f32> = Vec::new();
        let mut n = 0usize;
        while n < max_rows.max(1) {
            let Some(line) = self.lines.next() else { break };
            self.lineno += 1;
            let line = line?;
            let trimmed = line.trim();
            if trimmed.is_empty() {
                continue;
            }
            let mut count = 0usize;
            for tok in trimmed.split(',') {
                let v: f32 = tok
                    .trim()
                    .parse()
                    .with_context(|| format!("{path:?}:{}: bad value {tok:?}", self.lineno))?;
                if !v.is_finite() {
                    bail!("{path:?}:{}: non-finite value", self.lineno);
                }
                xs.push(v);
                count += 1;
            }
            match self.d {
                None => self.d = Some(count),
                Some(d) if count != d => {
                    bail!("{path:?}:{}: ragged row ({count} cols, expected {d})", self.lineno)
                }
                Some(_) => {}
            }
            n += 1;
        }
        self.rows_read += n;
        Ok(Dataset { n, d: self.d.unwrap_or(0), xs })
    }
}

/// Load from cache if present, else generate and cache. The workhorse for
/// `--full`-scale experiment reruns.
pub fn load_or_generate(path: &Path, generate: impl FnOnce() -> Dataset) -> Result<Dataset> {
    if path.exists() {
        return load_csv(path);
    }
    let ds = generate();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    save_csv(&ds, path)?;
    Ok(ds)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_blobs, SynthConfig};

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("greedi_loader_{name}.csv"))
    }

    #[test]
    fn roundtrip_exact() {
        let ds = gaussian_blobs(&SynthConfig::tiny_images(50, 6), 3);
        let p = tmp("roundtrip");
        save_csv(&ds, &p).unwrap();
        let back = load_csv(&p).unwrap();
        assert_eq!(back.n, ds.n);
        assert_eq!(back.d, ds.d);
        // f32 → decimal → f32 is exact for shortest-roundtrip formatting
        assert_eq!(back.xs, ds.xs);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn ragged_rejected() {
        let p = tmp("ragged");
        std::fs::write(&p, "1,2,3\n4,5\n").unwrap();
        assert!(load_csv(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn bad_value_rejected() {
        let p = tmp("bad");
        std::fs::write(&p, "1,2\n3,abc\n").unwrap();
        assert!(load_csv(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn empty_rejected() {
        let p = tmp("empty");
        std::fs::write(&p, "\n\n").unwrap();
        assert!(load_csv(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn chunked_reader_matches_bulk_load() {
        let ds = gaussian_blobs(&SynthConfig::tiny_images(53, 5), 9);
        let p = tmp("chunked");
        save_csv(&ds, &p).unwrap();
        let bulk = load_csv(&p).unwrap();
        for chunk_rows in [1usize, 7, 53, 200] {
            let mut r = ChunkedCsvReader::open(&p).unwrap();
            let mut xs: Vec<f32> = Vec::new();
            let mut n = 0usize;
            loop {
                let c = r.next_chunk(chunk_rows).unwrap();
                if c.n == 0 {
                    break;
                }
                assert!(c.n <= chunk_rows, "chunk over-filled");
                assert_eq!(c.d, bulk.d);
                xs.extend_from_slice(&c.xs);
                n += c.n;
            }
            assert_eq!(n, bulk.n, "chunk_rows={chunk_rows}");
            assert_eq!(xs, bulk.xs, "chunk_rows={chunk_rows}");
            assert_eq!(r.rows_read(), bulk.n);
            assert_eq!(r.d(), Some(bulk.d));
            // EOF is sticky
            assert_eq!(r.next_chunk(chunk_rows).unwrap().n, 0);
        }
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn chunked_reader_rejects_ragged_mid_stream() {
        let p = tmp("chunked_ragged");
        std::fs::write(&p, "1,2\n3,4\n5\n").unwrap();
        let mut r = ChunkedCsvReader::open(&p).unwrap();
        assert_eq!(r.next_chunk(2).unwrap().n, 2);
        assert!(r.next_chunk(2).is_err(), "ragged row must surface as an error");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn load_or_generate_caches() {
        let p = tmp("cache");
        std::fs::remove_file(&p).ok();
        let mut calls = 0;
        let a = load_or_generate(&p, || {
            calls += 1;
            gaussian_blobs(&SynthConfig::tiny_images(20, 4), 1)
        })
        .unwrap();
        assert_eq!(calls, 1);
        let b = load_or_generate(&p, || unreachable!("must hit cache")).unwrap();
        assert_eq!(a.xs, b.xs);
        std::fs::remove_file(&p).ok();
    }
}
