//! Transaction (set-system) data for the submodular-coverage experiment
//! (paper §6.4: Accidents — 340,183 transactions, 468 items; Kosarak —
//! 990,002 transactions, 41,270 items). The generators below produce
//! scaled-down instances with matching shape: heavy-tailed item frequencies
//! (Zipf), transaction lengths matching each corpus's mean (Accidents is
//! dense/long, Kosarak sparse/short).

use crate::util::rng::{Rng, ZipfSampler};

/// A collection of transactions; element `i` of the ground set is the i-th
/// transaction (a set of item ids). Coverage of `S` = |union of S's items|.
#[derive(Debug, Clone)]
pub struct TransactionData {
    pub n_items: usize,
    pub transactions: Vec<Vec<u32>>,
}

impl TransactionData {
    pub fn n(&self) -> usize {
        self.transactions.len()
    }

    /// Union size of a set of transaction ids (reference implementation).
    pub fn union_size(&self, ids: &[usize]) -> usize {
        let mut seen = vec![false; self.n_items];
        let mut count = 0;
        for &t in ids {
            for &it in &self.transactions[t] {
                if !seen[it as usize] {
                    seen[it as usize] = true;
                    count += 1;
                }
            }
        }
        count
    }
}

/// Zipf-popularity transaction generator.
///
/// * `n` transactions over `n_items` items;
/// * lengths ~ `mean_len` (geometric-ish, at least 1);
/// * item draws Zipf(`skew`) so a few items are near-universal — the same
///   structure that makes greedy coverage saturate quickly on Accidents.
pub fn zipf_transactions(
    n: usize,
    n_items: usize,
    mean_len: usize,
    skew: f64,
    seed: u64,
) -> TransactionData {
    let mut rng = Rng::new(seed);
    let sampler = ZipfSampler::new(n_items, skew);
    let mut transactions = Vec::with_capacity(n);
    for _ in 0..n {
        // geometric length with the given mean, clamped to [1, 4*mean]
        let mut len = 1usize;
        let p = 1.0 / mean_len as f64;
        while !rng.bool(p) && len < mean_len * 4 {
            len += 1;
        }
        let mut items: Vec<u32> = Vec::with_capacity(len);
        for _ in 0..len {
            items.push(sampler.sample(&mut rng) as u32);
        }
        items.sort_unstable();
        items.dedup();
        transactions.push(items);
    }
    TransactionData { n_items, transactions }
}

/// Accidents-like instance (dense: 468 items, ~34 items/transaction),
/// scaled 10x down from the 340,183-transaction original by default.
pub fn accidents_like(n: usize, seed: u64) -> TransactionData {
    zipf_transactions(n, 468, 34, 1.05, seed)
}

/// Kosarak-like instance (sparse: 41,270 items, ~8 items/transaction),
/// scaled 10x down from the 990,002-transaction original by default.
pub fn kosarak_like(n: usize, seed: u64) -> TransactionData {
    zipf_transactions(n, 41_270, 8, 1.3, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_shapes() {
        let td = zipf_transactions(1000, 100, 10, 1.1, 3);
        assert_eq!(td.n(), 1000);
        assert!(td.transactions.iter().all(|t| !t.is_empty()));
        assert!(td
            .transactions
            .iter()
            .all(|t| t.iter().all(|&i| (i as usize) < 100)));
    }

    #[test]
    fn items_deduped_and_sorted() {
        let td = zipf_transactions(200, 50, 20, 1.5, 4);
        for t in &td.transactions {
            for w in t.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
    }

    #[test]
    fn union_size_monotone() {
        let td = accidents_like(500, 5);
        let u1 = td.union_size(&[0, 1]);
        let u2 = td.union_size(&[0, 1, 2, 3]);
        assert!(u2 >= u1);
        assert!(u2 <= td.n_items);
    }

    #[test]
    fn deterministic() {
        let a = kosarak_like(300, 6);
        let b = kosarak_like(300, 6);
        assert_eq!(a.transactions, b.transactions);
    }

    #[test]
    fn accidents_denser_than_kosarak() {
        let a = accidents_like(500, 7);
        let k = kosarak_like(500, 7);
        let mean = |td: &TransactionData| {
            td.transactions.iter().map(|t| t.len()).sum::<usize>() as f64 / td.n() as f64
        };
        assert!(mean(&a) > mean(&k));
    }
}
