//! Directed-graph substrate + the social-network generator for the max-cut
//! experiment (paper §6.3: a Facebook-like message network with 1,899 users
//! and 20,296 directed ties — we generate a preferential-attachment digraph
//! with the same node/edge counts and heavy-tailed degrees).

use crate::util::rng::Rng;

/// Directed weighted graph in adjacency-list form (out- and in-lists kept so
/// the cut objective can scan both directions in O(deg)).
#[derive(Debug, Clone)]
pub struct Digraph {
    pub n: usize,
    /// out[u] = list of (v, w) with edge u->v weight w
    pub out: Vec<Vec<(usize, f64)>>,
    /// rin[v] = list of (u, w) with edge u->v weight w
    pub rin: Vec<Vec<(usize, f64)>>,
    pub m: usize,
}

impl Digraph {
    pub fn new(n: usize) -> Self {
        Digraph { n, out: vec![Vec::new(); n], rin: vec![Vec::new(); n], m: 0 }
    }

    pub fn add_edge(&mut self, u: usize, v: usize, w: f64) {
        assert!(u < self.n && v < self.n);
        self.out[u].push((v, w));
        self.rin[v].push((u, w));
        self.m += 1;
    }

    pub fn total_weight(&self) -> f64 {
        self.out.iter().flatten().map(|&(_, w)| w).sum()
    }

    /// Out-degree + in-degree.
    pub fn degree(&self, u: usize) -> usize {
        self.out[u].len() + self.rin[u].len()
    }
}

/// Preferential-attachment directed graph: `n` nodes, ~`m_edges` edges,
/// unit weights. Endpoint popularity follows a heavy-tailed distribution,
/// mirroring the UCI message network's degree skew.
pub fn social_network(n: usize, m_edges: usize, seed: u64) -> Digraph {
    let mut rng = Rng::new(seed);
    let mut g = Digraph::new(n);
    // Maintain an endpoint pool for preferential attachment; seed it with
    // every node once so isolated nodes are possible but rare.
    let mut pool: Vec<usize> = (0..n).collect();
    let mut edges_seen = std::collections::HashSet::with_capacity(m_edges);
    let mut attempts = 0usize;
    while g.m < m_edges && attempts < m_edges * 50 {
        attempts += 1;
        let u = if rng.bool(0.8) {
            pool[rng.below(pool.len())]
        } else {
            rng.below(n)
        };
        let v = if rng.bool(0.8) {
            pool[rng.below(pool.len())]
        } else {
            rng.below(n)
        };
        if u == v || edges_seen.contains(&(u, v)) {
            continue;
        }
        edges_seen.insert((u, v));
        g.add_edge(u, v, 1.0);
        pool.push(u);
        pool.push(v);
    }
    g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_edge_updates_both_lists() {
        let mut g = Digraph::new(3);
        g.add_edge(0, 1, 2.0);
        assert_eq!(g.out[0], vec![(1, 2.0)]);
        assert_eq!(g.rin[1], vec![(0, 2.0)]);
        assert_eq!(g.m, 1);
        assert_eq!(g.total_weight(), 2.0);
    }

    #[test]
    fn social_network_counts() {
        let g = social_network(1899, 20_296, 42);
        assert_eq!(g.n, 1899);
        assert_eq!(g.m, 20_296);
    }

    #[test]
    fn social_network_deterministic() {
        let a = social_network(200, 1000, 5);
        let b = social_network(200, 1000, 5);
        assert_eq!(a.m, b.m);
        assert_eq!(a.out[0], b.out[0]);
    }

    #[test]
    fn social_network_heavy_tail() {
        let g = social_network(1000, 10_000, 9);
        let mut degs: Vec<usize> = (0..g.n).map(|u| g.degree(u)).collect();
        degs.sort_unstable_by(|a, b| b.cmp(a));
        // top 1% of nodes should hold well above their uniform share
        let top: usize = degs[..10].iter().sum();
        let total: usize = degs.iter().sum();
        // top 1% of nodes hold >= 3x their uniform share of degree
        assert!(
            top as f64 > 0.03 * total as f64,
            "no skew: top10={top}, total={total}"
        );
    }

    #[test]
    fn no_self_loops_or_duplicates() {
        let g = social_network(100, 500, 11);
        let mut seen = std::collections::HashSet::new();
        for u in 0..g.n {
            for &(v, _) in &g.out[u] {
                assert_ne!(u, v, "self loop");
                assert!(seen.insert((u, v)), "duplicate edge {u}->{v}");
            }
        }
    }
}
