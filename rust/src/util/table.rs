//! ASCII table / series rendering — the experiment harnesses print the same
//! rows/series the paper's figures plot, in a diff-friendly format that is
//! also recorded in EXPERIMENTS.md.

/// A column-aligned ASCII table.
#[derive(Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Convenience: format f64 cells with 4 decimals.
    pub fn row_f(&mut self, label: &str, values: &[f64]) {
        let mut cells = vec![label.to_string()];
        cells.extend(values.iter().map(|v| format!("{v:.4}")));
        self.row(&cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            (0..ncol)
                .map(|i| format!(" {:<width$} ", cells[i], width = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// Named (x, y) series — one per curve in a paper figure.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

impl Series {
    pub fn new(name: &str) -> Self {
        Series { name: name.to_string(), points: Vec::new() }
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.points.push((x, y));
    }
}

/// Render a set of series as a table with one x column and one column per
/// series (the textual equivalent of a multi-line figure).
pub fn render_series(title: &str, xlabel: &str, series: &[Series]) -> String {
    let mut headers: Vec<&str> = vec![xlabel];
    for s in series {
        headers.push(&s.name);
    }
    let mut t = Table::new(title, &headers);
    let xs: Vec<f64> = series
        .first()
        .map(|s| s.points.iter().map(|p| p.0).collect())
        .unwrap_or_default();
    for (i, x) in xs.iter().enumerate() {
        let mut cells = vec![format!("{x}")];
        for s in series {
            cells.push(
                s.points
                    .get(i)
                    .map(|p| format!("{:.4}", p.1))
                    .unwrap_or_else(|| "-".into()),
            );
        }
        t.row(&cells);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["m", "greedi", "random"]);
        t.row_f("2", &[0.98, 0.55]);
        t.row_f("4", &[0.97, 0.52]);
        let s = t.render();
        assert!(s.contains("## demo"));
        assert!(s.contains("greedi"));
        assert!(s.lines().count() >= 4);
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".to_string()]);
    }

    #[test]
    fn series_render() {
        let mut a = Series::new("greedi");
        a.push(2.0, 0.99);
        a.push(4.0, 0.98);
        let mut b = Series::new("random");
        b.push(2.0, 0.6);
        b.push(4.0, 0.5);
        let out = render_series("fig", "m", &[a, b]);
        assert!(out.contains("greedi") && out.contains("random"));
        assert!(out.contains("0.9900"));
    }
}
