//! Minimal CLI argument parser (the vendored dependency closure has no
//! `clap`). Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed accessors and a generated usage string.

use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
    declared: Vec<(String, String, String)>, // (name, default, help)
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Self {
        let mut a = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    a.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    a.options.insert(rest.to_string(), v);
                } else {
                    a.flags.push(rest.to_string());
                }
            } else {
                a.positional.push(tok);
            }
        }
        a
    }

    /// Parse from the process environment.
    pub fn from_env() -> Self {
        Args::parse(std::env::args().skip(1))
    }

    /// Declare an option for the usage string; returns self for chaining.
    pub fn declare(mut self, name: &str, default: &str, help: &str) -> Self {
        self.declared
            .push((name.to_string(), default.to_string(), help.to_string()));
        self
    }

    pub fn usage(&self, prog: &str) -> String {
        let mut s = format!("usage: {prog} [options]\n");
        for (name, default, help) in &self.declared {
            s.push_str(&format!("  --{name:<16} {help} (default: {default})\n"));
        }
        s
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a float, got {v:?}")))
            .unwrap_or(default)
    }

    /// Comma-separated list of usize (`--k 8,16,32`).
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.trim().parse().unwrap_or_else(|_| panic!("--{name}: bad integer {s:?}")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("fig4 --part a --k 50 --seed=7 --verbose");
        assert_eq!(a.positional, vec!["fig4"]);
        assert_eq!(a.get("part"), Some("a"));
        assert_eq!(a.get_usize("k", 0), 50);
        assert_eq!(a.get_u64("seed", 0), 7);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.get_usize("k", 10), 10);
        assert_eq!(a.get_f64("alpha", 1.5), 1.5);
        assert_eq!(a.get_str("part", "a"), "a");
    }

    #[test]
    fn list_parsing() {
        let a = parse("x --ks 8,16,32");
        assert_eq!(a.get_usize_list("ks", &[1]), vec![8, 16, 32]);
        assert_eq!(a.get_usize_list("ms", &[1, 2]), vec![1, 2]);
    }

    #[test]
    fn flag_before_option() {
        let a = parse("--dry-run --m 4");
        assert!(a.has_flag("dry-run"));
        assert_eq!(a.get_usize("m", 0), 4);
    }

    #[test]
    #[should_panic]
    fn bad_int_panics() {
        parse("--k abc").get_usize("k", 0);
    }
}
