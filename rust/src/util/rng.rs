//! Deterministic pseudo-random number generation.
//!
//! All experiment randomness (dataset synthesis, random partitioning,
//! stochastic greedy sampling, RandomGreedy tie-breaking) flows through this
//! module so every figure is exactly reproducible from its seed. The core
//! generator is xoshiro256** (Blackman & Vigna), seeded through SplitMix64 —
//! the standard, well-tested pairing.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG. `Clone` so worker shards can fork deterministic
/// sub-streams via [`Rng::fork`].
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 expansion (any seed, including 0, is fine).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Deterministic sub-stream `i` of this generator (stream splitting for
    /// per-machine randomness in the simulated cluster).
    pub fn fork(&self, i: u64) -> Self {
        let mut sm = self.s[0] ^ self.s[2] ^ i.wrapping_mul(0xA24B_AED4_963E_E407);
        Rng::new(splitmix64(&mut sm))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`, 53-bit precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire's rejection-free-ish method with
    /// a correctness fallback loop for small biases).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply trick; bias negligible but we keep it exact with
        // a rejection threshold.
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (cached second value omitted for
    /// simplicity; synthesis is not on the hot path).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE); // avoid ln(0)
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` without replacement
    /// (Floyd's algorithm — O(k) expected, order not uniform but set is).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample {k} from {n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }

}

/// Zipf(s) sampler over `[0, n)` with an exact precomputed inverse-CDF
/// table (heavy-tailed item popularity for the transaction generators).
/// Build once, draw many times: O(n) setup, O(log n) per draw.
#[derive(Debug, Clone)]
pub struct ZipfSampler {
    cdf: Vec<f64>,
}

impl ZipfSampler {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += (k as f64).powf(-s);
            cdf.push(acc);
        }
        let total = acc;
        for c in cdf.iter_mut() {
            *c /= total;
        }
        ZipfSampler { cdf }
    }

    /// Draw an index in `[0, n)`; index 0 is the most popular item.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        // first index with cdf >= u
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_same_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(5);
        for _ in 0..100 {
            let s = r.sample_indices(50, 20);
            assert_eq!(s.len(), 20);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 20);
            assert!(s.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let base = Rng::new(123);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
        // and reproducible
        let mut a2 = base.fork(0);
        let mut a3 = Rng::new(123).fork(0);
        assert_eq!(a2.next_u64(), a3.next_u64());
    }

    #[test]
    fn zipf_heavy_tail() {
        let mut r = Rng::new(17);
        let n = 1000;
        let sampler = ZipfSampler::new(n, 1.2);
        let mut counts = vec![0usize; n];
        for _ in 0..20_000 {
            counts[sampler.sample(&mut r)] += 1;
        }
        // item 0 must be far more popular than item 100
        assert!(counts[0] > counts[100] * 3, "{} vs {}", counts[0], counts[100]);
        // and the tail must still be visited
        assert!(counts[100..].iter().sum::<usize>() > 100);
    }
}
