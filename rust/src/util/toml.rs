//! TOML-subset parser for experiment config files (`configs/*.toml`).
//!
//! The vendored dependency closure has no `serde`/`toml`, so we implement the
//! subset the config system needs: `[section]` headers, `key = value` with
//! string / integer / float / bool / flat array values, `#` comments, and
//! blank lines. Nested tables and multi-line values are intentionally out of
//! scope — config presets stay flat by design.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed TOML-subset value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "{s:?}"),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Array(xs) => {
                write!(f, "[")?;
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
        }
    }
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|i| usize::try_from(i).ok())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(x) => Some(*x),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_usize_array(&self) -> Option<Vec<usize>> {
        match self {
            Value::Array(xs) => xs.iter().map(|v| v.as_usize()).collect(),
            _ => None,
        }
    }
}

/// A parsed document: `section.key -> value`; keys before any `[section]`
/// live in the "" (root) section.
#[derive(Debug, Default, Clone)]
pub struct Document {
    pub entries: BTreeMap<String, Value>,
}

impl Document {
    pub fn get(&self, path: &str) -> Option<&Value> {
        self.entries.get(path)
    }

    pub fn sections(&self) -> Vec<String> {
        let mut out: Vec<String> = self
            .entries
            .keys()
            .filter_map(|k| k.rsplit_once('.').map(|(s, _)| s.to_string()))
            .collect();
        out.sort();
        out.dedup();
        out
    }
}

/// Parse error with a line number.
#[derive(Debug)]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml parse error at line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ParseError {}

fn parse_scalar(tok: &str, line: usize) -> Result<Value, ParseError> {
    let t = tok.trim();
    if t.starts_with('"') && t.ends_with('"') && t.len() >= 2 {
        return Ok(Value::Str(t[1..t.len() - 1].to_string()));
    }
    if t == "true" {
        return Ok(Value::Bool(true));
    }
    if t == "false" {
        return Ok(Value::Bool(false));
    }
    if let Ok(i) = t.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(x) = t.parse::<f64>() {
        return Ok(Value::Float(x));
    }
    Err(ParseError { line, msg: format!("bad value {t:?}") })
}

fn parse_value(tok: &str, line: usize) -> Result<Value, ParseError> {
    let t = tok.trim();
    if t.starts_with('[') {
        if !t.ends_with(']') {
            return Err(ParseError { line, msg: "unterminated array".into() });
        }
        let inner = &t[1..t.len() - 1];
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                items.push(parse_scalar(part, line)?);
            }
        }
        return Ok(Value::Array(items));
    }
    parse_scalar(t, line)
}

/// Strip a trailing comment that is not inside a quoted string.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Parse a TOML-subset document.
pub fn parse(text: &str) -> Result<Document, ParseError> {
    let mut doc = Document::default();
    let mut section = String::new();
    for (idx, raw) in text.lines().enumerate() {
        let line_no = idx + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') {
            if !line.ends_with(']') {
                return Err(ParseError { line: line_no, msg: "unterminated section header".into() });
            }
            section = line[1..line.len() - 1].trim().to_string();
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            return Err(ParseError { line: line_no, msg: format!("expected key = value, got {line:?}") });
        };
        let key = k.trim();
        if key.is_empty() {
            return Err(ParseError { line: line_no, msg: "empty key".into() });
        }
        let path = if section.is_empty() {
            key.to_string()
        } else {
            format!("{section}.{key}")
        };
        doc.entries.insert(path, parse_value(v, line_no)?);
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_sections() {
        let doc = parse(
            r#"
            # experiment preset
            name = "fig4a"
            [greedi]
            m = 10
            alpha = 1.5
            local = true
            ks = [10, 20, 50]
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("name").unwrap().as_str(), Some("fig4a"));
        assert_eq!(doc.get("greedi.m").unwrap().as_usize(), Some(10));
        assert_eq!(doc.get("greedi.alpha").unwrap().as_f64(), Some(1.5));
        assert_eq!(doc.get("greedi.local").unwrap().as_bool(), Some(true));
        assert_eq!(
            doc.get("greedi.ks").unwrap().as_usize_array(),
            Some(vec![10, 20, 50])
        );
    }

    #[test]
    fn comments_inside_strings_preserved() {
        let doc = parse(r##"s = "a#b" # trailing"##).unwrap();
        assert_eq!(doc.get("s").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn int_promotes_to_f64() {
        let doc = parse("x = 3").unwrap();
        assert_eq!(doc.get("x").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let err = parse("ok = 1\nbroken line").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn empty_array() {
        let doc = parse("xs = []").unwrap();
        assert_eq!(doc.get("xs").unwrap().as_usize_array(), Some(vec![]));
    }

    #[test]
    fn bad_value_rejected() {
        assert!(parse("x = nope").is_err());
    }

    #[test]
    fn sections_listing() {
        let doc = parse("[a]\nx=1\n[b]\ny=2").unwrap();
        assert_eq!(doc.sections(), vec!["a".to_string(), "b".to_string()]);
    }
}
