//! Infrastructure substrates built from scratch for the offline environment:
//! deterministic RNG, a persistent work-stealing executor (plus its
//! `threadpool` compatibility facade), CLI parsing, a TOML-subset config
//! reader, summary statistics, wallclock timing, ASCII table rendering and a
//! micro-benchmark harness (criterion/clap/serde/tokio/rayon are unavailable
//! in the vendored dependency closure — each is replaced by a purpose-built
//! module below).

pub mod args;
pub mod bench;
pub mod error;
pub mod executor;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
pub mod threadpool;
pub mod timer;
pub mod toml;
pub mod trace;
