//! Micro-benchmark harness (no criterion in the offline closure).
//!
//! Provides warmup + timed iterations with mean/std/min reporting, a
//! `black_box` to defeat const-folding, and a tiny registry so `cargo bench`
//! targets can share formatting. Deliberately simple: the experiment benches
//! measure end-to-end protocol runs (seconds), not nanosecond kernels.

use std::hint::black_box as std_black_box;
use std::time::Instant;

use super::stats::summarize;
use super::timer::fmt_duration;

/// Re-export of `std::hint::black_box` under the criterion-familiar name.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} ±{:>10}  (min {:>10}, n={})",
            self.name,
            fmt_duration(self.mean_s),
            fmt_duration(self.std_s),
            fmt_duration(self.min_s),
            self.iters
        )
    }
}

/// Benchmark runner with configurable warmup/iteration counts.
pub struct Bencher {
    pub warmup: usize,
    pub iters: usize,
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 1, iters: 5, results: Vec::new() }
    }
}

impl Bencher {
    pub fn new(warmup: usize, iters: usize) -> Self {
        Bencher { warmup, iters: iters.max(1), results: Vec::new() }
    }

    /// Honour `GREEDI_BENCH_FAST=1` for CI-speed runs.
    pub fn from_env() -> Self {
        if std::env::var("GREEDI_BENCH_FAST").ok().as_deref() == Some("1") {
            Bencher::new(0, 2)
        } else {
            Bencher::default()
        }
    }

    /// Run `f` and record its timing under `name`. The closure's output is
    /// black-boxed so the work cannot be optimized away.
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples = Vec::with_capacity(self.iters);
        for _ in 0..self.iters {
            let t = Instant::now();
            black_box(f());
            samples.push(t.elapsed().as_secs_f64());
        }
        let s = summarize(&samples);
        let res = BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean_s: s.mean,
            std_s: s.std,
            min_s: s.min,
        };
        println!("{}", res.report());
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Ratio between two recorded results (speedup of `b` over `a`).
    pub fn speedup(&self, a: &str, b: &str) -> Option<f64> {
        let fa = self.results.iter().find(|r| r.name == a)?.mean_s;
        let fb = self.results.iter().find(|r| r.name == b)?.mean_s;
        Some(fa / fb)
    }

    /// Serialize results as a flat JSON object `op name -> ns/iter` (mean),
    /// in recording order — the machine-readable trail CI archives so the
    /// perf trajectory is diffable across PRs.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        for (i, r) in self.results.iter().enumerate() {
            let sep = if i + 1 == self.results.len() { "" } else { "," };
            out.push_str(&format!(
                "  \"{}\": {:.1}{}\n",
                json_escape(&r.name),
                r.mean_s * 1e9,
                sep
            ));
        }
        out.push_str("}\n");
        out
    }

    /// Write [`Bencher::to_json`] to `path`.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }

    /// Honour `GREEDI_BENCH_JSON=path`: if set, dump the ns/iter table
    /// there. Returns the path written, if any.
    pub fn maybe_write_json_env(&self) -> Option<String> {
        let path = std::env::var("GREEDI_BENCH_JSON").ok()?;
        if path.is_empty() {
            return None;
        }
        match self.write_json(&path) {
            Ok(()) => {
                println!("(wrote bench JSON to {path})");
                Some(path)
            }
            Err(e) => {
                eprintln!("warning: could not write bench JSON to {path}: {e}");
                None
            }
        }
    }
}

/// Minimal JSON string escaping for bench op names (quotes, backslashes,
/// control chars — names are ASCII labels, nothing fancier needed).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_result() {
        let mut b = Bencher::new(0, 3);
        b.bench("noop", || 1 + 1);
        assert_eq!(b.results.len(), 1);
        assert_eq!(b.results[0].iters, 3);
        assert!(b.results[0].mean_s >= 0.0);
    }

    #[test]
    fn json_output_parses_and_keys_match() {
        let mut b = Bencher::new(0, 2);
        b.bench("op one", || 1);
        b.bench("op \"two\"", || 2);
        let json = b.to_json();
        let parsed = crate::util::json::parse(&json).expect("bench JSON must parse");
        assert!((parsed.get("op one").and_then(|v| v.as_f64())).is_some());
        assert!((parsed.get("op \"two\"").and_then(|v| v.as_f64())).is_some());
    }

    #[test]
    fn speedup_of_slower_over_faster_gt_one() {
        let mut b = Bencher::new(0, 3);
        b.bench("slow", || {
            let mut s = 0u64;
            for i in 0..200_000u64 {
                s = s.wrapping_add(black_box(i));
            }
            s
        });
        b.bench("fast", || black_box(1u64));
        assert!(b.speedup("slow", "fast").unwrap() > 1.0);
        assert!(b.speedup("missing", "fast").is_none());
    }
}
