//! Process-wide structured observability: spans, a metrics registry, and
//! Chrome-trace/NDJSON exporters — zero dependencies, dogfooding
//! [`util::json`](crate::util::json) for every byte it writes.
//!
//! ## Model
//!
//! * **Spans** are RAII guards ([`span`] / [`span_with`]) carrying a static
//!   name, optional key=value fields, the emitting thread, and monotonic
//!   start/end timestamps taken from one process epoch. A thread-local depth
//!   counter nests them, so a traced run yields the full
//!   protocol → stage → shard → kernel-dispatch tree.
//! * **Instant events** ([`event`] / [`event_with`]) mark points in time
//!   (a fault retry, a sieve ladder re-price) without a duration.
//! * **Metrics** are process-global named atomics — [`Counter`] (monotonic),
//!   [`Gauge`] (high-water), [`Histogram`] (power-of-two buckets + count/sum/
//!   max) — always on, readable at any time via [`metrics_snapshot`]. They
//!   are independent of the span switch: a relaxed `fetch_add` is cheap
//!   enough to leave in every hot path unconditionally.
//! * **Exporters**: [`flush`] drains every per-thread span buffer (in buffer
//!   registration order, chronological within a thread — a deterministic
//!   total order) and writes two files: the configured path gets a Chrome
//!   `trace_event` JSON document (open it in Perfetto / `chrome://tracing`),
//!   and `<path>.ndjson` gets one compact JSON event per line for ad-hoc
//!   `grep`/`jq` analysis.
//!
//! Tracing is activated by `GREEDI_TRACE=path` (see [`init_from_env`]),
//! `--trace path` on the CLI, or the `trace` TOML key — all three end in
//! [`enable`]. The enabled check is a single relaxed atomic load, and the
//! disabled [`SpanGuard`] holds only an empty `Vec` (which does not
//! allocate), so an untraced span site costs a branch and nothing else.
//!
//! ## The non-perturbation contract
//!
//! Tracing must never change results: spans and events only *read* values
//! already computed by the instrumented code and never touch algorithm
//! state, so traced runs are bit-identical to untraced runs (pinned across
//! the protocol registry by `tests/integration_trace.rs`). Span collection
//! is lock-sharded per thread — each thread appends to its own buffer under
//! its own mutex — so tracing does not serialize the executor.
//!
//! ## Recipe: add a span
//!
//! ```ignore
//! use crate::util::trace;
//! // zero-field span; guard closes the span when dropped
//! let _g = trace::span("merge.round");
//! // fields are built inside a closure that only runs when tracing is on
//! let _g = trace::span_with("mr.stage", || vec![("tasks", n.into())]);
//! ```
//!
//! ## Recipe: add a counter
//!
//! ```ignore
//! // per-call-site cached pointer: one registry lookup ever, then a
//! // relaxed fetch_add per hit
//! crate::trace_counter!("executor.submitted").incr();
//! // or resolve once at construction for the very hottest paths
//! let c: &'static trace::Counter = trace::counter("engine.batches");
//! ```

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use super::json::Json;

// ---------------------------------------------------------------------------
// Enabled gate + output path
// ---------------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is span/event collection on? One relaxed atomic load — the only cost a
/// disabled call site pays besides its branch.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

fn out_path() -> &'static Mutex<Option<PathBuf>> {
    static P: OnceLock<Mutex<Option<PathBuf>>> = OnceLock::new();
    P.get_or_init(|| Mutex::new(None))
}

/// Turn span collection on and remember where [`flush`] should write.
pub fn enable(path: impl Into<PathBuf>) {
    *out_path().lock().unwrap() = Some(path.into());
    ENABLED.store(true, Ordering::Relaxed);
}

/// Turn span collection off. Buffered events stay until [`flush`] or
/// [`clear_events`].
pub fn disable() {
    ENABLED.store(false, Ordering::Relaxed);
}

/// Honour `GREEDI_TRACE=path`: enable tracing to that path. Returns the
/// path when the variable was set and non-empty.
pub fn init_from_env() -> Option<PathBuf> {
    match std::env::var("GREEDI_TRACE") {
        Ok(p) if !p.is_empty() => {
            let pb = PathBuf::from(p);
            enable(pb.clone());
            Some(pb)
        }
        _ => None,
    }
}

/// The currently configured output path, if any.
pub fn output_path() -> Option<PathBuf> {
    out_path().lock().unwrap().clone()
}

fn epoch() -> Instant {
    static E: OnceLock<Instant> = OnceLock::new();
    *E.get_or_init(Instant::now)
}

fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

// ---------------------------------------------------------------------------
// Fields
// ---------------------------------------------------------------------------

/// A span/event field value. `From` impls cover the common cases so call
/// sites can write `("tasks", n.into())`.
#[derive(Debug, Clone)]
pub enum FieldValue {
    U(u64),
    F(f64),
    S(String),
}

impl From<usize> for FieldValue {
    fn from(v: usize) -> Self {
        FieldValue::U(v as u64)
    }
}
impl From<u64> for FieldValue {
    fn from(v: u64) -> Self {
        FieldValue::U(v)
    }
}
impl From<u32> for FieldValue {
    fn from(v: u32) -> Self {
        FieldValue::U(v as u64)
    }
}
impl From<f64> for FieldValue {
    fn from(v: f64) -> Self {
        FieldValue::F(v)
    }
}
impl From<&str> for FieldValue {
    fn from(v: &str) -> Self {
        FieldValue::S(v.to_string())
    }
}
impl From<String> for FieldValue {
    fn from(v: String) -> Self {
        FieldValue::S(v)
    }
}

impl FieldValue {
    fn to_json(&self) -> Json {
        match self {
            FieldValue::U(v) => Json::num(*v as f64),
            FieldValue::F(v) => Json::num(*v),
            FieldValue::S(s) => Json::str(s.clone()),
        }
    }
}

/// Field list type accepted by [`span_with`] / [`event_with`] closures.
pub type Fields = Vec<(&'static str, FieldValue)>;

// ---------------------------------------------------------------------------
// Per-thread event buffers
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
struct Event {
    name: &'static str,
    start_ns: u64,
    /// `Some(dur)` for a completed span, `None` for an instant event.
    dur_ns: Option<u64>,
    depth: u32,
    fields: Fields,
}

type SharedBuf = Arc<Mutex<Vec<Event>>>;

/// Registry of every thread's buffer, in first-emit order. Flush iterates
/// this order, so the export is a deterministic total order for a given run.
fn buffers() -> &'static Mutex<Vec<SharedBuf>> {
    static B: OnceLock<Mutex<Vec<SharedBuf>>> = OnceLock::new();
    B.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static LOCAL: RefCell<Option<(usize, SharedBuf)>> = const { RefCell::new(None) };
    static DEPTH: Cell<u32> = const { Cell::new(0) };
}

fn push_event(ev: Event) {
    LOCAL.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            let buf: SharedBuf = Arc::new(Mutex::new(Vec::new()));
            let mut reg = buffers().lock().unwrap();
            let tid = reg.len();
            reg.push(Arc::clone(&buf));
            drop(reg);
            *slot = Some((tid, buf));
        }
        let (_, buf) = slot.as_ref().unwrap();
        buf.lock().unwrap().push(ev);
    });
}

// ---------------------------------------------------------------------------
// Spans + instant events
// ---------------------------------------------------------------------------

/// RAII span guard: records one complete event when dropped. Inert (and
/// allocation-free) when tracing was disabled at open time.
pub struct SpanGuard {
    name: &'static str,
    start_ns: u64,
    depth: u32,
    fields: Fields,
    active: bool,
}

/// Open a span with no fields. Disabled path: one branch, no allocation
/// (`Vec::new` does not allocate).
#[inline(always)]
pub fn span(name: &'static str) -> SpanGuard {
    if !enabled() {
        return SpanGuard { name, start_ns: 0, depth: 0, fields: Vec::new(), active: false };
    }
    open_span(name, Vec::new())
}

/// Open a span with fields. The closure only runs when tracing is enabled,
/// so field construction costs nothing on the disabled path.
#[inline(always)]
pub fn span_with<F>(name: &'static str, fields: F) -> SpanGuard
where
    F: FnOnce() -> Fields,
{
    if !enabled() {
        return SpanGuard { name, start_ns: 0, depth: 0, fields: Vec::new(), active: false };
    }
    open_span(name, fields())
}

#[cold]
fn open_span(name: &'static str, fields: Fields) -> SpanGuard {
    let depth = DEPTH.with(|d| {
        let v = d.get();
        d.set(v + 1);
        v
    });
    SpanGuard { name, start_ns: now_ns(), depth, fields, active: true }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if !self.active {
            return;
        }
        DEPTH.with(|d| d.set(d.get().saturating_sub(1)));
        let end = now_ns();
        push_event(Event {
            name: self.name,
            start_ns: self.start_ns,
            dur_ns: Some(end.saturating_sub(self.start_ns)),
            depth: self.depth,
            fields: std::mem::take(&mut self.fields),
        });
    }
}

/// Record an instant event (no duration) with no fields.
#[inline(always)]
pub fn event(name: &'static str) {
    if !enabled() {
        return;
    }
    emit_event(name, Vec::new());
}

/// Record an instant event with fields; the closure only runs when enabled.
#[inline(always)]
pub fn event_with<F>(name: &'static str, fields: F)
where
    F: FnOnce() -> Fields,
{
    if !enabled() {
        return;
    }
    emit_event(name, fields());
}

#[cold]
fn emit_event(name: &'static str, fields: Fields) {
    push_event(Event {
        name,
        start_ns: now_ns(),
        dur_ns: None,
        depth: DEPTH.with(|d| d.get()),
        fields,
    });
}

// ---------------------------------------------------------------------------
// Metrics: counters, gauges, histograms
// ---------------------------------------------------------------------------

/// Monotonic counter (relaxed atomic). Always on — independent of the span
/// switch.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    #[inline(always)]
    pub fn incr(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline(always)]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

/// High-water gauge: `record` keeps the maximum ever seen.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    #[inline(always)]
    pub fn record(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }

    fn reset(&self) {
        self.0.store(0, Ordering::Relaxed);
    }
}

const HIST_BUCKETS: usize = 40;

/// Fixed power-of-two-bucket histogram: bucket `i` counts values with
/// `v < 2^i` (and `v` in the previous bucket's range), plus exact
/// count/sum/max. Units are the caller's (serve records microseconds).
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    #[inline(always)]
    pub fn record(&self, v: u64) {
        // value 0 lands in bucket 0; otherwise bucket = bit width of v
        let b = (64 - v.leading_zeros() as usize).min(HIST_BUCKETS - 1);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    fn to_json(&self) -> Json {
        let count = self.count();
        let sum = self.sum();
        let mean = if count > 0 { sum as f64 / count as f64 } else { 0.0 };
        let mut bs: Vec<Json> = Vec::new();
        for (i, b) in self.buckets.iter().enumerate() {
            let n = b.load(Ordering::Relaxed);
            if n > 0 {
                // upper bound of bucket i is 2^i - 1 (bucket 0 holds v == 0)
                let le = if i == 0 { 0.0 } else { (1u64 << i) as f64 - 1.0 };
                bs.push(Json::obj([("le", Json::num(le)), ("n", Json::num(n as f64))]));
            }
        }
        Json::obj([
            ("count", Json::num(count as f64)),
            ("sum", Json::num(sum as f64)),
            ("mean", Json::num(mean)),
            ("max", Json::num(self.max() as f64)),
            ("buckets", Json::Arr(bs)),
        ])
    }

    fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.sum.store(0, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }
}

/// Per-kernel dispatch accounting for the sharded gain engine: how many
/// candidate gains were priced and which path priced them. Resolved once
/// per engine construction (see `ShardedGainEngine::new`), so the hot
/// pricing loop touches only relaxed atomics.
#[derive(Debug, Default)]
pub struct KernelCounters {
    /// Candidate gains requested through `price`.
    pub gains: Counter,
    /// Batches answered by an accelerator backend (`backend_batch`).
    pub backend: Counter,
    /// Batches answered by the closed-form singleton path.
    pub closed_form: Counter,
    /// Batches priced by the CPU sharded path (SIMD or scalar kernel).
    pub sharded: Counter,
}

impl KernelCounters {
    fn new() -> KernelCounters {
        KernelCounters {
            gains: Counter::new(),
            backend: Counter::new(),
            closed_form: Counter::new(),
            sharded: Counter::new(),
        }
    }

    fn to_json(&self) -> Json {
        Json::obj([
            ("gains", Json::num(self.gains.get() as f64)),
            ("backend_batches", Json::num(self.backend.get() as f64)),
            ("closed_form_batches", Json::num(self.closed_form.get() as f64)),
            ("sharded_batches", Json::num(self.sharded.get() as f64)),
        ])
    }

    fn reset(&self) {
        self.gains.reset();
        self.backend.reset();
        self.closed_form.reset();
        self.sharded.reset();
    }
}

#[derive(Default)]
struct Registries {
    counters: BTreeMap<&'static str, &'static Counter>,
    gauges: BTreeMap<&'static str, &'static Gauge>,
    histograms: BTreeMap<&'static str, &'static Histogram>,
    kernels: BTreeMap<&'static str, &'static KernelCounters>,
}

fn registries() -> &'static Mutex<Registries> {
    static R: OnceLock<Mutex<Registries>> = OnceLock::new();
    R.get_or_init(|| Mutex::new(Registries::default()))
}

/// Look up (or create) the named counter. Takes the registry lock — cache
/// the returned `&'static` at the call site ([`crate::trace_counter!`]) or
/// at construction time for hot paths.
pub fn counter(name: &'static str) -> &'static Counter {
    let mut r = registries().lock().unwrap();
    r.counters.entry(name).or_insert_with(|| Box::leak(Box::new(Counter::new())))
}

/// Look up (or create) the named high-water gauge.
pub fn gauge(name: &'static str) -> &'static Gauge {
    let mut r = registries().lock().unwrap();
    r.gauges.entry(name).or_insert_with(|| Box::leak(Box::new(Gauge::new())))
}

/// Look up (or create) the named histogram.
pub fn histogram(name: &'static str) -> &'static Histogram {
    let mut r = registries().lock().unwrap();
    r.histograms.entry(name).or_insert_with(|| Box::leak(Box::new(Histogram::new())))
}

/// Look up (or create) the dispatch counters for one kernel label.
pub fn kernel_counters(label: &'static str) -> &'static KernelCounters {
    let mut r = registries().lock().unwrap();
    r.kernels.entry(label).or_insert_with(|| Box::leak(Box::new(KernelCounters::new())))
}

/// Per-call-site cached counter handle: one registry lookup ever, then a
/// raw `&'static Counter` per hit.
#[macro_export]
macro_rules! trace_counter {
    ($name:expr) => {{
        static SITE: ::std::sync::OnceLock<&'static $crate::util::trace::Counter> =
            ::std::sync::OnceLock::new();
        *SITE.get_or_init(|| $crate::util::trace::counter($name))
    }};
}

/// Per-call-site cached gauge handle (see [`crate::trace_counter!`]).
#[macro_export]
macro_rules! trace_gauge {
    ($name:expr) => {{
        static SITE: ::std::sync::OnceLock<&'static $crate::util::trace::Gauge> =
            ::std::sync::OnceLock::new();
        *SITE.get_or_init(|| $crate::util::trace::gauge($name))
    }};
}

/// Snapshot every registered metric as a deterministic JSON object
/// (BTreeMap name order): `{counters, gauges, histograms, kernels}`.
pub fn metrics_snapshot() -> Json {
    let r = registries().lock().unwrap();
    let counters = Json::obj(
        r.counters.iter().map(|(k, c)| (k.to_string(), Json::num(c.get() as f64))),
    );
    let gauges =
        Json::obj(r.gauges.iter().map(|(k, g)| (k.to_string(), Json::num(g.get() as f64))));
    let histograms = Json::obj(r.histograms.iter().map(|(k, h)| (k.to_string(), h.to_json())));
    let kernels = Json::obj(r.kernels.iter().map(|(k, kc)| (k.to_string(), kc.to_json())));
    Json::obj([
        ("counters", counters),
        ("gauges", gauges),
        ("histograms", histograms),
        ("kernels", kernels),
    ])
}

/// Zero every registered metric (benches/tests; names stay registered).
pub fn reset_metrics() {
    let r = registries().lock().unwrap();
    for c in r.counters.values() {
        c.reset();
    }
    for g in r.gauges.values() {
        g.reset();
    }
    for h in r.histograms.values() {
        h.reset();
    }
    for k in r.kernels.values() {
        k.reset();
    }
}

/// Drop all buffered span/instant events without exporting them.
pub fn clear_events() {
    let reg = buffers().lock().unwrap();
    for buf in reg.iter() {
        buf.lock().unwrap().clear();
    }
}

/// Number of events currently buffered across all threads.
pub fn buffered_events() -> usize {
    let reg = buffers().lock().unwrap();
    reg.iter().map(|b| b.lock().unwrap().len()).sum()
}

// ---------------------------------------------------------------------------
// Exporters
// ---------------------------------------------------------------------------

/// The NDJSON sidecar path for a Chrome-trace path: `<path>.ndjson`.
pub fn ndjson_path(p: &Path) -> PathBuf {
    let mut s = p.as_os_str().to_os_string();
    s.push(".ndjson");
    PathBuf::from(s)
}

/// Drain every per-thread buffer (registration order, chronological within
/// a thread) and write the Chrome `trace_event` JSON document to the
/// configured path plus an NDJSON sidecar at `<path>.ndjson`. Returns the
/// Chrome-trace path on success; `None` when no path is configured or the
/// write failed (warning on stderr — tracing must never abort a run).
pub fn flush() -> Option<PathBuf> {
    let path = output_path()?;
    let mut events: Vec<(usize, Event)> = Vec::new();
    {
        let reg = buffers().lock().unwrap();
        for (tid, buf) in reg.iter().enumerate() {
            let drained: Vec<Event> = std::mem::take(&mut *buf.lock().unwrap());
            events.extend(drained.into_iter().map(|e| (tid, e)));
        }
    }

    let mut trace_events: Vec<Json> = Vec::with_capacity(events.len());
    let mut ndjson = String::new();
    for (tid, e) in &events {
        let ts_us = e.start_ns as f64 / 1000.0;
        let mut args: BTreeMap<String, Json> =
            e.fields.iter().map(|(k, v)| (k.to_string(), v.to_json())).collect();
        args.insert("depth".to_string(), Json::num(e.depth as f64));

        let mut obj: BTreeMap<String, Json> = BTreeMap::new();
        obj.insert("name".to_string(), Json::str(e.name));
        obj.insert("cat".to_string(), Json::str("greedi"));
        obj.insert("pid".to_string(), Json::num(1.0));
        obj.insert("tid".to_string(), Json::num(*tid as f64));
        obj.insert("ts".to_string(), Json::num(ts_us));
        obj.insert("args".to_string(), Json::Obj(args.clone()));
        match e.dur_ns {
            Some(d) => {
                obj.insert("ph".to_string(), Json::str("X"));
                obj.insert("dur".to_string(), Json::num(d as f64 / 1000.0));
            }
            None => {
                obj.insert("ph".to_string(), Json::str("i"));
                obj.insert("s".to_string(), Json::str("t"));
            }
        }
        trace_events.push(Json::Obj(obj));

        let mut line: BTreeMap<String, Json> = BTreeMap::new();
        line.insert("name".to_string(), Json::str(e.name));
        line.insert(
            "kind".to_string(),
            Json::str(if e.dur_ns.is_some() { "span" } else { "event" }),
        );
        line.insert("tid".to_string(), Json::num(*tid as f64));
        line.insert("ts_us".to_string(), Json::num(ts_us));
        if let Some(d) = e.dur_ns {
            line.insert("dur_us".to_string(), Json::num(d as f64 / 1000.0));
        }
        line.insert("depth".to_string(), Json::num(e.depth as f64));
        line.insert("fields".to_string(), Json::Obj(args));
        ndjson.push_str(&Json::Obj(line).dump());
        ndjson.push('\n');
    }

    let doc = Json::obj([
        ("traceEvents", Json::Arr(trace_events)),
        ("displayTimeUnit", Json::str("ms")),
        ("metrics", metrics_snapshot()),
    ]);
    if let Err(e) = std::fs::write(&path, doc.dump()) {
        eprintln!("warning: could not write trace to {}: {e}", path.display());
        return None;
    }
    if let Err(e) = std::fs::write(ndjson_path(&path), ndjson) {
        eprintln!("warning: could not write NDJSON trace sidecar: {e}");
    }
    Some(path)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json;

    /// Enabling tracing is process-global; tests that flip the switch
    /// serialize here so they don't see each other's events.
    fn test_lock() -> &'static Mutex<()> {
        static L: OnceLock<Mutex<()>> = OnceLock::new();
        L.get_or_init(|| Mutex::new(()))
    }

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("greedi_trace_unit_{name}_{}", std::process::id()))
    }

    /// Parse the flushed Chrome trace and keep only events whose name
    /// starts with `prefix` (other suites' events may be interleaved —
    /// tracing is process-global and the test binary is concurrent).
    fn flush_named(prefix: &str) -> Vec<Json> {
        let path = flush().expect("flush with path configured");
        let doc = json::parse(&std::fs::read_to_string(&path).unwrap()).expect("trace parses");
        let evs = doc.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        evs.iter()
            .filter(|e| {
                e.get("name").and_then(|n| n.as_str()).is_some_and(|n| n.starts_with(prefix))
            })
            .cloned()
            .collect()
    }

    #[test]
    fn disabled_span_is_inert() {
        let _l = test_lock().lock().unwrap();
        disable();
        {
            let _g = span("unit.disabled");
            let _h = span_with("unit.disabled.fields", || vec![("x", 1usize.into())]);
            event("unit.disabled.event");
        }
        // no way to observe per-name buffered events without flushing, so
        // assert via the global count delta under the lock
        let before = buffered_events();
        {
            let _g = span("unit.disabled.again");
        }
        assert_eq!(buffered_events(), before, "disabled span must record nothing");
    }

    #[test]
    fn spans_nest_and_export_chrome_trace() {
        let _l = test_lock().lock().unwrap();
        let path = tmp("nest");
        enable(&path);
        {
            let _outer = span_with("unitnest.outer", || vec![("m", 4usize.into())]);
            {
                let _inner = span("unitnest.inner");
            }
            event_with("unitnest.mark", || vec![("e", 7usize.into())]);
        }
        disable();
        let evs = flush_named("unitnest.");
        assert_eq!(evs.len(), 3);
        let by_name = |n: &str| {
            evs.iter()
                .find(|e| e.get("name").and_then(|v| v.as_str()) == Some(n))
                .unwrap_or_else(|| panic!("missing event {n}"))
        };
        let outer = by_name("unitnest.outer");
        let inner = by_name("unitnest.inner");
        let mark = by_name("unitnest.mark");
        assert_eq!(outer.get("ph").and_then(|v| v.as_str()), Some("X"));
        assert_eq!(mark.get("ph").and_then(|v| v.as_str()), Some("i"));
        let depth = |e: &Json| {
            e.get("args").and_then(|a| a.get("depth")).and_then(|v| v.as_f64()).unwrap()
        };
        assert_eq!(depth(outer), 0.0);
        assert_eq!(depth(inner), 1.0);
        assert_eq!(depth(mark), 1.0, "instant inherits current nesting depth");
        assert_eq!(
            outer.get("args").and_then(|a| a.get("m")).and_then(|v| v.as_f64()),
            Some(4.0)
        );
        // inner interval contained in outer interval
        let ts = |e: &Json| e.get("ts").and_then(|v| v.as_f64()).unwrap();
        let dur = |e: &Json| e.get("dur").and_then(|v| v.as_f64()).unwrap();
        assert!(ts(inner) >= ts(outer));
        assert!(ts(inner) + dur(inner) <= ts(outer) + dur(outer) + 1e-9);
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(ndjson_path(&path));
    }

    #[test]
    fn ndjson_sidecar_one_parseable_object_per_line() {
        let _l = test_lock().lock().unwrap();
        let path = tmp("ndjson");
        enable(&path);
        {
            let _g = span("unitnd.a");
            event("unitnd.b");
        }
        disable();
        flush().expect("flush");
        let nd = std::fs::read_to_string(ndjson_path(&path)).unwrap();
        let mut seen = 0;
        for line in nd.lines() {
            let v = json::parse(line).expect("every NDJSON line parses");
            if v.get("name").and_then(|n| n.as_str()).is_some_and(|n| n.starts_with("unitnd.")) {
                seen += 1;
                assert!(v.get("kind").is_some() && v.get("ts_us").is_some());
            }
        }
        assert_eq!(seen, 2);
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(ndjson_path(&path));
    }

    #[test]
    fn counters_gauges_histograms() {
        let c = counter("unit.test.counter");
        let base = c.get();
        c.incr();
        c.add(4);
        assert_eq!(c.get(), base + 5);
        assert!(std::ptr::eq(c, counter("unit.test.counter")), "registry interns by name");

        let g = gauge("unit.test.gauge");
        g.record(3);
        g.record(9);
        g.record(5);
        assert_eq!(g.get(), 9, "gauge keeps the high-water mark");

        let h = histogram("unit.test.hist");
        for v in [0u64, 1, 2, 3, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.sum(), 1006);
        assert_eq!(h.max(), 1000);

        let k = kernel_counters("unit.test.kernel");
        k.gains.add(64);
        k.sharded.incr();
        assert!(std::ptr::eq(k, kernel_counters("unit.test.kernel")));

        let snap = metrics_snapshot();
        assert!(
            snap.get("counters").and_then(|c| c.get("unit.test.counter")).is_some(),
            "snapshot carries registered counters"
        );
        let hist = snap.get("histograms").and_then(|h| h.get("unit.test.hist")).unwrap();
        assert_eq!(hist.get("count").and_then(|v| v.as_f64()), Some(5.0));
        let kern = snap.get("kernels").and_then(|m| m.get("unit.test.kernel")).unwrap();
        assert_eq!(kern.get("gains").and_then(|v| v.as_f64()), Some(64.0));
        // snapshot itself must round-trip through the writer/parser
        let rt = json::parse(&snap.dump()).expect("snapshot round-trips");
        assert_eq!(rt, snap);
    }

    #[test]
    fn trace_counter_macro_caches_site() {
        let a = trace_counter!("unit.test.macro");
        let before = a.get();
        trace_counter!("unit.test.macro").incr();
        assert_eq!(counter("unit.test.macro").get(), before + 1);
    }
}
