//! Summary statistics for experiment series (mean/std bands in the paper's
//! figures, bench reporting).

/// Running summary of a sample.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

/// Compute mean/std (population)/min/max of a slice.
pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    Summary {
        n: xs.len(),
        mean,
        std: var.sqrt(),
        min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
        max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    }
}

/// p-th percentile (nearest-rank, p in [0,100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Mean of a slice (0 if empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.min - 1.0).abs() < 1e-12);
        assert!((s.max - 4.0).abs() < 1e-12);
        assert!((s.std - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_empty() {
        let s = summarize(&[]);
        assert_eq!(s.n, 0);
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..101).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
    }

    #[test]
    fn mean_empty_is_zero() {
        assert_eq!(mean(&[]), 0.0);
    }
}
