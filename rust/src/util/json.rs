//! Minimal JSON parser **and writer** (no `serde` in the offline closure).
//! Full JSON value model — objects, arrays, strings with escapes, numbers,
//! booleans, null — with byte-offset error reporting on the parse side and
//! a deterministic compact serializer ([`write`] / [`Json::dump`]) on the
//! write side. Originally parse-only (the AOT artifact manifest); the
//! `serve::wire` NDJSON protocol made emission a first-class need, and the
//! bench/metrics JSON trails now share the same writer instead of
//! hand-formatting.
//!
//! ## Writer determinism contract
//!
//! * Objects serialize in `BTreeMap` key order — the same document always
//!   produces the same bytes.
//! * Numbers use Rust's shortest-round-trip `Display` for `f64` (never
//!   scientific notation), so `parse(write(v)) == v` **bit-for-bit** for
//!   every finite value, across runs and platforms. Non-finite values
//!   (NaN/±inf) have no JSON spelling and serialize as `null`.
//! * Output is a single line (no interior newlines even in strings —
//!   control characters are `\u` escaped), which is what makes it safe as
//!   one newline-delimited frame on the wire.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// `[1, 2, 3]` -> `vec![1, 2, 3]`.
    pub fn as_usize_arr(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 && x <= u64::MAX as f64 {
                Some(x as u64)
            } else {
                None
            }
        })
    }

    /// Shorthand string constructor.
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Shorthand number constructor (accepts anything convertible to f64).
    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    /// Build an object from `(key, value)` pairs.
    pub fn obj(pairs: impl IntoIterator<Item = (impl Into<String>, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.into(), v)).collect())
    }

    /// Serialize to compact single-line JSON (see the module docs for the
    /// determinism contract). Alias of [`write`].
    pub fn dump(&self) -> String {
        write(self)
    }
}

/// Serialize a [`Json`] value to compact single-line JSON. Deterministic:
/// object keys in `BTreeMap` order, shortest-round-trip number formatting,
/// control characters escaped so the output never contains a newline.
pub fn write(v: &Json) -> String {
    let mut out = String::new();
    write_into(v, &mut out);
    out
}

fn write_into(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(x) => write_num(*x, out),
        Json::Str(s) => write_str(s, out),
        Json::Arr(xs) => {
            out.push('[');
            for (i, x) in xs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_into(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_str(k, out);
                out.push(':');
                write_into(x, out);
            }
            out.push('}');
        }
    }
}

/// f64 → JSON number. Rust's `Display` for f64 is the shortest decimal
/// string that round-trips to the identical bits (and never uses scientific
/// notation), which is exactly the stability the bench trails and the wire
/// protocol need: `parse(write(x)) == x` bit-for-bit for finite `x`, and
/// the same `x` formats identically on every run/platform. NaN and ±inf
/// have no JSON representation and degrade to `null`.
fn write_num(x: f64, out: &mut String) {
    if x.is_finite() {
        out.push_str(&x.to_string());
    } else {
        out.push_str("null");
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            // Non-ASCII passes through as raw UTF-8 (legal JSON; the parser
            // decodes it back losslessly).
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, JsonError> {
        Err(JsonError { offset: self.pos, msg: msg.to_string() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            self.err(&format!("expected {:?}", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            self.err(&format!("expected {word}"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { offset: start, msg: format!("bad number {s:?}") })
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or(JsonError {
                                offset: self.pos,
                                msg: "bad \\u escape".into(),
                            })?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or(JsonError {
                                    offset: self.pos,
                                    msg: "bad hex digit".into(),
                                })?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Multi-byte UTF-8 sequence: the input began life as a
                    // &str, so the bytes are valid — decode the full scalar
                    // instead of mangling each byte into a Latin-1 char.
                    let start = self.pos - 1;
                    let len = match c {
                        b if b >= 0xf0 => 4,
                        b if b >= 0xe0 => 3,
                        _ => 2,
                    };
                    let end = (start + len).min(self.bytes.len());
                    match std::str::from_utf8(&self.bytes[start..end]) {
                        Ok(s) => {
                            out.push_str(s);
                            self.pos = end;
                        }
                        Err(_) => return self.err("invalid utf-8 in string"),
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return self.err("expected , or ]"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return self.err("expected , or }"),
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = parse(
            r#"{
              "format": "hlo-text",
              "entries": [
                {"name": "a", "file": "a.hlo.txt", "inputs": [[64, 8], [1024]], "outputs": [[64]]}
              ]
            }"#,
        )
        .unwrap();
        assert_eq!(doc.get("format").unwrap().as_str(), Some("hlo-text"));
        let entries = doc.get("entries").unwrap().as_arr().unwrap();
        assert_eq!(entries.len(), 1);
        let inputs = entries[0].get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs[0].as_usize_arr(), Some(vec![64, 8]));
        assert_eq!(inputs[1].as_usize_arr(), Some(vec![1024]));
    }

    #[test]
    fn scalars() {
        assert_eq!(parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(parse("-1.5e2").unwrap().as_f64(), Some(-150.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse(r#""hi\nthere""#).unwrap().as_str(), Some("hi\nthere"));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap().as_str(), Some("A"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn errors_reported() {
        assert!(parse("{").is_err());
        assert!(parse("[1, 2,]").is_err());
        assert!(parse(r#"{"a": }"#).is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn nested_arrays() {
        let v = parse("[[1, 2], [3]]").unwrap();
        let outer = v.as_arr().unwrap();
        assert_eq!(outer[0].as_usize_arr(), Some(vec![1, 2]));
        assert_eq!(outer[1].as_usize_arr(), Some(vec![3]));
    }

    #[test]
    fn as_usize_rejects_fraction_and_negative() {
        assert_eq!(parse("1.5").unwrap().as_usize(), None);
        assert_eq!(parse("-3").unwrap().as_usize(), None);
    }

    // ---- writer -----------------------------------------------------------

    /// parse(write(v)) must reproduce v exactly (the wire-protocol
    /// round-trip the serve subsystem depends on).
    fn assert_round_trips(v: &Json) {
        let text = write(v);
        let back = parse(&text).unwrap_or_else(|e| panic!("write produced unparseable {text:?}: {e}"));
        assert_eq!(&back, v, "round trip changed value (text {text:?})");
        // and writing the re-parsed value must be byte-stable
        assert_eq!(write(&back), text, "write not idempotent");
    }

    #[test]
    fn write_scalars() {
        assert_eq!(write(&Json::Null), "null");
        assert_eq!(write(&Json::Bool(true)), "true");
        assert_eq!(write(&Json::Num(3.0)), "3");
        assert_eq!(write(&Json::Num(-1.5)), "-1.5");
        assert_eq!(write(&Json::str("hi")), "\"hi\"");
    }

    #[test]
    fn write_containers_compact_and_ordered() {
        let v = Json::obj([
            ("b", Json::num(2)),
            ("a", Json::Arr(vec![Json::num(1), Json::Null])),
        ]);
        // BTreeMap order: "a" before "b" regardless of insertion order
        assert_eq!(write(&v), r#"{"a":[1,null],"b":2}"#);
        assert_round_trips(&v);
    }

    #[test]
    fn write_escapes_round_trip() {
        for s in [
            "plain",
            "quote\"backslash\\slash/",
            "newline\ntab\tcr\r",
            "ctrl\u{1}\u{1f}",
            "unicode λ λλ — ünïcødé 日本語",
            "",
        ] {
            assert_round_trips(&Json::str(s));
        }
        // escaped output stays single-line (NDJSON framing requirement)
        assert!(!write(&Json::str("a\nb")).contains('\n'));
    }

    #[test]
    fn write_numbers_bit_exact_round_trip() {
        for x in [
            0.0f64,
            -0.0,
            1.0,
            -1.0,
            0.1,
            1.0 / 3.0,
            6.02214076e23,
            1e-12,
            f64::MAX,
            f64::MIN_POSITIVE,
            123456789.123456789,
            (u64::MAX as f64),
        ] {
            let text = write(&Json::Num(x));
            let back = parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x:e} -> {text} -> {back:e}");
        }
    }

    #[test]
    fn write_nonfinite_degrades_to_null() {
        assert_eq!(write(&Json::Num(f64::NAN)), "null");
        assert_eq!(write(&Json::Num(f64::INFINITY)), "null");
        assert_eq!(write(&Json::Num(f64::NEG_INFINITY)), "null");
    }

    #[test]
    fn deep_structure_round_trips() {
        let v = Json::obj([
            ("solution", Json::Arr((0..20).map(|i| Json::num(i as f64)).collect())),
            ("value", Json::num(123.456789012345)),
            (
                "nested",
                Json::obj([
                    ("label", Json::str("greedi \"v1\"\n")),
                    ("flags", Json::Arr(vec![Json::Bool(false), Json::Null])),
                ]),
            ),
        ]);
        assert_round_trips(&v);
    }

    /// Seeded pseudo-random documents: the property-test style sweep for the
    /// parse↔write contract (deterministic, no external prop-test crate).
    #[test]
    fn random_documents_round_trip() {
        let mut rng = crate::util::rng::Rng::new(0xC0FFEE);
        for _ in 0..200 {
            let v = random_json(&mut rng, 3);
            assert_round_trips(&v);
        }
    }

    fn random_json(rng: &mut crate::util::rng::Rng, depth: usize) -> Json {
        let choice = rng.below(if depth == 0 { 4 } else { 6 });
        match choice {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => {
                // mix of integral, fractional, large and tiny magnitudes
                let mag = [1.0, 1e-6, 1e6, 1e12][rng.below(4)];
                let sign = if rng.below(2) == 0 { 1.0 } else { -1.0 };
                Json::Num(sign * mag * (rng.below(1_000_000) as f64) / 997.0)
            }
            3 => {
                let alphabet = ['a', 'Z', '0', '"', '\\', '\n', '\t', 'λ', '素', ' '];
                let len = rng.below(12);
                Json::Str((0..len).map(|_| alphabet[rng.below(alphabet.len())]).collect())
            }
            4 => Json::Arr((0..rng.below(5)).map(|_| random_json(rng, depth - 1)).collect()),
            _ => Json::obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect::<Vec<_>>(),
            ),
        }
    }

    #[test]
    fn parser_decodes_raw_utf8() {
        // multi-byte chars arrive as raw UTF-8 on the wire; the parser must
        // decode them losslessly (it used to mangle bytes into Latin-1)
        assert_eq!(parse("\"λ 日本\"").unwrap().as_str(), Some("λ 日本"));
    }
}
