//! Minimal JSON parser for the AOT artifact manifest (no `serde` in the
//! offline closure). Full JSON value model — objects, arrays, strings with
//! escapes, numbers, booleans, null — with line/column error reporting.
//! Parsing only; the crate never needs to emit JSON.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    /// `[1, 2, 3]` -> `vec![1, 2, 3]`.
    pub fn as_usize_arr(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: &str) -> Result<T, JsonError> {
        Err(JsonError { offset: self.pos, msg: msg.to_string() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos -= usize::from(self.pos > 0);
            self.err(&format!("expected {:?}", c as char))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => self.err("unexpected character"),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            self.err(&format!("expected {word}"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { offset: start, msg: format!("bad number {s:?}") })
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or(JsonError {
                                offset: self.pos,
                                msg: "bad \\u escape".into(),
                            })?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or(JsonError {
                                    offset: self.pos,
                                    msg: "bad hex digit".into(),
                                })?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return self.err("bad escape"),
                },
                Some(c) => out.push(c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return self.err("expected , or ]"),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return self.err("expected , or }"),
            }
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let doc = parse(
            r#"{
              "format": "hlo-text",
              "entries": [
                {"name": "a", "file": "a.hlo.txt", "inputs": [[64, 8], [1024]], "outputs": [[64]]}
              ]
            }"#,
        )
        .unwrap();
        assert_eq!(doc.get("format").unwrap().as_str(), Some("hlo-text"));
        let entries = doc.get("entries").unwrap().as_arr().unwrap();
        assert_eq!(entries.len(), 1);
        let inputs = entries[0].get("inputs").unwrap().as_arr().unwrap();
        assert_eq!(inputs[0].as_usize_arr(), Some(vec![64, 8]));
        assert_eq!(inputs[1].as_usize_arr(), Some(vec![1024]));
    }

    #[test]
    fn scalars() {
        assert_eq!(parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(parse("-1.5e2").unwrap().as_f64(), Some(-150.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse(r#""hi\nthere""#).unwrap().as_str(), Some("hi\nthere"));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap().as_str(), Some("A"));
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn errors_reported() {
        assert!(parse("{").is_err());
        assert!(parse("[1, 2,]").is_err());
        assert!(parse(r#"{"a": }"#).is_err());
        assert!(parse("1 2").is_err());
    }

    #[test]
    fn nested_arrays() {
        let v = parse("[[1, 2], [3]]").unwrap();
        let outer = v.as_arr().unwrap();
        assert_eq!(outer[0].as_usize_arr(), Some(vec![1, 2]));
        assert_eq!(outer[1].as_usize_arr(), Some(vec![3]));
    }

    #[test]
    fn as_usize_rejects_fraction_and_negative() {
        assert_eq!(parse("1.5").unwrap().as_usize(), None);
        assert_eq!(parse("-3").unwrap().as_usize(), None);
    }
}
