//! Minimal `anyhow`-compatible error plumbing (the offline dependency
//! closure has no `anyhow`; these four names — [`Error`], [`Result`],
//! [`Context`], and the `anyhow!`/`bail!` macros — cover every use in the
//! crate, so the default build needs zero external dependencies).

use std::fmt;

/// String-backed error value (the `anyhow::Error` stand-in).
///
/// Deliberately does NOT implement `std::error::Error`, so the blanket
/// `From<E: std::error::Error>` below cannot conflict with the reflexive
/// `From<T> for T` — the same trick `anyhow` itself uses.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(m: impl fmt::Display) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(&e)
    }
}

/// `Result` defaulting its error type to [`Error`], like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to any displayable error, like `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

/// `anyhow!("...")` — build an [`Error`] from a format string.
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// `bail!("...")` — early-return an `Err` from a format string.
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

pub(crate) use anyhow;
pub(crate) use bail;

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")
            .with_context(|| "reading config".to_string())?;
        Ok(s)
    }

    fn bails(x: i32) -> Result<i32> {
        if x < 0 {
            bail!("negative input {x}");
        }
        Ok(x)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(e.to_string().starts_with("reading config:"), "{e}");
    }

    #[test]
    fn context_wraps_message() {
        let r: std::result::Result<(), &str> = Err("boom");
        let e = r.context("stage 2").unwrap_err();
        assert_eq!(e.to_string(), "stage 2: boom");
    }

    #[test]
    fn anyhow_and_bail_macros() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(format!("{e}"), "bad value 7");
        assert_eq!(format!("{e:?}"), "bad value 7");
        assert!(bails(-1).is_err());
        assert_eq!(bails(3).unwrap(), 3);
    }
}
