//! Wallclock timing helpers used by the simulated-cluster clock and the
//! micro-benchmark harness.

use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug, Clone)]
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Time a closure, returning `(result, seconds)`.
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let out = f();
    (out, t.elapsed_secs())
}

/// Human-readable duration (`1.23 s`, `45.6 ms`, `789 µs`).
pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else {
        format!("{:.1} µs", secs * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        let a = t.elapsed_secs();
        let b = t.elapsed_secs();
        assert!(b >= a);
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 42);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_duration(2.5).ends_with(" s"));
        assert!(fmt_duration(0.002).ends_with(" ms"));
        assert!(fmt_duration(0.000002).ends_with(" µs"));
    }
}
