//! Persistent work-stealing oracle executor (perf pass §B).
//!
//! Every parallel surface in the crate — the sharded gain engine
//! (`objective::engine::ShardedGainEngine`, serving every objective's
//! `State::par_batch_gains`), `MapReduce::run_stage{,_faulted}` (and
//! through it all nine protocols), the `stream::sieve` batch pricing and
//! `LazyGreedy`'s batch repricing — used to fan out through
//! `util::threadpool::parallel_map`, which spawned **scoped OS threads per
//! batch**. Thread launch costs ~10 µs, paid once per greedy round × per
//! reprice block × per sieve batch, and that launch floor bounded the
//! speedup on small windows no matter how fast the kernel got (ROADMAP
//! "Persistent oracle pool").
//!
//! This module replaces the per-batch spawn model with **one long-lived
//! pool of parked workers**:
//!
//! * **Per-worker deques + stealing.** Each worker owns a deque; submission
//!   round-robins across deques; a worker pops its own deque LIFO (cache
//!   locality) and steals FIFO from the others in a fixed scan order.
//!   Idle workers park on a condvar and are woken per submitted task, so an
//!   idle pool costs nothing between protocol runs.
//! * **Scoped submission.** [`Executor::scope`] mirrors `std::thread::scope`:
//!   tasks may borrow the caller's stack (gain shards reference the packed
//!   dataset window), and `scope` does not return until every spawned task
//!   has finished, which is what makes the lifetime erasure sound.
//! * **Helping waiters, so nesting cannot deadlock.** A thread blocked in
//!   `scope` does not sleep while its own tasks sit in a queue — it pops
//!   and runs them itself. Protocol map tasks therefore may open nested
//!   gain scopes (map stage × oracle threads) on a bounded pool: every
//!   blocked waiter makes progress on exactly the work it is waiting for,
//!   by induction down the nesting depth no cycle of waits can starve.
//! * **Deterministic panic surfacing.** The *first* panic (first in item
//!   order on the serial path, first observed under real concurrency) is
//!   captured; remaining queued work of the failing scope is drained
//!   without running (cancellation), later panics are swallowed, and the
//!   captured payload is re-raised on the caller once the scope has fully
//!   quiesced. A panicking task never kills a pool worker.
//!
//! ## Determinism contract
//!
//! [`parallel_map`] returns results in input order and every item is mapped
//! by a pure function, so outputs are identical to the serial map at any
//! worker count — the same contract the scoped-spawn implementation had.
//! Work *placement* (which worker runs which item) is nondeterministic;
//! nothing in this crate may let placement leak into results. Shard
//! boundaries come from [`shard_ranges`], a pure function of the length, and
//! reductions happen in shard order on the caller. (The facility kernel's
//! SIMD dispatch adds one caveat one layer down: see
//! `objective::facility` — values are bit-identical across thread counts
//! *per dispatch path*, and the path is fixed per process.)
//!
//! ## Sizing and escape hatches
//!
//! The global pool ([`Executor::global`]) is lazily created on first
//! parallel call, sized by `GREEDI_POOL_THREADS` if set, else
//! `available_parallelism`. Call-site `threads` arguments (from
//! `RunSpec::threads` / `RunSpec::oracle_threads`) bound the *concurrency of
//! that call* (how many runner tasks are submitted), not the pool size — the
//! pool is the machine-wide resource, the spec is the per-stage budget, and
//! oversubscription is impossible because tasks multiplex onto the fixed
//! workers. `threads <= 1` never touches the pool (inline serial execution,
//! exact timings for the MapReduce accounting), and
//! `GREEDI_EXECUTOR_SERIAL=1` forces that serial path process-wide — the
//! test/debug escape hatch.
//!
//! Follow-on (ROADMAP): NUMA pinning now has a natural home — pin each
//! worker thread to the socket whose memory holds its shard of the packed
//! window at pool construction.

use std::collections::VecDeque;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread;
use std::time::Duration;

/// Total worker threads ever spawned by any [`Executor`] in this process —
/// the reuse tests assert this stays flat across back-to-back protocol runs
/// (a leaking pool would re-spawn workers per run).
static SPAWNED_WORKERS: AtomicUsize = AtomicUsize::new(0);

/// One queued unit of work: the lifetime-erased closure plus the scope it
/// belongs to (helpers filter by scope identity).
struct Task {
    scope: Arc<ScopeState>,
    run: Box<dyn FnOnce() + Send + 'static>,
}

/// Bookkeeping for one [`Executor::scope`] invocation.
struct ScopeState {
    /// Tasks spawned and not yet finished (guarded: condvar partner).
    remaining: Mutex<usize>,
    done: Condvar,
    /// Set by the first panicking task; cancelled tasks skip their closure
    /// but still count down `remaining`.
    cancelled: AtomicBool,
    /// First panic payload (first-wins under the lock).
    panic: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

impl ScopeState {
    fn new() -> Self {
        ScopeState {
            remaining: Mutex::new(0),
            done: Condvar::new(),
            cancelled: AtomicBool::new(false),
            panic: Mutex::new(None),
        }
    }
}

/// Shared pool state.
struct Inner {
    /// Per-worker deques (owner pops back, thieves pop front).
    deques: Vec<Mutex<VecDeque<Task>>>,
    /// Tasks currently queued (≥ actual, transiently) — parking gate.
    queued: AtomicUsize,
    /// Round-robin submission cursor.
    rr: AtomicUsize,
    park: Mutex<()>,
    alarm: Condvar,
    shutdown: AtomicBool,
}

impl Inner {
    fn submit(&self, task: Task) {
        let i = self.rr.fetch_add(1, Ordering::Relaxed) % self.deques.len();
        // Increment BEFORE the push: workers treat `queued == 0` as "safe to
        // park", so the counter must never under-report. (It may transiently
        // over-report between this increment and the push — a worker that
        // races in just re-scans.)
        let depth = self.queued.fetch_add(1, Ordering::Release) + 1;
        crate::trace_counter!("executor.submitted").incr();
        crate::trace_gauge!("executor.queue_depth_max").record(depth as u64);
        self.deques[i].lock().unwrap().push_back(task);
        let _g = self.park.lock().unwrap();
        self.alarm.notify_one();
    }

    /// Pop the back of worker `idx`'s own deque.
    fn pop_own(&self, idx: usize) -> Option<Task> {
        let task = self.deques[idx].lock().unwrap().pop_back();
        if task.is_some() {
            self.queued.fetch_sub(1, Ordering::Release);
        }
        task
    }

    /// Steal the front of someone else's deque, scanning from `idx + 1` in
    /// a fixed wrap-around order.
    fn steal(&self, idx: usize) -> Option<Task> {
        let n = self.deques.len();
        for off in 1..n {
            let j = (idx + off) % n;
            let task = self.deques[j].lock().unwrap().pop_front();
            if task.is_some() {
                self.queued.fetch_sub(1, Ordering::Release);
                crate::trace_counter!("executor.stolen").incr();
                return task;
            }
        }
        None
    }

    /// Remove one queued task belonging to `scope` (helping waiter path).
    fn take_scope_task(&self, scope: &Arc<ScopeState>) -> Option<Task> {
        for dq in &self.deques {
            let mut q = dq.lock().unwrap();
            if let Some(pos) = q.iter().position(|t| Arc::ptr_eq(&t.scope, scope)) {
                let task = q.remove(pos);
                drop(q);
                if task.is_some() {
                    self.queued.fetch_sub(1, Ordering::Release);
                }
                return task;
            }
        }
        None
    }

    fn worker_loop(self: Arc<Self>, idx: usize) {
        loop {
            if let Some(task) = self.pop_own(idx).or_else(|| self.steal(idx)) {
                // The closure does its own catch_unwind; a task panic can
                // never unwind through (and kill) a pool worker.
                (task.run)();
                continue;
            }
            let guard = self.park.lock().unwrap();
            if self.shutdown.load(Ordering::Acquire) {
                return;
            }
            if self.queued.load(Ordering::Acquire) == 0 {
                // Park. The timeout is a belt-and-braces backstop only; the
                // queued-counter handshake above already prevents lost
                // wakeups (submitters notify under the same lock).
                crate::trace_counter!("executor.parked").incr();
                let _ = self
                    .alarm
                    .wait_timeout(guard, Duration::from_millis(50))
                    .unwrap();
            }
        }
    }
}

/// A persistent pool of parked worker threads with per-worker deques and
/// work stealing. See the module docs for the full design.
pub struct Executor {
    inner: Arc<Inner>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl Executor {
    /// Create a pool with `workers` threads (min 1).
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let inner = Arc::new(Inner {
            deques: (0..workers).map(|_| Mutex::new(VecDeque::new())).collect(),
            queued: AtomicUsize::new(0),
            rr: AtomicUsize::new(0),
            park: Mutex::new(()),
            alarm: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let handles = (0..workers)
            .map(|i| {
                let inner = Arc::clone(&inner);
                SPAWNED_WORKERS.fetch_add(1, Ordering::Relaxed);
                thread::Builder::new()
                    .name(format!("greedi-exec-{i}"))
                    .spawn(move || inner.worker_loop(i))
                    .expect("spawn executor worker")
            })
            .collect();
        Executor { inner, handles }
    }

    /// The process-wide pool, lazily created on first use: sized by
    /// `GREEDI_POOL_THREADS` if set, else `available_parallelism`. Every
    /// `parallel_map`/`parallel_gains` call multiplexes onto this one pool,
    /// so back-to-back protocol runs reuse the same parked workers.
    pub fn global() -> &'static Executor {
        static GLOBAL: OnceLock<Executor> = OnceLock::new();
        GLOBAL.get_or_init(|| {
            let n = std::env::var("GREEDI_POOL_THREADS")
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .filter(|&n| n > 0)
                .unwrap_or_else(|| {
                    thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
                });
            Executor::new(n)
        })
    }

    /// Worker threads in this pool.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// Total worker threads ever spawned by executors in this process
    /// (monotone; flat across runs ⇔ the pool is being reused, not leaked).
    pub fn total_spawned_workers() -> usize {
        SPAWNED_WORKERS.load(Ordering::Relaxed)
    }

    /// Run `f` with a [`Scope`] on which tasks borrowing the caller's stack
    /// may be spawned. Does not return until every spawned task finished.
    /// If `f` itself panics, its panic is re-raised after the tasks
    /// quiesce; otherwise the first task panic (if any) is re-raised.
    pub fn scope<'env, F, R>(&'env self, f: F) -> R
    where
        F: for<'scope> FnOnce(&'scope Scope<'scope, 'env>) -> R,
    {
        let state = Arc::new(ScopeState::new());
        let scope = Scope {
            exec: self,
            state: Arc::clone(&state),
            _scope: PhantomData,
            _env: PhantomData,
        };
        let result = catch_unwind(AssertUnwindSafe(|| f(&scope)));
        self.wait_scope(&state);
        match result {
            Err(payload) => resume_unwind(payload),
            Ok(r) => {
                let first = state.panic.lock().unwrap().take();
                if let Some(payload) = first {
                    resume_unwind(payload);
                }
                r
            }
        }
    }

    /// Block until `scope` has no unfinished tasks, HELPING while blocked:
    /// queued tasks of this scope are popped and run on the waiting thread.
    /// This is what makes nested scopes on a bounded pool deadlock-free —
    /// and it means `scope` works even with zero free workers.
    fn wait_scope(&self, state: &Arc<ScopeState>) {
        loop {
            if let Some(task) = self.inner.take_scope_task(state) {
                (task.run)();
                continue;
            }
            let guard = state.remaining.lock().unwrap();
            if *guard == 0 {
                return;
            }
            // All of this scope's tasks are in flight on workers; sleep
            // until one finishes (finishers notify under `remaining`'s
            // lock, so this cannot miss the last decrement).
            let (guard, _) = state
                .done
                .wait_timeout(guard, Duration::from_millis(10))
                .unwrap();
            drop(guard);
        }
    }
}

impl Drop for Executor {
    fn drop(&mut self) {
        self.inner.shutdown.store(true, Ordering::Release);
        {
            let _g = self.inner.park.lock().unwrap();
            self.inner.alarm.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Handle for spawning borrowing tasks inside [`Executor::scope`].
///
/// Mirrors `std::thread::Scope`: `'scope` is the scope's own lifetime,
/// `'env` the environment it may borrow from. Spawn only from within the
/// scope closure itself (tasks spawning onto their own scope is not
/// supported — every call site in this crate submits its fan-out up front).
pub struct Scope<'scope, 'env: 'scope> {
    exec: &'env Executor,
    state: Arc<ScopeState>,
    _scope: PhantomData<&'scope mut &'scope ()>,
    _env: PhantomData<&'env mut &'env ()>,
}

impl<'scope, 'env> Scope<'scope, 'env> {
    /// Submit a task that may borrow `'scope` data. Panics inside `f` are
    /// captured (first one wins) and re-raised when the scope closes; a
    /// panic also cancels this scope's still-queued tasks (drained without
    /// running, deterministic bookkeeping).
    pub fn spawn<F>(&self, f: F)
    where
        F: FnOnce() + Send + 'scope,
    {
        *self.state.remaining.lock().unwrap() += 1;
        let state = Arc::clone(&self.state);
        let wrapped: Box<dyn FnOnce() + Send + 'scope> = Box::new(move || {
            if !state.cancelled.load(Ordering::Acquire) {
                if let Err(payload) = catch_unwind(AssertUnwindSafe(f)) {
                    state.cancelled.store(true, Ordering::Release);
                    let mut slot = state.panic.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(payload);
                    }
                }
            }
            let mut g = state.remaining.lock().unwrap();
            *g -= 1;
            if *g == 0 {
                state.done.notify_all();
            }
        });
        // SAFETY: `Executor::scope` blocks in `wait_scope` until `remaining`
        // reaches zero before returning (on the panic path too), so this
        // closure — and everything it borrows from `'scope`/`'env` — is
        // guaranteed to have finished running before those borrows expire.
        // This is the same argument `std::thread::scope` makes; only the
        // execution vehicle (pool task vs OS thread) differs.
        let wrapped: Box<dyn FnOnce() + Send + 'static> =
            unsafe { std::mem::transmute(wrapped) };
        self.exec.inner.submit(Task { scope: Arc::clone(&self.state), run: wrapped });
    }
}

/// Best-effort human-readable text from a caught panic payload.
fn panic_message(payload: &Box<dyn std::any::Any + Send>) -> String {
    payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_else(|| "task panicked".into())
}

/// `GREEDI_EXECUTOR_SERIAL=1` forces every [`parallel_map`]/
/// [`parallel_gains`] call onto the inline serial path (no pool, no worker
/// threads) — the explicit escape hatch for tests and debugging. Read once
/// and cached for the life of the process.
pub fn serial_forced() -> bool {
    static SERIAL: OnceLock<bool> = OnceLock::new();
    *SERIAL.get_or_init(|| {
        std::env::var("GREEDI_EXECUTOR_SERIAL").ok().as_deref() == Some("1")
    })
}

/// Split `0..len` into `parts` contiguous near-equal ranges (longer ranges
/// first), clamped to at most `len` non-empty parts. Deterministic: the
/// boundaries depend only on `(len, parts)` — the parallel gain engine
/// relies on this to reduce per-shard partial sums in a fixed order no
/// matter how many workers execute the shards.
pub fn shard_ranges(len: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    let parts = parts.max(1).min(len.max(1));
    let base = len / parts;
    let extra = len % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0usize;
    for i in 0..parts {
        let size = base + usize::from(i < extra);
        out.push(start..start + size);
        start += size;
    }
    out
}

/// Candidate-count floor below which [`parallel_gains`] stays serial: when
/// each candidate's pricing touches only a few cache lines (coverage's one
/// transaction, cut's one adjacency list), fan-out only pays off for wide
/// batches.
pub const MIN_PAR_CANDIDATES: usize = 64;

/// Price every candidate id in `es` through `f`, sharding the *candidate
/// list* across up to `threads` runner tasks once it is at least
/// [`MIN_PAR_CANDIDATES`] long. `f` must be a pure function of the
/// candidate (given the caller's frozen state), so the output equals the
/// serial map bit-for-bit at any thread count. (Pre-refactor this was the
/// fan-out behind the coverage/cut `par_batch_gains`; objectives now route
/// through `objective::engine::ShardedGainEngine`, which owns its own
/// candidate sharding — this helper stays as a general-purpose utility.)
pub fn parallel_gains<F>(es: &[usize], threads: usize, f: F) -> Vec<f64>
where
    F: Fn(usize) -> f64 + Sync,
{
    if threads <= 1 || es.len() < MIN_PAR_CANDIDATES {
        return es.iter().map(|&e| f(e)).collect();
    }
    let ranges = shard_ranges(es.len(), threads);
    parallel_map(ranges, threads, |_, r| {
        es[r].iter().map(|&e| f(e)).collect::<Vec<f64>>()
    })
    .into_iter()
    .flatten()
    .collect()
}

/// Run `f` over `items` on the process-wide [`Executor`], returning results
/// in input order. At most `workers` items are in flight at once (the
/// stage's thread budget); `workers <= 1`, a single item, or
/// [`serial_forced`] short-circuit to inline serial execution. Panics in
/// any task cancel the remaining queued items (drained, never run) and the
/// *first* panic is re-raised on the caller — deterministically the
/// lowest-index item's panic on the serial path, the first observed one
/// under real concurrency; later panics are swallowed, and the pool's
/// workers survive to serve the next call.
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 || n == 1 || serial_forced() {
        // Same panic contract as the pooled path (one wrapped message), and
        // trivially the lowest-index panic: serial execution stops at the
        // first failing item.
        return match catch_unwind(AssertUnwindSafe(|| {
            items.into_iter().enumerate().map(|(i, x)| f(i, x)).collect::<Vec<R>>()
        })) {
            Ok(out) => out,
            Err(payload) => {
                panic!("parallel_map task panicked: {}", panic_message(&payload))
            }
        };
    }

    let work: Mutex<std::vec::IntoIter<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().collect::<Vec<_>>().into_iter());
    let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let slots: Vec<Mutex<&mut Option<R>>> =
        results.iter_mut().map(Mutex::new).collect();
    let cancelled = AtomicBool::new(false);
    let first_panic: Mutex<Option<Box<dyn std::any::Any + Send>>> = Mutex::new(None);

    // Each runner drains the shared work list item by item. Per-ITEM
    // catch_unwind (not per-runner) is what fixes the old panic path: a
    // panic records the payload (first wins), flips `cancelled`, and every
    // runner stops pulling new items — queued work is abandoned
    // deterministically instead of racing a half-poisoned slot array.
    let runner = || loop {
        if cancelled.load(Ordering::Acquire) {
            break;
        }
        let next = { work.lock().unwrap().next() };
        let Some((idx, item)) = next else { break };
        match catch_unwind(AssertUnwindSafe(|| f(idx, item))) {
            Ok(r) => {
                **slots[idx].lock().unwrap() = Some(r);
            }
            Err(payload) => {
                {
                    let mut slot = first_panic.lock().unwrap();
                    if slot.is_none() {
                        *slot = Some(payload);
                    }
                }
                cancelled.store(true, Ordering::Release);
                break;
            }
        }
    };

    Executor::global().scope(|s| {
        for _ in 0..workers {
            s.spawn(&runner);
        }
    });

    if let Some(payload) = first_panic.into_inner().unwrap() {
        panic!("parallel_map task panicked: {}", panic_message(&payload));
    }
    drop(slots);
    results
        .into_iter()
        .map(|r| r.expect("task did not complete"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn scope_runs_borrowing_tasks() {
        let exec = Executor::new(3);
        let data = vec![1.0f64; 128];
        let sums: Vec<Mutex<f64>> = (0..8).map(|_| Mutex::new(0.0)).collect();
        exec.scope(|s| {
            for slot in &sums {
                s.spawn(|| {
                    *slot.lock().unwrap() = data.iter().sum::<f64>();
                });
            }
        });
        for slot in &sums {
            assert!((*slot.lock().unwrap() - 128.0).abs() < 1e-12);
        }
    }

    #[test]
    fn scope_returns_closure_value() {
        let exec = Executor::new(2);
        let out = exec.scope(|s| {
            s.spawn(|| {});
            41 + 1
        });
        assert_eq!(out, 42);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map((0..1000).collect(), 8, |_, x: i32| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn parallel_map_borrows_environment() {
        let data = vec![1.0f64; 100];
        let sums = parallel_map(vec![0usize, 1, 2, 3], 2, |_, _| data.iter().sum::<f64>());
        assert!(sums.iter().all(|&s| (s - 100.0).abs() < 1e-12));
    }

    #[test]
    fn parallel_map_serial_path_matches() {
        let par = parallel_map((0..100).collect(), 4, |i, x: i32| x * 3 + i as i32);
        let ser = parallel_map((0..100).collect(), 1, |i, x: i32| x * 3 + i as i32);
        assert_eq!(par, ser);
    }

    #[test]
    #[should_panic(expected = "parallel_map task panicked")]
    fn parallel_map_propagates_panic() {
        parallel_map(vec![1, 2, 3], 2, |_, x: i32| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn parallel_map_serial_surfaces_first_panic_by_index() {
        let err = std::panic::catch_unwind(|| {
            parallel_map((0..8).collect(), 1, |i, _x: i32| -> i32 {
                panic!("boom-{i}");
            })
        })
        .expect_err("must panic");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("boom-0"), "serial path must surface item 0's panic, got {msg}");
    }

    #[test]
    fn parallel_map_every_item_panicking_surfaces_exactly_one() {
        // The old scoped implementation could overwrite the recorded panic
        // with a later one and, with unlucky interleaving, lose the message
        // entirely. Now: exactly one payload, always a real task message.
        let err = std::panic::catch_unwind(|| {
            parallel_map((0..64).collect(), 8, |i, _x: i32| -> i32 {
                panic!("boom-{i}");
            })
        })
        .expect_err("must panic");
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        assert!(
            msg.contains("parallel_map task panicked: boom-"),
            "panic message lost: {msg}"
        );
    }

    #[test]
    fn pool_survives_task_panics() {
        // A panicking task must neither kill its worker nor poison the pool.
        // (The global pool's worker-count-flat-across-runs assertion lives in
        // tests/integration_executor.rs, where no local pools run alongside.)
        let exec = Executor::new(2);
        let err = std::panic::catch_unwind(AssertUnwindSafe(|| {
            exec.scope(|s| {
                s.spawn(|| panic!("kaboom"));
            })
        }));
        assert!(err.is_err(), "scope must re-raise the task panic");
        let counter = AtomicUsize::new(0);
        exec.scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    counter.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        assert_eq!(counter.load(Ordering::SeqCst), 8, "pool must keep serving");
        assert_eq!(exec.workers(), 2);
    }

    #[test]
    fn nested_parallel_map_completes() {
        // Map tasks opening nested gain scopes is the protocol shape
        // (map stage × oracle threads); helping waiters make it safe on a
        // bounded pool.
        let out = parallel_map((0..6).collect(), 4, |_, x: i32| {
            parallel_map((0..6).collect(), 4, |_, y: i32| x * 10 + y)
                .into_iter()
                .sum::<i32>()
        });
        let expect: Vec<i32> = (0..6).map(|x| (0..6).map(|y| x * 10 + y).sum()).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn scope_works_on_tiny_local_pool() {
        // Even a 1-worker pool must serve nested scopes (the owner helps).
        let exec = Executor::new(1);
        let counter = AtomicUsize::new(0);
        exec.scope(|s| {
            for _ in 0..16 {
                s.spawn(|| {
                    counter.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        assert_eq!(counter.load(Ordering::SeqCst), 16);
        assert_eq!(exec.workers(), 1);
    }

    #[test]
    fn local_executor_drop_joins_workers() {
        let exec = Executor::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        exec.scope(|s| {
            for _ in 0..50 {
                let c = Arc::clone(&counter);
                s.spawn(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        drop(exec); // joins without hanging
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn shard_ranges_cover_exactly_once() {
        for (len, parts) in [(0usize, 4usize), (1, 4), (7, 3), (100, 8), (8, 8), (5, 16)] {
            let ranges = shard_ranges(len, parts);
            assert!(ranges.len() <= parts.max(1));
            let mut next = 0usize;
            for r in &ranges {
                assert_eq!(r.start, next, "gap at {r:?} (len={len}, parts={parts})");
                next = r.end;
            }
            assert_eq!(next, len, "ranges must cover 0..{len}");
        }
    }

    #[test]
    fn shard_ranges_deterministic_and_balanced() {
        let a = shard_ranges(1000, 7);
        let b = shard_ranges(1000, 7);
        assert_eq!(a, b);
        let sizes: Vec<usize> = a.iter().map(|r| r.end - r.start).collect();
        let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(max - min <= 1, "near-equal shards, got {sizes:?}");
    }

    #[test]
    fn parallel_gains_matches_serial_map_any_threads() {
        let es: Vec<usize> = (0..500).collect();
        let f = |e: usize| (e as f64).sqrt() * 3.0 - 1.0;
        let serial: Vec<f64> = es.iter().map(|&e| f(e)).collect();
        for threads in [1usize, 2, 5, 16] {
            assert_eq!(serial, parallel_gains(&es, threads, f), "threads={threads}");
        }
        // short batches stay serial but still produce the same values
        let short: Vec<usize> = (0..10).collect();
        let expect: Vec<f64> = short.iter().map(|&e| f(e)).collect();
        assert_eq!(expect, parallel_gains(&short, 8, f));
    }

    #[test]
    fn executor_min_one_worker() {
        let exec = Executor::new(0);
        assert_eq!(exec.workers(), 1);
    }
}
