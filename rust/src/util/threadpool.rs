//! Compatibility facade over [`util::executor`](super::executor).
//!
//! Historically this module owned the crate's parallelism: a
//! channel-of-boxed-closures `ThreadPool` plus a `parallel_map` that spawned
//! **scoped OS threads per batch**. The per-batch spawn cost (~10 µs) was
//! paid once per greedy round × per reprice block × per sieve batch and
//! bounded the speedup on small windows, so the whole surface moved to the
//! persistent work-stealing [`Executor`](super::executor::Executor) — parked
//! workers, per-worker deques + stealing, scoped borrowing submission,
//! deterministic first-panic propagation.
//!
//! The names below are re-exports so existing call sites and downstream
//! users keep compiling; new code should import from `util::executor`
//! directly. Semantics are unchanged: input-order results, bit-identical
//! outputs at any thread count, panics re-raised on the caller (see the
//! executor module docs for the determinism contract and the pool
//! lifecycle).

pub use super::executor::{
    parallel_gains, parallel_map, serial_forced, shard_ranges, Executor,
    MIN_PAR_CANDIDATES,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn facade_reexports_are_live() {
        // One smoke assertion per re-export family so a facade regression
        // (e.g. dropping a name) fails here, closest to the contract.
        let out = parallel_map((0..100).collect(), 4, |_, x: i32| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
        let es: Vec<usize> = (0..MIN_PAR_CANDIDATES * 2).collect();
        let gains = parallel_gains(&es, 4, |e| e as f64);
        assert_eq!(gains.len(), es.len());
        assert_eq!(shard_ranges(10, 3).len(), 3);
        assert!(Executor::global().workers() >= 1);
        let _ = serial_forced();
    }
}
