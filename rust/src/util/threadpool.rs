//! Fixed-size worker thread pool (no tokio in the offline closure).
//!
//! The simulated MapReduce engine runs map/reduce tasks on this pool. The
//! design is the classic channel-of-boxed-closures worker pool plus a scoped
//! `parallel_map` helper that preserves input order and propagates panics.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads executing boxed jobs.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    sender: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    /// Create a pool with `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                thread::Builder::new()
                    .name(format!("greedi-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { workers, sender: Some(sender) }
    }

    /// Pool sized to the machine (`available_parallelism`, >= 1).
    pub fn default_size() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(n)
    }

    /// Submit a fire-and-forget job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("worker channel closed");
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take()); // close channel => workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Split `0..len` into `parts` contiguous near-equal ranges (longer ranges
/// first), clamped to at most `len` non-empty parts. Deterministic: the
/// boundaries depend only on `(len, parts)` — the parallel gain engine
/// relies on this to reduce per-shard partial sums in a fixed order no
/// matter how many workers execute the shards.
pub fn shard_ranges(len: usize, parts: usize) -> Vec<std::ops::Range<usize>> {
    let parts = parts.max(1).min(len.max(1));
    let base = len / parts;
    let extra = len % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0usize;
    for i in 0..parts {
        let size = base + usize::from(i < extra);
        out.push(start..start + size);
        start += size;
    }
    out
}

/// Candidate-count floor below which [`parallel_gains`] stays serial: when
/// each candidate's pricing touches only a few cache lines (coverage's one
/// transaction, cut's one adjacency list), fan-out only pays off for wide
/// batches.
pub const MIN_PAR_CANDIDATES: usize = 64;

/// Price every candidate id in `es` through `f`, sharding the *candidate
/// list* across up to `threads` workers once it is at least
/// [`MIN_PAR_CANDIDATES`] long. `f` must be a pure function of the
/// candidate (given the caller's frozen state), so the output equals the
/// serial map bit-for-bit at any thread count. This is the shared engine
/// behind the coverage and cut `State::par_batch_gains` implementations —
/// objectives whose per-candidate work has no window to shard.
pub fn parallel_gains<F>(es: &[usize], threads: usize, f: F) -> Vec<f64>
where
    F: Fn(usize) -> f64 + Sync,
{
    if threads <= 1 || es.len() < MIN_PAR_CANDIDATES {
        return es.iter().map(|&e| f(e)).collect();
    }
    let ranges = shard_ranges(es.len(), threads);
    parallel_map(ranges, threads, |_, r| {
        es[r].iter().map(|&e| f(e)).collect::<Vec<f64>>()
    })
    .into_iter()
    .flatten()
    .collect()
}

/// Run `f` over `items` in parallel on a temporary scoped pool, returning
/// results in input order. Panics in any task are re-raised on the caller.
///
/// This uses `std::thread::scope` rather than the long-lived pool so that
/// `f` may borrow from the caller's stack (shards reference the dataset).
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let workers = workers.max(1);
    let n = items.len();
    let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
    if n == 0 {
        return Vec::new();
    }
    let work: Mutex<std::vec::IntoIter<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().collect::<Vec<_>>().into_iter());
    let slots: Vec<Mutex<&mut Option<R>>> =
        results.iter_mut().map(Mutex::new).collect();
    let panicked = Mutex::new(None::<String>);

    thread::scope(|scope| {
        for _ in 0..workers.min(n) {
            scope.spawn(|| loop {
                let next = { work.lock().unwrap().next() };
                let Some((idx, item)) = next else { break };
                let out = catch_unwind(AssertUnwindSafe(|| f(idx, item)));
                match out {
                    Ok(r) => {
                        **slots[idx].lock().unwrap() = Some(r);
                    }
                    Err(e) => {
                        let msg = e
                            .downcast_ref::<String>()
                            .cloned()
                            .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                            .unwrap_or_else(|| "task panicked".into());
                        *panicked.lock().unwrap() = Some(msg);
                        break;
                    }
                }
            });
        }
    });

    if let Some(msg) = panicked.into_inner().unwrap() {
        panic!("parallel_map task panicked: {msg}");
    }
    results
        .into_iter()
        .map(|r| r.expect("task did not complete"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map((0..1000).collect(), 8, |_, x: i32| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn parallel_map_borrows_environment() {
        let data = vec![1.0f64; 100];
        let sums = parallel_map(vec![0usize, 1, 2, 3], 2, |_, _| data.iter().sum::<f64>());
        assert!(sums.iter().all(|&s| (s - 100.0).abs() < 1e-12));
    }

    #[test]
    #[should_panic(expected = "parallel_map task panicked")]
    fn parallel_map_propagates_panic() {
        parallel_map(vec![1, 2, 3], 2, |_, x: i32| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn shard_ranges_cover_exactly_once() {
        for (len, parts) in [(0usize, 4usize), (1, 4), (7, 3), (100, 8), (8, 8), (5, 16)] {
            let ranges = shard_ranges(len, parts);
            assert!(ranges.len() <= parts.max(1));
            let mut next = 0usize;
            for r in &ranges {
                assert_eq!(r.start, next, "gap at {r:?} (len={len}, parts={parts})");
                next = r.end;
            }
            assert_eq!(next, len, "ranges must cover 0..{len}");
        }
    }

    #[test]
    fn parallel_gains_matches_serial_map_any_threads() {
        let es: Vec<usize> = (0..500).collect();
        let f = |e: usize| (e as f64).sqrt() * 3.0 - 1.0;
        let serial: Vec<f64> = es.iter().map(|&e| f(e)).collect();
        for threads in [1usize, 2, 5, 16] {
            assert_eq!(serial, parallel_gains(&es, threads, f), "threads={threads}");
        }
        // short batches stay serial but still produce the same values
        let short: Vec<usize> = (0..10).collect();
        let expect: Vec<f64> = short.iter().map(|&e| f(e)).collect();
        assert_eq!(expect, parallel_gains(&short, 8, f));
    }

    #[test]
    fn shard_ranges_deterministic_and_balanced() {
        let a = shard_ranges(1000, 7);
        let b = shard_ranges(1000, 7);
        assert_eq!(a, b);
        let sizes: Vec<usize> = a.iter().map(|r| r.end - r.start).collect();
        let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(max - min <= 1, "near-equal shards, got {sizes:?}");
    }

    #[test]
    fn pool_min_one_worker() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
    }
}
