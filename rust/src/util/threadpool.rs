//! Fixed-size worker thread pool (no tokio in the offline closure).
//!
//! The simulated MapReduce engine runs map/reduce tasks on this pool. The
//! design is the classic channel-of-boxed-closures worker pool plus a scoped
//! `parallel_map` helper that preserves input order and propagates panics.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads executing boxed jobs.
pub struct ThreadPool {
    workers: Vec<thread::JoinHandle<()>>,
    sender: Option<mpsc::Sender<Job>>,
}

impl ThreadPool {
    /// Create a pool with `size` workers (min 1).
    pub fn new(size: usize) -> Self {
        let size = size.max(1);
        let (sender, receiver) = mpsc::channel::<Job>();
        let receiver = Arc::new(Mutex::new(receiver));
        let workers = (0..size)
            .map(|i| {
                let rx = Arc::clone(&receiver);
                thread::Builder::new()
                    .name(format!("greedi-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // channel closed
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { workers, sender: Some(sender) }
    }

    /// Pool sized to the machine (`available_parallelism`, >= 1).
    pub fn default_size() -> Self {
        let n = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        ThreadPool::new(n)
    }

    /// Submit a fire-and-forget job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.sender
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("worker channel closed");
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.sender.take()); // close channel => workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Run `f` over `items` in parallel on a temporary scoped pool, returning
/// results in input order. Panics in any task are re-raised on the caller.
///
/// This uses `std::thread::scope` rather than the long-lived pool so that
/// `f` may borrow from the caller's stack (shards reference the dataset).
pub fn parallel_map<T, R, F>(items: Vec<T>, workers: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let workers = workers.max(1);
    let n = items.len();
    let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
    if n == 0 {
        return Vec::new();
    }
    let work: Mutex<std::vec::IntoIter<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().collect::<Vec<_>>().into_iter());
    let slots: Vec<Mutex<&mut Option<R>>> =
        results.iter_mut().map(Mutex::new).collect();
    let panicked = Mutex::new(None::<String>);

    thread::scope(|scope| {
        for _ in 0..workers.min(n) {
            scope.spawn(|| loop {
                let next = { work.lock().unwrap().next() };
                let Some((idx, item)) = next else { break };
                let out = catch_unwind(AssertUnwindSafe(|| f(idx, item)));
                match out {
                    Ok(r) => {
                        **slots[idx].lock().unwrap() = Some(r);
                    }
                    Err(e) => {
                        let msg = e
                            .downcast_ref::<String>()
                            .cloned()
                            .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                            .unwrap_or_else(|| "task panicked".into());
                        *panicked.lock().unwrap() = Some(msg);
                        break;
                    }
                }
            });
        }
    });

    if let Some(msg) = panicked.into_inner().unwrap() {
        panic!("parallel_map task panicked: {msg}");
    }
    results
        .into_iter()
        .map(|r| r.expect("task did not complete"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map((0..1000).collect(), 8, |_, x: i32| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |_, x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn parallel_map_borrows_environment() {
        let data = vec![1.0f64; 100];
        let sums = parallel_map(vec![0usize, 1, 2, 3], 2, |_, _| data.iter().sum::<f64>());
        assert!(sums.iter().all(|&s| (s - 100.0).abs() < 1e-12));
    }

    #[test]
    #[should_panic(expected = "parallel_map task panicked")]
    fn parallel_map_propagates_panic() {
        parallel_map(vec![1, 2, 3], 2, |_, x: i32| {
            if x == 2 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn pool_min_one_worker() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
    }
}
