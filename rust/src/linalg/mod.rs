//! Small dense linear algebra substrate: row-major matrices and the
//! incremental (bordered) Cholesky factorization that gives the GP
//! information-gain objective O(k²) marginal-gain evaluations instead of
//! O(k³) log-det recomputations.

pub mod cholesky;
pub mod matrix;

pub use cholesky::IncrementalCholesky;
pub use matrix::Matrix;
