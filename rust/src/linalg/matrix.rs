//! Row-major dense f64 matrix with just the operations the objectives need
//! (no BLAS in the offline closure — loops are written cache-friendly).

/// Dense row-major matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: Vec<Vec<f64>>) -> Self {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in &rows {
            assert_eq!(row.len(), c, "ragged matrix");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// `self * other`.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul dims");
        let mut out = Matrix::zeros(self.rows, other.cols);
        // i-k-j loop order: streams `other` rows, cache friendly.
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = &mut out.data[i * other.cols..(i + 1) * other.cols];
                for (o, &b) in out_row.iter_mut().zip(orow.iter()) {
                    *o += a * b;
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Plain (non-incremental) Cholesky: self = L Lᵀ, returns L or None if
    /// not positive definite. Used as the oracle for the incremental
    /// version's tests.
    pub fn cholesky(&self) -> Option<Matrix> {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        let mut l = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = self[(i, j)];
                for k in 0..j {
                    s -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if s <= 0.0 {
                        return None;
                    }
                    l[(i, i)] = s.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Some(l)
    }

    /// log det of a PD matrix via Cholesky (None if not PD).
    pub fn logdet(&self) -> Option<f64> {
        let l = self.cholesky()?;
        Some((0..self.rows).map(|i| l[(i, i)].ln()).sum::<f64>() * 2.0)
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_matmul() {
        let i3 = Matrix::identity(3);
        let a = Matrix::from_rows(vec![
            vec![1.0, 2.0, 3.0],
            vec![4.0, 5.0, 6.0],
            vec![7.0, 8.0, 9.0],
        ]);
        assert_eq!(i3.matmul(&a), a);
        assert_eq!(a.matmul(&i3), a);
    }

    #[test]
    fn matmul_known_values() {
        let a = Matrix::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Matrix::from_rows(vec![vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c, Matrix::from_rows(vec![vec![19.0, 22.0], vec![43.0, 50.0]]));
    }

    #[test]
    fn transpose_involution() {
        let a = Matrix::from_rows(vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn cholesky_reconstructs() {
        // A = B Bᵀ + I is PD
        let b = Matrix::from_rows(vec![
            vec![1.0, 0.5],
            vec![0.2, 1.3],
            vec![-0.7, 0.4],
        ]);
        let mut a = b.matmul(&b.transpose());
        for i in 0..3 {
            a[(i, i)] += 1.0;
        }
        let l = a.cholesky().unwrap();
        let recon = l.matmul(&l.transpose());
        for i in 0..3 {
            for j in 0..3 {
                assert!((recon[(i, j)] - a[(i, j)]).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Matrix::from_rows(vec![vec![0.0, 1.0], vec![1.0, 0.0]]);
        assert!(a.cholesky().is_none());
    }

    #[test]
    fn logdet_matches_diagonal() {
        let mut a = Matrix::identity(4);
        a[(0, 0)] = 2.0;
        a[(1, 1)] = 3.0;
        let ld = a.logdet().unwrap();
        assert!((ld - (2.0f64.ln() + 3.0f64.ln())).abs() < 1e-12);
    }
}
