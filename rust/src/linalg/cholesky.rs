//! Incremental (bordered) Cholesky factorization of `I + σ⁻² K_SS`.
//!
//! The GP information gain `f(S) = ½ log det(I + σ⁻² K_SS)` (paper §3.4.1)
//! is evaluated thousands of times inside greedy. Recomputing the log-det
//! from scratch is O(|S|³) per call; bordering the existing factor when one
//! element is added costs O(|S|²) and — crucially — the *marginal gain* of a
//! candidate can be priced without committing it:
//!
//!   gain(e | S) = ½ log( d_e ),  d_e = a_ee − ‖w‖²,
//!   where a_ee = 1 + σ⁻² K(e,e) and L w = a_Se.
//!
//! This is the standard "Cholesky pricing" trick; it is what makes the lazy
//! greedy info-gain run in the Fig. 6/7 experiments tractable.

use super::matrix::Matrix;

/// Maintains the lower-triangular factor `L` of `I + σ⁻² K_SS` as elements
/// are appended to `S`.
#[derive(Debug, Clone)]
pub struct IncrementalCholesky {
    /// Row-packed lower triangle: row i holds i+1 entries.
    l: Vec<Vec<f64>>,
    /// Running log-det of the factored matrix.
    logdet: f64,
}

impl Default for IncrementalCholesky {
    fn default() -> Self {
        Self::new()
    }
}

impl IncrementalCholesky {
    pub fn new() -> Self {
        IncrementalCholesky { l: Vec::new(), logdet: 0.0 }
    }

    pub fn len(&self) -> usize {
        self.l.len()
    }

    pub fn is_empty(&self) -> bool {
        self.l.is_empty()
    }

    /// log det(I + σ⁻² K_SS) of the current set.
    pub fn logdet(&self) -> f64 {
        self.logdet
    }

    /// Solve `L w = b` by forward substitution into `w` (no allocation —
    /// perf pass §B: gain pricing is called for every candidate in every
    /// greedy round, so the scratch buffer is caller-owned).
    pub fn forward_solve_into(&self, b: &[f64], w: &mut Vec<f64>) {
        let k = self.l.len();
        debug_assert_eq!(b.len(), k);
        w.clear();
        w.resize(k, 0.0);
        for i in 0..k {
            let mut s = b[i];
            let row = &self.l[i];
            for j in 0..i {
                s -= row[j] * w[j];
            }
            w[i] = s / row[i];
        }
    }

    /// Solve `L w = b` by forward substitution (allocating convenience).
    fn forward_solve(&self, b: &[f64]) -> Vec<f64> {
        let mut w = Vec::new();
        self.forward_solve_into(b, &mut w);
        w
    }

    /// Allocation-free pivot: like [`pivot`](Self::pivot) with a caller
    /// scratch buffer.
    pub fn pivot_with(&self, a_ee: f64, a_se: &[f64], scratch: &mut Vec<f64>) -> f64 {
        self.forward_solve_into(a_se, scratch);
        a_ee - scratch.iter().map(|x| x * x).sum::<f64>()
    }

    /// Allocation-free gain (ln pivot, floored).
    pub fn gain_with(&self, a_ee: f64, a_se: &[f64], scratch: &mut Vec<f64>) -> f64 {
        self.pivot_with(a_ee, a_se, scratch).max(1e-12).ln()
    }

    /// Pivot value `d = a_ee − ‖w‖²` for a candidate with self-term `a_ee`
    /// and cross-terms `a_se[i] = σ⁻² K(S_i, e)`. The candidate's log-det
    /// increment is `ln d` (must be > 0 for a PD-consistent kernel).
    pub fn pivot(&self, a_ee: f64, a_se: &[f64]) -> f64 {
        let w = self.forward_solve(a_se);
        a_ee - w.iter().map(|x| x * x).sum::<f64>()
    }

    /// Marginal log-det gain of a candidate (ln of the pivot, floored at a
    /// tiny epsilon to absorb f32 kernel round-off).
    pub fn gain(&self, a_ee: f64, a_se: &[f64]) -> f64 {
        self.pivot(a_ee, a_se).max(1e-12).ln()
    }

    /// Append the candidate, updating the factor and log-det. Returns the
    /// realized log-det increment.
    pub fn push(&mut self, a_ee: f64, a_se: &[f64]) -> f64 {
        let w = self.forward_solve(a_se);
        let d = (a_ee - w.iter().map(|x| x * x).sum::<f64>()).max(1e-12);
        let mut row = w;
        row.push(d.sqrt());
        self.l.push(row);
        let inc = d.ln();
        self.logdet += inc;
        inc
    }

    /// Reconstruct the dense factor (tests/debugging).
    pub fn dense(&self) -> Matrix {
        let k = self.l.len();
        let mut m = Matrix::zeros(k, k);
        for (i, row) in self.l.iter().enumerate() {
            for (j, &v) in row.iter().enumerate() {
                m[(i, j)] = v;
            }
        }
        m
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Random PD matrix A = B Bᵀ + I.
    fn random_pd(n: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let mut b = Matrix::zeros(n, n);
        for v in b.data.iter_mut() {
            *v = rng.normal();
        }
        let mut a = b.matmul(&b.transpose());
        for i in 0..n {
            a[(i, i)] += 1.0 + n as f64;
        }
        a
    }

    #[test]
    fn matches_batch_cholesky() {
        let a = random_pd(8, 1);
        let mut inc = IncrementalCholesky::new();
        for i in 0..8 {
            let a_se: Vec<f64> = (0..i).map(|j| a[(i, j)]).collect();
            inc.push(a[(i, i)], &a_se);
        }
        let batch = a.cholesky().unwrap();
        let dense = inc.dense();
        for i in 0..8 {
            for j in 0..=i {
                assert!(
                    (dense[(i, j)] - batch[(i, j)]).abs() < 1e-9,
                    "L[{i},{j}]: {} vs {}",
                    dense[(i, j)],
                    batch[(i, j)]
                );
            }
        }
    }

    #[test]
    fn logdet_matches_batch() {
        let a = random_pd(10, 2);
        let mut inc = IncrementalCholesky::new();
        for i in 0..10 {
            let a_se: Vec<f64> = (0..i).map(|j| a[(i, j)]).collect();
            inc.push(a[(i, i)], &a_se);
        }
        assert!((inc.logdet() - a.logdet().unwrap()).abs() < 1e-8);
    }

    #[test]
    fn gain_equals_realized_increment() {
        let a = random_pd(6, 3);
        let mut inc = IncrementalCholesky::new();
        for i in 0..6 {
            let a_se: Vec<f64> = (0..i).map(|j| a[(i, j)]).collect();
            let predicted = inc.gain(a[(i, i)], &a_se);
            let realized = inc.push(a[(i, i)], &a_se);
            assert!((predicted - realized).abs() < 1e-10);
        }
    }

    #[test]
    fn empty_logdet_zero() {
        let inc = IncrementalCholesky::new();
        assert_eq!(inc.logdet(), 0.0);
        assert!(inc.is_empty());
    }

    #[test]
    fn pivot_positive_for_pd() {
        let a = random_pd(5, 4);
        let mut inc = IncrementalCholesky::new();
        for i in 0..4 {
            let a_se: Vec<f64> = (0..i).map(|j| a[(i, j)]).collect();
            inc.push(a[(i, i)], &a_se);
        }
        let a_se: Vec<f64> = (0..4).map(|j| a[(4, j)]).collect();
        assert!(inc.pivot(a[(4, 4)], &a_se) > 0.0);
    }
}
