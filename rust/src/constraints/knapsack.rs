//! Knapsack constraints (paper §5.2): single budget `Σ c(e) ≤ R` and the
//! d-dimensional generalization (multiple knapsacks).

use super::Constraint;

/// Single knapsack: `Σ_{e∈S} cost[e] ≤ budget`.
#[derive(Debug, Clone)]
pub struct Knapsack {
    pub cost: Vec<f64>,
    pub budget: f64,
}

impl Knapsack {
    pub fn new(cost: Vec<f64>, budget: f64) -> Self {
        assert!(cost.iter().all(|&c| c > 0.0), "positive costs required");
        assert!(budget >= 0.0);
        Knapsack { cost, budget }
    }

    pub fn used(&self, s: &[usize]) -> f64 {
        s.iter().map(|&e| self.cost[e]).sum()
    }
}

impl Constraint for Knapsack {
    fn can_add(&self, current: &[usize], e: usize) -> bool {
        self.used(current) + self.cost[e] <= self.budget + 1e-12
    }

    fn rho(&self) -> usize {
        // ⌈R / min cost⌉ (paper, discussion under Thm 12)
        let min_cost = self
            .cost
            .iter()
            .cloned()
            .fold(f64::INFINITY, f64::min)
            .max(1e-12);
        (self.budget / min_cost).ceil() as usize
    }
}

/// d-dimensional knapsack: cost vectors, elementwise budget.
#[derive(Debug, Clone)]
pub struct MultiKnapsack {
    /// cost[e] is a d-vector.
    pub cost: Vec<Vec<f64>>,
    pub budget: Vec<f64>,
}

impl MultiKnapsack {
    pub fn new(cost: Vec<Vec<f64>>, budget: Vec<f64>) -> Self {
        let d = budget.len();
        assert!(cost.iter().all(|c| c.len() == d), "cost dim mismatch");
        assert!(cost.iter().flatten().all(|&c| c >= 0.0));
        MultiKnapsack { cost, budget }
    }

    fn used(&self, s: &[usize]) -> Vec<f64> {
        let d = self.budget.len();
        let mut u = vec![0.0; d];
        for &e in s {
            for t in 0..d {
                u[t] += self.cost[e][t];
            }
        }
        u
    }
}

impl Constraint for MultiKnapsack {
    fn can_add(&self, current: &[usize], e: usize) -> bool {
        let u = self.used(current);
        (0..self.budget.len()).all(|t| u[t] + self.cost[e][t] <= self.budget[t] + 1e-12)
    }

    fn rho(&self) -> usize {
        // loosest single-dimension bound
        (0..self.budget.len())
            .map(|t| {
                let min_c = self
                    .cost
                    .iter()
                    .map(|c| c[t])
                    .filter(|&c| c > 0.0)
                    .fold(f64::INFINITY, f64::min);
                if min_c.is_finite() {
                    (self.budget[t] / min_c).ceil() as usize
                } else {
                    self.cost.len()
                }
            })
            .min()
            .unwrap_or(self.cost.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knapsack_budget_respected() {
        let k = Knapsack::new(vec![1.0, 2.0, 3.0], 4.0);
        assert!(k.can_add(&[], 2)); // 3 <= 4
        assert!(k.can_add(&[0], 1)); // 1+2 <= 4
        assert!(!k.can_add(&[0, 1], 2)); // 1+2+3 > 4
        assert!(k.is_feasible(&[0, 2])); // 4 <= 4 exactly
    }

    #[test]
    fn knapsack_rho() {
        let k = Knapsack::new(vec![0.5, 2.0], 3.0);
        assert_eq!(k.rho(), 6); // 3 / 0.5
    }

    #[test]
    fn heredity() {
        let k = Knapsack::new(vec![2.0, 2.0, 2.0], 4.0);
        assert!(k.is_feasible(&[0, 1]));
        assert!(k.is_feasible(&[0]));
        assert!(k.is_feasible(&[1]));
    }

    #[test]
    #[should_panic]
    fn zero_cost_rejected() {
        Knapsack::new(vec![0.0], 1.0);
    }

    #[test]
    fn multi_knapsack_all_dims_must_fit() {
        let mk = MultiKnapsack::new(
            vec![vec![1.0, 0.0], vec![0.0, 1.0], vec![1.0, 1.0]],
            vec![1.0, 1.0],
        );
        assert!(mk.can_add(&[], 2));
        assert!(mk.can_add(&[0], 1)); // dims (1,0)+(0,1) = (1,1) OK
        assert!(!mk.can_add(&[0], 2)); // dim 0 would hit 2 > 1
        assert!(mk.is_feasible(&[0, 1]));
        assert!(!mk.is_feasible(&[0, 1, 2]));
    }

    #[test]
    fn multi_knapsack_rho() {
        let mk = MultiKnapsack::new(vec![vec![1.0], vec![1.0]], vec![2.0]);
        assert_eq!(mk.rho(), 2);
    }
}
