//! Generic intersection of heterogeneous hereditary constraints — e.g. the
//! paper's "p-system + d knapsacks" setting (§5.2, Badanidiyuru & Vondrák
//! 2014): feasible iff feasible in every component system.

use super::Constraint;

/// Intersection of arbitrary hereditary constraints (boxed, heterogeneous).
pub struct Intersection {
    pub parts: Vec<Box<dyn Constraint + Send>>,
}

impl Intersection {
    pub fn new(parts: Vec<Box<dyn Constraint + Send>>) -> Self {
        assert!(!parts.is_empty());
        Intersection { parts }
    }
}

impl Constraint for Intersection {
    fn can_add(&self, current: &[usize], e: usize) -> bool {
        self.parts.iter().all(|c| c.can_add(current, e))
    }

    fn rho(&self) -> usize {
        self.parts.iter().map(|c| c.rho()).min().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraints::cardinality::Cardinality;
    use crate::constraints::knapsack::Knapsack;
    use crate::constraints::matroid::PartitionMatroid;

    fn psystem_plus_knapsack() -> Intersection {
        Intersection::new(vec![
            Box::new(PartitionMatroid::new(vec![0, 0, 1, 1], vec![1, 2])),
            Box::new(Knapsack::new(vec![1.0, 3.0, 1.0, 1.0], 2.0)),
        ])
    }

    #[test]
    fn all_parts_must_allow() {
        let ix = psystem_plus_knapsack();
        assert!(ix.can_add(&[], 0)); // both OK
        assert!(!ix.can_add(&[], 1)); // knapsack blocks (3 > 2)
        assert!(!ix.can_add(&[0], 1)); // matroid also blocks cat-0 repeat
        assert!(ix.can_add(&[0], 2)); // 1+1 <= 2, different category
    }

    #[test]
    fn rho_is_min_over_parts() {
        let ix = Intersection::new(vec![
            Box::new(Cardinality::new(5)),
            Box::new(Cardinality::new(3)),
        ]);
        assert_eq!(ix.rho(), 3);
    }

    #[test]
    fn heredity_preserved() {
        let ix = psystem_plus_knapsack();
        assert!(ix.is_feasible(&[0, 2]));
        assert!(ix.is_feasible(&[0]));
        assert!(ix.is_feasible(&[2]));
    }

    #[test]
    #[should_panic]
    fn empty_intersection_rejected() {
        Intersection::new(vec![]);
    }
}
