//! Cardinality constraint `|S| ≤ k` — the paper's primary setting
//! (Sections 3–4) and the uniform matroid's independence system.

use super::Constraint;

/// `|S| ≤ k`.
#[derive(Debug, Clone, Copy)]
pub struct Cardinality {
    pub k: usize,
}

impl Cardinality {
    pub fn new(k: usize) -> Self {
        Cardinality { k }
    }
}

impl Constraint for Cardinality {
    fn can_add(&self, current: &[usize], _e: usize) -> bool {
        current.len() < self.k
    }

    fn rho(&self) -> usize {
        self.k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic() {
        let c = Cardinality::new(3);
        assert!(c.can_add(&[], 0));
        assert!(c.can_add(&[1, 2], 0));
        assert!(!c.can_add(&[1, 2, 3], 0));
        assert_eq!(c.rho(), 3);
    }

    #[test]
    fn zero_budget() {
        let c = Cardinality::new(0);
        assert!(!c.can_add(&[], 0));
        assert!(c.is_feasible(&[]));
    }
}
