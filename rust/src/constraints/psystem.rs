//! p-independence systems (paper §5.1): for every restriction V′, the sizes
//! of maximal independent subsets of V′ differ by at most a factor p.
//!
//! We provide the canonical constructive example — the intersection of p
//! partition matroids is a p-system — plus a generic wrapper that treats an
//! arbitrary hereditary oracle as a p-system with a declared p (callers
//! assert the bound; tests verify it by enumeration on small instances).

use super::matroid::PartitionMatroid;
use super::Constraint;

/// A declared p-system backed by an arbitrary hereditary membership oracle.
pub struct PSystem<C: Constraint> {
    pub inner: C,
    pub p: usize,
}

impl<C: Constraint> PSystem<C> {
    pub fn new(inner: C, p: usize) -> Self {
        assert!(p >= 1);
        PSystem { inner, p }
    }
}

impl<C: Constraint> Constraint for PSystem<C> {
    fn can_add(&self, current: &[usize], e: usize) -> bool {
        self.inner.can_add(current, e)
    }

    fn rho(&self) -> usize {
        self.inner.rho()
    }
}

/// Exhaustively compute the true p of a hereditary system on a small ground
/// set: max over V′ of (largest maximal set / smallest maximal set).
/// Exponential — test/diagnostic use only.
pub fn measure_p(c: &dyn Constraint, n: usize) -> f64 {
    assert!(n <= 16, "measure_p is exponential");
    let mut worst: f64 = 1.0;
    for mask in 1u32..(1 << n) {
        let vprime: Vec<usize> = (0..n).filter(|&i| mask & (1 << i) != 0).collect();
        // enumerate maximal independent subsets of vprime by greedy closure
        // over all insertion orders is too slow; instead enumerate all
        // independent subsets and keep the maximal ones.
        let mut independents: Vec<Vec<usize>> = vec![vec![]];
        for &e in &vprime {
            let mut new_sets = Vec::new();
            for s in &independents {
                if c.can_add(s, e) {
                    let mut t = s.clone();
                    t.push(e);
                    new_sets.push(t);
                }
            }
            independents.extend(new_sets);
        }
        // maximal = cannot add any element of vprime
        let maximal: Vec<&Vec<usize>> = independents
            .iter()
            .filter(|s| {
                vprime
                    .iter()
                    .all(|&e| s.contains(&e) || !c.can_add(s, e))
            })
            .collect();
        if maximal.is_empty() {
            continue;
        }
        let max_len = maximal.iter().map(|s| s.len()).max().unwrap();
        let min_len = maximal.iter().map(|s| s.len()).min().unwrap();
        if min_len > 0 {
            worst = worst.max(max_len as f64 / min_len as f64);
        }
    }
    worst
}

/// Intersection of p partition matroids — the standard p-system instance.
pub struct MatroidIntersection {
    pub matroids: Vec<PartitionMatroid>,
}

impl MatroidIntersection {
    pub fn new(matroids: Vec<PartitionMatroid>) -> Self {
        assert!(!matroids.is_empty());
        MatroidIntersection { matroids }
    }

    pub fn p(&self) -> usize {
        self.matroids.len()
    }
}

impl Constraint for MatroidIntersection {
    fn can_add(&self, current: &[usize], e: usize) -> bool {
        self.matroids.iter().all(|m| m.can_add(current, e))
    }

    fn rho(&self) -> usize {
        self.matroids.iter().map(|m| m.rho()).min().unwrap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_matroid_is_1_system() {
        let m = PartitionMatroid::new(vec![0, 0, 1, 1], vec![1, 1]);
        let p = measure_p(&m, 4);
        assert!((p - 1.0).abs() < 1e-12, "matroid must be a 1-system, got {p}");
    }

    #[test]
    fn intersection_respects_all_matroids() {
        let m1 = PartitionMatroid::new(vec![0, 0, 1, 1], vec![1, 2]);
        let m2 = PartitionMatroid::new(vec![0, 1, 0, 1], vec![1, 1]);
        let ix = MatroidIntersection::new(vec![m1, m2]);
        assert!(ix.can_add(&[], 0));
        // 0 (cats 0/0) then 3 (cats 1/1) fine
        assert!(ix.can_add(&[0], 3));
        // but 2 conflicts with 0 in m2 (both cat 0 there)
        assert!(!ix.can_add(&[0], 2));
    }

    #[test]
    fn intersection_p_bounded() {
        let m1 = PartitionMatroid::new(vec![0, 0, 1, 1, 2], vec![1, 1, 1]);
        let m2 = PartitionMatroid::new(vec![0, 1, 0, 1, 0], vec![2, 1]);
        let ix = MatroidIntersection::new(vec![m1, m2]);
        let p = measure_p(&ix, 5);
        assert!(p <= 2.0 + 1e-12, "intersection of 2 matroids is a 2-system, got {p}");
    }

    #[test]
    fn psystem_wrapper_delegates() {
        let m = PartitionMatroid::new(vec![0, 1], vec![1, 1]);
        let ps = PSystem::new(m, 1);
        assert!(ps.can_add(&[], 0));
        assert_eq!(ps.rho(), 2);
    }
}
