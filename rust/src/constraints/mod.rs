//! Hereditary constraint systems (paper §5): cardinality, matroids,
//! knapsacks, p-systems and intersections. All are *hereditary* — every
//! subset of a feasible set is feasible — which is exactly the property
//! Theorem 12 needs for GreeDi's general-constraint guarantee.

pub mod cardinality;
pub mod intersection;
pub mod knapsack;
pub mod matroid;
pub mod psystem;

/// A hereditary feasibility constraint over ground set `0..n`.
pub trait Constraint: Sync {
    /// Can `e` be added to the (assumed feasible) set `current`?
    fn can_add(&self, current: &[usize], e: usize) -> bool;

    /// Is `s` feasible? Default: incremental check (valid for hereditary
    /// systems where feasibility can be verified by insertion order — true
    /// for all the systems here).
    fn is_feasible(&self, s: &[usize]) -> bool {
        let mut cur: Vec<usize> = Vec::with_capacity(s.len());
        for &e in s {
            if !self.can_add(&cur, e) {
                return false;
            }
            cur.push(e);
        }
        true
    }

    /// ρ(ζ) = max cardinality of a feasible set (paper Thm 12). Used for
    /// buffer sizing and for GreeDi's round budgets.
    fn rho(&self) -> usize;
}

#[cfg(test)]
mod tests {
    use super::cardinality::Cardinality;
    use super::*;

    #[test]
    fn default_is_feasible_uses_can_add() {
        let c = Cardinality::new(2);
        assert!(c.is_feasible(&[0, 1]));
        assert!(!c.is_feasible(&[0, 1, 2]));
        assert!(c.is_feasible(&[]));
    }
}
