//! Matroid constraints (paper §5.1): uniform and partition matroids.
//!
//! A partition matroid splits the ground set into categories with per-
//! category capacities — the paper's motivating examples are content
//! aggregation and advertising with per-topic budgets.

use super::Constraint;

/// Uniform matroid — identical to a cardinality constraint but kept as its
/// own type so experiments can name the matroid semantics explicitly.
#[derive(Debug, Clone, Copy)]
pub struct UniformMatroid {
    pub rank: usize,
}

impl UniformMatroid {
    pub fn new(rank: usize) -> Self {
        UniformMatroid { rank }
    }
}

impl Constraint for UniformMatroid {
    fn can_add(&self, current: &[usize], _e: usize) -> bool {
        current.len() < self.rank
    }

    fn rho(&self) -> usize {
        self.rank
    }
}

/// Partition matroid: element `e` belongs to category `category[e]`;
/// at most `capacity[c]` elements per category.
#[derive(Debug, Clone)]
pub struct PartitionMatroid {
    pub category: Vec<usize>,
    pub capacity: Vec<usize>,
}

impl PartitionMatroid {
    pub fn new(category: Vec<usize>, capacity: Vec<usize>) -> Self {
        assert!(
            category.iter().all(|&c| c < capacity.len()),
            "category id out of range"
        );
        PartitionMatroid { category, capacity }
    }

    /// Uniform capacities across `ncat` categories.
    pub fn uniform(category: Vec<usize>, ncat: usize, per_cat: usize) -> Self {
        Self::new(category, vec![per_cat; ncat])
    }
}

impl Constraint for PartitionMatroid {
    fn can_add(&self, current: &[usize], e: usize) -> bool {
        let cat = self.category[e];
        let used = current.iter().filter(|&&x| self.category[x] == cat).count();
        used < self.capacity[cat]
    }

    fn rho(&self) -> usize {
        self.capacity.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_matroid_is_cardinality() {
        let m = UniformMatroid::new(2);
        assert!(m.can_add(&[5], 9));
        assert!(!m.can_add(&[5, 6], 9));
        assert_eq!(m.rho(), 2);
    }

    #[test]
    fn partition_respects_per_category_caps() {
        // elements 0,1,2 in cat 0; 3,4 in cat 1; caps [2, 1]
        let m = PartitionMatroid::new(vec![0, 0, 0, 1, 1], vec![2, 1]);
        assert!(m.can_add(&[], 0));
        assert!(m.can_add(&[0], 1));
        assert!(!m.can_add(&[0, 1], 2)); // cat 0 full
        assert!(m.can_add(&[0, 1], 3)); // cat 1 open
        assert!(!m.can_add(&[3], 4)); // cat 1 full
        assert_eq!(m.rho(), 3);
    }

    #[test]
    fn heredity_property() {
        // every subset of a feasible set is feasible
        let m = PartitionMatroid::new(vec![0, 0, 1, 1], vec![1, 2]);
        let full = vec![0, 2, 3];
        assert!(m.is_feasible(&full));
        for drop in 0..full.len() {
            let sub: Vec<usize> = full
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != drop)
                .map(|(_, &e)| e)
                .collect();
            assert!(m.is_feasible(&sub));
        }
    }

    #[test]
    fn augmentation_property_spotcheck() {
        // |B| > |A| both independent => some b in B\A augments A
        let m = PartitionMatroid::new(vec![0, 0, 1, 1, 2], vec![1, 1, 1]);
        let a = vec![0]; // cat 0
        let b = vec![1, 2, 4]; // cats 0,1,2 — |B|>|A|
        assert!(m.is_feasible(&a) && m.is_feasible(&b));
        let can_augment = b
            .iter()
            .filter(|e| !a.contains(e))
            .any(|&e| m.can_add(&a, e));
        assert!(can_augment);
    }

    #[test]
    #[should_panic]
    fn bad_category_rejected() {
        PartitionMatroid::new(vec![0, 3], vec![1, 1]);
    }
}
