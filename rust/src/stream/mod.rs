//! Bounded-memory streaming subsystem: stream sources, the batched sieve
//! engine, and the distributed sieve→merge protocol.
//!
//! The paper's GreeDi assumes each machine can hold and repeatedly scan its
//! whole shard. This subsystem opens the workload class where it cannot:
//! elements arrive as a **stream** and each machine may keep only a
//! candidate summary, never the shard.
//!
//! * [`source`] — [`source::StreamSource`]: one-pass batch streams of
//!   element ids (in-memory permuted order, deterministic seeded shuffle,
//!   synthetic drift/adversarial orders, chunked reads from disk through
//!   `data::loader`).
//! * [`sieve`] — [`sieve::BatchedSieve`]: single-pass sieve-streaming over
//!   a geometric threshold ladder, pricing whole batches through the
//!   parallel gain engine (`State::par_batch_gains`) with output provably
//!   identical to element-at-a-time processing at any batch size and
//!   thread count.
//! * [`distributed`] — [`distributed::StreamGreedi`]: the two-stage
//!   protocol (m one-pass local sieves → one GreeDi-style merge), run on
//!   the simulated MapReduce engine and registered as
//!   `protocol::by_name("stream_greedi")`.
//!
//! ## Guarantee
//!
//! The local stage is Sieve-Streaming (Badanidiyuru et al. 2014): one pass,
//! any arrival order, `(1/2 − ε)·OPT_local` for monotone submodular f under
//! a cardinality constraint. Composed with the merge round over the union
//! of sieve summaries — the randomized-core-set composition of Barbosa et
//! al. (arXiv:1507.03719) / Lucic et al. (arXiv:1605.09619) — the protocol
//! keeps a constant-factor guarantee in expectation under randomized
//! partitioning, with exactly **2** synchronous rounds and poly(κ, 1/ε, m)
//! communication, never O(n).
//!
//! ## Memory bound
//!
//! Per machine, live state is one incremental sieve per ladder rung with at
//! most κ committed elements each; the lazily maintained ladder spans
//! `[m, 2κm]` (m = best singleton so far), i.e. at most
//! `⌈log_{1+ε}(2κ)⌉ + 2` rungs at any instant. Peak live candidates are
//! therefore bounded by [`sieve::candidate_bound`]`(κ, ε) = O(κ·log(κ)/ε)`
//! — independent of the stream length — and every run reports its realized
//! peak against that ceiling in
//! [`RunMetrics::stream`](crate::coordinator::metrics::StreamStats).

pub mod distributed;
pub mod sieve;
pub mod source;

pub use distributed::StreamGreedi;
pub use sieve::{candidate_bound, sieve_stream, BatchedSieve, SieveResult};
pub use source::{ChunkedCsvSource, DriftSource, StreamOrder, StreamSource, VecSource};
