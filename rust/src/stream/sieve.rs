//! The batched sieve-streaming engine — one-pass, bounded-memory
//! cardinality-constrained maximization over a [`StreamSource`], with every
//! hot pricing routed through the shared sharded gain engine
//! (`objective::engine::ShardedGainEngine`, behind
//! [`State::par_batch_gains`] and [`SubmodularFn::singleton_gains`]): the
//! ladder inherits the engine's bit-identical-across-threads contract for
//! every objective, and objectives with closed-form singletons (modular
//! weights, coverage set sizes) price the ladder with no state work at all.
//!
//! ## Algorithm
//!
//! Classic Sieve-Streaming (Badanidiyuru et al. 2014): maintain a geometric
//! threshold ladder `v = (1+ε)^i` lazily covering `[m, 2·k·m]`, where `m`
//! is the best singleton value seen so far; the sieve at threshold `v`
//! keeps an element iff its marginal gain is at least
//! `(v/2 − f(S_v)) / (k − |S_v|)`; the best sieve at end of stream is a
//! `(1/2 − ε)`-approximation in **one pass**, for any arrival order.
//!
//! ## Batching without changing a single answer
//!
//! The one-at-a-time formulation starves a batched/parallel oracle. This
//! engine prices a whole incoming batch at once and still produces output
//! **identical to element-at-a-time processing**, by exploiting
//! submodularity twice per batch:
//!
//! 1. **Singletons** `f({e})` do not depend on any sieve state, so the
//!    ladder bookkeeping for the whole batch is driven by one batched call.
//! 2. Per sieve, gains priced at batch start are **upper bounds** once the
//!    sieve grows mid-batch. Walking the batch in arrival order: a cached
//!    gain below the admission threshold proves the true gain is below it
//!    (reject with zero extra oracle work); a cached gain above it is exact
//!    if the sieve has not grown since pricing, and is otherwise re-priced
//!    with one fresh `gain` call before the test. Since at most `k`
//!    elements ever commit per sieve, re-pricings are rare and the oracle
//!    sees wide batches almost exclusively.
//!
//! Both batched paths honor the gain engine's bit-identical-across-threads
//! contract — which since the engine refactor holds for EVERY objective,
//! not just facility/coverage/cut — so this engine's output is invariant to
//! **both** the batch size and the thread count (asserted by
//! `tests/integration_stream`).
//!
//! ## Memory bound
//!
//! Live state is one incremental [`State`] per ladder rung, each holding at
//! most `k` committed elements. The lazily instantiated ladder spans
//! `[m, 2·k·m]`, i.e. at most `⌈log_{1+ε}(2k)⌉ + 2` rungs regardless of the
//! data scale Δ (rungs below a risen `m` are dropped), so the peak number
//! of live candidates is at most [`candidate_bound`]`(k, ε) =
//! k·(⌈log_{1+ε}(2k)⌉ + 2) = O(k·log(k)/ε)` — the engine tracks the
//! realized peak ([`SieveResult::peak_live`]) and reports it against this
//! bound.

use std::collections::BTreeMap;
use std::ops::RangeInclusive;

use super::source::StreamSource;
use crate::objective::{State, SubmodularFn};
use crate::util::trace;

/// Outcome of one single-pass sieve run.
#[derive(Debug, Clone, Default)]
pub struct SieveResult {
    /// Best sieve's selection, in commit order.
    pub solution: Vec<usize>,
    /// f(solution) as tracked incrementally by the winning sieve.
    pub value: f64,
    /// Union of every live sieve's committed elements (sorted, deduped) —
    /// the machine's summary in the distributed sieve→merge protocol.
    pub union: Vec<usize>,
    /// Marginal-gain oracle evaluations issued (batched calls count their
    /// width).
    pub oracle_calls: u64,
    /// Peak live committed candidates across the ladder at any batch
    /// boundary — must stay ≤ [`SieveResult::bound`].
    pub peak_live: usize,
    /// The O(k·log(k)/ε) candidate bound ([`candidate_bound`]).
    pub bound: usize,
    /// Elements consumed from the stream.
    pub elements: usize,
    /// Batches consumed from the stream.
    pub batches: usize,
}

/// Hard ceiling on live committed candidates: `k` per rung times the
/// maximum number of simultaneously live rungs, `⌈log_{1+ε}(2k)⌉ + 2`
/// (the lazy ladder spans `[m, 2km]`, a fixed ratio of `2k` — independent
/// of the data's value scale).
pub fn candidate_bound(k: usize, epsilon: f64) -> usize {
    let k = k.max(1);
    assert!(epsilon > 0.0 && epsilon < 1.0, "epsilon must be in (0, 1)");
    let rungs = ((2.0 * k as f64).ln() / (1.0 + epsilon).ln()).ceil() as usize + 2;
    k * rungs.max(1)
}

/// One ladder rung: an incremental state plus, transiently, the position in
/// the current batch at which this rung was instantiated (elements before
/// it must not be offered — they were already gone when it was born).
struct Rung<'a> {
    state: Box<dyn State + 'a>,
    birth: usize,
}

/// The batched sieve engine. Feed batches with
/// [`BatchedSieve::process_batch`], close with [`BatchedSieve::finish`];
/// or drive a whole [`StreamSource`] through [`sieve_stream`].
pub struct BatchedSieve<'a> {
    f: &'a dyn SubmodularFn,
    k: usize,
    epsilon: f64,
    threads: usize,
    sieves: BTreeMap<i64, Rung<'a>>,
    best_singleton: f64,
    oracle_calls: u64,
    peak_live: usize,
    elements: usize,
    batches: usize,
}

impl<'a> BatchedSieve<'a> {
    pub fn new(f: &'a dyn SubmodularFn, k: usize, epsilon: f64, threads: usize) -> Self {
        assert!(epsilon > 0.0 && epsilon < 1.0, "epsilon must be in (0, 1)");
        BatchedSieve {
            f,
            k: k.max(1),
            epsilon,
            threads: threads.max(1),
            sieves: BTreeMap::new(),
            best_singleton: 0.0,
            oracle_calls: 0,
            peak_live: 0,
            elements: 0,
            batches: 0,
        }
    }

    /// Ladder rung indices covering `[lo, hi]` (same grid as the classic
    /// sieve: rung `i` is threshold `(1+ε)^i`).
    fn grid(&self, lo: f64, hi: f64) -> RangeInclusive<i64> {
        let base = 1.0 + self.epsilon;
        let i_lo = (lo.max(1e-12).ln() / base.ln()).floor() as i64;
        let i_hi = (hi.max(1e-12).ln() / base.ln()).ceil() as i64;
        i_lo..=i_hi
    }

    /// Live committed candidates across the ladder right now.
    pub fn live_candidates(&self) -> usize {
        self.sieves.values().map(|r| r.state.selected().len()).sum()
    }

    /// Peak of [`BatchedSieve::live_candidates`] over all processed batches.
    pub fn peak_live(&self) -> usize {
        self.peak_live
    }

    /// Process one arrival batch (order within the batch is arrival order).
    /// Output after any prefix of batches is identical to processing the
    /// same elements one at a time (see module docs).
    pub fn process_batch(&mut self, es: &[usize]) {
        if es.is_empty() {
            return;
        }
        self.batches += 1;
        self.elements += es.len();

        // ---- Phase A: ladder bookkeeping off one batched singleton call.
        // Singleton values are state-independent, so pricing them up front
        // is exact, not an upper bound.
        let singles = self.f.singleton_gains(es, self.threads);
        self.oracle_calls += es.len() as u64;
        // Rungs born mid-batch, keyed by ladder index → birth position.
        let mut births: BTreeMap<i64, usize> = BTreeMap::new();
        for (pos, &fe) in singles.iter().enumerate() {
            if fe > self.best_singleton {
                self.best_singleton = fe;
                let range =
                    self.grid(self.best_singleton, 2.0 * self.k as f64 * self.best_singleton);
                // Rungs that fell below the risen floor are discarded — in
                // the element-at-a-time reference they would never be read
                // again either, so dropping them before pricing only skips
                // wasted work.
                self.sieves.retain(|i, _| range.contains(i));
                births.retain(|i, _| range.contains(i));
                for i in range {
                    if !self.sieves.contains_key(&i) && !births.contains_key(&i) {
                        births.insert(i, pos);
                    }
                }
            }
        }
        for (&i, &pos) in &births {
            self.sieves.insert(i, Rung { state: self.f.state(), birth: pos });
        }

        // ---- Phase B: per rung, one batched pricing + an in-order walk.
        // Rungs are independent of each other (only `m` couples them, and
        // `m` was fully resolved in phase A), so rung-major order here is
        // output-identical to the element-major reference interleaving.
        let base = 1.0 + self.epsilon;
        let k = self.k;
        let threads = self.threads;
        let mut calls = 0u64;
        for (&i, rung) in self.sieves.iter_mut() {
            let start = rung.birth;
            rung.birth = 0; // transient: next batch offers everything
            let sub = &es[start..];
            if sub.is_empty() || rung.state.selected().len() >= k {
                continue;
            }
            let v = base.powi(i as i32);
            // A rung that has committed nothing yet prices every element at
            // its singleton value, which phase A already computed through
            // the identical fresh-state path — reuse it instead of issuing
            // a duplicate batched call (bit-identical, and newborn rungs
            // churn on exactly the adversarial streams where this matters).
            let cached_owned;
            let cached: &[f64] = if rung.state.selected().is_empty() {
                &singles[start..]
            } else {
                cached_owned = rung.state.par_batch_gains(sub, threads);
                calls += sub.len() as u64;
                &cached_owned
            };
            // `dirty` flips on the first commit after pricing: from then on
            // `cached` entries are upper bounds, exact before.
            let mut dirty = false;
            for (off, &e) in sub.iter().enumerate() {
                let sel = rung.state.selected().len();
                if sel >= k {
                    break;
                }
                let needed = (v / 2.0 - rung.state.value()) / (k - sel) as f64;
                let ub = cached[off];
                if ub < needed || ub <= 0.0 {
                    // true gain ≤ cached upper bound < threshold: reject
                    // without touching the oracle.
                    continue;
                }
                if dirty {
                    let g = rung.state.gain(e);
                    calls += 1;
                    crate::trace_counter!("sieve.reprices").incr();
                    trace::event_with("sieve.reprice", || {
                        vec![("rung", (i as f64).into()), ("element", e.into())]
                    });
                    if g >= needed && g > 0.0 {
                        rung.state.push(e);
                    }
                } else {
                    // state unchanged since pricing ⇒ cached value is exact
                    rung.state.push(e);
                    dirty = true;
                }
            }
        }
        self.oracle_calls += calls;
        self.peak_live = self.peak_live.max(self.live_candidates());
    }

    /// Close the stream: pick the best sieve (ties resolve to the highest
    /// rung, matching the classic implementation) and assemble the summary.
    pub fn finish(self) -> SieveResult {
        let mut best: Option<(f64, Vec<usize>)> = None;
        let mut union: Vec<usize> = Vec::new();
        for rung in self.sieves.values() {
            let v = rung.state.value();
            let sel = rung.state.selected().to_vec();
            union.extend_from_slice(&sel);
            if best.as_ref().map(|(bv, _)| v >= *bv).unwrap_or(true) {
                best = Some((v, sel));
            }
        }
        union.sort_unstable();
        union.dedup();
        let (value, solution) = best.unwrap_or((0.0, Vec::new()));
        SieveResult {
            solution,
            value,
            union,
            oracle_calls: self.oracle_calls,
            peak_live: self.peak_live,
            bound: candidate_bound(self.k, self.epsilon),
            elements: self.elements,
            batches: self.batches,
        }
    }
}

/// Drive `source` to its end through a [`BatchedSieve`] — the one-pass
/// local stage of the distributed protocol, and the engine behind the
/// `sieve_streaming` algorithm wrapper.
///
/// A stream ends on exhaustion *or* on a source error; fallible sources
/// (disk ingest) retain the error, so callers that must not accept a
/// result computed on a truncated corpus should check
/// [`StreamSource::error`] afterwards (the end-to-end tests and the
/// streaming example do).
pub fn sieve_stream(
    f: &dyn SubmodularFn,
    source: &mut dyn StreamSource,
    k: usize,
    epsilon: f64,
    batch: usize,
    threads: usize,
) -> SieveResult {
    let mut engine = BatchedSieve::new(f, k, epsilon, threads);
    loop {
        let es = source.next_batch(batch.max(1));
        if es.is_empty() {
            break;
        }
        engine.process_batch(&es);
    }
    engine.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraints::cardinality::Cardinality;
    use crate::constraints::Constraint;
    use crate::data::synth::{gaussian_blobs, SynthConfig};
    use crate::data::transactions::zipf_transactions;
    use crate::objective::coverage::Coverage;
    use crate::objective::facility::FacilityLocation;
    use crate::stream::source::VecSource;
    use std::sync::Arc;

    /// The classic element-at-a-time sieve (the pre-refactor
    /// `algorithms::sieve_streaming` loop, verbatim semantics) — the oracle
    /// the batched engine must match exactly.
    fn reference_sieve(
        f: &dyn SubmodularFn,
        ground: &[usize],
        k: usize,
        epsilon: f64,
    ) -> (Vec<usize>, f64) {
        let base = 1.0 + epsilon;
        let grid = |lo: f64, hi: f64| {
            let i_lo = (lo.max(1e-12).ln() / base.ln()).floor() as i64;
            let i_hi = (hi.max(1e-12).ln() / base.ln()).ceil() as i64;
            i_lo..=i_hi
        };
        let mut sieves: BTreeMap<i64, Box<dyn State + '_>> = BTreeMap::new();
        let mut best_singleton = 0.0f64;
        for &e in ground {
            let mut probe = f.state();
            let fe = probe.gain(e);
            if fe > best_singleton {
                best_singleton = fe;
                let range = grid(best_singleton, 2.0 * k as f64 * best_singleton);
                sieves.retain(|i, _| range.contains(i));
                for i in range {
                    sieves.entry(i).or_insert_with(|| f.state());
                }
            }
            for (&i, sieve) in sieves.iter_mut() {
                let sel = sieve.selected().len();
                if sel >= k {
                    continue;
                }
                let v = base.powi(i as i32);
                let needed = (v / 2.0 - sieve.value()) / (k - sel) as f64;
                let g = sieve.gain(e);
                if g >= needed && g > 0.0 {
                    sieve.push(e);
                }
            }
        }
        match sieves
            .into_values()
            .max_by(|a, b| a.value().partial_cmp(&b.value()).unwrap())
        {
            Some(s) => (s.selected().to_vec(), s.value()),
            None => (Vec::new(), 0.0),
        }
    }

    #[test]
    fn engine_matches_element_at_a_time_reference_exactly() {
        // facility
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(220, 6), 17));
        let fac = FacilityLocation::from_dataset(&ds);
        // coverage
        let td = Arc::new(zipf_transactions(180, 160, 7, 1.1, 4));
        let cov = Coverage::new(&td);
        let cases: [(&str, &dyn SubmodularFn, usize); 2] =
            [("facility", &fac, 220), ("coverage", &cov, 180)];
        for (label, f, n) in cases {
            let ground: Vec<usize> = (0..n).rev().collect(); // non-trivial order
            let (ref_sol, ref_val) = reference_sieve(f, &ground, 8, 0.1);
            for batch in [1usize, 7, 64, 4096] {
                let mut src = VecSource::new(ground.clone());
                let r = sieve_stream(f, &mut src, 8, 0.1, batch, 1);
                assert_eq!(r.solution, ref_sol, "{label}: batch={batch} changed the solution");
                assert_eq!(r.value, ref_val, "{label}: batch={batch} changed the value");
                assert_eq!(r.elements, n);
            }
        }
    }

    #[test]
    fn peak_live_within_bound_even_on_adversarial_order() {
        // Ascending singleton values force maximal ladder churn.
        use crate::stream::source::{DriftSource, StreamOrder};
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(300, 6), 23));
        let f = FacilityLocation::from_dataset(&ds);
        for (k, eps) in [(5usize, 0.1f64), (10, 0.2), (20, 0.5)] {
            let mut src = DriftSource::new(&ds, ds.ids(), StreamOrder::ValueAscending);
            let r = sieve_stream(&f, &mut src, k, eps, 32, 1);
            assert!(
                r.peak_live <= r.bound,
                "k={k} ε={eps}: peak {} exceeds bound {}",
                r.peak_live,
                r.bound
            );
            assert!(r.peak_live > 0, "sieve committed nothing");
            assert!(r.union.len() <= r.bound);
            assert!(r.solution.len() <= k);
        }
    }

    #[test]
    fn union_contains_solution_and_is_deduped() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(150, 6), 29));
        let f = FacilityLocation::from_dataset(&ds);
        let mut src = VecSource::shuffled(ds.ids(), 3);
        let r = sieve_stream(&f, &mut src, 6, 0.2, 16, 1);
        let union: std::collections::HashSet<_> = r.union.iter().collect();
        assert_eq!(union.len(), r.union.len(), "union must be deduped");
        for e in &r.solution {
            assert!(union.contains(e), "solution must be inside the union");
        }
        let mut sorted = r.union.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, r.union, "union must be sorted");
    }

    #[test]
    fn quality_at_least_half_of_greedy_minus_eps() {
        use crate::algorithms::{greedy::Greedy, Maximizer};
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(200, 8), 31));
        let f = FacilityLocation::from_dataset(&ds);
        let ground = ds.ids();
        let c = Cardinality::new(10);
        let mut rng = crate::util::rng::Rng::new(0);
        let greedy = Greedy.maximize(&f, &ground, &c, &mut rng);
        let mut src = VecSource::new(ground.clone());
        let r = sieve_stream(&f, &mut src, c.rho(), 0.1, 64, 1);
        assert!(
            r.value >= 0.45 * greedy.value,
            "sieve {} vs greedy {}",
            r.value,
            greedy.value
        );
    }

    #[test]
    fn empty_stream_and_degenerate_inputs() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(20, 4), 5));
        let f = FacilityLocation::from_dataset(&ds);
        let mut src = VecSource::new(Vec::new());
        let r = sieve_stream(&f, &mut src, 4, 0.2, 8, 1);
        assert!(r.solution.is_empty());
        assert_eq!(r.value, 0.0);
        assert_eq!(r.elements, 0);
        assert_eq!(r.peak_live, 0);
    }

    #[test]
    fn candidate_bound_monotonicity() {
        // Finer ladders and larger budgets can only raise the bound.
        assert!(candidate_bound(10, 0.1) >= candidate_bound(10, 0.5));
        assert!(candidate_bound(20, 0.1) >= candidate_bound(10, 0.1));
        assert!(candidate_bound(1, 0.5) >= 1);
    }

    #[test]
    #[should_panic]
    fn bad_epsilon_rejected() {
        candidate_bound(5, 1.0);
    }
}
