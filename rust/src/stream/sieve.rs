//! The batched sieve-streaming engine — one-pass, bounded-memory
//! cardinality-constrained maximization over a [`StreamSource`], with every
//! hot pricing routed through the shared sharded gain engine
//! (`objective::engine::ShardedGainEngine`, behind
//! [`State::par_batch_gains`] and [`SubmodularFn::singleton_gains`]): the
//! ladder inherits the engine's bit-identical-across-threads contract for
//! every objective, and objectives with closed-form singletons (modular
//! weights, coverage set sizes) price the ladder with no state work at all.
//!
//! ## Algorithm
//!
//! Classic Sieve-Streaming (Badanidiyuru et al. 2014): maintain a geometric
//! threshold ladder `v = (1+ε)^i` lazily covering `[m, 2·k·m]`, where `m`
//! is the best singleton value seen so far; the sieve at threshold `v`
//! keeps an element iff its marginal gain is at least
//! `(v/2 − f(S_v)) / (k − |S_v|)`; the best sieve at end of stream is a
//! `(1/2 − ε)`-approximation in **one pass**, for any arrival order.
//!
//! ## Batching without changing a single answer
//!
//! The one-at-a-time formulation starves a batched/parallel oracle. This
//! engine prices a whole incoming batch at once and still produces output
//! **identical to element-at-a-time processing**, by exploiting
//! submodularity twice per batch:
//!
//! 1. **Singletons** `f({e})` do not depend on any sieve state, so the
//!    ladder bookkeeping for the whole batch is driven by one batched call.
//! 2. Per sieve, gains priced at batch start are **upper bounds** once the
//!    sieve grows mid-batch. Walking the batch in arrival order: a cached
//!    gain below the admission threshold proves the true gain is below it
//!    (reject with zero extra oracle work); a cached gain above it is exact
//!    if the sieve has not grown since pricing, and is otherwise re-priced
//!    with one fresh `gain` call before the test. Since at most `k`
//!    elements ever commit per sieve, re-pricings are rare and the oracle
//!    sees wide batches almost exclusively.
//!
//! Both batched paths honor the gain engine's bit-identical-across-threads
//! contract — which since the engine refactor holds for EVERY objective,
//! not just facility/coverage/cut — so this engine's output is invariant to
//! **both** the batch size and the thread count (asserted by
//! `tests/integration_stream`).
//!
//! ## Memory bound
//!
//! Live state is one incremental [`State`] per ladder rung, each holding at
//! most `k` committed elements. The lazily instantiated ladder spans
//! `[m, 2·k·m]`, i.e. at most `⌈log_{1+ε}(2k)⌉ + 2` rungs regardless of the
//! data scale Δ (rungs below a risen `m` are dropped), so the peak number
//! of live candidates is at most [`candidate_bound`]`(k, ε) =
//! k·(⌈log_{1+ε}(2k)⌉ + 2) = O(k·log(k)/ε)` — the engine tracks the
//! realized peak ([`SieveResult::peak_live`]) and reports it against this
//! bound.
//!
//! ## Checkpoints (lineage-style partial-progress recovery)
//!
//! A [`Checkpoint`] is a tiny durable snapshot of the live ladder taken at
//! a batch boundary: per rung the threshold index and the committed
//! elements *in commit order*, plus the scalar counters. Because every
//! rung's [`State`] is exactly the result of pushing its committed
//! elements in that order onto a fresh state, [`BatchedSieve::restore`]
//! rebuilds the full engine **bit-identically** from a checkpoint by
//! replaying at most `k` pushes per rung — `O(k·log(k)/ε)` pushes total —
//! instead of re-pricing the entire checkpointed stream prefix. That is
//! the whole recovery story for `RecoveryPolicy::Resume`: salvage the
//! crashed machine's last checkpoint, restore, replay only the tail.
//!
//! **Cost and frequency guidance.** Taking a checkpoint copies only
//! committed element ids — at most [`candidate_bound`]`(k, ε)` `usize`s
//! plus a handful of scalars; it issues **zero** oracle calls. With
//! checkpoint period `B` (batches), the expected recomputation on a crash
//! is `B/2` batches of pricing, while the steady-state overhead is one
//! `O(k·log(k)/ε)`-word copy every `B` batches. Since a batch prices
//! `batch_size` elements through the oracle, the copy is almost always
//! orders of magnitude cheaper than one batch: small `B` (even `B = 1`)
//! is affordable whenever the oracle does real work per element, and the
//! `bench_protocols` checkpoint rows (`checkpoint_every ∈ {off, 8, 64}`)
//! track the realized overhead in the CI perf trail.

use std::collections::BTreeMap;
use std::ops::RangeInclusive;

use super::source::StreamSource;
use crate::objective::{State, SubmodularFn};
use crate::util::trace;

/// Outcome of one single-pass sieve run.
#[derive(Debug, Clone, Default)]
pub struct SieveResult {
    /// Best sieve's selection, in commit order.
    pub solution: Vec<usize>,
    /// f(solution) as tracked incrementally by the winning sieve.
    pub value: f64,
    /// Union of every live sieve's committed elements (sorted, deduped) —
    /// the machine's summary in the distributed sieve→merge protocol.
    pub union: Vec<usize>,
    /// Marginal-gain oracle evaluations issued (batched calls count their
    /// width).
    pub oracle_calls: u64,
    /// Peak live committed candidates across the ladder at any batch
    /// boundary — must stay ≤ [`SieveResult::bound`].
    pub peak_live: usize,
    /// The O(k·log(k)/ε) candidate bound ([`candidate_bound`]).
    pub bound: usize,
    /// Elements consumed from the stream.
    pub elements: usize,
    /// Batches consumed from the stream.
    pub batches: usize,
}

/// Hard ceiling on live committed candidates: `k` per rung times the
/// maximum number of simultaneously live rungs, `⌈log_{1+ε}(2k)⌉ + 2`
/// (the lazy ladder spans `[m, 2km]`, a fixed ratio of `2k` — independent
/// of the data's value scale).
pub fn candidate_bound(k: usize, epsilon: f64) -> usize {
    let k = k.max(1);
    assert!(epsilon > 0.0 && epsilon < 1.0, "epsilon must be in (0, 1)");
    let rungs = ((2.0 * k as f64).ln() / (1.0 + epsilon).ln()).ceil() as usize + 2;
    k * rungs.max(1)
}

/// One ladder rung: an incremental state plus, transiently, the position in
/// the current batch at which this rung was instantiated (elements before
/// it must not be offered — they were already gone when it was born).
struct Rung<'a> {
    state: Box<dyn State + 'a>,
    birth: usize,
}

/// Durable snapshot of a [`BatchedSieve`] at a batch boundary — everything
/// needed to rebuild the engine bit-identically via
/// [`BatchedSieve::restore`] (the objective and thread budget are
/// reconstruction parameters, not state). See the module docs for the
/// cost/frequency guidance.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub k: usize,
    pub epsilon: f64,
    pub best_singleton: f64,
    pub oracle_calls: u64,
    pub peak_live: usize,
    pub elements: usize,
    pub batches: usize,
    /// Per live rung: (ladder index, committed elements in commit order).
    pub rungs: Vec<(i64, Vec<usize>)>,
}

/// The batched sieve engine. Feed batches with
/// [`BatchedSieve::process_batch`], close with [`BatchedSieve::finish`];
/// or drive a whole [`StreamSource`] through [`sieve_stream`].
pub struct BatchedSieve<'a> {
    f: &'a dyn SubmodularFn,
    k: usize,
    epsilon: f64,
    threads: usize,
    sieves: BTreeMap<i64, Rung<'a>>,
    best_singleton: f64,
    oracle_calls: u64,
    peak_live: usize,
    elements: usize,
    batches: usize,
    /// Snapshot period in batches (0 = checkpointing off).
    checkpoint_period: usize,
    last_checkpoint: Option<Checkpoint>,
}

impl<'a> BatchedSieve<'a> {
    pub fn new(f: &'a dyn SubmodularFn, k: usize, epsilon: f64, threads: usize) -> Self {
        assert!(epsilon > 0.0 && epsilon < 1.0, "epsilon must be in (0, 1)");
        BatchedSieve {
            f,
            k: k.max(1),
            epsilon,
            threads: threads.max(1),
            sieves: BTreeMap::new(),
            best_singleton: 0.0,
            oracle_calls: 0,
            peak_live: 0,
            elements: 0,
            batches: 0,
            checkpoint_period: 0,
            last_checkpoint: None,
        }
    }

    /// Take a [`Checkpoint`] automatically every `b` batches (0 disables).
    /// The latest snapshot is available from
    /// [`BatchedSieve::last_checkpoint`].
    pub fn checkpoint_every(mut self, b: usize) -> Self {
        self.checkpoint_period = b;
        self
    }

    /// The most recent automatic checkpoint, if any was taken.
    pub fn last_checkpoint(&self) -> Option<&Checkpoint> {
        self.last_checkpoint.as_ref()
    }

    /// Snapshot the live ladder (cheap: copies committed ids and scalars,
    /// zero oracle calls). Meaningful at batch boundaries, where rung
    /// `birth` offsets are always zero.
    pub fn checkpoint(&self) -> Checkpoint {
        Checkpoint {
            k: self.k,
            epsilon: self.epsilon,
            best_singleton: self.best_singleton,
            oracle_calls: self.oracle_calls,
            peak_live: self.peak_live,
            elements: self.elements,
            batches: self.batches,
            rungs: self
                .sieves
                .iter()
                .map(|(&i, rung)| (i, rung.state.selected().to_vec()))
                .collect(),
        }
    }

    /// Rebuild an engine bit-identically from `ckpt`: each rung's state is
    /// reconstructed by replaying its committed elements in commit order on
    /// a fresh state — at most `k` pushes per rung, no re-pricing of the
    /// checkpointed stream prefix. Counters (including `oracle_calls`) are
    /// restored from the snapshot, so a resumed run's final accounting
    /// matches the uninterrupted run exactly.
    pub fn restore(f: &'a dyn SubmodularFn, threads: usize, ckpt: &Checkpoint) -> Self {
        let mut engine = BatchedSieve::new(f, ckpt.k, ckpt.epsilon, threads);
        for (i, selected) in &ckpt.rungs {
            let mut state = f.state();
            for &e in selected {
                state.push(e);
            }
            engine.sieves.insert(*i, Rung { state, birth: 0 });
        }
        engine.best_singleton = ckpt.best_singleton;
        engine.oracle_calls = ckpt.oracle_calls;
        engine.peak_live = ckpt.peak_live;
        engine.elements = ckpt.elements;
        engine.batches = ckpt.batches;
        engine
    }

    /// Ladder rung indices covering `[lo, hi]` (same grid as the classic
    /// sieve: rung `i` is threshold `(1+ε)^i`).
    fn grid(&self, lo: f64, hi: f64) -> RangeInclusive<i64> {
        let base = 1.0 + self.epsilon;
        let i_lo = (lo.max(1e-12).ln() / base.ln()).floor() as i64;
        let i_hi = (hi.max(1e-12).ln() / base.ln()).ceil() as i64;
        i_lo..=i_hi
    }

    /// Live committed candidates across the ladder right now.
    pub fn live_candidates(&self) -> usize {
        self.sieves.values().map(|r| r.state.selected().len()).sum()
    }

    /// Peak of [`BatchedSieve::live_candidates`] over all processed batches.
    pub fn peak_live(&self) -> usize {
        self.peak_live
    }

    /// Process one arrival batch (order within the batch is arrival order).
    /// Output after any prefix of batches is identical to processing the
    /// same elements one at a time (see module docs).
    pub fn process_batch(&mut self, es: &[usize]) {
        if es.is_empty() {
            return;
        }
        self.batches += 1;
        self.elements += es.len();

        // ---- Phase A: ladder bookkeeping off one batched singleton call.
        // Singleton values are state-independent, so pricing them up front
        // is exact, not an upper bound.
        let singles = self.f.singleton_gains(es, self.threads);
        self.oracle_calls += es.len() as u64;
        // Rungs born mid-batch, keyed by ladder index → birth position.
        let mut births: BTreeMap<i64, usize> = BTreeMap::new();
        for (pos, &fe) in singles.iter().enumerate() {
            if fe > self.best_singleton {
                self.best_singleton = fe;
                let range =
                    self.grid(self.best_singleton, 2.0 * self.k as f64 * self.best_singleton);
                // Rungs that fell below the risen floor are discarded — in
                // the element-at-a-time reference they would never be read
                // again either, so dropping them before pricing only skips
                // wasted work.
                self.sieves.retain(|i, _| range.contains(i));
                births.retain(|i, _| range.contains(i));
                for i in range {
                    if !self.sieves.contains_key(&i) && !births.contains_key(&i) {
                        births.insert(i, pos);
                    }
                }
            }
        }
        for (&i, &pos) in &births {
            self.sieves.insert(i, Rung { state: self.f.state(), birth: pos });
        }

        // ---- Phase B: per rung, one batched pricing + an in-order walk.
        // Rungs are independent of each other (only `m` couples them, and
        // `m` was fully resolved in phase A), so rung-major order here is
        // output-identical to the element-major reference interleaving.
        let base = 1.0 + self.epsilon;
        let k = self.k;
        let threads = self.threads;
        let mut calls = 0u64;
        for (&i, rung) in self.sieves.iter_mut() {
            let start = rung.birth;
            rung.birth = 0; // transient: next batch offers everything
            let sub = &es[start..];
            if sub.is_empty() || rung.state.selected().len() >= k {
                continue;
            }
            let v = base.powi(i as i32);
            // A rung that has committed nothing yet prices every element at
            // its singleton value, which phase A already computed through
            // the identical fresh-state path — reuse it instead of issuing
            // a duplicate batched call (bit-identical, and newborn rungs
            // churn on exactly the adversarial streams where this matters).
            let cached_owned;
            let cached: &[f64] = if rung.state.selected().is_empty() {
                &singles[start..]
            } else {
                cached_owned = rung.state.par_batch_gains(sub, threads);
                calls += sub.len() as u64;
                &cached_owned
            };
            // `dirty` flips on the first commit after pricing: from then on
            // `cached` entries are upper bounds, exact before.
            let mut dirty = false;
            for (off, &e) in sub.iter().enumerate() {
                let sel = rung.state.selected().len();
                if sel >= k {
                    break;
                }
                let needed = (v / 2.0 - rung.state.value()) / (k - sel) as f64;
                let ub = cached[off];
                if ub < needed || ub <= 0.0 {
                    // true gain ≤ cached upper bound < threshold: reject
                    // without touching the oracle.
                    continue;
                }
                if dirty {
                    let g = rung.state.gain(e);
                    calls += 1;
                    crate::trace_counter!("sieve.reprices").incr();
                    trace::event_with("sieve.reprice", || {
                        vec![("rung", (i as f64).into()), ("element", e.into())]
                    });
                    if g >= needed && g > 0.0 {
                        rung.state.push(e);
                    }
                } else {
                    // state unchanged since pricing ⇒ cached value is exact
                    rung.state.push(e);
                    dirty = true;
                }
            }
        }
        self.oracle_calls += calls;
        self.peak_live = self.peak_live.max(self.live_candidates());
        if self.checkpoint_period > 0 && self.batches % self.checkpoint_period == 0 {
            self.last_checkpoint = Some(self.checkpoint());
        }
    }

    /// Close the stream: pick the best sieve (ties resolve to the highest
    /// rung, matching the classic implementation) and assemble the summary.
    pub fn finish(self) -> SieveResult {
        let mut best: Option<(f64, Vec<usize>)> = None;
        let mut union: Vec<usize> = Vec::new();
        for rung in self.sieves.values() {
            let v = rung.state.value();
            let sel = rung.state.selected().to_vec();
            union.extend_from_slice(&sel);
            if best.as_ref().map(|(bv, _)| v >= *bv).unwrap_or(true) {
                best = Some((v, sel));
            }
        }
        union.sort_unstable();
        union.dedup();
        let (value, solution) = best.unwrap_or((0.0, Vec::new()));
        SieveResult {
            solution,
            value,
            union,
            oracle_calls: self.oracle_calls,
            peak_live: self.peak_live,
            bound: candidate_bound(self.k, self.epsilon),
            elements: self.elements,
            batches: self.batches,
        }
    }
}

/// Drive `source` to its end through a [`BatchedSieve`] — the one-pass
/// local stage of the distributed protocol, and the engine behind the
/// `sieve_streaming` algorithm wrapper.
///
/// A stream ends on exhaustion *or* on a source error; fallible sources
/// (disk ingest) retain the error, so callers that must not accept a
/// result computed on a truncated corpus should check
/// [`StreamSource::error`] afterwards (the end-to-end tests and the
/// streaming example do).
pub fn sieve_stream(
    f: &dyn SubmodularFn,
    source: &mut dyn StreamSource,
    k: usize,
    epsilon: f64,
    batch: usize,
    threads: usize,
) -> SieveResult {
    let mut engine = BatchedSieve::new(f, k, epsilon, threads);
    loop {
        let es = source.next_batch(batch.max(1));
        if es.is_empty() {
            break;
        }
        engine.process_batch(&es);
    }
    engine.finish()
}

/// A [`sieve_stream`] run recovered through a checkpoint, with salvage
/// accounting. See [`sieve_stream_resumed`].
#[derive(Debug, Clone)]
pub struct ResumedSieve {
    /// Final result — bit-identical to the uninterrupted [`sieve_stream`].
    pub result: SieveResult,
    /// Elements whose pricing the checkpoint made durable (not re-scanned
    /// by the restore path).
    pub salvaged_elements: usize,
    /// Batches the recovery actually replayed (the tail after the
    /// checkpoint).
    pub replayed_batches: usize,
    /// Batches of pricing the checkpoint saved vs a from-scratch recompute.
    pub saved_batches: usize,
}

/// Drive `source` through a sieve that crashes after `ckpt_batches`
/// batches and recovers via checkpoint restore: the prefix models the
/// crashed machine's pre-crash work (whose last durable snapshot a real
/// deployment would read back from disk), [`BatchedSieve::restore`]
/// rebuilds the ladder from that snapshot with `O(k·log(k)/ε)` pushes, and
/// only the tail is replayed. The output is **bit-identical** to the
/// uninterrupted [`sieve_stream`] on the same source — every field,
/// including `oracle_calls` — which `RecoveryPolicy::Resume` relies on.
pub fn sieve_stream_resumed(
    f: &dyn SubmodularFn,
    source: &mut dyn StreamSource,
    k: usize,
    epsilon: f64,
    batch: usize,
    threads: usize,
    ckpt_batches: usize,
) -> ResumedSieve {
    // Pre-crash prefix: the work the dead machine completed and snapshot.
    let mut prefix = BatchedSieve::new(f, k, epsilon, threads);
    let mut ran = 0usize;
    while ran < ckpt_batches {
        let es = source.next_batch(batch.max(1));
        if es.is_empty() {
            break;
        }
        prefix.process_batch(&es);
        ran += 1;
    }
    let ckpt = prefix.checkpoint();
    drop(prefix); // the machine is gone; only the durable snapshot survives

    // Recovery: restore from the snapshot and replay the tail only.
    let mut engine = BatchedSieve::restore(f, threads, &ckpt);
    let mut replayed = 0usize;
    loop {
        let es = source.next_batch(batch.max(1));
        if es.is_empty() {
            break;
        }
        engine.process_batch(&es);
        replayed += 1;
    }
    ResumedSieve {
        result: engine.finish(),
        salvaged_elements: ckpt.elements,
        replayed_batches: replayed,
        saved_batches: ckpt.batches,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraints::cardinality::Cardinality;
    use crate::constraints::Constraint;
    use crate::data::synth::{gaussian_blobs, SynthConfig};
    use crate::data::transactions::zipf_transactions;
    use crate::objective::coverage::Coverage;
    use crate::objective::facility::FacilityLocation;
    use crate::stream::source::VecSource;
    use std::sync::Arc;

    /// The classic element-at-a-time sieve (the pre-refactor
    /// `algorithms::sieve_streaming` loop, verbatim semantics) — the oracle
    /// the batched engine must match exactly.
    fn reference_sieve(
        f: &dyn SubmodularFn,
        ground: &[usize],
        k: usize,
        epsilon: f64,
    ) -> (Vec<usize>, f64) {
        let base = 1.0 + epsilon;
        let grid = |lo: f64, hi: f64| {
            let i_lo = (lo.max(1e-12).ln() / base.ln()).floor() as i64;
            let i_hi = (hi.max(1e-12).ln() / base.ln()).ceil() as i64;
            i_lo..=i_hi
        };
        let mut sieves: BTreeMap<i64, Box<dyn State + '_>> = BTreeMap::new();
        let mut best_singleton = 0.0f64;
        for &e in ground {
            let mut probe = f.state();
            let fe = probe.gain(e);
            if fe > best_singleton {
                best_singleton = fe;
                let range = grid(best_singleton, 2.0 * k as f64 * best_singleton);
                sieves.retain(|i, _| range.contains(i));
                for i in range {
                    sieves.entry(i).or_insert_with(|| f.state());
                }
            }
            for (&i, sieve) in sieves.iter_mut() {
                let sel = sieve.selected().len();
                if sel >= k {
                    continue;
                }
                let v = base.powi(i as i32);
                let needed = (v / 2.0 - sieve.value()) / (k - sel) as f64;
                let g = sieve.gain(e);
                if g >= needed && g > 0.0 {
                    sieve.push(e);
                }
            }
        }
        match sieves
            .into_values()
            .max_by(|a, b| a.value().partial_cmp(&b.value()).unwrap())
        {
            Some(s) => (s.selected().to_vec(), s.value()),
            None => (Vec::new(), 0.0),
        }
    }

    #[test]
    fn engine_matches_element_at_a_time_reference_exactly() {
        // facility
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(220, 6), 17));
        let fac = FacilityLocation::from_dataset(&ds);
        // coverage
        let td = Arc::new(zipf_transactions(180, 160, 7, 1.1, 4));
        let cov = Coverage::new(&td);
        let cases: [(&str, &dyn SubmodularFn, usize); 2] =
            [("facility", &fac, 220), ("coverage", &cov, 180)];
        for (label, f, n) in cases {
            let ground: Vec<usize> = (0..n).rev().collect(); // non-trivial order
            let (ref_sol, ref_val) = reference_sieve(f, &ground, 8, 0.1);
            for batch in [1usize, 7, 64, 4096] {
                let mut src = VecSource::new(ground.clone());
                let r = sieve_stream(f, &mut src, 8, 0.1, batch, 1);
                assert_eq!(r.solution, ref_sol, "{label}: batch={batch} changed the solution");
                assert_eq!(r.value, ref_val, "{label}: batch={batch} changed the value");
                assert_eq!(r.elements, n);
            }
        }
    }

    #[test]
    fn peak_live_within_bound_even_on_adversarial_order() {
        // Ascending singleton values force maximal ladder churn.
        use crate::stream::source::{DriftSource, StreamOrder};
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(300, 6), 23));
        let f = FacilityLocation::from_dataset(&ds);
        for (k, eps) in [(5usize, 0.1f64), (10, 0.2), (20, 0.5)] {
            let mut src = DriftSource::new(&ds, ds.ids(), StreamOrder::ValueAscending);
            let r = sieve_stream(&f, &mut src, k, eps, 32, 1);
            assert!(
                r.peak_live <= r.bound,
                "k={k} ε={eps}: peak {} exceeds bound {}",
                r.peak_live,
                r.bound
            );
            assert!(r.peak_live > 0, "sieve committed nothing");
            assert!(r.union.len() <= r.bound);
            assert!(r.solution.len() <= k);
        }
    }

    #[test]
    fn union_contains_solution_and_is_deduped() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(150, 6), 29));
        let f = FacilityLocation::from_dataset(&ds);
        let mut src = VecSource::shuffled(ds.ids(), 3);
        let r = sieve_stream(&f, &mut src, 6, 0.2, 16, 1);
        let union: std::collections::HashSet<_> = r.union.iter().collect();
        assert_eq!(union.len(), r.union.len(), "union must be deduped");
        for e in &r.solution {
            assert!(union.contains(e), "solution must be inside the union");
        }
        let mut sorted = r.union.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, r.union, "union must be sorted");
    }

    #[test]
    fn quality_at_least_half_of_greedy_minus_eps() {
        use crate::algorithms::{greedy::Greedy, Maximizer};
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(200, 8), 31));
        let f = FacilityLocation::from_dataset(&ds);
        let ground = ds.ids();
        let c = Cardinality::new(10);
        let mut rng = crate::util::rng::Rng::new(0);
        let greedy = Greedy.maximize(&f, &ground, &c, &mut rng);
        let mut src = VecSource::new(ground.clone());
        let r = sieve_stream(&f, &mut src, c.rho(), 0.1, 64, 1);
        assert!(
            r.value >= 0.45 * greedy.value,
            "sieve {} vs greedy {}",
            r.value,
            greedy.value
        );
    }

    #[test]
    fn empty_stream_and_degenerate_inputs() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(20, 4), 5));
        let f = FacilityLocation::from_dataset(&ds);
        let mut src = VecSource::new(Vec::new());
        let r = sieve_stream(&f, &mut src, 4, 0.2, 8, 1);
        assert!(r.solution.is_empty());
        assert_eq!(r.value, 0.0);
        assert_eq!(r.elements, 0);
        assert_eq!(r.peak_live, 0);
    }

    fn assert_same_result(a: &SieveResult, b: &SieveResult, what: &str) {
        assert_eq!(a.solution, b.solution, "{what}: solution");
        assert_eq!(a.value.to_bits(), b.value.to_bits(), "{what}: value");
        assert_eq!(a.union, b.union, "{what}: union");
        assert_eq!(a.oracle_calls, b.oracle_calls, "{what}: oracle_calls");
        assert_eq!(a.peak_live, b.peak_live, "{what}: peak_live");
        assert_eq!(a.elements, b.elements, "{what}: elements");
        assert_eq!(a.batches, b.batches, "{what}: batches");
    }

    #[test]
    fn checkpoint_restore_replay_bit_identity_across_batch_and_threads() {
        // satellite: snapshot -> restore -> replay must equal the
        // uninterrupted run in EVERY field, at batch ∈ {1, 64, 4096} ×
        // threads ∈ {1, 2, 8}, for several crash points.
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(260, 6), 17));
        let f = FacilityLocation::from_dataset(&ds);
        let ground: Vec<usize> = (0..260).rev().collect();
        for batch in [1usize, 64, 4096] {
            for threads in [1usize, 2, 8] {
                let mut src = VecSource::new(ground.clone());
                let full = sieve_stream(&f, &mut src, 8, 0.1, batch, threads);
                let total_batches = full.batches;
                for ckpt_at in [0, 1, total_batches / 2, total_batches] {
                    let mut src = VecSource::new(ground.clone());
                    let resumed =
                        sieve_stream_resumed(&f, &mut src, 8, 0.1, batch, threads, ckpt_at);
                    assert_same_result(
                        &resumed.result,
                        &full,
                        &format!("batch={batch} threads={threads} ckpt={ckpt_at}"),
                    );
                    assert_eq!(
                        resumed.saved_batches,
                        ckpt_at.min(total_batches),
                        "batch={batch} ckpt={ckpt_at}"
                    );
                    assert_eq!(
                        resumed.replayed_batches,
                        total_batches - ckpt_at.min(total_batches)
                    );
                }
            }
        }
    }

    #[test]
    fn automatic_checkpoints_land_on_the_period() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(100, 4), 3));
        let f = FacilityLocation::from_dataset(&ds);
        let mut engine = BatchedSieve::new(&f, 5, 0.2, 1).checkpoint_every(3);
        assert!(engine.last_checkpoint().is_none());
        let ids: Vec<usize> = (0..100).collect();
        for chunk in ids.chunks(10) {
            engine.process_batch(chunk);
        }
        let ckpt = engine.last_checkpoint().expect("periodic snapshot taken");
        assert_eq!(ckpt.batches, 9, "last multiple of 3 within 10 batches");
        assert_eq!(ckpt.elements, 90);
        // the snapshot itself restores to a working engine
        let restored = BatchedSieve::restore(&f, 1, ckpt);
        assert_eq!(restored.batches, 9);
        assert_eq!(restored.live_candidates(), ckpt.rungs.iter().map(|(_, s)| s.len()).sum());
    }

    #[test]
    fn resume_salvage_accounting_is_positive_midstream() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(120, 4), 11));
        let f = FacilityLocation::from_dataset(&ds);
        let ground: Vec<usize> = (0..120).collect();
        let mut src = VecSource::new(ground.clone());
        let resumed = sieve_stream_resumed(&f, &mut src, 6, 0.2, 8, 1, 7);
        assert!(resumed.salvaged_elements > 0);
        assert_eq!(resumed.saved_batches, 7);
        assert_eq!(resumed.replayed_batches, 15 - 7, "120 elements / batch 8 = 15 batches");
    }

    #[test]
    fn candidate_bound_monotonicity() {
        // Finer ladders and larger budgets can only raise the bound.
        assert!(candidate_bound(10, 0.1) >= candidate_bound(10, 0.5));
        assert!(candidate_bound(20, 0.1) >= candidate_bound(10, 0.1));
        assert!(candidate_bound(1, 0.5) >= 1);
    }

    #[test]
    #[should_panic]
    fn bad_epsilon_rejected() {
        candidate_bound(5, 1.0);
    }
}
