//! StreamGreedi — the two-stage distributed sieve→merge protocol: each of
//! the m machines makes **one bounded-memory pass** over its shard stream
//! with the batched sieve engine, then a single merge round runs the
//! configured black box (lazy greedy by default) over the union of the
//! machines' sieve summaries, exactly like GreeDi's second round.
//!
//! This is the composition Barbosa et al. (randomized composable core-sets,
//! arXiv:1507.03719) and Lucic et al. (horizontally scalable submodular
//! maximization, arXiv:1605.09619) analyze: a constant-factor one-pass
//! local stage whose output is a composable core-set, merged by a
//! constant-factor sequential stage, keeps a constant-factor guarantee
//! end-to-end under randomized partitioning — while each machine holds only
//! O(κ·log(κ)/ε) candidates instead of its whole shard
//! ([`crate::stream::sieve`] module docs give the ladder argument).
//!
//! Execution rides the simulated MapReduce engine, so the run inherits
//! per-stage [`StageReport`](crate::mapreduce::StageReport) timing, the
//! [`FaultPlan`] retry model (map tasks are pure functions of
//! (shard, seed), so retries cannot change the output — asserted by
//! `tests/integration_stream`), and the shared [`RunSpec`] threading: map
//! tasks split `spec.threads` with the oracle layer through
//! [`RunSpec::oracle_threads`], and the merge round gets the full budget.
//!
//! Registered as `"stream_greedi"`; reads m, k, κ (per-machine sieve
//! budget), `batch`, `epsilon` (ladder resolution), `fanout` (merge-tree
//! fan-in — default is the historical flat single-root merge), algorithm
//! (merge round), local/global mode, partition, threads and seed from the
//! spec.

use super::sieve::{candidate_bound, sieve_stream};
use super::source::VecSource;
use crate::algorithms;
use crate::constraints::cardinality::Cardinality;
use crate::constraints::Constraint;
use crate::coordinator::metrics::{FaultStats, RunMetrics, StreamStats};
use crate::coordinator::protocol::{Protocol, RunSpec};
use crate::coordinator::Problem;
use crate::mapreduce::fault::{FaultPlan, RecoveryPolicy, StageFailed};
use crate::mapreduce::reduce::{NodeOutput, TreeReduce};
use crate::mapreduce::{JobReport, MapReduce};
use crate::util::rng::Rng;
use crate::util::trace;

/// The distributed sieve→merge protocol.
pub struct StreamGreedi;

impl Protocol for StreamGreedi {
    fn run(&self, problem: &dyn Problem, spec: &RunSpec) -> RunMetrics {
        let plan = spec.fault.clone().unwrap_or_else(FaultPlan::none);
        self.run_with_faults(problem, spec, &plan)
            .unwrap_or_else(|e| {
                panic!(
                    "stream_greedi aborted: {e} (policy=retry turns machine crashes into \
                     job aborts; use drop_shard or survivor_merge to recover)"
                )
            })
    }

    fn name(&self) -> &'static str {
        "stream_greedi"
    }
}

impl StreamGreedi {
    /// Run under an explicit fault plan: every map/merge task is retried per
    /// the plan and, being a pure function of (input, seed), produces the
    /// identical protocol output — only the stage timings and the retry
    /// count move. `Err` only when a task exhausts `plan.max_attempts`.
    pub fn run_with_faults(
        &self,
        problem: &dyn Problem,
        spec: &RunSpec,
        plan: &FaultPlan,
    ) -> Result<RunMetrics, StageFailed> {
        let _proto_span = trace::span_with("protocol.stream_greedi", || {
            vec![("m", spec.m.into()), ("k", spec.k.into()), ("kappa", spec.kappa.into())]
        });
        let base_rng = Rng::new(spec.seed);
        let mut rng = base_rng.clone();
        let ground = problem.ground();
        let policy = spec.recovery;
        let multiplicity = spec.multiplicity.clamp(1, spec.m);
        let shards = spec.partition.split_placed(
            &ground,
            spec.m,
            multiplicity,
            spec.placement,
            &plan.domains,
            &mut rng,
        );

        let engine = MapReduce::new(spec.threads);
        let mut job = JobReport::default();
        let local_eval = spec.local_eval;
        let batch = spec.batch.max(1);
        let epsilon = spec.epsilon;
        let kappa = spec.kappa.max(1);

        // ---- Stage 1: one-pass sieve per machine -------------------------
        // Arrival order is a deterministic per-machine shuffle (the random
        // order the streaming analysis assumes), forked from the base seed
        // so retries replay the identical stream.
        let inputs: Vec<(usize, Vec<usize>)> = shards.iter().cloned().enumerate().collect();
        let oracle_threads = spec.oracle_threads(inputs.len());
        // One task body for the sieve stage AND crash recovery: recovery
        // re-runs a machine with the SAME fork (3000 + i), so a shard
        // rebuilt in full from survivor replicas replays the identical
        // stream and reproduces the lost summary bit for bit.
        let run_sieve = |i: usize, shard: Vec<usize>| {
            let mut task_rng = base_rng.fork(3_000 + i as u64);
            let obj = if local_eval {
                problem.local(&shard, &mut task_rng)
            } else {
                problem.global()
            };
            let mut src = VecSource::shuffled_with(shard, &mut task_rng);
            sieve_stream(obj.as_ref(), &mut src, kappa, epsilon, batch, oracle_threads)
        };
        let stage1 = engine
            .run_stage_policied(inputs, plan, policy, |_, (i, shard)| run_sieve(i, shard))?;
        let mut results = stage1.outputs;
        let crashed = stage1.crashed;
        let straggled = stage1.straggled;
        let retries1 = stage1.retries;
        job.stages.push(stage1.report);

        // ---- Crash recovery (map machines hold the shard streams) --------
        let mut recovery_time = 0.0;
        let mut dropped = 0usize;
        let mut salvaged_units = 0usize;
        let mut replayed_units = 0usize;
        if !crashed.is_empty() {
            let surviving: std::collections::HashSet<usize> = shards
                .iter()
                .enumerate()
                .filter(|(i, _)| !crashed.contains(i))
                .flat_map(|(_, s)| s.iter().copied())
                .collect();
            dropped = ground.iter().filter(|e| !surviving.contains(e)).count();
            if policy.rebuilds() {
                // A shard that lost elements (all replicas crashed) degrades
                // to drop-shard semantics for the missing part: the partial
                // stream still runs, coverage() stays < 1.
                let rebuilt: Vec<(usize, Vec<usize>, bool)> = crashed
                    .iter()
                    .map(|&j| {
                        let shard: Vec<usize> =
                            shards[j].iter().copied().filter(|e| surviving.contains(e)).collect();
                        let complete = shard.len() == shards[j].len();
                        (j, shard, complete)
                    })
                    .filter(|(_, shard, _)| !shard.is_empty())
                    .collect();
                if !rebuilt.is_empty() {
                    let rebuilt_ids: Vec<usize> = rebuilt.iter().map(|(j, _, _)| *j).collect();
                    // Resume restores the crashed machine's last sieve
                    // checkpoint and replays only the tail of its stream —
                    // valid only when the rebuilt shard is byte-for-byte the
                    // lost one, so the checkpointed ladder matches the
                    // replayed arrival order exactly.
                    let ckpt_b = spec.checkpoint_every;
                    let can_salvage = policy == RecoveryPolicy::Resume && ckpt_b > 0;
                    let (recovered, rec_stage) =
                        engine.run_stage(rebuilt, |_, (j, shard, complete)| {
                            if can_salvage && complete {
                                let total_batches = shard.len().div_ceil(batch);
                                let frac = plan.crash_point(j);
                                let ckpt_batches = ((frac * total_batches as f64).floor()
                                    as usize
                                    / ckpt_b)
                                    * ckpt_b;
                                let mut task_rng = base_rng.fork(3_000 + j as u64);
                                let obj = if local_eval {
                                    problem.local(&shard, &mut task_rng)
                                } else {
                                    problem.global()
                                };
                                let mut src = VecSource::shuffled_with(shard, &mut task_rng);
                                let r = super::sieve::sieve_stream_resumed(
                                    obj.as_ref(),
                                    &mut src,
                                    kappa,
                                    epsilon,
                                    batch,
                                    oracle_threads,
                                    ckpt_batches,
                                );
                                (r.result, r.saved_batches, r.replayed_batches)
                            } else {
                                (run_sieve(j, shard), 0, 0)
                            }
                        });
                    recovery_time = rec_stage.max_task_time;
                    job.stages.push(rec_stage);
                    for (j, (r, salvaged, replayed)) in rebuilt_ids.into_iter().zip(recovered) {
                        salvaged_units += salvaged;
                        replayed_units += replayed;
                        results[j] = Some(r);
                    }
                }
            }
        }

        let mut oracle_calls: u64 = results.iter().flatten().map(|r| r.oracle_calls).sum();

        // ---- Stage 2+: accumulation-tree merge ---------------------------
        // Each surviving machine contributes (sieve union, sieve solution):
        // the union is what a node pools (at most candidate_bound(κ, ε) ids
        // per machine — the only shuffled data, independent of n), the
        // solution is the A^gc_max-style floor. The default (flat) fan-in is
        // the single full-budget reducer this protocol always had, bit for
        // bit; fanout r < m stages the merge so no node pools more than
        // r·bound ids. Interior nodes re-select κ candidates under the
        // κ-budget and pass them up as both pool and floor; the root
        // re-selects under k. Reduce nodes read driver-held summaries, so
        // the root runs under the transient plan only and crashed interior
        // nodes are re-run inline by the tree.
        let pairs: Vec<(Vec<usize>, Vec<usize>)> = results
            .iter()
            .flatten()
            .map(|r| (r.union.clone(), r.solution.clone()))
            .collect();
        let algo_name = spec.algorithm.clone();
        let (m, k) = (spec.m, spec.k);
        let tree = TreeReduce::new(spec.tree_fanout(true)).force_root(true);
        let tree_run = tree.run(&engine, pairs, plan, policy, &mut job, |ctx, sets| {
            let mut task_rng = if ctx.is_root {
                base_rng.fork(4_000)
            } else {
                base_rng.fork(910_000 + (ctx.level as u64) * 4096 + ctx.node as u64)
            };
            let mut pool: Vec<usize> =
                sets.iter().flat_map(|(union, _)| union.iter().copied()).collect();
            pool.sort_unstable();
            pool.dedup();
            let obj = if local_eval {
                problem.merge(m, &mut task_rng)
            } else {
                problem.global()
            };
            let merge_con = Cardinality::new(if ctx.is_root { k } else { kappa });
            let algo = algorithms::by_name(&algo_name).expect("algorithm");
            let node_threads = spec.oracle_threads(ctx.level_nodes);
            let run_b = algo.maximize_threaded(
                obj.as_ref(),
                &pool,
                &merge_con,
                &mut task_rng,
                node_threads,
            );
            let mut extra_oracle = run_b.oracle_calls;

            // Like GreeDi's A^gc_max: keep the best input sieve solution
            // under this node's objective as a floor (κ-budget sets trim to
            // the budget prefix, feasible by heredity — sieves commit
            // greedily in stream order).
            let mut best: Option<(Vec<usize>, f64)> = None;
            for (_, cand) in sets {
                let mut trimmed: Vec<usize> = Vec::new();
                for &e in cand {
                    if merge_con.can_add(&trimmed, e) {
                        trimmed.push(e);
                    }
                }
                let v = obj.eval(&trimmed);
                extra_oracle += trimmed.len() as u64;
                if best.as_ref().map(|(_, bv)| v > *bv).unwrap_or(true) {
                    best = Some((trimmed, v));
                }
            }
            let (max_sol, max_val) = best.unwrap_or((Vec::new(), f64::NEG_INFINITY));
            let winner = if run_b.value >= max_val { run_b.solution } else { max_sol };
            let pooled = pool.len();
            NodeOutput { result: (winner.clone(), winner), pooled, oracle_calls: extra_oracle }
        })?;
        let retries2 = tree_run.stats.retries;
        oracle_calls += tree_run.oracle_calls;
        let rounds = 1 + tree_run.stats.depth;
        let solution = tree_run.result.map(|(sol, _)| sol).unwrap_or_default();
        let tree_stats = tree_run.stats;

        // Reported value: always the true global objective.
        let value = problem.global().eval(&solution);
        // Per-machine vectors keep length m: a machine crashed-and-dropped
        // reports 0 peak candidates / 0 elements at its slot.
        let stream = StreamStats {
            peak_live_per_machine: results
                .iter()
                .map(|r| r.as_ref().map_or(0, |r| r.peak_live))
                .collect(),
            live_bound: candidate_bound(kappa, epsilon),
            elements_per_machine: results
                .iter()
                .map(|r| r.as_ref().map_or(0, |r| r.elements))
                .collect(),
            batch,
            retries: retries1 + retries2,
        };
        let fault = plan.active().then(|| FaultStats {
            policy: policy.label().to_string(),
            multiplicity,
            retries: retries1 + retries2,
            crashed_machines: crashed,
            straggled_machines: straggled,
            dropped_elements: dropped,
            ground_size: ground.len(),
            recovery_time,
            salvaged_units,
            replayed_units,
        });

        Ok(RunMetrics {
            name: format!(
                "stream_greedi[m={},k={},κ={},b={},ε={}{}]",
                spec.m,
                spec.k,
                kappa,
                batch,
                epsilon,
                if local_eval { ",local" } else { "" }
            ),
            solution,
            value,
            oracle_calls,
            job,
            rounds,
            stream: Some(stream),
            tree: Some(tree_stats),
            fault,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::protocol;
    use crate::coordinator::FacilityProblem;
    use crate::data::synth::{gaussian_blobs, SynthConfig};
    use std::sync::Arc;

    fn problem(n: usize, seed: u64) -> FacilityProblem {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 8), seed));
        FacilityProblem::new(&ds)
    }

    fn spec(m: usize, k: usize) -> RunSpec {
        RunSpec::new(m, k).epsilon(0.2).batch(32)
    }

    #[test]
    fn respects_budget_and_reports_stream_stats() {
        let p = problem(240, 61);
        let r = StreamGreedi.run(&p, &spec(4, 8).seed(5));
        assert!(r.solution.len() <= 8);
        assert!(r.value.is_finite() && r.value >= 0.0);
        assert_eq!(r.rounds, 2);
        let s = r.stream.expect("stream stats must be reported");
        assert_eq!(s.peak_live_per_machine.len(), 4);
        assert_eq!(s.elements_per_machine.iter().sum::<usize>(), 240);
        assert!(s.within_bound(), "peak {} vs bound {}", s.peak_live(), s.live_bound);
        assert_eq!(s.retries, 0);
        assert_eq!(s.batch, 32);
    }

    #[test]
    fn registered_and_round_trips() {
        let proto = protocol::by_name("stream_greedi").expect("registered");
        assert_eq!(proto.name(), "stream_greedi");
        let p = problem(120, 62);
        let run = proto.run(&p, &spec(3, 5).seed(1));
        let direct = StreamGreedi.run(&p, &spec(3, 5).seed(1));
        assert_eq!(run.solution, direct.solution);
        assert_eq!(run.value, direct.value);
        assert_eq!(run.oracle_calls, direct.oracle_calls);
    }

    #[test]
    fn deterministic_given_seed_and_batch_independent() {
        let p = problem(200, 63);
        let a = StreamGreedi.run(&p, &spec(4, 6).seed(9));
        let b = StreamGreedi.run(&p, &spec(4, 6).seed(9));
        assert_eq!(a.solution, b.solution);
        assert_eq!(a.value, b.value);
        // the per-machine stream ORDER is fixed by the seed, so the batch
        // size is pure mechanics — output must not move
        for bs in [1usize, 7, 1024] {
            let c = StreamGreedi.run(&p, &spec(4, 6).seed(9).batch(bs));
            assert_eq!(a.solution, c.solution, "batch={bs} changed the protocol output");
            assert_eq!(a.value, c.value, "batch={bs}");
        }
    }

    #[test]
    fn communication_bounded_by_summaries() {
        let p = problem(300, 64);
        let sp = spec(6, 5).seed(3);
        let r = StreamGreedi.run(&p, &sp);
        let bound = candidate_bound(sp.kappa, sp.epsilon);
        assert!(
            r.job.shuffled_elements <= 6 * bound,
            "shuffle {} exceeds m·bound {}",
            r.job.shuffled_elements,
            6 * bound
        );
    }

    #[test]
    fn local_mode_runs_and_stays_feasible() {
        let p = problem(200, 65);
        let r = StreamGreedi.run(&p, &spec(4, 6).local().seed(2));
        assert!(r.solution.len() <= 6);
        assert!(r.value >= 0.0);
        let set: std::collections::HashSet<_> = r.solution.iter().collect();
        assert_eq!(set.len(), r.solution.len(), "duplicate ids");
    }

    #[test]
    fn resume_recovery_bit_identical_with_sieve_checkpoints() {
        let p = problem(240, 67);
        let domains = FaultPlan::none().domain_groups(2);
        let base = |plan: FaultPlan| {
            spec(4, 6)
                .multiplicity(2)
                .placement(crate::mapreduce::partition::PlacementPolicy::DistinctDomains)
                .seed(9)
                .faults(plan)
        };
        let clean = StreamGreedi.run(&p, &base(domains.clone()));
        assert!(clean.fault.is_none(), "bare domain map must not activate the plan");
        let run = StreamGreedi.run(
            &p,
            &base(domains.crash_tasks(vec![2]).crash_progress(0.8))
                .recovery(RecoveryPolicy::Resume)
                .checkpoint_every(1),
        );
        assert_eq!(run.solution, clean.solution, "resume changed the solution");
        assert_eq!(run.value.to_bits(), clean.value.to_bits());
        assert_eq!(
            run.oracle_calls, clean.oracle_calls,
            "sieve restore recovers the oracle counter too"
        );
        let f = run.fault.expect("active plan records stats");
        assert_eq!(f.policy, "resume");
        assert!((f.coverage() - 1.0).abs() < 1e-12, "replicas in the other rack");
        assert!(f.salvaged_units > 0, "crash at 80% of 4 batches must salvage");
        assert!(f.replayed_units > 0, "the tail past the checkpoint is replayed");
    }

    #[test]
    fn kappa_over_selection_trims_to_k() {
        let p = problem(180, 66);
        let r = StreamGreedi.run(&p, &spec(3, 5).alpha(2.0).seed(4));
        assert!(r.solution.len() <= 5, "κ>k must still respect the final budget");
    }
}
