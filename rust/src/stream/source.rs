//! Stream sources — where element batches come from.
//!
//! A [`StreamSource`] yields the ground set in *arrival order*, a fixed
//! number of elements at a time, and is consumed exactly once (the sieve
//! engine makes a single pass; that one-pass discipline is the whole point
//! of the streaming model). Sources only move element **ids**; data access
//! stays behind the objective, which is what keeps the abstraction honest —
//! a source never needs the corpus in memory, only the order book.
//!
//! Provided sources:
//!
//! * [`VecSource`] — an in-memory id list in the given (arbitrary/permuted)
//!   order, plus a deterministic seeded-shuffle constructor (the random
//!   arrival order the streaming theory's expectation bounds assume);
//! * [`DriftSource`] — synthetic adversarial orders over a point dataset:
//!   covariate drift (sorted along the first feature axis) and
//!   value-ascending/descending norm orders, the stress cases for a
//!   threshold ladder (ascending singletons force maximal sieve churn);
//! * [`ChunkedCsvSource`] — bounded-memory ingestion from disk through
//!   [`crate::data::loader::ChunkedCsvReader`]: rows are parsed a chunk at
//!   a time and immediately reduced to ids, so ingest memory is O(batch·d)
//!   regardless of file size.

use std::cmp::Ordering;
use std::path::Path;
use std::sync::Arc;

use crate::data::loader::ChunkedCsvReader;
use crate::data::Dataset;
use crate::util::rng::Rng;

/// A one-pass batch stream of element ids.
pub trait StreamSource {
    /// Up to `batch` ids in arrival order; an empty vector means the stream
    /// has ended (sources never yield an empty batch mid-stream). A stream
    /// can end for two reasons — exhaustion or a source error; check
    /// [`StreamSource::error`] to tell them apart.
    fn next_batch(&mut self, batch: usize) -> Vec<usize>;

    /// Total elements remaining, when known (progress reporting only).
    fn len_hint(&self) -> Option<usize> {
        None
    }

    /// The error that terminated the stream early, if any. Consumers that
    /// must not silently accept a truncated stream (e.g. a sieve pass whose
    /// result is meaningless on a partial corpus) check this after the
    /// first empty batch. Default: infallible source.
    fn error(&self) -> Option<&str> {
        None
    }
}

/// In-memory id stream in a caller-chosen (e.g. permuted) order.
pub struct VecSource {
    ids: Vec<usize>,
    at: usize,
}

impl VecSource {
    /// Stream `ids` exactly in the given order.
    pub fn new(ids: Vec<usize>) -> Self {
        VecSource { ids, at: 0 }
    }

    /// Deterministic seeded shuffle of `ids` — the uniformly random arrival
    /// order assumed by the streaming analysis, reproducible from `seed`.
    pub fn shuffled(mut ids: Vec<usize>, seed: u64) -> Self {
        Rng::new(seed).shuffle(&mut ids);
        VecSource { ids, at: 0 }
    }

    /// Seeded shuffle drawing from an existing RNG stream (the distributed
    /// protocol forks one sub-stream per machine).
    pub fn shuffled_with(mut ids: Vec<usize>, rng: &mut Rng) -> Self {
        rng.shuffle(&mut ids);
        VecSource { ids, at: 0 }
    }
}

impl StreamSource for VecSource {
    fn next_batch(&mut self, batch: usize) -> Vec<usize> {
        let end = (self.at + batch.max(1)).min(self.ids.len());
        let out = self.ids[self.at..end].to_vec();
        self.at = end;
        out
    }

    fn len_hint(&self) -> Option<usize> {
        Some(self.ids.len() - self.at)
    }
}

/// Synthetic arrival orders over a point dataset.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamOrder {
    /// Covariate drift: points arrive sorted along feature axis 0, so the
    /// data distribution shifts continuously over the stream.
    Drift,
    /// Squared norms ascending — for the facility objective singleton values
    /// rise monotonically, forcing the threshold ladder to churn maximally
    /// (every new best singleton drops old sieves and opens new ones).
    ValueAscending,
    /// Squared norms descending — the benign mirror (the ladder settles on
    /// the first batch).
    ValueDescending,
}

/// Adversarial/drifting order source (in-memory; ordering is precomputed
/// deterministically, ties broken by id).
pub struct DriftSource {
    inner: VecSource,
}

impl DriftSource {
    pub fn new(data: &Arc<Dataset>, mut ids: Vec<usize>, order: StreamOrder) -> Self {
        let key = |i: usize| -> f64 {
            match order {
                StreamOrder::Drift => data.row(i).first().copied().unwrap_or(0.0) as f64,
                StreamOrder::ValueAscending | StreamOrder::ValueDescending => {
                    data.row(i).iter().map(|&x| (x as f64) * (x as f64)).sum()
                }
            }
        };
        ids.sort_by(|&a, &b| {
            let ord = key(a).partial_cmp(&key(b)).unwrap_or(Ordering::Equal);
            let ord = if order == StreamOrder::ValueDescending {
                ord.reverse()
            } else {
                ord
            };
            ord.then_with(|| a.cmp(&b))
        });
        DriftSource { inner: VecSource::new(ids) }
    }
}

impl StreamSource for DriftSource {
    fn next_batch(&mut self, batch: usize) -> Vec<usize> {
        self.inner.next_batch(batch)
    }

    fn len_hint(&self) -> Option<usize> {
        self.inner.len_hint()
    }
}

/// Bounded-memory ingestion from a headerless CSV: rows stream off disk a
/// chunk at a time and are assigned consecutive ids `0, 1, 2, …` in file
/// order. Only the reader's chunk buffer is ever resident *on the ingest
/// side* — today's objectives still hold their own evaluation window, so
/// this bounds the arrival path, not the whole pipeline (a reservoir/
/// chunk-local objective window is a ROADMAP follow-on).
///
/// Read errors (ragged row, bad value) end the stream early; the error is
/// retained and queryable via [`StreamSource::error`] so callers can
/// distinguish EOF from corruption.
pub struct ChunkedCsvSource {
    reader: ChunkedCsvReader,
    next_id: usize,
    error: Option<String>,
}

impl ChunkedCsvSource {
    pub fn open(path: &Path) -> crate::util::error::Result<Self> {
        Ok(ChunkedCsvSource {
            reader: ChunkedCsvReader::open(path)?,
            next_id: 0,
            error: None,
        })
    }

    /// Rows successfully streamed so far.
    pub fn rows_read(&self) -> usize {
        self.next_id
    }
}

impl StreamSource for ChunkedCsvSource {
    fn next_batch(&mut self, batch: usize) -> Vec<usize> {
        if self.error.is_some() {
            return Vec::new();
        }
        match self.reader.next_chunk(batch.max(1)) {
            Ok(chunk) => {
                let start = self.next_id;
                self.next_id += chunk.n;
                (start..self.next_id).collect()
            }
            Err(e) => {
                self.error = Some(e.to_string());
                Vec::new()
            }
        }
    }

    fn error(&self) -> Option<&str> {
        self.error.as_deref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::loader::save_csv;
    use crate::data::synth::{gaussian_blobs, SynthConfig};

    fn drain(src: &mut dyn StreamSource, batch: usize) -> Vec<usize> {
        let mut all = Vec::new();
        loop {
            let b = src.next_batch(batch);
            if b.is_empty() {
                break;
            }
            all.extend(b);
        }
        all
    }

    #[test]
    fn vec_source_preserves_order_any_batch() {
        let ids: Vec<usize> = vec![5, 3, 9, 1, 7, 2];
        for batch in [1usize, 2, 4, 100] {
            let mut s = VecSource::new(ids.clone());
            assert_eq!(drain(&mut s, batch), ids, "batch={batch}");
            assert!(s.next_batch(batch).is_empty(), "exhausted source must stay empty");
        }
    }

    #[test]
    fn shuffled_source_is_seeded_permutation() {
        let ids: Vec<usize> = (0..100).collect();
        let a = drain(&mut VecSource::shuffled(ids.clone(), 7), 9);
        let b = drain(&mut VecSource::shuffled(ids.clone(), 7), 13);
        assert_eq!(a, b, "same seed must give same order at any batch size");
        let c = drain(&mut VecSource::shuffled(ids.clone(), 8), 9);
        assert_ne!(a, c, "different seed must move the order");
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, ids, "shuffle must be a permutation");
        assert_ne!(a, ids, "100 elements staying in place is astronomically unlikely");
    }

    #[test]
    fn len_hint_counts_down() {
        let mut s = VecSource::new((0..10).collect());
        assert_eq!(s.len_hint(), Some(10));
        s.next_batch(4);
        assert_eq!(s.len_hint(), Some(6));
        drain(&mut s, 4);
        assert_eq!(s.len_hint(), Some(0));
    }

    #[test]
    fn drift_orders_are_sorted_and_deterministic() {
        let ds = std::sync::Arc::new(gaussian_blobs(&SynthConfig::tiny_images(80, 6), 3));
        let ids: Vec<usize> = (0..80).collect();
        let norm = |i: usize| -> f64 {
            ds.row(i).iter().map(|&x| (x as f64) * (x as f64)).sum()
        };
        let asc = drain(&mut DriftSource::new(&ds, ids.clone(), StreamOrder::ValueAscending), 7);
        assert_eq!(asc.len(), 80);
        for w in asc.windows(2) {
            assert!(norm(w[0]) <= norm(w[1]) + 1e-12, "ascending order violated");
        }
        let desc = drain(&mut DriftSource::new(&ds, ids.clone(), StreamOrder::ValueDescending), 7);
        let mut rev = desc.clone();
        rev.reverse();
        assert_eq!(asc, rev, "descending must be the exact reverse (ids tie-break flips too only when norms tie — none here)");
        let drift = drain(&mut DriftSource::new(&ds, ids.clone(), StreamOrder::Drift), 11);
        for w in drift.windows(2) {
            assert!(
                ds.row(w[0])[0] <= ds.row(w[1])[0] + 1e-6,
                "drift order must ascend along axis 0"
            );
        }
        let drift2 = drain(&mut DriftSource::new(&ds, ids, StreamOrder::Drift), 5);
        assert_eq!(drift, drift2, "ordering must be deterministic");
    }

    #[test]
    fn chunked_csv_source_streams_all_rows() {
        let ds = gaussian_blobs(&SynthConfig::tiny_images(37, 4), 5);
        let path = std::env::temp_dir().join("greedi_stream_src_ok.csv");
        save_csv(&ds, &path).unwrap();
        for batch in [1usize, 8, 64] {
            let mut src = ChunkedCsvSource::open(&path).unwrap();
            let ids = drain(&mut src, batch);
            assert_eq!(ids, (0..37).collect::<Vec<_>>(), "batch={batch}");
            assert_eq!(src.rows_read(), 37);
            assert!(src.error().is_none());
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn chunked_csv_source_surfaces_corruption() {
        let path = std::env::temp_dir().join("greedi_stream_src_bad.csv");
        std::fs::write(&path, "1,2\n3,4\nnope,6\n7,8\n").unwrap();
        let mut src = ChunkedCsvSource::open(&path).unwrap();
        let first = src.next_batch(2);
        assert_eq!(first, vec![0, 1]);
        let second = src.next_batch(2);
        assert!(second.is_empty(), "corrupt chunk must end the stream");
        assert!(src.error().is_some());
        assert!(src.next_batch(2).is_empty(), "errored source stays ended");
        std::fs::remove_file(&path).ok();
    }
}
