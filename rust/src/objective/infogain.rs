//! GP active-set selection objective (paper §3.4.1, experiments §6.2):
//! information gain `f(S) = ½ log det(I + σ⁻² K_SS)` with the squared
//! exponential kernel `K(eᵢ, eⱼ) = exp(−‖eᵢ − eⱼ‖² / h²)`.
//!
//! Monotone submodular (Krause & Guestrin 2005). Marginal gains are priced
//! through the incremental Cholesky factor (`linalg::cholesky`): O(k·d) for
//! the kernel row plus an O(k²) forward solve — never an O(k³) log-det.
//!
//! Pricing rides the shared [`ShardedGainEngine`] as a candidate-sharded
//! [`GainKernel`] — the objective the paper's 45M-record GP-inference
//! experiments bottleneck on gains real parallel batching here for the
//! first time. Each candidate shard builds its **own probe columns**
//! (`a_se` cross-terms + forward-solve scratch, allocated once per shard
//! and reused across that shard's candidates) against the shared read-only
//! Cholesky factor, so shards price concurrently with bit-identical
//! results at any thread count. Commits keep the kernel-owned scratch
//! (`apply_push` is exclusive), exactly as fast as before.

use std::ops::Range;
use std::sync::Arc;

use super::engine::{
    GainKernel, ShardSpec, ShardedGainEngine, MIN_HEAVY_CANDIDATES_PER_SHARD,
};
use super::{State, SubmodularFn};
use crate::data::Dataset;
use crate::linalg::IncrementalCholesky;

/// Information-gain objective over a dataset with an RBF kernel.
pub struct InfoGain {
    data: Arc<Dataset>,
    inv_h2: f64,
    inv_sigma2: f64,
}

impl InfoGain {
    /// Paper parameters: h = 0.75, σ = 1.
    pub fn paper_params(data: &Arc<Dataset>) -> Self {
        Self::new(data, 0.75, 1.0)
    }

    pub fn new(data: &Arc<Dataset>, h: f64, sigma: f64) -> Self {
        InfoGain {
            data: Arc::clone(data),
            inv_h2: 1.0 / (h * h),
            inv_sigma2: 1.0 / (sigma * sigma),
        }
    }

    /// σ⁻² K(i, j).
    #[inline]
    pub fn scaled_kernel(&self, i: usize, j: usize) -> f64 {
        (-self.data.sqdist(i, j) * self.inv_h2).exp() * self.inv_sigma2
    }
}

impl SubmodularFn for InfoGain {
    fn state(&self) -> Box<dyn State + '_> {
        Box::new(ShardedGainEngine::new(InfoGainKernel {
            obj: self,
            chol: IncrementalCholesky::new(),
            selected: Vec::new(),
            a_se: Vec::new(),
        }))
    }

    fn ground_size(&self) -> usize {
        self.data.n
    }
}

/// Candidate-sharded info-gain kernel: Cholesky factor of I + σ⁻² K_SS.
/// The `a_se` scratch buffer is reused across *commits* (which are
/// exclusive); concurrent shard pricing allocates per-shard probe columns
/// instead (see [`GainKernel::shard_gain_partial`]).
pub struct InfoGainKernel<'a> {
    obj: &'a InfoGain,
    chol: IncrementalCholesky,
    selected: Vec<usize>,
    a_se: Vec<f64>,
}

/// Pre-refactor name for the info-gain state, preserved as the engine alias.
pub type InfoGainState<'a> = ShardedGainEngine<InfoGainKernel<'a>>;

impl<'a> InfoGainKernel<'a> {
    /// Fill `self.a_se` with σ⁻²K(s, e) for the current selection and
    /// return a_ee (commit path only — pricing builds per-shard columns).
    fn fill_cross_terms(&mut self, e: usize) -> f64 {
        self.a_se.clear();
        for &s in &self.selected {
            self.a_se.push(self.obj.scaled_kernel(s, e));
        }
        1.0 + self.obj.scaled_kernel(e, e)
    }
}

impl<'a> GainKernel for InfoGainKernel<'a> {
    fn label(&self) -> &'static str {
        "infogain"
    }

    fn shard_spec(&self) -> ShardSpec {
        // O(k²) per candidate: even narrow batches amortize a shard.
        ShardSpec::Candidates { min_per_shard: MIN_HEAVY_CANDIDATES_PER_SHARD }
    }

    /// Per-shard Cholesky probe columns: one `a_se`/`solve` pair allocated
    /// per shard invocation and reused for every candidate in the shard —
    /// the same arithmetic (`gain_with`) the serial path has always run,
    /// so gains are bit-identical across shard/thread counts.
    fn shard_gain_partial(&self, es: &[usize], rows: &Range<usize>) -> Vec<f64> {
        let mut a_se: Vec<f64> = Vec::with_capacity(self.selected.len());
        let mut solve: Vec<f64> = Vec::with_capacity(self.selected.len());
        es[rows.clone()]
            .iter()
            .map(|&e| {
                a_se.clear();
                for &s in &self.selected {
                    a_se.push(self.obj.scaled_kernel(s, e));
                }
                let a_ee = 1.0 + self.obj.scaled_kernel(e, e);
                0.5 * self.chol.gain_with(a_ee, &a_se, &mut solve)
            })
            .collect()
    }

    fn apply_push(&mut self, e: usize) -> f64 {
        let a_ee = self.fill_cross_terms(e);
        let a_se = std::mem::take(&mut self.a_se);
        let inc = 0.5 * self.chol.push(a_ee, &a_se);
        self.a_se = a_se;
        self.selected.push(e);
        inc
    }

    fn value(&self) -> f64 {
        0.5 * self.chol.logdet()
    }

    fn selected(&self) -> &[usize] {
        &self.selected
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::parkinsons_like;
    use crate::linalg::Matrix;
    use crate::objective::{check_diminishing_returns, check_monotone};
    use crate::util::rng::Rng;

    fn dataset(n: usize) -> Arc<Dataset> {
        Arc::new(parkinsons_like(n, 10, 3))
    }

    /// Brute-force f(S) via dense log det.
    fn brute(obj: &InfoGain, s: &[usize]) -> f64 {
        let k = s.len();
        let mut m = Matrix::identity(k);
        for i in 0..k {
            for j in 0..k {
                m[(i, j)] += obj.scaled_kernel(s[i], s[j]);
            }
        }
        0.5 * m.logdet().unwrap()
    }

    #[test]
    fn matches_dense_logdet() {
        let ds = dataset(30);
        let f = InfoGain::paper_params(&ds);
        let s = [0, 5, 9, 22, 17];
        assert!((f.eval(&s) - brute(&f, &s)).abs() < 1e-8);
    }

    #[test]
    fn empty_is_zero() {
        let ds = dataset(10);
        let f = InfoGain::paper_params(&ds);
        assert_eq!(f.eval(&[]), 0.0);
    }

    #[test]
    fn gain_matches_eval_difference() {
        let ds = dataset(25);
        let f = InfoGain::paper_params(&ds);
        let mut st = f.state();
        st.push(1);
        st.push(8);
        let g = st.gain(14);
        let diff = brute(&f, &[1, 8, 14]) - brute(&f, &[1, 8]);
        assert!((g - diff).abs() < 1e-8, "{g} vs {diff}");
    }

    #[test]
    fn batched_gains_bit_identical_to_serial() {
        // The first parallel path this objective ever had: per-shard probe
        // columns must reproduce the serial gains exactly.
        let ds = dataset(120);
        let f = InfoGain::paper_params(&ds);
        let mut st = f.state();
        for e in [1usize, 8, 40, 77] {
            st.push(e);
        }
        let cands: Vec<usize> = (0..120).collect();
        let serial = st.batch_gains(&cands);
        for threads in [2usize, 8] {
            assert_eq!(serial, st.par_batch_gains(&cands, threads), "threads={threads}");
        }
        for (i, &e) in cands.iter().enumerate() {
            assert_eq!(serial[i], st.gain(e), "gain({e}) diverged from batch");
        }
    }

    #[test]
    fn monotone_and_submodular() {
        let ds = dataset(16);
        let f = InfoGain::paper_params(&ds);
        let ground: Vec<usize> = (0..16).collect();
        let mut rng = Rng::new(2);
        assert!(check_monotone(&f, &ground, &mut rng, 40) < 1e-9);
        assert!(check_diminishing_returns(&f, &ground, &mut rng, 40) < 1e-8);
    }

    #[test]
    fn duplicate_gain_near_zero() {
        // adding an identical point twice: σ⁻²K row is duplicated, the
        // pivot collapses toward 1+σ⁻² − (that same mass), small positive.
        let ds = dataset(12);
        let f = InfoGain::paper_params(&ds);
        let mut st = f.state();
        let first = st.push(4);
        let dup = st.gain(4);
        assert!(dup < first * 0.9, "duplicate {dup} vs first {first}");
        assert!(dup >= 0.0 - 1e-12);
    }

    #[test]
    fn sigma_scaling_sanity() {
        let ds = dataset(20);
        let tight = InfoGain::new(&ds, 0.75, 0.5);
        let loose = InfoGain::new(&ds, 0.75, 2.0);
        let s = [0, 3, 7];
        assert!(tight.eval(&s) > loose.eval(&s));
    }
}
