//! GP active-set selection objective (paper §3.4.1, experiments §6.2):
//! information gain `f(S) = ½ log det(I + σ⁻² K_SS)` with the squared
//! exponential kernel `K(eᵢ, eⱼ) = exp(−‖eᵢ − eⱼ‖² / h²)`.
//!
//! Monotone submodular (Krause & Guestrin 2005). Marginal gains are priced
//! through the incremental Cholesky factor (`linalg::cholesky`): O(k·d) for
//! the kernel row plus an O(k²) forward solve — never an O(k³) log-det.

use std::sync::Arc;

use super::{State, SubmodularFn};
use crate::data::Dataset;
use crate::linalg::IncrementalCholesky;

/// Information-gain objective over a dataset with an RBF kernel.
pub struct InfoGain {
    data: Arc<Dataset>,
    inv_h2: f64,
    inv_sigma2: f64,
}

impl InfoGain {
    /// Paper parameters: h = 0.75, σ = 1.
    pub fn paper_params(data: &Arc<Dataset>) -> Self {
        Self::new(data, 0.75, 1.0)
    }

    pub fn new(data: &Arc<Dataset>, h: f64, sigma: f64) -> Self {
        InfoGain {
            data: Arc::clone(data),
            inv_h2: 1.0 / (h * h),
            inv_sigma2: 1.0 / (sigma * sigma),
        }
    }

    /// σ⁻² K(i, j).
    #[inline]
    pub fn scaled_kernel(&self, i: usize, j: usize) -> f64 {
        (-self.data.sqdist(i, j) * self.inv_h2).exp() * self.inv_sigma2
    }
}

impl SubmodularFn for InfoGain {
    fn state(&self) -> Box<dyn State + '_> {
        Box::new(InfoGainState {
            obj: self,
            chol: IncrementalCholesky::new(),
            selected: Vec::new(),
            a_se: Vec::new(),
            solve: Vec::new(),
        })
    }

    fn ground_size(&self) -> usize {
        self.data.n
    }
}

/// Incremental state: Cholesky factor of I + σ⁻² K_SS. Scratch buffers
/// (`a_se`, `solve`) are reused across gain calls — pricing a candidate
/// allocates nothing (perf pass §B).
pub struct InfoGainState<'a> {
    obj: &'a InfoGain,
    chol: IncrementalCholesky,
    selected: Vec<usize>,
    a_se: Vec<f64>,
    solve: Vec<f64>,
}

impl<'a> InfoGainState<'a> {
    /// Fill `self.a_se` with σ⁻²K(s, e) for the current selection and
    /// return a_ee.
    fn fill_cross_terms(&mut self, e: usize) -> f64 {
        self.a_se.clear();
        for &s in &self.selected {
            self.a_se.push(self.obj.scaled_kernel(s, e));
        }
        1.0 + self.obj.scaled_kernel(e, e)
    }
}

impl<'a> State for InfoGainState<'a> {
    fn value(&self) -> f64 {
        0.5 * self.chol.logdet()
    }

    fn gain(&mut self, e: usize) -> f64 {
        let a_ee = self.fill_cross_terms(e);
        // split borrows: take a_se out to appease the borrow checker
        let a_se = std::mem::take(&mut self.a_se);
        let g = 0.5 * self.chol.gain_with(a_ee, &a_se, &mut self.solve);
        self.a_se = a_se;
        g
    }

    fn push(&mut self, e: usize) -> f64 {
        let a_ee = self.fill_cross_terms(e);
        let a_se = std::mem::take(&mut self.a_se);
        let inc = 0.5 * self.chol.push(a_ee, &a_se);
        self.a_se = a_se;
        self.selected.push(e);
        inc
    }

    fn selected(&self) -> &[usize] {
        &self.selected
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::parkinsons_like;
    use crate::linalg::Matrix;
    use crate::objective::{check_diminishing_returns, check_monotone};
    use crate::util::rng::Rng;

    fn dataset(n: usize) -> Arc<Dataset> {
        Arc::new(parkinsons_like(n, 10, 3))
    }

    /// Brute-force f(S) via dense log det.
    fn brute(obj: &InfoGain, s: &[usize]) -> f64 {
        let k = s.len();
        let mut m = Matrix::identity(k);
        for i in 0..k {
            for j in 0..k {
                m[(i, j)] += obj.scaled_kernel(s[i], s[j]);
            }
        }
        0.5 * m.logdet().unwrap()
    }

    #[test]
    fn matches_dense_logdet() {
        let ds = dataset(30);
        let f = InfoGain::paper_params(&ds);
        let s = [0, 5, 9, 22, 17];
        assert!((f.eval(&s) - brute(&f, &s)).abs() < 1e-8);
    }

    #[test]
    fn empty_is_zero() {
        let ds = dataset(10);
        let f = InfoGain::paper_params(&ds);
        assert_eq!(f.eval(&[]), 0.0);
    }

    #[test]
    fn gain_matches_eval_difference() {
        let ds = dataset(25);
        let f = InfoGain::paper_params(&ds);
        let mut st = f.state();
        st.push(1);
        st.push(8);
        let g = st.gain(14);
        let diff = brute(&f, &[1, 8, 14]) - brute(&f, &[1, 8]);
        assert!((g - diff).abs() < 1e-8, "{g} vs {diff}");
    }

    #[test]
    fn monotone_and_submodular() {
        let ds = dataset(16);
        let f = InfoGain::paper_params(&ds);
        let ground: Vec<usize> = (0..16).collect();
        let mut rng = Rng::new(2);
        assert!(check_monotone(&f, &ground, &mut rng, 40) < 1e-9);
        assert!(check_diminishing_returns(&f, &ground, &mut rng, 40) < 1e-8);
    }

    #[test]
    fn duplicate_gain_near_zero() {
        // adding an identical point twice: σ⁻²K row is duplicated, the
        // pivot collapses toward 1+σ⁻² − (that same mass), small positive.
        let ds = dataset(12);
        let f = InfoGain::paper_params(&ds);
        let mut st = f.state();
        let first = st.push(4);
        let dup = st.gain(4);
        assert!(dup < first * 0.9, "duplicate {dup} vs first {first}");
        assert!(dup >= 0.0 - 1e-12);
    }

    #[test]
    fn sigma_scaling_sanity() {
        let ds = dataset(20);
        let tight = InfoGain::new(&ds, 0.75, 0.5);
        let loose = InfoGain::new(&ds, 0.75, 2.0);
        let s = [0, 3, 7];
        assert!(tight.eval(&s) > loose.eval(&s));
    }
}
