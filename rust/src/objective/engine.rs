//! The sharded gain engine — ONE deterministic batch-pricing core under
//! every objective.
//!
//! Before this module existed, `FacilityLocation` privately owned the whole
//! fast path (window sharding, shard-ordered reduction, SIMD dispatch, the
//! `curmin` backend mirror), coverage/cut re-derived their own candidate
//! sharding, and the Cholesky-priced objectives (info-gain, DPP) plus the
//! analytic ones (entropy worst-case, modular) fell back to serial
//! element-at-a-time pricing. [`ShardedGainEngine`] lifts the shared
//! machinery out so an objective only supplies a small [`GainKernel`]:
//!
//! * **shard-boundary computation** — a pure function of *problem shape*
//!   (window length or candidate count), NEVER the thread count;
//! * **submission** to the persistent work-stealing pool
//!   (`util::executor`), bounded by the caller's per-stage thread budget;
//! * **shard-ordered deterministic reduction** — window-sharded partial
//!   sums fold in shard order, candidate-sharded outputs concatenate in
//!   shard order (= input order), so results are bit-identical at 1, 2 or
//!   64 threads;
//! * **oracle-call accounting** — every state carries an
//!   [`OracleCounter`](super::OracleCounter) maintained here, exposed via
//!   [`State::oracle_counter`](super::State::oracle_counter);
//! * **the runtime-dispatch seam** — [`GainKernel::backend_batch`] lets an
//!   accelerator ([`GainBackend`], today the XLA facility artifact,
//!   tomorrow a CUDA/Pallas or NUMA-pinned backend) intercept whole
//!   batches, while per-shard CPU kernels keep their own ISA dispatch
//!   (facility's AVX2+FMA path) inside [`GainKernel::shard_gain_partial`].
//!
//! ## Two shard shapes
//!
//! [`ShardSpec::Window`] — the objective's per-candidate work streams a
//! large evaluation buffer (facility location's packed window): the window
//! is cut into contiguous shards, every shard prices *every* candidate over
//! its slice, and the per-candidate partials are summed in shard order.
//!
//! [`ShardSpec::Candidates`] — the per-candidate work is self-contained
//! (coverage's one transaction scan, cut's one adjacency scan, info-gain's
//! probe-column forward solve, DPP's Schur complement, modular's weight
//! lookup, entropy's group lookup): the candidate *list* is cut into
//! contiguous shards and each shard prices its own slice completely. Each
//! kernel declares how many candidates one shard must hold to amortize the
//! fan-out (`min_per_shard`): cheap lookups use
//! [`MIN_CANDIDATES_PER_SHARD`], the O(k²)-per-candidate Cholesky kernels
//! use [`MIN_HEAVY_CANDIDATES_PER_SHARD`].
//!
//! ## Determinism rules (the thread-invariance contract)
//!
//! 1. Shard boundaries come from [`shard_ranges`] with a shard *count* that
//!    is a pure function of problem shape ([`window_shard_count`] /
//!    [`candidate_shard_count`]) — never of `threads`, pool size, or
//!    timing. `threads` only bounds how many shards are in flight at once.
//! 2. [`GainKernel::shard_gain_partial`] must be a pure read-only function
//!    of the kernel state and its shard (it is called concurrently); any
//!    scratch space is allocated per shard invocation.
//! 3. Reduction happens on the calling thread in shard order — work
//!    *placement* can never leak into results.
//! 4. `gain`, `batch_gains` and `par_batch_gains` all run the identical
//!    sharded reduction (serial execution of the same shard loop), so every
//!    pricing surface is bit-identical to every other. The one documented
//!    carve-out: single-element [`State::gain`](super::State::gain) stays on
//!    the CPU kernel even when a [`GainBackend`] is installed — the backend
//!    is a *batched* accelerator and may differ from the CPU kernel at f32
//!    tolerance, so mixing it into single-gain pricing would break the
//!    gain-equals-eval-difference contract the scalar path guarantees.
//!
//! ## Adding an objective (~50 lines)
//!
//! Implement [`GainKernel`] for a struct holding your incremental state:
//! `shard_spec` (shape only), `shard_gain_partial` (read-only pricing of a
//! shard), `apply_push` (commit + realized gain), `value`/`selected`
//! getters, and optionally `normalize` (post-reduction scaling),
//! `singleton` (closed-form f({e})) and `backend_batch` (accelerator hook).
//! Then `SubmodularFn::state` returns
//! `Box::new(ShardedGainEngine::new(kernel))` and your objective inherits
//! batched, parallel, thread-invariant pricing plus oracle accounting —
//! see `objective::modular` for the smallest complete example.

use std::ops::Range;

use super::{OracleCounter, State};
use crate::util::executor::{parallel_map, shard_ranges};
use crate::util::trace;

/// Pluggable batched-gain accelerator backend (implemented by
/// `runtime::xla_facility`, and the seam a CUDA/Pallas backend will use).
/// Lives here — not in any one objective — because the engine owns the
/// dispatch decision; facility re-exports it for compatibility.
pub trait GainBackend: Sync + Send {
    /// For each candidate id, the UNNORMALIZED gain
    /// `Σ_{v∈W} max(curmin[v] − l(cand, v), 0)`, where `curmin` is indexed
    /// by position in the evaluation window.
    fn batch_gain_sums(&self, cands: &[usize], curmin: &[f32]) -> Vec<f64>;
}

/// Window points per shard below which sharding stops paying for itself;
/// also bounds the shard count so tiny windows stay one serial stream.
pub const MIN_SHARD_POINTS: usize = 256;

/// Hard cap on shards per pricing call (window reduction cost is
/// `shards × candidates`; candidate-shard joins are `shards` appends).
pub const MAX_SHARDS: usize = 16;

/// Default candidate-shard floor for kernels whose per-candidate work is a
/// few cache lines (coverage, cut, modular, entropy): fan-out only pays for
/// itself on wide batches.
pub const MIN_CANDIDATES_PER_SHARD: usize = 64;

/// Candidate-shard floor for heavy kernels (info-gain, DPP): each candidate
/// costs an O(k²) forward solve, so even narrow batches amortize a shard.
pub const MIN_HEAVY_CANDIDATES_PER_SHARD: usize = 8;

/// How a kernel's batched pricing splits across the executor — a pure
/// function of problem shape (see the module-level determinism rules).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardSpec {
    /// Split the evaluation window of `len` points: every shard prices
    /// every candidate over its window slice, partial sums reduce in shard
    /// order, then [`GainKernel::normalize`] runs per candidate.
    Window { len: usize },
    /// Split the candidate list, at least `min_per_shard` candidates per
    /// shard: each shard returns final gains for its own slice, and slices
    /// concatenate in shard order (= input order).
    Candidates { min_per_shard: usize },
}

/// Number of window shards for a window of `len` points — a fixed function
/// of the window length ONLY (never the thread count), which is what makes
/// the parallel path bit-identical across thread counts.
pub fn window_shard_count(len: usize) -> usize {
    (len / MIN_SHARD_POINTS).clamp(1, MAX_SHARDS)
}

/// Number of candidate shards for a batch of `n_cands` candidates with a
/// per-shard floor of `min_per_shard` — again a function of batch shape
/// only. (Concatenation in shard order makes thread-independence trivial
/// here, but keeping boundaries shape-only means the engine has ONE rule.)
pub fn candidate_shard_count(n_cands: usize, min_per_shard: usize) -> usize {
    (n_cands / min_per_shard.max(1)).clamp(1, MAX_SHARDS)
}

/// The per-objective contract: everything the engine cannot know. All
/// pricing entry points of [`State`] are derived from these few methods.
pub trait GainKernel: Sync {
    /// Shard shape for batched pricing — pure function of problem shape.
    fn shard_spec(&self) -> ShardSpec;

    /// Price candidates against one shard. Read-only (called concurrently
    /// on the executor); scratch space must be local to the invocation.
    ///
    /// [`ShardSpec::Window`]: `rows` is the window slice; return one
    /// *partial, unnormalized* sum per candidate in `es` (all of them).
    /// [`ShardSpec::Candidates`]: `rows` indexes into `es`; return the
    /// *final* gains of `es[rows]` only.
    fn shard_gain_partial(&self, es: &[usize], rows: &Range<usize>) -> Vec<f64>;

    /// Commit `e` into the solution, returning the realized gain. Must use
    /// the same arithmetic/kernel as pricing (the incremental caches are
    /// the cross-call carriers — mixing kernels would make a gain disagree
    /// with the eval-difference it promises).
    fn apply_push(&mut self, e: usize) -> f64;

    /// Current f(S).
    fn value(&self) -> f64;

    /// Elements committed so far, in insertion order.
    fn selected(&self) -> &[usize];

    /// Per-candidate normalization applied after the window-shard
    /// reduction (facility divides by |W|). Candidate-sharded kernels
    /// return final gains and never see this. Must be a pure function.
    fn normalize(&self, sum: f64) -> f64 {
        sum
    }

    /// Closed-form singleton value f({e}), when it can be computed without
    /// any state — MUST be bit-identical to pricing `e` through a fresh
    /// kernel (the sieve ladder and the empty-state fast path rely on
    /// exact agreement). Default: none.
    fn singleton(&self, _e: usize) -> Option<f64> {
        None
    }

    /// Accelerator seam: whole-batch override returning NORMALIZED gains
    /// (the facility XLA artifact; a GPU backend tomorrow). When `Some`,
    /// the engine skips CPU sharding entirely for batch pricing. Default:
    /// none.
    fn backend_batch(&self, _es: &[usize]) -> Option<Vec<f64>> {
        None
    }

    /// Stable label for the observability registry: dispatch-path counts
    /// land under `kernels.<label>` in [`trace::metrics_snapshot`]
    /// (see `util::trace`). Override per objective.
    ///
    /// [`trace::metrics_snapshot`]: crate::util::trace::metrics_snapshot
    fn label(&self) -> &'static str {
        "kernel"
    }
}

/// Closed-form singletons for a whole batch — `Some` only if the kernel
/// prices *every* candidate in closed form.
pub fn closed_form_singletons<K: GainKernel + ?Sized>(
    kernel: &K,
    es: &[usize],
) -> Option<Vec<f64>> {
    let mut out = Vec::with_capacity(es.len());
    for &e in es {
        out.push(kernel.singleton(e)?);
    }
    Some(out)
}

/// The engine: wraps a [`GainKernel`] into a full [`State`], owning shard
/// planning, executor submission, deterministic reduction, the accelerator
/// seam and oracle accounting. Every objective's `state()` returns one of
/// these.
pub struct ShardedGainEngine<K: GainKernel> {
    kernel: K,
    counter: OracleCounter,
    /// Dispatch-path metrics, resolved ONCE per engine from the kernel's
    /// label — the hot pricing loop only touches relaxed atomics.
    metrics: &'static trace::KernelCounters,
}

impl<K: GainKernel> ShardedGainEngine<K> {
    pub fn new(kernel: K) -> Self {
        let metrics = trace::kernel_counters(kernel.label());
        ShardedGainEngine { kernel, counter: OracleCounter::default(), metrics }
    }

    /// The wrapped kernel (tests/benches peek at objective-specific state).
    pub fn kernel(&self) -> &K {
        &self.kernel
    }

    /// The sharded CPU pricing path — shared verbatim by `gain`,
    /// `batch_gains` and `par_batch_gains` (`threads` only bounds in-flight
    /// shards; boundaries and reduction order never move).
    fn sharded_price(&self, es: &[usize], threads: usize) -> Vec<f64> {
        if es.is_empty() {
            return Vec::new();
        }
        let (shards, windowed) = match self.kernel.shard_spec() {
            ShardSpec::Window { len } => (shard_ranges(len, window_shard_count(len)), true),
            ShardSpec::Candidates { min_per_shard } => (
                shard_ranges(es.len(), candidate_shard_count(es.len(), min_per_shard)),
                false,
            ),
        };
        let kernel = &self.kernel;
        let partials: Vec<Vec<f64>> = if threads > 1 && shards.len() > 1 {
            parallel_map(shards, threads, |i, rows| {
                let _sp = trace::span_with("engine.shard", || {
                    vec![("shard", i.into()), ("rows", (rows.end - rows.start).into())]
                });
                kernel.shard_gain_partial(es, &rows)
            })
        } else {
            shards
                .into_iter()
                .enumerate()
                .map(|(i, rows)| {
                    let _sp = trace::span_with("engine.shard", || {
                        vec![("shard", i.into()), ("rows", (rows.end - rows.start).into())]
                    });
                    kernel.shard_gain_partial(es, &rows)
                })
                .collect()
        };
        if windowed {
            let mut out = vec![0.0f64; es.len()];
            for partial in &partials {
                for (acc, p) in out.iter_mut().zip(partial) {
                    *acc += p;
                }
            }
            out.into_iter().map(|s| self.kernel.normalize(s)).collect()
        } else {
            let mut out = Vec::with_capacity(es.len());
            for partial in partials {
                out.extend(partial);
            }
            out
        }
    }

    /// Single-candidate pricing without the batch machinery's planning
    /// allocations — the exact same per-shard computation and reduction
    /// order as [`ShardedGainEngine::sharded_price`] on a one-element
    /// batch, so `gain` stays bit-identical to the batch surfaces while
    /// hot single-gain loops (greedy-scaling commits, sieve re-pricing)
    /// avoid the Vec-of-partials round trip.
    fn sharded_gain_single(&self, e: usize) -> f64 {
        match self.kernel.shard_spec() {
            ShardSpec::Window { len } => {
                let sum: f64 = shard_ranges(len, window_shard_count(len))
                    .into_iter()
                    .map(|rows| self.kernel.shard_gain_partial(&[e], &rows)[0])
                    .sum();
                self.kernel.normalize(sum)
            }
            // shard_ranges(1, _) is always the single shard 0..1.
            ShardSpec::Candidates { .. } => self.kernel.shard_gain_partial(&[e], &(0..1))[0],
        }
    }

    /// Batched pricing entry: accelerator seam first, then the empty-state
    /// closed-form fast path (exact by the [`GainKernel::singleton`]
    /// contract — this is what makes sieve ladder pricing skip state work
    /// on objectives with analytic singletons), then the sharded path.
    fn price(&mut self, es: &[usize], threads: usize) -> Vec<f64> {
        self.counter.count_batch();
        self.counter.count_gain(es.len());
        self.metrics.gains.add(es.len() as u64);
        let _sp = trace::span_with("engine.price", || {
            vec![("kernel", self.kernel.label().into()), ("cands", es.len().into())]
        });
        if let Some(out) = self.kernel.backend_batch(es) {
            self.metrics.backend.incr();
            return out;
        }
        if self.kernel.selected().is_empty() {
            if let Some(out) = closed_form_singletons(&self.kernel, es) {
                self.metrics.closed_form.incr();
                return out;
            }
        }
        self.metrics.sharded.incr();
        self.sharded_price(es, threads)
    }
}

impl<K: GainKernel> State for ShardedGainEngine<K> {
    fn value(&self) -> f64 {
        self.kernel.value()
    }

    fn gain(&mut self, e: usize) -> f64 {
        // Single-gain pricing stays on the CPU kernel path even with a
        // backend installed (module docs, determinism rule 4).
        self.counter.count_gain(1);
        self.sharded_gain_single(e)
    }

    fn batch_gains(&mut self, es: &[usize]) -> Vec<f64> {
        self.price(es, 1)
    }

    fn par_batch_gains(&mut self, es: &[usize], threads: usize) -> Vec<f64> {
        self.price(es, threads)
    }

    fn push(&mut self, e: usize) -> f64 {
        self.kernel.apply_push(e)
    }

    fn selected(&self) -> &[usize] {
        self.kernel.selected()
    }

    fn oracle_counter(&self) -> OracleCounter {
        self.counter.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Minimal window kernel: f(S) = Σ_w base[w]·|S| over a fake window,
    /// normalized by window length — exercises shard reduction + normalize.
    struct ToyWindowKernel {
        base: Vec<f64>,
        selected: Vec<usize>,
    }

    impl GainKernel for ToyWindowKernel {
        fn shard_spec(&self) -> ShardSpec {
            ShardSpec::Window { len: self.base.len() }
        }
        fn shard_gain_partial(&self, es: &[usize], rows: &Range<usize>) -> Vec<f64> {
            let slice: f64 = self.base[rows.clone()].iter().sum();
            es.iter().map(|&e| slice * (1.0 + e as f64)).collect()
        }
        fn apply_push(&mut self, e: usize) -> f64 {
            self.selected.push(e);
            0.0
        }
        fn value(&self) -> f64 {
            0.0
        }
        fn selected(&self) -> &[usize] {
            &self.selected
        }
        fn normalize(&self, sum: f64) -> f64 {
            sum / self.base.len().max(1) as f64
        }
    }

    /// Minimal candidate kernel with a closed-form singleton.
    struct ToyCandKernel {
        weights: Vec<f64>,
        selected: Vec<usize>,
    }

    impl GainKernel for ToyCandKernel {
        fn shard_spec(&self) -> ShardSpec {
            ShardSpec::Candidates { min_per_shard: 4 }
        }
        fn shard_gain_partial(&self, es: &[usize], rows: &Range<usize>) -> Vec<f64> {
            es[rows.clone()].iter().map(|&e| self.weights[e]).collect()
        }
        fn apply_push(&mut self, e: usize) -> f64 {
            self.selected.push(e);
            self.weights[e]
        }
        fn value(&self) -> f64 {
            self.selected.iter().map(|&e| self.weights[e]).sum()
        }
        fn selected(&self) -> &[usize] {
            &self.selected
        }
        fn singleton(&self, e: usize) -> Option<f64> {
            Some(self.weights[e])
        }
    }

    #[test]
    fn shard_counts_are_shape_only_and_clamped() {
        assert_eq!(window_shard_count(0), 1);
        assert_eq!(window_shard_count(255), 1);
        assert_eq!(window_shard_count(512), 2);
        assert_eq!(window_shard_count(1 << 20), MAX_SHARDS);
        assert_eq!(candidate_shard_count(10, 64), 1);
        assert_eq!(candidate_shard_count(128, 64), 2);
        assert_eq!(candidate_shard_count(100_000, 64), MAX_SHARDS);
        assert_eq!(candidate_shard_count(64, 0), MAX_SHARDS.min(64));
    }

    #[test]
    fn window_reduction_thread_invariant() {
        let mut st = ShardedGainEngine::new(ToyWindowKernel {
            base: (0..2_000).map(|i| (i as f64).sin()).collect(),
            selected: Vec::new(),
        });
        let es: Vec<usize> = (0..37).collect();
        let serial = st.batch_gains(&es);
        for threads in [1usize, 2, 3, 8] {
            assert_eq!(serial, st.par_batch_gains(&es, threads), "threads={threads}");
        }
        for (i, &e) in es.iter().enumerate() {
            assert_eq!(serial[i], st.gain(e), "gain({e}) diverged from batch");
        }
    }

    #[test]
    fn candidate_concat_preserves_input_order() {
        let mut st = ShardedGainEngine::new(ToyCandKernel {
            weights: (0..500).map(|i| i as f64 * 0.5).collect(),
            selected: vec![0], // defeat the singleton fast path
        });
        let es: Vec<usize> = (0..500).rev().collect();
        let serial = st.batch_gains(&es);
        let expect: Vec<f64> = es.iter().map(|&e| e as f64 * 0.5).collect();
        assert_eq!(serial, expect);
        for threads in [2usize, 8] {
            assert_eq!(serial, st.par_batch_gains(&es, threads));
        }
    }

    #[test]
    fn empty_state_uses_closed_form_singletons() {
        let mut st = ShardedGainEngine::new(ToyCandKernel {
            weights: vec![1.0, 2.0, 3.0],
            selected: Vec::new(),
        });
        assert_eq!(st.batch_gains(&[2, 0]), vec![3.0, 1.0]);
        st.push(1);
        // after a commit the sharded path takes over (same values here)
        assert_eq!(st.batch_gains(&[2, 0]), vec![3.0, 1.0]);
    }

    #[test]
    fn oracle_counter_tracks_batches_and_gains() {
        let mut st = ShardedGainEngine::new(ToyCandKernel {
            weights: vec![1.0; 100],
            selected: Vec::new(),
        });
        st.batch_gains(&(0..100).collect::<Vec<_>>());
        st.par_batch_gains(&[1, 2, 3], 4);
        st.gain(5);
        let c = st.oracle_counter();
        assert_eq!(c.batches, 2);
        assert_eq!(c.gains, 104);
    }

    #[test]
    fn empty_batch_prices_to_empty() {
        let mut st = ShardedGainEngine::new(ToyWindowKernel {
            base: vec![1.0; 10],
            selected: Vec::new(),
        });
        assert!(st.batch_gains(&[]).is_empty());
        assert!(st.par_batch_gains(&[], 8).is_empty());
    }
}
