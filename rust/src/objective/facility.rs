//! Exemplar-based clustering objective (paper §3.4.2, experiments §6.1).
//!
//! With dissimilarity `l(e, v) = ‖e − v‖²` and phantom exemplar `e₀ = 0`
//! (valid after the paper's mean-subtract + unit-norm preprocessing, which
//! bounds all pairwise distances), the k-medoid loss
//! `L(S) = 1/|W| Σ_{v∈W} min_{e∈S} l(e, v)` turns into the monotone
//! submodular utility `f(S) = L({e₀}) − L(S ∪ {e₀})`.
//!
//! The incremental state caches `curmin[v] = min_{e ∈ S∪{e₀}} l(e, v)`,
//! giving O(|W|) marginal gains and O(|W|) commits — this cache *is* the
//! hot path the Pallas kernel (`facility_gain.py`) reproduces blockwise;
//! the [`GainBackend`] hook lets the runtime swap the scalar loop for the
//! batched XLA artifact without the algorithms noticing.
//!
//! `W` (the evaluation window) is the full dataset in global mode or the
//! local shard in the paper's decomposable mode (§4.5).

use std::sync::Arc;

use super::{State, SubmodularFn};
use crate::data::Dataset;

/// Pluggable batched-gain backend (implemented by `runtime::xla_facility`).
pub trait GainBackend: Sync + Send {
    /// For each candidate id, the UNNORMALIZED gain
    /// `Σ_{v∈W} max(curmin[v] − l(cand, v), 0)`, where `curmin` is indexed
    /// by position in the evaluation window.
    fn batch_gain_sums(&self, cands: &[usize], curmin: &[f32]) -> Vec<f64>;
}

/// Facility-location / exemplar clustering objective.
pub struct FacilityLocation {
    data: Arc<Dataset>,
    /// Evaluation window W: indices of the points the loss averages over.
    window: Vec<usize>,
    /// Distance from the phantom exemplar (= squared norm of each window
    /// point, since e₀ is the origin), precomputed.
    phantom: Vec<f64>,
    /// Window rows packed contiguously (row-major |W|×d) — the gain loop
    /// streams this sequentially instead of gathering `data.row(window[i])`
    /// (perf pass §A: ~2× on the scalar hot path from cache locality).
    packed: Vec<f32>,
    backend: Option<Arc<dyn GainBackend>>,
}

impl FacilityLocation {
    /// Global-mode objective: loss averaged over the entire dataset.
    pub fn from_dataset(data: &Arc<Dataset>) -> Self {
        let window = (0..data.n).collect();
        Self::with_window(data, window)
    }

    /// Restricted objective: loss averaged over `window` only (the paper's
    /// local/decomposable evaluation, §4.5 — `window` is a machine's shard
    /// or the random subset U used in GreeDi's second stage).
    pub fn with_window(data: &Arc<Dataset>, window: Vec<usize>) -> Self {
        let phantom = window
            .iter()
            .map(|&v| data.row(v).iter().map(|&x| (x as f64) * (x as f64)).sum())
            .collect();
        let mut packed = Vec::with_capacity(window.len() * data.d);
        for &v in &window {
            packed.extend_from_slice(data.row(v));
        }
        FacilityLocation {
            data: Arc::clone(data),
            window,
            phantom,
            packed,
            backend: None,
        }
    }

    /// Install a batched-gain backend (XLA artifact executor).
    pub fn with_backend(mut self, backend: Arc<dyn GainBackend>) -> Self {
        self.backend = Some(backend);
        self
    }

    pub fn window(&self) -> &[usize] {
        &self.window
    }

    pub fn data(&self) -> &Arc<Dataset> {
        &self.data
    }
}

impl SubmodularFn for FacilityLocation {
    fn state(&self) -> Box<dyn State + '_> {
        Box::new(FacilityState {
            obj: self,
            curmin: self.phantom.clone(),
            selected: Vec::new(),
            value: 0.0,
        })
    }

    fn ground_size(&self) -> usize {
        self.data.n
    }
}

/// Incremental state: cached min squared distance per window point.
pub struct FacilityState<'a> {
    obj: &'a FacilityLocation,
    curmin: Vec<f64>,
    selected: Vec<usize>,
    value: f64,
}

impl<'a> FacilityState<'a> {
    /// Scalar-loop gain sum for one candidate (reference hot path):
    /// streams the packed window buffer sequentially.
    fn gain_sum(&self, e: usize) -> f64 {
        let d = self.obj.data.d;
        let erow = self.obj.data.row(e);
        let mut sum = 0.0;
        // per-point distance accumulates in f32 (data is f32; relative error
        // ~1e-6 ≪ the f32 kernel's own noise); the cross-point sum stays f64.
        // NOTE(perf §A, iteration 3): an early-exit variant (break once the
        // partial d² passes curmin) was tried and REVERTED — the branch in
        // the inner loop defeated auto-vectorization and cost 2.2×.
        for (idx, vrow) in self.obj.packed.chunks_exact(d).enumerate() {
            let mut d2 = 0.0f32;
            for t in 0..d {
                let diff = vrow[t] - erow[t];
                d2 += diff * diff;
            }
            let gain = self.curmin[idx] - d2 as f64;
            if gain > 0.0 {
                sum += gain;
            }
        }
        sum
    }

    /// Expose curmin as f32 (what the XLA backend consumes).
    fn curmin_f32(&self) -> Vec<f32> {
        self.curmin.iter().map(|&x| x as f32).collect()
    }
}

impl<'a> State for FacilityState<'a> {
    fn value(&self) -> f64 {
        self.value
    }

    fn gain(&mut self, e: usize) -> f64 {
        self.gain_sum(e) / self.obj.window.len().max(1) as f64
    }

    fn batch_gains(&mut self, es: &[usize]) -> Vec<f64> {
        let n = self.obj.window.len().max(1) as f64;
        if let Some(backend) = &self.obj.backend {
            let cm = self.curmin_f32();
            return backend
                .batch_gain_sums(es, &cm)
                .into_iter()
                .map(|s| s / n)
                .collect();
        }
        // Scalar path: per-candidate streaming of the packed window.
        // NOTE(perf §A, iteration 4): a blocked loop interchange (window
        // outer, 64-candidate block inner) was tried and REVERTED — the
        // per-point stores into the per-candidate accumulators cost more
        // than the window re-streams they saved (2.4 ms vs 1.7 ms).
        es.iter().map(|&e| self.gain_sum(e) / n).collect()
    }

    fn push(&mut self, e: usize) -> f64 {
        let d = self.obj.data.d;
        let erow = self.obj.data.row(e);
        let mut sum = 0.0;
        for (idx, vrow) in self.obj.packed.chunks_exact(d).enumerate() {
            let mut d2f = 0.0f32;
            for t in 0..d {
                let diff = vrow[t] - erow[t];
                d2f += diff * diff;
            }
            let d2 = d2f as f64;
            if d2 < self.curmin[idx] {
                sum += self.curmin[idx] - d2;
                self.curmin[idx] = d2;
            }
        }
        let gain = sum / self.obj.window.len().max(1) as f64;
        self.value += gain;
        self.selected.push(e);
        gain
    }

    fn selected(&self) -> &[usize] {
        &self.selected
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_blobs, SynthConfig};
    use crate::objective::{check_diminishing_returns, check_monotone};
    use crate::util::rng::Rng;

    fn dataset(n: usize) -> Arc<Dataset> {
        Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 8), 11))
    }

    #[test]
    fn empty_set_value_zero() {
        let ds = dataset(50);
        let f = FacilityLocation::from_dataset(&ds);
        assert_eq!(f.eval(&[]), 0.0);
    }

    #[test]
    fn gain_matches_eval_difference() {
        let ds = dataset(60);
        let f = FacilityLocation::from_dataset(&ds);
        let mut st = f.state();
        st.push(3);
        st.push(17);
        let g = st.gain(25);
        let brute = f.eval(&[3, 17, 25]) - f.eval(&[3, 17]);
        assert!((g - brute).abs() < 1e-9, "{g} vs {brute}");
    }

    #[test]
    fn push_returns_realized_gain_and_updates_value() {
        let ds = dataset(40);
        let f = FacilityLocation::from_dataset(&ds);
        let mut st = f.state();
        let g1 = st.push(0);
        let g2 = st.push(7);
        assert!((st.value() - (g1 + g2)).abs() < 1e-12);
        assert!((st.value() - f.eval(&[0, 7])).abs() < 1e-9);
    }

    #[test]
    fn is_monotone_and_submodular() {
        let ds = dataset(24);
        let f = FacilityLocation::from_dataset(&ds);
        let ground: Vec<usize> = (0..24).collect();
        let mut rng = Rng::new(5);
        assert!(check_monotone(&f, &ground, &mut rng, 50) < 1e-9);
        assert!(check_diminishing_returns(&f, &ground, &mut rng, 50) < 1e-9);
    }

    #[test]
    fn duplicate_push_zero_gain() {
        let ds = dataset(30);
        let f = FacilityLocation::from_dataset(&ds);
        let mut st = f.state();
        st.push(5);
        assert!(st.gain(5).abs() < 1e-12);
        assert!(st.push(5).abs() < 1e-12);
    }

    #[test]
    fn windowed_matches_manual_restriction() {
        let ds = dataset(40);
        let window: Vec<usize> = (0..40).step_by(2).collect();
        let f = FacilityLocation::with_window(&ds, window.clone());
        // manual: mean over window of curmin reduction
        let s = [1, 9];
        let mut expect = 0.0;
        for &v in &window {
            let phantom: f64 = ds.row(v).iter().map(|&x| (x as f64).powi(2)).sum();
            let best = s
                .iter()
                .map(|&e| ds.sqdist(e, v))
                .fold(phantom, f64::min);
            expect += phantom - best;
        }
        expect /= window.len() as f64;
        // per-point distances accumulate in f32 on the hot path — compare
        // against the f64 oracle at f32 precision.
        assert!((f.eval(&s) - expect).abs() < 1e-5);
    }

    #[test]
    fn batch_gains_matches_scalar() {
        let ds = dataset(50);
        let f = FacilityLocation::from_dataset(&ds);
        let mut st = f.state();
        st.push(2);
        let cands = vec![0, 1, 5, 9, 30];
        let batch = st.batch_gains(&cands);
        for (i, &e) in cands.iter().enumerate() {
            assert!((batch[i] - st.gain(e)).abs() < 1e-12);
        }
    }

    struct FakeBackend;
    impl GainBackend for FakeBackend {
        fn batch_gain_sums(&self, cands: &[usize], _curmin: &[f32]) -> Vec<f64> {
            cands.iter().map(|&c| c as f64).collect()
        }
    }

    #[test]
    fn backend_is_used_for_batches() {
        let ds = dataset(20);
        let f = FacilityLocation::from_dataset(&ds).with_backend(Arc::new(FakeBackend));
        let mut st = f.state();
        let gains = st.batch_gains(&[4, 8]);
        assert!((gains[0] - 4.0 / 20.0).abs() < 1e-12);
        assert!((gains[1] - 8.0 / 20.0).abs() < 1e-12);
    }
}
