//! Exemplar-based clustering objective (paper §3.4.2, experiments §6.1).
//!
//! With dissimilarity `l(e, v) = ‖e − v‖²` and phantom exemplar `e₀ = 0`
//! (valid after the paper's mean-subtract + unit-norm preprocessing, which
//! bounds all pairwise distances), the k-medoid loss
//! `L(S) = 1/|W| Σ_{v∈W} min_{e∈S} l(e, v)` turns into the monotone
//! submodular utility `f(S) = L({e₀}) − L(S ∪ {e₀})`.
//!
//! The incremental state caches `curmin[v] = min_{e ∈ S∪{e₀}} l(e, v)`,
//! giving O(|W|) marginal gains and O(|W|) commits — this cache *is* the
//! hot path the Pallas kernel (`facility_gain.py`) reproduces blockwise;
//! the [`GainBackend`] hook lets the runtime swap the scalar loop for the
//! batched XLA artifact without the algorithms noticing.
//!
//! `W` (the evaluation window) is the full dataset in global mode or the
//! local shard in the paper's decomposable mode (§4.5).
//!
//! ## The engine refactor: facility as a thin [`GainKernel`]
//!
//! `Σ_v max(curmin[v] − ‖e−v‖², 0)` is embarrassingly parallel over `v`.
//! Window sharding, executor submission, shard-ordered reduction and the
//! backend seam all moved to [`engine::ShardedGainEngine`] — this module
//! now only supplies [`FacilityKernel`]: the `curmin` caches, the
//! per-shard distance loop ([`FacilityKernel::gain_partial`]), the commit
//! scan, and the `/|W|` normalization. Shard boundaries are the engine's
//! [`engine::window_shard_count`] — the same `(|W|/256).clamp(1, 16)`
//! rule this module used pre-refactor, a fixed function of `|W|` only —
//! and per-shard partials still reduce in shard order, so gains remain
//! bit-identical at 1, 2 or 64 threads and bit-for-bit unchanged vs. the
//! pre-refactor module per dispatch path. The sequential-stream inner loop
//! that made perf iteration 2 fast stays intact per shard (the loop
//! interchange of iteration 4 and the early-exit of iteration 3 remain
//! reverted — see the NOTE on [`FacilityKernel::gain_partial`]).
//!
//! ## Runtime-dispatched explicit SIMD distance kernel (perf pass §B)
//!
//! On `x86_64` the distance kernel has a hand-rolled **AVX2 + FMA**
//! implementation ([`kernel_sq_dist`] and the fused per-shard loops in
//! `kernel_x86`), selected once per process via `is_x86_feature_detected!`
//! with the [`LANES`]-lane scalar loop as the portable fallback (and as the
//! forced path under `GREEDI_NO_SIMD=1`, which CI exercises). Auto-
//! vectorization already kept a SIMD register busy; the explicit kernel
//! additionally fuses the multiply-add (`vfmadd231ps`) and removes the
//! epilogue LLVM generates for the generic lane loop.
//!
//! **Determinism contract (per dispatch path).** Every evaluation surface —
//! `gain`, `batch_gains`, `par_batch_gains`, `push`, and through them
//! `eval` — routes through the *same* dispatched kernel, the same shard
//! boundaries, and the same shard-ordered reduction, so results remain
//! bit-identical across 1/2/N threads and across repeated runs on the same
//! machine. SIMD vs scalar may differ in the last ulp (FMA keeps the
//! intermediate product unrounded; the scalar path rounds twice), so runs
//! are comparable across ISAs/dispatch paths only to f32 tolerance — the
//! contract is *per dispatch path*, and the path is fixed for the life of
//! the process (detection is cached).

use std::ops::Range;
use std::sync::{Arc, OnceLock};

use super::engine::{self, GainKernel, ShardSpec, ShardedGainEngine};
use super::{State, SubmodularFn};
use crate::data::Dataset;

/// Re-exported accelerator seam (canonical home: [`engine::GainBackend`];
/// kept here so pre-refactor import paths keep compiling).
pub use super::engine::GainBackend;

/// Independent f32 accumulator lanes in the distance inner loop (perf §A,
/// iteration 5): enough independent chains for LLVM to keep a full SIMD
/// register busy, reduced in a fixed tree order for determinism.
const LANES: usize = 8;

/// Squared Euclidean distance in f32 with [`LANES`] independent accumulator
/// chains and a deterministic tree reduction — the portable kernel, and the
/// fallback whenever AVX2+FMA is unavailable or disabled.
#[inline]
fn sq_dist_scalar(a: &[f32], b: &[f32]) -> f32 {
    let mut lanes = [0.0f32; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
        for l in 0..LANES {
            let diff = xa[l] - xb[l];
            lanes[l] += diff * diff;
        }
    }
    let mut tail = 0.0f32;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        let diff = x - y;
        tail += diff * diff;
    }
    let q0 = (lanes[0] + lanes[4]) + (lanes[1] + lanes[5]);
    let q1 = (lanes[2] + lanes[6]) + (lanes[3] + lanes[7]);
    (q0 + q1) + tail
}

/// Whether the explicit AVX2+FMA kernel is active for this process.
/// Detected once and cached: `GREEDI_NO_SIMD` (any value but `0`) forces the
/// scalar path; otherwise `x86_64` hosts with AVX2 *and* FMA take the
/// intrinsics path. Fixing the path per process is what keeps repeated runs
/// on one machine bit-identical (the determinism contract in the module
/// docs is per dispatch path).
#[allow(unreachable_code)]
pub fn simd_active() -> bool {
    static ACTIVE: OnceLock<bool> = OnceLock::new();
    *ACTIVE.get_or_init(|| {
        if std::env::var_os("GREEDI_NO_SIMD").is_some_and(|v| v != "0") {
            return false;
        }
        #[cfg(target_arch = "x86_64")]
        {
            return is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma");
        }
        false
    })
}

/// Bench/test-facing label for the dispatched kernel.
pub fn kernel_name() -> &'static str {
    if simd_active() {
        "avx2+fma"
    } else {
        "scalar-8lane"
    }
}

/// Squared Euclidean distance through the runtime-dispatched kernel — the
/// single distance primitive every facility evaluation path shares.
#[inline]
fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    {
        if simd_active() {
            // SAFETY: `simd_active` returned true only after
            // `is_x86_feature_detected!` confirmed AVX2 and FMA.
            return unsafe { kernel_x86::sq_dist_avx2(a, b) };
        }
    }
    sq_dist_scalar(a, b)
}

/// Public (bench-facing) dispatched distance kernel — see [`kernel_name`]
/// for which path it resolves to on this host.
#[inline]
pub fn kernel_sq_dist(a: &[f32], b: &[f32]) -> f32 {
    sq_dist(a, b)
}

/// Public (bench-facing) portable scalar kernel, for SIMD-vs-scalar
/// microbenches and cross-path tolerance tests.
#[inline]
pub fn kernel_sq_dist_scalar(a: &[f32], b: &[f32]) -> f32 {
    sq_dist_scalar(a, b)
}

/// Scalar per-shard gain loop (the worker kernel of the sharded engine on
/// the portable path). See [`FacilityKernel::gain_partial`] for dispatch.
fn gain_partial_scalar(packed: &[f32], d: usize, curmin: &[f64], erow: &[f32]) -> f64 {
    let mut sum = 0.0f64;
    for (idx, vrow) in packed.chunks_exact(d).enumerate() {
        let gain = curmin[idx] - sq_dist_scalar(vrow, erow) as f64;
        if gain > 0.0 {
            sum += gain;
        }
    }
    sum
}

/// Dispatched commit scan: commits MUST use the same kernel as gains —
/// `curmin` is the cross-call carrier, so mixing kernels would make a gain
/// disagree with the eval-difference it promises.
fn push_scan(
    packed: &[f32],
    d: usize,
    curmin: &mut [f64],
    curmin32: &mut [f32],
    erow: &[f32],
) -> f64 {
    #[cfg(target_arch = "x86_64")]
    {
        if simd_active() {
            // SAFETY: `simd_active` gates on `is_x86_feature_detected!`.
            return unsafe { kernel_x86::push_scan_avx2(packed, d, curmin, curmin32, erow) };
        }
    }
    push_scan_scalar(packed, d, curmin, curmin32, erow)
}

/// Scalar commit scan: lower `curmin`/`curmin32` where the new exemplar is
/// closer, returning the summed reduction. See [`FacilityKernel::apply_push`].
fn push_scan_scalar(
    packed: &[f32],
    d: usize,
    curmin: &mut [f64],
    curmin32: &mut [f32],
    erow: &[f32],
) -> f64 {
    let mut sum = 0.0f64;
    for (idx, vrow) in packed.chunks_exact(d).enumerate() {
        let d2 = sq_dist_scalar(vrow, erow) as f64;
        if d2 < curmin[idx] {
            sum += curmin[idx] - d2;
            curmin[idx] = d2;
            curmin32[idx] = d2 as f32;
        }
    }
    sum
}

/// Explicit AVX2+FMA kernels (perf pass §B). The whole per-shard loop lives
/// inside one `#[target_feature]` function so the 8-wide distance body
/// inlines into it — dispatch happens once per shard / per push, never per
/// window point. Reduction order mirrors the scalar kernel's lane-pair tree
/// (`(l0+l4)+(l1+l5)` …), but FMA keeps products unrounded, so values may
/// differ from the scalar path in the last ulp (documented contract).
#[cfg(target_arch = "x86_64")]
mod kernel_x86 {
    use std::arch::x86_64::*;

    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    unsafe fn hsum256(v: __m256) -> f32 {
        // pair lanes (l0+l4, l1+l5, l2+l6, l3+l7), then the 4→1 tree —
        // the same pairing the scalar kernel reduces with.
        let hi = _mm256_extractf128_ps::<1>(v);
        let lo = _mm256_castps256_ps128(v);
        let pairs = _mm_add_ps(lo, hi);
        let shuf = _mm_movehdup_ps(pairs); // [p1, p1, p3, p3]
        let sums = _mm_add_ps(pairs, shuf); // [p0+p1, _, p2+p3, _]
        let hi2 = _mm_movehl_ps(sums, sums); // lane0 = p2+p3
        _mm_cvtss_f32(_mm_add_ss(sums, hi2))
    }

    /// 8-wide FMA squared distance; scalar tail handled after the reduce.
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    pub unsafe fn sq_dist_avx2(a: &[f32], b: &[f32]) -> f32 {
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let pa = a.as_ptr();
        let pb = b.as_ptr();
        let mut acc = _mm256_setzero_ps();
        let mut i = 0usize;
        while i + 8 <= n {
            let va = _mm256_loadu_ps(pa.add(i));
            let vb = _mm256_loadu_ps(pb.add(i));
            let diff = _mm256_sub_ps(va, vb);
            acc = _mm256_fmadd_ps(diff, diff, acc);
            i += 8;
        }
        let mut sum = hsum256(acc);
        while i < n {
            let diff = *pa.add(i) - *pb.add(i);
            sum += diff * diff;
            i += 1;
        }
        sum
    }

    /// AVX2 per-shard gain loop (same shape as `gain_partial_scalar`; the
    /// cross-point accumulator stays f64, so only the per-point distance
    /// differs from the portable path).
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    pub unsafe fn gain_partial_avx2(
        packed: &[f32],
        d: usize,
        curmin: &[f64],
        erow: &[f32],
    ) -> f64 {
        let mut sum = 0.0f64;
        for (idx, vrow) in packed.chunks_exact(d).enumerate() {
            let gain = curmin[idx] - sq_dist_avx2(vrow, erow) as f64;
            if gain > 0.0 {
                sum += gain;
            }
        }
        sum
    }

    /// AVX2 commit scan (same shape as `push_scan_scalar`).
    #[target_feature(enable = "avx2")]
    #[target_feature(enable = "fma")]
    pub unsafe fn push_scan_avx2(
        packed: &[f32],
        d: usize,
        curmin: &mut [f64],
        curmin32: &mut [f32],
        erow: &[f32],
    ) -> f64 {
        let mut sum = 0.0f64;
        for (idx, vrow) in packed.chunks_exact(d).enumerate() {
            let d2 = sq_dist_avx2(vrow, erow) as f64;
            if d2 < curmin[idx] {
                sum += curmin[idx] - d2;
                curmin[idx] = d2;
                curmin32[idx] = d2 as f32;
            }
        }
        sum
    }
}

/// Facility-location / exemplar clustering objective.
pub struct FacilityLocation {
    data: Arc<Dataset>,
    /// Evaluation window W: indices of the points the loss averages over.
    window: Vec<usize>,
    /// Distance from the phantom exemplar (= squared norm of each window
    /// point, since e₀ is the origin), precomputed.
    phantom: Vec<f64>,
    /// f32 image of `phantom` — seeds each state's `curmin32` mirror without
    /// a per-state conversion pass.
    phantom32: Vec<f32>,
    /// Window rows packed contiguously (row-major |W|×d) — the gain loop
    /// streams this sequentially instead of gathering `data.row(window[i])`
    /// (perf pass §A: ~2× on the scalar hot path from cache locality).
    packed: Vec<f32>,
    backend: Option<Arc<dyn GainBackend>>,
}

impl FacilityLocation {
    /// Global-mode objective: loss averaged over the entire dataset.
    pub fn from_dataset(data: &Arc<Dataset>) -> Self {
        let window = (0..data.n).collect();
        Self::with_window(data, window)
    }

    /// Restricted objective: loss averaged over `window` only (the paper's
    /// local/decomposable evaluation, §4.5 — `window` is a machine's shard
    /// or the random subset U used in GreeDi's second stage).
    pub fn with_window(data: &Arc<Dataset>, window: Vec<usize>) -> Self {
        let phantom: Vec<f64> = window
            .iter()
            .map(|&v| data.row(v).iter().map(|&x| (x as f64) * (x as f64)).sum())
            .collect();
        let phantom32 = phantom.iter().map(|&x| x as f32).collect();
        let mut packed = Vec::with_capacity(window.len() * data.d);
        for &v in &window {
            packed.extend_from_slice(data.row(v));
        }
        FacilityLocation {
            data: Arc::clone(data),
            window,
            phantom,
            phantom32,
            packed,
            backend: None,
        }
    }

    /// Install a batched-gain backend (XLA artifact executor).
    pub fn with_backend(mut self, backend: Arc<dyn GainBackend>) -> Self {
        self.backend = Some(backend);
        self
    }

    pub fn window(&self) -> &[usize] {
        &self.window
    }

    pub fn data(&self) -> &Arc<Dataset> {
        &self.data
    }
}

impl SubmodularFn for FacilityLocation {
    fn state(&self) -> Box<dyn State + '_> {
        Box::new(ShardedGainEngine::new(FacilityKernel {
            obj: self,
            curmin: self.phantom.clone(),
            curmin32: self.phantom32.clone(),
            selected: Vec::new(),
            value: 0.0,
        }))
    }

    fn ground_size(&self) -> usize {
        self.data.n
    }
}

/// The facility [`GainKernel`]: cached min squared distance per window
/// point, plus an f32 mirror kept in sync by `apply_push` (consumed
/// zero-copy by [`GainBackend`]). Sharding, reduction, accounting and the
/// backend dispatch live in [`ShardedGainEngine`].
pub struct FacilityKernel<'a> {
    obj: &'a FacilityLocation,
    curmin: Vec<f64>,
    curmin32: Vec<f32>,
    selected: Vec<usize>,
    value: f64,
}

/// Pre-refactor name for the facility state, preserved as the engine-typed
/// alias (`SubmodularFn::state` boxes one of these).
pub type FacilityState<'a> = ShardedGainEngine<FacilityKernel<'a>>;

impl<'a> FacilityKernel<'a> {
    /// Unnormalized gain of one candidate over window rows `rows` — the
    /// worker kernel of the sharded engine. Streams its contiguous slice of
    /// the packed buffer sequentially; per-point distances accumulate in f32
    /// lanes (data is f32; relative error ~1e-6 ≪ the f32 kernel's own
    /// noise); the cross-point sum stays f64.
    /// NOTE(perf §A, iteration 3): an early-exit variant (break once the
    /// partial d² passes curmin) was tried and REVERTED — the branch in the
    /// inner loop defeated auto-vectorization and cost 2.2×.
    /// NOTE(perf §B): SIMD dispatch happens HERE, once per shard — the whole
    /// shard loop runs inside one `#[target_feature]` function so the
    /// intrinsics inline and the inner loop carries no dispatch branch.
    fn gain_partial(&self, e: usize, rows: &Range<usize>) -> f64 {
        let d = self.obj.data.d;
        let erow = self.obj.data.row(e);
        let packed = &self.obj.packed[rows.start * d..rows.end * d];
        let curmin = &self.curmin[rows.start..rows.end];
        #[cfg(target_arch = "x86_64")]
        {
            if simd_active() {
                // SAFETY: `simd_active` gates on `is_x86_feature_detected!`.
                return unsafe { kernel_x86::gain_partial_avx2(packed, d, curmin, erow) };
            }
        }
        gain_partial_scalar(packed, d, curmin, erow)
    }
}

impl<'a> GainKernel for FacilityKernel<'a> {
    fn label(&self) -> &'static str {
        "facility"
    }

    fn shard_spec(&self) -> ShardSpec {
        ShardSpec::Window { len: self.obj.window.len() }
    }

    fn shard_gain_partial(&self, es: &[usize], rows: &Range<usize>) -> Vec<f64> {
        es.iter().map(|&e| self.gain_partial(e, rows)).collect()
    }

    fn normalize(&self, sum: f64) -> f64 {
        sum / self.obj.window.len().max(1) as f64
    }

    fn backend_batch(&self, es: &[usize]) -> Option<Vec<f64>> {
        let backend = self.obj.backend.as_ref()?;
        // The incrementally-maintained f32 mirror goes straight to the
        // backend — no per-call allocation or f64→f32 conversion pass.
        let n = self.obj.window.len().max(1) as f64;
        Some(
            backend
                .batch_gain_sums(es, &self.curmin32)
                .into_iter()
                .map(|s| s / n)
                .collect(),
        )
    }

    fn apply_push(&mut self, e: usize) -> f64 {
        let obj = self.obj;
        let d = obj.data.d;
        let erow = obj.data.row(e);
        let sum = push_scan(&obj.packed, d, &mut self.curmin, &mut self.curmin32, erow);
        let gain = sum / obj.window.len().max(1) as f64;
        self.value += gain;
        self.selected.push(e);
        gain
    }

    fn value(&self) -> f64 {
        self.value
    }

    fn selected(&self) -> &[usize] {
        &self.selected
    }
}

/// Window shards the engine will use for this window length — bench-facing
/// mirror of [`engine::window_shard_count`] (kept so perf harnesses shard
/// their frozen baselines identically).
pub fn window_shards(window_len: usize) -> usize {
    engine::window_shard_count(window_len)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_blobs, SynthConfig};
    use crate::objective::{check_diminishing_returns, check_monotone};
    use crate::util::rng::Rng;

    fn dataset(n: usize) -> Arc<Dataset> {
        Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 8), 11))
    }

    #[test]
    fn empty_set_value_zero() {
        let ds = dataset(50);
        let f = FacilityLocation::from_dataset(&ds);
        assert_eq!(f.eval(&[]), 0.0);
    }

    #[test]
    fn gain_matches_eval_difference() {
        let ds = dataset(60);
        let f = FacilityLocation::from_dataset(&ds);
        let mut st = f.state();
        st.push(3);
        st.push(17);
        let g = st.gain(25);
        let brute = f.eval(&[3, 17, 25]) - f.eval(&[3, 17]);
        assert!((g - brute).abs() < 1e-9, "{g} vs {brute}");
    }

    #[test]
    fn push_returns_realized_gain_and_updates_value() {
        let ds = dataset(40);
        let f = FacilityLocation::from_dataset(&ds);
        let mut st = f.state();
        let g1 = st.push(0);
        let g2 = st.push(7);
        assert!((st.value() - (g1 + g2)).abs() < 1e-12);
        assert!((st.value() - f.eval(&[0, 7])).abs() < 1e-9);
    }

    #[test]
    fn is_monotone_and_submodular() {
        let ds = dataset(24);
        let f = FacilityLocation::from_dataset(&ds);
        let ground: Vec<usize> = (0..24).collect();
        let mut rng = Rng::new(5);
        assert!(check_monotone(&f, &ground, &mut rng, 50) < 1e-9);
        assert!(check_diminishing_returns(&f, &ground, &mut rng, 50) < 1e-9);
    }

    #[test]
    fn duplicate_push_zero_gain() {
        let ds = dataset(30);
        let f = FacilityLocation::from_dataset(&ds);
        let mut st = f.state();
        st.push(5);
        assert!(st.gain(5).abs() < 1e-12);
        assert!(st.push(5).abs() < 1e-12);
    }

    #[test]
    fn windowed_matches_manual_restriction() {
        let ds = dataset(40);
        let window: Vec<usize> = (0..40).step_by(2).collect();
        let f = FacilityLocation::with_window(&ds, window.clone());
        // manual: mean over window of curmin reduction
        let s = [1, 9];
        let mut expect = 0.0;
        for &v in &window {
            let phantom: f64 = ds.row(v).iter().map(|&x| (x as f64).powi(2)).sum();
            let best = s
                .iter()
                .map(|&e| ds.sqdist(e, v))
                .fold(phantom, f64::min);
            expect += phantom - best;
        }
        expect /= window.len() as f64;
        // per-point distances accumulate in f32 on the hot path — compare
        // against the f64 oracle at f32 precision.
        assert!((f.eval(&s) - expect).abs() < 1e-5);
    }

    #[test]
    fn batch_gains_matches_scalar() {
        let ds = dataset(50);
        let f = FacilityLocation::from_dataset(&ds);
        let mut st = f.state();
        st.push(2);
        let cands = vec![0, 1, 5, 9, 30];
        let batch = st.batch_gains(&cands);
        for (i, &e) in cands.iter().enumerate() {
            assert!((batch[i] - st.gain(e)).abs() < 1e-12);
        }
    }

    #[test]
    fn gain_bit_identical_to_batch_paths() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(900, 8), 14));
        let f = FacilityLocation::from_dataset(&ds);
        let mut st = f.state();
        st.push(1);
        for e in [0usize, 5, 250, 899] {
            let single = st.gain(e);
            let batched = st.par_batch_gains(&[e], 4)[0];
            assert_eq!(single, batched, "gain({e}) diverged from batch path");
        }
    }

    struct FakeBackend;
    impl GainBackend for FakeBackend {
        fn batch_gain_sums(&self, cands: &[usize], _curmin: &[f32]) -> Vec<f64> {
            cands.iter().map(|&c| c as f64).collect()
        }
    }

    #[test]
    fn backend_is_used_for_batches() {
        let ds = dataset(20);
        let f = FacilityLocation::from_dataset(&ds).with_backend(Arc::new(FakeBackend));
        let mut st = f.state();
        let gains = st.batch_gains(&[4, 8]);
        assert!((gains[0] - 4.0 / 20.0).abs() < 1e-12);
        assert!((gains[1] - 8.0 / 20.0).abs() < 1e-12);
    }

    /// Backend that echoes the curmin snapshot it was handed, so tests can
    /// observe the f32 mirror without reaching into private state.
    struct EchoBackend;
    impl GainBackend for EchoBackend {
        fn batch_gain_sums(&self, cands: &[usize], curmin: &[f32]) -> Vec<f64> {
            cands.iter().map(|&c| curmin[c] as f64).collect()
        }
    }

    #[test]
    fn dispatched_kernel_agrees_with_scalar_to_f32_tolerance() {
        // On AVX2+FMA hosts this cross-checks the intrinsics against the
        // portable kernel; on other hosts (or under GREEDI_NO_SIMD=1) both
        // sides are the scalar kernel and the test pins exact equality.
        let mut rng = Rng::new(17);
        for d in [1usize, 3, 7, 8, 15, 16, 22, 64] {
            let a: Vec<f32> = (0..d).map(|_| rng.f32() - 0.5).collect();
            let b: Vec<f32> = (0..d).map(|_| rng.f32() - 0.5).collect();
            let dispatched = kernel_sq_dist(&a, &b);
            let scalar = kernel_sq_dist_scalar(&a, &b);
            let tol = 1e-5f32 * scalar.abs().max(1.0);
            assert!(
                (dispatched - scalar).abs() <= tol,
                "d={d}: dispatched {dispatched} vs scalar {scalar} (kernel {})",
                kernel_name()
            );
            if !simd_active() {
                assert_eq!(dispatched, scalar, "scalar dispatch must be the scalar kernel");
            }
        }
    }

    #[test]
    fn simd_dispatch_is_stable_and_consistent_across_paths() {
        // The dispatch decision is cached per process, and gain/push/eval
        // all ride the same kernel: gain must equal the eval difference at
        // f64 noise (not merely f32), which fails if push and gain ever
        // resolve to different kernels.
        assert_eq!(simd_active(), simd_active());
        assert!(!kernel_name().is_empty());
        let ds = dataset(80);
        let f = FacilityLocation::from_dataset(&ds);
        let mut st = f.state();
        st.push(11);
        let g = st.gain(42);
        let brute = f.eval(&[11, 42]) - f.eval(&[11]);
        assert!((g - brute).abs() < 1e-9, "gain {g} vs eval diff {brute}");
    }

    #[test]
    fn f32_mirror_tracks_pushes() {
        let ds = dataset(30);
        let mirrored = FacilityLocation::from_dataset(&ds).with_backend(Arc::new(EchoBackend));
        let mut st = mirrored.state();
        for &e in &[4usize, 21, 9] {
            st.push(e);
        }
        // EchoBackend reports curmin32[c]·30 / 30 = curmin32[c]; the mirror
        // must match the f64 cache at f32 precision WITHOUT any refresh call
        // between pushes (it is maintained incrementally).
        let probe: Vec<usize> = (0..30).collect();
        let echoed = st.batch_gains(&probe);
        for (v, &g) in probe.iter().map(|&c| {
            // recompute the f64 curmin for window point c
            let phantom: f64 = ds.row(c).iter().map(|&x| (x as f64).powi(2)).sum();
            [4usize, 21, 9]
                .iter()
                .map(|&e| sq_dist(ds.row(c), ds.row(e)) as f64)
                .fold(phantom, f64::min)
        }).zip(echoed.iter()) {
            assert!((g * 30.0 - v).abs() < 1e-3, "mirror stale: {} vs {v}", g * 30.0);
        }
    }
}
