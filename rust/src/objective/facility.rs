//! Exemplar-based clustering objective (paper §3.4.2, experiments §6.1).
//!
//! With dissimilarity `l(e, v) = ‖e − v‖²` and phantom exemplar `e₀ = 0`
//! (valid after the paper's mean-subtract + unit-norm preprocessing, which
//! bounds all pairwise distances), the k-medoid loss
//! `L(S) = 1/|W| Σ_{v∈W} min_{e∈S} l(e, v)` turns into the monotone
//! submodular utility `f(S) = L({e₀}) − L(S ∪ {e₀})`.
//!
//! The incremental state caches `curmin[v] = min_{e ∈ S∪{e₀}} l(e, v)`,
//! giving O(|W|) marginal gains and O(|W|) commits — this cache *is* the
//! hot path the Pallas kernel (`facility_gain.py`) reproduces blockwise;
//! the [`GainBackend`] hook lets the runtime swap the scalar loop for the
//! batched XLA artifact without the algorithms noticing.
//!
//! `W` (the evaluation window) is the full dataset in global mode or the
//! local shard in the paper's decomposable mode (§4.5).
//!
//! ## Perf pass §A, iteration 5: the window-sharded parallel gain engine
//!
//! `Σ_v max(curmin[v] − ‖e−v‖², 0)` is embarrassingly parallel over `v`, so
//! [`State::par_batch_gains`] splits the packed window into **contiguous
//! shards** and has each worker stream *its own* shard for all candidates —
//! the sequential-stream inner loop that made iteration 2 fast stays intact
//! per thread (unlike the reverted loop interchange of iteration 4), and
//! there is no early-exit branch in the inner loop (reverted iteration 3).
//! The shard boundaries are a fixed function of `|W|` only — never the
//! thread count — and per-shard partials reduce in shard order, so gains are
//! bit-identical at 1, 2 or 64 threads; the serial `batch_gains`/`gain`
//! paths run the *same* sharded reduction on one thread, keeping every
//! evaluation path bit-identical to every other. The inner distance loop
//! accumulates in [`LANES`] independent f32 lanes so LLVM auto-vectorizes
//! the d-loop, and `push` maintains an f32 mirror of `curmin` so the XLA
//! backend path never re-allocates or converts per call.

use std::ops::Range;
use std::sync::Arc;

use super::{State, SubmodularFn};
use crate::data::Dataset;
use crate::util::threadpool::{parallel_map, shard_ranges};

/// Pluggable batched-gain backend (implemented by `runtime::xla_facility`).
pub trait GainBackend: Sync + Send {
    /// For each candidate id, the UNNORMALIZED gain
    /// `Σ_{v∈W} max(curmin[v] − l(cand, v), 0)`, where `curmin` is indexed
    /// by position in the evaluation window.
    fn batch_gain_sums(&self, cands: &[usize], curmin: &[f32]) -> Vec<f64>;
}

/// Independent f32 accumulator lanes in the distance inner loop (perf §A,
/// iteration 5): enough independent chains for LLVM to keep a full SIMD
/// register busy, reduced in a fixed tree order for determinism.
const LANES: usize = 8;

/// Window points per shard below which sharding stops paying for itself;
/// also bounds the shard count so tiny windows stay one serial stream.
const MIN_SHARD_POINTS: usize = 256;

/// Hard cap on window shards (reduction cost is `shards × candidates`).
const MAX_SHARDS: usize = 16;

/// Number of window shards the gain engine uses — a fixed function of the
/// window length ONLY (never the thread count), which is what makes the
/// parallel path bit-identical across thread counts.
fn shard_count(window_len: usize) -> usize {
    (window_len / MIN_SHARD_POINTS).clamp(1, MAX_SHARDS)
}

/// Squared Euclidean distance in f32 with [`LANES`] independent accumulator
/// chains and a deterministic tree reduction.
#[inline]
fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    let mut lanes = [0.0f32; LANES];
    let mut ca = a.chunks_exact(LANES);
    let mut cb = b.chunks_exact(LANES);
    for (xa, xb) in ca.by_ref().zip(cb.by_ref()) {
        for l in 0..LANES {
            let diff = xa[l] - xb[l];
            lanes[l] += diff * diff;
        }
    }
    let mut tail = 0.0f32;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        let diff = x - y;
        tail += diff * diff;
    }
    let q0 = (lanes[0] + lanes[4]) + (lanes[1] + lanes[5]);
    let q1 = (lanes[2] + lanes[6]) + (lanes[3] + lanes[7]);
    (q0 + q1) + tail
}

/// Facility-location / exemplar clustering objective.
pub struct FacilityLocation {
    data: Arc<Dataset>,
    /// Evaluation window W: indices of the points the loss averages over.
    window: Vec<usize>,
    /// Distance from the phantom exemplar (= squared norm of each window
    /// point, since e₀ is the origin), precomputed.
    phantom: Vec<f64>,
    /// f32 image of `phantom` — seeds each state's `curmin32` mirror without
    /// a per-state conversion pass.
    phantom32: Vec<f32>,
    /// Window rows packed contiguously (row-major |W|×d) — the gain loop
    /// streams this sequentially instead of gathering `data.row(window[i])`
    /// (perf pass §A: ~2× on the scalar hot path from cache locality).
    packed: Vec<f32>,
    backend: Option<Arc<dyn GainBackend>>,
}

impl FacilityLocation {
    /// Global-mode objective: loss averaged over the entire dataset.
    pub fn from_dataset(data: &Arc<Dataset>) -> Self {
        let window = (0..data.n).collect();
        Self::with_window(data, window)
    }

    /// Restricted objective: loss averaged over `window` only (the paper's
    /// local/decomposable evaluation, §4.5 — `window` is a machine's shard
    /// or the random subset U used in GreeDi's second stage).
    pub fn with_window(data: &Arc<Dataset>, window: Vec<usize>) -> Self {
        let phantom: Vec<f64> = window
            .iter()
            .map(|&v| data.row(v).iter().map(|&x| (x as f64) * (x as f64)).sum())
            .collect();
        let phantom32 = phantom.iter().map(|&x| x as f32).collect();
        let mut packed = Vec::with_capacity(window.len() * data.d);
        for &v in &window {
            packed.extend_from_slice(data.row(v));
        }
        FacilityLocation {
            data: Arc::clone(data),
            window,
            phantom,
            phantom32,
            packed,
            backend: None,
        }
    }

    /// Install a batched-gain backend (XLA artifact executor).
    pub fn with_backend(mut self, backend: Arc<dyn GainBackend>) -> Self {
        self.backend = Some(backend);
        self
    }

    pub fn window(&self) -> &[usize] {
        &self.window
    }

    pub fn data(&self) -> &Arc<Dataset> {
        &self.data
    }
}

impl SubmodularFn for FacilityLocation {
    fn state(&self) -> Box<dyn State + '_> {
        Box::new(FacilityState {
            obj: self,
            curmin: self.phantom.clone(),
            curmin32: self.phantom32.clone(),
            selected: Vec::new(),
            value: 0.0,
        })
    }

    fn ground_size(&self) -> usize {
        self.data.n
    }
}

/// Incremental state: cached min squared distance per window point, plus an
/// f32 mirror kept in sync by `push` (consumed zero-copy by [`GainBackend`]).
pub struct FacilityState<'a> {
    obj: &'a FacilityLocation,
    curmin: Vec<f64>,
    curmin32: Vec<f32>,
    selected: Vec<usize>,
    value: f64,
}

impl<'a> FacilityState<'a> {
    /// Unnormalized gain of one candidate over window rows `rows` — the
    /// worker kernel of the sharded engine. Streams its contiguous slice of
    /// the packed buffer sequentially; per-point distances accumulate in f32
    /// lanes (data is f32; relative error ~1e-6 ≪ the f32 kernel's own
    /// noise); the cross-point sum stays f64.
    /// NOTE(perf §A, iteration 3): an early-exit variant (break once the
    /// partial d² passes curmin) was tried and REVERTED — the branch in the
    /// inner loop defeated auto-vectorization and cost 2.2×.
    fn gain_partial(&self, e: usize, rows: &Range<usize>) -> f64 {
        let d = self.obj.data.d;
        let erow = self.obj.data.row(e);
        let packed = &self.obj.packed[rows.start * d..rows.end * d];
        let curmin = &self.curmin[rows.start..rows.end];
        let mut sum = 0.0f64;
        for (idx, vrow) in packed.chunks_exact(d).enumerate() {
            let gain = curmin[idx] - sq_dist(vrow, erow) as f64;
            if gain > 0.0 {
                sum += gain;
            }
        }
        sum
    }

    /// The window-sharded gain engine (perf §A, iteration 5): per-shard
    /// partial sums for all candidates, reduced in deterministic shard
    /// order. `threads == 1` runs the identical shard loop serially, so
    /// every thread count produces bit-identical sums.
    fn gain_sums(&self, es: &[usize], threads: usize) -> Vec<f64> {
        let shards = shard_ranges(self.obj.window.len(), shard_count(self.obj.window.len()));
        let partials: Vec<Vec<f64>> = if threads > 1 && shards.len() > 1 && !es.is_empty() {
            parallel_map(shards, threads, |_, rows| {
                es.iter().map(|&e| self.gain_partial(e, &rows)).collect()
            })
        } else {
            shards
                .into_iter()
                .map(|rows| es.iter().map(|&e| self.gain_partial(e, &rows)).collect())
                .collect()
        };
        let mut out = vec![0.0f64; es.len()];
        for partial in &partials {
            for (acc, p) in out.iter_mut().zip(partial) {
                *acc += p;
            }
        }
        out
    }

    /// Single-candidate gain sum through the same sharded reduction (keeps
    /// `gain` bit-identical to `batch_gains`/`par_batch_gains`).
    fn gain_sum(&self, e: usize) -> f64 {
        let len = self.obj.window.len();
        shard_ranges(len, shard_count(len))
            .into_iter()
            .map(|rows| self.gain_partial(e, &rows))
            .sum()
    }
}

impl<'a> State for FacilityState<'a> {
    fn value(&self) -> f64 {
        self.value
    }

    fn gain(&mut self, e: usize) -> f64 {
        self.gain_sum(e) / self.obj.window.len().max(1) as f64
    }

    fn batch_gains(&mut self, es: &[usize]) -> Vec<f64> {
        self.par_batch_gains(es, 1)
    }

    fn par_batch_gains(&mut self, es: &[usize], threads: usize) -> Vec<f64> {
        let n = self.obj.window.len().max(1) as f64;
        if let Some(backend) = &self.obj.backend {
            // The incrementally-maintained f32 mirror goes straight to the
            // backend — no per-call allocation or f64→f32 conversion pass.
            return backend
                .batch_gain_sums(es, &self.curmin32)
                .into_iter()
                .map(|s| s / n)
                .collect();
        }
        self.gain_sums(es, threads).into_iter().map(|s| s / n).collect()
    }

    fn push(&mut self, e: usize) -> f64 {
        let d = self.obj.data.d;
        let erow = self.obj.data.row(e);
        let mut sum = 0.0f64;
        for (idx, vrow) in self.obj.packed.chunks_exact(d).enumerate() {
            let d2 = sq_dist(vrow, erow) as f64;
            if d2 < self.curmin[idx] {
                sum += self.curmin[idx] - d2;
                self.curmin[idx] = d2;
                self.curmin32[idx] = d2 as f32;
            }
        }
        let gain = sum / self.obj.window.len().max(1) as f64;
        self.value += gain;
        self.selected.push(e);
        gain
    }

    fn selected(&self) -> &[usize] {
        &self.selected
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_blobs, SynthConfig};
    use crate::objective::{check_diminishing_returns, check_monotone};
    use crate::util::rng::Rng;

    fn dataset(n: usize) -> Arc<Dataset> {
        Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 8), 11))
    }

    #[test]
    fn empty_set_value_zero() {
        let ds = dataset(50);
        let f = FacilityLocation::from_dataset(&ds);
        assert_eq!(f.eval(&[]), 0.0);
    }

    #[test]
    fn gain_matches_eval_difference() {
        let ds = dataset(60);
        let f = FacilityLocation::from_dataset(&ds);
        let mut st = f.state();
        st.push(3);
        st.push(17);
        let g = st.gain(25);
        let brute = f.eval(&[3, 17, 25]) - f.eval(&[3, 17]);
        assert!((g - brute).abs() < 1e-9, "{g} vs {brute}");
    }

    #[test]
    fn push_returns_realized_gain_and_updates_value() {
        let ds = dataset(40);
        let f = FacilityLocation::from_dataset(&ds);
        let mut st = f.state();
        let g1 = st.push(0);
        let g2 = st.push(7);
        assert!((st.value() - (g1 + g2)).abs() < 1e-12);
        assert!((st.value() - f.eval(&[0, 7])).abs() < 1e-9);
    }

    #[test]
    fn is_monotone_and_submodular() {
        let ds = dataset(24);
        let f = FacilityLocation::from_dataset(&ds);
        let ground: Vec<usize> = (0..24).collect();
        let mut rng = Rng::new(5);
        assert!(check_monotone(&f, &ground, &mut rng, 50) < 1e-9);
        assert!(check_diminishing_returns(&f, &ground, &mut rng, 50) < 1e-9);
    }

    #[test]
    fn duplicate_push_zero_gain() {
        let ds = dataset(30);
        let f = FacilityLocation::from_dataset(&ds);
        let mut st = f.state();
        st.push(5);
        assert!(st.gain(5).abs() < 1e-12);
        assert!(st.push(5).abs() < 1e-12);
    }

    #[test]
    fn windowed_matches_manual_restriction() {
        let ds = dataset(40);
        let window: Vec<usize> = (0..40).step_by(2).collect();
        let f = FacilityLocation::with_window(&ds, window.clone());
        // manual: mean over window of curmin reduction
        let s = [1, 9];
        let mut expect = 0.0;
        for &v in &window {
            let phantom: f64 = ds.row(v).iter().map(|&x| (x as f64).powi(2)).sum();
            let best = s
                .iter()
                .map(|&e| ds.sqdist(e, v))
                .fold(phantom, f64::min);
            expect += phantom - best;
        }
        expect /= window.len() as f64;
        // per-point distances accumulate in f32 on the hot path — compare
        // against the f64 oracle at f32 precision.
        assert!((f.eval(&s) - expect).abs() < 1e-5);
    }

    #[test]
    fn batch_gains_matches_scalar() {
        let ds = dataset(50);
        let f = FacilityLocation::from_dataset(&ds);
        let mut st = f.state();
        st.push(2);
        let cands = vec![0, 1, 5, 9, 30];
        let batch = st.batch_gains(&cands);
        for (i, &e) in cands.iter().enumerate() {
            assert!((batch[i] - st.gain(e)).abs() < 1e-12);
        }
    }

    #[test]
    fn par_batch_gains_bit_identical_across_threads() {
        // Big enough window for several shards (shard_count > 1), so the
        // parallel path genuinely fans out.
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(1200, 8), 13));
        let f = FacilityLocation::from_dataset(&ds);
        let mut st = f.state();
        st.push(7);
        st.push(311);
        let cands: Vec<usize> = (0..64).map(|i| i * 17 % 1200).collect();
        let serial = st.batch_gains(&cands);
        for threads in [1usize, 2, 3, 8] {
            let par = st.par_batch_gains(&cands, threads);
            assert_eq!(serial, par, "threads={threads} changed gain bits");
        }
    }

    #[test]
    fn gain_bit_identical_to_batch_paths() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(900, 8), 14));
        let f = FacilityLocation::from_dataset(&ds);
        let mut st = f.state();
        st.push(1);
        for e in [0usize, 5, 250, 899] {
            let single = st.gain(e);
            let batched = st.par_batch_gains(&[e], 4)[0];
            assert_eq!(single, batched, "gain({e}) diverged from batch path");
        }
    }

    struct FakeBackend;
    impl GainBackend for FakeBackend {
        fn batch_gain_sums(&self, cands: &[usize], _curmin: &[f32]) -> Vec<f64> {
            cands.iter().map(|&c| c as f64).collect()
        }
    }

    #[test]
    fn backend_is_used_for_batches() {
        let ds = dataset(20);
        let f = FacilityLocation::from_dataset(&ds).with_backend(Arc::new(FakeBackend));
        let mut st = f.state();
        let gains = st.batch_gains(&[4, 8]);
        assert!((gains[0] - 4.0 / 20.0).abs() < 1e-12);
        assert!((gains[1] - 8.0 / 20.0).abs() < 1e-12);
    }

    /// Backend that echoes the curmin snapshot it was handed, so tests can
    /// observe the f32 mirror without reaching into private state.
    struct EchoBackend;
    impl GainBackend for EchoBackend {
        fn batch_gain_sums(&self, cands: &[usize], curmin: &[f32]) -> Vec<f64> {
            cands.iter().map(|&c| curmin[c] as f64).collect()
        }
    }

    #[test]
    fn f32_mirror_tracks_pushes() {
        let ds = dataset(30);
        let mirrored = FacilityLocation::from_dataset(&ds).with_backend(Arc::new(EchoBackend));
        let mut st = mirrored.state();
        for &e in &[4usize, 21, 9] {
            st.push(e);
        }
        // EchoBackend reports curmin32[c]·30 / 30 = curmin32[c]; the mirror
        // must match the f64 cache at f32 precision WITHOUT any refresh call
        // between pushes (it is maintained incrementally).
        let probe: Vec<usize> = (0..30).collect();
        let echoed = st.batch_gains(&probe);
        for (v, &g) in probe.iter().map(|&c| {
            // recompute the f64 curmin for window point c
            let phantom: f64 = ds.row(c).iter().map(|&x| (x as f64).powi(2)).sum();
            [4usize, 21, 9]
                .iter()
                .map(|&e| sq_dist(ds.row(c), ds.row(e)) as f64)
                .fold(phantom, f64::min)
        }).zip(echoed.iter()) {
            assert!((g * 30.0 - v).abs() < 1e-3, "mirror stale: {} vs {v}", g * 30.0);
        }
    }
}
