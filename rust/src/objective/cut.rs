//! Directed graph-cut objective (paper §6.3): `f(S) = Σ_{u∈S, v∉S} w(u→v)`
//! — **non-monotone** submodular (f(V) = 0). The paper runs RandomGreedy
//! (Buchbinder et al. 2014) on a Facebook-like message network, evaluating
//! the function *locally* on each partition (cross-partition links
//! disconnected), which [`GraphCut::restricted`] reproduces.
//!
//! Pricing rides the shared [`ShardedGainEngine`]: [`CutKernel`] is a
//! candidate-sharded kernel — `delta` only reads the membership flags and
//! the (immutable) adjacency lists, so the engine splits the candidate list
//! and every thread count yields bit-identical results (the pre-refactor
//! module carried its own `parallel_gains` fan-out for this).

use std::ops::Range;
use std::sync::Arc;

use super::engine::{GainKernel, ShardSpec, ShardedGainEngine, MIN_CANDIDATES_PER_SHARD};
use super::{State, SubmodularFn};
use crate::data::graph::Digraph;

/// Directed cut function, optionally restricted to an induced subgraph.
pub struct GraphCut {
    g: Arc<Digraph>,
    /// If present: only edges with BOTH endpoints in this set count
    /// (membership indexed by node id).
    member: Option<Vec<bool>>,
}

impl GraphCut {
    pub fn new(g: &Arc<Digraph>) -> Self {
        GraphCut { g: Arc::clone(g), member: None }
    }

    /// Restrict to the subgraph induced by `nodes` (local evaluation mode).
    pub fn restricted(g: &Arc<Digraph>, nodes: &[usize]) -> Self {
        let mut member = vec![false; g.n];
        for &u in nodes {
            member[u] = true;
        }
        GraphCut { g: Arc::clone(g), member: Some(member) }
    }

    #[inline]
    fn visible(&self, u: usize) -> bool {
        self.member.as_ref().map(|m| m[u]).unwrap_or(true)
    }
}

impl SubmodularFn for GraphCut {
    fn state(&self) -> Box<dyn State + '_> {
        Box::new(ShardedGainEngine::new(CutKernel {
            obj: self,
            in_s: vec![false; self.g.n],
            selected: Vec::new(),
            value: 0.0,
        }))
    }

    fn is_monotone(&self) -> bool {
        false
    }

    fn ground_size(&self) -> usize {
        self.g.n
    }
}

/// Candidate-sharded cut kernel: membership flags + running cut value.
pub struct CutKernel<'a> {
    obj: &'a GraphCut,
    in_s: Vec<bool>,
    selected: Vec<usize>,
    value: f64,
}

/// Pre-refactor name for the cut state, preserved as the engine alias.
pub type CutState<'a> = ShardedGainEngine<CutKernel<'a>>;

impl<'a> CutKernel<'a> {
    /// Marginal change of adding `e`:
    ///  + outgoing edges e→v with v ∉ S
    ///  + 0 for outgoing edges into S
    ///  − incoming edges u→e with u ∈ S (they stop being cut)
    fn delta(&self, e: usize) -> f64 {
        if self.in_s[e] {
            return 0.0;
        }
        let mut d = 0.0;
        for &(v, w) in &self.obj.g.out[e] {
            if self.obj.visible(v) && !self.in_s[v] {
                d += w;
            }
        }
        for &(u, w) in &self.obj.g.rin[e] {
            if self.obj.visible(u) && self.in_s[u] {
                d -= w;
            }
        }
        d
    }
}

impl<'a> GainKernel for CutKernel<'a> {
    fn label(&self) -> &'static str {
        "cut"
    }

    fn shard_spec(&self) -> ShardSpec {
        ShardSpec::Candidates { min_per_shard: MIN_CANDIDATES_PER_SHARD }
    }

    fn shard_gain_partial(&self, es: &[usize], rows: &Range<usize>) -> Vec<f64> {
        es[rows.clone()].iter().map(|&e| self.delta(e)).collect()
    }

    fn apply_push(&mut self, e: usize) -> f64 {
        let d = self.delta(e);
        if !self.in_s[e] {
            self.in_s[e] = true;
            self.value += d;
            self.selected.push(e);
        }
        d
    }

    fn value(&self) -> f64 {
        self.value
    }

    fn selected(&self) -> &[usize] {
        &self.selected
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::graph::social_network;
    use crate::objective::check_diminishing_returns;
    use crate::util::rng::Rng;

    fn triangle() -> Arc<Digraph> {
        // 0 -> 1 (2.0), 1 -> 2 (3.0), 2 -> 0 (5.0)
        let mut g = Digraph::new(3);
        g.add_edge(0, 1, 2.0);
        g.add_edge(1, 2, 3.0);
        g.add_edge(2, 0, 5.0);
        Arc::new(g)
    }

    #[test]
    fn known_cut_values() {
        let g = triangle();
        let f = GraphCut::new(&g);
        assert_eq!(f.eval(&[]), 0.0);
        assert_eq!(f.eval(&[0]), 2.0); // 0->1 cut
        assert_eq!(f.eval(&[1]), 3.0);
        assert_eq!(f.eval(&[0, 1]), 3.0); // 1->2 cut, 0->1 internal
        assert_eq!(f.eval(&[0, 1, 2]), 0.0); // everything internal
    }

    #[test]
    fn non_monotone() {
        let g = triangle();
        let f = GraphCut::new(&g);
        assert!(!f.is_monotone());
        assert!(f.eval(&[0, 1, 2]) < f.eval(&[1]));
    }

    #[test]
    fn submodular_on_random_graph() {
        let g = Arc::new(social_network(30, 120, 1));
        let f = GraphCut::new(&g);
        let ground: Vec<usize> = (0..30).collect();
        let mut rng = Rng::new(8);
        assert!(check_diminishing_returns(&f, &ground, &mut rng, 80) < 1e-12);
    }

    #[test]
    fn gain_matches_eval_difference() {
        let g = Arc::new(social_network(25, 100, 2));
        let f = GraphCut::new(&g);
        let mut st = f.state();
        st.push(3);
        st.push(11);
        let gain = st.gain(7);
        let brute = f.eval(&[3, 11, 7]) - f.eval(&[3, 11]);
        assert!((gain - brute).abs() < 1e-12);
    }

    #[test]
    fn restriction_drops_cross_edges() {
        let g = triangle();
        // restrict to {0, 1}: only edge 0->1 visible
        let f = GraphCut::restricted(&g, &[0, 1]);
        assert_eq!(f.eval(&[0]), 2.0);
        assert_eq!(f.eval(&[1]), 0.0); // 1->2 invisible
        assert_eq!(f.eval(&[0, 1]), 0.0);
    }

    #[test]
    fn double_push_is_noop() {
        let g = triangle();
        let f = GraphCut::new(&g);
        let mut st = f.state();
        st.push(0);
        let v = st.value();
        assert_eq!(st.push(0), 0.0);
        assert_eq!(st.value(), v);
        assert_eq!(st.selected(), &[0]);
    }
}
