//! Submodular coverage objective (paper §6.4): given a collection `V` of
//! sets (transactions), `f(S) = |⋃_{t∈S} items(t)|` — pick at most k
//! transactions maximizing the size of their union. Monotone submodular
//! (maximum coverage); this is the objective the GreeDi-vs-GreedyScaling
//! comparison (Fig. 10) runs on.
//!
//! Pricing rides the shared [`ShardedGainEngine`]: [`CoverageKernel`] is a
//! candidate-sharded kernel (each candidate's gain is one transaction scan
//! against the read-only covered bitset, so the engine splits the candidate
//! *list*; the pre-refactor module carried its own `parallel_gains` fan-out
//! for this). Singletons have the closed form `Σ_{it∈t(e)} w(it)` — no
//! covered bitset needed — so [`Coverage::singleton_gains`] skips state
//! construction entirely for the streaming sieve's ladder pricing
//! (bit-identical to the fresh-state path: same items, same summation
//! order).

use std::ops::Range;
use std::sync::Arc;

use super::engine::{GainKernel, ShardSpec, ShardedGainEngine, MIN_CANDIDATES_PER_SHARD};
use super::{State, SubmodularFn};
use crate::data::transactions::TransactionData;

/// Weighted coverage over a transaction database.
pub struct Coverage {
    td: Arc<TransactionData>,
    /// Optional per-item weights (uniform 1.0 when None).
    weights: Option<Vec<f64>>,
}

impl Coverage {
    pub fn new(td: &Arc<TransactionData>) -> Self {
        Coverage { td: Arc::clone(td), weights: None }
    }

    pub fn weighted(td: &Arc<TransactionData>, weights: Vec<f64>) -> Self {
        assert_eq!(weights.len(), td.n_items);
        Coverage { td: Arc::clone(td), weights: Some(weights) }
    }

    #[inline]
    fn weight(&self, item: u32) -> f64 {
        match &self.weights {
            Some(w) => w[item as usize],
            None => 1.0,
        }
    }

    /// Closed-form f({e}): the transaction's total item weight (on a fresh
    /// state nothing is covered, so every item of `e` counts — the same
    /// items in the same iteration/summation order as the state path).
    #[inline]
    fn singleton_value(&self, e: usize) -> f64 {
        self.td.transactions[e].iter().map(|&it| self.weight(it)).sum()
    }

    pub fn transactions(&self) -> &Arc<TransactionData> {
        &self.td
    }
}

impl SubmodularFn for Coverage {
    fn state(&self) -> Box<dyn State + '_> {
        Box::new(ShardedGainEngine::new(CoverageKernel {
            obj: self,
            covered: vec![false; self.td.n_items],
            selected: Vec::new(),
            value: 0.0,
        }))
    }

    /// Ladder pricing without any state construction (satellite of the
    /// engine refactor): maps the closed-form singleton directly.
    fn singleton_gains(&self, es: &[usize], _threads: usize) -> Vec<f64> {
        es.iter().map(|&e| self.singleton_value(e)).collect()
    }

    fn ground_size(&self) -> usize {
        self.td.n()
    }
}

/// Candidate-sharded coverage kernel: covered-item bitset + running value.
pub struct CoverageKernel<'a> {
    obj: &'a Coverage,
    covered: Vec<bool>,
    selected: Vec<usize>,
    value: f64,
}

/// Pre-refactor name for the coverage state, preserved as the engine alias.
pub type CoverageState<'a> = ShardedGainEngine<CoverageKernel<'a>>;

impl<'a> CoverageKernel<'a> {
    /// Read-only gain (shared by the serial and parallel paths: each
    /// candidate's gain depends only on the covered bitset, so candidates
    /// price independently and in any order).
    fn gain_at(&self, e: usize) -> f64 {
        self.obj.td.transactions[e]
            .iter()
            .filter(|&&it| !self.covered[it as usize])
            .map(|&it| self.obj.weight(it))
            .sum()
    }
}

impl<'a> GainKernel for CoverageKernel<'a> {
    fn label(&self) -> &'static str {
        "coverage"
    }

    fn shard_spec(&self) -> ShardSpec {
        ShardSpec::Candidates { min_per_shard: MIN_CANDIDATES_PER_SHARD }
    }

    fn shard_gain_partial(&self, es: &[usize], rows: &Range<usize>) -> Vec<f64> {
        es[rows.clone()].iter().map(|&e| self.gain_at(e)).collect()
    }

    fn singleton(&self, e: usize) -> Option<f64> {
        Some(self.obj.singleton_value(e))
    }

    fn apply_push(&mut self, e: usize) -> f64 {
        let mut gain = 0.0;
        for &it in &self.obj.td.transactions[e] {
            if !self.covered[it as usize] {
                self.covered[it as usize] = true;
                gain += self.obj.weight(it);
            }
        }
        self.value += gain;
        self.selected.push(e);
        gain
    }

    fn value(&self) -> f64 {
        self.value
    }

    fn selected(&self) -> &[usize] {
        &self.selected
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::transactions::zipf_transactions;
    use crate::objective::{check_diminishing_returns, check_monotone};
    use crate::util::rng::Rng;

    fn db() -> Arc<TransactionData> {
        Arc::new(zipf_transactions(40, 60, 8, 1.1, 9))
    }

    #[test]
    fn matches_union_size() {
        let td = db();
        let f = Coverage::new(&td);
        let s = [0, 3, 7, 12];
        assert_eq!(f.eval(&s), td.union_size(&s) as f64);
    }

    #[test]
    fn monotone_and_submodular() {
        let td = db();
        let f = Coverage::new(&td);
        let ground: Vec<usize> = (0..td.n()).collect();
        let mut rng = Rng::new(4);
        assert!(check_monotone(&f, &ground, &mut rng, 60) < 1e-12);
        assert!(check_diminishing_returns(&f, &ground, &mut rng, 60) < 1e-12);
    }

    #[test]
    fn gain_then_push_consistent() {
        let td = db();
        let f = Coverage::new(&td);
        let mut st = f.state();
        st.push(1);
        let g = st.gain(2);
        let realized = st.push(2);
        assert_eq!(g, realized);
    }

    #[test]
    fn weighted_coverage() {
        let td = Arc::new(TransactionData {
            n_items: 3,
            transactions: vec![vec![0], vec![1, 2], vec![0, 1, 2]],
        });
        let f = Coverage::weighted(&td, vec![10.0, 1.0, 1.0]);
        assert_eq!(f.eval(&[0]), 10.0);
        assert_eq!(f.eval(&[1]), 2.0);
        assert_eq!(f.eval(&[0, 1]), 12.0);
        assert_eq!(f.eval(&[2]), 12.0);
    }

    #[test]
    fn closed_form_singletons_match_state_path() {
        // The override must be bit-identical to a fresh state's gains (the
        // sieve ladder reuses singletons in place of state pricing).
        let td = db();
        for f in [Coverage::new(&td), Coverage::weighted(&td, (0..60).map(|i| 0.5 + i as f64).collect())] {
            let es: Vec<usize> = (0..td.n()).collect();
            let closed = f.singleton_gains(&es, 1);
            let mut fresh = f.state();
            for (i, &e) in es.iter().enumerate() {
                assert_eq!(closed[i], fresh.gain(e), "singleton({e}) diverged");
                assert_eq!(closed[i], f.eval(&[e]), "singleton({e}) != eval");
            }
        }
    }

    #[test]
    fn covering_everything_saturates() {
        let td = db();
        let f = Coverage::new(&td);
        let all: Vec<usize> = (0..td.n()).collect();
        let full = f.eval(&all);
        assert!(full <= td.n_items as f64);
        // adding anything after everything is covered gains zero
        let mut st = f.state();
        for &e in &all {
            st.push(e);
        }
        assert_eq!(st.gain(0), 0.0);
    }
}
