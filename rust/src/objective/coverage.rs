//! Submodular coverage objective (paper §6.4): given a collection `V` of
//! sets (transactions), `f(S) = |⋃_{t∈S} items(t)|` — pick at most k
//! transactions maximizing the size of their union. Monotone submodular
//! (maximum coverage); this is the objective the GreeDi-vs-GreedyScaling
//! comparison (Fig. 10) runs on.

use std::sync::Arc;

use super::{State, SubmodularFn};
use crate::data::transactions::TransactionData;
use crate::util::executor::parallel_gains;

/// Weighted coverage over a transaction database.
pub struct Coverage {
    td: Arc<TransactionData>,
    /// Optional per-item weights (uniform 1.0 when None).
    weights: Option<Vec<f64>>,
}

impl Coverage {
    pub fn new(td: &Arc<TransactionData>) -> Self {
        Coverage { td: Arc::clone(td), weights: None }
    }

    pub fn weighted(td: &Arc<TransactionData>, weights: Vec<f64>) -> Self {
        assert_eq!(weights.len(), td.n_items);
        Coverage { td: Arc::clone(td), weights: Some(weights) }
    }

    #[inline]
    fn weight(&self, item: u32) -> f64 {
        match &self.weights {
            Some(w) => w[item as usize],
            None => 1.0,
        }
    }

    pub fn transactions(&self) -> &Arc<TransactionData> {
        &self.td
    }
}

impl SubmodularFn for Coverage {
    fn state(&self) -> Box<dyn State + '_> {
        Box::new(CoverageState {
            obj: self,
            covered: vec![false; self.td.n_items],
            selected: Vec::new(),
            value: 0.0,
        })
    }

    fn ground_size(&self) -> usize {
        self.td.n()
    }
}

/// Incremental state: covered-item bitset.
pub struct CoverageState<'a> {
    obj: &'a Coverage,
    covered: Vec<bool>,
    selected: Vec<usize>,
    value: f64,
}

impl<'a> CoverageState<'a> {
    /// Read-only gain (shared by the serial and parallel paths: each
    /// candidate's gain depends only on the covered bitset, so candidates
    /// price independently and in any order).
    fn gain_at(&self, e: usize) -> f64 {
        self.obj.td.transactions[e]
            .iter()
            .filter(|&&it| !self.covered[it as usize])
            .map(|&it| self.obj.weight(it))
            .sum()
    }
}

impl<'a> State for CoverageState<'a> {
    fn value(&self) -> f64 {
        self.value
    }

    fn gain(&mut self, e: usize) -> f64 {
        self.gain_at(e)
    }

    fn batch_gains(&mut self, es: &[usize]) -> Vec<f64> {
        es.iter().map(|&e| self.gain_at(e)).collect()
    }

    /// Parallel gains shard the *candidate list* across workers via
    /// [`parallel_gains`] (the per-candidate work is a single transaction
    /// scan, so the window-style sharding used by facility location has
    /// nothing to split). Each candidate's value is computed independently
    /// from the read-only covered bitset, hence results are bit-identical
    /// at any thread count.
    fn par_batch_gains(&mut self, es: &[usize], threads: usize) -> Vec<f64> {
        let this: &CoverageState<'a> = self;
        parallel_gains(es, threads, |e| this.gain_at(e))
    }

    fn push(&mut self, e: usize) -> f64 {
        let mut gain = 0.0;
        for &it in &self.obj.td.transactions[e] {
            if !self.covered[it as usize] {
                self.covered[it as usize] = true;
                gain += self.obj.weight(it);
            }
        }
        self.value += gain;
        self.selected.push(e);
        gain
    }

    fn selected(&self) -> &[usize] {
        &self.selected
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::transactions::zipf_transactions;
    use crate::objective::{check_diminishing_returns, check_monotone};
    use crate::util::rng::Rng;

    fn db() -> Arc<TransactionData> {
        Arc::new(zipf_transactions(40, 60, 8, 1.1, 9))
    }

    #[test]
    fn matches_union_size() {
        let td = db();
        let f = Coverage::new(&td);
        let s = [0, 3, 7, 12];
        assert_eq!(f.eval(&s), td.union_size(&s) as f64);
    }

    #[test]
    fn monotone_and_submodular() {
        let td = db();
        let f = Coverage::new(&td);
        let ground: Vec<usize> = (0..td.n()).collect();
        let mut rng = Rng::new(4);
        assert!(check_monotone(&f, &ground, &mut rng, 60) < 1e-12);
        assert!(check_diminishing_returns(&f, &ground, &mut rng, 60) < 1e-12);
    }

    #[test]
    fn gain_then_push_consistent() {
        let td = db();
        let f = Coverage::new(&td);
        let mut st = f.state();
        st.push(1);
        let g = st.gain(2);
        let realized = st.push(2);
        assert_eq!(g, realized);
    }

    #[test]
    fn weighted_coverage() {
        let td = Arc::new(TransactionData {
            n_items: 3,
            transactions: vec![vec![0], vec![1, 2], vec![0, 1, 2]],
        });
        let f = Coverage::weighted(&td, vec![10.0, 1.0, 1.0]);
        assert_eq!(f.eval(&[0]), 10.0);
        assert_eq!(f.eval(&[1]), 2.0);
        assert_eq!(f.eval(&[0, 1]), 12.0);
        assert_eq!(f.eval(&[2]), 12.0);
    }

    #[test]
    fn par_batch_gains_bit_identical_across_threads() {
        let td = Arc::new(zipf_transactions(300, 200, 8, 1.1, 17));
        let f = Coverage::new(&td);
        let mut st = f.state();
        st.push(3);
        st.push(150);
        let cands: Vec<usize> = (0..300).collect();
        let serial = st.batch_gains(&cands);
        for threads in [1usize, 2, 8] {
            let par = st.par_batch_gains(&cands, threads);
            assert_eq!(serial, par, "threads={threads} changed coverage gains");
        }
    }

    #[test]
    fn covering_everything_saturates() {
        let td = db();
        let f = Coverage::new(&td);
        let all: Vec<usize> = (0..td.n()).collect();
        let full = f.eval(&all);
        assert!(full <= td.n_items as f64);
        // adding anything after everything is covered gains zero
        let mut st = f.state();
        for &e in &all {
            st.push(e);
        }
        assert_eq!(st.gain(0), 0.0);
    }
}
