//! Modular (additive) function `f(S) = Σ_{e∈S} w(e)` — the degenerate case
//! where GreeDi is *exactly* optimal (paper §4.1 discussion). Used heavily
//! in tests as the analytically solvable objective — and, since the engine
//! refactor, as the smallest complete [`GainKernel`] example: one shard
//! spec, one read-only shard pricer, one commit, a closed-form singleton.

use std::ops::Range;

use super::engine::{GainKernel, ShardSpec, ShardedGainEngine, MIN_CANDIDATES_PER_SHARD};
use super::{State, SubmodularFn};

/// Additive objective with non-negative weights.
pub struct Modular {
    pub weights: Vec<f64>,
}

impl Modular {
    pub fn new(weights: Vec<f64>) -> Self {
        assert!(weights.iter().all(|&w| w >= 0.0), "non-negative weights");
        Modular { weights }
    }

    /// Optimal value for a cardinality constraint (top-k weights).
    pub fn opt_cardinality(&self, k: usize) -> f64 {
        let mut w = self.weights.clone();
        w.sort_by(|a, b| b.partial_cmp(a).unwrap());
        w.iter().take(k).sum()
    }
}

impl SubmodularFn for Modular {
    fn state(&self) -> Box<dyn State + '_> {
        Box::new(ShardedGainEngine::new(ModularKernel {
            obj: self,
            selected: Vec::new(),
            value: 0.0,
        }))
    }

    /// Ladder pricing without any state construction: f({e}) = w(e).
    fn singleton_gains(&self, es: &[usize], _threads: usize) -> Vec<f64> {
        es.iter().map(|&e| self.weights[e]).collect()
    }

    fn ground_size(&self) -> usize {
        self.weights.len()
    }
}

/// Candidate-sharded modular kernel.
pub struct ModularKernel<'a> {
    obj: &'a Modular,
    selected: Vec<usize>,
    value: f64,
}

/// Pre-refactor name for the modular state, preserved as the engine alias.
pub type ModularState<'a> = ShardedGainEngine<ModularKernel<'a>>;

impl<'a> ModularKernel<'a> {
    fn gain_at(&self, e: usize) -> f64 {
        if self.selected.contains(&e) {
            0.0
        } else {
            self.obj.weights[e]
        }
    }
}

impl<'a> GainKernel for ModularKernel<'a> {
    fn label(&self) -> &'static str {
        "modular"
    }

    fn shard_spec(&self) -> ShardSpec {
        ShardSpec::Candidates { min_per_shard: MIN_CANDIDATES_PER_SHARD }
    }

    fn shard_gain_partial(&self, es: &[usize], rows: &Range<usize>) -> Vec<f64> {
        es[rows.clone()].iter().map(|&e| self.gain_at(e)).collect()
    }

    fn singleton(&self, e: usize) -> Option<f64> {
        Some(self.obj.weights[e])
    }

    fn apply_push(&mut self, e: usize) -> f64 {
        if self.selected.contains(&e) {
            return 0.0;
        }
        self.selected.push(e);
        self.value += self.obj.weights[e];
        self.obj.weights[e]
    }

    fn value(&self) -> f64 {
        self.value
    }

    fn selected(&self) -> &[usize] {
        &self.selected
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn additive_eval() {
        let f = Modular::new(vec![1.0, 2.0, 3.0]);
        assert_eq!(f.eval(&[0, 2]), 4.0);
        assert_eq!(f.eval(&[]), 0.0);
    }

    #[test]
    fn duplicates_ignored() {
        let f = Modular::new(vec![1.0, 2.0]);
        assert_eq!(f.eval(&[1, 1, 1]), 2.0);
    }

    #[test]
    fn opt_cardinality_topk() {
        let f = Modular::new(vec![5.0, 1.0, 3.0, 2.0]);
        assert_eq!(f.opt_cardinality(2), 8.0);
        assert_eq!(f.opt_cardinality(10), 11.0);
    }

    #[test]
    fn closed_form_singletons_match_state_path() {
        let f = Modular::new(vec![5.0, 1.0, 3.0, 2.0]);
        let es = [3usize, 0, 2];
        let closed = f.singleton_gains(&es, 1);
        let mut fresh = f.state();
        for (i, &e) in es.iter().enumerate() {
            assert_eq!(closed[i], fresh.gain(e));
            assert_eq!(closed[i], f.eval(&[e]));
        }
    }

    #[test]
    fn batched_gains_skip_committed_elements() {
        let f = Modular::new(vec![1.0, 2.0, 3.0, 4.0]);
        let mut st = f.state();
        st.push(1);
        assert_eq!(st.batch_gains(&[0, 1, 2, 3]), vec![1.0, 0.0, 3.0, 4.0]);
        assert_eq!(st.par_batch_gains(&[0, 1, 2, 3], 8), vec![1.0, 0.0, 3.0, 4.0]);
    }

    #[test]
    #[should_panic]
    fn negative_weight_rejected() {
        Modular::new(vec![1.0, -0.5]);
    }
}
