//! Modular (additive) function `f(S) = Σ_{e∈S} w(e)` — the degenerate case
//! where GreeDi is *exactly* optimal (paper §4.1 discussion). Used heavily
//! in tests as the analytically solvable objective.

use super::{State, SubmodularFn};

/// Additive objective with non-negative weights.
pub struct Modular {
    pub weights: Vec<f64>,
}

impl Modular {
    pub fn new(weights: Vec<f64>) -> Self {
        assert!(weights.iter().all(|&w| w >= 0.0), "non-negative weights");
        Modular { weights }
    }

    /// Optimal value for a cardinality constraint (top-k weights).
    pub fn opt_cardinality(&self, k: usize) -> f64 {
        let mut w = self.weights.clone();
        w.sort_by(|a, b| b.partial_cmp(a).unwrap());
        w.iter().take(k).sum()
    }
}

impl SubmodularFn for Modular {
    fn state(&self) -> Box<dyn State + '_> {
        Box::new(ModularState { obj: self, selected: Vec::new(), value: 0.0 })
    }

    fn ground_size(&self) -> usize {
        self.weights.len()
    }
}

pub struct ModularState<'a> {
    obj: &'a Modular,
    selected: Vec<usize>,
    value: f64,
}

impl<'a> State for ModularState<'a> {
    fn value(&self) -> f64 {
        self.value
    }

    fn gain(&mut self, e: usize) -> f64 {
        if self.selected.contains(&e) {
            0.0
        } else {
            self.obj.weights[e]
        }
    }

    fn push(&mut self, e: usize) -> f64 {
        if self.selected.contains(&e) {
            return 0.0;
        }
        self.selected.push(e);
        self.value += self.obj.weights[e];
        self.obj.weights[e]
    }

    fn selected(&self) -> &[usize] {
        &self.selected
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn additive_eval() {
        let f = Modular::new(vec![1.0, 2.0, 3.0]);
        assert_eq!(f.eval(&[0, 2]), 4.0);
        assert_eq!(f.eval(&[]), 0.0);
    }

    #[test]
    fn duplicates_ignored() {
        let f = Modular::new(vec![1.0, 2.0]);
        assert_eq!(f.eval(&[1, 1, 1]), 2.0);
    }

    #[test]
    fn opt_cardinality_topk() {
        let f = Modular::new(vec![5.0, 1.0, 3.0, 2.0]);
        assert_eq!(f.opt_cardinality(2), 8.0);
        assert_eq!(f.opt_cardinality(10), 11.0);
    }

    #[test]
    #[should_panic]
    fn negative_weight_rejected() {
        Modular::new(vec![1.0, -0.5]);
    }
}
