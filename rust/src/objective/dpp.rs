//! DPP MAP-inference objective (paper §3.4.1): `f(S) = log det(K_S)` for a
//! PSD kernel `K` — log-submodular, in general **non-monotone**. Included
//! as the second nonparametric-learning application the paper motivates;
//! exercised by tests and the `theory` experiment.
//!
//! To keep f finite we require K to be positive definite (the generators add
//! a ridge). Gains are priced through the same incremental-Cholesky trick
//! as info-gain, on K_S itself (no +I).
//!
//! Pricing rides the shared [`ShardedGainEngine`] as a candidate-sharded
//! [`GainKernel`] — like info-gain, this objective gains real parallel
//! batching for the first time: each candidate shard computes its **own
//! Schur complements** (the pivot `d_e = a_ee − ‖w‖²` from a per-shard
//! forward-solve scratch) against the shared read-only Cholesky factor of
//! K_S, bit-identical across shard/thread counts.

use std::ops::Range;
use std::sync::Arc;

use super::engine::{
    GainKernel, ShardSpec, ShardedGainEngine, MIN_HEAVY_CANDIDATES_PER_SHARD,
};
use super::{State, SubmodularFn};
use crate::data::Dataset;
use crate::linalg::IncrementalCholesky;

/// Log-det DPP objective with an RBF kernel plus ridge.
pub struct DppLogDet {
    data: Arc<Dataset>,
    inv_h2: f64,
    /// Diagonal ridge (> 0 keeps K_S PD; paper's DPP kernels are PSD —
    /// the ridge models the usual quality-term regularization).
    ridge: f64,
}

impl DppLogDet {
    pub fn new(data: &Arc<Dataset>, h: f64, ridge: f64) -> Self {
        assert!(ridge > 0.0);
        DppLogDet { data: Arc::clone(data), inv_h2: 1.0 / (h * h), ridge }
    }

    #[inline]
    fn kernel(&self, i: usize, j: usize) -> f64 {
        let k = (-self.data.sqdist(i, j) * self.inv_h2).exp();
        if i == j {
            k + self.ridge
        } else {
            k
        }
    }
}

impl SubmodularFn for DppLogDet {
    fn state(&self) -> Box<dyn State + '_> {
        Box::new(ShardedGainEngine::new(DppKernel {
            obj: self,
            chol: IncrementalCholesky::new(),
            selected: Vec::new(),
        }))
    }

    fn is_monotone(&self) -> bool {
        false // log det(K_S) decreases once pivots drop below 1
    }

    fn ground_size(&self) -> usize {
        self.data.n
    }
}

/// Candidate-sharded DPP kernel: incremental Cholesky of K_S.
pub struct DppKernel<'a> {
    obj: &'a DppLogDet,
    chol: IncrementalCholesky,
    selected: Vec<usize>,
}

/// Pre-refactor name for the DPP state, preserved as the engine alias.
pub type DppState<'a> = ShardedGainEngine<DppKernel<'a>>;

impl<'a> DppKernel<'a> {
    fn terms(&self, e: usize) -> (f64, Vec<f64>) {
        let a_ee = self.obj.kernel(e, e);
        let a_se = self
            .selected
            .iter()
            .map(|&s| self.obj.kernel(s, e))
            .collect();
        (a_ee, a_se)
    }
}

impl<'a> GainKernel for DppKernel<'a> {
    fn label(&self) -> &'static str {
        "dpp"
    }

    fn shard_spec(&self) -> ShardSpec {
        // O(k²) per candidate: even narrow batches amortize a shard.
        ShardSpec::Candidates { min_per_shard: MIN_HEAVY_CANDIDATES_PER_SHARD }
    }

    /// Per-shard Schur complements: one cross-term + forward-solve scratch
    /// pair per shard invocation, reused across the shard's candidates —
    /// the same pivot arithmetic (`gain_with`) as the serial path.
    fn shard_gain_partial(&self, es: &[usize], rows: &Range<usize>) -> Vec<f64> {
        let mut a_se: Vec<f64> = Vec::with_capacity(self.selected.len());
        let mut solve: Vec<f64> = Vec::with_capacity(self.selected.len());
        es[rows.clone()]
            .iter()
            .map(|&e| {
                a_se.clear();
                for &s in &self.selected {
                    a_se.push(self.obj.kernel(s, e));
                }
                self.chol.gain_with(self.obj.kernel(e, e), &a_se, &mut solve)
            })
            .collect()
    }

    fn apply_push(&mut self, e: usize) -> f64 {
        let (a_ee, a_se) = self.terms(e);
        let inc = self.chol.push(a_ee, &a_se);
        self.selected.push(e);
        inc
    }

    fn value(&self) -> f64 {
        self.chol.logdet()
    }

    fn selected(&self) -> &[usize] {
        &self.selected
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_blobs, SynthConfig};
    use crate::linalg::Matrix;

    fn dataset() -> Arc<Dataset> {
        Arc::new(gaussian_blobs(&SynthConfig::unstructured(30, 6), 13))
    }

    fn brute(obj: &DppLogDet, s: &[usize]) -> f64 {
        let k = s.len();
        let mut m = Matrix::zeros(k, k);
        for i in 0..k {
            for j in 0..k {
                m[(i, j)] = obj.kernel(s[i], s[j]);
            }
        }
        m.logdet().unwrap()
    }

    #[test]
    fn matches_dense_logdet() {
        let ds = dataset();
        let f = DppLogDet::new(&ds, 1.0, 0.5);
        let s = [2, 7, 19, 11];
        assert!((f.eval(&s) - brute(&f, &s)).abs() < 1e-8);
    }

    #[test]
    fn prefers_diverse_sets() {
        // Near-duplicate pairs should score lower than spread pairs.
        let ds = Arc::new(Dataset::from_rows(vec![
            vec![0.0, 0.0],
            vec![0.01, 0.0], // near-duplicate of 0
            vec![5.0, 5.0],  // far away
        ]));
        let f = DppLogDet::new(&ds, 1.0, 0.1);
        assert!(f.eval(&[0, 2]) > f.eval(&[0, 1]));
    }

    #[test]
    fn non_monotone_flag() {
        let ds = dataset();
        assert!(!DppLogDet::new(&ds, 1.0, 0.5).is_monotone());
    }

    #[test]
    fn gain_push_consistency() {
        let ds = dataset();
        let f = DppLogDet::new(&ds, 1.0, 0.5);
        let mut st = f.state();
        st.push(0);
        let g = st.gain(9);
        let realized = st.push(9);
        assert!((g - realized).abs() < 1e-10);
    }

    #[test]
    fn batched_gains_bit_identical_to_serial() {
        // The first parallel path this objective ever had: per-shard Schur
        // complements must reproduce the serial gains exactly.
        let ds = Arc::new(gaussian_blobs(&SynthConfig::unstructured(90, 6), 19));
        let f = DppLogDet::new(&ds, 1.0, 0.5);
        let mut st = f.state();
        for e in [0usize, 31, 62] {
            st.push(e);
        }
        let cands: Vec<usize> = (0..90).collect();
        let serial = st.batch_gains(&cands);
        for threads in [2usize, 8] {
            assert_eq!(serial, st.par_batch_gains(&cands, threads), "threads={threads}");
        }
        for (i, &e) in cands.iter().enumerate() {
            assert_eq!(serial[i], st.gain(e), "gain({e}) diverged from batch");
        }
    }
}
