//! DPP MAP-inference objective (paper §3.4.1): `f(S) = log det(K_S)` for a
//! PSD kernel `K` — log-submodular, in general **non-monotone**. Included
//! as the second nonparametric-learning application the paper motivates;
//! exercised by tests and the `theory` experiment.
//!
//! To keep f finite we require K to be positive definite (the generators add
//! a ridge). Gains are priced through the same incremental-Cholesky trick
//! as info-gain, on K_S itself (no +I).

use std::sync::Arc;

use super::{State, SubmodularFn};
use crate::data::Dataset;
use crate::linalg::IncrementalCholesky;

/// Log-det DPP objective with an RBF kernel plus ridge.
pub struct DppLogDet {
    data: Arc<Dataset>,
    inv_h2: f64,
    /// Diagonal ridge (> 0 keeps K_S PD; paper's DPP kernels are PSD —
    /// the ridge models the usual quality-term regularization).
    ridge: f64,
}

impl DppLogDet {
    pub fn new(data: &Arc<Dataset>, h: f64, ridge: f64) -> Self {
        assert!(ridge > 0.0);
        DppLogDet { data: Arc::clone(data), inv_h2: 1.0 / (h * h), ridge }
    }

    #[inline]
    fn kernel(&self, i: usize, j: usize) -> f64 {
        let k = (-self.data.sqdist(i, j) * self.inv_h2).exp();
        if i == j {
            k + self.ridge
        } else {
            k
        }
    }
}

impl SubmodularFn for DppLogDet {
    fn state(&self) -> Box<dyn State + '_> {
        Box::new(DppState {
            obj: self,
            chol: IncrementalCholesky::new(),
            selected: Vec::new(),
        })
    }

    fn is_monotone(&self) -> bool {
        false // log det(K_S) decreases once pivots drop below 1
    }

    fn ground_size(&self) -> usize {
        self.data.n
    }
}

pub struct DppState<'a> {
    obj: &'a DppLogDet,
    chol: IncrementalCholesky,
    selected: Vec<usize>,
}

impl<'a> DppState<'a> {
    fn terms(&self, e: usize) -> (f64, Vec<f64>) {
        let a_ee = self.obj.kernel(e, e);
        let a_se = self
            .selected
            .iter()
            .map(|&s| self.obj.kernel(s, e))
            .collect();
        (a_ee, a_se)
    }
}

impl<'a> State for DppState<'a> {
    fn value(&self) -> f64 {
        self.chol.logdet()
    }

    fn gain(&mut self, e: usize) -> f64 {
        let (a_ee, a_se) = self.terms(e);
        self.chol.gain(a_ee, &a_se)
    }

    fn push(&mut self, e: usize) -> f64 {
        let (a_ee, a_se) = self.terms(e);
        let inc = self.chol.push(a_ee, &a_se);
        self.selected.push(e);
        inc
    }

    fn selected(&self) -> &[usize] {
        &self.selected
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_blobs, SynthConfig};
    use crate::linalg::Matrix;

    fn dataset() -> Arc<Dataset> {
        Arc::new(gaussian_blobs(&SynthConfig::unstructured(30, 6), 13))
    }

    fn brute(obj: &DppLogDet, s: &[usize]) -> f64 {
        let k = s.len();
        let mut m = Matrix::zeros(k, k);
        for i in 0..k {
            for j in 0..k {
                m[(i, j)] = obj.kernel(s[i], s[j]);
            }
        }
        m.logdet().unwrap()
    }

    #[test]
    fn matches_dense_logdet() {
        let ds = dataset();
        let f = DppLogDet::new(&ds, 1.0, 0.5);
        let s = [2, 7, 19, 11];
        assert!((f.eval(&s) - brute(&f, &s)).abs() < 1e-8);
    }

    #[test]
    fn prefers_diverse_sets() {
        // Near-duplicate pairs should score lower than spread pairs.
        let ds = Arc::new(Dataset::from_rows(vec![
            vec![0.0, 0.0],
            vec![0.01, 0.0], // near-duplicate of 0
            vec![5.0, 5.0],  // far away
        ]));
        let f = DppLogDet::new(&ds, 1.0, 0.1);
        assert!(f.eval(&[0, 2]) > f.eval(&[0, 1]));
    }

    #[test]
    fn non_monotone_flag() {
        let ds = dataset();
        assert!(!DppLogDet::new(&ds, 1.0, 0.5).is_monotone());
    }

    #[test]
    fn gain_push_consistency() {
        let ds = dataset();
        let f = DppLogDet::new(&ds, 1.0, 0.5);
        let mut st = f.state();
        st.push(0);
        let g = st.gain(9);
        let realized = st.push(9);
        assert!((g - realized).abs() < 1e-10);
    }
}
