//! Submodular objective functions, all served by ONE batch-pricing core.
//!
//! ## Architecture: kernels under an engine
//!
//! The central abstraction is [`SubmodularFn`], which hands out
//! *incremental evaluation states* ([`State`]): greedy algorithms price
//! candidates through `State::gain` / `State::batch_gains` /
//! `State::par_batch_gains` and commit with `State::push`. Since the
//! engine refactor, **no objective implements those pricing surfaces
//! itself**. Each objective supplies a small [`engine::GainKernel`] — its
//! incremental caches plus a read-only per-shard pricing function — and
//! `state()` returns an [`engine::ShardedGainEngine`] wrapping it. The
//! engine owns, for every objective uniformly:
//!
//! * shard-boundary computation (pure function of problem shape, never the
//!   thread count),
//! * submission to the persistent work-stealing pool (`util::executor`),
//! * shard-ordered deterministic reduction,
//! * oracle-call accounting ([`State::oracle_counter`]),
//! * the runtime-dispatch seam ([`engine::GainBackend`] batches to the XLA
//!   facility artifact today; the GPU/NUMA backends ROADMAP names plug in
//!   at the same hook).
//!
//! The per-objective caches are what make the paper's experiments
//! tractable — facility location keeps a cached `curmin` vector (O(n)
//! gains instead of O(n·k)), information gain and DPP keep an incremental
//! Cholesky factor (O(k²) probe columns / Schur complements instead of
//! O(k³) log-dets), coverage keeps a covered bitset, the cut function
//! keeps membership flags, and modular/entropy are analytic.
//!
//! ## Determinism rules
//!
//! Every pricing surface of every objective is **bit-identical across
//! thread counts** and across `gain`/`batch_gains`/`par_batch_gains`:
//! shard boundaries depend only on problem shape, per-shard pricing is
//! read-only, and reduction happens in shard order on the caller (the full
//! contract is spelled out in [`engine`]'s module docs; the facility SIMD
//! dispatch adds a per-dispatch-path caveat documented in [`facility`]).
//! `tests/integration_gain_engine.rs` sweeps the whole matrix — every
//! objective × threads {1, 2, 8} × the serial-executor escape hatch — and
//! CI re-runs it under `GREEDI_NO_SIMD=1` and `GREEDI_EXECUTOR_SERIAL=1`.
//!
//! ## Adding an objective
//!
//! Implement [`engine::GainKernel`] (~50 lines: shard spec, one read-only
//! shard pricer, one commit, two getters) and return
//! `Box::new(ShardedGainEngine::new(kernel))` from `state()`. See
//! [`modular`] for the smallest complete example and [`engine`]'s module
//! docs for the full walk-through. Objectives with an analytic f({e})
//! should also override [`SubmodularFn::singleton_gains`] (and
//! [`engine::GainKernel::singleton`]) so streaming-sieve ladder pricing
//! skips state construction — [`modular`] and [`coverage`] do.
//!
//! Every objective supports *restriction* to a subset of the data for the
//! decomposable/local evaluation mode of the paper's §4.5 (function
//! evaluation limited to the elements on a machine).

pub mod coverage;
pub mod curvature;
pub mod cut;
pub mod dpp;
pub mod engine;
pub mod entropy_worstcase;
pub mod facility;
pub mod infogain;
pub mod modular;

/// Incremental evaluation state for one growing solution set.
pub trait State {
    /// Current f(S).
    fn value(&self) -> f64;

    /// Marginal gain f(S ∪ {e}) − f(S). Does not commit `e`.
    fn gain(&mut self, e: usize) -> f64;

    /// Batched gains (hot path; backends may vectorize via XLA artifacts).
    /// Default implementation prices candidates one by one.
    fn batch_gains(&mut self, es: &[usize]) -> Vec<f64> {
        es.iter().map(|&e| self.gain(e)).collect()
    }

    /// Data-parallel batched gains: price `es` using up to `threads`
    /// workers of the persistent `util::executor` pool. Implementations MUST
    /// return bit-identical results for every `threads` value (the engine
    /// shards work along boundaries that depend only on problem shape, never
    /// on the thread count), so algorithms stay deterministic under any
    /// parallelism. Default: the serial [`State::batch_gains`] path.
    fn par_batch_gains(&mut self, es: &[usize], threads: usize) -> Vec<f64> {
        let _ = threads;
        self.batch_gains(es)
    }

    /// Commit `e` into the solution, returning the realized gain.
    fn push(&mut self, e: usize) -> f64;

    /// Elements committed so far, in insertion order.
    fn selected(&self) -> &[usize];

    /// Oracle-call accounting maintained by the gain engine (gains priced
    /// and batched calls issued through this state). Counts are a pure
    /// function of the call sequence, hence thread-invariant. Default:
    /// zeros, for states not routed through
    /// [`engine::ShardedGainEngine`].
    fn oracle_counter(&self) -> OracleCounter {
        OracleCounter::default()
    }
}

/// A non-negative submodular set function over ground set `0..n`.
pub trait SubmodularFn: Sync {
    /// Fresh incremental state with `S = ∅`.
    fn state(&self) -> Box<dyn State + '_>;

    /// Evaluate f(S) from scratch (default: replay through a state).
    fn eval(&self, s: &[usize]) -> f64 {
        let mut st = self.state();
        for &e in s {
            st.push(e);
        }
        st.value()
    }

    /// Batched singleton values `f({e})` for each `e` in `es` — the
    /// streaming sieve's threshold-ladder pricing entry point (every
    /// incoming batch is priced once to drive the `(1+ε)^i` ladder).
    /// Default: one [`State::par_batch_gains`] call on a fresh state, which
    /// is exact (gains from ∅ *are* the singletons), inherits the engine's
    /// bit-identical-across-threads contract, and — for kernels with a
    /// closed-form [`engine::GainKernel::singleton`] — already skips the
    /// sharded scan. Objectives whose singletons need no state at all
    /// (modular weights, coverage set sizes) override this to also skip
    /// state *construction*; overrides MUST stay bit-identical to the
    /// default path.
    fn singleton_gains(&self, es: &[usize], threads: usize) -> Vec<f64> {
        let mut st = self.state();
        st.par_batch_gains(es, threads)
    }

    /// Whether f is monotone (greedy stopping rules differ).
    fn is_monotone(&self) -> bool {
        true
    }

    /// Size of the ground set, if known (buffers, sanity checks).
    fn ground_size(&self) -> usize;
}

/// Gain-oracle call counter, shared by algorithms to report the metric the
/// paper's speedup plots are driven by. Maintained for every objective by
/// [`engine::ShardedGainEngine`] (see [`State::oracle_counter`]).
#[derive(Debug, Default, Clone)]
pub struct OracleCounter {
    pub gains: u64,
    pub batches: u64,
}

impl OracleCounter {
    pub fn count_gain(&mut self, n: usize) {
        self.gains += n as u64;
    }
    pub fn count_batch(&mut self) {
        self.batches += 1;
    }
}

/// Brute-force submodularity check on a small ground set (test helper):
/// verifies diminishing returns f(A+e)−f(A) ≥ f(B+e)−f(B) for sampled
/// chains A ⊆ B. Returns the worst violation (≤ tol means pass).
pub fn check_diminishing_returns(
    f: &dyn SubmodularFn,
    ground: &[usize],
    rng: &mut crate::util::rng::Rng,
    trials: usize,
) -> f64 {
    let mut worst: f64 = 0.0;
    for _ in 0..trials {
        let mut pool = ground.to_vec();
        rng.shuffle(&mut pool);
        let bsz = 1 + rng.below(pool.len().saturating_sub(1).max(1));
        let asz = rng.below(bsz) + 1;
        let b: Vec<usize> = pool[..bsz].to_vec();
        let a: Vec<usize> = b[..asz].to_vec();
        let Some(&e) = pool[bsz..].first() else { continue };
        let fa = f.eval(&a);
        let fb = f.eval(&b);
        let mut ae = a.clone();
        ae.push(e);
        let mut be = b.clone();
        be.push(e);
        let gain_a = f.eval(&ae) - fa;
        let gain_b = f.eval(&be) - fb;
        worst = worst.max(gain_b - gain_a);
    }
    worst
}

/// Monotonicity spot-check (test helper): f(A) ≤ f(A ∪ e) over random sets.
pub fn check_monotone(
    f: &dyn SubmodularFn,
    ground: &[usize],
    rng: &mut crate::util::rng::Rng,
    trials: usize,
) -> f64 {
    let mut worst: f64 = 0.0;
    for _ in 0..trials {
        let mut pool = ground.to_vec();
        rng.shuffle(&mut pool);
        let asz = rng.below(pool.len() - 1) + 1;
        let a: Vec<usize> = pool[..asz].to_vec();
        let e = pool[asz];
        let fa = f.eval(&a);
        let mut ae = a.clone();
        ae.push(e);
        worst = worst.max(fa - f.eval(&ae));
    }
    worst
}
