//! Total curvature (paper §5.1): `c = 1 − min_j f(j | V∖j) / f(j)` measures
//! how far f is from modular. Greedy achieves `1/(1+c)` under a matroid and
//! `(1−e^{−c})/c` under a cardinality constraint (Conforti & Cornuéjols
//! 1984) — both validated empirically by the theory experiment and tests.

use super::SubmodularFn;

/// Exact total curvature (O(n) evals of f(V∖j) chains — use on small/medium
/// ground sets; the sampled variant below scales further).
pub fn total_curvature(f: &dyn SubmodularFn, ground: &[usize]) -> f64 {
    let mut worst_ratio = f64::INFINITY;
    for (pos, &j) in ground.iter().enumerate() {
        let singleton = f.eval(&[j]);
        if singleton <= 1e-12 {
            continue; // f(j) = 0 elements do not constrain curvature
        }
        let mut rest: Vec<usize> = ground.to_vec();
        rest.remove(pos);
        let f_rest = f.eval(&rest);
        let mut all = rest.clone();
        all.push(j);
        let marginal = f.eval(&all) - f_rest;
        worst_ratio = worst_ratio.min(marginal / singleton);
    }
    if worst_ratio.is_finite() {
        (1.0 - worst_ratio).clamp(0.0, 1.0)
    } else {
        0.0
    }
}

/// Sampled curvature estimate: evaluates the ratio on `samples` random
/// elements (upper bound estimate of c; exact as samples → n).
pub fn sampled_curvature(
    f: &dyn SubmodularFn,
    ground: &[usize],
    rng: &mut crate::util::rng::Rng,
    samples: usize,
) -> f64 {
    let mut worst_ratio = f64::INFINITY;
    let picks = rng.sample_indices(ground.len(), samples.min(ground.len()));
    for pos in picks {
        let j = ground[pos];
        let singleton = f.eval(&[j]);
        if singleton <= 1e-12 {
            continue;
        }
        let mut rest: Vec<usize> = ground.to_vec();
        rest.retain(|&e| e != j);
        let f_rest = f.eval(&rest);
        let mut all = rest.clone();
        all.push(j);
        worst_ratio = worst_ratio.min((f.eval(&all) - f_rest) / singleton);
    }
    if worst_ratio.is_finite() {
        (1.0 - worst_ratio).clamp(0.0, 1.0)
    } else {
        0.0
    }
}

/// The curvature-dependent cardinality-constraint greedy guarantee
/// `(1 − e^{−c})/c` (→ 1 as c → 0, → 1−1/e as c → 1).
pub fn greedy_guarantee_cardinality(c: f64) -> f64 {
    if c <= 1e-12 {
        1.0
    } else {
        (1.0 - (-c).exp()) / c
    }
}

/// Matroid-constraint guarantee `1/(1+c)`.
pub fn greedy_guarantee_matroid(c: f64) -> f64 {
    1.0 / (1.0 + c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::transactions::TransactionData;
    use crate::objective::coverage::Coverage;
    use crate::objective::modular::Modular;
    use crate::util::rng::Rng;
    use std::sync::Arc;

    #[test]
    fn modular_has_zero_curvature() {
        let f = Modular::new(vec![1.0, 2.0, 3.0, 4.0]);
        let ground: Vec<usize> = (0..4).collect();
        assert!(total_curvature(&f, &ground) < 1e-12);
        assert_eq!(greedy_guarantee_cardinality(0.0), 1.0);
        assert_eq!(greedy_guarantee_matroid(0.0), 1.0);
    }

    #[test]
    fn fully_overlapping_coverage_has_curvature_one() {
        // two identical transactions: adding the second to V∖{second}
        // gains nothing → c = 1.
        let td = Arc::new(TransactionData {
            n_items: 3,
            transactions: vec![vec![0, 1, 2], vec![0, 1, 2]],
        });
        let f = Coverage::new(&td);
        let c = total_curvature(&f, &[0, 1]);
        assert!((c - 1.0).abs() < 1e-12);
    }

    #[test]
    fn disjoint_coverage_has_zero_curvature() {
        let td = Arc::new(TransactionData {
            n_items: 4,
            transactions: vec![vec![0, 1], vec![2, 3]],
        });
        let f = Coverage::new(&td);
        assert!(total_curvature(&f, &[0, 1]) < 1e-12);
    }

    #[test]
    fn sampled_never_exceeds_exact_by_much() {
        let td = Arc::new(TransactionData {
            n_items: 6,
            transactions: vec![vec![0, 1], vec![1, 2], vec![2, 3], vec![3, 4], vec![4, 5]],
        });
        let f = Coverage::new(&td);
        let ground: Vec<usize> = (0..5).collect();
        let exact = total_curvature(&f, &ground);
        let mut rng = Rng::new(1);
        let sampled = sampled_curvature(&f, &ground, &mut rng, 5);
        assert!((exact - sampled).abs() < 1e-12); // full sample = exact
    }

    #[test]
    fn guarantee_endpoints() {
        assert!((greedy_guarantee_cardinality(1.0) - (1.0 - (-1.0f64).exp())).abs() < 1e-12);
        assert!((greedy_guarantee_matroid(1.0) - 0.5).abs() < 1e-12);
    }
}
