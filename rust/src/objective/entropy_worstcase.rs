//! The tight lower-bound instance from the proof of Theorem 3 (appendix
//! A.1): independent Bernoulli bits `X_{i,j}` (i ∈ machines, j ∈ 1..k) and
//! aggregate variables `Y_i = (X_{i,1}, …, X_{i,k})`; `f(S) = H(S)` is the
//! joint entropy. Machine i's shard is `{X_{i,1}, …, X_{i,k}, Y_i}`; on it,
//! both `{X_{i,·}}` and `{Y_i}` achieve local value k, while globally only
//! `{Y_1, …, Y_m}` reaches `min(m,k)·k`.
//!
//! Closed form: `H(S) = Σ_i [ k if Y_i ∈ S else |{j : X_{i,j} ∈ S}| ]`
//! (each group's bits are determined by its Y; groups are independent).
//!
//! Element numbering: group i occupies ids `i·(k+1) .. i·(k+1)+k`, the
//! last id of a group being its `Y_i`.
//!
//! Pricing rides the shared [`ShardedGainEngine`] as a candidate-sharded
//! [`GainKernel`] (each candidate's gain is an O(1) group lookup against
//! read-only membership counters) — pre-refactor this objective priced
//! serially, element at a time.

use std::ops::Range;

use super::engine::{GainKernel, ShardSpec, ShardedGainEngine, MIN_CANDIDATES_PER_SHARD};
use super::{State, SubmodularFn};

/// The Θ(min(m,k)) tightness instance for the two-round protocol.
pub struct EntropyWorstCase {
    pub m: usize,
    pub k: usize,
}

impl EntropyWorstCase {
    pub fn new(m: usize, k: usize) -> Self {
        EntropyWorstCase { m, k }
    }

    /// Group of an element.
    pub fn group(&self, e: usize) -> usize {
        e / (self.k + 1)
    }

    /// Is this element the aggregate `Y_i` of its group?
    pub fn is_y(&self, e: usize) -> bool {
        e % (self.k + 1) == self.k
    }

    /// The natural adversarial partition: machine i holds group i.
    pub fn adversarial_partition(&self) -> Vec<Vec<usize>> {
        (0..self.m)
            .map(|i| (i * (self.k + 1)..(i + 1) * (self.k + 1)).collect())
            .collect()
    }

    /// The optimal centralized solution: all the Y_i (value min(m,k)·k
    /// when choosing k of them, i.e. k·min(m,k)).
    pub fn optimal_value(&self, budget: usize) -> f64 {
        // picking Y's first (k bits each), then leftover single bits
        let ys = budget.min(self.m);
        let mut v = (ys * self.k) as f64;
        let leftover = budget - ys;
        // extra X bits only help in groups whose Y is absent — none left if
        // ys == m; otherwise each adds 1. Cap by available bits.
        if ys == self.m {
            // all groups covered: extra X bits add nothing
        } else {
            v += leftover.min((self.m - ys) * self.k) as f64;
        }
        v
    }
}

impl SubmodularFn for EntropyWorstCase {
    fn state(&self) -> Box<dyn State + '_> {
        Box::new(ShardedGainEngine::new(EntropyKernel {
            obj: self,
            y_in: vec![false; self.m],
            x_count: vec![0usize; self.m],
            x_in: vec![false; self.m * (self.k + 1)],
            selected: Vec::new(),
        }))
    }

    fn ground_size(&self) -> usize {
        self.m * (self.k + 1)
    }
}

/// Candidate-sharded entropy kernel: per-group membership counters.
pub struct EntropyKernel<'a> {
    obj: &'a EntropyWorstCase,
    y_in: Vec<bool>,
    x_count: Vec<usize>,
    x_in: Vec<bool>,
    selected: Vec<usize>,
}

/// Pre-refactor name for the entropy state, preserved as the engine alias.
pub type EntropyState<'a> = ShardedGainEngine<EntropyKernel<'a>>;

impl<'a> EntropyKernel<'a> {
    fn group_value(&self, g: usize) -> usize {
        if self.y_in[g] {
            self.obj.k
        } else {
            self.x_count[g]
        }
    }

    /// Read-only marginal gain (the pre-refactor `gain` body verbatim).
    fn gain_at(&self, e: usize) -> f64 {
        let g = self.obj.group(e);
        if self.x_in[e] {
            return 0.0;
        }
        if self.obj.is_y(e) {
            (self.obj.k - self.group_value(g)) as f64
        } else if self.y_in[g] {
            0.0
        } else {
            1.0
        }
    }
}

impl<'a> GainKernel for EntropyKernel<'a> {
    fn label(&self) -> &'static str {
        "entropy"
    }

    fn shard_spec(&self) -> ShardSpec {
        ShardSpec::Candidates { min_per_shard: MIN_CANDIDATES_PER_SHARD }
    }

    fn shard_gain_partial(&self, es: &[usize], rows: &Range<usize>) -> Vec<f64> {
        es[rows.clone()].iter().map(|&e| self.gain_at(e)).collect()
    }

    fn apply_push(&mut self, e: usize) -> f64 {
        let gain = self.gain_at(e);
        if !self.x_in[e] {
            self.x_in[e] = true;
            let g = self.obj.group(e);
            if self.obj.is_y(e) {
                self.y_in[g] = true;
            } else {
                self.x_count[g] += 1;
            }
            self.selected.push(e);
        }
        gain
    }

    fn value(&self) -> f64 {
        (0..self.obj.m).map(|g| self.group_value(g)).sum::<usize>() as f64
    }

    fn selected(&self) -> &[usize] {
        &self.selected
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::{check_diminishing_returns, check_monotone};
    use crate::util::rng::Rng;

    #[test]
    fn closed_form_values() {
        let f = EntropyWorstCase::new(2, 3); // groups of X0..X2,Y per machine
        // element ids: group 0 = {0,1,2, 3=Y0}, group 1 = {4,5,6, 7=Y1}
        assert_eq!(f.eval(&[0, 1]), 2.0);
        assert_eq!(f.eval(&[3]), 3.0); // Y0 carries all 3 bits
        assert_eq!(f.eval(&[3, 0]), 3.0); // X bit absorbed by Y
        assert_eq!(f.eval(&[3, 7]), 6.0);
        assert_eq!(f.eval(&[0, 4]), 2.0);
    }

    #[test]
    fn monotone_and_submodular() {
        let f = EntropyWorstCase::new(3, 3);
        let ground: Vec<usize> = (0..f.ground_size()).collect();
        let mut rng = Rng::new(6);
        assert!(check_monotone(&f, &ground, &mut rng, 80) < 1e-12);
        assert!(check_diminishing_returns(&f, &ground, &mut rng, 80) < 1e-12);
    }

    #[test]
    fn optimal_value_formula() {
        let f = EntropyWorstCase::new(4, 5);
        assert_eq!(f.optimal_value(3), 15.0); // 3 Y's
        assert_eq!(f.optimal_value(4), 20.0);
        assert_eq!(f.optimal_value(6), 20.0); // 4 Y's; stray bits add nothing
    }

    #[test]
    fn batched_gains_match_serial(){
        let f = EntropyWorstCase::new(16, 12);
        let mut st = f.state();
        st.push(12); // Y_0
        st.push(13); // X_{1,0}
        let cands: Vec<usize> = (0..f.ground_size()).collect();
        let serial = st.batch_gains(&cands);
        assert_eq!(serial, st.par_batch_gains(&cands, 8));
        for (i, &e) in cands.iter().enumerate() {
            assert_eq!(serial[i], st.gain(e));
        }
    }

    #[test]
    fn adversarial_partition_shape() {
        let f = EntropyWorstCase::new(3, 2);
        let parts = f.adversarial_partition();
        assert_eq!(parts.len(), 3);
        assert!(parts.iter().all(|p| p.len() == 3));
        // Y of group 1 is element 5
        assert!(f.is_y(5));
        assert_eq!(f.group(5), 1);
    }
}
