//! API-compatible stand-ins for the PJRT engine when the `xla` feature is
//! off (the default — the vendored `xla` crate only exists in the offline
//! closure). Constructors return errors instead of engines, so callers
//! keep compiling and take their scalar fallback paths; the execution
//! methods are unreachable because no stub value can ever be constructed.

use std::path::Path;
use std::sync::atomic::AtomicU64;
use std::sync::Arc;

use super::artifact::Manifest;
use crate::coordinator::BackendFactory;
use crate::data::Dataset;
use crate::objective::engine::GainBackend;
use crate::util::error::{anyhow, Result};

/// Stand-in for `runtime::engine::Engine`; `load` always errors.
pub struct Engine {
    pub manifest: Manifest,
    /// Cumulative number of executions (perf accounting).
    pub exec_count: AtomicU64,
    _unconstructible: (),
}

impl Engine {
    pub fn load(_dir: &Path) -> Result<Engine> {
        Err(anyhow!(
            "PJRT runtime disabled — vendor the `xla` crate (see rust/Cargo.toml [features]) and rebuild with `--features xla`"
        ))
    }

    pub fn load_default() -> Result<Engine> {
        Engine::load(&super::default_artifact_dir())
    }

    pub fn execute_f32(&self, _name: &str, _inputs: &[&[f32]]) -> Result<Vec<f32>> {
        unreachable!("stub Engine cannot be constructed")
    }
}

/// Stand-in for the batched facility-gain backend; `new` always errors.
pub struct XlaFacilityBackend {
    _unconstructible: (),
}

impl XlaFacilityBackend {
    pub fn new(
        _engine: &Arc<Engine>,
        _data: &Arc<Dataset>,
        _window: &[usize],
    ) -> Result<Self> {
        Err(anyhow!(
            "XLA facility backend disabled — vendor the `xla` crate and rebuild with `--features xla`"
        ))
    }
}

impl GainBackend for XlaFacilityBackend {
    fn batch_gain_sums(&self, _cands: &[usize], _curmin: &[f32]) -> Vec<f64> {
        unreachable!("stub XlaFacilityBackend cannot be constructed")
    }
}

/// Stand-in for the window-specific backend factory.
pub struct XlaBackendFactory {
    pub engine: Arc<Engine>,
}

impl BackendFactory for XlaBackendFactory {
    fn make(&self, _data: &Arc<Dataset>, _window: &[usize]) -> Arc<dyn GainBackend> {
        unreachable!("stub Engine cannot be constructed, so no factory can exist")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_engine_load_errors_helpfully() {
        let err = Engine::load_default().unwrap_err();
        assert!(err.to_string().contains("--features xla"), "{err}");
    }
}
