//! Artifact manifest: the contract between `aot.py` and the rust runtime.
//! `manifest.json` lists every compiled graph with its shape bucket; the
//! registry validates shapes at load time so a stale `artifacts/` directory
//! fails fast instead of mis-executing.

use std::path::{Path, PathBuf};

use crate::util::error::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// One AOT-compiled graph.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub name: String,
    pub file: String,
    pub doc: String,
    /// Input shapes in argument order (f32).
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes (single-output graphs in this project).
    pub outputs: Vec<Vec<usize>>,
}

impl ManifestEntry {
    /// Total f32 element count of input `i`.
    pub fn input_len(&self, i: usize) -> usize {
        self.inputs[i].iter().product()
    }

    pub fn output_len(&self, i: usize) -> usize {
        self.outputs[i].iter().product()
    }
}

/// The parsed manifest.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
        let doc = json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        if doc.get("format").and_then(Json::as_str) != Some("hlo-text") {
            bail!("{path:?}: unsupported manifest format");
        }
        let mut entries = Vec::new();
        for e in doc
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest: missing entries"))?
        {
            let shapes = |key: &str| -> Result<Vec<Vec<usize>>> {
                e.get(key)
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("entry missing {key}"))?
                    .iter()
                    .map(|s| s.as_usize_arr().ok_or_else(|| anyhow!("bad shape in {key}")))
                    .collect()
            };
            entries.push(ManifestEntry {
                name: e
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("entry missing name"))?
                    .to_string(),
                file: e
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("entry missing file"))?
                    .to_string(),
                doc: e.get("doc").and_then(Json::as_str).unwrap_or("").to_string(),
                inputs: shapes("inputs")?,
                outputs: shapes("outputs")?,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    pub fn find(&self, name: &str) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Path of an entry's HLO file.
    pub fn path_of(&self, entry: &ManifestEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }

    /// Pick the facility-gain artifact bucket for dimension `d` (smallest
    /// bucket ≥ d), returning `(entry, padded_d, block_b, block_n)`.
    pub fn facility_bucket(&self, d: usize) -> Option<(&ManifestEntry, usize, usize, usize)> {
        let mut best: Option<(&ManifestEntry, usize)> = None;
        for e in &self.entries {
            if !e.name.starts_with("facility_gain") {
                continue;
            }
            let bucket_d = *e.inputs[0].last()?;
            if bucket_d >= d && best.map(|(_, bd)| bucket_d < bd).unwrap_or(true) {
                best = Some((e, bucket_d));
            }
        }
        best.map(|(e, bd)| (e, bd, e.inputs[0][0], e.inputs[1][0]))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn loads_and_finds() {
        let dir = std::env::temp_dir().join("greedi_manifest_test1");
        write_manifest(
            &dir,
            r#"{"format": "hlo-text", "entries": [
                {"name": "facility_gain_b64_n1024_d8", "file": "f.hlo.txt", "doc": "",
                 "inputs": [[64, 8], [1024, 8], [1024]], "outputs": [[64]]},
                {"name": "facility_gain_b64_n1024_d32", "file": "g.hlo.txt", "doc": "",
                 "inputs": [[64, 32], [1024, 32], [1024]], "outputs": [[64]]}
            ]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 2);
        assert!(m.find("facility_gain_b64_n1024_d8").is_some());
        assert!(m.find("nope").is_none());
        let e = m.find("facility_gain_b64_n1024_d8").unwrap();
        assert_eq!(e.input_len(0), 64 * 8);
        assert_eq!(e.output_len(0), 64);
    }

    #[test]
    fn facility_bucket_selects_smallest_fit() {
        let dir = std::env::temp_dir().join("greedi_manifest_test2");
        write_manifest(
            &dir,
            r#"{"format": "hlo-text", "entries": [
                {"name": "facility_gain_b64_n1024_d8", "file": "f.hlo.txt", "doc": "",
                 "inputs": [[64, 8], [1024, 8], [1024]], "outputs": [[64]]},
                {"name": "facility_gain_b64_n1024_d32", "file": "g.hlo.txt", "doc": "",
                 "inputs": [[64, 32], [1024, 32], [1024]], "outputs": [[64]]}
            ]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        let (e, d, b, n) = m.facility_bucket(6).unwrap();
        assert_eq!(d, 8);
        assert_eq!((b, n), (64, 1024));
        assert!(e.name.ends_with("_d8"));
        let (_, d32, _, _) = m.facility_bucket(22).unwrap();
        assert_eq!(d32, 32);
        assert!(m.facility_bucket(64).is_none());
    }

    #[test]
    fn missing_manifest_errors() {
        let dir = std::env::temp_dir().join("greedi_manifest_missing");
        let _ = std::fs::remove_dir_all(&dir);
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn bad_format_rejected() {
        let dir = std::env::temp_dir().join("greedi_manifest_badfmt");
        write_manifest(&dir, r#"{"format": "protobuf", "entries": []}"#);
        assert!(Manifest::load(&dir).is_err());
    }
}
