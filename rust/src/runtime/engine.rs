//! The PJRT execution engine: one CPU client, one compiled executable per
//! manifest entry, typed f32 execute helpers.
//!
//! Thread-safety: the underlying PJRT CPU client is thread-safe, but the
//! `xla` crate's wrapper types are not marked `Send`/`Sync`. The engine
//! therefore serializes executions behind a `Mutex` and asserts
//! `Send + Sync` for the whole struct — sound because every FFI call is
//! made while holding the lock, and the CPU client itself is re-entrant.

use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

use crate::util::error::{anyhow, bail, Context, Result};

use super::artifact::{Manifest, ManifestEntry};

struct Loaded {
    exe: xla::PjRtLoadedExecutable,
}

struct Inner {
    _client: xla::PjRtClient,
    loaded: HashMap<String, Loaded>,
}

/// Compiled-artifact execution engine.
pub struct Engine {
    pub manifest: Manifest,
    inner: Mutex<Inner>,
    /// Cumulative number of executions (perf accounting).
    pub exec_count: std::sync::atomic::AtomicU64,
}

// SAFETY: all xla FFI objects are only touched under `inner`'s Mutex; the
// PJRT CPU client itself is thread-safe. See module docs.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Load every artifact in `<dir>/manifest.json` and compile it on the
    /// PJRT CPU client.
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e}"))?;
        let mut loaded = HashMap::new();
        for entry in &manifest.entries {
            let path = manifest.path_of(entry);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {path:?}: {e}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e}", entry.name))?;
            loaded.insert(entry.name.clone(), Loaded { exe });
        }
        Ok(Engine {
            manifest,
            inner: Mutex::new(Inner { _client: client, loaded }),
            exec_count: std::sync::atomic::AtomicU64::new(0),
        })
    }

    /// Load from the default artifact directory.
    pub fn load_default() -> Result<Engine> {
        Engine::load(&super::default_artifact_dir())
    }

    pub fn entry(&self, name: &str) -> Result<ManifestEntry> {
        self.manifest
            .find(name)
            .cloned()
            .ok_or_else(|| anyhow!("unknown artifact {name}"))
    }

    /// Execute artifact `name` on f32 buffers (shapes validated against the
    /// manifest). Returns the flattened f32 output of the (single-output)
    /// tuple the graphs produce.
    pub fn execute_f32(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        let entry = self.entry(name)?;
        if inputs.len() != entry.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                entry.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (data, shape)) in inputs.iter().zip(&entry.inputs).enumerate() {
            let want: usize = shape.iter().product();
            if data.len() != want {
                bail!("{name}: input {i} has {} elems, shape {shape:?} wants {want}", data.len());
            }
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
            };
            let lit = xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                shape,
                bytes,
            )
            .map_err(|e| anyhow!("{name}: literal for input {i}: {e}"))?;
            literals.push(lit);
        }

        let inner = self.inner.lock().unwrap();
        let loaded = inner
            .loaded
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name} not loaded"))?;
        let result = loaded
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("{name}: execute: {e}"))?;
        self.exec_count
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("{name}: readback: {e}"))?;
        // Graphs are lowered with return_tuple=True → unwrap the 1-tuple.
        let out = lit
            .to_tuple1()
            .map_err(|e| anyhow!("{name}: tuple unwrap: {e}"))?;
        out.to_vec::<f32>()
            .map_err(|e| anyhow!("{name}: to_vec: {e}"))
            .with_context(|| format!("output shape {:?}", entry.outputs))
    }
}

#[cfg(test)]
mod tests {
    //! Engine tests live in `rust/tests/integration_runtime.rs` — they need
    //! the real artifacts directory, which unit tests must not assume.
}
