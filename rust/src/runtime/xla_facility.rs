//! XLA-backed facility-location gain oracle — the batched hot path.
//!
//! Implements [`GainBackend`](crate::objective::engine::GainBackend) — the
//! gain engine's accelerator seam: `objective::engine::ShardedGainEngine`
//! dispatches whole batches here via `GainKernel::backend_batch` before
//! any CPU sharding — by streaming fixed-shape blocks through the
//! `facility_gain_*` artifact (the Pallas kernel lowered into the L2
//! graph):
//!
//! * candidates are packed into `B`-row blocks (last block padded by
//!   repeating the first candidate; surplus outputs are dropped);
//! * the evaluation window is packed once, at construction, into `N`-row
//!   data blocks padded with zero rows;
//! * padded `curmin` entries are 0, so padding rows contribute exactly 0 to
//!   the gain sums (verified by `test_padding_rows_contribute_zero` on the
//!   python side and the integration tests here);
//! * feature dimension is zero-padded up to the artifact's shape bucket
//!   (zero dims add zero to squared distances).

use std::sync::Arc;

use crate::util::error::{anyhow, Result};

use super::engine::Engine;
use crate::data::Dataset;
use crate::objective::engine::GainBackend;

/// Batched facility-gain executor over one evaluation window.
pub struct XlaFacilityBackend {
    engine: Arc<Engine>,
    data: Arc<Dataset>,
    artifact: String,
    /// Bucketed dims.
    d_pad: usize,
    block_b: usize,
    block_n: usize,
    /// Window rows packed into padded data blocks (each `block_n * d_pad`).
    data_blocks: Vec<Vec<f32>>,
    /// Number of *real* rows per data block (suffix rows are padding).
    real_rows: Vec<usize>,
}

impl XlaFacilityBackend {
    /// Build a backend evaluating gains against `window` (global mode:
    /// `0..n`; local mode: the machine's shard).
    pub fn new(engine: &Arc<Engine>, data: &Arc<Dataset>, window: &[usize]) -> Result<Self> {
        let (entry, d_pad, block_b, block_n) = engine
            .manifest
            .facility_bucket(data.d)
            .ok_or_else(|| anyhow!("no facility_gain bucket for d={}", data.d))?;
        let artifact = entry.name.clone();

        let mut data_blocks = Vec::new();
        let mut real_rows = Vec::new();
        for chunk in window.chunks(block_n) {
            let mut block = vec![0.0f32; block_n * d_pad];
            for (r, &v) in chunk.iter().enumerate() {
                let row = data.row(v);
                block[r * d_pad..r * d_pad + data.d].copy_from_slice(row);
            }
            data_blocks.push(block);
            real_rows.push(chunk.len());
        }

        Ok(XlaFacilityBackend {
            engine: Arc::clone(engine),
            data: Arc::clone(data),
            artifact,
            d_pad,
            block_b,
            block_n,
            data_blocks,
            real_rows,
        })
    }

    /// Pack a candidate block (ids) into a padded `[block_b, d_pad]` buffer.
    fn pack_cands(&self, cands: &[usize]) -> Vec<f32> {
        debug_assert!(!cands.is_empty() && cands.len() <= self.block_b);
        let mut buf = vec![0.0f32; self.block_b * self.d_pad];
        for (r, &c) in cands.iter().enumerate() {
            buf[r * self.d_pad..r * self.d_pad + self.data.d]
                .copy_from_slice(self.data.row(c));
        }
        // pad by repeating the first candidate (outputs ignored)
        for r in cands.len()..self.block_b {
            let (first, rest) = buf.split_at_mut(self.d_pad);
            let _ = &rest; // slices below copy from `first`
            let dst = r * self.d_pad;
            // copy_within: first row -> row r
            let src: Vec<f32> = first.to_vec();
            buf[dst..dst + self.d_pad].copy_from_slice(&src);
        }
        buf
    }
}

/// `BackendFactory` implementation: builds window-specific backends from a
/// shared engine (so local/merge objectives each get a matching backend).
pub struct XlaBackendFactory {
    pub engine: Arc<Engine>,
}

impl crate::coordinator::BackendFactory for XlaBackendFactory {
    fn make(
        &self,
        data: &Arc<Dataset>,
        window: &[usize],
    ) -> Arc<dyn GainBackend> {
        Arc::new(
            XlaFacilityBackend::new(&self.engine, data, window)
                .expect("facility backend construction"),
        )
    }
}

impl GainBackend for XlaFacilityBackend {
    fn batch_gain_sums(&self, cands: &[usize], curmin: &[f32]) -> Vec<f64> {
        let window_len: usize = self.real_rows.iter().sum();
        assert_eq!(
            curmin.len(),
            window_len,
            "curmin length {} != backend window {} — backend/objective window mismatch",
            curmin.len(),
            window_len
        );
        let mut sums = vec![0.0f64; cands.len()];
        // Pack curmin per data block once per call (padded with zeros).
        let mut curmin_blocks: Vec<Vec<f32>> = Vec::with_capacity(self.data_blocks.len());
        let mut at = 0usize;
        for &rows in &self.real_rows {
            let mut cm = vec![0.0f32; self.block_n];
            cm[..rows].copy_from_slice(&curmin[at..at + rows]);
            curmin_blocks.push(cm);
            at += rows;
        }
        debug_assert_eq!(at, curmin.len(), "curmin length != window length");

        for cand_chunk_idx in 0..cands.len().div_ceil(self.block_b) {
            let lo = cand_chunk_idx * self.block_b;
            let hi = (lo + self.block_b).min(cands.len());
            let cbuf = self.pack_cands(&cands[lo..hi]);
            for (dblock, cm) in self.data_blocks.iter().zip(&curmin_blocks) {
                let out = self
                    .engine
                    .execute_f32(&self.artifact, &[&cbuf, dblock, cm])
                    .expect("facility_gain artifact execution failed");
                for (i, s) in sums[lo..hi].iter_mut().enumerate() {
                    *s += out[i] as f64;
                }
            }
        }
        sums
    }
}

#[cfg(test)]
mod tests {
    //! Numerical agreement with the scalar path is covered by
    //! `rust/tests/integration_runtime.rs` (requires built artifacts).
}
