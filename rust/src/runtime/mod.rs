//! PJRT runtime — loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! Python is build-time only: after `make artifacts` the rust binary runs
//! the Layer-1/2 compute (Pallas kernels inside JAX graphs) through the
//! `xla` crate's PJRT C API. Interchange is HLO **text** because the
//! crate's xla_extension 0.5.1 rejects jax≥0.5 serialized protos (64-bit
//! instruction ids) — see DESIGN.md and /opt/xla-example/README.md.

pub mod artifact;
pub mod engine;
pub mod xla_facility;

pub use artifact::{Manifest, ManifestEntry};
pub use engine::Engine;
pub use xla_facility::{XlaBackendFactory, XlaFacilityBackend};

/// Default artifact directory (relative to the repo root).
pub fn default_artifact_dir() -> std::path::PathBuf {
    // Honour GREEDI_ARTIFACTS for tests/deployment; else ./artifacts.
    if let Ok(dir) = std::env::var("GREEDI_ARTIFACTS") {
        return dir.into();
    }
    "artifacts".into()
}
