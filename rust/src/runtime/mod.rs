//! PJRT runtime — loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the request path.
//!
//! Python is build-time only: after `make artifacts` the rust binary runs
//! the Layer-1/2 compute (Pallas kernels inside JAX graphs) through the
//! `xla` crate's PJRT C API. Interchange is HLO **text** because the
//! crate's xla_extension 0.5.1 rejects jax≥0.5 serialized protos (64-bit
//! instruction ids) — see DESIGN.md and /opt/xla-example/README.md.
//!
//! The PJRT path needs the vendored `xla` crate, which only exists in the
//! offline dependency closure — it is therefore gated behind the `xla`
//! cargo feature. Without the feature, [`stub`] provides API-compatible
//! stand-ins whose constructors return errors, so every caller (experiment
//! harnesses, examples, benches) compiles and falls back to the scalar
//! gain oracle gracefully.

pub mod artifact;
#[cfg(feature = "xla")]
pub mod engine;
#[cfg(not(feature = "xla"))]
pub mod stub;
#[cfg(feature = "xla")]
pub mod xla_facility;

pub use artifact::{Manifest, ManifestEntry};
#[cfg(feature = "xla")]
pub use engine::Engine;
#[cfg(not(feature = "xla"))]
pub use stub::{Engine, XlaBackendFactory, XlaFacilityBackend};
#[cfg(feature = "xla")]
pub use xla_facility::{XlaBackendFactory, XlaFacilityBackend};

/// Default artifact directory (relative to the repo root).
pub fn default_artifact_dir() -> std::path::PathBuf {
    // Honour GREEDI_ARTIFACTS for tests/deployment; else ./artifacts.
    if let Ok(dir) = std::env::var("GREEDI_ARTIFACTS") {
        return dir.into();
    }
    "artifacts".into()
}
