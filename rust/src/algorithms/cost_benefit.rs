//! Knapsack-aware greedy (paper §5.2): the max of (a) plain greedy by raw
//! gain and (b) cost-benefit greedy by gain/cost ratio gives the
//! (1 − 1/√e)-approximation of Krause & Guestrin (2005b). Plain greedy
//! alone can be arbitrarily poor under non-uniform costs.

use super::{greedy::Greedy, Maximizer, RunResult};
use crate::constraints::knapsack::Knapsack;
use crate::constraints::Constraint;
use crate::objective::SubmodularFn;
use crate::util::rng::Rng;

/// Combined plain + cost-benefit greedy for knapsack constraints.
///
/// The knapsack costs must be supplied (the generic [`Constraint`] trait
/// does not expose them); when none are given this degrades to plain
/// greedy, which keeps the `by_name` registry uniform.
pub struct CostBenefitGreedy {
    pub costs: Option<Vec<f64>>,
}

impl CostBenefitGreedy {
    pub fn for_knapsack(k: &Knapsack) -> Self {
        CostBenefitGreedy { costs: Some(k.cost.clone()) }
    }

    pub fn plain() -> Self {
        CostBenefitGreedy { costs: None }
    }

    /// Greedy by benefit/cost ratio.
    fn ratio_greedy(
        &self,
        f: &dyn SubmodularFn,
        ground: &[usize],
        constraint: &dyn Constraint,
        costs: &[f64],
    ) -> RunResult {
        let mut state = f.state();
        let mut oracle_calls = 0u64;
        let mut remaining: Vec<usize> = ground.to_vec();
        loop {
            let feasible: Vec<usize> = remaining
                .iter()
                .copied()
                .filter(|&e| constraint.can_add(state.selected(), e))
                .collect();
            if feasible.is_empty() {
                break;
            }
            let gains = state.batch_gains(&feasible);
            oracle_calls += feasible.len() as u64;
            let best = feasible
                .iter()
                .zip(&gains)
                .max_by(|(a, ga), (b, gb)| {
                    let ra = *ga / costs[**a];
                    let rb = *gb / costs[**b];
                    ra.partial_cmp(&rb).unwrap()
                })
                .map(|(&e, &g)| (e, g));
            let Some((chosen, gain)) = best else { break };
            if gain <= 0.0 {
                break;
            }
            state.push(chosen);
            remaining.retain(|&e| e != chosen);
        }
        RunResult {
            value: state.value(),
            solution: state.selected().to_vec(),
            oracle_calls,
        }
    }
}

impl Maximizer for CostBenefitGreedy {
    fn maximize(
        &self,
        f: &dyn SubmodularFn,
        ground: &[usize],
        constraint: &dyn Constraint,
        rng: &mut Rng,
    ) -> RunResult {
        let plain = Greedy.maximize(f, ground, constraint, rng);
        let Some(costs) = &self.costs else {
            return plain;
        };
        let ratio = self.ratio_greedy(f, ground, constraint, costs);
        // Report the better solution; oracle accounting covers both branches.
        let total_calls = plain.oracle_calls + ratio.oracle_calls;
        let mut best = if ratio.value > plain.value { ratio } else { plain };
        best.oracle_calls = total_calls;
        best
    }

    fn name(&self) -> &'static str {
        "cost_benefit"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::objective::modular::Modular;

    #[test]
    fn beats_plain_greedy_on_adversarial_knapsack() {
        // Classic counterexample: one element with huge gain and huge cost
        // vs many small high-ratio elements. Plain greedy takes the big
        // one and stops; cost-benefit packs the small ones.
        let mut weights = vec![10.0]; // element 0: gain 10, cost 10 (fills budget)
        let mut costs = vec![10.0];
        for _ in 0..10 {
            weights.push(2.0); // ratio 2.0 each
            costs.push(1.0);
        }
        let f = Modular::new(weights);
        let k = Knapsack::new(costs, 10.0);
        let ground: Vec<usize> = (0..11).collect();
        let mut rng = Rng::new(0);
        let plain = Greedy.maximize(&f, &ground, &k, &mut rng);
        let combined = CostBenefitGreedy::for_knapsack(&k).maximize(&f, &ground, &k, &mut rng);
        assert_eq!(plain.value, 10.0);
        assert_eq!(combined.value, 20.0); // ten ratio-2 elements
    }

    #[test]
    fn falls_back_to_plain_when_no_costs() {
        let f = Modular::new(vec![3.0, 1.0]);
        let k = Knapsack::new(vec![1.0, 1.0], 1.0);
        let mut rng = Rng::new(0);
        let r = CostBenefitGreedy::plain().maximize(&f, &[0, 1], &k, &mut rng);
        assert_eq!(r.value, 3.0);
    }

    #[test]
    fn feasible_output() {
        let f = Modular::new(vec![5.0, 4.0, 3.0, 2.0]);
        let k = Knapsack::new(vec![4.0, 3.0, 2.0, 1.0], 5.0);
        let mut rng = Rng::new(0);
        let r = CostBenefitGreedy::for_knapsack(&k).maximize(&f, &(0..4).collect::<Vec<_>>(), &k, &mut rng);
        assert!(k.is_feasible(&r.solution));
    }
}
