//! Sieve-Streaming (Badanidiyuru, Mirzasoleiman, Karbasi & Krause 2014) —
//! the single-pass streaming comparator the paper's related work (§2.2)
//! positions GreeDi against: (1/2 − ε)-approximation for cardinality-
//! constrained monotone maximization with O((k log k)/ε) memory and **one**
//! pass, no assumptions on stream order.
//!
//! Mechanics: lazily maintain candidate thresholds
//! `v ∈ {(1+ε)^i : m ≤ (1+ε)^i ≤ 2·k·m}` where m is the best singleton seen
//! so far; each sieve greedily keeps elements whose marginal gain exceeds
//! `(v/2 − f(S_v))/(k − |S_v|)`; return the best sieve at the end.

use super::{Maximizer, RunResult};
use crate::constraints::Constraint;
use crate::objective::{State, SubmodularFn};
use crate::util::rng::Rng;

/// Single-pass sieve-streaming for cardinality constraints.
pub struct SieveStreaming {
    pub epsilon: f64,
}

impl Default for SieveStreaming {
    fn default() -> Self {
        SieveStreaming { epsilon: 0.1 }
    }
}

impl SieveStreaming {
    pub fn new(epsilon: f64) -> Self {
        assert!(epsilon > 0.0 && epsilon < 1.0);
        SieveStreaming { epsilon }
    }

    /// Threshold grid index range covering [lo, hi].
    fn grid(&self, lo: f64, hi: f64) -> std::ops::RangeInclusive<i64> {
        let base = 1.0 + self.epsilon;
        let i_lo = (lo.max(1e-12).ln() / base.ln()).floor() as i64;
        let i_hi = (hi.max(1e-12).ln() / base.ln()).ceil() as i64;
        i_lo..=i_hi
    }
}

impl Maximizer for SieveStreaming {
    fn maximize(
        &self,
        f: &dyn SubmodularFn,
        ground: &[usize],
        constraint: &dyn Constraint,
        rng: &mut Rng,
    ) -> RunResult {
        let _ = rng;
        let k = constraint.rho().max(1);
        let base = 1.0 + self.epsilon;
        let mut oracle_calls = 0u64;

        // sieves keyed by grid index i (threshold v = base^i)
        let mut sieves: std::collections::BTreeMap<i64, Box<dyn State + '_>> =
            std::collections::BTreeMap::new();
        let mut best_singleton = 0.0f64;

        for &e in ground {
            // singleton value (for the lazy threshold grid)
            let mut probe = f.state();
            let fe = probe.gain(e);
            oracle_calls += 1;
            if fe > best_singleton {
                best_singleton = fe;
                // instantiate newly needed sieves; drop stale ones
                let range = self.grid(best_singleton, 2.0 * k as f64 * best_singleton);
                sieves.retain(|i, _| range.contains(i));
                for i in range {
                    sieves.entry(i).or_insert_with(|| f.state());
                }
            }
            for (&i, sieve) in sieves.iter_mut() {
                let sel = sieve.selected().len();
                if sel >= k {
                    continue;
                }
                let v = base.powi(i as i32);
                let needed = (v / 2.0 - sieve.value()) / (k - sel) as f64;
                let g = sieve.gain(e);
                oracle_calls += 1;
                if g >= needed && g > 0.0 {
                    sieve.push(e);
                }
            }
        }

        let best = sieves
            .into_values()
            .max_by(|a, b| a.value().partial_cmp(&b.value()).unwrap());
        match best {
            Some(s) => RunResult {
                value: s.value(),
                solution: s.selected().to_vec(),
                oracle_calls,
            },
            None => RunResult { value: 0.0, solution: vec![], oracle_calls },
        }
    }

    fn name(&self) -> &'static str {
        "sieve_streaming"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::greedy::Greedy;
    use crate::constraints::cardinality::Cardinality;
    use crate::data::synth::{gaussian_blobs, SynthConfig};
    use crate::data::transactions::zipf_transactions;
    use crate::objective::coverage::Coverage;
    use crate::objective::facility::FacilityLocation;
    use std::sync::Arc;

    #[test]
    fn half_of_greedy_on_coverage() {
        let td = Arc::new(zipf_transactions(200, 150, 8, 1.1, 1));
        let f = Coverage::new(&td);
        let ground: Vec<usize> = (0..200).collect();
        let c = Cardinality::new(10);
        let mut rng = Rng::new(0);
        let greedy = Greedy.maximize(&f, &ground, &c, &mut rng);
        let sieve = SieveStreaming::new(0.05).maximize(&f, &ground, &c, &mut rng);
        assert!(sieve.solution.len() <= 10);
        // guarantee is (1/2-ε)·OPT ≥ (1/2-ε)·greedy; empirically much better
        assert!(
            sieve.value >= 0.45 * greedy.value,
            "sieve {} vs greedy {}",
            sieve.value,
            greedy.value
        );
    }

    #[test]
    fn single_pass_order_insensitive_quality() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(150, 6), 2));
        let f = FacilityLocation::from_dataset(&ds);
        let c = Cardinality::new(8);
        let mut rng = Rng::new(1);
        let fwd: Vec<usize> = (0..150).collect();
        let mut rev = fwd.clone();
        rev.reverse();
        let a = SieveStreaming::default().maximize(&f, &fwd, &c, &mut rng);
        let b = SieveStreaming::default().maximize(&f, &rev, &c, &mut rng);
        // not identical, but both within the guarantee band
        let greedy = Greedy.maximize(&f, &fwd, &c, &mut rng);
        assert!(a.value >= 0.45 * greedy.value);
        assert!(b.value >= 0.45 * greedy.value);
    }

    #[test]
    fn empty_ground() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(10, 4), 3));
        let f = FacilityLocation::from_dataset(&ds);
        let mut rng = Rng::new(0);
        let r = SieveStreaming::default().maximize(&f, &[], &Cardinality::new(3), &mut rng);
        assert!(r.solution.is_empty());
    }

    #[test]
    #[should_panic]
    fn bad_epsilon() {
        SieveStreaming::new(0.0);
    }
}
