//! Sieve-Streaming (Badanidiyuru, Mirzasoleiman, Karbasi & Krause 2014) —
//! the single-pass streaming comparator the paper's related work (§2.2)
//! positions GreeDi against: (1/2 − ε)-approximation for cardinality-
//! constrained monotone maximization with O((k log k)/ε) memory and **one**
//! pass, no assumptions on stream order.
//!
//! Since the streaming subsystem landed, this is a thin [`Maximizer`]
//! wrapper over [`crate::stream::sieve`]: the ground slice becomes a
//! fixed-order [`VecSource`] and the batched engine does the work, pricing
//! [`Self::batch`] elements per oracle round through
//! [`State::par_batch_gains`](crate::objective::State) instead of the old
//! one-element-at-a-time loop. The engine's output is provably identical
//! to element-at-a-time processing (see the `stream::sieve` module docs),
//! so this wrapper preserves the classic algorithm's selections exactly
//! while `maximize_threaded` actually reaches the parallel gain engine.

use super::{Maximizer, RunResult};
use crate::constraints::Constraint;
use crate::objective::SubmodularFn;
use crate::stream::sieve::sieve_stream;
use crate::stream::source::VecSource;
use crate::util::rng::Rng;

/// Single-pass sieve-streaming for cardinality constraints.
pub struct SieveStreaming {
    pub epsilon: f64,
    /// Elements priced per batched oracle round (purely mechanical: any
    /// value yields the same output; wider batches feed the gain engine
    /// better).
    pub batch: usize,
}

impl Default for SieveStreaming {
    fn default() -> Self {
        SieveStreaming { epsilon: 0.1, batch: 64 }
    }
}

impl SieveStreaming {
    pub fn new(epsilon: f64) -> Self {
        assert!(epsilon > 0.0 && epsilon < 1.0);
        SieveStreaming { epsilon, ..Default::default() }
    }

    /// Explicit batch width (output-invariant; see the `batch` field).
    pub fn batched(epsilon: f64, batch: usize) -> Self {
        assert!(epsilon > 0.0 && epsilon < 1.0);
        SieveStreaming { epsilon, batch: batch.max(1) }
    }
}

impl Maximizer for SieveStreaming {
    fn maximize(
        &self,
        f: &dyn SubmodularFn,
        ground: &[usize],
        constraint: &dyn Constraint,
        rng: &mut Rng,
    ) -> RunResult {
        self.maximize_threaded(f, ground, constraint, rng, 1)
    }

    fn maximize_threaded(
        &self,
        f: &dyn SubmodularFn,
        ground: &[usize],
        constraint: &dyn Constraint,
        rng: &mut Rng,
        threads: usize,
    ) -> RunResult {
        let _ = rng;
        let k = constraint.rho().max(1);
        let mut src = VecSource::new(ground.to_vec());
        let r = sieve_stream(f, &mut src, k, self.epsilon, self.batch, threads);
        RunResult { value: r.value, solution: r.solution, oracle_calls: r.oracle_calls }
    }

    fn name(&self) -> &'static str {
        "sieve_streaming"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::greedy::Greedy;
    use crate::constraints::cardinality::Cardinality;
    use crate::data::synth::{gaussian_blobs, SynthConfig};
    use crate::data::transactions::zipf_transactions;
    use crate::objective::coverage::Coverage;
    use crate::objective::facility::FacilityLocation;
    use std::sync::Arc;

    #[test]
    fn half_of_greedy_on_coverage() {
        let td = Arc::new(zipf_transactions(200, 150, 8, 1.1, 1));
        let f = Coverage::new(&td);
        let ground: Vec<usize> = (0..200).collect();
        let c = Cardinality::new(10);
        let mut rng = Rng::new(0);
        let greedy = Greedy.maximize(&f, &ground, &c, &mut rng);
        let sieve = SieveStreaming::new(0.05).maximize(&f, &ground, &c, &mut rng);
        assert!(sieve.solution.len() <= 10);
        // guarantee is (1/2-ε)·OPT ≥ (1/2-ε)·greedy; empirically much better
        assert!(
            sieve.value >= 0.45 * greedy.value,
            "sieve {} vs greedy {}",
            sieve.value,
            greedy.value
        );
    }

    #[test]
    fn single_pass_order_insensitive_quality() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(150, 6), 2));
        let f = FacilityLocation::from_dataset(&ds);
        let c = Cardinality::new(8);
        let mut rng = Rng::new(1);
        let fwd: Vec<usize> = (0..150).collect();
        let mut rev = fwd.clone();
        rev.reverse();
        let a = SieveStreaming::default().maximize(&f, &fwd, &c, &mut rng);
        let b = SieveStreaming::default().maximize(&f, &rev, &c, &mut rng);
        // not identical, but both within the guarantee band
        let greedy = Greedy.maximize(&f, &fwd, &c, &mut rng);
        assert!(a.value >= 0.45 * greedy.value);
        assert!(b.value >= 0.45 * greedy.value);
    }

    #[test]
    fn batch_width_and_threads_do_not_move_the_output() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(180, 6), 4));
        let f = FacilityLocation::from_dataset(&ds);
        let ground: Vec<usize> = (0..180).collect();
        let c = Cardinality::new(7);
        let mut rng = Rng::new(0);
        let reference = SieveStreaming::batched(0.1, 1).maximize(&f, &ground, &c, &mut rng);
        for batch in [2usize, 64, 4096] {
            for threads in [1usize, 4] {
                let r = SieveStreaming::batched(0.1, batch)
                    .maximize_threaded(&f, &ground, &c, &mut rng, threads);
                assert_eq!(reference.solution, r.solution, "batch={batch} threads={threads}");
                assert_eq!(reference.value, r.value, "batch={batch} threads={threads}");
            }
        }
    }

    #[test]
    fn empty_ground() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(10, 4), 3));
        let f = FacilityLocation::from_dataset(&ds);
        let mut rng = Rng::new(0);
        let r = SieveStreaming::default().maximize(&f, &[], &Cardinality::new(3), &mut rng);
        assert!(r.solution.is_empty());
    }

    #[test]
    #[should_panic]
    fn bad_epsilon() {
        SieveStreaming::new(0.0);
    }
}
