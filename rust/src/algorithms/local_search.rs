//! Local search for non-monotone submodular maximization (Lee et al.
//! 2009a-style add/swap moves) — the paper's Table 1 cites local-search
//! approximations for knapsack and matroid constraints; we provide the
//! practical variant: start from RandomGreedy, then hill-climb with
//! swap moves until no single exchange improves f by more than ε.

use super::{random_greedy::RandomGreedy, Maximizer, RunResult};
use crate::constraints::Constraint;
use crate::objective::SubmodularFn;
use crate::util::rng::Rng;

/// Swap-improvement local search seeded by RandomGreedy.
pub struct LocalSearch {
    /// Minimum relative improvement to accept a swap.
    pub eps: f64,
    /// Cap on improvement sweeps (each sweep is O(k·n) evals).
    pub max_sweeps: usize,
}

impl Default for LocalSearch {
    fn default() -> Self {
        LocalSearch { eps: 1e-6, max_sweeps: 8 }
    }
}

impl Maximizer for LocalSearch {
    fn maximize(
        &self,
        f: &dyn SubmodularFn,
        ground: &[usize],
        constraint: &dyn Constraint,
        rng: &mut Rng,
    ) -> RunResult {
        let seed = RandomGreedy.maximize(f, ground, constraint, rng);
        let mut solution = seed.solution;
        let mut value = seed.value;
        let mut oracle_calls = seed.oracle_calls;

        for _sweep in 0..self.max_sweeps {
            let mut improved = false;
            // Try replacing each member with each outside element.
            'outer: for pos in 0..solution.len() {
                for &cand in ground {
                    if solution.contains(&cand) {
                        continue;
                    }
                    let mut trial = solution.clone();
                    trial[pos] = cand;
                    if !constraint.is_feasible(&trial) {
                        continue;
                    }
                    let v = f.eval(&trial);
                    oracle_calls += 1;
                    if v > value * (1.0 + self.eps) + 1e-15 {
                        solution = trial;
                        value = v;
                        improved = true;
                        continue 'outer;
                    }
                }
            }
            if !improved {
                break;
            }
        }

        RunResult { solution, value, oracle_calls }
    }

    fn name(&self) -> &'static str {
        "local_search"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraints::cardinality::Cardinality;
    use crate::data::graph::social_network;
    use crate::objective::cut::GraphCut;
    use std::sync::Arc;

    #[test]
    fn never_worse_than_seed() {
        let g = Arc::new(social_network(40, 250, 4));
        let f = GraphCut::new(&g);
        let ground: Vec<usize> = (0..40).collect();
        let c = Cardinality::new(8);
        for seed in 0..5 {
            let mut r1 = Rng::new(seed);
            let mut r2 = Rng::new(seed);
            let base = RandomGreedy.maximize(&f, &ground, &c, &mut r1);
            let ls = LocalSearch::default().maximize(&f, &ground, &c, &mut r2);
            assert!(ls.value >= base.value - 1e-9, "{} < {}", ls.value, base.value);
        }
    }

    #[test]
    fn output_feasible() {
        let g = Arc::new(social_network(30, 150, 5));
        let f = GraphCut::new(&g);
        let c = Cardinality::new(6);
        let mut rng = Rng::new(1);
        let r = LocalSearch::default().maximize(&f, &(0..30).collect::<Vec<_>>(), &c, &mut rng);
        assert!(r.solution.len() <= 6);
        assert!((f.eval(&r.solution) - r.value).abs() < 1e-9);
    }
}
