//! Single-machine maximization algorithms — the "black box X" of the
//! paper's Algorithm 3, and the standard greedy used by Algorithm 2.
//!
//! All algorithms operate on an arbitrary [`SubmodularFn`] through its
//! incremental [`State`](crate::objective::State), restricted to an explicit
//! ground slice (a machine's shard), under an arbitrary hereditary
//! [`Constraint`]. They report oracle-call counts, which drive the paper's
//! speedup analysis (Fig. 8).

pub mod cost_benefit;
pub mod greedy;
pub mod lazy;
pub mod local_search;
pub mod random_greedy;
pub mod sieve_streaming;
pub mod stochastic;

use crate::constraints::Constraint;
use crate::objective::SubmodularFn;
use crate::util::rng::Rng;

/// Outcome of a single-machine maximization run.
#[derive(Debug, Clone, Default)]
pub struct RunResult {
    /// Selected elements in selection order.
    pub solution: Vec<usize>,
    /// f(solution) as tracked incrementally.
    pub value: f64,
    /// Number of marginal-gain oracle evaluations issued.
    pub oracle_calls: u64,
}

/// A submodular maximization algorithm (the paper's black box `X`).
pub trait Maximizer: Sync {
    /// Maximize `f` over `ground` subject to `constraint`.
    fn maximize(
        &self,
        f: &dyn SubmodularFn,
        ground: &[usize],
        constraint: &dyn Constraint,
        rng: &mut Rng,
    ) -> RunResult;

    /// Maximize with `threads` OS threads available to the *oracle layer*:
    /// algorithms that batch their pricing route candidate evaluation
    /// through [`State::par_batch_gains`](crate::objective::State), whose
    /// contract guarantees bit-identical results at any thread count — so
    /// `maximize_threaded(.., t)` returns exactly `maximize(..)` for every
    /// `t`, only faster. Default: ignore the hint (serial algorithms).
    fn maximize_threaded(
        &self,
        f: &dyn SubmodularFn,
        ground: &[usize],
        constraint: &dyn Constraint,
        rng: &mut Rng,
        threads: usize,
    ) -> RunResult {
        let _ = threads;
        self.maximize(f, ground, constraint, rng)
    }

    /// Short identifier for reports.
    fn name(&self) -> &'static str;
}

/// Resolve an algorithm by name (config files / CLI).
pub fn by_name(name: &str) -> Option<Box<dyn Maximizer + Send>> {
    match name {
        "greedy" => Some(Box::new(greedy::Greedy)),
        "lazy" => Some(Box::new(lazy::LazyGreedy)),
        "stochastic" => Some(Box::new(stochastic::StochasticGreedy::default())),
        "random_greedy" => Some(Box::new(random_greedy::RandomGreedy)),
        "cost_benefit" => Some(Box::new(cost_benefit::CostBenefitGreedy::plain())),
        "sieve_streaming" => Some(Box::new(sieve_streaming::SieveStreaming::default())),
        "local_search" => Some(Box::new(local_search::LocalSearch::default())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_resolves_known() {
        for n in [
            "greedy",
            "lazy",
            "stochastic",
            "random_greedy",
            "cost_benefit",
            "local_search",
            "sieve_streaming",
        ] {
            assert!(by_name(n).is_some(), "{n}");
            assert_eq!(by_name(n).unwrap().name(), n);
        }
        assert!(by_name("nope").is_none());
    }
}
