//! The standard greedy algorithm (Nemhauser et al. 1978): iteratively add
//! the feasible element with the largest marginal gain. (1−1/e) for
//! monotone + cardinality; 1/(p+1) for p-systems (Fisher et al. 1978).
//!
//! For monotone objectives the loop stops when no feasible element has a
//! positive gain; the generalized matroid greedy continues while *any*
//! feasible element exists only if gains are non-negative (equivalent here
//! because committing a zero-gain element never hurts a monotone f — we
//! stop instead, which only shortens solutions without lowering value).

use super::{Maximizer, RunResult};
use crate::constraints::Constraint;
use crate::objective::SubmodularFn;
use crate::util::rng::Rng;

/// Naive O(n·k) greedy with batched gain evaluation.
pub struct Greedy;

impl Maximizer for Greedy {
    fn maximize(
        &self,
        f: &dyn SubmodularFn,
        ground: &[usize],
        constraint: &dyn Constraint,
        rng: &mut Rng,
    ) -> RunResult {
        self.maximize_threaded(f, ground, constraint, rng, 1)
    }

    fn maximize_threaded(
        &self,
        f: &dyn SubmodularFn,
        ground: &[usize],
        constraint: &dyn Constraint,
        rng: &mut Rng,
        threads: usize,
    ) -> RunResult {
        let _ = rng;
        let mut state = f.state();
        let mut remaining: Vec<usize> = ground.to_vec();
        let oracle_calls =
            greedy_loop(f, state.as_mut(), &mut remaining, constraint, threads, None);
        RunResult {
            value: state.value(),
            solution: state.selected().to_vec(),
            oracle_calls,
        }
    }

    fn name(&self) -> &'static str {
        "greedy"
    }
}

/// The greedy selection loop, shared by [`Greedy`] and [`greedy_resumed`]:
/// commit up to `max_picks` further elements (`None` = until natural
/// termination) onto `state`, consuming winners from `remaining`. Returns
/// the oracle calls issued. The loop is memoryless in (selected set,
/// remaining set) — each round's winner is a pure function of those two
/// sets, with ties broken toward the smallest element id and candidate
/// gains priced independently — so running it in two installments is
/// bit-identical to one uninterrupted run (the `Resume` recovery contract).
fn greedy_loop<'b>(
    f: &dyn SubmodularFn,
    state: &mut (dyn crate::objective::State + 'b),
    remaining: &mut Vec<usize>,
    constraint: &dyn Constraint,
    threads: usize,
    max_picks: Option<usize>,
) -> u64 {
    let mut oracle_calls = 0u64;
    let mut picks = 0usize;
    // Reusable feasibility buffers for the whole run (perf: the old
    // per-round `collect` + O(n) `retain` were measurable on large
    // shards). `feasible_pos` records each candidate's index in
    // `remaining` during the scan, so the winner leaves via a true O(1)
    // `swap_remove` — no relocation scan. Selection itself is
    // order-independent: ties break on element id, never on position.
    let mut feasible: Vec<usize> = Vec::with_capacity(remaining.len());
    let mut feasible_pos: Vec<usize> = Vec::with_capacity(remaining.len());

    while max_picks.map(|cap| picks < cap).unwrap_or(true) {
        // feasible candidates under the current prefix
        feasible.clear();
        feasible_pos.clear();
        for (pos, &e) in remaining.iter().enumerate() {
            if constraint.can_add(state.selected(), e) {
                feasible.push(e);
                feasible_pos.push(pos);
            }
        }
        if feasible.is_empty() {
            break;
        }
        let gains = state.par_batch_gains(&feasible, threads);
        oracle_calls += feasible.len() as u64;
        // Ties broken toward the smallest element id — keeps plain and
        // lazy greedy bit-identical (they must agree up to ties).
        let (best_idx, &best_gain) = gains
            .iter()
            .enumerate()
            .max_by(|(ia, ga), (ib, gb)| {
                ga.partial_cmp(gb)
                    .unwrap()
                    .then_with(|| feasible[*ib].cmp(&feasible[*ia]))
            })
            .unwrap();
        if best_gain <= 0.0 && f.is_monotone() {
            break; // nothing improves a monotone objective
        }
        if best_gain < 0.0 {
            break; // non-monotone: never commit a strictly negative gain
        }
        let chosen = feasible[best_idx];
        state.push(chosen);
        picks += 1;
        // `remaining` has not moved since the scan, so the recorded
        // position is still the winner's slot.
        remaining.swap_remove(feasible_pos[best_idx]);
    }
    oracle_calls
}

/// Outcome of a greedy run recovered through a prefix checkpoint.
#[derive(Debug, Clone)]
pub struct ResumedGreedy {
    /// Final result — solution and value bit-identical to the
    /// uninterrupted run (and `oracle_calls` too whenever the checkpoint
    /// landed strictly before natural termination).
    pub result: RunResult,
    /// Picks salvaged from the checkpoint (not re-selected by recovery).
    pub salvaged_picks: usize,
    /// Picks the recovery actually re-ran after the checkpoint.
    pub replayed_picks: usize,
}

/// Run greedy as if the machine crashed after committing `ckpt_picks`
/// selections and recovered from its durable prefix checkpoint: the
/// prefix phase models the pre-crash work (a checkpoint is just the
/// selected prefix, in commit order), the restore replays that prefix onto
/// a fresh state with at most `k` pushes — no re-pricing of any candidate
/// round — and the continuation finishes the selection. Because the greedy
/// round winner is a pure function of (selected set, remaining set), the
/// recovered solution and value are **bit-identical** to an uninterrupted
/// [`Greedy::maximize_threaded`] run, which `RecoveryPolicy::Resume`
/// relies on for the greedi/multiround map stages.
pub fn greedy_resumed(
    f: &dyn SubmodularFn,
    ground: &[usize],
    constraint: &dyn Constraint,
    threads: usize,
    ckpt_picks: usize,
) -> ResumedGreedy {
    // Pre-crash prefix: what the dead machine committed and snapshot.
    let mut state = f.state();
    let mut remaining: Vec<usize> = ground.to_vec();
    let mut oracle_calls = greedy_loop(
        f,
        state.as_mut(),
        &mut remaining,
        constraint,
        threads,
        Some(ckpt_picks),
    );
    let prefix: Vec<usize> = state.selected().to_vec();
    drop(state); // the machine is gone; only the durable prefix survives

    // Restore: replay the prefix onto a fresh state (≤ k pushes), then
    // continue the selection to natural termination.
    let mut state = f.state();
    for &e in &prefix {
        state.push(e);
    }
    let chosen: std::collections::HashSet<usize> = prefix.iter().copied().collect();
    let mut remaining: Vec<usize> =
        ground.iter().copied().filter(|e| !chosen.contains(e)).collect();
    oracle_calls +=
        greedy_loop(f, state.as_mut(), &mut remaining, constraint, threads, None);
    let solution = state.selected().to_vec();
    let replayed_picks = solution.len() - prefix.len();
    ResumedGreedy {
        result: RunResult { value: state.value(), solution, oracle_calls },
        salvaged_picks: prefix.len(),
        replayed_picks,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraints::cardinality::Cardinality;
    use crate::constraints::knapsack::Knapsack;
    use crate::constraints::matroid::PartitionMatroid;
    use crate::objective::modular::Modular;
    use crate::objective::coverage::Coverage;
    use crate::data::transactions::zipf_transactions;
    use std::sync::Arc;

    #[test]
    fn modular_greedy_is_optimal() {
        let f = Modular::new(vec![3.0, 1.0, 4.0, 1.0, 5.0]);
        let ground: Vec<usize> = (0..5).collect();
        let mut rng = Rng::new(0);
        let r = Greedy.maximize(&f, &ground, &Cardinality::new(2), &mut rng);
        assert_eq!(r.value, 9.0); // 5 + 4
        assert_eq!(r.solution.len(), 2);
    }

    #[test]
    fn respects_cardinality() {
        let td = Arc::new(zipf_transactions(30, 50, 6, 1.1, 2));
        let f = Coverage::new(&td);
        let ground: Vec<usize> = (0..30).collect();
        let mut rng = Rng::new(0);
        let r = Greedy.maximize(&f, &ground, &Cardinality::new(5), &mut rng);
        assert!(r.solution.len() <= 5);
        assert!((r.value - f.eval(&r.solution)).abs() < 1e-9);
    }

    #[test]
    fn respects_matroid() {
        // categories alternate; capacity 1 each => at most one even, one odd id
        let f = Modular::new(vec![1.0, 10.0, 2.0, 20.0]);
        let m = PartitionMatroid::new(vec![0, 1, 0, 1], vec![1, 1]);
        let mut rng = Rng::new(0);
        let r = Greedy.maximize(&f, &[0, 1, 2, 3], &m, &mut rng);
        assert_eq!(r.value, 22.0); // 20 (cat 1) + 2 (cat 0)
        assert!(m.is_feasible(&r.solution));
    }

    #[test]
    fn respects_knapsack() {
        let f = Modular::new(vec![5.0, 4.0, 3.0]);
        let k = Knapsack::new(vec![3.0, 2.0, 2.0], 4.0);
        let mut rng = Rng::new(0);
        let r = Greedy.maximize(&f, &[0, 1, 2], &k, &mut rng);
        assert!(k.is_feasible(&r.solution));
        // greedy takes 0 (5.0, cost 3) then nothing fits except... cost left 1
        assert_eq!(r.value, 5.0);
    }

    #[test]
    fn ground_restriction_respected() {
        let f = Modular::new(vec![100.0, 1.0, 2.0]);
        let mut rng = Rng::new(0);
        let r = Greedy.maximize(&f, &[1, 2], &Cardinality::new(1), &mut rng);
        assert_eq!(r.solution, vec![2]); // 0 not in ground
    }

    #[test]
    fn oracle_calls_counted() {
        let f = Modular::new(vec![1.0; 10]);
        let mut rng = Rng::new(0);
        let r = Greedy.maximize(&f, &(0..10).collect::<Vec<_>>(), &Cardinality::new(3), &mut rng);
        // 10 + 9 + 8 gains... plus the terminating round (7) if gains stay > 0:
        // all weights 1 so three rounds then k reached: 10+9+8 = 27
        assert_eq!(r.oracle_calls, 27);
    }

    #[test]
    fn resumed_greedy_bit_identical_to_uninterrupted() {
        let td = Arc::new(zipf_transactions(40, 60, 6, 1.1, 2));
        let f = Coverage::new(&td);
        let ground: Vec<usize> = (0..40).rev().collect();
        let k = Cardinality::new(8);
        let mut rng = Rng::new(0);
        let full = Greedy.maximize_threaded(&f, &ground, &k, &mut rng, 1);
        assert!(!full.solution.is_empty());
        for ckpt in [0usize, 1, 3, 5, 8, 20] {
            let resumed = greedy_resumed(&f, &ground, &k, 1, ckpt);
            assert_eq!(resumed.result.solution, full.solution, "ckpt={ckpt}");
            assert_eq!(
                resumed.result.value.to_bits(),
                full.value.to_bits(),
                "ckpt={ckpt}"
            );
            assert_eq!(resumed.salvaged_picks, ckpt.min(full.solution.len()));
            assert_eq!(
                resumed.salvaged_picks + resumed.replayed_picks,
                full.solution.len()
            );
            if ckpt < full.solution.len() {
                assert_eq!(
                    resumed.result.oracle_calls, full.oracle_calls,
                    "ckpt={ckpt}: mid-run checkpoints keep even the call count"
                );
            }
        }
    }

    #[test]
    fn resumed_greedy_matches_lazy_greedy_selection() {
        // protocols run `lazy` by default; resume replays via the plain
        // greedy loop, which is pinned bit-identical to lazy up to ties
        use crate::algorithms::lazy::LazyGreedy;
        let td = Arc::new(zipf_transactions(50, 80, 6, 1.2, 9));
        let f = Coverage::new(&td);
        let ground: Vec<usize> = (0..50).collect();
        let k = Cardinality::new(6);
        let mut rng = Rng::new(0);
        let lazy = LazyGreedy.maximize_threaded(&f, &ground, &k, &mut rng, 1);
        let resumed = greedy_resumed(&f, &ground, &k, 1, 3);
        assert_eq!(resumed.result.solution, lazy.solution);
        assert_eq!(resumed.result.value.to_bits(), lazy.value.to_bits());
    }

    #[test]
    fn nemhauser_bound_on_coverage() {
        // (1 - 1/e) ≈ 0.632 of optimum; verify against brute force on a
        // small instance.
        let td = Arc::new(zipf_transactions(12, 30, 5, 1.0, 5));
        let f = Coverage::new(&td);
        let ground: Vec<usize> = (0..12).collect();
        let k = 3;
        // brute force optimum
        let mut opt = 0.0f64;
        for a in 0..12 {
            for b in (a + 1)..12 {
                for c in (b + 1)..12 {
                    opt = opt.max(f.eval(&[a, b, c]));
                }
            }
        }
        let mut rng = Rng::new(0);
        let r = Greedy.maximize(&f, &ground, &Cardinality::new(k), &mut rng);
        assert!(
            r.value >= (1.0 - (-1.0f64).exp()) * opt - 1e-9,
            "greedy {} < 0.632 * {opt}",
            r.value
        );
    }
}
