//! The standard greedy algorithm (Nemhauser et al. 1978): iteratively add
//! the feasible element with the largest marginal gain. (1−1/e) for
//! monotone + cardinality; 1/(p+1) for p-systems (Fisher et al. 1978).
//!
//! For monotone objectives the loop stops when no feasible element has a
//! positive gain; the generalized matroid greedy continues while *any*
//! feasible element exists only if gains are non-negative (equivalent here
//! because committing a zero-gain element never hurts a monotone f — we
//! stop instead, which only shortens solutions without lowering value).

use super::{Maximizer, RunResult};
use crate::constraints::Constraint;
use crate::objective::SubmodularFn;
use crate::util::rng::Rng;

/// Naive O(n·k) greedy with batched gain evaluation.
pub struct Greedy;

impl Maximizer for Greedy {
    fn maximize(
        &self,
        f: &dyn SubmodularFn,
        ground: &[usize],
        constraint: &dyn Constraint,
        rng: &mut Rng,
    ) -> RunResult {
        self.maximize_threaded(f, ground, constraint, rng, 1)
    }

    fn maximize_threaded(
        &self,
        f: &dyn SubmodularFn,
        ground: &[usize],
        constraint: &dyn Constraint,
        rng: &mut Rng,
        threads: usize,
    ) -> RunResult {
        let _ = rng;
        let mut state = f.state();
        let mut oracle_calls = 0u64;
        let mut remaining: Vec<usize> = ground.to_vec();
        // Reusable feasibility buffers for the whole run (perf: the old
        // per-round `collect` + O(n) `retain` were measurable on large
        // shards). `feasible_pos` records each candidate's index in
        // `remaining` during the scan, so the winner leaves via a true O(1)
        // `swap_remove` — no relocation scan. Selection itself is
        // order-independent: ties break on element id, never on position.
        let mut feasible: Vec<usize> = Vec::with_capacity(remaining.len());
        let mut feasible_pos: Vec<usize> = Vec::with_capacity(remaining.len());

        loop {
            // feasible candidates under the current prefix
            feasible.clear();
            feasible_pos.clear();
            for (pos, &e) in remaining.iter().enumerate() {
                if constraint.can_add(state.selected(), e) {
                    feasible.push(e);
                    feasible_pos.push(pos);
                }
            }
            if feasible.is_empty() {
                break;
            }
            let gains = state.par_batch_gains(&feasible, threads);
            oracle_calls += feasible.len() as u64;
            // Ties broken toward the smallest element id — keeps plain and
            // lazy greedy bit-identical (they must agree up to ties).
            let (best_idx, &best_gain) = gains
                .iter()
                .enumerate()
                .max_by(|(ia, ga), (ib, gb)| {
                    ga.partial_cmp(gb)
                        .unwrap()
                        .then_with(|| feasible[*ib].cmp(&feasible[*ia]))
                })
                .unwrap();
            if best_gain <= 0.0 && f.is_monotone() {
                break; // nothing improves a monotone objective
            }
            if best_gain < 0.0 {
                break; // non-monotone: never commit a strictly negative gain
            }
            let chosen = feasible[best_idx];
            state.push(chosen);
            // `remaining` has not moved since the scan, so the recorded
            // position is still the winner's slot.
            remaining.swap_remove(feasible_pos[best_idx]);
        }

        RunResult {
            value: state.value(),
            solution: state.selected().to_vec(),
            oracle_calls,
        }
    }

    fn name(&self) -> &'static str {
        "greedy"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraints::cardinality::Cardinality;
    use crate::constraints::knapsack::Knapsack;
    use crate::constraints::matroid::PartitionMatroid;
    use crate::objective::modular::Modular;
    use crate::objective::coverage::Coverage;
    use crate::data::transactions::zipf_transactions;
    use std::sync::Arc;

    #[test]
    fn modular_greedy_is_optimal() {
        let f = Modular::new(vec![3.0, 1.0, 4.0, 1.0, 5.0]);
        let ground: Vec<usize> = (0..5).collect();
        let mut rng = Rng::new(0);
        let r = Greedy.maximize(&f, &ground, &Cardinality::new(2), &mut rng);
        assert_eq!(r.value, 9.0); // 5 + 4
        assert_eq!(r.solution.len(), 2);
    }

    #[test]
    fn respects_cardinality() {
        let td = Arc::new(zipf_transactions(30, 50, 6, 1.1, 2));
        let f = Coverage::new(&td);
        let ground: Vec<usize> = (0..30).collect();
        let mut rng = Rng::new(0);
        let r = Greedy.maximize(&f, &ground, &Cardinality::new(5), &mut rng);
        assert!(r.solution.len() <= 5);
        assert!((r.value - f.eval(&r.solution)).abs() < 1e-9);
    }

    #[test]
    fn respects_matroid() {
        // categories alternate; capacity 1 each => at most one even, one odd id
        let f = Modular::new(vec![1.0, 10.0, 2.0, 20.0]);
        let m = PartitionMatroid::new(vec![0, 1, 0, 1], vec![1, 1]);
        let mut rng = Rng::new(0);
        let r = Greedy.maximize(&f, &[0, 1, 2, 3], &m, &mut rng);
        assert_eq!(r.value, 22.0); // 20 (cat 1) + 2 (cat 0)
        assert!(m.is_feasible(&r.solution));
    }

    #[test]
    fn respects_knapsack() {
        let f = Modular::new(vec![5.0, 4.0, 3.0]);
        let k = Knapsack::new(vec![3.0, 2.0, 2.0], 4.0);
        let mut rng = Rng::new(0);
        let r = Greedy.maximize(&f, &[0, 1, 2], &k, &mut rng);
        assert!(k.is_feasible(&r.solution));
        // greedy takes 0 (5.0, cost 3) then nothing fits except... cost left 1
        assert_eq!(r.value, 5.0);
    }

    #[test]
    fn ground_restriction_respected() {
        let f = Modular::new(vec![100.0, 1.0, 2.0]);
        let mut rng = Rng::new(0);
        let r = Greedy.maximize(&f, &[1, 2], &Cardinality::new(1), &mut rng);
        assert_eq!(r.solution, vec![2]); // 0 not in ground
    }

    #[test]
    fn oracle_calls_counted() {
        let f = Modular::new(vec![1.0; 10]);
        let mut rng = Rng::new(0);
        let r = Greedy.maximize(&f, &(0..10).collect::<Vec<_>>(), &Cardinality::new(3), &mut rng);
        // 10 + 9 + 8 gains... plus the terminating round (7) if gains stay > 0:
        // all weights 1 so three rounds then k reached: 10+9+8 = 27
        assert_eq!(r.oracle_calls, 27);
    }

    #[test]
    fn nemhauser_bound_on_coverage() {
        // (1 - 1/e) ≈ 0.632 of optimum; verify against brute force on a
        // small instance.
        let td = Arc::new(zipf_transactions(12, 30, 5, 1.0, 5));
        let f = Coverage::new(&td);
        let ground: Vec<usize> = (0..12).collect();
        let k = 3;
        // brute force optimum
        let mut opt = 0.0f64;
        for a in 0..12 {
            for b in (a + 1)..12 {
                for c in (b + 1)..12 {
                    opt = opt.max(f.eval(&[a, b, c]));
                }
            }
        }
        let mut rng = Rng::new(0);
        let r = Greedy.maximize(&f, &ground, &Cardinality::new(k), &mut rng);
        assert!(
            r.value >= (1.0 - (-1.0f64).exp()) * opt - 1e-9,
            "greedy {} < 0.632 * {opt}",
            r.value
        );
    }
}
