//! Lazy greedy (Minoux 1978) — the accelerated greedy the paper actually
//! runs inside each Hadoop reducer (§6.1: "performed the lazy greedy
//! algorithm on its own set of 10,000 images").
//!
//! Submodularity makes cached marginal gains upper bounds after the
//! solution grows; a max-heap of stale bounds re-evaluates the top
//! candidates until one is *fresh*, typically cutting oracle calls from
//! O(n·k) to roughly O(n + k·log n) on benign data. Exact same output as
//! plain greedy (up to ties).
//!
//! ## Perf pass §A, iteration 5: batch repricing
//!
//! The classic formulation reprices ONE stale heap entry per oracle call,
//! which starves any batched/parallel gain backend — the oracle never sees
//! more than one candidate at a time. This implementation pops a *block* of
//! stale entries and reprices them with a single
//! [`State::par_batch_gains`](crate::objective::State) call; the winner
//! commits only when its *fresh* bound resurfaces at the top of the heap,
//! so the selected set is bit-identical to plain greedy (and to the
//! one-at-a-time lazy variant) up to ties, at any thread count.
//!
//! ## Perf pass §B: adaptive reprice block
//!
//! A fixed `B = 16` (the PR-2 sweep winner) overpays on easy instances —
//! on benign data the classic variant refreshes only a handful of entries
//! per commit, so most of a wide block is speculative oracle work the lazy
//! heap existed to avoid — and underpays on adversarial ones, where the
//! top of the heap stays stale for many consecutive reprice rounds and a
//! narrow block starves the batched engine. The block width now *adapts to
//! the observed fresh-hit sequence and nothing else*:
//!
//! * start at [`MIN_REPRICE_BLOCK`];
//! * **grow** (double, capped at [`MAX_REPRICE_BLOCK`]) when a reprice
//!   round is followed by another reprice round with no commit in between
//!   — the freshly priced bounds failed to reach the top, so the heap is
//!   churning and wider batches amortize better;
//! * **shrink** (halve, floored at [`MIN_REPRICE_BLOCK`]) after every
//!   commit — the heap is settling and narrow blocks waste less.
//!
//! The fresh/stale pop sequence is a pure function of the cached bounds,
//! which are bit-identical at every thread count (the gain engine's
//! contract), so the block trajectory — and with it the reported
//! oracle-call count — stays **thread-invariant**: the width never reads
//! the thread count, pool size, or any timing. Selection is untouched (a
//! winner still commits only when its *fresh* bound resurfaces at the top),
//! so lazy == greedy bit-identically up to ties, exactly as before. Note
//! the parallel payoff depends on the objective's shard shape
//! (`objective::engine::ShardSpec`): window-sharded objectives (facility
//! location) fan their window out for any batch width; cheap
//! candidate-sharded objectives (coverage, cut, modular, entropy) price
//! narrow batches serially by design — their per-candidate work is far too
//! small to amortize a fan-out (`engine::MIN_CANDIDATES_PER_SHARD`), and
//! their parallel win comes from the wide initial full-ground pass instead
//! — while the heavy Cholesky objectives (info-gain, DPP) shard even
//! narrow reprice blocks (`engine::MIN_HEAVY_CANDIDATES_PER_SHARD`).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::{Maximizer, RunResult};
use crate::constraints::Constraint;
use crate::objective::SubmodularFn;
use crate::util::rng::Rng;

/// Smallest (and initial) reprice block — also the post-commit reset floor.
const MIN_REPRICE_BLOCK: usize = 4;

/// Widest reprice block the stale-streak doubling may reach.
const MAX_REPRICE_BLOCK: usize = 64;

/// Heap entry: cached upper bound for an element, stamped with the solution
/// size at which it was computed.
struct Entry {
    bound: f64,
    element: usize,
    stamp: usize,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound && self.element == other.element
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // max-heap by bound; ties broken by element id for determinism
        self.bound
            .partial_cmp(&other.bound)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.element.cmp(&self.element))
    }
}

/// Lazy (accelerated) greedy with batch repricing.
pub struct LazyGreedy;

impl Maximizer for LazyGreedy {
    fn maximize(
        &self,
        f: &dyn SubmodularFn,
        ground: &[usize],
        constraint: &dyn Constraint,
        rng: &mut Rng,
    ) -> RunResult {
        self.maximize_threaded(f, ground, constraint, rng, 1)
    }

    fn maximize_threaded(
        &self,
        f: &dyn SubmodularFn,
        ground: &[usize],
        constraint: &dyn Constraint,
        rng: &mut Rng,
        threads: usize,
    ) -> RunResult {
        let _ = rng;
        let mut state = f.state();
        let mut oracle_calls = 0u64;

        // Initial pass: gains w.r.t. the empty set (one wide batch — this is
        // where the parallel gain engine earns most of its keep).
        let gains = state.par_batch_gains(ground, threads);
        oracle_calls += ground.len() as u64;
        let mut heap: BinaryHeap<Entry> = ground
            .iter()
            .zip(gains)
            .map(|(&e, g)| Entry { bound: g, element: e, stamp: 0 })
            .collect();

        let mut round = 0usize;
        let mut batch: Vec<usize> = Vec::with_capacity(MAX_REPRICE_BLOCK);
        // Adaptive block width, driven ONLY by the fresh/stale pop sequence
        // (module docs) — never by the thread count, so oracle-call metrics
        // stay thread-invariant.
        let mut block = MIN_REPRICE_BLOCK;
        let mut repriced_since_commit = false;
        while let Some(top) = heap.pop() {
            if !constraint.can_add(state.selected(), top.element) {
                // infeasible *now*; it can become feasible again only for
                // non-cardinality systems after... never (hereditary +
                // growing prefix => once blocked, always blocked).
                continue;
            }
            if top.stamp == round {
                // Fresh bound — it is the true current gain and it beats
                // every other upper bound: commit.
                if top.bound <= 0.0 && f.is_monotone() {
                    break;
                }
                if top.bound < 0.0 {
                    break;
                }
                state.push(top.element);
                round += 1;
                block = (block / 2).max(MIN_REPRICE_BLOCK);
                repriced_since_commit = false;
                continue;
            }
            // Stale: batch-reprice. A stale top right after a reprice means
            // the fresh bounds failed to surface — widen; a commit between
            // reprices resets the streak (and halved the block above).
            if repriced_since_commit {
                block = (block * 2).min(MAX_REPRICE_BLOCK);
            }
            // Collect up to `block` stale feasible entries from the top of
            // the heap (stopping at the first fresh one — its bound is
            // already exact), price them all with ONE batched call, and
            // push the fresh bounds back. The winner commits on a later pop
            // iff its fresh bound still tops the heap.
            batch.clear();
            batch.push(top.element);
            while batch.len() < block {
                match heap.peek() {
                    Some(next) if next.stamp != round => {
                        let next = heap.pop().expect("peeked entry");
                        if constraint.can_add(state.selected(), next.element) {
                            batch.push(next.element);
                        }
                        // infeasible entries drop here exactly as they would
                        // have dropped on their own pop (heredity).
                    }
                    _ => break,
                }
            }
            let fresh = state.par_batch_gains(&batch, threads);
            oracle_calls += batch.len() as u64;
            for (&e, &g) in batch.iter().zip(fresh.iter()) {
                heap.push(Entry { bound: g, element: e, stamp: round });
            }
            repriced_since_commit = true;
        }

        RunResult {
            value: state.value(),
            solution: state.selected().to_vec(),
            oracle_calls,
        }
    }

    fn name(&self) -> &'static str {
        "lazy"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::greedy::Greedy;
    use crate::constraints::cardinality::Cardinality;
    use crate::data::graph::social_network;
    use crate::data::synth::{gaussian_blobs, SynthConfig};
    use crate::data::transactions::zipf_transactions;
    use crate::objective::coverage::Coverage;
    use crate::objective::cut::GraphCut;
    use crate::objective::facility::FacilityLocation;
    use crate::objective::modular::Modular;
    use std::sync::Arc;

    #[test]
    fn matches_plain_greedy_on_coverage() {
        let td = Arc::new(zipf_transactions(60, 80, 7, 1.1, 3));
        let f = Coverage::new(&td);
        let ground: Vec<usize> = (0..60).collect();
        let c = Cardinality::new(8);
        let mut rng = Rng::new(0);
        let a = Greedy.maximize(&f, &ground, &c, &mut rng);
        let b = LazyGreedy.maximize(&f, &ground, &c, &mut rng);
        assert!((a.value - b.value).abs() < 1e-9, "{} vs {}", a.value, b.value);
    }

    #[test]
    fn matches_plain_greedy_on_facility() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(80, 8), 21));
        let f = FacilityLocation::from_dataset(&ds);
        let ground: Vec<usize> = (0..80).collect();
        let c = Cardinality::new(6);
        let mut rng = Rng::new(0);
        let a = Greedy.maximize(&f, &ground, &c, &mut rng);
        let b = LazyGreedy.maximize(&f, &ground, &c, &mut rng);
        assert!((a.value - b.value).abs() < 1e-6, "{} vs {}", a.value, b.value);
    }

    #[test]
    fn solutions_bit_identical_to_plain_greedy_all_objectives() {
        let mut rng = Rng::new(0);
        // facility
        {
            let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(150, 8), 23));
            let f = FacilityLocation::from_dataset(&ds);
            let ground: Vec<usize> = (0..150).collect();
            let c = Cardinality::new(9);
            let a = Greedy.maximize(&f, &ground, &c, &mut rng);
            let b = LazyGreedy.maximize(&f, &ground, &c, &mut rng);
            assert_eq!(a.solution, b.solution, "facility");
        }
        // coverage
        {
            let td = Arc::new(zipf_transactions(120, 150, 7, 1.1, 5));
            let f = Coverage::new(&td);
            let ground: Vec<usize> = (0..120).collect();
            let c = Cardinality::new(10);
            let a = Greedy.maximize(&f, &ground, &c, &mut rng);
            let b = LazyGreedy.maximize(&f, &ground, &c, &mut rng);
            assert_eq!(a.solution, b.solution, "coverage");
        }
        // cut (non-monotone)
        {
            let g = Arc::new(social_network(90, 600, 3));
            let f = GraphCut::new(&g);
            let ground: Vec<usize> = (0..90).collect();
            let c = Cardinality::new(12);
            let a = Greedy.maximize(&f, &ground, &c, &mut rng);
            let b = LazyGreedy.maximize(&f, &ground, &c, &mut rng);
            assert_eq!(a.solution, b.solution, "cut");
        }
        // modular (every gain a constant — pure tie-break territory)
        {
            let f = Modular::new(vec![2.0, 5.0, 5.0, 1.0, 5.0, 3.0]);
            let ground: Vec<usize> = (0..6).collect();
            let c = Cardinality::new(4);
            let a = Greedy.maximize(&f, &ground, &c, &mut rng);
            let b = LazyGreedy.maximize(&f, &ground, &c, &mut rng);
            assert_eq!(a.solution, b.solution, "modular ties");
        }
    }

    #[test]
    fn threaded_solution_identical_to_serial() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(700, 8), 29));
        let f = FacilityLocation::from_dataset(&ds);
        let ground: Vec<usize> = (0..700).collect();
        let c = Cardinality::new(8);
        let mut rng = Rng::new(0);
        let serial = LazyGreedy.maximize_threaded(&f, &ground, &c, &mut rng, 1);
        for threads in [2usize, 8] {
            let par = LazyGreedy.maximize_threaded(&f, &ground, &c, &mut rng, threads);
            assert_eq!(serial.solution, par.solution, "threads={threads}");
            assert_eq!(serial.value, par.value, "threads={threads}");
            assert_eq!(serial.oracle_calls, par.oracle_calls, "threads={threads}");
        }
    }

    #[test]
    fn adaptive_block_deterministic_across_runs_and_threads() {
        // The block width derives only from the fresh/stale pop sequence,
        // so repeated runs AND different thread counts must agree on the
        // oracle-call count exactly (it is part of reported metrics).
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(400, 8), 31));
        let f = FacilityLocation::from_dataset(&ds);
        let ground: Vec<usize> = (0..400).collect();
        let c = Cardinality::new(12);
        let mut rng = Rng::new(0);
        let a = LazyGreedy.maximize_threaded(&f, &ground, &c, &mut rng, 1);
        let b = LazyGreedy.maximize_threaded(&f, &ground, &c, &mut rng, 1);
        assert_eq!(a.oracle_calls, b.oracle_calls);
        assert_eq!(a.solution, b.solution);
        for t in [2usize, 8] {
            let p = LazyGreedy.maximize_threaded(&f, &ground, &c, &mut rng, t);
            assert_eq!(a.oracle_calls, p.oracle_calls, "threads={t}");
            assert_eq!(a.solution, p.solution, "threads={t}");
        }
    }

    #[test]
    fn fewer_oracle_calls_than_plain() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(150, 8), 22));
        let f = FacilityLocation::from_dataset(&ds);
        let ground: Vec<usize> = (0..150).collect();
        let c = Cardinality::new(10);
        let mut rng = Rng::new(0);
        let a = Greedy.maximize(&f, &ground, &c, &mut rng);
        let b = LazyGreedy.maximize(&f, &ground, &c, &mut rng);
        assert!(
            b.oracle_calls < a.oracle_calls / 2,
            "lazy {} vs plain {}",
            b.oracle_calls,
            a.oracle_calls
        );
    }

    #[test]
    fn respects_budget() {
        let f = Modular::new(vec![1.0; 20]);
        let mut rng = Rng::new(0);
        let r = LazyGreedy.maximize(&f, &(0..20).collect::<Vec<_>>(), &Cardinality::new(4), &mut rng);
        assert_eq!(r.solution.len(), 4);
    }

    #[test]
    fn empty_ground() {
        let f = Modular::new(vec![1.0]);
        let mut rng = Rng::new(0);
        let r = LazyGreedy.maximize(&f, &[], &Cardinality::new(3), &mut rng);
        assert!(r.solution.is_empty());
        assert_eq!(r.value, 0.0);
    }
}
