//! Lazy greedy (Minoux 1978) — the accelerated greedy the paper actually
//! runs inside each Hadoop reducer (§6.1: "performed the lazy greedy
//! algorithm on its own set of 10,000 images").
//!
//! Submodularity makes cached marginal gains upper bounds after the
//! solution grows; a max-heap of stale bounds re-evaluates only the top
//! candidate until one is *fresh*, typically cutting oracle calls from
//! O(n·k) to roughly O(n + k·log n) on benign data. Exact same output as
//! plain greedy (up to ties).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::{Maximizer, RunResult};
use crate::constraints::Constraint;
use crate::objective::SubmodularFn;
use crate::util::rng::Rng;

/// Heap entry: cached upper bound for an element, stamped with the solution
/// size at which it was computed.
struct Entry {
    bound: f64,
    element: usize,
    stamp: usize,
}

impl PartialEq for Entry {
    fn eq(&self, other: &Self) -> bool {
        self.bound == other.bound && self.element == other.element
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // max-heap by bound; ties broken by element id for determinism
        self.bound
            .partial_cmp(&other.bound)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.element.cmp(&self.element))
    }
}

/// Lazy (accelerated) greedy.
pub struct LazyGreedy;

impl Maximizer for LazyGreedy {
    fn maximize(
        &self,
        f: &dyn SubmodularFn,
        ground: &[usize],
        constraint: &dyn Constraint,
        rng: &mut Rng,
    ) -> RunResult {
        let _ = rng;
        let mut state = f.state();
        let mut oracle_calls = 0u64;

        // Initial pass: gains w.r.t. the empty set.
        let gains = state.batch_gains(ground);
        oracle_calls += ground.len() as u64;
        let mut heap: BinaryHeap<Entry> = ground
            .iter()
            .zip(gains)
            .map(|(&e, g)| Entry { bound: g, element: e, stamp: 0 })
            .collect();

        let mut round = 0usize;
        while let Some(top) = heap.pop() {
            if !constraint.can_add(state.selected(), top.element) {
                // infeasible *now*; it can become feasible again only for
                // non-cardinality systems after... never (hereditary +
                // growing prefix => once blocked, always blocked).
                continue;
            }
            if top.stamp == round {
                // Fresh bound — it is the true current gain and it beats
                // every other upper bound: commit.
                if top.bound <= 0.0 && f.is_monotone() {
                    break;
                }
                if top.bound < 0.0 {
                    break;
                }
                state.push(top.element);
                round += 1;
                continue;
            }
            // Stale: re-price and re-insert.
            let g = state.gain(top.element);
            oracle_calls += 1;
            heap.push(Entry { bound: g, element: top.element, stamp: round });
        }

        RunResult {
            value: state.value(),
            solution: state.selected().to_vec(),
            oracle_calls,
        }
    }

    fn name(&self) -> &'static str {
        "lazy"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::greedy::Greedy;
    use crate::constraints::cardinality::Cardinality;
    use crate::data::synth::{gaussian_blobs, SynthConfig};
    use crate::data::transactions::zipf_transactions;
    use crate::objective::coverage::Coverage;
    use crate::objective::facility::FacilityLocation;
    use crate::objective::modular::Modular;
    use std::sync::Arc;

    #[test]
    fn matches_plain_greedy_on_coverage() {
        let td = Arc::new(zipf_transactions(60, 80, 7, 1.1, 3));
        let f = Coverage::new(&td);
        let ground: Vec<usize> = (0..60).collect();
        let c = Cardinality::new(8);
        let mut rng = Rng::new(0);
        let a = Greedy.maximize(&f, &ground, &c, &mut rng);
        let b = LazyGreedy.maximize(&f, &ground, &c, &mut rng);
        assert!((a.value - b.value).abs() < 1e-9, "{} vs {}", a.value, b.value);
    }

    #[test]
    fn matches_plain_greedy_on_facility() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(80, 8), 21));
        let f = FacilityLocation::from_dataset(&ds);
        let ground: Vec<usize> = (0..80).collect();
        let c = Cardinality::new(6);
        let mut rng = Rng::new(0);
        let a = Greedy.maximize(&f, &ground, &c, &mut rng);
        let b = LazyGreedy.maximize(&f, &ground, &c, &mut rng);
        assert!((a.value - b.value).abs() < 1e-6, "{} vs {}", a.value, b.value);
    }

    #[test]
    fn fewer_oracle_calls_than_plain() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(150, 8), 22));
        let f = FacilityLocation::from_dataset(&ds);
        let ground: Vec<usize> = (0..150).collect();
        let c = Cardinality::new(10);
        let mut rng = Rng::new(0);
        let a = Greedy.maximize(&f, &ground, &c, &mut rng);
        let b = LazyGreedy.maximize(&f, &ground, &c, &mut rng);
        assert!(
            b.oracle_calls < a.oracle_calls / 2,
            "lazy {} vs plain {}",
            b.oracle_calls,
            a.oracle_calls
        );
    }

    #[test]
    fn respects_budget() {
        let f = Modular::new(vec![1.0; 20]);
        let mut rng = Rng::new(0);
        let r = LazyGreedy.maximize(&f, &(0..20).collect::<Vec<_>>(), &Cardinality::new(4), &mut rng);
        assert_eq!(r.solution.len(), 4);
    }

    #[test]
    fn empty_ground() {
        let f = Modular::new(vec![1.0]);
        let mut rng = Rng::new(0);
        let r = LazyGreedy.maximize(&f, &[], &Cardinality::new(3), &mut rng);
        assert!(r.solution.is_empty());
        assert_eq!(r.value, 0.0);
    }
}
