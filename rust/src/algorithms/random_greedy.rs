//! RandomGreedy (Buchbinder et al. 2014) for **non-monotone** submodular
//! maximization under a cardinality constraint — the algorithm the paper
//! runs on each partition in the max-cut experiment (§6.3). Guarantee:
//! 1/e in expectation (and (1−1/e) when f happens to be monotone).
//!
//! Each of the k rounds computes all marginal gains, takes the set M of the
//! k highest (padding with dummy zero-gain slots when fewer than k remain),
//! and commits a uniformly random member of M; dummy draws skip the round.

use super::{Maximizer, RunResult};
use crate::constraints::Constraint;
use crate::objective::SubmodularFn;
use crate::util::rng::Rng;

/// Buchbinder et al.'s RandomGreedy.
pub struct RandomGreedy;

impl Maximizer for RandomGreedy {
    fn maximize(
        &self,
        f: &dyn SubmodularFn,
        ground: &[usize],
        constraint: &dyn Constraint,
        rng: &mut Rng,
    ) -> RunResult {
        let mut state = f.state();
        let mut oracle_calls = 0u64;
        let mut remaining: Vec<usize> = ground.to_vec();
        let k = constraint.rho();

        for _round in 0..k {
            let feasible: Vec<usize> = remaining
                .iter()
                .copied()
                .filter(|&e| constraint.can_add(state.selected(), e))
                .collect();
            if feasible.is_empty() {
                break;
            }
            let gains = state.batch_gains(&feasible);
            oracle_calls += feasible.len() as u64;

            // top-k gains (by value), clamping negatives to dummies
            let mut order: Vec<usize> = (0..feasible.len()).collect();
            order.sort_by(|&a, &b| gains[b].partial_cmp(&gains[a]).unwrap());
            let top: Vec<usize> = order.into_iter().take(k).collect();

            // M has exactly k slots: real candidates with positive gain,
            // plus dummies for the rest (Buchbinder et al.'s padding).
            let real: Vec<usize> = top
                .iter()
                .copied()
                .filter(|&i| gains[i] > 0.0)
                .collect();
            let slot = rng.below(k);
            if slot >= real.len() {
                continue; // drew a dummy (or a clamped negative): skip
            }
            let chosen = feasible[real[slot]];
            state.push(chosen);
            remaining.retain(|&e| e != chosen);
        }

        RunResult {
            value: state.value(),
            solution: state.selected().to_vec(),
            oracle_calls,
        }
    }

    fn name(&self) -> &'static str {
        "random_greedy"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constraints::cardinality::Cardinality;
    use crate::data::graph::social_network;
    use crate::objective::cut::GraphCut;
    use crate::objective::modular::Modular;
    use crate::util::stats::mean;
    use std::sync::Arc;

    #[test]
    fn never_exceeds_budget() {
        let g = Arc::new(social_network(60, 400, 1));
        let f = GraphCut::new(&g);
        let mut rng = Rng::new(1);
        let r = RandomGreedy.maximize(&f, &(0..60).collect::<Vec<_>>(), &Cardinality::new(10), &mut rng);
        assert!(r.solution.len() <= 10);
        assert!((r.value - f.eval(&r.solution)).abs() < 1e-9);
    }

    #[test]
    fn nonnegative_value_on_cut() {
        let g = Arc::new(social_network(40, 250, 2));
        let f = GraphCut::new(&g);
        for seed in 0..10 {
            let mut rng = Rng::new(seed);
            let r = RandomGreedy.maximize(&f, &(0..40).collect::<Vec<_>>(), &Cardinality::new(8), &mut rng);
            assert!(r.value >= 0.0);
        }
    }

    #[test]
    fn cut_quality_reasonable() {
        // Expected 1/e of OPT; empirically RandomGreedy lands far above
        // that on sparse graphs. Compare against a large random-set
        // baseline: RandomGreedy should beat random selection on average.
        let g = Arc::new(social_network(80, 600, 3));
        let f = GraphCut::new(&g);
        let ground: Vec<usize> = (0..80).collect();
        let k = 15;
        let mut rg_vals = Vec::new();
        let mut rand_vals = Vec::new();
        for seed in 0..8 {
            let mut rng = Rng::new(seed);
            rg_vals.push(
                RandomGreedy
                    .maximize(&f, &ground, &Cardinality::new(k), &mut rng)
                    .value,
            );
            let idx = rng.sample_indices(80, k);
            rand_vals.push(f.eval(&idx));
        }
        assert!(
            mean(&rg_vals) > 1.2 * mean(&rand_vals),
            "rg {} vs random {}",
            mean(&rg_vals),
            mean(&rand_vals)
        );
    }

    #[test]
    fn monotone_modular_close_to_optimal() {
        // On a modular function RandomGreedy picks uniformly among the top
        // k each round => still decent; with k distinct large weights and
        // the rest tiny it must pick mostly large ones.
        let mut w = vec![0.01; 30];
        for t in w.iter_mut().take(5) {
            *t = 10.0;
        }
        let f = Modular::new(w);
        let mut vals = Vec::new();
        for seed in 0..10 {
            let mut rng = Rng::new(seed);
            let r = RandomGreedy.maximize(&f, &(0..30).collect::<Vec<_>>(), &Cardinality::new(5), &mut rng);
            vals.push(r.value);
        }
        assert!(mean(&vals) > 30.0, "mean {}", mean(&vals)); // >= 3 of the 10.0s on average
    }
}
