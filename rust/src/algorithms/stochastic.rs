//! Stochastic greedy / "lazier than lazy greedy" (Mirzasoleiman et al.
//! 2015a, cited by the paper as a drop-in accelerator for the per-machine
//! stage): each round prices a random sample of size ⌈(n/k)·ln(1/ε)⌉
//! instead of all remaining elements, giving a (1 − 1/e − ε) guarantee in
//! expectation with O(n·ln(1/ε)) total oracle calls.

use super::{Maximizer, RunResult};
use crate::constraints::Constraint;
use crate::objective::SubmodularFn;
use crate::util::rng::Rng;

/// Stochastic greedy with accuracy parameter ε.
pub struct StochasticGreedy {
    pub epsilon: f64,
}

impl Default for StochasticGreedy {
    fn default() -> Self {
        StochasticGreedy { epsilon: 0.1 }
    }
}

impl StochasticGreedy {
    pub fn new(epsilon: f64) -> Self {
        assert!(epsilon > 0.0 && epsilon < 1.0);
        StochasticGreedy { epsilon }
    }
}

impl Maximizer for StochasticGreedy {
    fn maximize(
        &self,
        f: &dyn SubmodularFn,
        ground: &[usize],
        constraint: &dyn Constraint,
        rng: &mut Rng,
    ) -> RunResult {
        self.maximize_threaded(f, ground, constraint, rng, 1)
    }

    fn maximize_threaded(
        &self,
        f: &dyn SubmodularFn,
        ground: &[usize],
        constraint: &dyn Constraint,
        rng: &mut Rng,
        threads: usize,
    ) -> RunResult {
        let mut state = f.state();
        let mut oracle_calls = 0u64;
        let mut remaining: Vec<usize> = ground.to_vec();
        let n = ground.len();
        let k = constraint.rho().max(1);
        let sample_size =
            (((n as f64 / k as f64) * (1.0 / self.epsilon).ln()).ceil() as usize).max(1);

        loop {
            let feasible: Vec<usize> = remaining
                .iter()
                .copied()
                .filter(|&e| constraint.can_add(state.selected(), e))
                .collect();
            if feasible.is_empty() {
                break;
            }
            // Random sample (whole pool if small).
            let sample: Vec<usize> = if feasible.len() <= sample_size {
                feasible
            } else {
                rng.sample_indices(feasible.len(), sample_size)
                    .into_iter()
                    .map(|i| feasible[i])
                    .collect()
            };
            // NOTE: `remaining` keeps ground order (no swap_remove here) —
            // the sampler draws positional indices, so reordering would
            // change which elements a fixed seed samples.
            let gains = state.par_batch_gains(&sample, threads);
            oracle_calls += sample.len() as u64;
            let (best_idx, &best_gain) = gains
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .unwrap();
            if best_gain <= 0.0 {
                break;
            }
            let chosen = sample[best_idx];
            state.push(chosen);
            remaining.retain(|&e| e != chosen);
        }

        RunResult {
            value: state.value(),
            solution: state.selected().to_vec(),
            oracle_calls,
        }
    }

    fn name(&self) -> &'static str {
        "stochastic"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::greedy::Greedy;
    use crate::constraints::cardinality::Cardinality;
    use crate::data::synth::{gaussian_blobs, SynthConfig};
    use crate::objective::facility::FacilityLocation;
    use std::sync::Arc;

    #[test]
    fn close_to_plain_greedy() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(200, 8), 31));
        let f = FacilityLocation::from_dataset(&ds);
        let ground: Vec<usize> = (0..200).collect();
        let c = Cardinality::new(10);
        let mut rng = Rng::new(1);
        let exact = Greedy.maximize(&f, &ground, &c, &mut rng);
        let mut vals = Vec::new();
        for seed in 0..5 {
            let mut r = Rng::new(seed);
            vals.push(StochasticGreedy::new(0.05).maximize(&f, &ground, &c, &mut r).value);
        }
        let mean: f64 = vals.iter().sum::<f64>() / vals.len() as f64;
        assert!(mean > 0.9 * exact.value, "stochastic {mean} vs greedy {}", exact.value);
    }

    #[test]
    fn fewer_oracle_calls() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(300, 8), 32));
        let f = FacilityLocation::from_dataset(&ds);
        let ground: Vec<usize> = (0..300).collect();
        let c = Cardinality::new(20);
        let mut rng = Rng::new(2);
        let exact = Greedy.maximize(&f, &ground, &c, &mut rng);
        let fast = StochasticGreedy::new(0.2).maximize(&f, &ground, &c, &mut rng);
        assert!(fast.oracle_calls < exact.oracle_calls / 2);
    }

    #[test]
    fn respects_budget() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(50, 4), 33));
        let f = FacilityLocation::from_dataset(&ds);
        let mut rng = Rng::new(3);
        let r = StochasticGreedy::default().maximize(
            &f,
            &(0..50).collect::<Vec<_>>(),
            &Cardinality::new(5),
            &mut rng,
        );
        assert!(r.solution.len() <= 5);
    }

    #[test]
    #[should_panic]
    fn bad_epsilon_rejected() {
        StochasticGreedy::new(1.5);
    }
}
