//! Staged r-ary accumulation-tree reduction over the MapReduce engine.
//!
//! Every protocol used to funnel all m candidate sets into ONE root merge,
//! so root memory and merge time grow as O(m·κ) — the real ceiling on the
//! paper's "millions of machines" story. GreedyML (arXiv:2403.10332)
//! replaces the root with an r-ary tree of partial merges: each level
//! groups `fanout` sets per reduce node, runs the node body, and feeds the
//! winners to the next level, until one set remains. Per-node input volume
//! drops from m·κ to fanout·κ at the cost of ⌈log_r m⌉ − 1 extra rounds.
//!
//! [`TreeReduce`] is that tree as engine infrastructure: protocols supply
//! only the per-node merge body (`Fn(&NodeCtx, &[R]) -> NodeOutput<R>`) and
//! inherit, per level,
//!
//! - executor parallelism + [`StageReport`](super::StageReport) timing
//!   (each level is one engine stage; nodes are its tasks),
//! - the fault model: transient failures and stragglers at every node,
//!   crashes at interior nodes recovered under the run's
//!   [`RecoveryPolicy`] (the driver retains every node's inputs, so a
//!   crashed partial merge is always re-runnable — see below),
//! - `util::trace` spans (`mr.tree.level` / `mr.tree.node`) and the
//!   `mr.tree.peak_candidates` high-water gauge,
//! - shuffle accounting ([`JobReport::record_shuffle`] per node) and
//!   per-level peak-candidate stats ([`TreeStats`]).
//!
//! Fault semantics, chosen to keep flat runs bit-for-bit compatible with
//! the historical single-root merge:
//!
//! - The **root level** (and every level under `RecoveryPolicy::Retry`)
//!   runs via `run_stage_faulted` under `plan.without_crashes()` — crashes
//!   model losing data-holding *leaf* machines, while reduce nodes read
//!   candidate sets held at the driver and are always re-schedulable.
//!   This is exactly the historical merge path, including its retry
//!   accounting and straggler timing.
//! - **Interior levels** under a rebuilding policy (`DropShard`,
//!   `SurvivorMerge`, `Resume`) run via `run_stage_policied` under the
//!   full plan: a crashed node is re-run inline from its driver-held
//!   inputs (same ctx, same body ⇒ bit-identical output) with the
//!   recovery wallclock spliced into the level's report at the crashed
//!   slot. Interior levels therefore never lose data — unlike leaves,
//!   where a lost shard can be genuinely unrecoverable.
//!
//! Determinism contract: groups are formed by chunking the frontier in
//! node order, outputs fold back in node order, and the node body derives
//! its RNG from (seed, level, node) — so results are bit-identical at any
//! thread count, and `fanout ≥ inputs` reproduces the flat merge exactly.

use super::fault::{FaultPlan, RecoveryPolicy, StageFailed};
use super::{JobReport, MapReduce};
use crate::util::json::Json;
use crate::util::trace;

/// Where a reduce node sits in the tree — everything a merge body needs to
/// derive its RNG fork, constraint and oracle-thread budget.
#[derive(Debug, Clone, Copy)]
pub struct NodeCtx {
    /// Tree level, 1-based (level 1 consumes the leaf frontier).
    pub level: usize,
    /// Node index within the level (= chunk index, node order).
    pub node: usize,
    /// Number of nodes at this level (feeds `RunSpec::oracle_threads`).
    pub level_nodes: usize,
    /// Whether this level produces the final single output (the root gets
    /// the final budget k and the full thread budget).
    pub is_root: bool,
}

/// What a merge body returns for one node.
#[derive(Debug, Clone)]
pub struct NodeOutput<R> {
    /// The partial merge fed to the next level (or the final result).
    pub result: R,
    /// Candidates pooled at this node (deduped input volume) — the
    /// per-node memory footprint and shuffle contribution.
    pub pooled: usize,
    /// Oracle calls spent inside this node.
    pub oracle_calls: u64,
}

/// Per-level accounting for one tree reduction — the `tree` block of
/// `RunMetrics`, mirroring how `stream_greedi` reports `peak_live`.
#[derive(Debug, Clone, Default)]
pub struct TreeStats {
    /// Effective fan-in r (clamped to the leaf count for display: a flat
    /// merge over m sets reports r = m).
    pub fanout: usize,
    /// Number of reduction levels (flat single-root merge ⇒ 1).
    pub depth: usize,
    /// Reduce nodes per level, level order (root last).
    pub nodes_per_level: Vec<usize>,
    /// Max candidates pooled at any node of each level, level order. The
    /// last entry is the root's peak — O(r·κ) for a tree vs O(m·κ) flat.
    pub peak_per_level: Vec<usize>,
    /// Transient-failure retries across all levels.
    pub retries: usize,
    /// Interior nodes that crashed and were re-run from driver-held inputs.
    pub recovered_nodes: usize,
}

impl TreeStats {
    /// Candidates pooled at the root — the memory number the fan-in sweep
    /// charts against quality.
    pub fn root_peak(&self) -> usize {
        self.peak_per_level.last().copied().unwrap_or(0)
    }

    /// The `tree` block of `RunMetrics::to_json`.
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("fanout", Json::num(self.fanout as f64)),
            ("depth", Json::num(self.depth as f64)),
            (
                "nodes_per_level",
                Json::Arr(self.nodes_per_level.iter().map(|&n| Json::num(n as f64)).collect()),
            ),
            (
                "peak_per_level",
                Json::Arr(self.peak_per_level.iter().map(|&p| Json::num(p as f64)).collect()),
            ),
            ("root_peak", Json::num(self.root_peak() as f64)),
            ("retries", Json::num(self.retries as f64)),
            ("recovered_nodes", Json::num(self.recovered_nodes as f64)),
        ])
    }
}

/// Outcome of [`TreeReduce::run`].
#[derive(Debug, Clone)]
pub struct TreeRun<R> {
    /// The root's result (`None` only for an empty frontier without
    /// `force_root`).
    pub result: Option<R>,
    pub stats: TreeStats,
    /// Σ oracle calls over all nodes.
    pub oracle_calls: u64,
}

/// The staged r-ary reduction.
#[derive(Debug, Clone)]
pub struct TreeReduce {
    /// Sets merged per node per level (clamped to ≥ 2; `usize::MAX` ⇒ one
    /// flat root level).
    pub fanout: usize,
    /// Run a root level even when the frontier is already a single set
    /// (GreeDi's merge round always runs, re-selecting under the final
    /// budget; multiround's m = 1 case skips it instead).
    pub force_root: bool,
}

impl TreeReduce {
    pub fn new(fanout: usize) -> Self {
        TreeReduce { fanout, force_root: false }
    }

    pub fn force_root(mut self, yes: bool) -> Self {
        self.force_root = yes;
        self
    }

    /// Reduce `inputs` to one result. Each level is one engine stage whose
    /// report is pushed onto `job`; each node's `pooled` count is recorded
    /// as shuffle volume. `Err` only when a task exhausts the plan's
    /// attempts on the abort-on-exhaustion path (root level, or any level
    /// under `Retry`).
    pub fn run<R, F>(
        &self,
        engine: &MapReduce,
        inputs: Vec<R>,
        plan: &FaultPlan,
        policy: RecoveryPolicy,
        job: &mut JobReport,
        merge_fn: F,
    ) -> Result<TreeRun<R>, StageFailed>
    where
        R: Send + Clone,
        F: Fn(&NodeCtx, &[R]) -> NodeOutput<R> + Sync,
    {
        let fanout = self.fanout.max(2);
        let leaves = inputs.len();
        let mut stats =
            TreeStats { fanout: fanout.min(leaves.max(1)), ..TreeStats::default() };
        let mut oracle_calls = 0u64;
        let mut frontier = inputs;
        let mut level = 0usize;

        while frontier.len() > 1 || (self.force_root && level == 0) {
            level += 1;
            let groups: Vec<Vec<R>> = if frontier.is_empty() {
                vec![Vec::new()]
            } else {
                frontier.chunks(fanout).map(|c| c.to_vec()).collect()
            };
            let level_nodes = groups.len();
            let is_root = level_nodes == 1;
            let _level_span = trace::span_with("mr.tree.level", || {
                vec![("level", level.into()), ("nodes", level_nodes.into())]
            });
            let run_node = |node: usize, sets: &[R]| -> NodeOutput<R> {
                let ctx = NodeCtx { level, node, level_nodes, is_root };
                let _node_span = trace::span_with("mr.tree.node", || {
                    vec![("level", level.into()), ("node", node.into()), ("inputs", sets.len().into())]
                });
                let out = merge_fn(&ctx, sets);
                crate::trace_gauge!("mr.tree.peak_candidates").record(out.pooled as u64);
                out
            };

            // Root levels (and everything under Retry) take the historical
            // flat-merge path: transients + stragglers only, abort on
            // exhaustion. Interior levels under a rebuilding policy run the
            // full plan and recover crashed nodes inline (see module docs).
            let stage_inputs: Vec<(usize, Vec<R>)> =
                groups.iter().cloned().enumerate().collect();
            let (outputs, report, level_retries) =
                if is_root || policy == RecoveryPolicy::Retry {
                    let (outs, report, retries) = engine.run_stage_faulted(
                        stage_inputs,
                        &plan.without_crashes(),
                        |_, (node, sets)| run_node(node, &sets),
                    )?;
                    (outs, report, retries)
                } else {
                    let stage = engine.run_stage_policied(
                        stage_inputs,
                        plan,
                        policy,
                        |_, (node, sets)| run_node(node, &sets),
                    )?;
                    let mut outs = stage.outputs;
                    let mut report = stage.report;
                    if !stage.crashed.is_empty() {
                        let lost: Vec<(usize, Vec<R>)> = stage
                            .crashed
                            .iter()
                            .map(|&nid| (nid, groups[nid].clone()))
                            .collect();
                        let (rec_outs, rec_report) =
                            engine.run_stage(lost, |_, (node, sets)| run_node(node, &sets));
                        for ((&nid, out), &t) in stage
                            .crashed
                            .iter()
                            .zip(rec_outs)
                            .zip(rec_report.task_times.iter())
                        {
                            outs[nid] = Some(out);
                            report.task_times[nid] = t;
                        }
                        report.max_task_time =
                            report.task_times.iter().cloned().fold(0.0, f64::max);
                        report.total_cpu_time = report.task_times.iter().sum();
                        stats.recovered_nodes += stage.crashed.len();
                    }
                    let outs: Vec<NodeOutput<R>> = outs
                        .into_iter()
                        .map(|o| o.expect("interior nodes always recover"))
                        .collect();
                    (outs, report, stage.retries)
                };

            job.stages.push(report);
            stats.retries += level_retries;
            let mut peak = 0usize;
            let mut next = Vec::with_capacity(outputs.len());
            for out in outputs {
                job.record_shuffle(out.pooled);
                peak = peak.max(out.pooled);
                oracle_calls += out.oracle_calls;
                next.push(out.result);
            }
            stats.nodes_per_level.push(level_nodes);
            stats.peak_per_level.push(peak);
            trace::event_with("mr.tree.level.done", || {
                vec![("level", level.into()), ("nodes", level_nodes.into()), ("peak", peak.into())]
            });
            frontier = next;
        }

        stats.depth = stats.nodes_per_level.len();
        Ok(TreeRun { result: frontier.pop(), stats, oracle_calls })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic merge body: sorted dedup union, capped to `cap`.
    fn union_cap(cap: usize) -> impl Fn(&NodeCtx, &[Vec<usize>]) -> NodeOutput<Vec<usize>> + Sync {
        move |ctx, sets| {
            let mut pool: Vec<usize> = sets.iter().flatten().copied().collect();
            pool.sort_unstable();
            pool.dedup();
            let pooled = pool.len();
            let keep = if ctx.is_root { cap } else { cap + 2 };
            pool.truncate(keep);
            NodeOutput { result: pool, pooled, oracle_calls: 1 }
        }
    }

    fn leaves(m: usize, per: usize) -> Vec<Vec<usize>> {
        (0..m).map(|i| (0..per).map(|j| i * per + j).collect()).collect()
    }

    #[test]
    fn flat_fanout_is_single_root_level() {
        let engine = MapReduce::new(1);
        let mut job = JobReport::default();
        let tree = TreeReduce::new(usize::MAX).force_root(true);
        let run = tree
            .run(&engine, leaves(6, 3), &FaultPlan::none(), RecoveryPolicy::Retry, &mut job, union_cap(4))
            .unwrap();
        assert_eq!(run.stats.depth, 1);
        assert_eq!(run.stats.nodes_per_level, vec![1]);
        assert_eq!(run.stats.fanout, 6, "flat merge reports r = leaves");
        assert_eq!(run.stats.root_peak(), 18, "root pools every candidate");
        assert_eq!(run.result.unwrap(), vec![0, 1, 2, 3]);
        assert_eq!(job.stages.len(), 1);
        assert_eq!(job.shuffled_elements, 18);
    }

    #[test]
    fn binary_tree_shape_and_order() {
        let engine = MapReduce::new(1);
        let mut job = JobReport::default();
        let run = TreeReduce::new(2)
            .run(&engine, leaves(5, 2), &FaultPlan::none(), RecoveryPolicy::Retry, &mut job, union_cap(100))
            .unwrap();
        // 5 → 3 → 2 → 1
        assert_eq!(run.stats.depth, 3);
        assert_eq!(run.stats.nodes_per_level, vec![3, 2, 1]);
        assert_eq!(run.stats.peak_per_level.len(), 3);
        assert_eq!(job.stages.len(), 3);
        // union-preserving body ⇒ the root sees everything, in order
        assert_eq!(run.result.unwrap(), (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn threads_do_not_change_the_result() {
        let plan = FaultPlan::none();
        let mut runs = Vec::new();
        for threads in [1usize, 2, 8] {
            let engine = MapReduce::new(threads);
            let mut job = JobReport::default();
            let run = TreeReduce::new(3)
                .run(&engine, leaves(9, 4), &plan, RecoveryPolicy::Retry, &mut job, union_cap(5))
                .unwrap();
            runs.push((run.result.unwrap(), run.stats.nodes_per_level, job.shuffled_elements));
        }
        assert_eq!(runs[0], runs[1]);
        assert_eq!(runs[0], runs[2]);
    }

    #[test]
    fn force_root_runs_on_single_and_empty_frontiers() {
        let engine = MapReduce::new(1);
        let mut job = JobReport::default();
        let tree = TreeReduce::new(usize::MAX).force_root(true);
        let one = tree
            .run(&engine, leaves(1, 3), &FaultPlan::none(), RecoveryPolicy::Retry, &mut job, union_cap(2))
            .unwrap();
        assert_eq!(one.stats.depth, 1, "the root re-selects even for one input");
        assert_eq!(one.result.unwrap(), vec![0, 1]);
        let empty = tree
            .run(&engine, Vec::new(), &FaultPlan::none(), RecoveryPolicy::Retry, &mut job, union_cap(2))
            .unwrap();
        assert_eq!(empty.stats.depth, 1);
        assert_eq!(empty.result.unwrap(), Vec::<usize>::new());
        // without force_root, degenerate frontiers skip the tree entirely
        let skip = TreeReduce::new(2)
            .run(&engine, leaves(1, 3), &FaultPlan::none(), RecoveryPolicy::Retry, &mut job, union_cap(2))
            .unwrap();
        assert_eq!(skip.stats.depth, 0);
        assert_eq!(skip.result.unwrap(), vec![0, 1, 2], "untouched leaf passes through");
    }

    #[test]
    fn interior_crash_recovers_bit_identically() {
        let engine = MapReduce::new(2);
        let clean = {
            let mut job = JobReport::default();
            TreeReduce::new(2)
                .run(&engine, leaves(4, 2), &FaultPlan::none(), RecoveryPolicy::SurvivorMerge, &mut job, union_cap(100))
                .unwrap()
        };
        // crash task 0 of every stage: at level 1 that's an interior node
        let plan = FaultPlan::none().crash_tasks(vec![0]);
        let mut job = JobReport::default();
        let run = TreeReduce::new(2)
            .run(&engine, leaves(4, 2), &plan, RecoveryPolicy::SurvivorMerge, &mut job, union_cap(100))
            .unwrap();
        assert_eq!(run.result.unwrap(), clean.result.unwrap(), "recovery changed the result");
        assert!(run.stats.recovered_nodes >= 1, "level-1 node 0 must be recovered");
        assert_eq!(job.stages.len(), 2, "inline recovery adds no stage");
        assert_eq!(run.oracle_calls, clean.oracle_calls);
    }

    #[test]
    fn transient_retries_are_counted_and_output_invariant() {
        let engine = MapReduce::new(1);
        let clean = {
            let mut job = JobReport::default();
            TreeReduce::new(2)
                .run(&engine, leaves(16, 2), &FaultPlan::none(), RecoveryPolicy::Retry, &mut job, union_cap(50))
                .unwrap()
        };
        let plan = FaultPlan::new(0.5, 20, 11);
        let mut job = JobReport::default();
        let run = TreeReduce::new(2)
            .run(&engine, leaves(16, 2), &plan, RecoveryPolicy::Retry, &mut job, union_cap(50))
            .unwrap();
        assert_eq!(run.result.unwrap(), clean.result.unwrap());
        assert!(run.stats.retries > 0, "p=0.5 over 15 nodes must retry sometimes");
    }

    #[test]
    fn tree_stats_json_shape() {
        let s = TreeStats {
            fanout: 2,
            depth: 3,
            nodes_per_level: vec![3, 2, 1],
            peak_per_level: vec![6, 8, 9],
            retries: 1,
            recovered_nodes: 2,
        };
        assert_eq!(s.root_peak(), 9);
        let j = s.to_json();
        assert_eq!(j.get("fanout").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(j.get("depth").and_then(|v| v.as_f64()), Some(3.0));
        assert_eq!(j.get("root_peak").and_then(|v| v.as_f64()), Some(9.0));
        assert_eq!(j.get("nodes_per_level").and_then(|v| v.as_arr()).map(|a| a.len()), Some(3));
        assert_eq!(j.get("recovered_nodes").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(TreeStats::default().root_peak(), 0);
    }
}
