//! Simulated MapReduce runtime.
//!
//! The paper runs GreeDi on Hadoop/Spark clusters and reports, per stage,
//! the *maximum running time per reduce task* (§6.1/§6.2). This engine
//! reproduces that accounting on a single box: each map/reduce task is run
//! as an independent unit of work whose own wallclock is measured, and a
//! stage's **simulated parallel time** is the maximum task time (every
//! machine runs its task concurrently in the modeled cluster) plus the
//! driver-side shuffle cost. Tasks multiplex onto the persistent
//! work-stealing pool (`util::executor`) when `threads > 1` — no per-stage
//! thread launch, and nested oracle fan-out inside a task shares the same
//! workers — or run sequentially inline when `threads == 1`; the
//! accounting is identical either way, and sequential execution keeps the
//! per-task timings interference-free on small hosts.
//!
//! The engine is generic over task payloads; GreeDi's coordinator submits
//! one map task per machine shard, and the aggregation side goes through
//! [`reduce::TreeReduce`] — a staged r-ary accumulation tree whose levels
//! are ordinary stages (one reduce node per task), so partial merges
//! inherit the same timing, fault and tracing story as map tasks. With
//! `fanout ≥ m` the tree degenerates to the classic single-root merge.

pub mod fault;
pub mod partition;
pub mod reduce;

use std::time::Instant;

use crate::util::executor::parallel_map;
use crate::util::trace;

/// Per-stage execution report (the paper's per-stage metrics).
#[derive(Debug, Clone, Default)]
pub struct StageReport {
    /// Wallclock of each task, seconds, task order = input order.
    pub task_times: Vec<f64>,
    /// max(task_times) — the simulated parallel stage time.
    pub max_task_time: f64,
    /// Σ task_times — the sequential (centralized) cost of the stage.
    pub total_cpu_time: f64,
}

impl StageReport {
    fn from_times(task_times: Vec<f64>) -> Self {
        let max_task_time = task_times.iter().cloned().fold(0.0, f64::max);
        let total_cpu_time = task_times.iter().sum();
        StageReport { task_times, max_task_time, total_cpu_time }
    }
}

/// A whole simulated job: ordered stage reports + shuffle accounting.
#[derive(Debug, Clone, Default)]
pub struct JobReport {
    pub stages: Vec<StageReport>,
    /// Elements moved between stages (communication volume; the paper's
    /// protocols exchange poly(k·m) elements, never O(n)).
    pub shuffled_elements: usize,
}

impl JobReport {
    /// Simulated end-to-end parallel wallclock: Σ over stages of each
    /// stage's max task time.
    pub fn sim_parallel_time(&self) -> f64 {
        self.stages.iter().map(|s| s.max_task_time).sum()
    }

    /// Total CPU across all tasks (≈ a centralized single-machine run of
    /// the same work).
    pub fn total_cpu_time(&self) -> f64 {
        self.stages.iter().map(|s| s.total_cpu_time).sum()
    }

    pub fn record_shuffle(&mut self, elements: usize) {
        self.shuffled_elements += elements;
    }
}

/// The engine: runs stages of independent tasks with per-task timing.
#[derive(Debug, Clone)]
pub struct MapReduce {
    /// OS threads used to execute tasks (1 = sequential, exact timings).
    pub threads: usize,
}

impl Default for MapReduce {
    fn default() -> Self {
        MapReduce { threads: 1 }
    }
}

impl MapReduce {
    pub fn new(threads: usize) -> Self {
        MapReduce { threads: threads.max(1) }
    }

    /// Run one stage: `f(task_index, input) -> output` per task. Returns
    /// outputs in input order plus the stage report.
    pub fn run_stage<T, R, F>(&self, inputs: Vec<T>, f: F) -> (Vec<R>, StageReport)
    where
        T: Send,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
    {
        let n_tasks = inputs.len();
        let _stage_span = trace::span_with("mr.stage", || {
            vec![("tasks", n_tasks.into()), ("threads", self.threads.into())]
        });
        let timed: Vec<(R, f64)> = if self.threads == 1 {
            inputs
                .into_iter()
                .enumerate()
                .map(|(i, x)| {
                    let _task_span = trace::span_with("mr.task", || vec![("task", i.into())]);
                    let t = Instant::now();
                    let r = f(i, x);
                    (r, t.elapsed().as_secs_f64())
                })
                .collect()
        } else {
            parallel_map(inputs, self.threads, |i, x| {
                let _task_span = trace::span_with("mr.task", || vec![("task", i.into())]);
                let t = Instant::now();
                let r = f(i, x);
                (r, t.elapsed().as_secs_f64())
            })
        };
        let mut outputs = Vec::with_capacity(timed.len());
        let mut times = Vec::with_capacity(timed.len());
        for (r, t) in timed {
            outputs.push(r);
            times.push(t);
        }
        (outputs, StageReport::from_times(times))
    }

    /// [`MapReduce::run_stage`] under a [`fault::FaultPlan`]: with no
    /// injected faults the tasks run on the pool exactly as `run_stage`
    /// does (zero retries); with any fault injection active (transient,
    /// crash, or straggler), execution delegates to
    /// [`fault::run_stage_with_faults`] on the same `threads` budget. For
    /// pure task functions the outputs are identical on both paths, which
    /// is what lets protocols expose a fault-injected run mode without
    /// forking their stage logic. Returns the retry count alongside the
    /// outputs and stage report.
    pub fn run_stage_faulted<T, R, F>(
        &self,
        inputs: Vec<T>,
        plan: &fault::FaultPlan,
        f: F,
    ) -> Result<(Vec<R>, StageReport, usize), fault::StageFailed>
    where
        T: Send + Clone,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
    {
        if !plan.active() {
            let (out, rep) = self.run_stage(inputs, f);
            return Ok((out, rep, 0));
        }
        fault::run_stage_with_faults(inputs, plan, self.threads, f)
    }

    /// [`MapReduce::run_stage`] under a [`fault::FaultPlan`] *and* a
    /// [`fault::RecoveryPolicy`]: crashed machines become `None` outputs
    /// instead of stage aborts (except under `Retry`, which keeps the
    /// abort-on-exhaustion contract). Inactive plans take the plain
    /// `run_stage` path with every output present.
    pub fn run_stage_policied<T, R, F>(
        &self,
        inputs: Vec<T>,
        plan: &fault::FaultPlan,
        policy: fault::RecoveryPolicy,
        f: F,
    ) -> Result<fault::PoliciedStage<R>, fault::StageFailed>
    where
        T: Send + Clone,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
    {
        if !plan.active() {
            let (out, report) = self.run_stage(inputs, f);
            return Ok(fault::PoliciedStage {
                outputs: out.into_iter().map(Some).collect(),
                report,
                retries: 0,
                crashed: Vec::new(),
                straggled: Vec::new(),
            });
        }
        fault::run_stage_policied(inputs, plan, policy, self.threads, f)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_outputs_in_order() {
        let mr = MapReduce::new(1);
        let (out, rep) = mr.run_stage((0..10).collect(), |_, x: i32| x * x);
        assert_eq!(out, (0..10).map(|x| x * x).collect::<Vec<_>>());
        assert_eq!(rep.task_times.len(), 10);
        assert!(rep.max_task_time <= rep.total_cpu_time + 1e-12);
    }

    #[test]
    fn parallel_matches_sequential_outputs() {
        let seq = MapReduce::new(1);
        let par = MapReduce::new(4);
        let (a, _) = seq.run_stage((0..50).collect(), |i, x: i32| x + i as i32);
        let (b, _) = par.run_stage((0..50).collect(), |i, x: i32| x + i as i32);
        assert_eq!(a, b);
    }

    #[test]
    fn job_report_accumulates() {
        let mr = MapReduce::new(1);
        let mut job = JobReport::default();
        let (_, s1) = mr.run_stage(vec![1, 2, 3], |_, x: i32| {
            std::hint::black_box((0..10_000 * x).sum::<i32>())
        });
        let (_, s2) = mr.run_stage(vec![4], |_, x: i32| x);
        job.stages.push(s1);
        job.stages.push(s2);
        job.record_shuffle(12);
        assert_eq!(job.shuffled_elements, 12);
        assert!(job.sim_parallel_time() > 0.0);
        assert!(job.total_cpu_time() >= job.sim_parallel_time() - 1e-12);
    }

    #[test]
    fn faulted_stage_matches_clean_stage_outputs() {
        let mr = MapReduce::new(4);
        let clean = mr.run_stage((0..40).collect(), |i, x: i32| x * 3 + i as i32).0;
        let (none_out, _, r0) = mr
            .run_stage_faulted((0..40).collect(), &fault::FaultPlan::none(), |i, x: i32| {
                x * 3 + i as i32
            })
            .unwrap();
        assert_eq!(none_out, clean);
        assert_eq!(r0, 0, "no plan, no retries");
        let plan = fault::FaultPlan::new(0.4, 25, 9);
        let (faulty_out, _, retries) = mr
            .run_stage_faulted((0..40).collect(), &plan, |i, x: i32| x * 3 + i as i32)
            .unwrap();
        assert_eq!(faulty_out, clean, "retries must not change outputs");
        assert!(retries > 0, "p=0.4 over 40 tasks must retry sometimes");
    }

    #[test]
    fn crash_only_plan_is_not_silently_ignored() {
        // fail_prob == 0 but a pinned crash: the faulted path must engage
        // (the old gate keyed on fail_prob alone and would skip it).
        let mr = MapReduce::new(2);
        let plan = fault::FaultPlan::none().crash_tasks(vec![1]);
        let err = mr.run_stage_faulted((0..4).collect(), &plan, |_, x: i32| x).unwrap_err();
        assert_eq!(err.task, 1);
        let stage = mr
            .run_stage_policied(
                (0..4).collect(),
                &plan,
                fault::RecoveryPolicy::SurvivorMerge,
                |_, x: i32| x,
            )
            .unwrap();
        assert_eq!(stage.crashed, vec![1]);
        assert_eq!(stage.outputs[1], None);
    }

    #[test]
    fn domain_crash_plan_takes_a_whole_rack_out_of_a_stage() {
        // machines 0..6 in 2 racks (i % 2); pinning rack 0 crashes exactly
        // the even machines, and the policied stage skips them atomically.
        let mr = MapReduce::new(1);
        let plan = fault::FaultPlan::none().domain_groups(2).crash_domains(vec![0]);
        assert!(plan.active());
        let stage = mr
            .run_stage_policied(
                (0..6).collect(),
                &plan,
                fault::RecoveryPolicy::DropShard,
                |_, x: i32| x * 10,
            )
            .unwrap();
        assert_eq!(stage.crashed, vec![0, 2, 4]);
        assert_eq!(
            stage.outputs,
            vec![None, Some(10), None, Some(30), None, Some(50)]
        );
    }

    #[test]
    fn max_task_time_is_max() {
        let mr = MapReduce::new(1);
        let (_, rep) = mr.run_stage(vec![1usize, 50_000], |_, n| {
            std::hint::black_box((0..n as u64).sum::<u64>())
        });
        assert!((rep.max_task_time - rep.task_times.iter().cloned().fold(0.0, f64::max)).abs() < 1e-15);
    }
}
