//! Fault injection + recovery for the simulated MapReduce runtime.
//!
//! The paper's Hadoop deployment leans on MapReduce's core resilience
//! property: failed tasks are rescheduled and the job still completes with
//! identical output (map tasks are deterministic and side-effect-free).
//! This module models that and two stronger failure modes:
//!
//! - **Transient attempt failures** — a [`FaultPlan`] decides,
//!   deterministically from a seed, which (task, attempt) pairs fail;
//!   [`run_stage_with_faults`] re-executes failed tasks up to
//!   `max_attempts`, charging each attempt's wallclock to the stage like a
//!   real re-scheduled container would be.
//! - **Machine crashes** — a crashed task loses *every* attempt for the
//!   stage (the machine and its shard are gone). Crashes are either drawn
//!   per-task from `crash_prob` or pinned explicitly via
//!   [`FaultPlan::crash_tasks`].
//! - **Stragglers** — a deterministic per-task slowdown factor multiplies
//!   the recorded task wallclock (timing only; outputs are untouched),
//!   modeling the slow-node tail that dominates real stage latency.
//! - **Failure domains** — a [`DomainMap`] assigns machines to rack/zone
//!   groups; [`FaultPlan::domain_crashes`] flips one salted coin *per
//!   group* and takes every machine in an unlucky group down atomically
//!   (the top-of-rack-switch failure mode real replication placement must
//!   survive). When a domain map is present, transient attempt-failure
//!   coins are keyed on (domain, attempt) instead of (task, attempt) —
//!   a rack-local network blip costs the whole rack the same attempt.
//!
//! What happens after a crash is the [`RecoveryPolicy`]'s call:
//! [`run_stage_policied`] either aborts like today (`Retry`), or skips the
//! crashed machines and lets the protocol degrade (`DropShard`), rebuild
//! the lost shard from surviving replicas (`SurvivorMerge`, with
//! multiplicity ≥ 2 from `partition::split_replicated`), or additionally
//! salvage the crashed machine's checkpointed partial progress and replay
//! only the tail (`Resume`, with `RunSpec::checkpoint_every`). The
//! deterministic crash point — how far a crashed machine got before dying,
//! as a fraction of its planned work — comes from [`FaultPlan::crash_point`]
//! (salted coin per task, pinnable via [`FaultPlan::crash_progress`]).
//!
//! Because GreeDi's map tasks are pure functions of (shard, seed), retries
//! cannot change the protocol's output — asserted by the integration tests.

use std::time::Instant;

use super::StageReport;
use crate::util::executor::parallel_map;
use crate::util::rng::Rng;
use crate::util::trace;

/// Assignment of machines (tasks) to failure domains — racks, zones,
/// power strips: whatever fails together. The default (`None`) keeps the
/// PR 7 model where every machine is its own domain.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum DomainMap {
    /// Every machine is its own failure domain (independent crashes).
    #[default]
    None,
    /// Round-robin racks: machine `i` lives in domain `i % d`.
    Modulo(usize),
    /// Explicit per-machine domain ids; machines beyond the map's length
    /// each get a private fresh domain (never grouped with anything).
    Explicit(Vec<usize>),
}

impl DomainMap {
    /// Is this the trivial one-machine-per-domain map?
    pub fn is_trivial(&self) -> bool {
        matches!(self, DomainMap::None) || matches!(self, DomainMap::Modulo(1))
    }

    /// The failure domain machine `task` lives in.
    pub fn domain_of(&self, task: usize) -> usize {
        match self {
            DomainMap::None => task,
            DomainMap::Modulo(d) => task % (*d).max(1),
            // out-of-map machines get private high domains, disjoint from
            // any sane explicit id and from each other
            DomainMap::Explicit(v) => v.get(task).copied().unwrap_or(usize::MAX - task),
        }
    }

    /// Number of distinct domains across machines `0..m`.
    pub fn count(&self, m: usize) -> usize {
        match self {
            DomainMap::None => m,
            DomainMap::Modulo(d) => (*d).max(1).min(m),
            DomainMap::Explicit(_) => {
                let doms: std::collections::HashSet<usize> =
                    (0..m).map(|t| self.domain_of(t)).collect();
                doms.len()
            }
        }
    }
}

/// Deterministic per-(task, attempt) failure oracle, plus machine-level
/// crash, correlated domain-crash, and straggler injection.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Probability a given task attempt fails (transient; retried). Keyed
    /// per (task, attempt) — or per (domain, attempt) when a non-trivial
    /// [`DomainMap`] is configured (correlated transients).
    pub fail_prob: f64,
    /// Probability a given task's machine crashes for the whole stage.
    pub crash_prob: f64,
    /// Probability a given task's machine is a straggler.
    pub straggle_prob: f64,
    /// Wallclock multiplier charged to straggling tasks (≥ 1).
    pub straggle_factor: f64,
    /// Attempts per task before the stage aborts (under `Retry`).
    pub max_attempts: usize,
    /// Tasks that crash unconditionally (in addition to `crash_prob` draws).
    pub crashed_tasks: Vec<usize>,
    /// Machine → failure-domain assignment (racks/zones).
    pub domains: DomainMap,
    /// Probability a whole failure domain crashes atomically.
    pub domain_crash_prob: f64,
    /// Domains that crash unconditionally (deterministic chaos scripting).
    pub crashed_domains: Vec<usize>,
    /// Pinned crash point for `Resume` salvage tests; `None` draws it from
    /// the salted coin in [`FaultPlan::crash_point`].
    crash_progress: Option<f64>,
    seed: u64,
}

const CRASH_SALT: u64 = 0x5851_F42D_4C95_7F2D;
const STRAGGLE_SALT: u64 = 0x1405_7B7E_F767_814F;
/// Salts the per-domain crash coin so rack loss never mirrors the
/// per-machine crash draws at the same seed.
const DOMAIN_SALT: u64 = 0x9E6C_63D0_985E_E21Bu64;
/// Salts the per-task crash-point draw (how far a crashed machine got
/// before dying) used by `RecoveryPolicy::Resume` salvage.
const SALVAGE_SALT: u64 = 0x27D4_EB2F_1656_67C5u64;

impl FaultPlan {
    pub fn new(fail_prob: f64, max_attempts: usize, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&fail_prob));
        assert!(max_attempts >= 1);
        FaultPlan {
            fail_prob,
            crash_prob: 0.0,
            straggle_prob: 0.0,
            straggle_factor: 1.0,
            max_attempts,
            crashed_tasks: Vec::new(),
            domains: DomainMap::None,
            domain_crash_prob: 0.0,
            crashed_domains: Vec::new(),
            crash_progress: None,
            seed,
        }
    }

    /// No faults (baseline).
    pub fn none() -> Self {
        FaultPlan::new(0.0, 1, 0)
    }

    /// Draw machine crashes per task with probability `p`.
    pub fn crashes(mut self, p: f64) -> Self {
        assert!((0.0..=1.0).contains(&p));
        self.crash_prob = p;
        self
    }

    /// Crash these tasks unconditionally (deterministic chaos scripting).
    pub fn crash_tasks(mut self, tasks: Vec<usize>) -> Self {
        self.crashed_tasks = tasks;
        self
    }

    /// Mark tasks as stragglers with probability `p`; a straggler's recorded
    /// wallclock is multiplied by `factor` (its output is unchanged).
    pub fn stragglers(mut self, p: f64, factor: f64) -> Self {
        assert!((0.0..=1.0).contains(&p));
        assert!(factor >= 1.0, "straggle factor {factor} must be >= 1");
        self.straggle_prob = p;
        self.straggle_factor = factor;
        self
    }

    /// Assign machines to failure domains explicitly: machine `i` lives in
    /// domain `groups[i]` (machines beyond the map get private domains).
    pub fn domains(mut self, groups: Vec<usize>) -> Self {
        self.domains = DomainMap::Explicit(groups);
        self
    }

    /// Assign machines round-robin to `d` failure domains (`i % d`).
    pub fn domain_groups(mut self, d: usize) -> Self {
        assert!(d >= 1, "need at least one failure domain");
        self.domains = DomainMap::Modulo(d);
        self
    }

    /// Draw whole-domain crashes per failure domain with probability `p`;
    /// every machine in an unlucky domain crashes atomically.
    pub fn domain_crashes(mut self, p: f64) -> Self {
        assert!((0.0..=1.0).contains(&p));
        self.domain_crash_prob = p;
        self
    }

    /// Crash these failure domains unconditionally (deterministic chaos
    /// scripting; composes with `domain_crashes` draws).
    pub fn crash_domains(mut self, doms: Vec<usize>) -> Self {
        self.crashed_domains = doms;
        self
    }

    /// Pin the crash point: every crashed machine died after completing
    /// exactly fraction `f ∈ [0, 1)` of its planned work. Without this, the
    /// crash point is drawn per task from a salted coin (see
    /// [`FaultPlan::crash_point`]).
    pub fn crash_progress(mut self, f: f64) -> Self {
        assert!((0.0..1.0).contains(&f), "crash progress {f} must be in [0, 1)");
        self.crash_progress = Some(f);
        self
    }

    /// Is any fault injection configured? Gates the faulted stage paths so
    /// crash-only or straggler-only plans are not silently ignored. A bare
    /// [`DomainMap`] with no crash probability is *not* active — protocols
    /// use it for replica placement even on clean reference runs.
    pub fn active(&self) -> bool {
        self.fail_prob > 0.0
            || self.crash_prob > 0.0
            || self.straggle_prob > 0.0
            || !self.crashed_tasks.is_empty()
            || self.domain_crash_prob > 0.0
            || !self.crashed_domains.is_empty()
    }

    /// The same plan with machine *and domain* crashes stripped (transient
    /// failures and stragglers kept). Merge/reduce stages run under this:
    /// crashes model the loss of data-holding *map* machines, while
    /// reducers read shuffle data held at the driver and are always
    /// re-schedulable. The domain map itself is kept — transient coins stay
    /// domain-correlated.
    pub fn without_crashes(&self) -> Self {
        let mut p = self.clone();
        p.crash_prob = 0.0;
        p.crashed_tasks.clear();
        p.domain_crash_prob = 0.0;
        p.crashed_domains.clear();
        p
    }

    /// Does attempt `attempt` of task `task` fail? With a non-trivial
    /// domain map the coin is keyed on the task's *domain*, so every
    /// machine in a rack loses the same attempts together (correlated
    /// transients). Output-invariant either way: retries replay the same
    /// pure task.
    pub fn fails(&self, task: usize, attempt: usize) -> bool {
        if self.fail_prob <= 0.0 {
            return false;
        }
        let key = if self.domains.is_trivial() { task } else { self.domains.domain_of(task) };
        let mut rng = Rng::new(
            self.seed ^ (key as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (attempt as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F),
        );
        rng.bool(self.fail_prob)
    }

    /// Is failure domain `dom` crashed for this stage (pinned or drawn)?
    pub fn domain_crashed(&self, dom: usize) -> bool {
        if self.crashed_domains.contains(&dom) {
            return true;
        }
        if self.domain_crash_prob <= 0.0 {
            return false;
        }
        let mut rng = Rng::new(
            self.seed ^ DOMAIN_SALT ^ (dom as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        rng.bool(self.domain_crash_prob)
    }

    /// Is task `task`'s machine crashed for this stage? Either its own
    /// machine coin/pin fired, or its whole failure domain went down.
    pub fn crashed(&self, task: usize) -> bool {
        if self.crashed_tasks.contains(&task) {
            return true;
        }
        if (self.domain_crash_prob > 0.0 || !self.crashed_domains.is_empty())
            && self.domain_crashed(self.domains.domain_of(task))
        {
            return true;
        }
        if self.crash_prob <= 0.0 {
            return false;
        }
        let mut rng = Rng::new(
            self.seed ^ CRASH_SALT ^ (task as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        rng.bool(self.crash_prob)
    }

    /// How far task `task`'s machine got before crashing, as a fraction of
    /// its planned work in `[0, 1)` — deterministic from (seed, task), or
    /// pinned for every task via [`FaultPlan::crash_progress`]. `Resume`
    /// floors this to the last checkpoint boundary to decide what is
    /// salvageable.
    pub fn crash_point(&self, task: usize) -> f64 {
        if let Some(f) = self.crash_progress {
            return f;
        }
        let mut rng = Rng::new(
            self.seed ^ SALVAGE_SALT ^ (task as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        rng.f64()
    }

    /// The wallclock multiplier for task `task`, if it straggles.
    pub fn straggle(&self, task: usize) -> Option<f64> {
        if self.straggle_prob <= 0.0 {
            return None;
        }
        let mut rng = Rng::new(
            self.seed ^ STRAGGLE_SALT ^ (task as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        rng.bool(self.straggle_prob).then_some(self.straggle_factor)
    }
}

/// What a stage does when a machine crashes (or a task exhausts attempts).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RecoveryPolicy {
    /// Re-execute until success; abort the job on exhaustion (the classic
    /// MapReduce behavior, and the only option before machine crashes
    /// existed). A crashed machine makes every attempt fail, so `Retry`
    /// turns crashes into job aborts.
    #[default]
    Retry,
    /// Proceed with the surviving machines and report the ground-set
    /// coverage lost (graceful degradation).
    DropShard,
    /// Rebuild each crashed shard from replicas surviving on other machines
    /// and re-run its task — with multiplicity ≥ 2, provably equal to the
    /// fault-free output whenever every element survives somewhere.
    SurvivorMerge,
    /// Like `SurvivorMerge`, but additionally salvage the crashed machine's
    /// last durable checkpoint (its greedy prefix / sieve ladder, taken
    /// every `RunSpec::checkpoint_every` work units) and replay only the
    /// tail under the same per-machine RNG fork — bit-identical to the
    /// fault-free shard output while recomputing strictly less. Falls back
    /// to a full `SurvivorMerge` recompute when checkpointing is off or the
    /// rebuilt shard is incomplete.
    Resume,
}

impl RecoveryPolicy {
    pub const ALL: [RecoveryPolicy; 4] = [
        RecoveryPolicy::Retry,
        RecoveryPolicy::DropShard,
        RecoveryPolicy::SurvivorMerge,
        RecoveryPolicy::Resume,
    ];

    pub fn parse(s: &str) -> Option<RecoveryPolicy> {
        Some(match s {
            "retry" => RecoveryPolicy::Retry,
            "drop_shard" => RecoveryPolicy::DropShard,
            "survivor_merge" => RecoveryPolicy::SurvivorMerge,
            "resume" => RecoveryPolicy::Resume,
            _ => return None,
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            RecoveryPolicy::Retry => "retry",
            RecoveryPolicy::DropShard => "drop_shard",
            RecoveryPolicy::SurvivorMerge => "survivor_merge",
            RecoveryPolicy::Resume => "resume",
        }
    }

    /// Policies that rebuild crashed shards from surviving replicas.
    pub fn rebuilds(&self) -> bool {
        matches!(self, RecoveryPolicy::SurvivorMerge | RecoveryPolicy::Resume)
    }
}

/// Error when a task exhausts its attempts.
#[derive(Debug)]
pub struct StageFailed {
    pub task: usize,
    pub attempts: usize,
}

impl std::fmt::Display for StageFailed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "task {} failed {} attempts", self.task, self.attempts)
    }
}

impl std::error::Error for StageFailed {}

/// A stage run under a [`RecoveryPolicy`]: crashed tasks produce `None`
/// outputs (in task order) instead of aborting the stage.
#[derive(Debug)]
pub struct PoliciedStage<R> {
    /// Per-task results; `None` where the machine crashed (or exhausted its
    /// attempts under a non-`Retry` policy).
    pub outputs: Vec<Option<R>>,
    pub report: StageReport,
    /// Total failed attempts that were re-executed.
    pub retries: usize,
    /// Tasks lost for the stage, in task order.
    pub crashed: Vec<usize>,
    /// Tasks whose wallclock was inflated by the straggle factor.
    pub straggled: Vec<usize>,
}

/// One task's attempt loop: re-execute until an attempt survives the fault
/// coin, charging every attempt's (possibly straggler-inflated) wallclock.
enum TaskRun<R> {
    Done { out: R, time: f64, retries: usize },
    Exhausted { retries: usize },
}

fn attempt_loop<T, R, F>(i: usize, input: T, plan: &FaultPlan, f: &F) -> TaskRun<R>
where
    T: Clone,
    F: Fn(usize, T) -> R,
{
    let mut time = 0.0;
    let mut retries = 0usize;
    for attempt in 0..plan.max_attempts {
        let t = Instant::now();
        let r = f(i, input.clone());
        let mut elapsed = t.elapsed().as_secs_f64();
        if let Some(factor) = plan.straggle(i) {
            elapsed *= factor;
        }
        time += elapsed;
        if plan.crashed(i) || plan.fails(i, attempt) {
            retries += 1;
            crate::trace_counter!("fault.retries").incr();
            trace::event_with("fault.retry", || {
                vec![("task", i.into()), ("attempt", attempt.into())]
            });
            continue; // attempt lost; result discarded like a dead container
        }
        return TaskRun::Done { out: r, time, retries };
    }
    TaskRun::Exhausted { retries }
}

/// Run a stage under a fault plan: each task is (re)executed until an
/// attempt succeeds; every attempt's wallclock is charged to the task
/// (a rescheduled container re-does the work). Inputs must be cloneable —
/// retries replay the same input, preserving determinism.
///
/// Tasks run on `threads` workers via the shared executor; outputs, retry
/// counts, and per-task times are bit-identical to the serial path at any
/// thread count, and on exhaustion the lowest-index failed task is reported
/// (exactly what the serial scan would hit first).
pub fn run_stage_with_faults<T, R, F>(
    inputs: Vec<T>,
    plan: &FaultPlan,
    threads: usize,
    f: F,
) -> Result<(Vec<R>, StageReport, usize), StageFailed>
where
    T: Clone + Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let runs = parallel_map(inputs, threads, |i, input| attempt_loop(i, input, plan, &f));
    let mut outputs = Vec::with_capacity(runs.len());
    let mut times = Vec::with_capacity(runs.len());
    let mut retries = 0usize;
    for (i, run) in runs.into_iter().enumerate() {
        match run {
            TaskRun::Done { out, time, retries: r } => {
                outputs.push(out);
                times.push(time);
                retries += r;
            }
            TaskRun::Exhausted { .. } => {
                return Err(StageFailed { task: i, attempts: plan.max_attempts })
            }
        }
    }
    let max_task_time = times.iter().cloned().fold(0.0, f64::max);
    let total_cpu_time = times.iter().sum();
    Ok((
        outputs,
        StageReport { task_times: times, max_task_time, total_cpu_time },
        retries,
    ))
}

/// Run a stage under a fault plan *and* a recovery policy.
///
/// `Retry` delegates to [`run_stage_with_faults`] (abort on exhaustion).
/// `DropShard` / `SurvivorMerge` never abort: crashed machines are skipped
/// entirely (no attempts run, `None` output, zero recorded time), transient
/// failures are still retried, and a task that exhausts its attempts is
/// treated as crashed. What to do with the `None` slots — drop them or
/// rebuild from replicas — is the protocol's job.
pub fn run_stage_policied<T, R, F>(
    inputs: Vec<T>,
    plan: &FaultPlan,
    policy: RecoveryPolicy,
    threads: usize,
    f: F,
) -> Result<PoliciedStage<R>, StageFailed>
where
    T: Clone + Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    let n = inputs.len();
    if policy == RecoveryPolicy::Retry {
        let (outputs, report, retries) = run_stage_with_faults(inputs, plan, threads, f)?;
        let straggled = (0..n).filter(|&i| plan.straggle(i).is_some()).collect();
        return Ok(PoliciedStage {
            outputs: outputs.into_iter().map(Some).collect(),
            report,
            retries,
            crashed: Vec::new(),
            straggled,
        });
    }

    let runs = parallel_map(inputs, threads, |i, input| {
        if plan.crashed(i) {
            crate::trace_counter!("fault.crashes").incr();
            trace::event_with("fault.crash", || vec![("task", i.into())]);
            None
        } else {
            Some(attempt_loop(i, input, plan, &f))
        }
    });
    let mut outputs = Vec::with_capacity(n);
    let mut times = Vec::with_capacity(n);
    let mut retries = 0usize;
    let mut crashed = Vec::new();
    let mut straggled = Vec::new();
    for (i, run) in runs.into_iter().enumerate() {
        match run {
            None => {
                outputs.push(None);
                times.push(0.0);
                crashed.push(i);
            }
            Some(TaskRun::Done { out, time, retries: r }) => {
                outputs.push(Some(out));
                times.push(time);
                retries += r;
                if plan.straggle(i).is_some() {
                    crate::trace_counter!("fault.straggles").incr();
                    straggled.push(i);
                }
            }
            Some(TaskRun::Exhausted { retries: r }) => {
                // attempts exhausted => machine effectively lost for the stage
                outputs.push(None);
                times.push(0.0);
                retries += r;
                crashed.push(i);
            }
        }
    }
    let max_task_time = times.iter().cloned().fold(0.0, f64::max);
    let total_cpu_time = times.iter().sum();
    Ok(PoliciedStage {
        outputs,
        report: StageReport { task_times: times, max_task_time, total_cpu_time },
        retries,
        crashed,
        straggled,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_faults_matches_plain_stage() {
        let (out, rep, retries) =
            run_stage_with_faults((0..10).collect(), &FaultPlan::none(), 1, |_, x: i32| x * 2)
                .unwrap();
        assert_eq!(out, (0..10).map(|x| x * 2).collect::<Vec<_>>());
        assert_eq!(retries, 0);
        assert_eq!(rep.task_times.len(), 10);
    }

    #[test]
    fn retries_preserve_outputs() {
        let plan = FaultPlan::new(0.4, 20, 7);
        let (out, _, retries) =
            run_stage_with_faults((0..50).collect(), &plan, 1, |i, x: i32| x + i as i32)
                .unwrap();
        let (base, _, _) =
            run_stage_with_faults((0..50).collect(), &FaultPlan::none(), 1, |i, x: i32| {
                x + i as i32
            })
            .unwrap();
        assert_eq!(out, base, "faults must not change results");
        assert!(retries > 0, "plan with p=0.4 over 50 tasks must fail sometimes");
    }

    #[test]
    fn parallel_faulted_stage_matches_serial() {
        let plan = FaultPlan::new(0.5, 30, 19);
        let (serial, _, serial_retries) =
            run_stage_with_faults((0..40).collect(), &plan, 1, |i, x: i32| x * 3 + i as i32)
                .unwrap();
        for threads in [2, 4, 8] {
            let (par, _, par_retries) =
                run_stage_with_faults((0..40).collect(), &plan, threads, |i, x: i32| {
                    x * 3 + i as i32
                })
                .unwrap();
            assert_eq!(par, serial, "threads={threads}: outputs drifted");
            assert_eq!(par_retries, serial_retries, "threads={threads}: retry count drifted");
        }
    }

    #[test]
    fn failed_attempts_charge_time() {
        let plan = FaultPlan::new(0.9, 50, 3);
        let (_, rep_faulty, retries) =
            run_stage_with_faults(vec![500_000usize], &plan, 1, |_, n| {
                (0..n as u64).map(std::hint::black_box).sum::<u64>()
            })
            .unwrap();
        assert!(retries >= 1);
        let (_, rep_clean, _) =
            run_stage_with_faults(vec![500_000usize], &FaultPlan::none(), 1, |_, n| {
                (0..n as u64).map(std::hint::black_box).sum::<u64>()
            })
            .unwrap();
        assert!(
            rep_faulty.max_task_time > rep_clean.max_task_time,
            "retries must inflate the task time"
        );
    }

    #[test]
    fn exhausted_attempts_abort() {
        // fail_prob = 1.0 is now expressible: guaranteed failure, one pass.
        let plan = FaultPlan::new(1.0, 2, 3);
        let err = run_stage_with_faults(vec![1, 2, 3], &plan, 1, |_, x: i32| x).unwrap_err();
        assert_eq!(err.task, 0, "lowest-index exhausted task reported");
        assert_eq!(err.attempts, 2);
        // parallel path reports the same task
        let err = run_stage_with_faults(vec![1, 2, 3], &plan, 4, |_, x: i32| x).unwrap_err();
        assert_eq!(err.task, 0);
    }

    #[test]
    fn fault_plan_deterministic() {
        let p = FaultPlan::new(0.3, 5, 11).crashes(0.2).stragglers(0.2, 4.0);
        for task in 0..20 {
            for attempt in 0..5 {
                assert_eq!(p.fails(task, attempt), p.fails(task, attempt));
            }
            assert_eq!(p.crashed(task), p.crashed(task));
            assert_eq!(p.straggle(task), p.straggle(task));
        }
    }

    #[test]
    fn crash_coin_independent_of_fail_coin() {
        // same seed, crash draws must not mirror attempt-failure draws
        let p = FaultPlan::new(0.5, 5, 42).crashes(0.5);
        let fails: Vec<bool> = (0..64).map(|t| p.fails(t, 0)).collect();
        let crashes: Vec<bool> = (0..64).map(|t| p.crashed(t)).collect();
        assert_ne!(fails, crashes, "crash salt collapsed onto the fail salt");
    }

    #[test]
    fn explicit_crash_tasks_skipped_under_drop_policy() {
        let plan = FaultPlan::none().crash_tasks(vec![1, 3]);
        assert!(plan.active());
        let stage = run_stage_policied(
            (0..5).collect(),
            &plan,
            RecoveryPolicy::DropShard,
            1,
            |_, x: i32| x * 10,
        )
        .unwrap();
        assert_eq!(stage.crashed, vec![1, 3]);
        let got: Vec<Option<i32>> = stage.outputs;
        assert_eq!(got, vec![Some(0), None, Some(20), None, Some(40)]);
        assert_eq!(stage.report.task_times[1], 0.0, "crashed task charges no time");
        assert_eq!(stage.retries, 0);
    }

    #[test]
    fn crash_under_retry_aborts_the_stage() {
        let plan = FaultPlan::none().crash_tasks(vec![2]);
        let err = run_stage_policied(
            (0..4).collect(),
            &plan,
            RecoveryPolicy::Retry,
            1,
            |_, x: i32| x,
        )
        .unwrap_err();
        assert_eq!(err.task, 2);
    }

    #[test]
    fn exhaustion_becomes_crash_under_survivor_merge() {
        let plan = FaultPlan::new(1.0, 3, 9);
        let stage = run_stage_policied(
            (0..3).collect(),
            &plan,
            RecoveryPolicy::SurvivorMerge,
            1,
            |_, x: i32| x,
        )
        .unwrap();
        assert_eq!(stage.crashed, vec![0, 1, 2]);
        assert!(stage.outputs.iter().all(Option::is_none));
        assert_eq!(stage.retries, 9, "3 tasks x 3 exhausted attempts");
    }

    #[test]
    fn stragglers_inflate_time_without_touching_outputs() {
        let plan = FaultPlan::new(0.0, 1, 5).stragglers(1.0, 1000.0);
        assert!(plan.active(), "straggler-only plan must count as active");
        let work = |_: usize, n: usize| (0..n as u64).map(std::hint::black_box).sum::<u64>();
        let stage = run_stage_policied(
            vec![200_000usize; 4],
            &plan,
            RecoveryPolicy::DropShard,
            1,
            work,
        )
        .unwrap();
        let (base, base_rep, _) =
            run_stage_with_faults(vec![200_000usize; 4], &FaultPlan::none(), 1, work).unwrap();
        assert_eq!(stage.outputs.into_iter().flatten().collect::<Vec<_>>(), base);
        assert_eq!(stage.straggled, vec![0, 1, 2, 3]);
        assert!(
            stage.report.max_task_time > base_rep.max_task_time * 10.0,
            "factor 1000 must dominate timing noise: {} vs {}",
            stage.report.max_task_time,
            base_rep.max_task_time
        );
    }

    #[test]
    fn without_crashes_keeps_transient_faults() {
        let plan = FaultPlan::new(0.4, 8, 21).crashes(0.9).crash_tasks(vec![0]);
        let stripped = plan.without_crashes();
        assert!(stripped.active());
        assert!(!stripped.crashed(0));
        assert_eq!(stripped.fail_prob, plan.fail_prob);
        for task in 0..16 {
            assert_eq!(stripped.fails(task, 0), plan.fails(task, 0));
        }
    }

    #[test]
    fn recovery_policy_parse_label_roundtrip() {
        for policy in RecoveryPolicy::ALL {
            assert_eq!(RecoveryPolicy::parse(policy.label()), Some(policy));
        }
        assert!(RecoveryPolicy::parse("pray").is_none());
        assert_eq!(RecoveryPolicy::default(), RecoveryPolicy::Retry);
        assert!(RecoveryPolicy::Resume.rebuilds() && RecoveryPolicy::SurvivorMerge.rebuilds());
        assert!(!RecoveryPolicy::Retry.rebuilds() && !RecoveryPolicy::DropShard.rebuilds());
    }

    #[test]
    fn domain_map_assigns_and_counts() {
        assert_eq!(DomainMap::None.domain_of(7), 7);
        assert_eq!(DomainMap::None.count(5), 5);
        let modulo = DomainMap::Modulo(3);
        assert_eq!((0..6).map(|t| modulo.domain_of(t)).collect::<Vec<_>>(), vec![0, 1, 2, 0, 1, 2]);
        assert_eq!(modulo.count(6), 3);
        assert_eq!(modulo.count(2), 2, "fewer machines than domains");
        let explicit = DomainMap::Explicit(vec![0, 0, 1, 1]);
        assert_eq!(explicit.domain_of(1), 0);
        assert_eq!(explicit.count(4), 2);
        // machines beyond the explicit map get private distinct domains
        assert_ne!(explicit.domain_of(4), explicit.domain_of(5));
        assert_eq!(explicit.count(6), 4);
        assert!(DomainMap::None.is_trivial() && DomainMap::Modulo(1).is_trivial());
        assert!(!modulo.is_trivial() && !explicit.is_trivial());
    }

    #[test]
    fn domain_crashes_take_whole_groups_atomically() {
        // 8 machines in 4 racks of 2; every rack coin fires for the pair or
        // not at all, at any seed.
        for seed in [3u64, 11, 1234] {
            let plan = FaultPlan::new(0.0, 1, seed).domain_groups(4).domain_crashes(0.5);
            assert!(plan.active(), "domain-crash plan must count as active");
            for rack in 0..4 {
                assert_eq!(
                    plan.crashed(rack),
                    plan.crashed(rack + 4),
                    "seed={seed}: rack {rack} lost only half its machines"
                );
                assert_eq!(plan.crashed(rack), plan.domain_crashed(rack));
            }
        }
    }

    #[test]
    fn pinned_domain_crash_and_stripping() {
        let plan = FaultPlan::new(0.3, 5, 9).domain_groups(3).crash_domains(vec![1]);
        assert!(plan.active());
        assert!(plan.crashed(1) && plan.crashed(4) && plan.crashed(7));
        assert!(!plan.crashed(0) && !plan.crashed(2));
        let stripped = plan.without_crashes();
        assert!((0..9).all(|t| !stripped.crashed(t)), "domain crashes must strip");
        assert_eq!(stripped.domains, plan.domains, "domain map survives stripping");
        for t in 0..9 {
            assert_eq!(stripped.fails(t, 0), plan.fails(t, 0), "transients survive stripping");
        }
    }

    #[test]
    fn domain_crash_coin_independent_of_machine_crash_coin() {
        // one machine per domain: domain crashes degenerate to per-machine
        // crashes, but the salted draws must differ at the same seed
        let per_machine = FaultPlan::new(0.0, 1, 42).crashes(0.5);
        let per_domain = FaultPlan::new(0.0, 1, 42).domain_crashes(0.5);
        let a: Vec<bool> = (0..64).map(|t| per_machine.crashed(t)).collect();
        let b: Vec<bool> = (0..64).map(|t| per_domain.crashed(t)).collect();
        assert_ne!(a, b, "domain salt collapsed onto the machine-crash salt");
    }

    #[test]
    fn transient_coins_correlate_within_a_domain() {
        let correlated = FaultPlan::new(0.4, 6, 21).domain_groups(2);
        for attempt in 0..6 {
            assert_eq!(correlated.fails(0, attempt), correlated.fails(2, attempt));
            assert_eq!(correlated.fails(1, attempt), correlated.fails(3, attempt));
        }
        // without a domain map the per-task coins must NOT all agree
        let independent = FaultPlan::new(0.4, 6, 21);
        let agree = (0..32).all(|t| {
            (0..6).all(|a| independent.fails(2 * t, a) == independent.fails(2 * t + 1, a))
        });
        assert!(!agree, "task-keyed coins should differ across machines somewhere");
    }

    #[test]
    fn crash_point_is_deterministic_and_pinnable() {
        let plan = FaultPlan::new(0.0, 1, 13).crashes(0.5);
        for t in 0..32 {
            let p = plan.crash_point(t);
            assert!((0.0..1.0).contains(&p));
            assert_eq!(p.to_bits(), plan.crash_point(t).to_bits());
        }
        // different tasks see different crash points (salted per task)
        assert_ne!(plan.crash_point(0).to_bits(), plan.crash_point(1).to_bits());
        let pinned = FaultPlan::none().crash_progress(0.75);
        assert_eq!(pinned.crash_point(0), 0.75);
        assert_eq!(pinned.crash_point(17), 0.75);
    }
}
