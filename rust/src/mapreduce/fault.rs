//! Fault injection + retry for the simulated MapReduce runtime.
//!
//! The paper's Hadoop deployment leans on MapReduce's core resilience
//! property: failed tasks are rescheduled and the job still completes with
//! identical output (map tasks are deterministic and side-effect-free).
//! This module models that: a [`FaultPlan`] decides, deterministically from
//! a seed, which (task, attempt) pairs fail; [`run_stage_with_faults`]
//! re-executes failed tasks up to `max_attempts`, charging each attempt's
//! wallclock to the stage like a real re-scheduled container would be.
//!
//! Because GreeDi's map tasks are pure functions of (shard, seed), retries
//! cannot change the protocol's output — asserted by the integration tests.

use std::time::Instant;

use super::StageReport;
use crate::util::rng::Rng;

/// Deterministic per-(task, attempt) failure oracle.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    /// Probability a given task attempt fails.
    pub fail_prob: f64,
    /// Attempts per task before the stage aborts.
    pub max_attempts: usize,
    seed: u64,
}

impl FaultPlan {
    pub fn new(fail_prob: f64, max_attempts: usize, seed: u64) -> Self {
        assert!((0.0..1.0).contains(&fail_prob));
        assert!(max_attempts >= 1);
        FaultPlan { fail_prob, max_attempts, seed }
    }

    /// No faults (baseline).
    pub fn none() -> Self {
        FaultPlan { fail_prob: 0.0, max_attempts: 1, seed: 0 }
    }

    /// Does attempt `attempt` of task `task` fail?
    pub fn fails(&self, task: usize, attempt: usize) -> bool {
        if self.fail_prob <= 0.0 {
            return false;
        }
        let mut rng = Rng::new(
            self.seed ^ (task as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (attempt as u64).wrapping_mul(0xC2B2_AE3D_27D4_EB4F),
        );
        rng.bool(self.fail_prob)
    }
}

/// Error when a task exhausts its attempts.
#[derive(Debug)]
pub struct StageFailed {
    pub task: usize,
    pub attempts: usize,
}

impl std::fmt::Display for StageFailed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "task {} failed {} attempts", self.task, self.attempts)
    }
}

impl std::error::Error for StageFailed {}

/// Run a stage under a fault plan: each task is (re)executed until an
/// attempt succeeds; every attempt's wallclock is charged to the task
/// (a rescheduled container re-does the work). Inputs must be cloneable —
/// retries replay the same input, preserving determinism.
pub fn run_stage_with_faults<T, R, F>(
    inputs: Vec<T>,
    plan: &FaultPlan,
    f: F,
) -> Result<(Vec<R>, StageReport, usize), StageFailed>
where
    T: Clone,
    F: Fn(usize, T) -> R,
{
    let mut outputs = Vec::with_capacity(inputs.len());
    let mut times = Vec::with_capacity(inputs.len());
    let mut retries = 0usize;
    for (i, input) in inputs.into_iter().enumerate() {
        let mut task_time = 0.0;
        let mut done = None;
        for attempt in 0..plan.max_attempts {
            let t = Instant::now();
            let r = f(i, input.clone());
            task_time += t.elapsed().as_secs_f64();
            if plan.fails(i, attempt) {
                retries += 1;
                continue; // attempt lost; result discarded like a dead container
            }
            done = Some(r);
            break;
        }
        match done {
            Some(r) => {
                outputs.push(r);
                times.push(task_time);
            }
            None => return Err(StageFailed { task: i, attempts: plan.max_attempts }),
        }
    }
    let max_task_time = times.iter().cloned().fold(0.0, f64::max);
    let total_cpu_time = times.iter().sum();
    Ok((
        outputs,
        StageReport { task_times: times, max_task_time, total_cpu_time },
        retries,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn no_faults_matches_plain_stage() {
        let (out, rep, retries) =
            run_stage_with_faults((0..10).collect(), &FaultPlan::none(), |_, x: i32| x * 2)
                .unwrap();
        assert_eq!(out, (0..10).map(|x| x * 2).collect::<Vec<_>>());
        assert_eq!(retries, 0);
        assert_eq!(rep.task_times.len(), 10);
    }

    #[test]
    fn retries_preserve_outputs() {
        let plan = FaultPlan::new(0.4, 20, 7);
        let (out, _, retries) =
            run_stage_with_faults((0..50).collect(), &plan, |i, x: i32| x + i as i32).unwrap();
        let (base, _, _) =
            run_stage_with_faults((0..50).collect(), &FaultPlan::none(), |i, x: i32| {
                x + i as i32
            })
            .unwrap();
        assert_eq!(out, base, "faults must not change results");
        assert!(retries > 0, "plan with p=0.4 over 50 tasks must fail sometimes");
    }

    #[test]
    fn failed_attempts_charge_time() {
        let plan = FaultPlan::new(0.9, 50, 3);
        let (_, rep_faulty, retries) =
            run_stage_with_faults(vec![500_000usize], &plan, |_, n| {
                (0..n as u64).map(std::hint::black_box).sum::<u64>()
            })
            .unwrap();
        assert!(retries >= 1);
        let (_, rep_clean, _) =
            run_stage_with_faults(vec![500_000usize], &FaultPlan::none(), |_, n| {
                (0..n as u64).map(std::hint::black_box).sum::<u64>()
            })
            .unwrap();
        assert!(
            rep_faulty.max_task_time > rep_clean.max_task_time,
            "retries must inflate the task time"
        );
    }

    #[test]
    fn exhausted_attempts_abort() {
        // fail_prob ~1 with 1 attempt => guaranteed failure
        let plan = FaultPlan::new(0.999, 1, 3);
        let mut failed = false;
        for _ in 0..5 {
            if run_stage_with_faults(vec![1, 2, 3], &plan, |_, x: i32| x).is_err() {
                failed = true;
                break;
            }
        }
        assert!(failed);
    }

    #[test]
    fn fault_plan_deterministic() {
        let p = FaultPlan::new(0.3, 5, 11);
        for task in 0..20 {
            for attempt in 0..5 {
                assert_eq!(p.fails(task, attempt), p.fails(task, attempt));
            }
        }
    }
}
