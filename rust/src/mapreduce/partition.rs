//! Ground-set partitioning strategies (paper step 1: "Partition V into m
//! sets V₁ … V_m (arbitrarily or at random)"). Random uniform assignment is
//! what Theorems 8–11 assume; round-robin and contiguous partitions exist
//! for ablations of that assumption.
//!
//! [`PartitionStrategy`] is the enum every protocol's `RunSpec` carries; it
//! lives here (not in the coordinator) because partitioning is a MapReduce
//! concern, not a GreeDi-specific one.

use crate::util::rng::Rng;

/// How the ground set is spread over machines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionStrategy {
    /// Uniform random assignment (the theory's assumption).
    Random,
    /// Shuffled round-robin (equal shard sizes).
    Balanced,
    /// Contiguous slices (no randomization — ablation / worst case).
    Contiguous,
}

impl PartitionStrategy {
    pub const ALL: [PartitionStrategy; 3] = [
        PartitionStrategy::Random,
        PartitionStrategy::Balanced,
        PartitionStrategy::Contiguous,
    ];

    /// Parse a config/CLI spelling.
    pub fn parse(s: &str) -> Option<PartitionStrategy> {
        Some(match s {
            "random" => PartitionStrategy::Random,
            "balanced" => PartitionStrategy::Balanced,
            "contiguous" => PartitionStrategy::Contiguous,
            _ => return None,
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            PartitionStrategy::Random => "random",
            PartitionStrategy::Balanced => "balanced",
            PartitionStrategy::Contiguous => "contiguous",
        }
    }

    /// Split `ground` into `m` shards under this strategy.
    pub fn split(&self, ground: &[usize], m: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
        match self {
            PartitionStrategy::Random => random_partition(ground, m, rng),
            PartitionStrategy::Balanced => balanced_partition(ground, m, rng),
            PartitionStrategy::Contiguous => contiguous_partition(ground, m),
        }
    }
}

/// Uniformly random assignment of each element to one of `m` machines.
/// Shards can differ in size (multinomial), exactly as the theory assumes.
pub fn random_partition(ground: &[usize], m: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    assert!(m >= 1);
    let mut shards = vec![Vec::with_capacity(ground.len() / m + 1); m];
    for &e in ground {
        shards[rng.below(m)].push(e);
    }
    shards
}

/// Balanced random partition: shuffle then deal round-robin — shard sizes
/// differ by at most one (what the paper's Hadoop deployment does with
/// fixed-size reducer inputs).
pub fn balanced_partition(ground: &[usize], m: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    assert!(m >= 1);
    let mut ids = ground.to_vec();
    rng.shuffle(&mut ids);
    let mut shards = vec![Vec::with_capacity(ids.len() / m + 1); m];
    for (i, e) in ids.into_iter().enumerate() {
        shards[i % m].push(e);
    }
    shards
}

/// Contiguous (adversarial-ish) partition: no randomization at all. Used by
/// ablations and by the worst-case instance, which needs the adversarial
/// grouping to bite.
pub fn contiguous_partition(ground: &[usize], m: usize) -> Vec<Vec<usize>> {
    assert!(m >= 1);
    let n = ground.len();
    let base = n / m;
    let extra = n % m;
    let mut shards = Vec::with_capacity(m);
    let mut at = 0;
    for i in 0..m {
        let len = base + usize::from(i < extra);
        shards.push(ground[at..at + len].to_vec());
        at += len;
    }
    shards
}

/// Verify that `shards` is an exact partition of `ground` (diagnostics and
/// property tests).
pub fn check_is_partition(ground: &[usize], shards: &[Vec<usize>]) -> bool {
    let mut all: Vec<usize> = shards.iter().flatten().copied().collect();
    all.sort_unstable();
    let mut g = ground.to_vec();
    g.sort_unstable();
    all == g
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn random_partition_covers_ground() {
        let ground: Vec<usize> = (0..1000).collect();
        let mut rng = Rng::new(1);
        let shards = random_partition(&ground, 7, &mut rng);
        assert_eq!(shards.len(), 7);
        assert!(check_is_partition(&ground, &shards));
    }

    #[test]
    fn balanced_partition_sizes() {
        let ground: Vec<usize> = (0..103).collect();
        let mut rng = Rng::new(2);
        let shards = balanced_partition(&ground, 10, &mut rng);
        assert!(check_is_partition(&ground, &shards));
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert!(sizes.iter().all(|&s| s == 10 || s == 11), "{sizes:?}");
    }

    #[test]
    fn contiguous_partition_order_preserved() {
        let ground: Vec<usize> = (0..10).collect();
        let shards = contiguous_partition(&ground, 3);
        assert_eq!(shards[0], vec![0, 1, 2, 3]);
        assert_eq!(shards[1], vec![4, 5, 6]);
        assert_eq!(shards[2], vec![7, 8, 9]);
    }

    #[test]
    fn deterministic_given_seed() {
        let ground: Vec<usize> = (0..50).collect();
        let a = random_partition(&ground, 5, &mut Rng::new(9));
        let b = random_partition(&ground, 5, &mut Rng::new(9));
        assert_eq!(a, b);
    }

    #[test]
    fn single_machine_gets_everything() {
        let ground: Vec<usize> = (0..20).collect();
        let shards = random_partition(&ground, 1, &mut Rng::new(3));
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0].len(), 20);
    }

    #[test]
    fn all_strategies_disjoint_and_cover() {
        // non-contiguous, non-sorted ground ids to rule out positional luck
        let ground: Vec<usize> = (0..211).map(|i| i * 3 + 1).rev().collect();
        for strat in PartitionStrategy::ALL {
            let mut rng = Rng::new(17);
            let shards = strat.split(&ground, 6, &mut rng);
            assert_eq!(shards.len(), 6, "{}", strat.label());
            // exact multiset equality ⇒ disjoint + covering (ground has no dups)
            assert!(check_is_partition(&ground, &shards), "{}", strat.label());
            let mut seen = HashSet::new();
            for shard in &shards {
                for &e in shard {
                    assert!(seen.insert(e), "{}: duplicate element {e}", strat.label());
                }
            }
            assert_eq!(seen.len(), ground.len(), "{}", strat.label());
        }
    }

    #[test]
    fn all_strategies_deterministic_per_seed() {
        let ground: Vec<usize> = (0..300).collect();
        for strat in PartitionStrategy::ALL {
            let a = strat.split(&ground, 8, &mut Rng::new(21));
            let b = strat.split(&ground, 8, &mut Rng::new(21));
            assert_eq!(a, b, "{} not deterministic", strat.label());
        }
        // and a different seed must actually move the randomized strategies
        for strat in [PartitionStrategy::Random, PartitionStrategy::Balanced] {
            let a = strat.split(&ground, 8, &mut Rng::new(21));
            let c = strat.split(&ground, 8, &mut Rng::new(22));
            assert_ne!(a, c, "{} ignores the seed", strat.label());
        }
    }

    #[test]
    fn balanced_shard_sizes_differ_by_at_most_one() {
        for (n, m) in [(103, 10), (64, 8), (7, 3), (5, 8)] {
            let ground: Vec<usize> = (0..n).collect();
            let shards = PartitionStrategy::Balanced.split(&ground, m, &mut Rng::new(4));
            let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
            let (lo, hi) = (
                *sizes.iter().min().unwrap(),
                *sizes.iter().max().unwrap(),
            );
            assert!(hi - lo <= 1, "n={n} m={m}: sizes {sizes:?}");
        }
    }

    #[test]
    fn strategy_parse_label_roundtrip() {
        for strat in PartitionStrategy::ALL {
            assert_eq!(PartitionStrategy::parse(strat.label()), Some(strat));
        }
        assert!(PartitionStrategy::parse("quantum").is_none());
    }
}
