//! Ground-set partitioning strategies (paper step 1: "Partition V into m
//! sets V₁ … V_m (arbitrarily or at random)"). Random uniform assignment is
//! what Theorems 8–11 assume; round-robin and contiguous partitions exist
//! for ablations of that assumption.
//!
//! [`PartitionStrategy`] is the enum every protocol's `RunSpec` carries; it
//! lives here (not in the coordinator) because partitioning is a MapReduce
//! concern, not a GreeDi-specific one.
//!
//! Replicated splits additionally take a [`PlacementPolicy`]: `Anywhere`
//! keeps the PR 7 behavior (replicas land on any distinct machines), while
//! `DistinctDomains` spreads each element's `c` replicas across `c`
//! distinct *failure domains* (racks/zones from [`DomainMap`]) whenever
//! `c ≤ #domains` — replication is only as good as its placement under
//! correlated loss (Lucic et al., 1605.09619), and domain-spread placement
//! makes any single-domain crash survivable by construction.

use super::fault::DomainMap;
use crate::util::rng::Rng;

/// How the ground set is spread over machines.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionStrategy {
    /// Uniform random assignment (the theory's assumption).
    Random,
    /// Shuffled round-robin (equal shard sizes).
    Balanced,
    /// Contiguous slices (no randomization — ablation / worst case).
    Contiguous,
}

impl PartitionStrategy {
    pub const ALL: [PartitionStrategy; 3] = [
        PartitionStrategy::Random,
        PartitionStrategy::Balanced,
        PartitionStrategy::Contiguous,
    ];

    /// Parse a config/CLI spelling.
    pub fn parse(s: &str) -> Option<PartitionStrategy> {
        Some(match s {
            "random" => PartitionStrategy::Random,
            "balanced" => PartitionStrategy::Balanced,
            "contiguous" => PartitionStrategy::Contiguous,
            _ => return None,
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            PartitionStrategy::Random => "random",
            PartitionStrategy::Balanced => "balanced",
            PartitionStrategy::Contiguous => "contiguous",
        }
    }

    /// Split `ground` into `m` shards under this strategy.
    pub fn split(&self, ground: &[usize], m: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
        match self {
            PartitionStrategy::Random => random_partition(ground, m, rng),
            PartitionStrategy::Balanced => balanced_partition(ground, m, rng),
            PartitionStrategy::Contiguous => contiguous_partition(ground, m),
        }
    }

    /// Split `ground` into `m` shards with multiplicity `c`: every element
    /// lands on exactly `c` *distinct* machines (Lucic et al., 1605.09619).
    /// `c = 1` delegates to [`PartitionStrategy::split`] and is bit-identical
    /// to it — same RNG stream, same shards — so existing runs are unchanged.
    pub fn split_replicated(
        &self,
        ground: &[usize],
        m: usize,
        c: usize,
        rng: &mut Rng,
    ) -> Vec<Vec<usize>> {
        assert!(m >= 1);
        assert!(
            (1..=m).contains(&c),
            "multiplicity {c} must be in 1..={m} (machines)"
        );
        if c == 1 {
            return self.split(ground, m, rng);
        }
        match self {
            PartitionStrategy::Random => random_replicated(ground, m, c, rng),
            PartitionStrategy::Balanced => balanced_replicated(ground, m, c, rng),
            PartitionStrategy::Contiguous => contiguous_replicated(ground, m, c),
        }
    }

    /// Placement-aware replicated split. `Anywhere` delegates to
    /// [`PartitionStrategy::split_replicated`] on the *same* RNG stream —
    /// bit-identical to the pre-placement behavior. `DistinctDomains`
    /// spreads each element's `c` replicas over `c` distinct failure
    /// domains; it falls back to the `Anywhere` path when the domain map is
    /// trivial or there are fewer domains than replicas (`c > d`), where
    /// domain-distinct placement is impossible.
    pub fn split_placed(
        &self,
        ground: &[usize],
        m: usize,
        c: usize,
        placement: PlacementPolicy,
        domains: &DomainMap,
        rng: &mut Rng,
    ) -> Vec<Vec<usize>> {
        assert!(m >= 1);
        assert!(
            (1..=m).contains(&c),
            "multiplicity {c} must be in 1..={m} (machines)"
        );
        let d = domains.count(m);
        if placement == PlacementPolicy::Anywhere || c == 1 || domains.is_trivial() || c > d {
            return self.split_replicated(ground, m, c, rng);
        }
        let groups = machines_by_domain(m, domains);
        match self {
            PartitionStrategy::Random => random_domain_replicated(ground, &groups, c, rng),
            PartitionStrategy::Balanced => balanced_domain_replicated(ground, &groups, c, rng),
            PartitionStrategy::Contiguous => contiguous_domain_replicated(ground, m, &groups, c),
        }
    }
}

/// Where an element's `c` replicas may land (replicated splits only).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementPolicy {
    /// Any `c` distinct machines (the pre-domain behavior; bit-identical
    /// default).
    #[default]
    Anywhere,
    /// `c` distinct failure domains whenever `c ≤ #domains`, so losing any
    /// single rack/zone leaves every element on a survivor.
    DistinctDomains,
}

impl PlacementPolicy {
    pub const ALL: [PlacementPolicy; 2] =
        [PlacementPolicy::Anywhere, PlacementPolicy::DistinctDomains];

    pub fn parse(s: &str) -> Option<PlacementPolicy> {
        Some(match s {
            "anywhere" => PlacementPolicy::Anywhere,
            "distinct_domains" => PlacementPolicy::DistinctDomains,
            _ => return None,
        })
    }

    pub fn label(&self) -> &'static str {
        match self {
            PlacementPolicy::Anywhere => "anywhere",
            PlacementPolicy::DistinctDomains => "distinct_domains",
        }
    }
}

/// Machines grouped by failure domain, domains ordered by first machine
/// appearance (stable, machine-id independent of the raw domain labels).
fn machines_by_domain(m: usize, domains: &DomainMap) -> Vec<Vec<usize>> {
    let mut index: std::collections::HashMap<usize, usize> = std::collections::HashMap::new();
    let mut groups: Vec<Vec<usize>> = Vec::new();
    for machine in 0..m {
        let dom = domains.domain_of(machine);
        let gi = *index.entry(dom).or_insert_with(|| {
            groups.push(Vec::new());
            groups.len() - 1
        });
        groups[gi].push(machine);
    }
    groups
}

/// Uniformly random assignment of each element to one of `m` machines.
/// Shards can differ in size (multinomial), exactly as the theory assumes.
pub fn random_partition(ground: &[usize], m: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    assert!(m >= 1);
    let mut shards = vec![Vec::with_capacity(ground.len() / m + 1); m];
    for &e in ground {
        shards[rng.below(m)].push(e);
    }
    shards
}

/// Balanced random partition: shuffle then deal round-robin — shard sizes
/// differ by at most one (what the paper's Hadoop deployment does with
/// fixed-size reducer inputs).
pub fn balanced_partition(ground: &[usize], m: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    assert!(m >= 1);
    let mut ids = ground.to_vec();
    rng.shuffle(&mut ids);
    let mut shards = vec![Vec::with_capacity(ids.len() / m + 1); m];
    for (i, e) in ids.into_iter().enumerate() {
        shards[i % m].push(e);
    }
    shards
}

/// Contiguous (adversarial-ish) partition: no randomization at all. Used by
/// ablations and by the worst-case instance, which needs the adversarial
/// grouping to bite.
pub fn contiguous_partition(ground: &[usize], m: usize) -> Vec<Vec<usize>> {
    assert!(m >= 1);
    let n = ground.len();
    let base = n / m;
    let extra = n % m;
    let mut shards = Vec::with_capacity(m);
    let mut at = 0;
    for i in 0..m {
        let len = base + usize::from(i < extra);
        shards.push(ground[at..at + len].to_vec());
        at += len;
    }
    shards
}

/// Uniform replicated assignment: each element is sent to `c` distinct
/// machines drawn uniformly without replacement (Floyd's sampling), so the
/// per-element replica sets are independent across elements.
pub fn random_replicated(ground: &[usize], m: usize, c: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    assert!(m >= 1 && (1..=m).contains(&c));
    let mut shards = vec![Vec::with_capacity(ground.len() * c / m + 1); m];
    for &e in ground {
        for machine in rng.sample_indices(m, c) {
            shards[machine].push(e);
        }
    }
    shards
}

/// Balanced replicated assignment: shuffle once, then deal each element's
/// `c` replicas into consecutive machine slots `(i*c + r) % m`. Replica
/// machines are distinct because `c <= m`, and every machine receives
/// `n*c/m` elements up to ±1 (slot dealing is exactly round-robin over the
/// `n*c` replica stream).
pub fn balanced_replicated(ground: &[usize], m: usize, c: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    assert!(m >= 1 && (1..=m).contains(&c));
    let mut ids = ground.to_vec();
    rng.shuffle(&mut ids);
    let mut shards = vec![Vec::with_capacity(ids.len() * c / m + 1); m];
    for (i, e) in ids.into_iter().enumerate() {
        for r in 0..c {
            shards[(i * c + r) % m].push(e);
        }
    }
    shards
}

/// Contiguous replicated assignment: the `m` base slices of the contiguous
/// partition, with base slice `j` chained onto machines `(j + r) % m` for
/// `r in 0..c` — the classic chained-replication layout, so any `c - 1`
/// machine crashes still leave every slice on some survivor.
pub fn contiguous_replicated(ground: &[usize], m: usize, c: usize) -> Vec<Vec<usize>> {
    assert!(m >= 1 && (1..=m).contains(&c));
    let base = contiguous_partition(ground, m);
    let mut shards = vec![Vec::new(); m];
    for (j, slice) in base.iter().enumerate() {
        for r in 0..c {
            shards[(j + r) % m].extend_from_slice(slice);
        }
    }
    shards
}

/// Uniform domain-spread assignment: each element draws `c` distinct
/// domains (Floyd's sampling over domain groups), then one uniform machine
/// within each — the domain-aware analogue of [`random_replicated`].
fn random_domain_replicated(
    ground: &[usize],
    groups: &[Vec<usize>],
    c: usize,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    let m: usize = groups.iter().map(Vec::len).sum();
    let mut shards = vec![Vec::with_capacity(ground.len() * c / m + 1); m];
    for &e in ground {
        for gi in rng.sample_indices(groups.len(), c) {
            let within = &groups[gi];
            shards[within[rng.below(within.len())]].push(e);
        }
    }
    shards
}

/// Balanced domain-spread assignment: shuffle once, deal replica `r` of the
/// `i`-th shuffled element into domain `(i*c + r) % d`, and rotate a
/// per-domain cursor over that domain's machines so load stays even within
/// each rack as well as across racks.
fn balanced_domain_replicated(
    ground: &[usize],
    groups: &[Vec<usize>],
    c: usize,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    let d = groups.len();
    let m: usize = groups.iter().map(Vec::len).sum();
    let mut ids = ground.to_vec();
    rng.shuffle(&mut ids);
    let mut shards = vec![Vec::with_capacity(ids.len() * c / m + 1); m];
    let mut cursor = vec![0usize; d];
    for (i, e) in ids.into_iter().enumerate() {
        for r in 0..c {
            let gi = (i * c + r) % d;
            let within = &groups[gi];
            shards[within[cursor[gi] % within.len()]].push(e);
            cursor[gi] += 1;
        }
    }
    shards
}

/// Contiguous domain-spread assignment: base slice `j` stays home on
/// machine `j`, and replica `r ≥ 1` lands in domain `(dom(j) + r) % d` on
/// the machine at `j`'s rotation offset — chained replication across racks
/// instead of across machine ids, with no randomization.
fn contiguous_domain_replicated(
    ground: &[usize],
    m: usize,
    groups: &[Vec<usize>],
    c: usize,
) -> Vec<Vec<usize>> {
    let d = groups.len();
    // machine -> (its domain's group index, its position within the group)
    let mut slot = vec![(0usize, 0usize); m];
    for (gi, g) in groups.iter().enumerate() {
        for (pos, &machine) in g.iter().enumerate() {
            slot[machine] = (gi, pos);
        }
    }
    let base = contiguous_partition(ground, m);
    let mut shards = vec![Vec::new(); m];
    for (j, slice) in base.iter().enumerate() {
        let (home, pos) = slot[j];
        for r in 0..c {
            let within = &groups[(home + r) % d];
            // r = 0 keeps the slice on its home machine j
            shards[within[pos % within.len()]].extend_from_slice(slice);
        }
    }
    shards
}

/// Verify that `shards` is an exact partition of `ground` (diagnostics and
/// property tests).
pub fn check_is_partition(ground: &[usize], shards: &[Vec<usize>]) -> bool {
    let mut all: Vec<usize> = shards.iter().flatten().copied().collect();
    all.sort_unstable();
    let mut g = ground.to_vec();
    g.sort_unstable();
    all == g
}

/// Verify that `shards` is an exact `c`-replicated partition of `ground`:
/// every element appears on exactly `c` machines and at most once per
/// machine. `c = 1` reduces to [`check_is_partition`].
pub fn check_replicated_partition(ground: &[usize], shards: &[Vec<usize>], c: usize) -> bool {
    use std::collections::HashMap;
    let mut copies: HashMap<usize, usize> = HashMap::with_capacity(ground.len());
    for shard in shards {
        let mut in_shard = std::collections::HashSet::with_capacity(shard.len());
        for &e in shard {
            if !in_shard.insert(e) {
                return false; // duplicate within one machine
            }
            *copies.entry(e).or_insert(0) += 1;
        }
    }
    copies.len() == ground.len() && ground.iter().all(|e| copies.get(e) == Some(&c))
}

/// Verify domain-distinct placement: `shards` is an exact `c`-replicated
/// partition AND every element's `c` replicas live in `c` distinct failure
/// domains under `domains` — the invariant that makes any single-domain
/// crash survivable.
pub fn check_distinct_domain_placement(
    ground: &[usize],
    shards: &[Vec<usize>],
    c: usize,
    domains: &DomainMap,
) -> bool {
    if !check_replicated_partition(ground, shards, c) {
        return false;
    }
    let mut doms: std::collections::HashMap<usize, std::collections::HashSet<usize>> =
        std::collections::HashMap::with_capacity(ground.len());
    for (machine, shard) in shards.iter().enumerate() {
        let dom = domains.domain_of(machine);
        for &e in shard {
            if !doms.entry(e).or_default().insert(dom) {
                return false; // two replicas in the same failure domain
            }
        }
    }
    ground.iter().all(|e| doms.get(e).map(std::collections::HashSet::len) == Some(c))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn random_partition_covers_ground() {
        let ground: Vec<usize> = (0..1000).collect();
        let mut rng = Rng::new(1);
        let shards = random_partition(&ground, 7, &mut rng);
        assert_eq!(shards.len(), 7);
        assert!(check_is_partition(&ground, &shards));
    }

    #[test]
    fn balanced_partition_sizes() {
        let ground: Vec<usize> = (0..103).collect();
        let mut rng = Rng::new(2);
        let shards = balanced_partition(&ground, 10, &mut rng);
        assert!(check_is_partition(&ground, &shards));
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert!(sizes.iter().all(|&s| s == 10 || s == 11), "{sizes:?}");
    }

    #[test]
    fn contiguous_partition_order_preserved() {
        let ground: Vec<usize> = (0..10).collect();
        let shards = contiguous_partition(&ground, 3);
        assert_eq!(shards[0], vec![0, 1, 2, 3]);
        assert_eq!(shards[1], vec![4, 5, 6]);
        assert_eq!(shards[2], vec![7, 8, 9]);
    }

    #[test]
    fn deterministic_given_seed() {
        let ground: Vec<usize> = (0..50).collect();
        let a = random_partition(&ground, 5, &mut Rng::new(9));
        let b = random_partition(&ground, 5, &mut Rng::new(9));
        assert_eq!(a, b);
    }

    #[test]
    fn single_machine_gets_everything() {
        let ground: Vec<usize> = (0..20).collect();
        let shards = random_partition(&ground, 1, &mut Rng::new(3));
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0].len(), 20);
    }

    #[test]
    fn all_strategies_disjoint_and_cover() {
        // non-contiguous, non-sorted ground ids to rule out positional luck
        let ground: Vec<usize> = (0..211).map(|i| i * 3 + 1).rev().collect();
        for strat in PartitionStrategy::ALL {
            let mut rng = Rng::new(17);
            let shards = strat.split(&ground, 6, &mut rng);
            assert_eq!(shards.len(), 6, "{}", strat.label());
            // exact multiset equality ⇒ disjoint + covering (ground has no dups)
            assert!(check_is_partition(&ground, &shards), "{}", strat.label());
            let mut seen = HashSet::new();
            for shard in &shards {
                for &e in shard {
                    assert!(seen.insert(e), "{}: duplicate element {e}", strat.label());
                }
            }
            assert_eq!(seen.len(), ground.len(), "{}", strat.label());
        }
    }

    #[test]
    fn all_strategies_deterministic_per_seed() {
        let ground: Vec<usize> = (0..300).collect();
        for strat in PartitionStrategy::ALL {
            let a = strat.split(&ground, 8, &mut Rng::new(21));
            let b = strat.split(&ground, 8, &mut Rng::new(21));
            assert_eq!(a, b, "{} not deterministic", strat.label());
        }
        // and a different seed must actually move the randomized strategies
        for strat in [PartitionStrategy::Random, PartitionStrategy::Balanced] {
            let a = strat.split(&ground, 8, &mut Rng::new(21));
            let c = strat.split(&ground, 8, &mut Rng::new(22));
            assert_ne!(a, c, "{} ignores the seed", strat.label());
        }
    }

    #[test]
    fn balanced_shard_sizes_differ_by_at_most_one() {
        for (n, m) in [(103, 10), (64, 8), (7, 3), (5, 8)] {
            let ground: Vec<usize> = (0..n).collect();
            let shards = PartitionStrategy::Balanced.split(&ground, m, &mut Rng::new(4));
            let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
            let (lo, hi) = (
                *sizes.iter().min().unwrap(),
                *sizes.iter().max().unwrap(),
            );
            assert!(hi - lo <= 1, "n={n} m={m}: sizes {sizes:?}");
        }
    }

    #[test]
    fn replicated_c1_bit_identical_to_split() {
        let ground: Vec<usize> = (0..157).map(|i| i * 2 + 3).collect();
        for strat in PartitionStrategy::ALL {
            let plain = strat.split(&ground, 9, &mut Rng::new(31));
            let rep = strat.split_replicated(&ground, 9, 1, &mut Rng::new(31));
            assert_eq!(plain, rep, "{}: c=1 must not change the split", strat.label());
        }
    }

    #[test]
    fn replicated_every_element_on_exactly_c_machines() {
        let ground: Vec<usize> = (0..211).map(|i| i * 3 + 1).rev().collect();
        for strat in PartitionStrategy::ALL {
            for (m, c) in [(6, 2), (6, 3), (6, 6), (10, 4), (2, 2)] {
                let shards = strat.split_replicated(&ground, m, c, &mut Rng::new(5));
                assert_eq!(shards.len(), m);
                assert!(
                    check_replicated_partition(&ground, &shards, c),
                    "{} m={m} c={c}",
                    strat.label()
                );
            }
        }
    }

    #[test]
    fn replicated_deterministic_per_seed() {
        let ground: Vec<usize> = (0..120).collect();
        for strat in PartitionStrategy::ALL {
            let a = strat.split_replicated(&ground, 7, 3, &mut Rng::new(13));
            let b = strat.split_replicated(&ground, 7, 3, &mut Rng::new(13));
            assert_eq!(a, b, "{} not deterministic under replication", strat.label());
        }
    }

    #[test]
    fn balanced_replicated_shard_sizes_differ_by_at_most_one() {
        for (n, m, c) in [(103, 10, 2), (64, 8, 3), (30, 6, 5), (7, 3, 2)] {
            let ground: Vec<usize> = (0..n).collect();
            let shards =
                PartitionStrategy::Balanced.split_replicated(&ground, m, c, &mut Rng::new(4));
            let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
            let (lo, hi) = (*sizes.iter().min().unwrap(), *sizes.iter().max().unwrap());
            assert!(hi - lo <= 1, "n={n} m={m} c={c}: sizes {sizes:?}");
        }
    }

    #[test]
    fn contiguous_replicated_survives_any_c_minus_1_crashes() {
        // chained replication: dropping any c-1 machines keeps full coverage
        let ground: Vec<usize> = (0..60).collect();
        let (m, c) = (6, 3);
        let shards = contiguous_replicated(&ground, m, c);
        for a in 0..m {
            for b in 0..m {
                if a == b {
                    continue;
                }
                let survivors: HashSet<usize> = shards
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| *i != a && *i != b)
                    .flat_map(|(_, s)| s.iter().copied())
                    .collect();
                assert_eq!(survivors.len(), ground.len(), "crash {{{a},{b}}} lost data");
            }
        }
    }

    #[test]
    #[should_panic(expected = "multiplicity")]
    fn replication_cannot_exceed_machine_count() {
        let ground: Vec<usize> = (0..10).collect();
        PartitionStrategy::Random.split_replicated(&ground, 3, 4, &mut Rng::new(1));
    }

    #[test]
    fn strategy_parse_label_roundtrip() {
        for strat in PartitionStrategy::ALL {
            assert_eq!(PartitionStrategy::parse(strat.label()), Some(strat));
        }
        assert!(PartitionStrategy::parse("quantum").is_none());
    }

    #[test]
    fn placement_parse_label_roundtrip() {
        for p in PlacementPolicy::ALL {
            assert_eq!(PlacementPolicy::parse(p.label()), Some(p));
        }
        assert!(PlacementPolicy::parse("everywhere").is_none());
        assert_eq!(PlacementPolicy::default(), PlacementPolicy::Anywhere);
    }

    #[test]
    fn anywhere_placement_bit_identical_to_split_replicated() {
        // acceptance (c): the placement-aware entry point with the default
        // policy must consume the same RNG stream and return the same shards
        let ground: Vec<usize> = (0..157).map(|i| i * 2 + 3).collect();
        let domains = DomainMap::Modulo(3);
        for strat in PartitionStrategy::ALL {
            for c in [1, 2, 4] {
                let plain = strat.split_replicated(&ground, 9, c, &mut Rng::new(31));
                let placed = strat.split_placed(
                    &ground,
                    9,
                    c,
                    PlacementPolicy::Anywhere,
                    &domains,
                    &mut Rng::new(31),
                );
                assert_eq!(plain, placed, "{} c={c}", strat.label());
            }
        }
    }

    #[test]
    fn distinct_domains_spreads_replicas_across_domains() {
        let ground: Vec<usize> = (0..211).map(|i| i * 3 + 1).rev().collect();
        for strat in PartitionStrategy::ALL {
            for (m, d, c) in [(6, 3, 2), (6, 3, 3), (9, 3, 2), (12, 4, 4), (10, 5, 3)] {
                let domains = DomainMap::Modulo(d);
                let shards = strat.split_placed(
                    &ground,
                    m,
                    c,
                    PlacementPolicy::DistinctDomains,
                    &domains,
                    &mut Rng::new(5),
                );
                assert_eq!(shards.len(), m);
                assert!(
                    check_distinct_domain_placement(&ground, &shards, c, &domains),
                    "{} m={m} d={d} c={c}",
                    strat.label()
                );
            }
        }
    }

    #[test]
    fn distinct_domains_with_explicit_map_and_uneven_racks() {
        // racks of uneven size: {0,1,2}, {3}, {4,5}
        let domains = DomainMap::Explicit(vec![0, 0, 0, 1, 2, 2]);
        let ground: Vec<usize> = (0..97).collect();
        for strat in PartitionStrategy::ALL {
            let shards = strat.split_placed(
                &ground,
                6,
                2,
                PlacementPolicy::DistinctDomains,
                &domains,
                &mut Rng::new(23),
            );
            assert!(
                check_distinct_domain_placement(&ground, &shards, 2, &domains),
                "{}",
                strat.label()
            );
        }
    }

    #[test]
    fn distinct_domains_falls_back_when_impossible() {
        let ground: Vec<usize> = (0..60).collect();
        // c = 3 replicas but only 2 domains: placement is impossible, so the
        // split must silently take the Anywhere path (and stay valid)
        let domains = DomainMap::Modulo(2);
        for strat in PartitionStrategy::ALL {
            let placed = strat.split_placed(
                &ground,
                6,
                3,
                PlacementPolicy::DistinctDomains,
                &domains,
                &mut Rng::new(7),
            );
            let anywhere = strat.split_replicated(&ground, 6, 3, &mut Rng::new(7));
            assert_eq!(placed, anywhere, "{}", strat.label());
            // trivial map likewise
            let trivial = strat.split_placed(
                &ground,
                6,
                3,
                PlacementPolicy::DistinctDomains,
                &DomainMap::None,
                &mut Rng::new(7),
            );
            assert_eq!(trivial, anywhere, "{}", strat.label());
        }
    }

    #[test]
    fn distinct_domains_survives_any_single_domain_crash() {
        let ground: Vec<usize> = (0..120).collect();
        let (m, d, c) = (12, 4, 2);
        let domains = DomainMap::Modulo(d);
        for strat in PartitionStrategy::ALL {
            let shards = strat.split_placed(
                &ground,
                m,
                c,
                PlacementPolicy::DistinctDomains,
                &domains,
                &mut Rng::new(41),
            );
            for dead in 0..d {
                let survivors: HashSet<usize> = shards
                    .iter()
                    .enumerate()
                    .filter(|(i, _)| domains.domain_of(*i) != dead)
                    .flat_map(|(_, s)| s.iter().copied())
                    .collect();
                assert_eq!(
                    survivors.len(),
                    ground.len(),
                    "{}: domain {dead} crash lost data",
                    strat.label()
                );
            }
        }
    }

    #[test]
    fn checker_rejects_same_domain_replicas() {
        // both replicas on machines 0 and 1, which share domain 0
        let domains = DomainMap::Explicit(vec![0, 0, 1, 1]);
        let shards = vec![vec![5], vec![5], vec![], vec![]];
        assert!(check_replicated_partition(&[5], &shards, 2));
        assert!(!check_distinct_domain_placement(&[5], &shards, 2, &domains));
        let good = vec![vec![5], vec![], vec![5], vec![]];
        assert!(check_distinct_domain_placement(&[5], &good, 2, &domains));
    }

    #[test]
    fn balanced_distinct_domains_keeps_sizes_even() {
        let ground: Vec<usize> = (0..103).collect();
        let domains = DomainMap::Modulo(4);
        let shards = PartitionStrategy::Balanced.split_placed(
            &ground,
            12,
            2,
            PlacementPolicy::DistinctDomains,
            &domains,
            &mut Rng::new(4),
        );
        let sizes: Vec<usize> = shards.iter().map(Vec::len).collect();
        let (lo, hi) = (*sizes.iter().min().unwrap(), *sizes.iter().max().unwrap());
        assert!(hi - lo <= 2, "sizes {sizes:?}");
    }
}
