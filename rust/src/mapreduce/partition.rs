//! Ground-set partitioning strategies (paper step 1: "Partition V into m
//! sets V₁ … V_m (arbitrarily or at random)"). Random uniform assignment is
//! what Theorems 8–11 assume; round-robin and contiguous partitions exist
//! for ablations of that assumption.

use crate::util::rng::Rng;

/// Uniformly random assignment of each element to one of `m` machines.
/// Shards can differ in size (multinomial), exactly as the theory assumes.
pub fn random_partition(ground: &[usize], m: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    assert!(m >= 1);
    let mut shards = vec![Vec::with_capacity(ground.len() / m + 1); m];
    for &e in ground {
        shards[rng.below(m)].push(e);
    }
    shards
}

/// Balanced random partition: shuffle then deal round-robin — shard sizes
/// differ by at most one (what the paper's Hadoop deployment does with
/// fixed-size reducer inputs).
pub fn balanced_partition(ground: &[usize], m: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    assert!(m >= 1);
    let mut ids = ground.to_vec();
    rng.shuffle(&mut ids);
    let mut shards = vec![Vec::with_capacity(ids.len() / m + 1); m];
    for (i, e) in ids.into_iter().enumerate() {
        shards[i % m].push(e);
    }
    shards
}

/// Contiguous (adversarial-ish) partition: no randomization at all. Used by
/// ablations and by the worst-case instance, which needs the adversarial
/// grouping to bite.
pub fn contiguous_partition(ground: &[usize], m: usize) -> Vec<Vec<usize>> {
    assert!(m >= 1);
    let n = ground.len();
    let base = n / m;
    let extra = n % m;
    let mut shards = Vec::with_capacity(m);
    let mut at = 0;
    for i in 0..m {
        let len = base + usize::from(i < extra);
        shards.push(ground[at..at + len].to_vec());
        at += len;
    }
    shards
}

/// Verify that `shards` is an exact partition of `ground` (diagnostics and
/// property tests).
pub fn check_is_partition(ground: &[usize], shards: &[Vec<usize>]) -> bool {
    let mut all: Vec<usize> = shards.iter().flatten().copied().collect();
    all.sort_unstable();
    let mut g = ground.to_vec();
    g.sort_unstable();
    all == g
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn random_partition_covers_ground() {
        let ground: Vec<usize> = (0..1000).collect();
        let mut rng = Rng::new(1);
        let shards = random_partition(&ground, 7, &mut rng);
        assert_eq!(shards.len(), 7);
        assert!(check_is_partition(&ground, &shards));
    }

    #[test]
    fn balanced_partition_sizes() {
        let ground: Vec<usize> = (0..103).collect();
        let mut rng = Rng::new(2);
        let shards = balanced_partition(&ground, 10, &mut rng);
        assert!(check_is_partition(&ground, &shards));
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert!(sizes.iter().all(|&s| s == 10 || s == 11), "{sizes:?}");
    }

    #[test]
    fn contiguous_partition_order_preserved() {
        let ground: Vec<usize> = (0..10).collect();
        let shards = contiguous_partition(&ground, 3);
        assert_eq!(shards[0], vec![0, 1, 2, 3]);
        assert_eq!(shards[1], vec![4, 5, 6]);
        assert_eq!(shards[2], vec![7, 8, 9]);
    }

    #[test]
    fn deterministic_given_seed() {
        let ground: Vec<usize> = (0..50).collect();
        let a = random_partition(&ground, 5, &mut Rng::new(9));
        let b = random_partition(&ground, 5, &mut Rng::new(9));
        assert_eq!(a, b);
    }

    #[test]
    fn single_machine_gets_everything() {
        let ground: Vec<usize> = (0..20).collect();
        let shards = random_partition(&ground, 1, &mut Rng::new(3));
        assert_eq!(shards.len(), 1);
        assert_eq!(shards[0].len(), 20);
    }
}
