//! Fault-tolerance experiment — the replication-buys-recovery tradeoff.
//!
//! The paper's Hadoop runs lean on MapReduce re-execution for transient
//! failures; this harness measures what happens when whole machines (and
//! their shards) are lost. Sweeps solution quality against the machine
//! crash rate at m ∈ {10, 100} (parts a/b) × multiplicity c ∈ {1, 2, 3}
//! × recovery policy:
//!
//! * `retry` with transient attempt failures only — re-execution keeps the
//!   output bit-identical (ratio exactly 1), the classic MapReduce story;
//! * `drop_shard` — survivors only; quality degrades with the coverage lost;
//! * `survivor_merge` — crashed shards rebuilt from replicas on surviving
//!   machines; with c ≥ 2 the rebuild is almost always complete and the
//!   run recovers the fault-free output exactly.
//!
//! Reported per row: value ratio vs the fault-free run at the same (m, c)
//! and seed, mean ground-set coverage after crashes, mean crashed-machine
//! count, total retries, and recovery-stage wallclock.
//!
//! Part c compares **correlated** (whole failure domain at once) against
//! **independent** machine crashes at matched expected crash volume, across
//! replica placement (anywhere vs distinct_domains) and recovery policy
//! (survivor_merge vs resume with checkpoints) — the failure-domain story:
//! independent losses rarely hit both replicas, rack-correlated losses hit
//! them together unless placement forces the copies into distinct racks.

use std::sync::Arc;

use super::{ExpOpts, FigureReport};
use crate::coordinator::protocol::{self, FaultPlan, PlacementPolicy, Protocol, RecoveryPolicy};
use crate::coordinator::FacilityProblem;
use crate::data::synth::{gaussian_blobs, SynthConfig};
use crate::util::stats::mean;
use crate::util::table::Table;

/// Per-trial plan seeds fork off the spec seed with a fixed salt so the
/// crash coins are independent of the partition/algorithm randomness.
const PLAN_SALT: u64 = 0xFA17;

pub fn run(opts: &ExpOpts) -> FigureReport {
    let n = opts.size(1_200, 20_000);
    let d = 16;
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, d), opts.seed));
    let problem = FacilityProblem::new(&ds);
    let k = 20.min(n / 10).max(2);
    let greedi = protocol::by_name("greedi").expect("greedi registered");
    let trials = opts.trials.max(1);

    let mut body = format!(
        "replicated-shard fault tolerance: n={n}, d={d}, k={k}, trials={trials}\n\n"
    );

    // (policy, crash_prob, transient fail_prob). The retry row has no
    // crashes (a crash under retry aborts the job); the c≥2 survivor_merge
    // rows are where replication pays off.
    let rows: [(RecoveryPolicy, f64, f64); 6] = [
        (RecoveryPolicy::Retry, 0.0, 0.2),
        (RecoveryPolicy::SurvivorMerge, 0.0, 0.0),
        (RecoveryPolicy::DropShard, 0.1, 0.0),
        (RecoveryPolicy::SurvivorMerge, 0.1, 0.0),
        (RecoveryPolicy::DropShard, 0.3, 0.0),
        (RecoveryPolicy::SurvivorMerge, 0.3, 0.0),
    ];

    for (part, m) in [("a", 10usize), ("b", 100usize)] {
        if !opts.wants(part) {
            continue;
        }
        let mut t = Table::new(
            &format!("greedi under machine crashes (m={m}; ratio vs fault-free at same c, seed)"),
            &["c", "policy", "crash_p", "fail_p", "ratio", "coverage", "crashed", "retries", "rec_s"],
        );
        for c in [1usize, 2, 3] {
            if c > m {
                continue;
            }
            // Fault-free reference per trial seed at this (m, c).
            let refs: Vec<f64> = (0..trials)
                .map(|t_idx| {
                    let seed = trial_seed(opts.seed, t_idx);
                    let base = opts.spec(m, k, false, "lazy").multiplicity(c).seed(seed);
                    greedi.run(&problem, &base).value
                })
                .collect();

            for &(policy, crash_p, fail_p) in &rows {
                let mut ratios = Vec::with_capacity(trials);
                let mut coverages = Vec::with_capacity(trials);
                let mut crashed_counts = Vec::with_capacity(trials);
                let mut retries_total = 0usize;
                let mut rec_time = 0.0;
                for t_idx in 0..trials {
                    let seed = trial_seed(opts.seed, t_idx);
                    let max_attempts = if fail_p > 0.0 { 8 } else { 1 };
                    let plan =
                        FaultPlan::new(fail_p, max_attempts, seed ^ PLAN_SALT).crashes(crash_p);
                    let spec = opts
                        .spec(m, k, false, "lazy")
                        .multiplicity(c)
                        .seed(seed)
                        .recovery(policy)
                        .faults(plan);
                    let r = greedi.run(&problem, &spec);
                    ratios.push(r.value / refs[t_idx].max(f64::MIN_POSITIVE));
                    // An all-zero plan (the survivor_merge sanity row at
                    // crash_p = 0) is inactive => no FaultStats attached.
                    match r.fault.as_ref() {
                        Some(fs) => {
                            coverages.push(fs.coverage());
                            crashed_counts.push(fs.crashed_machines.len() as f64);
                            retries_total += fs.retries;
                            rec_time += fs.recovery_time;
                        }
                        None => {
                            coverages.push(1.0);
                            crashed_counts.push(0.0);
                        }
                    }
                }
                t.row(&[
                    c.to_string(),
                    policy.label().into(),
                    format!("{crash_p:.1}"),
                    format!("{fail_p:.1}"),
                    format!("{:.4}", mean(&ratios)),
                    format!("{:.3}", mean(&coverages)),
                    format!("{:.1}", mean(&crashed_counts)),
                    retries_total.to_string(),
                    format!("{rec_time:.4}"),
                ]);
            }
        }
        body.push_str(&t.render());
        body.push('\n');
    }

    // ---- Part c: correlated vs independent crashes, matched volume -------
    // A domain crash with probability p takes each machine out with the
    // same marginal probability p as an independent machine coin, but the
    // losses arrive rack-at-a-time: with `anywhere` placement a rack can
    // hold every replica of an element, while `distinct_domains` placement
    // guarantees a single-rack loss leaves coverage 1.
    if opts.wants("c") {
        let (m, d, c, p) = (12usize, 4usize, 2usize, 0.25);
        let mut t = Table::new(
            &format!(
                "correlated vs independent crashes (m={m}, domains={d}, c={c}, p={p}; \
                 matched expected crash volume; ratio vs fault-free at same placement, seed)"
            ),
            &["mode", "placement", "policy", "ratio", "coverage", "crashed", "salvaged", "replayed"],
        );
        for placement in PlacementPolicy::ALL {
            // Fault-free reference per trial seed at this placement: an
            // inactive plan that still carries the domain map, so the
            // placement-aware partition is identical to the faulted runs.
            let refs: Vec<f64> = (0..trials)
                .map(|t_idx| {
                    let seed = trial_seed(opts.seed, t_idx);
                    let base = opts
                        .spec(m, k, false, "lazy")
                        .multiplicity(c)
                        .placement(placement)
                        .seed(seed)
                        .faults(FaultPlan::none().domain_groups(d));
                    greedi.run(&problem, &base).value
                })
                .collect();
            for correlated in [false, true] {
                for policy in [RecoveryPolicy::SurvivorMerge, RecoveryPolicy::Resume] {
                    let mut ratios = Vec::with_capacity(trials);
                    let mut coverages = Vec::with_capacity(trials);
                    let mut crashed_counts = Vec::with_capacity(trials);
                    let mut salvaged = 0usize;
                    let mut replayed = 0usize;
                    for t_idx in 0..trials {
                        let seed = trial_seed(opts.seed, t_idx);
                        let plan = FaultPlan::new(0.0, 1, seed ^ PLAN_SALT).domain_groups(d);
                        let plan = if correlated {
                            plan.domain_crashes(p)
                        } else {
                            plan.crashes(p)
                        };
                        let spec = opts
                            .spec(m, k, false, "lazy")
                            .multiplicity(c)
                            .placement(placement)
                            .seed(seed)
                            .recovery(policy)
                            .checkpoint_every(4)
                            .faults(plan);
                        let r = greedi.run(&problem, &spec);
                        ratios.push(r.value / refs[t_idx].max(f64::MIN_POSITIVE));
                        match r.fault.as_ref() {
                            Some(fs) => {
                                coverages.push(fs.coverage());
                                crashed_counts.push(fs.crashed_machines.len() as f64);
                                salvaged += fs.salvaged_units;
                                replayed += fs.replayed_units;
                            }
                            None => {
                                coverages.push(1.0);
                                crashed_counts.push(0.0);
                            }
                        }
                    }
                    t.row(&[
                        if correlated { "correlated".into() } else { "independent".to_string() },
                        placement.label().into(),
                        policy.label().into(),
                        format!("{:.4}", mean(&ratios)),
                        format!("{:.3}", mean(&coverages)),
                        format!("{:.1}", mean(&crashed_counts)),
                        salvaged.to_string(),
                        replayed.to_string(),
                    ]);
                }
            }
        }
        body.push_str(&t.render());
        body.push('\n');
    }

    FigureReport { id: "fault_tolerance".into(), body }
}

fn trial_seed(base: u64, t_idx: usize) -> u64 {
    base.wrapping_add(t_idx as u64).wrapping_mul(0x9E37_79B9)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_run_covers_policies_and_multiplicities() {
        let opts = ExpOpts { n: Some(150), trials: 1, part: "a".into(), ..Default::default() };
        let rep = run(&opts);
        assert_eq!(rep.id, "fault_tolerance");
        for needle in ["retry", "drop_shard", "survivor_merge", "coverage", "m=10"] {
            assert!(rep.body.contains(needle), "missing {needle:?} in:\n{}", rep.body);
        }
        assert!(!rep.body.contains("m=100"), "part=a must skip the m=100 sweep");
        assert!(!rep.body.contains("correlated"), "part=a must skip the domain sweep");
    }

    #[test]
    fn tiny_run_part_c_sweeps_domains_placement_and_resume() {
        let opts = ExpOpts { n: Some(150), trials: 1, part: "c".into(), ..Default::default() };
        let rep = run(&opts);
        for needle in ["correlated", "independent", "anywhere", "distinct_domains", "resume", "salvaged"] {
            assert!(rep.body.contains(needle), "missing {needle:?} in:\n{}", rep.body);
        }
        assert!(!rep.body.contains("m=10;"), "part=c must skip the crash-rate sweeps");
    }
}
