//! Experiment harnesses — one module per figure in the paper's §6, plus a
//! theory-validation experiment for the bounds of §4. Each harness prints
//! (and returns) the same rows/series the paper's figure plots: the ratio
//! of the distributed to the centralized solution, per protocol, as m, k
//! or α sweeps.
//!
//! Every harness drives protocols exclusively through the unified
//! `protocol::by_name` + [`RunSpec`] API, so adding a protocol to the
//! registry makes it sweepable here for free.
//!
//! Default sizes are scaled down from the paper's corpora so the full suite
//! runs in minutes on one core (see DESIGN.md §3 for the substitutions);
//! `--full` or explicit `--n` lifts them toward paper scale.

pub mod ablations;
pub mod fanin;
pub mod fault_tolerance;
pub mod fig10;
pub mod fig4;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod streaming;
pub mod theory;

use std::collections::BTreeMap;

use crate::coordinator::greedi::centralized;
use crate::coordinator::protocol::{
    self, PartitionStrategy, PlacementPolicy, Protocol, RecoveryPolicy, RunSpec,
};
use crate::coordinator::Problem;
use crate::util::stats::summarize;
use crate::util::table::Table;

/// Common experiment options (CLI-overridable).
#[derive(Debug, Clone)]
pub struct ExpOpts {
    /// Ground-set size override (each figure has its own default).
    pub n: Option<usize>,
    pub trials: usize,
    pub seed: u64,
    /// OS threads for every protocol's simulated cluster.
    pub threads: usize,
    /// Ground-set partitioning strategy for every protocol run.
    pub partition: PartitionStrategy,
    /// Replication multiplicity c for every protocol run (default 1).
    pub multiplicity: usize,
    /// Replica placement relative to the fault plan's failure domains.
    pub placement: PlacementPolicy,
    /// Crash-recovery policy for every protocol run.
    pub recovery: RecoveryPolicy,
    /// Checkpoint period B for `recovery = resume` (0 = checkpoints off).
    pub checkpoint_every: usize,
    /// Use the XLA facility-gain backend where applicable.
    pub xla: bool,
    /// Lift sizes toward paper scale.
    pub full: bool,
    /// Figure sub-part selector ("a", "b", …; empty = all).
    pub part: String,
}

impl Default for ExpOpts {
    fn default() -> Self {
        ExpOpts {
            n: None,
            trials: 3,
            seed: 42,
            threads: 1,
            partition: PartitionStrategy::Random,
            multiplicity: 1,
            placement: PlacementPolicy::Anywhere,
            recovery: RecoveryPolicy::Retry,
            checkpoint_every: 0,
            xla: false,
            full: false,
            part: String::new(),
        }
    }
}

impl ExpOpts {
    pub fn size(&self, fast: usize, full: usize) -> usize {
        self.n.unwrap_or(if self.full { full } else { fast })
    }

    pub fn wants(&self, part: &str) -> bool {
        self.part.is_empty() || self.part == part
    }

    /// Base [`RunSpec`] for one (m, k) sweep point under these options.
    pub fn spec(&self, m: usize, k: usize, local: bool, algorithm: &str) -> RunSpec {
        let mut spec = RunSpec::new(m, k)
            .algorithm(algorithm)
            .partition(self.partition)
            .multiplicity(self.multiplicity)
            .placement(self.placement)
            .recovery(self.recovery)
            .checkpoint_every(self.checkpoint_every)
            .threads(self.threads)
            .seed(self.seed);
        if local {
            spec = spec.local();
        }
        spec
    }
}

/// One sweep point: protocol label → per-trial ratios vs centralized.
pub type RatioRows = BTreeMap<String, Vec<f64>>;

/// Run the full protocol suite (GreeDi per α + the 4 baselines) at one
/// sweep point and collect distributed/centralized value ratios. The base
/// spec fixes (m, k, mode, algorithm, threads); per-trial seeds fork from
/// `base.seed`.
pub fn suite_ratios(
    problem: &dyn Problem,
    base: &RunSpec,
    alphas: &[f64],
    trials: usize,
    central_value: f64,
) -> RatioRows {
    let greedi = protocol::by_name("greedi").expect("greedi registered");
    let mut rows: RatioRows = BTreeMap::new();
    for t in 0..trials {
        let s = base.seed.wrapping_add(t as u64).wrapping_mul(0x9E37_79B9);
        for &alpha in alphas {
            let run = greedi.run(problem, &base.clone().alpha(alpha).seed(s));
            let label = if alphas.len() == 1 {
                "greedi".to_string()
            } else {
                format!("greedi(α={alpha})")
            };
            rows.entry(label).or_default().push(run.ratio_vs(central_value));
        }
        for name in protocol::BASELINE_NAMES {
            let proto = protocol::by_name(name).expect("baseline registered");
            let run = proto.run(problem, &base.clone().seed(s));
            rows.entry(run.name.clone())
                .or_default()
                .push(run.ratio_vs(central_value));
        }
    }
    rows
}

/// Render a sweep (x-axis values × protocol ratio rows) as the textual
/// analogue of a paper figure: `mean±std` per cell.
pub fn render_sweep(title: &str, xlabel: &str, xs: &[usize], rows: &[RatioRows]) -> String {
    assert_eq!(xs.len(), rows.len());
    let mut labels: Vec<String> = rows
        .iter()
        .flat_map(|r| r.keys().cloned())
        .collect::<std::collections::BTreeSet<_>>()
        .into_iter()
        .collect();
    // greedi curves first, then baselines alphabetically
    labels.sort_by_key(|l| (!l.starts_with("greedi"), l.clone()));
    let mut headers: Vec<&str> = vec![xlabel];
    for l in &labels {
        headers.push(l.as_str());
    }
    let mut t = Table::new(title, &headers);
    for (x, row) in xs.iter().zip(rows) {
        let mut cells = vec![x.to_string()];
        for l in &labels {
            let cell = row
                .get(l)
                .map(|v| {
                    let s = summarize(v);
                    format!("{:.3}±{:.3}", s.mean, s.std)
                })
                .unwrap_or_else(|| "-".into());
            cells.push(cell);
        }
        t.row(&cells);
    }
    t.render()
}

/// Centralized reference value/time for budget k (averaged over 1 run —
/// greedy is deterministic given the data).
pub fn central_ref(problem: &dyn Problem, k: usize, algorithm: &str, seed: u64) -> (f64, f64) {
    let c = centralized(problem, k, algorithm, seed);
    (c.value, c.sim_time())
}

/// A figure harness's output: rendered text report (printed by the CLI and
/// appended to EXPERIMENTS.md by `make experiments`).
pub struct FigureReport {
    pub id: String,
    pub body: String,
}

impl FigureReport {
    pub fn print(&self) {
        println!("==== {} ====\n{}", self.id, self.body);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::FacilityProblem;
    use crate::data::synth::{gaussian_blobs, SynthConfig};
    use std::sync::Arc;

    #[test]
    fn suite_ratios_contains_all_protocols() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(120, 8), 1));
        let p = FacilityProblem::new(&ds);
        let (cv, _) = central_ref(&p, 5, "lazy", 1);
        let base = RunSpec::new(3, 5).seed(1);
        let rows = suite_ratios(&p, &base, &[1.0], 2, cv);
        assert!(rows.contains_key("greedi"));
        assert!(rows.contains_key("random/random"));
        assert_eq!(rows["greedi"].len(), 2);
        for v in rows.values().flatten() {
            assert!(*v <= 1.0 + 1e-9 && *v >= 0.0);
        }
    }

    #[test]
    fn render_sweep_shape() {
        let mut r1: RatioRows = BTreeMap::new();
        r1.insert("greedi".into(), vec![0.99, 0.98]);
        r1.insert("random/random".into(), vec![0.5, 0.6]);
        let out = render_sweep("demo", "m", &[2], &[r1]);
        assert!(out.contains("greedi"));
        assert!(out.contains("0.9"));
    }

    #[test]
    fn opts_size_and_parts() {
        let mut o = ExpOpts::default();
        assert_eq!(o.size(100, 1000), 100);
        o.full = true;
        assert_eq!(o.size(100, 1000), 1000);
        o.n = Some(7);
        assert_eq!(o.size(100, 1000), 7);
        assert!(o.wants("a") && o.wants("b"));
        o.part = "a".into();
        assert!(o.wants("a") && !o.wants("b"));
    }

    #[test]
    fn opts_spec_threads_and_mode() {
        let o = ExpOpts { threads: 4, seed: 9, ..Default::default() };
        let s = o.spec(6, 12, true, "greedy");
        assert_eq!((s.m, s.k, s.threads, s.seed), (6, 12, 4, 9));
        assert!(s.local_eval);
        assert_eq!(s.algorithm, "greedy");
    }
}
