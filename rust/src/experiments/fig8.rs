//! Figure 8 — GreeDi speedup vs the centralized greedy (§6.2, Yahoo!
//! webscope workload): simulated-parallel GreeDi time (max round-1 task +
//! round-2 task) against the centralized single-machine time.
//!
//! * (a) k ∈ {64, 128, 256}, m ≤ 32 — near-linear speedup regime;
//! * (b) same ks, m ≤ 512 — the round-2 merge (m·κ candidates) grows with
//!   m and eventually dominates, rolling the speedup curve over. Larger k
//!   shifts the rollover left (the paper's exact observation).
//!
//! The simulated cluster clock comes from `mapreduce::JobReport`: each map
//! task's wallclock is measured in isolation, so `max + merge` is the
//! 2-round protocol's critical path on an ideal m-machine cluster.

use std::sync::Arc;

use super::{ExpOpts, FigureReport};
use crate::coordinator::greedi::{centralized, Greedi};
use crate::coordinator::protocol::Protocol;
use crate::coordinator::InfoGainProblem;
use crate::data::synth::yahoo_like;
use crate::util::table::Table;

pub fn run(opts: &ExpOpts) -> FigureReport {
    let n = opts.size(8_000, 45_811);
    let ds = Arc::new(yahoo_like(n, opts.seed));
    let problem = InfoGainProblem::paper_params(&ds);

    let ks: Vec<usize> = if opts.full { vec![64, 128, 256] } else { vec![32, 64, 128] };
    let ms_a: Vec<usize> = vec![2, 4, 8, 16, 32];
    let ms_b: Vec<usize> = vec![32, 64, 128, 256, 512];

    let mut body = format!("speedup workload: yahoo-like n={n}, d=6 (info-gain, lazy greedy)\n\n");

    for (part, ms) in [("a", &ms_a), ("b", &ms_b)] {
        if !opts.wants(part) {
            continue;
        }
        let mut headers: Vec<String> = vec!["m".into()];
        for &k in &ks {
            headers.push(format!("speedup(k={k})"));
        }
        let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(
            &format!("Fig 8{part}: simulated speedup vs m (centralized time / GreeDi time)"),
            &hdr_refs,
        );
        // centralized reference times per k
        let central: Vec<f64> = ks
            .iter()
            .map(|&k| centralized(&problem, k, "lazy", opts.seed).sim_time())
            .collect();
        for &m in ms {
            let mut cells = vec![m.to_string()];
            for (ki, &k) in ks.iter().enumerate() {
                let run = Greedi.run(&problem, &opts.spec(m, k, false, "lazy"));
                cells.push(format!("{:.2}", run.speedup_vs(central[ki])));
            }
            t.row(&cells);
        }
        body.push_str(&t.render());
        body.push('\n');
    }

    FigureReport { id: "fig8".into(), body }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_speedup_table() {
        let opts = ExpOpts { n: Some(500), trials: 1, part: "a".into(), ..Default::default() };
        let rep = run(&opts);
        assert!(rep.body.contains("Fig 8a"));
        assert!(rep.body.contains("speedup"));
    }
}
