//! Figure 7 — large-scale active-set selection on Yahoo! Front Page user
//! visits (§6.2): 45.8M 6-dimensional user feature vectors on Spark with
//! m = 32 reducers, k up to 256, information-gain objective.
//!
//! Scaled substitution: n = 5,000 (fast) / 45,811 (--full, 1000× down),
//! identical d = 6 and m = 32.

use std::sync::Arc;

use super::{central_ref, render_sweep, suite_ratios, ExpOpts, FigureReport};
use crate::coordinator::InfoGainProblem;
use crate::data::synth::yahoo_like;

pub fn run(opts: &ExpOpts) -> FigureReport {
    let n = opts.size(5_000, 45_811);
    let ds = Arc::new(yahoo_like(n, opts.seed));
    let problem = InfoGainProblem::paper_params(&ds);

    let m = 32;
    let ks: Vec<usize> = if opts.full {
        vec![16, 32, 64, 128, 256]
    } else {
        vec![16, 32, 64, 128]
    };

    let rows: Vec<_> = ks
        .iter()
        .map(|&k| {
            let (cv, _) = central_ref(&problem, k, "lazy", opts.seed);
            suite_ratios(&problem, &opts.spec(m, k, false, "lazy"), &[1.0], opts.trials, cv)
        })
        .collect();

    let mut body = format!("yahoo-webscope surrogate: n={n}, d=6, m={m}, trials={}\n\n", opts.trials);
    body.push_str(&render_sweep(
        &format!("Fig 7: ratio vs k (m={m}, info-gain, Yahoo-like)"),
        "k",
        &ks,
        &rows,
    ));
    FigureReport { id: "fig7".into(), body }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_run() {
        let opts = ExpOpts { n: Some(300), trials: 1, ..Default::default() };
        let rep = run(&opts);
        assert!(rep.body.contains("Fig 7"));
        assert!(rep.body.contains("greedi"));
    }
}
