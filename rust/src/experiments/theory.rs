//! Theory validation — empirical checks of the paper's bounds:
//!
//! * **Theorem 3/4 tightness** — on the adversarial entropy instance with
//!   the adversarial (contiguous) partition, GreeDi's value collapses
//!   toward OPT/min(m,k); with random partitioning it recovers (Theorem
//!   11's (1−1/e)/2 average-case bound is comfortably cleared).
//! * **Theorem 4 lower bound** — (1−e^{−κ/k})/min(m,k)·OPT holds across a
//!   (m, k, α) grid on a real objective.
//! * **Table 1 constraint classes** — greedy-family algorithms under
//!   matroid / knapsack / p-system constraints achieve their stated
//!   fractions on brute-forceable instances.

use std::sync::Arc;

use super::{ExpOpts, FigureReport};
use crate::algorithms::{cost_benefit::CostBenefitGreedy, greedy::Greedy, Maximizer};
use crate::constraints::knapsack::Knapsack;
use crate::constraints::matroid::PartitionMatroid;
use crate::coordinator::greedi::{Greedi, PartitionStrategy};
use crate::coordinator::protocol::{Protocol, RunSpec};
use crate::coordinator::OpaqueProblem;
use crate::data::synth::{gaussian_blobs, SynthConfig};
use crate::objective::entropy_worstcase::EntropyWorstCase;
use crate::objective::facility::FacilityLocation;
use crate::objective::SubmodularFn;
use crate::util::rng::Rng;
use crate::util::table::Table;

pub fn run(opts: &ExpOpts) -> FigureReport {
    let mut body = String::new();

    // ---- Worst-case instance (Thm 3/4) ---------------------------------
    // Two readings of the adversarial entropy instance:
    //  * "greedi" — the actual protocol. Greedy prefers each group's
    //    aggregate Y (gain k vs 1), so it *escapes* the trap: ratio 1.
    //  * "adversarial ties" — Algorithm 1 with the adversarial optimal
    //    tie-break A_i = {X_i1..X_ik} (both choices are optimal on the
    //    shard). The merged pool then contains only single bits and the
    //    ratio collapses to exactly 1/min(m,k) — Theorem 3's tight case.
    let mut t = Table::new(
        "Thm 3: adversarial entropy instance — ratio to OPT",
        &["(m,k)", "greedi (adv. part.)", "greedi (random)", "adversarial ties", "1/min(m,k)", "(1-1/e)/2"],
    );
    for (m, k) in [(2, 2), (4, 4), (8, 8), (4, 8)] {
        let f = EntropyWorstCase::new(m, k);
        let p = OpaqueProblem::new(&f);
        let opt = f.optimal_value(k);
        let adv = Greedi.run(
            &p,
            &RunSpec::new(m, k)
                .partition(PartitionStrategy::Contiguous)
                .seed(opts.seed),
        );
        let mut rnd_vals = Vec::new();
        for s in 0..opts.trials as u64 {
            rnd_vals.push(
                Greedi.run(&p, &RunSpec::new(m, k).seed(opts.seed + s)).value / opt,
            );
        }
        let rnd = crate::util::stats::mean(&rnd_vals);
        // Algorithm-1 adversarial tie-break: every machine returns its X
        // bits; the best k-subset of the merged pool is any k bits.
        let mut adversarial_pool: Vec<usize> = Vec::new();
        for g in 0..m {
            for j in 0..k {
                adversarial_pool.push(g * (k + 1) + j); // X_{g,j}
            }
        }
        let tie_run = {
            use crate::algorithms::greedy::Greedy;
            use crate::constraints::cardinality::Cardinality;
            let mut rng = Rng::new(opts.seed);
            Greedy.maximize(&f, &adversarial_pool, &Cardinality::new(k), &mut rng)
        };
        t.row(&[
            format!("({m},{k})"),
            format!("{:.3}", adv.value / opt),
            format!("{rnd:.3}"),
            format!("{:.3}", tie_run.value / opt),
            format!("{:.3}", 1.0 / m.min(k) as f64),
            format!("{:.3}", (1.0 - (-1.0f64).exp()) / 2.0),
        ]);
    }
    body.push_str(&t.render());
    body.push('\n');

    // ---- Thm 4 bound sweep on facility location -------------------------
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(400, 8), opts.seed));
    let fac = FacilityLocation::from_dataset(&ds);
    let p = OpaqueProblem::new(&fac);
    let mut t = Table::new(
        "Thm 4: f(greedi) ≥ (1-e^{-κ/k})/min(m,k) · f(central-greedy)",
        &["m", "k", "α", "ratio", "bound", "holds"],
    );
    for (m, k, alpha) in [(4, 8, 1.0), (8, 8, 1.0), (4, 8, 0.5), (4, 8, 2.0), (2, 16, 1.0)] {
        let central = crate::coordinator::greedi::centralized(&p, k, "lazy", opts.seed);
        let run = Greedi.run(&p, &RunSpec::new(m, k).alpha(alpha).seed(opts.seed));
        let kappa = (alpha * k as f64).round();
        let bound = (1.0 - (-kappa / k as f64).exp()) / m.min(k) as f64;
        let ratio = run.value / central.value;
        t.row(&[
            m.to_string(),
            k.to_string(),
            format!("{alpha}"),
            format!("{ratio:.3}"),
            format!("{bound:.3}"),
            (ratio >= bound - 1e-9).to_string(),
        ]);
    }
    body.push_str(&t.render());
    body.push('\n');

    // ---- Table 1 spot checks --------------------------------------------
    let mut t = Table::new(
        "Table 1: constraint-class approximation spot checks (vs brute force)",
        &["constraint", "algorithm", "achieved", "guarantee"],
    );
    let mut rng = Rng::new(opts.seed);

    // matroid + greedy: 1/2 (Fisher et al.)
    {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(12, 4), 3));
        let f = FacilityLocation::from_dataset(&ds);
        let cats: Vec<usize> = (0..12).map(|i| i % 3).collect();
        let con = PartitionMatroid::new(cats.clone(), vec![1, 1, 1]);
        let g = Greedy.maximize(&f, &(0..12).collect::<Vec<_>>(), &con, &mut rng);
        let opt = brute_force_best(&f, 12, &|s| {
            let mut used = [0usize; 3];
            for &e in s {
                used[cats[e]] += 1;
            }
            used.iter().all(|&u| u <= 1)
        });
        t.row(&[
            "1 matroid".into(),
            "greedy".into(),
            format!("{:.3}", g.value / opt),
            "0.500".into(),
        ]);
        assert!(g.value / opt >= 0.5 - 1e-9);
    }

    // knapsack + cost-benefit: 1 − 1/√e ≈ 0.393 (Krause & Guestrin)
    {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(12, 4), 4));
        let f = FacilityLocation::from_dataset(&ds);
        let mut costs = vec![1.0; 12];
        for (i, c) in costs.iter_mut().enumerate() {
            *c = 1.0 + (i % 4) as f64;
        }
        let con = Knapsack::new(costs.clone(), 6.0);
        let g = CostBenefitGreedy::for_knapsack(&con).maximize(
            &f,
            &(0..12).collect::<Vec<_>>(),
            &con,
            &mut rng,
        );
        let opt = brute_force_best(&f, 12, &|s| {
            s.iter().map(|&e| costs[e]).sum::<f64>() <= 6.0 + 1e-9
        });
        t.row(&[
            "1 knapsack".into(),
            "cost-benefit".into(),
            format!("{:.3}", g.value / opt),
            "0.393".into(),
        ]);
        assert!(g.value / opt >= 1.0 - (-0.5f64).exp() - 1e-9);
    }
    body.push_str(&t.render());

    FigureReport { id: "theory".into(), body }
}

/// Brute-force optimum of f over all feasible subsets of `0..n` (n ≤ 16).
fn brute_force_best(
    f: &dyn SubmodularFn,
    n: usize,
    feasible: &dyn Fn(&[usize]) -> bool,
) -> f64 {
    assert!(n <= 16);
    let mut best = f64::NEG_INFINITY;
    for mask in 0u32..(1 << n) {
        let s: Vec<usize> = (0..n).filter(|&i| mask & (1 << i) != 0).collect();
        if feasible(&s) {
            best = best.max(f.eval(&s));
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theory_report_runs() {
        let opts = ExpOpts { trials: 2, ..Default::default() };
        let rep = run(&opts);
        assert!(rep.body.contains("Thm 3:"));
        assert!(rep.body.contains("adversarial ties"));
        assert!(rep.body.contains("Table 1"));
        // every Thm 4 row must hold
        assert!(!rep.body.contains("false"));
    }
}
