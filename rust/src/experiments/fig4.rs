//! Figure 4 — exemplar-based clustering on Tiny-Images-like data (§6.1).
//!
//! * (a) global objective, k = 50, m ∈ {2..10}, α sweep for GreeDi;
//! * (b) local (decomposable) objective, same sweep;
//! * (c) global objective, m = 5, k ∈ {5..100};
//! * (d) local objective, same k sweep.
//!
//! Paper outcome: GreeDi ≳ 0.95× centralized everywhere (even for α < 1),
//! with the naive protocols clearly below — the sweeps here reproduce that
//! ordering on the synthetic tiny-image surrogate.

use std::sync::Arc;

use super::{central_ref, render_sweep, suite_ratios, ExpOpts, FigureReport};
use crate::coordinator::FacilityProblem;
use crate::data::synth::{gaussian_blobs, SynthConfig};

/// Scaled defaults: paper uses n = 10,000, d = 3072 (32×32 RGB); we default
/// to n = 2,000, d = 16 (fast) / n = 10,000, d = 32 (--full).
pub fn run(opts: &ExpOpts) -> FigureReport {
    let n = opts.size(2_000, 10_000);
    let d = if opts.full { 32 } else { 16 };
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, d), opts.seed));
    let problem = build_problem(&ds, opts);

    let k_fixed = 50.min(n / 10).max(5);
    let ms: Vec<usize> = vec![2, 4, 6, 8, 10];
    let m_fixed = 5;
    let ks: Vec<usize> = [5, 10, 20, 50, 80, 100]
        .into_iter()
        .filter(|&k| k <= n / 5)
        .collect();
    let alphas = [0.5, 1.0, 2.0];

    let mut body = format!("tiny-images surrogate: n={n}, d={d}, trials={}\n\n", opts.trials);

    for (part, local) in [("a", false), ("b", true)] {
        if !opts.wants(part) {
            continue;
        }
        let (cv, _) = central_ref(&problem, k_fixed, "lazy", opts.seed);
        let rows: Vec<_> = ms
            .iter()
            .map(|&m| {
                suite_ratios(
                    &problem,
                    &opts.spec(m, k_fixed, local, "lazy"),
                    &alphas,
                    opts.trials,
                    cv,
                )
            })
            .collect();
        body.push_str(&render_sweep(
            &format!(
                "Fig 4{part}: ratio vs m (k={k_fixed}, {} objective)",
                if local { "local" } else { "global" }
            ),
            "m",
            &ms,
            &rows,
        ));
        body.push('\n');
    }

    for (part, local) in [("c", false), ("d", true)] {
        if !opts.wants(part) {
            continue;
        }
        let rows: Vec<_> = ks
            .iter()
            .map(|&k| {
                let (cv, _) = central_ref(&problem, k, "lazy", opts.seed);
                suite_ratios(
                    &problem,
                    &opts.spec(m_fixed, k, local, "lazy"),
                    &alphas,
                    opts.trials,
                    cv,
                )
            })
            .collect();
        body.push_str(&render_sweep(
            &format!(
                "Fig 4{part}: ratio vs k (m={m_fixed}, {} objective)",
                if local { "local" } else { "global" }
            ),
            "k",
            &ks,
            &rows,
        ));
        body.push('\n');
    }

    FigureReport { id: "fig4".into(), body }
}

fn build_problem(ds: &Arc<crate::data::Dataset>, opts: &ExpOpts) -> FacilityProblem {
    let mut p = FacilityProblem::new(ds);
    if opts.xla {
        let engine = Arc::new(
            crate::runtime::Engine::load_default()
                .expect("--xla needs `make artifacts` and a `--features xla` build (vendored xla crate — see rust/Cargo.toml)"),
        );
        p = p.with_backend_factory(Arc::new(crate::runtime::XlaBackendFactory { engine }));
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_run_produces_all_parts() {
        let opts = ExpOpts { n: Some(150), trials: 1, ..Default::default() };
        let rep = run(&opts);
        for part in ["4a", "4b", "4c", "4d"] {
            assert!(rep.body.contains(&format!("Fig {part}")), "missing {part}");
        }
        assert!(rep.body.contains("greedi(α=1)"));
    }

    #[test]
    fn part_filter_respected() {
        let opts = ExpOpts { n: Some(120), trials: 1, part: "a".into(), ..Default::default() };
        let rep = run(&opts);
        assert!(rep.body.contains("Fig 4a"));
        assert!(!rep.body.contains("Fig 4c"));
    }
}
