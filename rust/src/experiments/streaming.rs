//! Streaming experiment — the bounded-memory workload class the paper's
//! batch protocols cannot touch: one-pass sieve→merge (`stream_greedi`)
//! against two-round GreeDi on the §6.1 exemplar-clustering setup.
//!
//! Reported per configuration:
//! * distributed/centralized value ratio (GreeDi's headline metric);
//! * per-machine **peak live candidates** against the O(κ·log(κ)/ε)
//!   ceiling — the memory story, which batch GreeDi has no analogue of;
//! * map-stage throughput (elements/sec of sequential stream CPU) as the
//!   batch size sweeps, showing the batched ladder pricing amortizing.

use std::sync::Arc;

use super::{central_ref, ExpOpts, FigureReport};
use crate::coordinator::protocol::{self, Protocol};
use crate::coordinator::FacilityProblem;
use crate::data::synth::{gaussian_blobs, SynthConfig};
use crate::util::table::Table;

pub fn run(opts: &ExpOpts) -> FigureReport {
    let n = opts.size(1_500, 20_000);
    let d = if opts.full { 32 } else { 16 };
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, d), opts.seed));
    let problem = FacilityProblem::new(&ds);

    let m = 5usize;
    let k = 20.min(n / 10).max(2);
    let epsilon = 0.2;
    let batches: [usize; 3] = [1, 64, 1024];

    let (cv, _) = central_ref(&problem, k, "lazy", opts.seed);
    let mut body = format!(
        "streaming sieve→merge: n={n}, d={d}, m={m}, k={k}, ε={epsilon}, trials={}\n\n",
        opts.trials
    );

    let mut t = Table::new(
        "stream_greedi vs greedi (ratio vs centralized; peak live candidates per machine)",
        &["protocol", "batch", "ratio", "peak_live", "bound", "elems/s"],
    );

    let greedi = protocol::by_name("greedi").expect("greedi registered");
    let stream = protocol::by_name("stream_greedi").expect("stream_greedi registered");

    for t_idx in 0..opts.trials.max(1) {
        let seed = opts
            .seed
            .wrapping_add(t_idx as u64)
            .wrapping_mul(0x9E37_79B9);
        let base = opts
            .spec(m, k, false, "lazy")
            .epsilon(epsilon)
            .seed(seed);
        let g = greedi.run(&problem, &base);
        t.row(&[
            "greedi".into(),
            "-".into(),
            format!("{:.4}", g.ratio_vs(cv)),
            "-".into(),
            "-".into(),
            "-".into(),
        ]);
        for &b in &batches {
            let r = stream.run(&problem, &base.clone().batch(b));
            let stats = r.stream.as_ref().expect("stream stats");
            // Sequential stream CPU of the map stage => elements/sec.
            let map_cpu = r.job.stages.first().map(|s| s.total_cpu_time).unwrap_or(0.0);
            let eps_rate = if map_cpu > 0.0 { n as f64 / map_cpu } else { f64::NAN };
            t.row(&[
                "stream_greedi".into(),
                b.to_string(),
                format!("{:.4}", r.ratio_vs(cv)),
                stats.peak_live().to_string(),
                stats.live_bound.to_string(),
                format!("{eps_rate:.0}"),
            ]);
        }
    }
    body.push_str(&t.render());
    body.push('\n');

    FigureReport { id: "streaming".into(), body }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_run_reports_both_protocols_and_memory() {
        let opts = ExpOpts { n: Some(150), trials: 1, ..Default::default() };
        let rep = run(&opts);
        assert_eq!(rep.id, "streaming");
        assert!(rep.body.contains("stream_greedi"));
        assert!(rep.body.contains("greedi"));
        assert!(rep.body.contains("peak_live"));
    }
}
