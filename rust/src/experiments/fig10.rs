//! Figure 10 — GreeDi vs GreedyScaling (Kumar et al. 2013) on submodular
//! coverage (§6.4): pick ≤ k transactions maximizing the size of the union
//! of their items.
//!
//! * (a) Accidents-like data (paper: 340,183 transactions, 468 items);
//! * (b) Kosarak-like data (paper: 990,002 transactions, 41,270 items).
//!
//! Both scaled 10× down by default. GreedyScaling runs with the paper's
//! δ = 1/2 memory setting and m = n/μ machines; the table also reports the
//! MapReduce round counts — the paper's point that GreedyScaling needs
//! substantially more rounds than GreeDi's two.

use std::sync::Arc;

use super::{central_ref, ExpOpts, FigureReport};
use crate::coordinator::greedi::Greedi;
use crate::coordinator::greedy_scaling::GreedyScaling;
use crate::coordinator::protocol::Protocol;
use crate::coordinator::CoverageProblem;
use crate::data::transactions::{accidents_like, kosarak_like};
use crate::util::table::Table;

pub fn run(opts: &ExpOpts) -> FigureReport {
    let mut body = String::new();

    for (part, name) in [("a", "accidents"), ("b", "kosarak")] {
        if !opts.wants(part) {
            continue;
        }
        let (n, td) = if name == "accidents" {
            let n = opts.size(34_018, 340_183);
            (n, Arc::new(accidents_like(n, opts.seed)))
        } else {
            let n = opts.size(99_000, 990_002);
            (n, Arc::new(kosarak_like(n, opts.seed)))
        };
        let problem = CoverageProblem::new(&td);
        let ks: Vec<usize> = vec![5, 10, 20, 50, 100];
        let m = 8; // GreeDi machine count (paper: m = n/μ varies; fixed here)

        let mut t = Table::new(
            &format!("Fig 10{part}: {name}-like coverage, GreeDi vs GreedyScaling (n={n})"),
            &["k", "greedi", "greedi rounds", "greedy_scaling", "gs rounds"],
        );
        for &k in &ks {
            let (cv, _) = central_ref(&problem, k, "lazy", opts.seed);
            let grd = Greedi.run(&problem, &opts.spec(m, k, false, "lazy"));
            let gs = GreedyScaling.run(&problem, &opts.spec(m, k, false, "lazy").delta(0.5));
            t.row(&[
                k.to_string(),
                format!("{:.3}", grd.ratio_vs(cv)),
                grd.rounds.to_string(),
                format!("{:.3}", gs.ratio_vs(cv)),
                gs.rounds.to_string(),
            ]);
        }
        body.push_str(&format!("{name}-like: n={n}, items={}\n", td.n_items));
        body.push_str(&t.render());
        body.push('\n');
    }

    FigureReport { id: "fig10".into(), body }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_run_both_datasets() {
        let opts = ExpOpts { n: Some(400), trials: 1, ..Default::default() };
        let rep = run(&opts);
        assert!(rep.body.contains("Fig 10a"));
        assert!(rep.body.contains("Fig 10b"));
        assert!(rep.body.contains("greedy_scaling"));
    }
}
