//! Figure 9 — non-monotone maximization: finding maximum cuts on a
//! Facebook-like social network (§6.3; 1,899 users, 20,296 directed ties,
//! RandomGreedy of Buchbinder et al. 2014 on each partition, objective
//! evaluated locally so cross-partition links are disconnected).
//!
//! * (a) k = 20, m ∈ {2..10};
//! * (b) m = 10, k ∈ {5..60}.

use std::sync::Arc;

use super::{central_ref, render_sweep, suite_ratios, ExpOpts, FigureReport};
use crate::coordinator::CutProblem;
use crate::data::graph::social_network;

pub fn run(opts: &ExpOpts) -> FigureReport {
    // Paper-matching graph size by default — the cut objective is cheap.
    let n = opts.size(1_899, 1_899);
    let edges = if n == 1_899 { 20_296 } else { n * 10 };
    let g = Arc::new(social_network(n, edges, opts.seed));
    let problem = CutProblem::new(&g);

    let ms: Vec<usize> = vec![2, 4, 6, 8, 10];
    let ks: Vec<usize> = vec![5, 10, 20, 40, 60];
    let k_fixed = 20;
    let m_fixed = 10;

    let mut body = format!(
        "social-graph surrogate: n={n}, edges={edges}, RandomGreedy, local evaluation, trials={}\n\n",
        opts.trials
    );

    if opts.wants("a") {
        let (cv, _) = central_ref(&problem, k_fixed, "random_greedy", opts.seed);
        let rows: Vec<_> = ms
            .iter()
            .map(|&m| {
                suite_ratios(
                    &problem,
                    &opts.spec(m, k_fixed, true, "random_greedy"),
                    &[1.0],
                    opts.trials,
                    cv,
                )
            })
            .collect();
        body.push_str(&render_sweep(
            &format!("Fig 9a: ratio vs m (k={k_fixed}, max-cut)"),
            "m",
            &ms,
            &rows,
        ));
        body.push('\n');
    }

    if opts.wants("b") {
        let rows: Vec<_> = ks
            .iter()
            .map(|&k| {
                let (cv, _) = central_ref(&problem, k, "random_greedy", opts.seed);
                suite_ratios(
                    &problem,
                    &opts.spec(m_fixed, k, true, "random_greedy"),
                    &[1.0],
                    opts.trials,
                    cv,
                )
            })
            .collect();
        body.push_str(&render_sweep(
            &format!("Fig 9b: ratio vs k (m={m_fixed}, max-cut)"),
            "k",
            &ks,
            &rows,
        ));
        body.push('\n');
    }

    FigureReport { id: "fig9".into(), body }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_run_both_parts() {
        let opts = ExpOpts { n: Some(150), trials: 1, ..Default::default() };
        let rep = run(&opts);
        assert!(rep.body.contains("Fig 9a"));
        assert!(rep.body.contains("Fig 9b"));
    }
}
