//! Figure 6 — GP active-set selection on Parkinsons-Telemonitoring-like
//! data (§6.2): information gain with the paper's squared-exponential
//! kernel (h = 0.75, σ = 1) on 22-attribute voice-measurement vectors.
//!
//! * (a) m = 10 fixed, k ∈ {5..100};
//! * (b) k = 50 fixed, m ∈ {2..10}.
//!
//! Paper outcome: GreeDi ≈ 0.97× centralized; baselines clearly below.

use std::sync::Arc;

use super::{central_ref, render_sweep, suite_ratios, ExpOpts, FigureReport};
use crate::coordinator::InfoGainProblem;
use crate::data::synth::parkinsons_like;

/// Paper: n = 5,875, d = 22. Fast default: n = 1,200 (same d).
pub fn run(opts: &ExpOpts) -> FigureReport {
    let n = opts.size(1_200, 5_875);
    let d = 22;
    let ds = Arc::new(parkinsons_like(n, d, opts.seed));
    let problem = InfoGainProblem::paper_params(&ds);

    let ks: Vec<usize> = vec![5, 10, 20, 30, 50, 80, 100];
    let ms: Vec<usize> = vec![2, 4, 6, 8, 10];
    let k_fixed = 50;
    let m_fixed = 10;
    let alphas = [1.0];

    let mut body = format!("parkinsons surrogate: n={n}, d={d}, h=0.75, σ=1, trials={}\n\n", opts.trials);

    if opts.wants("a") {
        let rows: Vec<_> = ks
            .iter()
            .map(|&k| {
                let (cv, _) = central_ref(&problem, k, "lazy", opts.seed);
                suite_ratios(&problem, &opts.spec(m_fixed, k, false, "lazy"), &alphas, opts.trials, cv)
            })
            .collect();
        body.push_str(&render_sweep(
            &format!("Fig 6a: ratio vs k (m={m_fixed}, info-gain)"),
            "k",
            &ks,
            &rows,
        ));
        body.push('\n');
    }

    if opts.wants("b") {
        let (cv, _) = central_ref(&problem, k_fixed, "lazy", opts.seed);
        let rows: Vec<_> = ms
            .iter()
            .map(|&m| {
                suite_ratios(&problem, &opts.spec(m, k_fixed, false, "lazy"), &alphas, opts.trials, cv)
            })
            .collect();
        body.push_str(&render_sweep(
            &format!("Fig 6b: ratio vs m (k={k_fixed}, info-gain)"),
            "m",
            &ms,
            &rows,
        ));
        body.push('\n');
    }

    FigureReport { id: "fig6".into(), body }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_run_both_parts() {
        let opts = ExpOpts { n: Some(200), trials: 1, ..Default::default() };
        let rep = run(&opts);
        assert!(rep.body.contains("Fig 6a"));
        assert!(rep.body.contains("Fig 6b"));
    }
}
