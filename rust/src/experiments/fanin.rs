//! Fan-in sweep for the accumulation-tree merge: r ∈ {2, 4, 8, flat} ×
//! m ∈ {10, 100, 1000}, charting solution quality against merge time and
//! the root node's candidate-pool peak.
//!
//! The flat single-root merge pools all m·κ candidates at once — its root
//! peak grows linearly in m. A staged r-ary tree caps every node's pool at
//! r·κ at the cost of extra rounds and a (slightly) lossier composition,
//! so this sweep is the quality / merge-latency / peak-memory trade-off
//! surface behind `RunSpec::fanout`. The m = 1000 column only runs under
//! `--full` (its flat merge is the slow point by design).

use std::sync::Arc;

use super::{ExpOpts, FigureReport};
use crate::coordinator::greedi::{centralized, Greedi};
use crate::coordinator::protocol::Protocol;
use crate::coordinator::FacilityProblem;
use crate::data::synth::{gaussian_blobs, SynthConfig};
use crate::util::table::Table;

pub fn run(opts: &ExpOpts) -> FigureReport {
    let n = opts.size(2_000, 20_000);
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 16), opts.seed));
    let problem = FacilityProblem::new(&ds);
    let k = 10.min(n / 100).max(4);
    let central = centralized(&problem, k, "lazy", opts.seed).value;
    let ms: &[usize] = if opts.full { &[10, 100, 1000] } else { &[10, 100] };
    let mut body = format!(
        "fan-in workload: tiny-images n={n}, k={k}; ratio is vs centralized \
         ({} omitted without --full)\n\n",
        if opts.full { "nothing" } else { "m=1000" }
    );

    for &m in ms {
        let mut t = Table::new(
            &format!("fan-in sweep at m={m}"),
            &["fanout", "ratio", "rounds", "depth", "root peak", "merge time"],
        );
        let mut flat_peak = 0usize;
        // flat first so the tree rows read as deltas against it
        for fanout in [0usize, 2, 4, 8] {
            if fanout != 0 && fanout >= m {
                continue; // r >= m is the flat row again, bit for bit
            }
            let spec = opts.spec(m, k, false, "lazy");
            let spec = if fanout == 0 { spec } else { spec.fanout(fanout) };
            let run = Greedi.run(&problem, &spec);
            let tree = run.tree.as_ref().expect("greedi reports tree stats");
            // everything after the map stage is a tree level
            let merge_time: f64 =
                run.job.stages[1..].iter().map(|s| s.max_task_time).sum();
            if fanout == 0 {
                flat_peak = tree.root_peak();
            }
            t.row(&[
                if fanout == 0 { "flat".into() } else { fanout.to_string() },
                format!("{:.4}", run.value / central),
                run.rounds.to_string(),
                tree.depth.to_string(),
                tree.root_peak().to_string(),
                format!("{merge_time:.4}"),
            ]);
            // staging can only shrink the root's pool: interior winners are
            // drawn from subsets of what the flat merge pools directly
            assert!(
                fanout == 0 || tree.root_peak() <= flat_peak,
                "root peak must be monotone in fan-in"
            );
        }
        body.push_str(&t.render());
        body.push('\n');
    }

    FigureReport { id: "fanin".into(), body }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fanin_report_complete() {
        let opts = ExpOpts { n: Some(400), trials: 1, ..Default::default() };
        let rep = run(&opts);
        assert_eq!(rep.id, "fanin");
        assert!(rep.body.contains("fan-in sweep at m=10"));
        assert!(rep.body.contains("fan-in sweep at m=100"));
        assert!(rep.body.contains("flat"));
        assert!(rep.body.contains("root peak"));
        // fast mode keeps the m=1000 column out
        assert!(!rep.body.contains("fan-in sweep at m=1000"));
    }
}
