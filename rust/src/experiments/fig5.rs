//! Figure 5a — the Hadoop-scale exemplar-clustering run (§6.1): the paper
//! selects 64 exemplars from 80M Tiny Images with m = 8,000 reducers and
//! *local* objective evaluation, comparing GreeDi against the distributed
//! baselines (no centralized run exists at that scale — ratios are against
//! the best distributed value, as in the paper's Fig 5a which plots raw
//! distributed utilities; we report values normalized by GreeDi's).
//!
//! Scaled substitution: n = 20,000 (fast) / 200,000 (--full), m = 40 / 200 —
//! the same n/m ≈ 500–1,000 shard geometry as the paper's 10,000 images per
//! reducer. The XLA facility backend is the intended engine here
//! (`--xla`); the scalar path is the default for CI speed.

use std::sync::Arc;

use super::{ExpOpts, FigureReport};
use crate::coordinator::protocol::{self, Protocol};
use crate::coordinator::FacilityProblem;
use crate::data::synth::{gaussian_blobs, SynthConfig};
use crate::util::stats::summarize;
use crate::util::table::Table;

pub fn run(opts: &ExpOpts) -> FigureReport {
    let n = opts.size(20_000, 200_000);
    let d = if opts.full { 32 } else { 16 };
    let m = if opts.full { 200 } else { 40 };
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, d), opts.seed));
    let mut problem = FacilityProblem::new(&ds);
    if opts.xla {
        let engine = Arc::new(
            crate::runtime::Engine::load_default()
                .expect("--xla needs `make artifacts` and a `--features xla` build (vendored xla crate — see rust/Cargo.toml)"),
        );
        problem = problem.with_backend_factory(Arc::new(crate::runtime::XlaBackendFactory { engine }));
    }

    let ks = [4, 8, 16, 32, 64];
    let mut t = Table::new(
        &format!("Fig 5a: large-scale local-objective clustering (n={n}, m={m})"),
        &["k", "greedi", "random/random", "random/greedy", "greedy/merge", "greedy/max"],
    );
    let mut body = format!(
        "80M-Tiny-Images surrogate: n={n}, d={d}, m={m}, local objective, trials={}\n\n",
        opts.trials
    );

    let greedi = protocol::by_name("greedi").expect("greedi registered");
    for &k in &ks {
        let mut cells = vec![k.to_string()];
        // GreeDi reference value for normalization (paper plots raw values;
        // we normalize per-k by GreeDi's mean so curves are comparable).
        let mut grd = Vec::new();
        for tdx in 0..opts.trials {
            let s = opts.seed.wrapping_add(tdx as u64 * 7919);
            let run = greedi.run(&problem, &opts.spec(m, k, true, "lazy").seed(s));
            grd.push(run.value);
        }
        let gref = summarize(&grd).mean;
        cells.push(format!("{:.3}", 1.0));
        for name in protocol::BASELINE_NAMES {
            let proto = protocol::by_name(name).expect("baseline registered");
            let mut vals = Vec::new();
            for tdx in 0..opts.trials {
                let s = opts.seed.wrapping_add(tdx as u64 * 7919);
                let run = proto.run(&problem, &opts.spec(m, k, true, "lazy").seed(s));
                vals.push(run.value / gref.max(1e-12));
            }
            cells.push(format!("{:.3}", summarize(&vals).mean));
        }
        t.row(&cells);
    }
    body.push_str(&t.render());
    body.push_str("\n(values normalized by GreeDi's mean utility per k)\n");
    FigureReport { id: "fig5".into(), body }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_run_has_all_columns() {
        let opts = ExpOpts { n: Some(400), trials: 1, ..Default::default() };
        let rep = run(&opts);
        assert!(rep.body.contains("Fig 5a"));
        assert!(rep.body.contains("greedy/max"));
    }
}
