//! Ablations of GreeDi's design choices (the knobs DESIGN.md calls out):
//!
//! * **partition strategy** — random (theory's assumption) vs balanced vs
//!   contiguous: how much does Theorem 8/11's randomization actually buy?
//! * **per-machine algorithm** — lazy greedy vs stochastic greedy vs
//!   sieve-streaming as Algorithm 3's black box `X`;
//! * **α = κ/k over-selection** — the paper's Fig. 4 knob, isolated;
//! * **flat 2-round vs tree reduction** — the multi-round extension's
//!   quality/communication/rounds trade-off.

use std::sync::Arc;

use super::{ExpOpts, FigureReport};
use crate::coordinator::greedi::{centralized, Greedi, PartitionStrategy};
use crate::coordinator::multiround::MultiRoundGreedi;
use crate::coordinator::protocol::Protocol;
use crate::coordinator::FacilityProblem;
use crate::data::synth::{gaussian_blobs, SynthConfig};
use crate::util::stats::summarize;
use crate::util::table::Table;

pub fn run(opts: &ExpOpts) -> FigureReport {
    let n = opts.size(2_000, 10_000);
    let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 16), opts.seed));
    let problem = FacilityProblem::new(&ds);
    let (m, k) = (8, 20.min(n / 20).max(4));
    let central = centralized(&problem, k, "lazy", opts.seed).value;
    let trials = opts.trials;
    let mut body = format!("ablation workload: tiny-images n={n}, m={m}, k={k}, trials={trials}\n\n");

    let ratio_of = |mk: &dyn Fn(u64) -> f64| -> (f64, f64) {
        let vals: Vec<f64> = (0..trials as u64)
            .map(|s| mk(opts.seed.wrapping_add(s * 101)) / central)
            .collect();
        let st = summarize(&vals);
        (st.mean, st.std)
    };

    // ---- partition strategy ---------------------------------------------
    let mut t = Table::new("ablation: partition strategy", &["strategy", "ratio"]);
    for (label, strat) in [
        ("random", PartitionStrategy::Random),
        ("balanced", PartitionStrategy::Balanced),
        ("contiguous", PartitionStrategy::Contiguous),
    ] {
        let (mean, std) = ratio_of(&|s| {
            Greedi
                .run(&problem, &opts.spec(m, k, false, "lazy").partition(strat).seed(s))
                .value
        });
        t.row(&[label.into(), format!("{mean:.4}±{std:.4}")]);
    }
    body.push_str(&t.render());
    body.push('\n');

    // ---- per-machine black box --------------------------------------------
    let mut t = Table::new(
        "ablation: Algorithm 3 black box X",
        &["algorithm", "ratio", "oracle calls"],
    );
    for algo in ["greedy", "lazy", "stochastic", "sieve_streaming"] {
        let run = Greedi.run(&problem, &opts.spec(m, k, false, algo));
        t.row(&[
            algo.into(),
            format!("{:.4}", run.value / central),
            run.oracle_calls.to_string(),
        ]);
    }
    body.push_str(&t.render());
    body.push('\n');

    // ---- α = κ/k ------------------------------------------------------------
    let mut t = Table::new("ablation: over-selection α = κ/k", &["α", "ratio", "comm (ids)"]);
    for alpha in [0.25, 0.5, 1.0, 2.0, 4.0] {
        let run = Greedi.run(&problem, &opts.spec(m, k, false, "lazy").alpha(alpha));
        t.row(&[
            format!("{alpha}"),
            format!("{:.4}", run.value / central),
            run.job.shuffled_elements.to_string(),
        ]);
    }
    body.push_str(&t.render());
    body.push('\n');

    // ---- flat vs tree --------------------------------------------------------
    let mut t = Table::new(
        "ablation: flat 2-round vs tree reduction (m=16)",
        &["protocol", "ratio", "rounds", "max comm per sync"],
    );
    let flat = Greedi.run(&problem, &opts.spec(16, k, false, "lazy"));
    t.row(&[
        "flat (1 merge point)".into(),
        format!("{:.4}", flat.value / central),
        flat.rounds.to_string(),
        flat.job.shuffled_elements.to_string(),
    ]);
    for fanout in [2, 4] {
        let tree = MultiRoundGreedi.run(&problem, &opts.spec(16, k, false, "lazy").fanout(fanout));
        t.row(&[
            format!("tree fanout={fanout}"),
            format!("{:.4}", tree.value / central),
            tree.rounds.to_string(),
            (fanout * k).to_string(),
        ]);
    }
    body.push_str(&t.render());

    FigureReport { id: "ablations".into(), body }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ablation_report_complete() {
        let opts = ExpOpts { n: Some(200), trials: 1, ..Default::default() };
        let rep = run(&opts);
        assert!(rep.body.contains("partition strategy"));
        assert!(rep.body.contains("black box X"));
        assert!(rep.body.contains("over-selection"));
        assert!(rep.body.contains("tree reduction"));
    }
}
