//! Multi-round (tree-reduction) GreeDi — the paper's §4.2 extension:
//! *"it is straightforward to generalize GreeDi to multiple rounds (i.e.,
//! more than two) for very large datasets."*
//!
//! Round 0 partitions V over m leaf machines exactly as Algorithm 2; each
//! subsequent round merges groups of `fanout` candidate sets and re-runs
//! the black box, halving-or-more the machine count until one set remains.
//! With L levels the communication per synchronization drops from m·κ ids
//! at a single merge point to fanout·κ, at the cost of L−1 extra rounds —
//! the trade Fig. 8b motivates when the round-2 merge dominates.
//!
//! Guarantee: composing Theorem 4 per level gives
//! `((1−e^{−κ/k})/min(fanout,k))^L · OPT` in the worst case; with random
//! partitioning each level keeps the (1−1/e)/2-style average-case behavior,
//! and empirically the tree loses almost nothing (see the ablation bench).
//!
//! The reduction itself is the shared
//! [`mapreduce::reduce::TreeReduce`](crate::mapreduce::reduce) engine —
//! this protocol only supplies the per-node merge body; `greedi` and
//! `stream_greedi` ride the same tree with `fanout` set below m.
//!
//! Registered as `"multiround"`; reads m, k, κ, `fanout`, algorithm,
//! local/global mode, partition, threads and seed from the shared
//! [`RunSpec`].

use super::metrics::{FaultStats, RunMetrics};
use super::protocol::{Protocol, RunSpec};
use super::Problem;
use crate::algorithms;
use crate::constraints::cardinality::Cardinality;
use crate::constraints::Constraint;
use crate::mapreduce::fault::{FaultPlan, RecoveryPolicy};
use crate::mapreduce::reduce::{NodeOutput, TreeReduce};
use crate::mapreduce::{JobReport, MapReduce};
use crate::util::rng::Rng;
use crate::util::trace;

/// The tree-reduction protocol.
pub struct MultiRoundGreedi;

impl Protocol for MultiRoundGreedi {
    fn name(&self) -> &'static str {
        "multiround"
    }

    fn run(&self, problem: &dyn Problem, spec: &RunSpec) -> RunMetrics {
        let fanout = spec.tree_fanout(false);
        let _proto_span = trace::span_with("protocol.multiround", || {
            vec![("m", spec.m.into()), ("k", spec.k.into()), ("fanout", fanout.into())]
        });
        let base_rng = Rng::new(spec.seed);
        let mut rng = base_rng.clone();
        let ground = problem.ground();
        let plan = spec.fault.clone().unwrap_or_else(FaultPlan::none);
        let policy = spec.recovery;
        let multiplicity = spec.multiplicity.clamp(1, spec.m);
        let shards = spec.partition.split_placed(
            &ground,
            spec.m,
            multiplicity,
            spec.placement,
            &plan.domains,
            &mut rng,
        );

        let engine = MapReduce::new(spec.threads);
        let mut job = JobReport::default();
        let mut oracle_calls = 0u64;
        let mut rounds = 0usize;

        // ---- Level 0: leaves ------------------------------------------------
        let leaf_con = Cardinality::new(spec.kappa);
        let local_eval = spec.local_eval;
        let algo_name = spec.algorithm.clone();
        let inputs: Vec<(usize, Vec<usize>)> = shards.iter().cloned().enumerate().collect();
        let leaf_oracle_threads = spec.oracle_threads(inputs.len());
        // Shared by level 0 and crash recovery: same fork (7000 + i), so a
        // shard rebuilt in full from survivor replicas reproduces the lost
        // leaf's result bit for bit.
        let run_leaf = |i: usize, shard: Vec<usize>| {
            let mut task_rng = base_rng.fork(7_000 + i as u64);
            let algo = algorithms::by_name(&algo_name).expect("algorithm");
            let obj = if local_eval {
                problem.local(&shard, &mut task_rng)
            } else {
                problem.global()
            };
            algo.maximize_threaded(
                obj.as_ref(),
                &shard,
                &leaf_con,
                &mut task_rng,
                leaf_oracle_threads,
            )
        };
        let leaves_span =
            trace::span_with("multiround.leaves", || vec![("machines", spec.m.into())]);
        let stage0 = engine
            .run_stage_policied(inputs, &plan, policy, |_, (i, shard)| run_leaf(i, shard))
            .unwrap_or_else(|e| {
                panic!(
                    "multiround leaves aborted: {e} (policy=retry turns machine crashes \
                     into job aborts; use drop_shard or survivor_merge to recover)"
                )
            });
        let mut leaf_results = stage0.outputs;
        let crashed = stage0.crashed;
        let straggled = stage0.straggled;
        let mut fault_retries = stage0.retries;
        job.stages.push(stage0.report);
        rounds += 1;
        drop(leaves_span);

        // ---- Crash recovery (leaves hold the data; reducers don't) ----------
        let mut recovery_time = 0.0;
        let mut dropped = 0usize;
        let mut salvaged_units = 0usize;
        let mut replayed_units = 0usize;
        if !crashed.is_empty() {
            let _rec_span = trace::span_with("multiround.recovery", || {
                vec![("crashed", crashed.len().into())]
            });
            let surviving: std::collections::HashSet<usize> = shards
                .iter()
                .enumerate()
                .filter(|(i, _)| !crashed.contains(i))
                .flat_map(|(_, s)| s.iter().copied())
                .collect();
            dropped = ground.iter().filter(|e| !surviving.contains(e)).count();
            if policy.rebuilds() {
                // Partial rebuilds (every replica of some element crashed)
                // degrade to drop-shard semantics for the missing elements:
                // the surviving slice still runs, coverage() stays < 1.
                let rebuilt: Vec<(usize, Vec<usize>, bool)> = crashed
                    .iter()
                    .map(|&j| {
                        let shard: Vec<usize> =
                            shards[j].iter().copied().filter(|e| surviving.contains(e)).collect();
                        let complete = shard.len() == shards[j].len();
                        (j, shard, complete)
                    })
                    .filter(|(_, shard, _)| !shard.is_empty())
                    .collect();
                if !rebuilt.is_empty() {
                    let rebuilt_ids: Vec<usize> = rebuilt.iter().map(|(j, _, _)| *j).collect();
                    // Resume: replay the crashed leaf's last prefix
                    // checkpoint (greedy family only — the selection is
                    // memoryless in (selected, remaining)) and re-run just
                    // the tail. See `coordinator::greedi` for the full
                    // salvage contract.
                    let ckpt_b = spec.checkpoint_every;
                    let can_salvage = policy == RecoveryPolicy::Resume
                        && ckpt_b > 0
                        && matches!(algo_name.as_str(), "greedy" | "lazy");
                    let kappa = spec.kappa;
                    let (recovered, rec_stage) =
                        engine.run_stage(rebuilt, |_, (j, shard, complete)| {
                            if can_salvage && complete {
                                let planned = kappa.min(shard.len());
                                let frac = plan.crash_point(j);
                                let ckpt_picks =
                                    ((frac * planned as f64).floor() as usize / ckpt_b) * ckpt_b;
                                let mut task_rng = base_rng.fork(7_000 + j as u64);
                                let obj = if local_eval {
                                    problem.local(&shard, &mut task_rng)
                                } else {
                                    problem.global()
                                };
                                let r = algorithms::greedy::greedy_resumed(
                                    obj.as_ref(),
                                    &shard,
                                    &leaf_con,
                                    leaf_oracle_threads,
                                    ckpt_picks,
                                );
                                (r.result, r.salvaged_picks, r.replayed_picks)
                            } else {
                                (run_leaf(j, shard), 0, 0)
                            }
                        });
                    recovery_time = rec_stage.max_task_time;
                    job.stages.push(rec_stage);
                    for (j, (r, salvaged, replayed)) in rebuilt_ids.into_iter().zip(recovered) {
                        salvaged_units += salvaged;
                        replayed_units += replayed;
                        leaf_results[j] = Some(r);
                    }
                }
            }
        }

        oracle_calls += leaf_results.iter().flatten().map(|r| r.oracle_calls).sum::<u64>();
        // Surviving (or recovered) leaves feed the tree in leaf order; under
        // DropShard the crashed leaves simply vanish from the frontier.
        let frontier: Vec<Vec<usize>> =
            leaf_results.into_iter().flatten().map(|r| r.solution).collect();

        // ---- Reduction levels: the shared accumulation tree -----------------
        // Every level is one engine stage; non-root nodes merge under the
        // κ-budget constraint, the root under k. Crashes model losing
        // data-holding leaf machines — reduce nodes read candidate sets held
        // at the driver, so the tree re-runs any crashed interior node inline
        // (bit-identical: same fork, same inputs) and the root runs under the
        // transient-failure plan only, as the hand-rolled loop always did.
        let m = spec.m;
        let algo_name = spec.algorithm.clone();
        let tree = TreeReduce::new(fanout);
        let tree_run = tree
            .run(&engine, frontier, &plan, policy, &mut job, |ctx, sets| {
                let mut task_rng =
                    base_rng.fork(8_000 + (ctx.level as u64) * 100 + ctx.node as u64);
                let mut pool: Vec<usize> = sets.iter().flatten().copied().collect();
                pool.sort_unstable();
                pool.dedup();
                let con = if ctx.is_root {
                    Cardinality::new(spec.k)
                } else {
                    Cardinality::new(spec.kappa)
                };
                // Fewer merge tasks each level => more oracle threads per
                // task (the root merge runs on the full budget).
                let oracle_threads = spec.oracle_threads(ctx.level_nodes);
                let algo = algorithms::by_name(&algo_name).expect("algorithm");
                let obj = if local_eval {
                    problem.merge(m, &mut task_rng)
                } else {
                    problem.global()
                };
                let run =
                    algo.maximize_threaded(obj.as_ref(), &pool, &con, &mut task_rng, oracle_threads);
                // keep the better of the merged re-run and the best input set
                // (trimmed to the level constraint), mirroring Algorithm 2.
                let mut best_set = run.solution;
                let mut best_val = obj.eval(&best_set);
                let mut calls = run.oracle_calls + best_set.len() as u64;
                for s in sets {
                    let mut trimmed = Vec::new();
                    for &e in s {
                        if con.can_add(&trimmed, e) {
                            trimmed.push(e);
                        }
                    }
                    let v = obj.eval(&trimmed);
                    calls += trimmed.len() as u64;
                    if v > best_val {
                        best_val = v;
                        best_set = trimmed;
                    }
                }
                let pooled = pool.len();
                NodeOutput { result: best_set, pooled, oracle_calls: calls }
            })
            .unwrap_or_else(|e| panic!("multiround reduction aborted: {e}"));
        fault_retries += tree_run.stats.retries;
        oracle_calls += tree_run.oracle_calls;
        rounds += tree_run.stats.depth;
        let tree_stats = tree_run.stats;

        let mut solution = tree_run.result.unwrap_or_default();
        // With m = 1 (or a degenerate tree) no root reduction ran, so the
        // leaf's κ-budget set may exceed k; the greedy selection order makes
        // the k-prefix feasible by heredity.
        solution.truncate(spec.k);
        let value = problem.global().eval(&solution);
        let fault = plan.active().then(|| FaultStats {
            policy: policy.label().to_string(),
            multiplicity,
            retries: fault_retries,
            crashed_machines: crashed,
            straggled_machines: straggled,
            dropped_elements: dropped,
            ground_size: ground.len(),
            recovery_time,
            salvaged_units,
            replayed_units,
        });
        RunMetrics {
            name: format!(
                "greedi-tree[m={},k={},fanout={}]",
                spec.m, spec.k, fanout
            ),
            solution,
            value,
            oracle_calls,
            job,
            rounds,
            stream: None,
            tree: Some(tree_stats),
            fault,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::greedi::{centralized, Greedi};
    use crate::coordinator::FacilityProblem;
    use crate::data::synth::{gaussian_blobs, SynthConfig};
    use std::sync::Arc;

    fn problem(n: usize, seed: u64) -> FacilityProblem {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 8), seed));
        FacilityProblem::new(&ds)
    }

    #[test]
    fn tree_reduces_to_single_solution() {
        let p = problem(400, 1);
        let r = MultiRoundGreedi.run(&p, &RunSpec::new(16, 8).fanout(4).seed(2));
        assert!(r.solution.len() <= 8);
        // 16 leaves → 4 → 1: 1 leaf round + 2 reduction rounds
        assert_eq!(r.rounds, 3);
        let t = r.tree.expect("multiround reports tree stats");
        assert_eq!(t.depth, 2);
        assert_eq!(t.nodes_per_level, vec![4, 1]);
        assert_eq!(t.fanout, 4);
        assert_eq!(t.peak_per_level.len(), 2);
    }

    #[test]
    fn tree_competitive_with_flat_greedi() {
        let p = problem(600, 2);
        let central = centralized(&p, 10, "lazy", 3).value;
        let flat = Greedi.run(&p, &RunSpec::new(16, 10).seed(3));
        let tree = MultiRoundGreedi.run(&p, &RunSpec::new(16, 10).fanout(4).seed(3));
        assert!(tree.value / central > 0.9, "tree ratio {}", tree.value / central);
        assert!(
            tree.value > 0.95 * flat.value,
            "tree {} vs flat {}",
            tree.value,
            flat.value
        );
    }

    #[test]
    fn per_merge_communication_bounded_by_fanout_kappa() {
        let p = problem(500, 3);
        let spec = RunSpec::new(16, 6).fanout(4).seed(4);
        let kappa = spec.kappa;
        let fanout = spec.fanout;
        let r = MultiRoundGreedi.run(&p, &spec);
        // total shuffle ≤ Σ over merge tasks of fanout·κ
        // 16→4→1: 4 + 1 merge tasks
        assert!(r.job.shuffled_elements <= 5 * fanout * kappa);
    }

    #[test]
    fn two_level_tree_equals_flat_when_fanout_ge_m() {
        let p = problem(300, 4);
        let flat = Greedi.run(&p, &RunSpec::new(4, 6).seed(5));
        let tree = MultiRoundGreedi.run(&p, &RunSpec::new(4, 6).fanout(8).seed(5));
        assert_eq!(tree.rounds, 2, "fanout ≥ m must collapse to two rounds");
        // same structure ⇒ same result given identical seeds is not
        // guaranteed (different rng streams), but quality must match.
        assert!((tree.value - flat.value).abs() / flat.value < 0.05);
    }

    #[test]
    fn single_machine_overselection_respects_k() {
        // m = 1 skips every reduction level; the κ = α·k leaf set must
        // still be clipped to the declared budget k.
        let p = problem(200, 6);
        let r = MultiRoundGreedi.run(&p, &RunSpec::new(1, 8).alpha(2.0).seed(7));
        assert!(r.solution.len() <= 8, "budget violated: {}", r.solution.len());
        assert_eq!(r.rounds, 1);
    }

    #[test]
    fn deterministic() {
        let p = problem(300, 5);
        let a = MultiRoundGreedi.run(&p, &RunSpec::new(9, 5).fanout(3).seed(6));
        let b = MultiRoundGreedi.run(&p, &RunSpec::new(9, 5).fanout(3).seed(6));
        assert_eq!(a.solution, b.solution);
    }
}
