//! Multi-round (tree-reduction) GreeDi — the paper's §4.2 extension:
//! *"it is straightforward to generalize GreeDi to multiple rounds (i.e.,
//! more than two) for very large datasets."*
//!
//! Round 0 partitions V over m leaf machines exactly as Algorithm 2; each
//! subsequent round merges groups of `fanout` candidate sets and re-runs
//! the black box, halving-or-more the machine count until one set remains.
//! With L levels the communication per synchronization drops from m·κ ids
//! at a single merge point to fanout·κ, at the cost of L−1 extra rounds —
//! the trade Fig. 8b motivates when the round-2 merge dominates.
//!
//! Guarantee: composing Theorem 4 per level gives
//! `((1−e^{−κ/k})/min(fanout,k))^L · OPT` in the worst case; with random
//! partitioning each level keeps the (1−1/e)/2-style average-case behavior,
//! and empirically the tree loses almost nothing (see the ablation bench).

use super::greedi::PartitionStrategy;
use super::metrics::RunMetrics;
use super::Problem;
use crate::algorithms;
use crate::constraints::cardinality::Cardinality;
use crate::constraints::Constraint;
use crate::mapreduce::partition::{balanced_partition, contiguous_partition, random_partition};
use crate::mapreduce::{JobReport, MapReduce};
use crate::util::rng::Rng;

/// Tree-reduction GreeDi configuration.
#[derive(Debug, Clone)]
pub struct MultiRoundConfig {
    /// Leaf machine count m.
    pub m: usize,
    /// Final budget k.
    pub k: usize,
    /// Per-machine budget κ at every level.
    pub kappa: usize,
    /// Candidate sets merged per reducer at each level (≥ 2).
    pub fanout: usize,
    pub algorithm: String,
    pub local_eval: bool,
    pub partition: PartitionStrategy,
}

impl MultiRoundConfig {
    pub fn new(m: usize, k: usize, fanout: usize) -> Self {
        MultiRoundConfig {
            m: m.max(1),
            k,
            kappa: k,
            fanout: fanout.max(2),
            algorithm: "lazy".into(),
            local_eval: false,
            partition: PartitionStrategy::Random,
        }
    }

    pub fn algorithm(mut self, name: &str) -> Self {
        assert!(algorithms::by_name(name).is_some(), "unknown algorithm {name}");
        self.algorithm = name.to_string();
        self
    }

    pub fn local(mut self) -> Self {
        self.local_eval = true;
        self
    }
}

/// The tree-reduction protocol.
pub struct MultiRoundGreedi {
    pub cfg: MultiRoundConfig,
}

impl MultiRoundGreedi {
    pub fn new(cfg: MultiRoundConfig) -> Self {
        MultiRoundGreedi { cfg }
    }

    pub fn run(&self, problem: &dyn Problem, seed: u64) -> RunMetrics {
        let cfg = &self.cfg;
        let base_rng = Rng::new(seed);
        let mut rng = base_rng.clone();
        let ground = problem.ground();
        let shards = match cfg.partition {
            PartitionStrategy::Random => random_partition(&ground, cfg.m, &mut rng),
            PartitionStrategy::Balanced => balanced_partition(&ground, cfg.m, &mut rng),
            PartitionStrategy::Contiguous => contiguous_partition(&ground, cfg.m),
        };

        let engine = MapReduce::new(1);
        let mut job = JobReport::default();
        let mut oracle_calls = 0u64;
        let mut rounds = 0usize;

        // ---- Level 0: leaves ------------------------------------------------
        let leaf_con = Cardinality::new(cfg.kappa);
        let local_eval = cfg.local_eval;
        let algo_name = cfg.algorithm.clone();
        let inputs: Vec<(usize, Vec<usize>)> = shards.into_iter().enumerate().collect();
        let (leaf_results, stage) = engine.run_stage(inputs, |_, (i, shard)| {
            let mut task_rng = base_rng.fork(7_000 + i as u64);
            let algo = algorithms::by_name(&algo_name).expect("algorithm");
            let obj = if local_eval {
                problem.local(&shard, &mut task_rng)
            } else {
                problem.global()
            };
            algo.maximize(obj.as_ref(), &shard, &leaf_con, &mut task_rng)
        });
        job.stages.push(stage);
        rounds += 1;
        oracle_calls += leaf_results.iter().map(|r| r.oracle_calls).sum::<u64>();
        let mut frontier: Vec<Vec<usize>> =
            leaf_results.into_iter().map(|r| r.solution).collect();

        // ---- Reduction levels ----------------------------------------------
        let mut level = 0u64;
        while frontier.len() > 1 {
            level += 1;
            rounds += 1;
            let groups: Vec<(usize, Vec<Vec<usize>>)> = frontier
                .chunks(cfg.fanout)
                .map(|c| c.to_vec())
                .enumerate()
                .collect();
            let is_root = groups.len() == 1;
            let con = if is_root {
                Cardinality::new(cfg.k)
            } else {
                Cardinality::new(cfg.kappa)
            };
            let m = cfg.m;
            let algo_name = cfg.algorithm.clone();
            let (next, stage) = engine.run_stage(groups, |_, (gi, sets)| {
                let mut task_rng = base_rng.fork(8_000 + level * 100 + gi as u64);
                let mut pool: Vec<usize> = sets.iter().flatten().copied().collect();
                pool.sort_unstable();
                pool.dedup();
                let algo = algorithms::by_name(&algo_name).expect("algorithm");
                let obj = if local_eval {
                    problem.merge(m, &mut task_rng)
                } else {
                    problem.global()
                };
                let run = algo.maximize(obj.as_ref(), &pool, &con, &mut task_rng);
                // keep the better of the merged re-run and the best input set
                // (trimmed to the level constraint), mirroring Algorithm 2.
                let mut best_set = run.solution;
                let mut best_val = obj.eval(&best_set);
                let mut calls = run.oracle_calls + best_set.len() as u64;
                for s in &sets {
                    let mut trimmed = Vec::new();
                    for &e in s {
                        if con.can_add(&trimmed, e) {
                            trimmed.push(e);
                        }
                    }
                    let v = obj.eval(&trimmed);
                    calls += trimmed.len() as u64;
                    if v > best_val {
                        best_val = v;
                        best_set = trimmed;
                    }
                }
                (best_set, pool.len(), calls)
            });
            job.stages.push(stage);
            let mut new_frontier = Vec::with_capacity(next.len());
            for (set, pool_len, calls) in next {
                job.record_shuffle(pool_len);
                oracle_calls += calls;
                new_frontier.push(set);
            }
            frontier = new_frontier;
        }

        let solution = frontier.pop().unwrap_or_default();
        let value = problem.global().eval(&solution);
        RunMetrics {
            name: format!(
                "greedi-tree[m={},k={},fanout={}]",
                cfg.m, cfg.k, cfg.fanout
            ),
            solution,
            value,
            oracle_calls,
            job,
            rounds,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::greedi::{centralized, Greedi, GreediConfig};
    use crate::coordinator::FacilityProblem;
    use crate::data::synth::{gaussian_blobs, SynthConfig};
    use std::sync::Arc;

    fn problem(n: usize, seed: u64) -> FacilityProblem {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 8), seed));
        FacilityProblem::new(&ds)
    }

    #[test]
    fn tree_reduces_to_single_solution() {
        let p = problem(400, 1);
        let r = MultiRoundGreedi::new(MultiRoundConfig::new(16, 8, 4)).run(&p, 2);
        assert!(r.solution.len() <= 8);
        // 16 leaves → 4 → 1: 1 leaf round + 2 reduction rounds
        assert_eq!(r.rounds, 3);
    }

    #[test]
    fn tree_competitive_with_flat_greedi() {
        let p = problem(600, 2);
        let central = centralized(&p, 10, "lazy", 3).value;
        let flat = Greedi::new(GreediConfig::new(16, 10)).run(&p, 3);
        let tree = MultiRoundGreedi::new(MultiRoundConfig::new(16, 10, 4)).run(&p, 3);
        assert!(tree.value / central > 0.9, "tree ratio {}", tree.value / central);
        assert!(
            tree.value > 0.95 * flat.value,
            "tree {} vs flat {}",
            tree.value,
            flat.value
        );
    }

    #[test]
    fn per_merge_communication_bounded_by_fanout_kappa() {
        let p = problem(500, 3);
        let cfg = MultiRoundConfig::new(16, 6, 4);
        let kappa = cfg.kappa;
        let fanout = cfg.fanout;
        let r = MultiRoundGreedi::new(cfg).run(&p, 4);
        // total shuffle ≤ Σ over merge tasks of fanout·κ
        // 16→4→1: 4 + 1 merge tasks
        assert!(r.job.shuffled_elements <= 5 * fanout * kappa);
    }

    #[test]
    fn two_level_tree_equals_flat_when_fanout_ge_m() {
        let p = problem(300, 4);
        let flat = Greedi::new(GreediConfig::new(4, 6)).run(&p, 5);
        let tree = MultiRoundGreedi::new(MultiRoundConfig::new(4, 6, 8)).run(&p, 5);
        assert_eq!(tree.rounds, 2, "fanout ≥ m must collapse to two rounds");
        // same structure ⇒ same result given identical seeds is not
        // guaranteed (different rng streams), but quality must match.
        assert!((tree.value - flat.value).abs() / flat.value < 0.05);
    }

    #[test]
    fn deterministic() {
        let p = problem(300, 5);
        let a = MultiRoundGreedi::new(MultiRoundConfig::new(9, 5, 3)).run(&p, 6);
        let b = MultiRoundGreedi::new(MultiRoundConfig::new(9, 5, 3)).run(&p, 6);
        assert_eq!(a.solution, b.solution);
    }
}
