//! Multi-round (tree-reduction) GreeDi — the paper's §4.2 extension:
//! *"it is straightforward to generalize GreeDi to multiple rounds (i.e.,
//! more than two) for very large datasets."*
//!
//! Round 0 partitions V over m leaf machines exactly as Algorithm 2; each
//! subsequent round merges groups of `fanout` candidate sets and re-runs
//! the black box, halving-or-more the machine count until one set remains.
//! With L levels the communication per synchronization drops from m·κ ids
//! at a single merge point to fanout·κ, at the cost of L−1 extra rounds —
//! the trade Fig. 8b motivates when the round-2 merge dominates.
//!
//! Guarantee: composing Theorem 4 per level gives
//! `((1−e^{−κ/k})/min(fanout,k))^L · OPT` in the worst case; with random
//! partitioning each level keeps the (1−1/e)/2-style average-case behavior,
//! and empirically the tree loses almost nothing (see the ablation bench).
//!
//! Registered as `"multiround"`; reads m, k, κ, `fanout`, algorithm,
//! local/global mode, partition, threads and seed from the shared
//! [`RunSpec`].

use super::metrics::RunMetrics;
use super::protocol::{Protocol, RunSpec};
use super::Problem;
use crate::algorithms;
use crate::constraints::cardinality::Cardinality;
use crate::constraints::Constraint;
use crate::mapreduce::{JobReport, MapReduce};
use crate::util::rng::Rng;

/// The tree-reduction protocol.
pub struct MultiRoundGreedi;

impl Protocol for MultiRoundGreedi {
    fn name(&self) -> &'static str {
        "multiround"
    }

    fn run(&self, problem: &dyn Problem, spec: &RunSpec) -> RunMetrics {
        let fanout = spec.fanout.max(2);
        let base_rng = Rng::new(spec.seed);
        let mut rng = base_rng.clone();
        let ground = problem.ground();
        let shards = spec.partition.split(&ground, spec.m, &mut rng);

        let engine = MapReduce::new(spec.threads);
        let mut job = JobReport::default();
        let mut oracle_calls = 0u64;
        let mut rounds = 0usize;

        // ---- Level 0: leaves ------------------------------------------------
        let leaf_con = Cardinality::new(spec.kappa);
        let local_eval = spec.local_eval;
        let algo_name = spec.algorithm.clone();
        let inputs: Vec<(usize, Vec<usize>)> = shards.into_iter().enumerate().collect();
        let leaf_oracle_threads = spec.oracle_threads(inputs.len());
        let (leaf_results, stage) = engine.run_stage(inputs, |_, (i, shard)| {
            let mut task_rng = base_rng.fork(7_000 + i as u64);
            let algo = algorithms::by_name(&algo_name).expect("algorithm");
            let obj = if local_eval {
                problem.local(&shard, &mut task_rng)
            } else {
                problem.global()
            };
            algo.maximize_threaded(
                obj.as_ref(),
                &shard,
                &leaf_con,
                &mut task_rng,
                leaf_oracle_threads,
            )
        });
        job.stages.push(stage);
        rounds += 1;
        oracle_calls += leaf_results.iter().map(|r| r.oracle_calls).sum::<u64>();
        let mut frontier: Vec<Vec<usize>> =
            leaf_results.into_iter().map(|r| r.solution).collect();

        // ---- Reduction levels ----------------------------------------------
        let mut level = 0u64;
        while frontier.len() > 1 {
            level += 1;
            rounds += 1;
            let groups: Vec<(usize, Vec<Vec<usize>>)> = frontier
                .chunks(fanout)
                .map(|c| c.to_vec())
                .enumerate()
                .collect();
            let is_root = groups.len() == 1;
            let con = if is_root {
                Cardinality::new(spec.k)
            } else {
                Cardinality::new(spec.kappa)
            };
            let m = spec.m;
            let algo_name = spec.algorithm.clone();
            // Fewer merge tasks each level => more oracle threads per task
            // (the root merge runs on the full budget).
            let oracle_threads = spec.oracle_threads(groups.len());
            let (next, stage) = engine.run_stage(groups, |_, (gi, sets)| {
                let mut task_rng = base_rng.fork(8_000 + level * 100 + gi as u64);
                let mut pool: Vec<usize> = sets.iter().flatten().copied().collect();
                pool.sort_unstable();
                pool.dedup();
                let algo = algorithms::by_name(&algo_name).expect("algorithm");
                let obj = if local_eval {
                    problem.merge(m, &mut task_rng)
                } else {
                    problem.global()
                };
                let run =
                    algo.maximize_threaded(obj.as_ref(), &pool, &con, &mut task_rng, oracle_threads);
                // keep the better of the merged re-run and the best input set
                // (trimmed to the level constraint), mirroring Algorithm 2.
                let mut best_set = run.solution;
                let mut best_val = obj.eval(&best_set);
                let mut calls = run.oracle_calls + best_set.len() as u64;
                for s in &sets {
                    let mut trimmed = Vec::new();
                    for &e in s {
                        if con.can_add(&trimmed, e) {
                            trimmed.push(e);
                        }
                    }
                    let v = obj.eval(&trimmed);
                    calls += trimmed.len() as u64;
                    if v > best_val {
                        best_val = v;
                        best_set = trimmed;
                    }
                }
                (best_set, pool.len(), calls)
            });
            job.stages.push(stage);
            let mut new_frontier = Vec::with_capacity(next.len());
            for (set, pool_len, calls) in next {
                job.record_shuffle(pool_len);
                oracle_calls += calls;
                new_frontier.push(set);
            }
            frontier = new_frontier;
        }

        let mut solution = frontier.pop().unwrap_or_default();
        // With m = 1 (or a degenerate tree) no root reduction ran, so the
        // leaf's κ-budget set may exceed k; the greedy selection order makes
        // the k-prefix feasible by heredity.
        solution.truncate(spec.k);
        let value = problem.global().eval(&solution);
        RunMetrics {
            name: format!(
                "greedi-tree[m={},k={},fanout={}]",
                spec.m, spec.k, fanout
            ),
            solution,
            value,
            oracle_calls,
            job,
            rounds,
            stream: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::greedi::{centralized, Greedi};
    use crate::coordinator::FacilityProblem;
    use crate::data::synth::{gaussian_blobs, SynthConfig};
    use std::sync::Arc;

    fn problem(n: usize, seed: u64) -> FacilityProblem {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 8), seed));
        FacilityProblem::new(&ds)
    }

    #[test]
    fn tree_reduces_to_single_solution() {
        let p = problem(400, 1);
        let r = MultiRoundGreedi.run(&p, &RunSpec::new(16, 8).fanout(4).seed(2));
        assert!(r.solution.len() <= 8);
        // 16 leaves → 4 → 1: 1 leaf round + 2 reduction rounds
        assert_eq!(r.rounds, 3);
    }

    #[test]
    fn tree_competitive_with_flat_greedi() {
        let p = problem(600, 2);
        let central = centralized(&p, 10, "lazy", 3).value;
        let flat = Greedi.run(&p, &RunSpec::new(16, 10).seed(3));
        let tree = MultiRoundGreedi.run(&p, &RunSpec::new(16, 10).fanout(4).seed(3));
        assert!(tree.value / central > 0.9, "tree ratio {}", tree.value / central);
        assert!(
            tree.value > 0.95 * flat.value,
            "tree {} vs flat {}",
            tree.value,
            flat.value
        );
    }

    #[test]
    fn per_merge_communication_bounded_by_fanout_kappa() {
        let p = problem(500, 3);
        let spec = RunSpec::new(16, 6).fanout(4).seed(4);
        let kappa = spec.kappa;
        let fanout = spec.fanout;
        let r = MultiRoundGreedi.run(&p, &spec);
        // total shuffle ≤ Σ over merge tasks of fanout·κ
        // 16→4→1: 4 + 1 merge tasks
        assert!(r.job.shuffled_elements <= 5 * fanout * kappa);
    }

    #[test]
    fn two_level_tree_equals_flat_when_fanout_ge_m() {
        let p = problem(300, 4);
        let flat = Greedi.run(&p, &RunSpec::new(4, 6).seed(5));
        let tree = MultiRoundGreedi.run(&p, &RunSpec::new(4, 6).fanout(8).seed(5));
        assert_eq!(tree.rounds, 2, "fanout ≥ m must collapse to two rounds");
        // same structure ⇒ same result given identical seeds is not
        // guaranteed (different rng streams), but quality must match.
        assert!((tree.value - flat.value).abs() / flat.value < 0.05);
    }

    #[test]
    fn single_machine_overselection_respects_k() {
        // m = 1 skips every reduction level; the κ = α·k leaf set must
        // still be clipped to the declared budget k.
        let p = problem(200, 6);
        let r = MultiRoundGreedi.run(&p, &RunSpec::new(1, 8).alpha(2.0).seed(7));
        assert!(r.solution.len() <= 8, "budget violated: {}", r.solution.len());
        assert_eq!(r.rounds, 1);
    }

    #[test]
    fn deterministic() {
        let p = problem(300, 5);
        let a = MultiRoundGreedi.run(&p, &RunSpec::new(9, 5).fanout(3).seed(6));
        let b = MultiRoundGreedi.run(&p, &RunSpec::new(9, 5).fanout(3).seed(6));
        assert_eq!(a.solution, b.solution);
    }
}
