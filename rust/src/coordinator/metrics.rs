//! Unified accounting for distributed runs: solution quality, oracle load,
//! simulated cluster time, communication volume and MapReduce round count —
//! the quantities behind every figure in the paper's §6.

use crate::mapreduce::JobReport;

/// Bounded-memory accounting for streaming protocols (`stream_greedi`):
/// the realized per-machine memory footprint of the one-pass sieve stage,
/// reported against its theoretical O(k·log(k)/ε) candidate ceiling.
#[derive(Debug, Clone, Default)]
pub struct StreamStats {
    /// Peak live sieve candidates on each machine (map-task order).
    pub peak_live_per_machine: Vec<usize>,
    /// The candidate ceiling every machine must respect
    /// ([`crate::stream::sieve::candidate_bound`]).
    pub live_bound: usize,
    /// Elements each machine consumed from its shard stream.
    pub elements_per_machine: Vec<usize>,
    /// Stream batch size used by the map stage.
    pub batch: usize,
    /// Map/merge task retries under the run's fault plan (0 without faults).
    pub retries: usize,
}

impl StreamStats {
    /// Largest per-machine peak (the number the memory bound gates on).
    pub fn peak_live(&self) -> usize {
        self.peak_live_per_machine.iter().copied().max().unwrap_or(0)
    }

    /// Whether every machine stayed within the candidate ceiling.
    pub fn within_bound(&self) -> bool {
        self.peak_live() <= self.live_bound
    }
}

/// Fault-tolerance accounting for a run executed under an active
/// `FaultPlan` (`None` on fault-free runs). Everything here is
/// deterministic from (seed, plan).
#[derive(Debug, Clone, Default)]
pub struct FaultStats {
    /// Recovery policy label ("retry" / "drop_shard" / "survivor_merge").
    pub policy: String,
    /// Replication multiplicity c the run partitioned with.
    pub multiplicity: usize,
    /// Failed attempts re-executed across all stages.
    pub retries: usize,
    /// Map machines lost for the run (task order).
    pub crashed_machines: Vec<usize>,
    /// Map machines whose wallclock was straggler-inflated.
    pub straggled_machines: Vec<usize>,
    /// Ground elements that survived on NO machine after the crashes.
    pub dropped_elements: usize,
    /// |V| — denominator for the surviving-coverage fraction.
    pub ground_size: usize,
    /// Wallclock of the survivor-merge recovery stage (0 when none ran).
    pub recovery_time: f64,
}

impl FaultStats {
    /// Fraction of the ground set still on some surviving machine.
    pub fn coverage(&self) -> f64 {
        if self.ground_size == 0 {
            return 1.0;
        }
        (self.ground_size - self.dropped_elements) as f64 / self.ground_size as f64
    }
}

/// Outcome of one distributed (or centralized) protocol run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    /// Protocol label ("greedi", "greedy/max", "centralized", …).
    pub name: String,
    /// Final solution (global element ids).
    pub solution: Vec<usize>,
    /// f(solution) under the TRUE global objective.
    pub value: f64,
    /// Total marginal-gain oracle calls across all machines and stages.
    pub oracle_calls: u64,
    /// Per-stage timing and shuffle accounting.
    pub job: JobReport,
    /// Synchronous MapReduce rounds used (GreeDi: 2; GreedyScaling: many).
    pub rounds: usize,
    /// Streaming-stage memory accounting (`None` for batch protocols).
    pub stream: Option<StreamStats>,
    /// Fault-tolerance accounting (`None` for fault-free runs).
    pub fault: Option<FaultStats>,
}

impl RunMetrics {
    /// Simulated parallel wallclock (max task per stage, summed).
    pub fn sim_time(&self) -> f64 {
        self.job.sim_parallel_time()
    }

    /// Speedup of this run relative to a centralized baseline time.
    pub fn speedup_vs(&self, centralized_secs: f64) -> f64 {
        if self.sim_time() <= 0.0 {
            return f64::NAN;
        }
        centralized_secs / self.sim_time()
    }

    /// Ratio of this run's value to a reference (the paper's headline
    /// "distributed / centralized" metric).
    pub fn ratio_vs(&self, centralized_value: f64) -> f64 {
        if centralized_value.abs() < 1e-300 {
            return f64::NAN;
        }
        self.value / centralized_value
    }

    pub fn one_line(&self) -> String {
        let stream = match &self.stream {
            Some(s) => format!(" peak_live={}/{}", s.peak_live(), s.live_bound),
            None => String::new(),
        };
        let fault = match &self.fault {
            Some(f) => format!(
                " fault=[{} c={} crashed={} cov={:.0}% retries={} rec={:.4}s]",
                f.policy,
                f.multiplicity,
                f.crashed_machines.len(),
                f.coverage() * 100.0,
                f.retries,
                f.recovery_time
            ),
            None => String::new(),
        };
        format!(
            "{:<16} f(S)={:<12.5} |S|={:<4} oracle={:<10} rounds={} simt={:.4}s comm={}{}{}",
            self.name,
            self.value,
            self.solution.len(),
            self.oracle_calls,
            self.rounds,
            self.sim_time(),
            self.job.shuffled_elements,
            stream,
            fault
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_and_speedup() {
        let mut m = RunMetrics {
            name: "x".into(),
            value: 9.0,
            ..Default::default()
        };
        assert!((m.ratio_vs(10.0) - 0.9).abs() < 1e-12);
        assert!(m.ratio_vs(0.0).is_nan());
        // no stages => sim_time 0 => NaN speedup
        assert!(m.speedup_vs(1.0).is_nan());
        m.job.stages.push(crate::mapreduce::StageReport {
            task_times: vec![0.5],
            max_task_time: 0.5,
            total_cpu_time: 0.5,
        });
        assert!((m.speedup_vs(1.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn one_line_contains_fields() {
        let m = RunMetrics { name: "greedi".into(), value: 1.25, rounds: 2, ..Default::default() };
        let s = m.one_line();
        assert!(s.contains("greedi"));
        assert!(s.contains("rounds=2"));
        assert!(!s.contains("peak_live"), "batch protocols carry no stream stats");
        assert!(!s.contains("fault="), "fault-free runs carry no fault block");
    }

    #[test]
    fn fault_stats_coverage_and_one_line() {
        let f = FaultStats {
            policy: "drop_shard".into(),
            multiplicity: 2,
            retries: 3,
            crashed_machines: vec![1, 4],
            dropped_elements: 25,
            ground_size: 100,
            ..Default::default()
        };
        assert!((f.coverage() - 0.75).abs() < 1e-12);
        assert!((FaultStats::default().coverage() - 1.0).abs() < 1e-12, "empty ground = full coverage");
        let m = RunMetrics { name: "greedi".into(), fault: Some(f), ..Default::default() };
        let line = m.one_line();
        assert!(line.contains("fault=[drop_shard c=2 crashed=2 cov=75%"), "{line}");
    }

    #[test]
    fn stream_stats_peak_and_bound() {
        let s = StreamStats {
            peak_live_per_machine: vec![12, 30, 7],
            live_bound: 40,
            elements_per_machine: vec![100, 100, 99],
            batch: 64,
            retries: 0,
        };
        assert_eq!(s.peak_live(), 30);
        assert!(s.within_bound());
        let over = StreamStats { live_bound: 20, ..s.clone() };
        assert!(!over.within_bound());
        assert_eq!(StreamStats::default().peak_live(), 0);
        let m = RunMetrics { name: "stream_greedi".into(), stream: Some(s), ..Default::default() };
        assert!(m.one_line().contains("peak_live=30/40"));
    }
}
