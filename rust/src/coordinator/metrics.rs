//! Unified accounting for distributed runs: solution quality, oracle load,
//! simulated cluster time, communication volume and MapReduce round count —
//! the quantities behind every figure in the paper's §6.

use crate::mapreduce::JobReport;
use crate::util::json::Json;

pub use crate::mapreduce::reduce::TreeStats;

/// Bounded-memory accounting for streaming protocols (`stream_greedi`):
/// the realized per-machine memory footprint of the one-pass sieve stage,
/// reported against its theoretical O(k·log(k)/ε) candidate ceiling.
#[derive(Debug, Clone, Default)]
pub struct StreamStats {
    /// Peak live sieve candidates on each machine (map-task order).
    pub peak_live_per_machine: Vec<usize>,
    /// The candidate ceiling every machine must respect
    /// ([`crate::stream::sieve::candidate_bound`]).
    pub live_bound: usize,
    /// Elements each machine consumed from its shard stream.
    pub elements_per_machine: Vec<usize>,
    /// Stream batch size used by the map stage.
    pub batch: usize,
    /// Map/merge task retries under the run's fault plan (0 without faults).
    pub retries: usize,
}

impl StreamStats {
    /// Largest per-machine peak (the number the memory bound gates on).
    pub fn peak_live(&self) -> usize {
        self.peak_live_per_machine.iter().copied().max().unwrap_or(0)
    }

    /// Whether every machine stayed within the candidate ceiling.
    pub fn within_bound(&self) -> bool {
        self.peak_live() <= self.live_bound
    }

    /// The `stream` block of [`RunMetrics::to_json`].
    pub fn to_json(&self) -> Json {
        Json::obj([
            (
                "peak_live_per_machine",
                Json::Arr(self.peak_live_per_machine.iter().map(|&p| Json::num(p as f64)).collect()),
            ),
            ("peak_live", Json::num(self.peak_live() as f64)),
            ("live_bound", Json::num(self.live_bound as f64)),
            ("within_bound", Json::Bool(self.within_bound())),
            (
                "elements_per_machine",
                Json::Arr(self.elements_per_machine.iter().map(|&e| Json::num(e as f64)).collect()),
            ),
            ("batch", Json::num(self.batch as f64)),
            ("retries", Json::num(self.retries as f64)),
        ])
    }
}

/// Fault-tolerance accounting for a run executed under an active
/// `FaultPlan` (`None` on fault-free runs). Everything here is
/// deterministic from (seed, plan).
#[derive(Debug, Clone, Default)]
pub struct FaultStats {
    /// Recovery policy label ("retry" / "drop_shard" / "survivor_merge" /
    /// "resume").
    pub policy: String,
    /// Replication multiplicity c the run partitioned with.
    pub multiplicity: usize,
    /// Failed attempts re-executed across all stages.
    pub retries: usize,
    /// Map machines lost for the run (task order).
    pub crashed_machines: Vec<usize>,
    /// Map machines whose wallclock was straggler-inflated.
    pub straggled_machines: Vec<usize>,
    /// Ground elements that survived on NO machine after the crashes.
    pub dropped_elements: usize,
    /// |V| — denominator for the surviving-coverage fraction.
    pub ground_size: usize,
    /// Wallclock of the survivor-merge recovery stage (0 when none ran).
    pub recovery_time: f64,
    /// Progress units (greedy picks / sieve batches) restored from crashed
    /// machines' last checkpoints under `Resume` — work NOT recomputed.
    pub salvaged_units: usize,
    /// Progress units re-executed past the last checkpoint under `Resume`.
    pub replayed_units: usize,
}

impl FaultStats {
    /// Fraction of the ground set still on some surviving machine.
    pub fn coverage(&self) -> f64 {
        if self.ground_size == 0 {
            return 1.0;
        }
        (self.ground_size - self.dropped_elements) as f64 / self.ground_size as f64
    }

    /// Fraction of a crashed machine's recovery work the checkpoints saved:
    /// salvaged / (salvaged + replayed), or 0 when no Resume recovery ran.
    pub fn recompute_saved(&self) -> f64 {
        let total = self.salvaged_units + self.replayed_units;
        if total == 0 {
            return 0.0;
        }
        self.salvaged_units as f64 / total as f64
    }

    /// The `fault` block of [`RunMetrics::to_json`].
    pub fn to_json(&self) -> Json {
        Json::obj([
            ("policy", Json::str(self.policy.as_str())),
            ("multiplicity", Json::num(self.multiplicity as f64)),
            ("retries", Json::num(self.retries as f64)),
            (
                "crashed_machines",
                Json::Arr(self.crashed_machines.iter().map(|&m| Json::num(m as f64)).collect()),
            ),
            (
                "straggled_machines",
                Json::Arr(self.straggled_machines.iter().map(|&m| Json::num(m as f64)).collect()),
            ),
            ("dropped_elements", Json::num(self.dropped_elements as f64)),
            ("ground_size", Json::num(self.ground_size as f64)),
            ("coverage", Json::num(self.coverage())),
            ("recovery_time", Json::num(self.recovery_time)),
            ("salvaged_units", Json::num(self.salvaged_units as f64)),
            ("replayed_units", Json::num(self.replayed_units as f64)),
            ("recompute_saved", Json::num(self.recompute_saved())),
        ])
    }
}

/// Outcome of one distributed (or centralized) protocol run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    /// Protocol label ("greedi", "greedy/max", "centralized", …).
    pub name: String,
    /// Final solution (global element ids).
    pub solution: Vec<usize>,
    /// f(solution) under the TRUE global objective.
    pub value: f64,
    /// Total marginal-gain oracle calls across all machines and stages.
    pub oracle_calls: u64,
    /// Per-stage timing and shuffle accounting.
    pub job: JobReport,
    /// Synchronous MapReduce rounds used (GreeDi: 2; GreedyScaling: many).
    pub rounds: usize,
    /// Streaming-stage memory accounting (`None` for batch protocols).
    pub stream: Option<StreamStats>,
    /// Accumulation-tree accounting — per-level peak candidates, depth,
    /// interior recoveries (`None` for protocols without a reduce tree).
    /// A flat single-root merge is a depth-1 tree.
    pub tree: Option<TreeStats>,
    /// Fault-tolerance accounting (`None` for fault-free runs).
    pub fault: Option<FaultStats>,
}

impl RunMetrics {
    /// Simulated parallel wallclock (max task per stage, summed).
    pub fn sim_time(&self) -> f64 {
        self.job.sim_parallel_time()
    }

    /// Speedup of this run relative to a centralized baseline time.
    pub fn speedup_vs(&self, centralized_secs: f64) -> f64 {
        if self.sim_time() <= 0.0 {
            return f64::NAN;
        }
        centralized_secs / self.sim_time()
    }

    /// Ratio of this run's value to a reference (the paper's headline
    /// "distributed / centralized" metric).
    pub fn ratio_vs(&self, centralized_value: f64) -> f64 {
        if centralized_value.abs() < 1e-300 {
            return f64::NAN;
        }
        self.value / centralized_value
    }

    /// Canonical JSON view of a run — the single formatter behind
    /// experiment trails and the serve wire's `query` / `stats` replies.
    /// Round-trips through `util::json::parse` (see the unit test).
    pub fn to_json(&self) -> Json {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("name".to_string(), Json::str(self.name.as_str()));
        obj.insert("value".to_string(), Json::num(self.value));
        obj.insert(
            "solution".to_string(),
            Json::Arr(self.solution.iter().map(|&e| Json::num(e as f64)).collect()),
        );
        obj.insert("oracle_calls".to_string(), Json::num(self.oracle_calls as f64));
        obj.insert("rounds".to_string(), Json::num(self.rounds as f64));
        obj.insert("sim_time".to_string(), Json::num(self.sim_time()));
        obj.insert("shuffled_elements".to_string(), Json::num(self.job.shuffled_elements as f64));
        if let Some(s) = &self.stream {
            obj.insert("stream".to_string(), s.to_json());
        }
        if let Some(t) = &self.tree {
            obj.insert("tree".to_string(), t.to_json());
        }
        if let Some(f) = &self.fault {
            obj.insert("fault".to_string(), f.to_json());
        }
        Json::Obj(obj)
    }

    pub fn one_line(&self) -> String {
        let stream = match &self.stream {
            Some(s) => format!(" peak_live={}/{}", s.peak_live(), s.live_bound),
            None => String::new(),
        };
        // Depth-1 trees are the classic flat merge — nothing worth a block.
        let tree = match &self.tree {
            Some(t) if t.depth > 1 => {
                format!(" tree=[r={} depth={} root_peak={}]", t.fanout, t.depth, t.root_peak())
            }
            _ => String::new(),
        };
        let fault = match &self.fault {
            Some(f) => {
                let salvage = if f.salvaged_units + f.replayed_units > 0 {
                    format!(
                        " salvaged={} replayed={}",
                        f.salvaged_units, f.replayed_units
                    )
                } else {
                    String::new()
                };
                format!(
                    " fault=[{} c={} crashed={} straggled={} cov={:.0}% retries={} rec={:.4}s{}]",
                    f.policy,
                    f.multiplicity,
                    f.crashed_machines.len(),
                    f.straggled_machines.len(),
                    f.coverage() * 100.0,
                    f.retries,
                    f.recovery_time,
                    salvage
                )
            }
            None => String::new(),
        };
        format!(
            "{:<16} f(S)={:<12.5} |S|={:<4} oracle={:<10} rounds={} simt={:.4}s comm={}{}{}{}",
            self.name,
            self.value,
            self.solution.len(),
            self.oracle_calls,
            self.rounds,
            self.sim_time(),
            self.job.shuffled_elements,
            stream,
            tree,
            fault
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_and_speedup() {
        let mut m = RunMetrics {
            name: "x".into(),
            value: 9.0,
            ..Default::default()
        };
        assert!((m.ratio_vs(10.0) - 0.9).abs() < 1e-12);
        assert!(m.ratio_vs(0.0).is_nan());
        // no stages => sim_time 0 => NaN speedup
        assert!(m.speedup_vs(1.0).is_nan());
        m.job.stages.push(crate::mapreduce::StageReport {
            task_times: vec![0.5],
            max_task_time: 0.5,
            total_cpu_time: 0.5,
        });
        assert!((m.speedup_vs(1.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn one_line_contains_fields() {
        let m = RunMetrics { name: "greedi".into(), value: 1.25, rounds: 2, ..Default::default() };
        let s = m.one_line();
        assert!(s.contains("greedi"));
        assert!(s.contains("rounds=2"));
        assert!(!s.contains("peak_live"), "batch protocols carry no stream stats");
        assert!(!s.contains("fault="), "fault-free runs carry no fault block");
    }

    #[test]
    fn fault_stats_coverage_and_one_line() {
        let f = FaultStats {
            policy: "drop_shard".into(),
            multiplicity: 2,
            retries: 3,
            crashed_machines: vec![1, 4],
            dropped_elements: 25,
            ground_size: 100,
            ..Default::default()
        };
        assert!((f.coverage() - 0.75).abs() < 1e-12);
        assert!((FaultStats::default().coverage() - 1.0).abs() < 1e-12, "empty ground = full coverage");
        let m = RunMetrics { name: "greedi".into(), fault: Some(f), ..Default::default() };
        let line = m.one_line();
        assert!(line.contains("fault=[drop_shard c=2 crashed=2 straggled=0 cov=75%"), "{line}");
    }

    #[test]
    fn salvage_accounting_surfaces_only_under_resume() {
        let f = FaultStats {
            policy: "resume".into(),
            multiplicity: 2,
            crashed_machines: vec![1],
            ground_size: 100,
            salvaged_units: 24,
            replayed_units: 8,
            ..Default::default()
        };
        assert!((f.recompute_saved() - 0.75).abs() < 1e-12);
        assert_eq!(FaultStats::default().recompute_saved(), 0.0, "no resume => 0");
        let j = f.to_json();
        assert_eq!(j.get("salvaged_units").and_then(|v| v.as_f64()), Some(24.0));
        assert_eq!(j.get("replayed_units").and_then(|v| v.as_f64()), Some(8.0));
        assert_eq!(j.get("recompute_saved").and_then(|v| v.as_f64()), Some(0.75));
        let m = RunMetrics { name: "greedi".into(), fault: Some(f), ..Default::default() };
        let line = m.one_line();
        assert!(line.contains("salvaged=24 replayed=8]"), "{line}");
        // without any salvage the fault block keeps its PR 7 shape
        let bare = RunMetrics {
            name: "greedi".into(),
            fault: Some(FaultStats { policy: "retry".into(), ..Default::default() }),
            ..Default::default()
        };
        assert!(!bare.one_line().contains("salvaged="), "{}", bare.one_line());
    }

    #[test]
    fn one_line_reports_stragglers() {
        let f = FaultStats {
            policy: "retry".into(),
            multiplicity: 1,
            straggled_machines: vec![0, 3, 7],
            ground_size: 10,
            ..Default::default()
        };
        let m = RunMetrics { name: "greedi".into(), fault: Some(f), ..Default::default() };
        let line = m.one_line();
        assert!(line.contains("straggled=3"), "{line}");
    }

    #[test]
    fn to_json_round_trips_and_carries_blocks() {
        let m = RunMetrics {
            name: "greedi".into(),
            solution: vec![3, 1, 4],
            value: 2.5,
            oracle_calls: 123,
            rounds: 2,
            stream: Some(StreamStats {
                peak_live_per_machine: vec![5, 9],
                live_bound: 12,
                elements_per_machine: vec![50, 49],
                batch: 16,
                retries: 1,
            }),
            fault: Some(FaultStats {
                policy: "survivor_merge".into(),
                multiplicity: 2,
                retries: 4,
                crashed_machines: vec![1],
                straggled_machines: vec![0, 2],
                dropped_elements: 5,
                ground_size: 100,
                recovery_time: 0.25,
                ..Default::default()
            }),
            ..Default::default()
        };
        let j = m.to_json();
        // deterministic dump → parse round-trip through util::json
        let back = crate::util::json::parse(&j.dump()).unwrap();
        assert_eq!(back, j);
        assert_eq!(j.get("name").and_then(|v| v.as_str()), Some("greedi"));
        assert_eq!(j.get("value").and_then(|v| v.as_f64()), Some(2.5));
        assert_eq!(j.get("oracle_calls").and_then(|v| v.as_f64()), Some(123.0));
        let sol: Vec<f64> = j
            .get("solution")
            .and_then(|v| v.as_arr())
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap())
            .collect();
        assert_eq!(sol, vec![3.0, 1.0, 4.0]);
        let stream = j.get("stream").unwrap();
        assert_eq!(stream.get("peak_live").and_then(|v| v.as_f64()), Some(9.0));
        assert_eq!(stream.get("live_bound").and_then(|v| v.as_f64()), Some(12.0));
        let fault = j.get("fault").unwrap();
        assert_eq!(fault.get("policy").and_then(|v| v.as_str()), Some("survivor_merge"));
        assert_eq!(fault.get("coverage").and_then(|v| v.as_f64()), Some(0.95));
        assert_eq!(
            fault.get("straggled_machines").and_then(|v| v.as_arr()).map(|a| a.len()),
            Some(2)
        );
        // fault-free batch runs carry neither optional block
        let bare = RunMetrics { name: "x".into(), ..Default::default() }.to_json();
        assert!(bare.get("stream").is_none());
        assert!(bare.get("fault").is_none());
    }

    #[test]
    fn tree_block_surfaces_only_for_deep_trees() {
        // depth-1 = the classic flat merge: no tree block in the one-liner
        let flat = RunMetrics {
            name: "greedi".into(),
            tree: Some(TreeStats {
                fanout: 8,
                depth: 1,
                nodes_per_level: vec![1],
                peak_per_level: vec![40],
                ..Default::default()
            }),
            ..Default::default()
        };
        assert!(!flat.one_line().contains("tree=["), "{}", flat.one_line());
        // ...but the JSON always carries it when present
        let j = flat.to_json();
        assert_eq!(
            j.get("tree").and_then(|t| t.get("root_peak")).and_then(|v| v.as_f64()),
            Some(40.0)
        );
        let deep = RunMetrics {
            name: "greedi".into(),
            tree: Some(TreeStats {
                fanout: 2,
                depth: 3,
                nodes_per_level: vec![4, 2, 1],
                peak_per_level: vec![16, 12, 9],
                ..Default::default()
            }),
            ..Default::default()
        };
        let line = deep.one_line();
        assert!(line.contains("tree=[r=2 depth=3 root_peak=9]"), "{line}");
        // round-trips through util::json like every other block
        let back = crate::util::json::parse(&deep.to_json().dump()).unwrap();
        assert_eq!(back, deep.to_json());
        // protocols without a reduce tree carry no block at all
        let bare = RunMetrics { name: "centralized".into(), ..Default::default() };
        assert!(bare.to_json().get("tree").is_none());
    }

    #[test]
    fn stream_stats_peak_and_bound() {
        let s = StreamStats {
            peak_live_per_machine: vec![12, 30, 7],
            live_bound: 40,
            elements_per_machine: vec![100, 100, 99],
            batch: 64,
            retries: 0,
        };
        assert_eq!(s.peak_live(), 30);
        assert!(s.within_bound());
        let over = StreamStats { live_bound: 20, ..s.clone() };
        assert!(!over.within_bound());
        assert_eq!(StreamStats::default().peak_live(), 0);
        let m = RunMetrics { name: "stream_greedi".into(), stream: Some(s), ..Default::default() };
        assert!(m.one_line().contains("peak_live=30/40"));
    }
}
