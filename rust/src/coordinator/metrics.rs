//! Unified accounting for distributed runs: solution quality, oracle load,
//! simulated cluster time, communication volume and MapReduce round count —
//! the quantities behind every figure in the paper's §6.

use crate::mapreduce::JobReport;

/// Outcome of one distributed (or centralized) protocol run.
#[derive(Debug, Clone, Default)]
pub struct RunMetrics {
    /// Protocol label ("greedi", "greedy/max", "centralized", …).
    pub name: String,
    /// Final solution (global element ids).
    pub solution: Vec<usize>,
    /// f(solution) under the TRUE global objective.
    pub value: f64,
    /// Total marginal-gain oracle calls across all machines and stages.
    pub oracle_calls: u64,
    /// Per-stage timing and shuffle accounting.
    pub job: JobReport,
    /// Synchronous MapReduce rounds used (GreeDi: 2; GreedyScaling: many).
    pub rounds: usize,
}

impl RunMetrics {
    /// Simulated parallel wallclock (max task per stage, summed).
    pub fn sim_time(&self) -> f64 {
        self.job.sim_parallel_time()
    }

    /// Speedup of this run relative to a centralized baseline time.
    pub fn speedup_vs(&self, centralized_secs: f64) -> f64 {
        if self.sim_time() <= 0.0 {
            return f64::NAN;
        }
        centralized_secs / self.sim_time()
    }

    /// Ratio of this run's value to a reference (the paper's headline
    /// "distributed / centralized" metric).
    pub fn ratio_vs(&self, centralized_value: f64) -> f64 {
        if centralized_value.abs() < 1e-300 {
            return f64::NAN;
        }
        self.value / centralized_value
    }

    pub fn one_line(&self) -> String {
        format!(
            "{:<16} f(S)={:<12.5} |S|={:<4} oracle={:<10} rounds={} simt={:.4}s comm={}",
            self.name,
            self.value,
            self.solution.len(),
            self.oracle_calls,
            self.rounds,
            self.sim_time(),
            self.job.shuffled_elements
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratio_and_speedup() {
        let mut m = RunMetrics {
            name: "x".into(),
            value: 9.0,
            ..Default::default()
        };
        assert!((m.ratio_vs(10.0) - 0.9).abs() < 1e-12);
        assert!(m.ratio_vs(0.0).is_nan());
        // no stages => sim_time 0 => NaN speedup
        assert!(m.speedup_vs(1.0).is_nan());
        m.job.stages.push(crate::mapreduce::StageReport {
            task_times: vec![0.5],
            max_task_time: 0.5,
            total_cpu_time: 0.5,
        });
        assert!((m.speedup_vs(1.0) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn one_line_contains_fields() {
        let m = RunMetrics { name: "greedi".into(), value: 1.25, rounds: 2, ..Default::default() };
        let s = m.one_line();
        assert!(s.contains("greedi"));
        assert!(s.contains("rounds=2"));
    }
}
