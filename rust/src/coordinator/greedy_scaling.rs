//! GreedyScaling — the threshold-greedy MapReduce algorithm of Kumar,
//! Moseley, Vassilvitskii & Vattani (2013), reimplemented as the comparator
//! for the paper's §6.4 / Fig. 10.
//!
//! The driver lowers a gain threshold τ geometrically from the largest
//! singleton gain. Each synchronous MapReduce round: the cluster filters
//! the surviving elements whose marginal gain w.r.t. the current solution
//! meets τ (the distributed map stage); a memory-bounded sample of size
//! μ = O(k·n^δ·log n) of the survivors is pulled to the driver, which
//! greedily commits those still meeting τ (the reduce stage). This is the
//! (1 − 1/e − ε)-style threshold greedy; the number of synchronous rounds
//! grows like log₍₁/(1−ε)₎(Δ) — *not* the constant 2 of GreeDi — which is
//! exactly the contrast Fig. 10's caption draws.

use super::metrics::RunMetrics;
use super::Problem;
use crate::mapreduce::{JobReport, MapReduce, StageReport};
use crate::util::rng::Rng;

/// GreedyScaling configuration.
#[derive(Debug, Clone)]
pub struct GreedyScaling {
    pub k: usize,
    /// Memory exponent δ: per-round driver pool μ = ⌈k · n^δ · ln n⌉
    /// (the paper's Fig. 10 uses δ = 1/2).
    pub delta: f64,
    /// Machines (distributed filter-stage accounting).
    pub m: usize,
    /// Threshold decay: τ ← τ·(1−ε) between rounds (ε of the guarantee).
    pub epsilon: f64,
}

impl GreedyScaling {
    pub fn new(k: usize, delta: f64, m: usize) -> Self {
        GreedyScaling { k, delta, m: m.max(1), epsilon: 0.5 }
    }

    pub fn epsilon(mut self, eps: f64) -> Self {
        assert!(eps > 0.0 && eps < 1.0);
        self.epsilon = eps;
        self
    }

    pub fn run(&self, problem: &dyn Problem, seed: u64) -> RunMetrics {
        let base_rng = Rng::new(seed);
        let mut rng = base_rng.clone();
        let ground = problem.ground();
        let n = ground.len();
        let mu = (((self.k as f64) * (n as f64).powf(self.delta)
            * (n as f64).ln().max(1.0))
            .ceil() as usize)
            .max(self.k);
        let engine = MapReduce::new(1);
        let mut job = JobReport::default();

        let obj = problem.global();
        let mut state = obj.state();
        let mut oracle_calls = 0u64;
        let mut surviving: Vec<usize> = ground.clone();
        let mut rounds = 0usize;

        // Round 0: distributed max-singleton-gain scan to seed τ.
        let chunks = self.chunk(&surviving);
        let (maxima, stage0) = engine.run_stage(chunks, |_, chunk| {
            let mut st = obj.state();
            let gains = st.batch_gains(&chunk);
            let best = gains.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            (best, chunk.len() as u64)
        });
        job.stages.push(stage0);
        rounds += 1;
        let mut tau = f64::NEG_INFINITY;
        for (mx, calls) in maxima {
            tau = tau.max(mx);
            oracle_calls += calls;
        }
        if !tau.is_finite() || tau <= 0.0 {
            let value = obj.eval(&[]);
            return self.finish(Vec::new(), value, oracle_calls, job, rounds);
        }
        let tau_floor = tau * self.epsilon / (2.0 * self.k as f64);

        while state.selected().len() < self.k && !surviving.is_empty() && tau > tau_floor {
            rounds += 1;

            // -- distributed filter: survivors with gain >= τ ----------------
            let selected_now = state.selected().to_vec();
            let chunks = self.chunk(&surviving);
            let (filtered, filter_stage) = engine.run_stage(chunks, |_, chunk| {
                let mut st = obj.state();
                for &s in &selected_now {
                    st.push(s);
                }
                let mut keep = Vec::new();
                let mut calls = 0u64;
                for &e in &chunk {
                    if st.gain(e) >= tau {
                        keep.push(e);
                    }
                    calls += 1;
                }
                (keep, calls)
            });
            job.stages.push(filter_stage);
            let mut pool: Vec<usize> = Vec::new();
            for (keep, calls) in filtered {
                pool.extend(keep);
                oracle_calls += calls;
            }

            // Elements below τ now may clear a *lower* τ later — they stay
            // in `surviving`; only committed elements are removed below.

            // -- driver: memory-bounded sample + sequential commit -----------
            let pool: Vec<usize> = if pool.len() > mu {
                job.record_shuffle(mu);
                rng.sample_indices(pool.len(), mu)
                    .into_iter()
                    .map(|i| pool[i])
                    .collect()
            } else {
                job.record_shuffle(pool.len());
                pool
            };
            let t = std::time::Instant::now();
            for &e in &pool {
                if state.selected().len() >= self.k {
                    break;
                }
                let g = state.gain(e);
                oracle_calls += 1;
                if g >= tau {
                    state.push(e);
                }
            }
            let elapsed = t.elapsed().as_secs_f64();
            job.stages.push(StageReport {
                task_times: vec![elapsed],
                max_task_time: elapsed,
                total_cpu_time: elapsed,
            });
            let committed: std::collections::HashSet<usize> =
                state.selected().iter().copied().collect();
            surviving.retain(|e| !committed.contains(e));

            tau *= 1.0 - self.epsilon;
        }

        let solution = state.selected().to_vec();
        let value = problem.global().eval(&solution);
        self.finish(solution, value, oracle_calls, job, rounds)
    }

    fn chunk(&self, items: &[usize]) -> Vec<Vec<usize>> {
        let mut cs = vec![Vec::new(); self.m];
        for (i, &e) in items.iter().enumerate() {
            cs[i % self.m].push(e);
        }
        cs
    }

    fn finish(
        &self,
        solution: Vec<usize>,
        value: f64,
        oracle_calls: u64,
        job: JobReport,
        rounds: usize,
    ) -> RunMetrics {
        RunMetrics {
            name: format!("greedy_scaling[k={},δ={}]", self.k, self.delta),
            solution,
            value,
            oracle_calls,
            job,
            rounds,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::greedi::centralized;
    use crate::coordinator::CoverageProblem;
    use crate::data::transactions::zipf_transactions;
    use std::sync::Arc;

    fn problem() -> CoverageProblem {
        let td = Arc::new(zipf_transactions(400, 300, 10, 1.1, 8));
        CoverageProblem::new(&td)
    }

    #[test]
    fn respects_budget_and_quality() {
        let p = problem();
        let gs = GreedyScaling::new(10, 0.5, 4).run(&p, 1);
        assert!(gs.solution.len() <= 10);
        let c = centralized(&p, 10, "lazy", 1);
        // threshold greedy with ε=0.5 is within (1-1/e-ε)-ish of OPT;
        // empirically it sits near plain greedy on coverage instances.
        assert!(
            gs.value >= 0.8 * c.value,
            "greedy scaling {} vs centralized {}",
            gs.value,
            c.value
        );
    }

    #[test]
    fn uses_multiple_rounds() {
        let p = problem();
        let gs = GreedyScaling::new(12, 0.5, 4).run(&p, 2);
        assert!(
            gs.rounds > 2,
            "threshold greedy must take more rounds than GreeDi's 2, got {}",
            gs.rounds
        );
    }

    #[test]
    fn smaller_epsilon_more_rounds() {
        let p = problem();
        let coarse = GreedyScaling::new(8, 0.5, 4).epsilon(0.5).run(&p, 3);
        let fine = GreedyScaling::new(8, 0.5, 4).epsilon(0.1).run(&p, 3);
        assert!(fine.rounds >= coarse.rounds);
        assert!(fine.value >= 0.95 * coarse.value);
    }

    #[test]
    fn deterministic_given_seed() {
        let p = problem();
        let a = GreedyScaling::new(8, 0.5, 4).run(&p, 7);
        let b = GreedyScaling::new(8, 0.5, 4).run(&p, 7);
        assert_eq!(a.solution, b.solution);
    }

    #[test]
    fn empty_ground_ok() {
        let td = Arc::new(zipf_transactions(1, 5, 2, 1.1, 1));
        let p = CoverageProblem::new(&td);
        let gs = GreedyScaling::new(3, 0.5, 2).run(&p, 1);
        assert!(gs.solution.len() <= 1);
    }
}
