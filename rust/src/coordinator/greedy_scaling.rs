//! GreedyScaling — the threshold-greedy MapReduce algorithm of Kumar,
//! Moseley, Vassilvitskii & Vattani (2013), reimplemented as the comparator
//! for the paper's §6.4 / Fig. 10.
//!
//! The driver lowers a gain threshold τ geometrically from the largest
//! singleton gain. Each synchronous MapReduce round: the cluster filters
//! the surviving elements whose marginal gain w.r.t. the current solution
//! meets τ (the distributed map stage); a memory-bounded sample of size
//! μ = O(k·n^δ·log n) of the survivors is pulled to the driver, which
//! greedily commits those still meeting τ (the reduce stage). This is the
//! (1 − 1/e − ε)-style threshold greedy; the number of synchronous rounds
//! grows like log₍₁/(1−ε)₎(Δ) — *not* the constant 2 of GreeDi — which is
//! exactly the contrast Fig. 10's caption draws.
//!
//! Registered as `"greedy_scaling"`; reads k, m, δ (`spec.delta`),
//! ε (`spec.epsilon`), threads and seed from the shared [`RunSpec`].

use super::metrics::RunMetrics;
use super::protocol::{Protocol, RunSpec};
use super::Problem;
use crate::mapreduce::{JobReport, MapReduce, StageReport};
use crate::util::rng::Rng;
use crate::util::trace;

/// The multi-round threshold-greedy protocol.
pub struct GreedyScaling;

impl Protocol for GreedyScaling {
    fn name(&self) -> &'static str {
        "greedy_scaling"
    }

    fn run(&self, problem: &dyn Problem, spec: &RunSpec) -> RunMetrics {
        let _proto_span = trace::span_with("protocol.greedy_scaling", || {
            vec![("m", spec.m.into()), ("k", spec.k.into())]
        });
        let (k, m, delta, epsilon) = (spec.k, spec.m, spec.delta, spec.epsilon);
        let base_rng = Rng::new(spec.seed);
        let mut rng = base_rng.clone();
        let ground = problem.ground();
        let n = ground.len();
        let mu = (((k as f64) * (n as f64).powf(delta) * (n as f64).ln().max(1.0)).ceil()
            as usize)
            .max(k);
        let engine = MapReduce::new(spec.threads);
        let mut job = JobReport::default();

        let obj = problem.global();
        let mut state = obj.state();
        let mut oracle_calls = 0u64;
        let mut surviving: Vec<usize> = ground.clone();
        let mut rounds = 0usize;

        // Round 0: distributed max-singleton-gain scan to seed τ.
        let chunks = chunk(&surviving, m);
        let oracle_threads = spec.oracle_threads(chunks.len());
        let (maxima, stage0) = engine.run_stage(chunks, |_, chunk| {
            let mut st = obj.state();
            let gains = st.par_batch_gains(&chunk, oracle_threads);
            let best = gains.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            (best, chunk.len() as u64)
        });
        job.stages.push(stage0);
        rounds += 1;
        let mut tau = f64::NEG_INFINITY;
        for (mx, calls) in maxima {
            tau = tau.max(mx);
            oracle_calls += calls;
        }
        if !tau.is_finite() || tau <= 0.0 {
            let value = obj.eval(&[]);
            return finish(spec, Vec::new(), value, oracle_calls, job, rounds);
        }
        let tau_floor = tau * epsilon / (2.0 * k as f64);

        while state.selected().len() < k && !surviving.is_empty() && tau > tau_floor {
            rounds += 1;
            let _round_span = trace::span_with("gs.round", || {
                vec![("round", rounds.into()), ("tau", tau.into()), ("surviving", surviving.len().into())]
            });

            // -- distributed filter: survivors with gain >= τ ----------------
            let selected_now = state.selected().to_vec();
            let chunks = chunk(&surviving, m);
            // Recomputed per round: `chunk` always yields m tasks today, but
            // the budget split must track the stage actually submitted.
            let oracle_threads = spec.oracle_threads(chunks.len());
            let (filtered, filter_stage) = engine.run_stage(chunks, |_, chunk| {
                let mut st = obj.state();
                for &s in &selected_now {
                    st.push(s);
                }
                // One wide batch through the parallel gain engine instead of
                // a scalar per-element loop (values are bit-identical).
                let gains = st.par_batch_gains(&chunk, oracle_threads);
                let keep: Vec<usize> = chunk
                    .iter()
                    .zip(&gains)
                    .filter(|&(_, &g)| g >= tau)
                    .map(|(&e, _)| e)
                    .collect();
                (keep, chunk.len() as u64)
            });
            job.stages.push(filter_stage);
            let mut pool: Vec<usize> = Vec::new();
            for (keep, calls) in filtered {
                pool.extend(keep);
                oracle_calls += calls;
            }

            // Elements below τ now may clear a *lower* τ later — they stay
            // in `surviving`; only committed elements are removed below.

            // -- driver: memory-bounded sample + sequential commit -----------
            let pool: Vec<usize> = if pool.len() > mu {
                job.record_shuffle(mu);
                rng.sample_indices(pool.len(), mu)
                    .into_iter()
                    .map(|i| pool[i])
                    .collect()
            } else {
                job.record_shuffle(pool.len());
                pool
            };
            let t = std::time::Instant::now();
            for &e in &pool {
                if state.selected().len() >= k {
                    break;
                }
                let g = state.gain(e);
                oracle_calls += 1;
                if g >= tau {
                    state.push(e);
                }
            }
            let elapsed = t.elapsed().as_secs_f64();
            job.stages.push(StageReport {
                task_times: vec![elapsed],
                max_task_time: elapsed,
                total_cpu_time: elapsed,
            });
            let committed: std::collections::HashSet<usize> =
                state.selected().iter().copied().collect();
            surviving.retain(|e| !committed.contains(e));

            tau *= 1.0 - epsilon;
        }

        let solution = state.selected().to_vec();
        let value = problem.global().eval(&solution);
        finish(spec, solution, value, oracle_calls, job, rounds)
    }
}

fn chunk(items: &[usize], m: usize) -> Vec<Vec<usize>> {
    let m = m.max(1);
    let mut cs = vec![Vec::new(); m];
    for (i, &e) in items.iter().enumerate() {
        cs[i % m].push(e);
    }
    cs
}

fn finish(
    spec: &RunSpec,
    solution: Vec<usize>,
    value: f64,
    oracle_calls: u64,
    job: JobReport,
    rounds: usize,
) -> RunMetrics {
    RunMetrics {
        name: format!("greedy_scaling[k={},δ={}]", spec.k, spec.delta),
        solution,
        value,
        oracle_calls,
        job,
        rounds,
        stream: None,
        tree: None,
        fault: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::greedi::centralized;
    use crate::coordinator::CoverageProblem;
    use crate::data::transactions::zipf_transactions;
    use std::sync::Arc;

    fn problem() -> CoverageProblem {
        let td = Arc::new(zipf_transactions(400, 300, 10, 1.1, 8));
        CoverageProblem::new(&td)
    }

    #[test]
    fn respects_budget_and_quality() {
        let p = problem();
        let gs = GreedyScaling.run(&p, &RunSpec::new(4, 10).delta(0.5).seed(1));
        assert!(gs.solution.len() <= 10);
        let c = centralized(&p, 10, "lazy", 1);
        // threshold greedy with ε=0.5 is within (1-1/e-ε)-ish of OPT;
        // empirically it sits near plain greedy on coverage instances.
        assert!(
            gs.value >= 0.8 * c.value,
            "greedy scaling {} vs centralized {}",
            gs.value,
            c.value
        );
    }

    #[test]
    fn uses_multiple_rounds() {
        let p = problem();
        let gs = GreedyScaling.run(&p, &RunSpec::new(4, 12).delta(0.5).seed(2));
        assert!(
            gs.rounds > 2,
            "threshold greedy must take more rounds than GreeDi's 2, got {}",
            gs.rounds
        );
    }

    #[test]
    fn smaller_epsilon_more_rounds() {
        let p = problem();
        let coarse = GreedyScaling.run(&p, &RunSpec::new(4, 8).epsilon(0.5).seed(3));
        let fine = GreedyScaling.run(&p, &RunSpec::new(4, 8).epsilon(0.1).seed(3));
        assert!(fine.rounds >= coarse.rounds);
        assert!(fine.value >= 0.95 * coarse.value);
    }

    #[test]
    fn deterministic_given_seed() {
        let p = problem();
        let a = GreedyScaling.run(&p, &RunSpec::new(4, 8).seed(7));
        let b = GreedyScaling.run(&p, &RunSpec::new(4, 8).seed(7));
        assert_eq!(a.solution, b.solution);
    }

    #[test]
    fn empty_ground_ok() {
        let td = Arc::new(zipf_transactions(1, 5, 2, 1.1, 1));
        let p = CoverageProblem::new(&td);
        let gs = GreedyScaling.run(&p, &RunSpec::new(2, 3).seed(1));
        assert!(gs.solution.len() <= 1);
    }
}
