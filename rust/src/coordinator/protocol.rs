//! The unified protocol API — every distributed coordinator behind one
//! trait, one spec, one registry.
//!
//! The paper's evaluation (§6) only means something because GreeDi, the four
//! naive two-round baselines and GreedyScaling all run under *identical*
//! budgets, partitions and seeds. [`RunSpec`] is that shared contract: one
//! builder carrying machine count `m`, budget `k`, per-machine budget κ
//! (α = κ/k), tree fanout, GreedyScaling's (δ, ε), local/global evaluation
//! mode, the black-box algorithm name, thread count, partition strategy,
//! seed, and optional per-round hereditary constraints (Algorithm 3).
//!
//! [`Protocol`] is the trait every coordinator implements, and [`by_name`]
//! is the registry mirroring `algorithms::by_name` — so experiments sweep
//! *protocols* exactly the way they already sweep black boxes:
//!
//! ```ignore
//! let spec = RunSpec::new(8, 20).threads(4).seed(7);
//! for name in protocol::NAMES {
//!     let run = protocol::by_name(name).unwrap().run(&problem, &spec);
//!     println!("{}", run.one_line());
//! }
//! ```

use std::fmt;
use std::sync::Arc;

use super::baselines::Baseline;
use super::greedi::{centralized_threaded, Greedi};
use super::greedy_scaling::GreedyScaling;
use super::metrics::RunMetrics;
use super::multiround::MultiRoundGreedi;
use super::Problem;
use crate::algorithms;
use crate::constraints::Constraint;

pub use crate::mapreduce::fault::{DomainMap, FaultPlan, RecoveryPolicy};
pub use crate::mapreduce::partition::{PartitionStrategy, PlacementPolicy};

/// Chaos-smoke hook: `GREEDI_CHAOS=fail_prob:max_attempts[:seed][:dN]`
/// injects a transient-failure [`FaultPlan`] into every spec built by
/// [`RunSpec::new`] (explicit `.faults(..)` calls still win). A trailing
/// `dN` segment assigns machines round-robin to `N` failure domains, which
/// makes the transient coins *rack-correlated* (a whole domain loses the
/// same attempts together). Under the default `Retry` policy both shapes
/// are output-invariant — retries re-run pure tasks — so the whole
/// integration surface can run under injected faults in CI without
/// touching a single test.
fn chaos_plan() -> Option<FaultPlan> {
    use std::sync::OnceLock;
    static CHAOS: OnceLock<Option<FaultPlan>> = OnceLock::new();
    fn parse(v: &str) -> Option<FaultPlan> {
        let mut parts = v.split(':');
        let fail_prob: f64 = parts.next()?.trim().parse().ok()?;
        let max_attempts: usize = parts.next()?.trim().parse().ok()?;
        let mut seed: u64 = 0xC0FFEE;
        let mut domains: Option<usize> = None;
        for part in parts {
            let part = part.trim();
            if let Some(d) = part.strip_prefix('d') {
                domains = Some(d.parse().ok().filter(|&d| d >= 1)?);
            } else {
                seed = part.parse().ok()?;
            }
        }
        if !(0.0..=1.0).contains(&fail_prob) || max_attempts == 0 {
            return None;
        }
        let plan = FaultPlan::new(fail_prob, max_attempts, seed);
        Some(match domains {
            Some(d) => plan.domain_groups(d),
            None => plan,
        })
    }
    CHAOS
        .get_or_init(|| std::env::var("GREEDI_CHAOS").ok().as_deref().and_then(parse))
        .clone()
}

/// A distributed maximization protocol: anything that can turn a
/// [`Problem`] plus a [`RunSpec`] into a finished [`RunMetrics`].
pub trait Protocol: Sync {
    /// Execute the protocol under `spec` (all randomness from `spec.seed`).
    fn run(&self, problem: &dyn Problem, spec: &RunSpec) -> RunMetrics;

    /// Registry identifier (round-trips through [`by_name`]).
    fn name(&self) -> &'static str;
}

/// Shared run specification — the one builder every protocol reads.
///
/// Fields a protocol does not use are simply ignored (e.g. `delta` only
/// matters to `greedy_scaling`, `batch` only to `stream_greedi`), so a
/// single spec can drive a whole protocol sweep apples-to-apples.
#[derive(Clone)]
pub struct RunSpec {
    /// Number of machines m.
    pub m: usize,
    /// Final solution budget k.
    pub k: usize,
    /// Per-machine budget κ (Algorithm 2 allows κ ≠ k; α = κ/k).
    pub kappa: usize,
    /// Accumulation-tree fan-in r: candidate sets merged per reduce node
    /// per level ([`mapreduce::reduce::TreeReduce`](crate::mapreduce::reduce)),
    /// shared by `greedi`, `multiround` and `stream_greedi`. `0` (the
    /// default) means "protocol default": the flat single-root merge for
    /// `greedi`/`stream_greedi`, a binary tree for `multiround`. Any
    /// r ≥ m collapses to the flat merge bit-for-bit.
    pub fanout: usize,
    /// Memory exponent δ: driver pool μ = ⌈k·n^δ·ln n⌉ (`greedy_scaling`).
    pub delta: f64,
    /// Approximation slack ε ∈ (0, 1): `greedy_scaling`'s threshold decay
    /// τ ← τ·(1−ε), and `stream_greedi`'s sieve-ladder resolution (rung
    /// ratio 1+ε — finer ε means more live sieves, tighter guarantee).
    pub epsilon: f64,
    /// Stream batch size: elements priced per oracle round by the one-pass
    /// sieve stage (`stream_greedi`). Purely mechanical — the protocol
    /// output is identical at any batch size; wider batches feed the
    /// parallel gain engine better.
    pub batch: usize,
    /// Decomposable local evaluation (paper §4.5).
    pub local_eval: bool,
    /// Black-box algorithm name (see `algorithms::by_name`).
    pub algorithm: String,
    /// OS threads for the simulated cluster's map stages.
    pub threads: usize,
    pub partition: PartitionStrategy,
    /// Replication multiplicity c: every element lands on `c` distinct
    /// machines (Lucic et al., 1605.09619). 1 = classic disjoint partition;
    /// protocols clamp to `min(c, m)`.
    pub multiplicity: usize,
    /// Where the `multiplicity` replicas may land relative to the fault
    /// plan's failure domains (`Anywhere` = PR 7 behavior, bit-identical).
    pub placement: PlacementPolicy,
    /// What map stages do when a machine crashes (see `mapreduce::fault`).
    pub recovery: RecoveryPolicy,
    /// Checkpoint period B for `RecoveryPolicy::Resume`: machines snapshot
    /// partial progress every B units (greedy picks / sieve batches) and a
    /// restarted task replays only the tail past the last checkpoint.
    /// `0` disables checkpointing (Resume degrades to full recompute).
    pub checkpoint_every: usize,
    /// Fault injection for the simulated cluster (`None` = fault-free).
    pub fault: Option<FaultPlan>,
    /// Base RNG seed — partitions and every per-task stream fork from it.
    pub seed: u64,
    /// Round-1 hereditary constraint override (Algorithm 3). `None` ⇒
    /// `Cardinality(kappa)`.
    pub round1: Option<Arc<dyn Constraint + Send + Sync>>,
    /// Round-2 / merge constraint override. `None` ⇒ `Cardinality(k)`.
    pub round2: Option<Arc<dyn Constraint + Send + Sync>>,
}

impl RunSpec {
    pub fn new(m: usize, k: usize) -> Self {
        RunSpec {
            m: m.max(1),
            k,
            kappa: k,
            fanout: 0,
            delta: 0.5,
            epsilon: 0.5,
            batch: 256,
            local_eval: false,
            algorithm: "lazy".to_string(),
            threads: 1,
            partition: PartitionStrategy::Random,
            multiplicity: 1,
            placement: PlacementPolicy::Anywhere,
            recovery: RecoveryPolicy::Retry,
            checkpoint_every: 0,
            fault: chaos_plan(),
            seed: 42,
            round1: None,
            round2: None,
        }
    }

    /// Set κ = ⌈α·k⌉ (the paper sweeps α = κ/k).
    pub fn alpha(mut self, alpha: f64) -> Self {
        self.kappa = ((alpha * self.k as f64).round() as usize).max(1);
        self
    }

    /// Set κ directly.
    pub fn kappa(mut self, kappa: usize) -> Self {
        self.kappa = kappa.max(1);
        self
    }

    /// Enable decomposable local evaluation (paper §4.5).
    pub fn local(mut self) -> Self {
        self.local_eval = true;
        self
    }

    pub fn algorithm(mut self, name: &str) -> Self {
        assert!(algorithms::by_name(name).is_some(), "unknown algorithm {name}");
        self.algorithm = name.to_string();
        self
    }

    pub fn partition(mut self, p: PartitionStrategy) -> Self {
        self.partition = p;
        self
    }

    /// Replication multiplicity c ≥ 1 (clamped to `m` at run time).
    pub fn multiplicity(mut self, c: usize) -> Self {
        self.multiplicity = c.max(1);
        self
    }

    /// Replica placement relative to failure domains (no-op when the run's
    /// fault plan has no domain map, or `multiplicity == 1`).
    pub fn placement(mut self, p: PlacementPolicy) -> Self {
        self.placement = p;
        self
    }

    /// Crash-recovery policy for the map stages.
    pub fn recovery(mut self, policy: RecoveryPolicy) -> Self {
        self.recovery = policy;
        self
    }

    /// Checkpoint period B for `Resume` recovery (0 = checkpoints off).
    pub fn checkpoint_every(mut self, b: usize) -> Self {
        self.checkpoint_every = b;
        self
    }

    /// Inject a fault plan into every stage of the run.
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.fault = Some(plan);
        self
    }

    pub fn threads(mut self, t: usize) -> Self {
        self.threads = t.max(1);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Accumulation-tree fan-in r, shared by every tree-reducing protocol
    /// (`greedi`, `multiround`, `stream_greedi`). Clamped to ≥ 2; r ≥ m
    /// reproduces the flat single-root merge exactly. Leave unset (the `0`
    /// sentinel) for the per-protocol default — see [`RunSpec::tree_fanout`].
    pub fn fanout(mut self, fanout: usize) -> Self {
        self.fanout = fanout.max(2);
        self
    }

    /// Resolve the `fanout` knob for a tree reduction. The `0` sentinel
    /// (never set explicitly) maps to the protocol's historical default:
    /// the flat single-root merge (`usize::MAX`) for protocols that always
    /// merged once (`greedi`, `stream_greedi`), a binary tree for
    /// `multiround`, which has always reduced in levels.
    pub fn tree_fanout(&self, flat_default: bool) -> usize {
        match self.fanout {
            0 => {
                if flat_default {
                    usize::MAX
                } else {
                    2
                }
            }
            f => f.max(2),
        }
    }

    /// GreedyScaling memory exponent δ.
    pub fn delta(mut self, delta: f64) -> Self {
        assert!(delta >= 0.0, "delta must be non-negative");
        self.delta = delta;
        self
    }

    /// Approximation slack ε ∈ (0, 1) (`greedy_scaling` threshold decay /
    /// `stream_greedi` sieve-ladder resolution).
    pub fn epsilon(mut self, eps: f64) -> Self {
        assert!(eps > 0.0 && eps < 1.0, "epsilon must be in (0, 1)");
        self.epsilon = eps;
        self
    }

    /// Stream batch size (`stream_greedi`; output-invariant, ≥ 1).
    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch.max(1);
        self
    }

    /// Oracle-layer thread budget for one task of a stage running `tasks`
    /// concurrent tasks: the map stage already occupies `min(tasks,
    /// threads)` pool workers, so each task's gain engine
    /// ([`State::par_batch_gains`](crate::objective::State)) gets the
    /// leftover parallelism. Guarantees `concurrent tasks × oracle threads
    /// ≤ threads` — intra-machine parallelism composes with the
    /// across-machine map stage without oversubscribing the host. A
    /// single-task stage (GreeDi's merge round, the centralized reference)
    /// therefore receives the full `threads`.
    pub fn oracle_threads(&self, tasks: usize) -> usize {
        (self.threads / tasks.clamp(1, self.threads.max(1))).max(1)
    }

    /// Per-round hereditary constraints (Algorithm 3). Protocols without a
    /// general-constraint path fall back to their cardinality behavior.
    pub fn constraints(
        mut self,
        round1: Arc<dyn Constraint + Send + Sync>,
        round2: Arc<dyn Constraint + Send + Sync>,
    ) -> Self {
        self.round1 = Some(round1);
        self.round2 = Some(round2);
        self
    }
}

impl fmt::Debug for RunSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("RunSpec")
            .field("m", &self.m)
            .field("k", &self.k)
            .field("kappa", &self.kappa)
            .field("fanout", &self.fanout)
            .field("delta", &self.delta)
            .field("epsilon", &self.epsilon)
            .field("batch", &self.batch)
            .field("local_eval", &self.local_eval)
            .field("algorithm", &self.algorithm)
            .field("threads", &self.threads)
            .field("partition", &self.partition)
            .field("multiplicity", &self.multiplicity)
            .field("placement", &self.placement)
            .field("recovery", &self.recovery)
            .field("checkpoint_every", &self.checkpoint_every)
            .field("fault", &self.fault)
            .field("seed", &self.seed)
            .field("round1", &self.round1.as_ref().map(|_| "<constraint>"))
            .field("round2", &self.round2.as_ref().map(|_| "<constraint>"))
            .finish()
    }
}

/// Every registered protocol name, in canonical report order.
pub const NAMES: [&str; 9] = [
    "greedi",
    "multiround",
    "greedy_scaling",
    "stream_greedi",
    "random_random",
    "random_greedy",
    "greedy_merge",
    "greedy_max",
    "centralized",
];

/// The four naive two-round baselines of §6, in `Baseline::ALL` order.
pub const BASELINE_NAMES: [&str; 4] =
    ["random_random", "random_greedy", "greedy_merge", "greedy_max"];

/// Resolve a protocol by name (config files / CLI / sweeps) — the protocol
/// analogue of `algorithms::by_name`.
pub fn by_name(name: &str) -> Option<Box<dyn Protocol + Send>> {
    match name {
        "greedi" => Some(Box::new(Greedi)),
        "multiround" => Some(Box::new(MultiRoundGreedi)),
        "greedy_scaling" => Some(Box::new(GreedyScaling)),
        "stream_greedi" => Some(Box::new(crate::stream::distributed::StreamGreedi)),
        "random_random" => Some(Box::new(Baseline::RandomRandom)),
        "random_greedy" => Some(Box::new(Baseline::RandomGreedy)),
        "greedy_merge" => Some(Box::new(Baseline::GreedyMerge)),
        "greedy_max" => Some(Box::new(Baseline::GreedyMax)),
        "centralized" => Some(Box::new(Centralized)),
        _ => None,
    }
}

/// Centralized single-machine reference run as a protocol — the denominator
/// of every ratio the paper reports, now sweepable like everything else.
pub struct Centralized;

impl Protocol for Centralized {
    fn run(&self, problem: &dyn Problem, spec: &RunSpec) -> RunMetrics {
        centralized_threaded(problem, spec.k, &spec.algorithm, spec.seed, spec.threads)
    }

    fn name(&self) -> &'static str {
        "centralized"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::FacilityProblem;
    use crate::data::synth::{gaussian_blobs, SynthConfig};

    fn problem(n: usize, seed: u64) -> FacilityProblem {
        let ds = std::sync::Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 8), seed));
        FacilityProblem::new(&ds)
    }

    #[test]
    fn registry_round_trips_every_name() {
        for name in NAMES {
            let proto = by_name(name);
            assert!(proto.is_some(), "{name} not registered");
            assert_eq!(proto.unwrap().name(), name, "{name} does not round-trip");
        }
        assert!(by_name("nope").is_none());
        assert!(by_name("").is_none());
        assert!(by_name("GreeDi").is_none(), "names are case-sensitive");
    }

    #[test]
    fn baseline_names_subset_of_registry() {
        for b in BASELINE_NAMES {
            assert!(NAMES.contains(&b));
            assert!(by_name(b).is_some());
        }
    }

    #[test]
    fn cross_protocol_smoke_under_shared_spec() {
        // Every protocol runs on one tiny problem under ONE spec — the whole
        // point of the unified API.
        let p = problem(80, 3);
        let spec = RunSpec::new(3, 4).seed(5);
        for name in NAMES {
            let run = by_name(name).unwrap().run(&p, &spec);
            assert!(run.value.is_finite(), "{name}: value {}", run.value);
            assert!(run.value >= 0.0, "{name}: negative value");
            assert!(run.solution.len() <= 4, "{name}: budget violated");
            assert!(run.rounds >= 1, "{name}: no rounds recorded");
            let set: std::collections::HashSet<_> = run.solution.iter().collect();
            assert_eq!(set.len(), run.solution.len(), "{name}: duplicate ids");
            // reported value must be the true global objective of the solution
            let fresh = p.global().eval(&run.solution);
            assert!((fresh - run.value).abs() < 1e-9, "{name}: stale value");
        }
    }

    #[test]
    fn registry_dispatch_matches_direct_call() {
        let p = problem(120, 4);
        let spec = RunSpec::new(4, 6).seed(9);
        let via_registry = by_name("greedi").unwrap().run(&p, &spec);
        let direct = Greedi.run(&p, &spec);
        assert_eq!(via_registry.solution, direct.solution);
        assert_eq!(via_registry.value, direct.value);
        assert_eq!(via_registry.oracle_calls, direct.oracle_calls);
    }

    #[test]
    fn spec_builder_defaults_and_overrides() {
        let s = RunSpec::new(0, 10);
        assert_eq!(s.m, 1, "m clamps to 1");
        assert_eq!(s.kappa, 10, "κ defaults to k");
        assert_eq!(s.algorithm, "lazy");
        assert_eq!(s.threads, 1);
        assert_eq!(s.batch, 256, "stream batch defaults to 256");
        assert!(!s.local_eval);
        assert_eq!(s.fanout, 0, "fanout defaults to the protocol-default sentinel");
        assert_eq!(s.tree_fanout(true), usize::MAX, "greedi/stream default: flat merge");
        assert_eq!(s.tree_fanout(false), 2, "multiround default: binary tree");
        assert_eq!(s.clone().fanout(4).tree_fanout(true), 4, "explicit fanout wins");
        assert_eq!(s.clone().fanout(4).tree_fanout(false), 4);
        let s = RunSpec::new(4, 10)
            .alpha(2.0)
            .local()
            .threads(0)
            .fanout(1)
            .batch(0)
            .partition(PartitionStrategy::Contiguous)
            .seed(99);
        assert_eq!(s.kappa, 20);
        assert!(s.local_eval);
        assert_eq!(s.threads, 1, "threads clamps to 1");
        assert_eq!(s.fanout, 2, "fanout clamps to 2");
        assert_eq!(s.batch, 1, "batch clamps to 1");
        assert_eq!(s.partition, PartitionStrategy::Contiguous);
        assert_eq!(s.seed, 99);
    }

    #[test]
    fn fault_spec_builders_default_and_clamp() {
        let s = RunSpec::new(4, 10);
        assert_eq!(s.multiplicity, 1, "replication off by default");
        assert_eq!(s.placement, PlacementPolicy::Anywhere, "placement-agnostic by default");
        assert_eq!(s.recovery, RecoveryPolicy::Retry, "classic MapReduce default");
        assert_eq!(s.checkpoint_every, 0, "checkpoints off by default");
        let s = RunSpec::new(4, 10)
            .multiplicity(0)
            .placement(PlacementPolicy::DistinctDomains)
            .recovery(RecoveryPolicy::SurvivorMerge)
            .checkpoint_every(8)
            .faults(FaultPlan::new(0.5, 10, 1).crashes(0.1));
        assert_eq!(s.multiplicity, 1, "multiplicity clamps to 1");
        assert_eq!(s.placement, PlacementPolicy::DistinctDomains);
        assert_eq!(s.recovery, RecoveryPolicy::SurvivorMerge);
        assert_eq!(s.checkpoint_every, 8);
        let plan = s.fault.expect("explicit plan stored");
        assert!(plan.active());
        assert_eq!(plan.crash_prob, 0.1);
        assert_eq!(RunSpec::new(2, 3).multiplicity(5).multiplicity, 5, "clamped to m at run time, not here");
    }

    #[test]
    fn oracle_threads_never_oversubscribe() {
        for threads in [1usize, 2, 4, 8, 16] {
            for tasks in [1usize, 2, 3, 8, 32] {
                let s = RunSpec::new(4, 5).threads(threads);
                let ot = s.oracle_threads(tasks);
                assert!(ot >= 1);
                assert!(
                    ot * tasks.min(threads) <= threads,
                    "threads={threads} tasks={tasks}: {ot} oversubscribes"
                );
            }
        }
        // single-task stages get the whole budget
        assert_eq!(RunSpec::new(4, 5).threads(8).oracle_threads(1), 8);
        // saturated map stage leaves one thread per task
        assert_eq!(RunSpec::new(4, 5).threads(4).oracle_threads(8), 1);
    }

    #[test]
    fn threads_do_not_change_any_protocol_result() {
        // The tentpole's perf half: every protocol's map stage may run on a
        // pool, and the pool must be invisible in the results.
        let p = problem(150, 6);
        for name in NAMES {
            let seq = by_name(name).unwrap().run(&p, &RunSpec::new(4, 5).seed(8));
            let par = by_name(name)
                .unwrap()
                .run(&p, &RunSpec::new(4, 5).seed(8).threads(4));
            assert_eq!(seq.solution, par.solution, "{name}: threads changed result");
            assert_eq!(seq.value, par.value, "{name}");
            assert_eq!(seq.oracle_calls, par.oracle_calls, "{name}");
        }
    }
}
