//! The distributed coordinator — the paper's system contribution.
//!
//! * [`greedi`] — the two-round GreeDi protocol (Algorithms 2 & 3) over the
//!   simulated MapReduce runtime, in global and local (decomposable, §4.5)
//!   evaluation modes.
//! * [`baselines`] — the four naive two-round protocols of §6
//!   (random/random, random/greedy, greedy/merge, greedy/max).
//! * [`greedy_scaling`] — the multi-round GreedyScaling comparator of
//!   Kumar et al. (2013) used in §6.4.
//! * [`metrics`] — unified run accounting (solution value, oracle calls,
//!   simulated cluster time, communication volume, MapReduce rounds).
//! * [`protocol`] — the unified API: the [`protocol::Protocol`] trait every
//!   coordinator implements, the shared [`protocol::RunSpec`] builder, and
//!   the `protocol::by_name` registry mirroring `algorithms::by_name`.
//!
//! The [`Problem`] trait is the bridge between the protocol (which moves
//! element ids around) and the objective library (which knows how to build
//! global, shard-local and merge-round objective instances).

pub mod baselines;
pub mod greedi;
pub mod greedy_scaling;
pub mod metrics;
pub mod multiround;
pub mod protocol;

pub use protocol::{Protocol, RunSpec};

use std::sync::Arc;

use crate::data::graph::Digraph;
use crate::data::transactions::TransactionData;
use crate::data::Dataset;
use crate::objective::coverage::Coverage;
use crate::objective::cut::GraphCut;
use crate::objective::engine::GainBackend;
use crate::objective::facility::FacilityLocation;
use crate::objective::infogain::InfoGain;
use crate::objective::SubmodularFn;
use crate::util::rng::Rng;

/// A distributable maximization problem: how to instantiate the objective
/// for the global view, for one machine's shard (local/decomposable mode,
/// paper §4.5), and for GreeDi's second round.
pub trait Problem: Sync {
    /// The ground set V.
    fn ground(&self) -> Vec<usize>;

    /// Full-information objective (used for final reporting and for every
    /// stage in global mode).
    fn global(&self) -> Box<dyn SubmodularFn + '_>;

    /// Objective evaluated by the machine holding `shard` in local mode.
    /// Default: same as global (objectives whose evaluation needs no data
    /// beyond the selected elements — info-gain, coverage).
    fn local(&self, shard: &[usize], _rng: &mut Rng) -> Box<dyn SubmodularFn + '_> {
        let _ = shard;
        self.global()
    }

    /// Objective for the merge round in local mode. `m` is the machine
    /// count — the paper's §4.5 evaluates the second stage on a uniform
    /// random subset U of size ⌈n/m⌉. Default: global.
    fn merge(&self, m: usize, _rng: &mut Rng) -> Box<dyn SubmodularFn + '_> {
        let _ = m;
        self.global()
    }

    /// Whether a *distinct* local restriction exists (affects experiment
    /// labeling only; protocols work either way).
    fn has_local_mode(&self) -> bool {
        false
    }
}

/// Builds a [`GainBackend`] (the gain engine's accelerator seam,
/// `objective::engine`) for a given evaluation window — implemented by
/// `runtime::Engine` (the XLA path). Window-specific because the batched
/// artifact streams pre-packed data blocks of exactly that window.
pub trait BackendFactory: Sync + Send {
    fn make(&self, data: &Arc<Dataset>, window: &[usize]) -> Arc<dyn GainBackend>;
}

/// Exemplar-based clustering problem (paper §6.1): decomposable, so local
/// mode restricts the loss average to the shard and the merge round to a
/// random ⌈n/m⌉-subset. An optional [`BackendFactory`] swaps the scalar
/// gain loop for the batched XLA artifact, per window.
pub struct FacilityProblem {
    pub data: Arc<Dataset>,
    pub backend_factory: Option<Arc<dyn BackendFactory>>,
}

impl FacilityProblem {
    pub fn new(data: &Arc<Dataset>) -> Self {
        FacilityProblem { data: Arc::clone(data), backend_factory: None }
    }

    pub fn with_backend_factory(mut self, factory: Arc<dyn BackendFactory>) -> Self {
        self.backend_factory = Some(factory);
        self
    }

    fn build(&self, window: Vec<usize>) -> Box<dyn SubmodularFn + '_> {
        let f = FacilityLocation::with_window(&self.data, window);
        match &self.backend_factory {
            Some(factory) => {
                let backend = factory.make(&self.data, f.window());
                Box::new(f.with_backend(backend))
            }
            None => Box::new(f),
        }
    }
}

impl Problem for FacilityProblem {
    fn ground(&self) -> Vec<usize> {
        self.data.ids()
    }

    fn global(&self) -> Box<dyn SubmodularFn + '_> {
        self.build(self.data.ids())
    }

    fn local(&self, shard: &[usize], _rng: &mut Rng) -> Box<dyn SubmodularFn + '_> {
        self.build(shard.to_vec())
    }

    fn merge(&self, m: usize, rng: &mut Rng) -> Box<dyn SubmodularFn + '_> {
        let n = self.data.n;
        let u_size = n.div_ceil(m).max(1).min(n);
        let window = rng.sample_indices(n, u_size);
        self.build(window)
    }

    fn has_local_mode(&self) -> bool {
        true
    }
}

/// GP active-set selection (paper §6.2). The info-gain objective depends
/// only on the selected set, so local evaluation *is* global evaluation.
pub struct InfoGainProblem {
    pub data: Arc<Dataset>,
    pub h: f64,
    pub sigma: f64,
}

impl InfoGainProblem {
    pub fn paper_params(data: &Arc<Dataset>) -> Self {
        InfoGainProblem { data: Arc::clone(data), h: 0.75, sigma: 1.0 }
    }
}

impl Problem for InfoGainProblem {
    fn ground(&self) -> Vec<usize> {
        self.data.ids()
    }

    fn global(&self) -> Box<dyn SubmodularFn + '_> {
        Box::new(InfoGain::new(&self.data, self.h, self.sigma))
    }
}

/// Max-cut on a social graph (paper §6.3). Local mode induces the shard's
/// subgraph (cross-partition links disconnected, as in the paper).
pub struct CutProblem {
    pub graph: Arc<Digraph>,
}

impl CutProblem {
    pub fn new(graph: &Arc<Digraph>) -> Self {
        CutProblem { graph: Arc::clone(graph) }
    }
}

impl Problem for CutProblem {
    fn ground(&self) -> Vec<usize> {
        (0..self.graph.n).collect()
    }

    fn global(&self) -> Box<dyn SubmodularFn + '_> {
        Box::new(GraphCut::new(&self.graph))
    }

    fn local(&self, shard: &[usize], _rng: &mut Rng) -> Box<dyn SubmodularFn + '_> {
        Box::new(GraphCut::restricted(&self.graph, shard))
    }

    fn has_local_mode(&self) -> bool {
        true
    }
}

/// Submodular coverage over transactions (paper §6.4). Each transaction
/// carries its own items, so shard-local evaluation equals global.
pub struct CoverageProblem {
    pub td: Arc<TransactionData>,
}

impl CoverageProblem {
    pub fn new(td: &Arc<TransactionData>) -> Self {
        CoverageProblem { td: Arc::clone(td) }
    }
}

impl Problem for CoverageProblem {
    fn ground(&self) -> Vec<usize> {
        (0..self.td.n()).collect()
    }

    fn global(&self) -> Box<dyn SubmodularFn + '_> {
        Box::new(Coverage::new(&self.td))
    }
}

/// Wrap any standalone objective as a Problem (local == global).
pub struct OpaqueProblem<'a> {
    pub f: &'a dyn SubmodularFn,
}

impl<'a> OpaqueProblem<'a> {
    pub fn new(f: &'a dyn SubmodularFn) -> Self {
        OpaqueProblem { f }
    }
}

impl<'a> Problem for OpaqueProblem<'a> {
    fn ground(&self) -> Vec<usize> {
        (0..self.f.ground_size()).collect()
    }

    fn global(&self) -> Box<dyn SubmodularFn + '_> {
        Box::new(ForwardFn { f: self.f })
    }
}

/// Forwarding shim so `OpaqueProblem` can hand out boxed views.
struct ForwardFn<'a> {
    f: &'a dyn SubmodularFn,
}

impl<'a> SubmodularFn for ForwardFn<'a> {
    fn state(&self) -> Box<dyn crate::objective::State + '_> {
        self.f.state()
    }

    fn eval(&self, s: &[usize]) -> f64 {
        self.f.eval(s)
    }

    fn singleton_gains(&self, es: &[usize], threads: usize) -> Vec<f64> {
        // Forward explicitly: the trait default would rebuild a fresh state
        // and miss the inner objective's closed-form override (modular,
        // coverage), silently re-pricing the sieve ladder the slow way.
        self.f.singleton_gains(es, threads)
    }

    fn is_monotone(&self) -> bool {
        self.f.is_monotone()
    }

    fn ground_size(&self) -> usize {
        self.f.ground_size()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{gaussian_blobs, SynthConfig};

    #[test]
    fn facility_problem_local_restricts() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(60, 8), 1));
        let p = FacilityProblem::new(&ds);
        let mut rng = Rng::new(0);
        let shard: Vec<usize> = (0..30).collect();
        let local = p.local(&shard, &mut rng);
        let global = p.global();
        // values generally differ because the loss averages over different sets
        let s = [3, 9];
        assert!(local.eval(&s).is_finite());
        assert!(global.eval(&s).is_finite());
        assert!(p.has_local_mode());
    }

    #[test]
    fn facility_merge_window_size() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(100, 8), 2));
        let p = FacilityProblem::new(&ds);
        let mut rng = Rng::new(0);
        let merged = p.merge(4, &mut rng);
        // ⌈100/4⌉ = 25-point window; eval still defined on global ids
        assert!(merged.eval(&[0, 50, 99]).is_finite());
    }

    #[test]
    fn opaque_problem_forwards() {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(20, 4), 3));
        let f = FacilityLocation::from_dataset(&ds);
        let p = OpaqueProblem::new(&f);
        assert_eq!(p.ground().len(), 20);
        let g = p.global();
        assert!((g.eval(&[1, 2]) - f.eval(&[1, 2])).abs() < 1e-12);
        assert!(!p.has_local_mode());
    }
}
