//! The four naive two-round protocols GreeDi is compared against in every
//! figure of §6:
//!
//! * **random/random** — k random per machine, then k random from the merge.
//! * **random/greedy** — k random per machine, greedy over the merged m·k.
//! * **greedy/merge** — ⌈k/m⌉ greedy per machine, concatenate (truncate to k).
//! * **greedy/max** — k greedy per machine, report the single best set.
//!
//! Each variant implements [`Protocol`] and is registered in
//! `protocol::by_name` under its snake_case name (`"random_random"`, …), so
//! baselines run under the exact same [`RunSpec`] — budgets, partition,
//! local/global mode, threads, seed — as GreeDi itself.

use super::metrics::RunMetrics;
use super::protocol::{Protocol, RunSpec};
use super::Problem;
use crate::algorithms::{self};
use crate::constraints::cardinality::Cardinality;
use crate::mapreduce::{JobReport, MapReduce};
use crate::util::rng::Rng;
use crate::util::trace;

/// Baseline protocol selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Baseline {
    RandomRandom,
    RandomGreedy,
    GreedyMerge,
    GreedyMax,
}

impl Baseline {
    pub const ALL: [Baseline; 4] = [
        Baseline::RandomRandom,
        Baseline::RandomGreedy,
        Baseline::GreedyMerge,
        Baseline::GreedyMax,
    ];

    /// Display label used in figures and `RunMetrics.name`.
    pub fn label(&self) -> &'static str {
        match self {
            Baseline::RandomRandom => "random/random",
            Baseline::RandomGreedy => "random/greedy",
            Baseline::GreedyMerge => "greedy/merge",
            Baseline::GreedyMax => "greedy/max",
        }
    }
}

impl Protocol for Baseline {
    fn name(&self) -> &'static str {
        match self {
            Baseline::RandomRandom => "random_random",
            Baseline::RandomGreedy => "random_greedy",
            Baseline::GreedyMerge => "greedy_merge",
            Baseline::GreedyMax => "greedy_max",
        }
    }

    /// Run the baseline under `spec`. `spec.local_eval` mirrors GreeDi's
    /// decomposable mode so comparisons stay apples-to-apples.
    fn run(&self, problem: &dyn Problem, spec: &RunSpec) -> RunMetrics {
        let _proto_span = trace::span_with("protocol.baseline", || {
            vec![("which", self.name().into()), ("m", spec.m.into()), ("k", spec.k.into())]
        });
        let (m, k) = (spec.m, spec.k);
        let local_eval = spec.local_eval;
        let base_rng = Rng::new(spec.seed);
        let mut rng = base_rng.clone();
        let ground = problem.ground();
        let shards = spec.partition.split(&ground, m, &mut rng);
        let engine = MapReduce::new(spec.threads);
        let mut job = JobReport::default();
        let this = *self;

        // ---- Round 1 ------------------------------------------------------
        let per_machine_k = match this {
            Baseline::GreedyMerge => k.div_ceil(m).max(1),
            _ => k,
        };
        let algorithm = spec.algorithm.clone();
        let inputs: Vec<(usize, Vec<usize>)> = shards.into_iter().enumerate().collect();
        let oracle_threads = spec.oracle_threads(inputs.len());
        let (r1, stage1) = engine.run_stage(inputs, |_, (i, shard)| {
            let mut task_rng = base_rng.fork(100 + i as u64);
            match this {
                Baseline::RandomRandom | Baseline::RandomGreedy => {
                    let take = per_machine_k.min(shard.len());
                    let picks = task_rng
                        .sample_indices(shard.len(), take)
                        .into_iter()
                        .map(|j| shard[j])
                        .collect::<Vec<_>>();
                    (picks, 0u64)
                }
                Baseline::GreedyMerge | Baseline::GreedyMax => {
                    let algo = algorithms::by_name(&algorithm).expect("algorithm");
                    let obj = if local_eval {
                        problem.local(&shard, &mut task_rng)
                    } else {
                        problem.global()
                    };
                    let r = algo.maximize_threaded(
                        obj.as_ref(),
                        &shard,
                        &Cardinality::new(per_machine_k),
                        &mut task_rng,
                        oracle_threads,
                    );
                    (r.solution, r.oracle_calls)
                }
            }
        });
        job.stages.push(stage1);
        let mut oracle_calls: u64 = r1.iter().map(|(_, c)| c).sum();

        let mut merged: Vec<usize> = Vec::new();
        for (sol, _) in &r1 {
            merged.extend_from_slice(sol);
        }
        merged.sort_unstable();
        merged.dedup();
        job.record_shuffle(merged.len());

        // ---- Round 2 ------------------------------------------------------
        let candidates: Vec<Vec<usize>> = r1.iter().map(|(s, _)| s.clone()).collect();
        let merged_in = merged.clone();
        let algorithm2 = spec.algorithm.clone();
        let merge_threads = spec.oracle_threads(1);
        let (mut out2, stage2) = engine.run_stage(vec![()], |_, ()| {
            let mut task_rng = base_rng.fork(999);
            match this {
                Baseline::RandomRandom => {
                    let take = k.min(merged_in.len());
                    let sol = task_rng
                        .sample_indices(merged_in.len(), take)
                        .into_iter()
                        .map(|j| merged_in[j])
                        .collect::<Vec<_>>();
                    (sol, 0u64)
                }
                Baseline::RandomGreedy => {
                    let algo = algorithms::by_name(&algorithm2).expect("algorithm");
                    let obj = if local_eval {
                        problem.merge(m, &mut task_rng)
                    } else {
                        problem.global()
                    };
                    let r = algo.maximize_threaded(
                        obj.as_ref(),
                        &merged_in,
                        &Cardinality::new(k),
                        &mut task_rng,
                        merge_threads,
                    );
                    (r.solution, r.oracle_calls)
                }
                Baseline::GreedyMerge => {
                    // concatenation, truncated to k
                    (merged_in.iter().copied().take(k).collect(), 0u64)
                }
                Baseline::GreedyMax => {
                    let obj = if local_eval {
                        problem.merge(m, &mut task_rng)
                    } else {
                        problem.global()
                    };
                    let mut best: Option<(Vec<usize>, f64)> = None;
                    let mut calls = 0u64;
                    for c in &candidates {
                        let v = obj.eval(c);
                        calls += c.len() as u64;
                        if best.as_ref().map(|(_, bv)| v > *bv).unwrap_or(true) {
                            best = Some((c.clone(), v));
                        }
                    }
                    (best.map(|(s, _)| s).unwrap_or_default(), calls)
                }
            }
        });
        job.stages.push(stage2);
        let (solution, extra) = out2.pop().unwrap();
        oracle_calls += extra;

        let value = problem.global().eval(&solution);
        RunMetrics {
            name: self.label().to_string(),
            solution,
            value,
            oracle_calls,
            job,
            rounds: 2,
            stream: None,
            tree: None,
            fault: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::greedi::{centralized, Greedi};
    use crate::coordinator::FacilityProblem;
    use crate::data::synth::{gaussian_blobs, SynthConfig};
    use crate::util::stats::mean;
    use std::sync::Arc;

    fn problem(n: usize, seed: u64) -> FacilityProblem {
        let ds = Arc::new(gaussian_blobs(&SynthConfig::tiny_images(n, 8), seed));
        FacilityProblem::new(&ds)
    }

    #[test]
    fn all_respect_budget() {
        let p = problem(200, 51);
        for b in Baseline::ALL {
            let r = b.run(&p, &RunSpec::new(5, 10).seed(3));
            assert!(r.solution.len() <= 10, "{} gave {}", b.label(), r.solution.len());
            assert!(r.value.is_finite());
            assert_eq!(r.rounds, 2);
        }
    }

    #[test]
    fn greedi_dominates_baselines_on_average() {
        let p = problem(300, 52);
        let k = 10;
        let m = 5;
        let mut greedi_vals = Vec::new();
        let mut base_vals: Vec<Vec<f64>> = vec![Vec::new(); 4];
        for seed in 0..3 {
            greedi_vals.push(Greedi.run(&p, &RunSpec::new(m, k).seed(seed)).value);
            for (i, b) in Baseline::ALL.iter().enumerate() {
                base_vals[i].push(b.run(&p, &RunSpec::new(m, k).seed(seed)).value);
            }
        }
        let g = mean(&greedi_vals);
        for (i, b) in Baseline::ALL.iter().enumerate() {
            let bv = mean(&base_vals[i]);
            assert!(g >= bv - 1e-9, "greedi {g} < {} {bv}", b.label());
        }
        // and random/random must be clearly worse
        assert!(g > 1.02 * mean(&base_vals[0]), "greedi {g} vs random/random");
    }

    #[test]
    fn ordering_random_random_weakest() {
        let p = problem(250, 53);
        let rr: Vec<f64> = (0..4)
            .map(|s| Baseline::RandomRandom.run(&p, &RunSpec::new(5, 8).seed(s)).value)
            .collect();
        let gm: Vec<f64> = (0..4)
            .map(|s| Baseline::GreedyMax.run(&p, &RunSpec::new(5, 8).seed(s)).value)
            .collect();
        assert!(mean(&gm) > mean(&rr));
    }

    #[test]
    fn baselines_below_centralized() {
        let p = problem(200, 54);
        let c = centralized(&p, 8, "lazy", 1);
        for b in Baseline::ALL {
            let r = b.run(&p, &RunSpec::new(4, 8).seed(1));
            assert!(r.value <= c.value + 1e-9, "{}", b.label());
        }
    }
}
